//! End-to-end driver: train a **true-scale ~100M-parameter** decoder
//! transformer with LOTION at INT4 on the synthetic corpus, logging the
//! loss curve — the full three-layer stack (rust coordinator → PJRT →
//! scanned JAX train program → Pallas quantization kernels) on a real
//! workload.
//!
//! Fastest with the e2e artifact set + a `--features pjrt` build:
//!     cd python && python -m compile.aot --out ../artifacts --set e2e
//! but also runs fully offline on the native transformer interpreter
//! (no artifacts, pure rust — much slower at this scale). Either way:
//!     cargo run --release --example e2e_train_lm -- [steps] [model]
//!
//! On this 1-core CPU testbed a step of the 100M config takes tens of
//! seconds; the default is a short smoke budget (EXPERIMENTS.md §E2E
//! records a longer run). Pass a different step count / model
//! (e.g. `lm-150m-sim`) to scale the run to your machine.

use anyhow::{Context, Result};
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::runtime::{auto_executor, Executor, Role};
use std::path::Path;

fn main() -> Result<()> {
    lotion::util::logging::init();
    let args: Vec<String> = std::env::args().collect();
    let steps: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(12);
    let model = args.get(2).cloned().unwrap_or_else(|| "lm-100m".to_string());

    // auto backend: PJRT when this build has the feature + the e2e
    // artifact set, else the native transformer interpreter (which
    // registers every lm-* preset, so this runs fully offline too —
    // expect tens of seconds per lm-100m step on the pure-rust path)
    let engine = auto_executor(Path::new("artifacts"))?;
    let engine: &dyn Executor = &*engine;
    let mut cfg = RunConfig::default();
    cfg.name = format!("e2e_{model}");
    cfg.model = model.clone();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = steps;
    cfg.lr = 1e-3;
    cfg.lambda = 300.0;
    cfg.eval_every = steps.max(1);
    cfg.schedule = Schedule::Cosine { warmup: steps / 10, final_frac: 0.1 };

    // batch geometry straight from the manifest
    let train = engine
        .manifest()
        .find_train(&cfg.model, &cfg.method, &cfg.format)
        .context("e2e artifacts missing — run: cd python && python -m compile.aot --out ../artifacts --set e2e (then build with --features pjrt)")?;
    let data = train.inputs.iter().find(|s| s.role == Role::Data).context("no data input")?;
    let (batch, t1) = (data.shape[1], data.shape[2]);
    let params: usize = train
        .inputs
        .iter()
        .filter(|s| s.role == Role::Param)
        .map(|s| s.elements())
        .sum();
    println!(
        "e2e: model={model} params={:.1}M batch={batch} seq={} steps={steps}",
        params as f64 / 1e6,
        t1 - 1
    );

    let corpus = ZipfMarkovCorpus::generate(4_000_000, 2048, 4, 7);
    let tokens = ByteTokenizer::new().encode(&corpus.bytes);
    let batcher = TokenBatcher::new(tokens, batch, t1 - 1, 0.05);

    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(engine, cfg.clone(), vec![], DataSource::Tokens(batcher))?;
    println!("init + state setup: {:.1}s", t0.elapsed().as_secs_f64());

    let mut eval = Evaluator::new(cfg.seed);
    let mut metrics = MetricsLogger::to_file(Path::new("results/e2e/metrics.jsonl"))?;
    let t0 = std::time::Instant::now();
    while trainer.step < cfg.steps {
        let (base, total) = trainer.chunk(&mut metrics)?;
        let tokens_seen = trainer.step * batch * (t1 - 1);
        println!(
            "step {:>5}  loss {base:.4}  (+penalty {:.4})  {:.2} s/step  {:.0} tok/s",
            trainer.step,
            total - base,
            t0.elapsed().as_secs_f64() / trainer.step as f64,
            tokens_seen as f64 / t0.elapsed().as_secs_f64(),
        );
    }
    eval.eval_all(&trainer, &mut metrics)?;
    println!("\nfinal evals:");
    for p in metrics.eval_points.iter().rev().take(3) {
        println!("  {}/{}: {:.4}", p.format, p.rounding, p.val_loss);
    }
    println!("loss curve + evals -> results/e2e/metrics.jsonl");
    Ok(())
}
