//! The paper's §4.1 linear-regression story in miniature: train the
//! same problem with all four methods (LOTION / QAT / RAT / PTQ) and
//! print the INT4 quantized validation losses side by side — a fast,
//! small-d version of `lotion-rs exp fig2`. Runs on the native backend
//! with no artifacts (or on PJRT when built with it).
//!
//!     cargo run --release --example linreg_lotion

use anyhow::Result;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::synth::population_loss;
use lotion::experiments::common::synth_statics;
use lotion::quant::{cast, QuantFormat, Rounding};
use lotion::runtime::{auto_executor, Executor};
use lotion::util::rng::Rng;
use std::path::Path;

const D: usize = 256; // the smoke-set problem; fig2 runs d=12000

fn main() -> Result<()> {
    lotion::util::logging::init();
    let engine = auto_executor(Path::new("artifacts"))?;
    let engine: &dyn Executor = &*engine;

    println!("{:<10} {:>12} {:>12} {:>12}", "method", "fp32", "int4/RTN", "int4/RR");
    for method in ["lotion", "qat", "rat", "ptq"] {
        let mut cfg = RunConfig::default();
        cfg.name = format!("linreg_{method}");
        cfg.model = format!("linreg_d{D}");
        cfg.method = method.into();
        cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
        cfg.eval_formats = vec!["int4".into()];
        cfg.steps = 400;
        cfg.lr = 0.1;
        cfg.lambda = 1.0; // exact GN diagonal: Eq. 3 is parameter-free here
        cfg.eval_every = 400;
        cfg.schedule = Schedule::Cosine { warmup: 0, final_frac: 0.05 };

        let (statics, _, _) = synth_statics(D, 42);
        let mut trainer = Trainer::new(engine, cfg.clone(), statics, DataSource::InGraph)?;
        let mut eval = Evaluator::new(0);
        let mut metrics = MetricsLogger::in_memory();
        trainer.run(&mut eval, &mut metrics)?;
        println!(
            "{:<10} {:>12.5} {:>12.5} {:>12.5}",
            method,
            metrics.final_eval("fp32", "none").unwrap(),
            metrics.final_eval("int4", "rtn").unwrap(),
            metrics.final_eval("int4", "rr").unwrap(),
        );
    }

    // the paper's PTQ oracle: quantize the target w* directly
    let (_, lam, wstar) = synth_statics(D, 42);
    let fmt = QuantFormat::int4();
    let mut rng = Rng::new(1);
    for (r, name) in [(Rounding::Rtn, "RTN"), (Rounding::Rr, "RR")] {
        let mut wq = wstar.clone();
        cast(&mut wq, &fmt, r, &mut rng);
        println!("PTQ(w*)/{name}: {:.5}", population_loss(&wq, &wstar, &lam));
    }
    Ok(())
}
