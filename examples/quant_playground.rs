//! Quantization playground: inspect what the paper's §2.1 quantizer and
//! §3.1 randomized rounding actually do to a tensor — scales, codes,
//! per-coordinate RR variance (sigma^2 = s^2 Δ(1-Δ)), and the LOTION
//! penalty — across INT4 / INT8 / FP4, per-tensor and block-wise.
//!
//!     cargo run --release --example quant_playground

use lotion::quant::{cast_rr, cast_rtn, lotion_penalty, sigma2, QuantFormat};
use lotion::util::rng::Rng;

fn main() {
    let mut rng = Rng::new(7);
    let n = 4096;
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.05).collect();
    let fisher = vec![1.0f32; n];

    println!(
        "{:<10} {:>8} {:>12} {:>14} {:>14} {:>14}",
        "format", "block", "scale[0]", "rtn err (rms)", "rr err (rms)", "penalty"
    );
    for fmt_name in ["int4", "int8", "fp4"] {
        for block in [0usize, 64] {
            let fmt = QuantFormat::parse(fmt_name, block).unwrap();
            let scales = lotion::quant::blocks::block_scales(&w, &fmt);

            let mut rtn = w.clone();
            cast_rtn(&mut rtn, &fmt);
            let mut rr = w.clone();
            cast_rr(&mut rr, &fmt, &mut rng);
            let rms = |q: &[f32]| {
                (w.iter().zip(q).map(|(a, b)| ((a - b) as f64).powi(2)).sum::<f64>()
                    / n as f64)
                    .sqrt()
            };
            println!(
                "{:<10} {:>8} {:>12.6} {:>14.6} {:>14.6} {:>14.6}",
                fmt_name,
                if block == 0 { "tensor".to_string() } else { block.to_string() },
                scales[0],
                rms(&rtn),
                rms(&rr),
                lotion_penalty(&w, &fisher, &fmt),
            );
            // the RR identity: E[rr err^2] per coord == sigma2
            let s2 = sigma2(&w, &fmt);
            let mean_s2: f64 = s2.iter().map(|&v| v as f64).sum::<f64>() / n as f64;
            assert!((rms(&rr).powi(2) - mean_s2).abs() < mean_s2 * 0.5 + 1e-9);
        }
    }

    // show the INT4 codes of a few values, paper-style
    println!("\nINT4 per-tensor codes of the first 8 weights:");
    let fmt = QuantFormat::int4();
    let s = lotion::quant::blocks::block_scales(&w, &fmt)[0];
    for &v in w.iter().take(8) {
        let z = v / s;
        println!(
            "  w={v:+.5}  z={z:+.3}  code={:+.0}  cast={:+.5}  sigma2={:.2e}",
            fmt.rtn(z),
            fmt.rtn(z) * s,
            s * s * (z - z.floor()) * (1.0 - (z - z.floor()))
        );
    }
}
