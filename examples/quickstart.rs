//! Quickstart: train the §4.1 linear-regression testbed with LOTION at
//! INT4 on the native pure-rust backend and print the quantized
//! validation losses. Runs out of the box — no artifacts, no python:
//!
//!     cargo run --release --example quickstart
//!
//! (With `make artifacts` + `--features pjrt` the same code runs the
//! AOT/XLA path instead; `auto_executor` picks whichever is available.)

use anyhow::Result;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::experiments::common::synth_statics;
use lotion::runtime::{auto_executor, Executor};
use std::path::Path;

const D: usize = 256;

fn main() -> Result<()> {
    lotion::util::logging::init();

    // 1. pick a backend: PJRT if artifacts exist (and the feature is
    //    compiled in), the native pure-rust engine otherwise
    let engine = auto_executor(Path::new("artifacts"))?;
    let engine: &dyn Executor = &*engine;

    // 2. configure a run: LOTION at INT4 on the smoke-scale linreg
    let mut cfg = RunConfig::default();
    cfg.name = "quickstart".into();
    cfg.model = format!("linreg_d{D}");
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 400;
    cfg.lr = 0.1;
    cfg.lambda = 1.0; // exact GN diagonal: Eq. 3 is parameter-free here
    cfg.eval_every = 80;
    cfg.schedule = Schedule::Cosine { warmup: 0, final_frac: 0.05 };

    // 3. statics: the power-law spectrum and the target w*
    let (statics, _, _) = synth_statics(D, 42);

    // 4. train; quantized eval (RTN + RR casts in rust) happens
    //    automatically at every eval point
    let mut trainer = Trainer::new(engine, cfg.clone(), statics, DataSource::InGraph)?;
    let mut eval = Evaluator::new(cfg.seed);
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics)?;

    println!("\nquickstart results after {} steps:", trainer.step);
    println!("  fp32 val loss:      {:.5}", metrics.final_eval("fp32", "none").unwrap());
    println!("  int4 val loss RTN:  {:.5}", metrics.final_eval("int4", "rtn").unwrap());
    println!("  int4 val loss RR:   {:.5}", metrics.final_eval("int4", "rr").unwrap());
    println!(
        "  train loss: {:.5} -> {:.5}",
        metrics.train_losses.first().unwrap().1,
        metrics.train_losses.last().unwrap().1
    );
    Ok(())
}
