//! Quickstart: train a tiny LM with LOTION at INT4 for a few hundred
//! steps and print the quantized validation losses.
//!
//!     make artifacts && cargo run --release --example quickstart

use anyhow::Result;
use lotion::config::RunConfig;
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::runtime::Engine;
use std::path::Path;

fn main() -> Result<()> {
    lotion::util::logging::init();

    // 1. the engine loads AOT artifacts (HLO text + manifest) over PJRT
    let engine = Engine::new(Path::new("artifacts"))?;

    // 2. configure a run: LOTION at INT4 on the lm-tiny preset
    let mut cfg = RunConfig::default();
    cfg.name = "quickstart".into();
    cfg.model = "lm-tiny".into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 200;
    cfg.lr = 3e-3;
    cfg.lambda = 100.0;
    cfg.eval_every = 40;

    // 3. data: synthetic Zipf–Markov corpus through the byte tokenizer
    let corpus = ZipfMarkovCorpus::generate(500_000, 1024, 4, 7);
    let tokens = ByteTokenizer::new().encode(&corpus.bytes);
    let batcher = TokenBatcher::new(tokens, 8, 64, 0.1);

    // 4. train; quantized eval (RTN + RR) happens automatically
    let mut trainer = Trainer::new(&engine, cfg.clone(), vec![], DataSource::Tokens(batcher))?;
    let mut eval = Evaluator::new(&engine, &cfg.model, cfg.seed)?;
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics)?;

    println!("\nquickstart results after {} steps:", trainer.step);
    println!("  fp32 val loss:      {:.4}", metrics.final_eval("fp32", "none").unwrap());
    println!("  int4 val loss RTN:  {:.4}", metrics.final_eval("int4", "rtn").unwrap());
    println!("  int4 val loss RR:   {:.4}", metrics.final_eval("int4", "rr").unwrap());
    println!(
        "  train loss: {:.4} -> {:.4}",
        metrics.train_losses.first().unwrap().1,
        metrics.train_losses.last().unwrap().1
    );
    Ok(())
}
