"""AOT pipeline: lower every program to HLO *text* + write the manifest.

Run once at build time (``make artifacts``); python never appears on the
request path. Interchange is HLO text, not serialized protos — jax>=0.5
emits 64-bit instruction ids that the image's xla_extension 0.5.1
rejects, while the text parser reassigns ids cleanly (see
/opt/xla-example/README.md).

Usage:  cd python && python -m compile.aot --out ../artifacts --set default
Sets:   smoke    tiny fixtures for fast tests
        synth    linreg d=12000 + linear2 k-sweep (Figs. 2/3/7/8)
        lm       the 150m-sim / 300m-sim presets (Figs. 1/4/5/9-12, Tabs. 1-2)
        default  smoke + synth + lm
        e2e      the true-scale lm-100m config (examples/e2e_train_lm.rs)
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
from jax._src.lib import xla_client as xc

from . import manifest, optim, programs
from .kernels import make_format
from .models import linear2, linreg, transformer

# Hidden dims for the Fig. 3 / Fig. 8 k-sweep.
LINEAR2_KS = (1, 2, 4, 8, 16, 32)
# Synthetic problem dimension (§4.1/§4.2).
SYNTH_D = 12000


def to_hlo_text(prog: programs.Program) -> str:
    lowered = jax.jit(prog.fn, keep_unused=True).lower(*programs.example_args(prog))
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _adapter_lm(preset: str, batch: int) -> programs.ModelAdapter:
    lm = transformer.PRESETS[preset]
    return programs.make_adapter("lm", programs.LMTrainConfig(lm, batch=batch))


def _train(ad, method, fmt_name, opt_name, K, block=0, **opt_kw):
    fmt = make_format(fmt_name, block) if fmt_name != "none" else None
    return programs.build_train_program(
        ad, method, fmt, optim.make_optimizer(opt_name, **opt_kw), K
    )


def set_smoke() -> list:
    """Small fixtures exercised by rust integration tests + quickstart."""
    out = []
    ad = programs.make_adapter("linreg", linreg.LinRegConfig(d=256, batch=64))
    for m in ("ptq", "qat", "rat", "lotion"):
        out.append(_train(ad, m, "none" if m == "ptq" else "int4", "sgd", 8))
    out.append(programs.build_eval_program(ad))
    out.append(programs.build_init_program(ad))
    adlm = _adapter_lm("lm-tiny", batch=8)
    for m, f in (("ptq", "none"), ("qat", "int4"), ("rat", "int4"),
                 ("lotion", "int4"), ("lotion", "fp4")):
        out.append(_train(adlm, m, f, "adamw", 4))
    out.append(programs.build_eval_program(adlm, eval_batches=4))
    out.append(programs.build_init_program(adlm))
    return out


def set_synth() -> list:
    """Figs. 2/7 (linreg) and Figs. 3/8 (linear2 k-sweep), INT4."""
    out = []
    ad = programs.make_adapter("linreg", linreg.LinRegConfig(d=SYNTH_D, batch=128))
    for m in ("ptq", "qat", "rat", "lotion"):
        out.append(_train(ad, m, "none" if m == "ptq" else "int4", "sgd", 16))
    out.append(programs.build_eval_program(ad))
    out.append(programs.build_init_program(ad))
    for k in LINEAR2_KS:
        adk = programs.make_adapter("linear2", linear2.Linear2Config(d=SYNTH_D, k=k))
        for m in ("ptq", "qat", "lotion"):
            out.append(_train(adk, m, "none" if m == "ptq" else "int4", "sgd", 16))
        out.append(programs.build_eval_program(adk))
        out.append(programs.build_init_program(adk))
    return out


def set_lm() -> list:
    """LM presets for Figs. 1/4/5/9-12 + Tables 1-2 (CPU-scaled)."""
    out = []
    ad150 = _adapter_lm("lm-150m-sim", batch=4)
    out.append(_train(ad150, "ptq", "none", "adamw", 8))
    for f in ("int4", "int8", "fp4"):
        out.append(_train(ad150, "qat", f, "adamw", 8))
        out.append(_train(ad150, "lotion", f, "adamw", 8))
    for f in ("int4", "int8"):
        out.append(_train(ad150, "rat", f, "adamw", 8))
    out.append(programs.build_eval_program(ad150, eval_batches=8))
    out.append(programs.build_init_program(ad150))

    ad300 = _adapter_lm("lm-300m-sim", batch=4)
    out.append(_train(ad300, "ptq", "none", "adamw", 8))
    for f in ("int4", "int8"):
        out.append(_train(ad300, "qat", f, "adamw", 8))
        out.append(_train(ad300, "lotion", f, "adamw", 8))
    out.append(programs.build_eval_program(ad300, eval_batches=8))
    out.append(programs.build_init_program(ad300))
    return out


def set_e2e() -> list:
    """True-scale ~100M-param config for the end-to-end example."""
    ad = _adapter_lm("lm-100m", batch=4)
    return [
        _train(ad, "lotion", "int4", "adamw", 4),
        _train(ad, "qat", "int4", "adamw", 4),
        programs.build_eval_program(ad, eval_batches=2),
        programs.build_init_program(ad),
    ]


SETS = {
    "smoke": set_smoke,
    "synth": set_synth,
    "lm": set_lm,
    "e2e": set_e2e,
}


def build(out_dir: str, set_names: list) -> None:
    os.makedirs(out_dir, exist_ok=True)
    progs: list = []
    for s in set_names:
        progs.extend(SETS[s]())
    # merge with an existing manifest so sets can be built incrementally
    entries = {}
    mpath = os.path.join(out_dir, "manifest.json")
    if os.path.exists(mpath):
        import json

        with open(mpath) as f:
            entries = json.load(f).get("artifacts", {})
    # prune entries whose artifact files have been removed/renamed
    entries = {
        k: v
        for k, v in entries.items()
        if os.path.exists(os.path.join(out_dir, v["file"]))
    }
    t_all = time.time()
    for prog in progs:
        fname = prog.name + ".hlo.txt"
        fpath = os.path.join(out_dir, fname)
        t0 = time.time()
        if os.path.exists(fpath) and prog.name in entries:
            print(f"  [skip] {prog.name}")
            continue
        txt = to_hlo_text(prog)
        with open(fpath, "w") as f:
            f.write(txt)
        entries[prog.name] = manifest.program_entry(prog, fname)
        print(f"  [{time.time()-t0:5.1f}s] {prog.name}  ({len(txt)//1024} KiB)")
        sys.stdout.flush()
    manifest.write_manifest(entries, out_dir)
    print(f"wrote {len(progs)} programs in {time.time()-t_all:.1f}s -> {mpath}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--set", default="default", help="|".join(list(SETS) + ["default"]))
    args = ap.parse_args()
    names = ["smoke", "synth", "lm"] if args.set == "default" else [args.set]
    build(args.out, names)


if __name__ == "__main__":
    main()
