"""Layer-1 Pallas kernels + pure-jnp oracles for LOTION quantization."""

from .common import FP4_LEVELS, FP4_QMAX, QuantFormat, make_format  # noqa: F401
from .pallas_ops import (  # noqa: F401
    fake_quant,
    lotion_penalty,
    penalty_grad,
    penalty_value,
    sigma2,
    ste_fake_quant,
    ste_stochastic_round,
    stochastic_round,
)
