"""Shared quantization-format definitions and block-partitioning helpers.

The paper (§2.1) uses fine-grained shared-scale symmetric quantization:
parameters are partitioned into blocks ``B``; each block stores one FP16
scale ``s_B = absmax(block) / qmax`` and an n-bit code per element.

Two format families are implemented:

* ``int<n>`` — uniform signed-integer lattice; ``qmax = 2^(n-1) - 1``
  (INT4 → 7, INT8 → 127). The representable scaled values are the
  integers ``[-qmax, qmax]``.
* ``fp4`` — the E2M1 codebook used by NVFP4/MXFP4-style formats
  (§4.3.3): ``±{0, 0.5, 1, 1.5, 2, 3, 4, 6}``; ``qmax = 6``. The
  scaled lattice is non-uniform, denser near zero.

``block_size == 0`` means per-tensor scaling, which is what the paper's
experiments use ("we scale the entire tensor", §4).
"""

from __future__ import annotations

import dataclasses
import math

import jax.numpy as jnp
import numpy as np

# E2M1 magnitude codebook (positive half, ascending). Full lattice is the
# signed union, 15 distinct values (zero appears once).
FP4_POS = (0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0)
FP4_LEVELS = tuple(sorted({-v for v in FP4_POS} | set(FP4_POS)))
FP4_QMAX = 6.0


@dataclasses.dataclass(frozen=True)
class QuantFormat:
    """A weight quantization format: a scaled lattice + block partitioning."""

    name: str            # "int4" | "int8" | "fp4"
    bits: int
    qmax: float          # scaled dynamic range: absmax maps to +-qmax
    uniform: bool        # True => integer lattice, False => codebook
    block_size: int = 0  # elements per shared-scale block; 0 = per-tensor

    @property
    def levels(self) -> np.ndarray:
        """The sorted scaled lattice (codebook formats only)."""
        if self.uniform:
            q = int(self.qmax)
            return np.arange(-q, q + 1, dtype=np.float32)
        return np.asarray(FP4_LEVELS, dtype=np.float32)

    def with_block(self, block_size: int) -> "QuantFormat":
        return dataclasses.replace(self, block_size=block_size)


def make_format(name: str, block_size: int = 0) -> QuantFormat:
    """Parse a format name ("int4", "int8", "fp4") into a QuantFormat."""
    name = name.lower()
    if name.startswith("int"):
        bits = int(name[3:])
        if not 2 <= bits <= 8:
            raise ValueError(f"unsupported int bit-width: {name}")
        return QuantFormat(name, bits, float(2 ** (bits - 1) - 1), True, block_size)
    if name == "fp4":
        return QuantFormat(name, 4, FP4_QMAX, False, block_size)
    raise ValueError(f"unknown quantization format: {name!r}")


def num_blocks(n: int, block_size: int) -> int:
    if block_size <= 0:
        return 1
    return -(-n // block_size)


def to_blocks(w: jnp.ndarray, block_size: int) -> tuple[jnp.ndarray, int]:
    """Flatten ``w`` and reshape into ``[num_blocks, block]`` with zero pad.

    Returns the blocked view and the original element count. Zero padding
    is harmless for absmax scales (zeros never dominate) and padded lanes
    are masked out of penalties by callers via the returned count.
    """
    flat = w.reshape(-1)
    n = flat.shape[0]
    bs = block_size if block_size > 0 else n
    nb = num_blocks(n, bs)
    pad = nb * bs - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(nb, bs), n


def from_blocks(blocked: jnp.ndarray, n: int, shape) -> jnp.ndarray:
    """Inverse of :func:`to_blocks`."""
    return blocked.reshape(-1)[:n].reshape(shape)


def pick_kernel_block(n: int, requested: int = 0, cap: int = 65536) -> int:
    """Choose the Pallas grid block length for an ``n``-element tensor.

    For per-tensor scaling the *scale* is global but the kernel still
    streams the tensor through VMEM-sized tiles; this picks the tile.
    """
    if requested > 0:
        return requested
    if n <= cap:
        # Round up to the next multiple of the 128-lane vector width so a
        # single grid step covers the tensor.
        return max(128, int(128 * math.ceil(n / 128)))
    return cap
