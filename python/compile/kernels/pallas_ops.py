"""Layer-1 Pallas kernels for LOTION's quantization hot paths.

Four kernels implement the paper's per-parameter math as single-pass
tiled programs:

* ``absmax rows``      — per-block absmax reduction feeding the shared
                         scales ``s_B`` (§2.1).
* ``fake quant``       — round-to-nearest cast onto the scaled lattice.
* ``stochastic round`` — unbiased randomized rounding (§3.1, A.2.4).
* ``lotion penalty``   — fused ``0.5 * sum f_i s^2 var_i`` value kernel
                         and its analytic gradient kernel (Eq. 3), wired
                         together with ``jax.custom_vjp``.

TPU mapping (DESIGN.md §Hardware-Adaptation): the Pallas grid iterates
over shared-scale blocks; each grid step holds one ``(1, block)`` tile of
``w`` (plus ``fisher``/noise tiles) in VMEM, so every operand is read
from HBM exactly once. ``interpret=True`` everywhere — the CPU PJRT
plugin cannot execute Mosaic custom-calls; interpret mode lowers the
same schedule to plain HLO, which is what the AOT pipeline ships to the
rust runtime.

All kernels take a per-row ``scales`` operand so that per-tensor scaling
(one scale broadcast over many tiles) and fine-grained block scaling
(one scale per tile row) share one code path.
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .common import QuantFormat, pick_kernel_block

INTERPRET = True  # CPU PJRT cannot run Mosaic custom-calls; see module doc.


# ---------------------------------------------------------------------------
# Tiling helpers
# ---------------------------------------------------------------------------


def _tile_rows(w: jnp.ndarray, fmt: QuantFormat) -> tuple[jnp.ndarray, int, int]:
    """Reshape ``w`` into ``[rows, tile]`` for the kernel grid.

    For block formats the rows *are* the shared-scale blocks. For
    per-tensor formats the rows are VMEM-sized tiles that all share one
    scale. Returns (tiled, n_orig, tile).
    """
    flat = w.reshape(-1)
    n = flat.shape[0]
    tile = pick_kernel_block(n, fmt.block_size)
    rows = -(-n // tile)
    pad = rows * tile - n
    if pad:
        flat = jnp.concatenate([flat, jnp.zeros((pad,), flat.dtype)])
    return flat.reshape(rows, tile), n, tile


def _untile(tiled: jnp.ndarray, n: int, shape) -> jnp.ndarray:
    return tiled.reshape(-1)[:n].reshape(shape)


# ---------------------------------------------------------------------------
# absmax / scales
# ---------------------------------------------------------------------------


def _absmax_rows_kernel(w_ref, o_ref):
    o_ref[0, 0] = jnp.max(jnp.abs(w_ref[...]))


def absmax_rows(tiled: jnp.ndarray) -> jnp.ndarray:
    """Per-row absolute maximum, shape ``[rows, 1]``."""
    rows, tile = tiled.shape
    return pl.pallas_call(
        _absmax_rows_kernel,
        grid=(rows,),
        in_specs=[pl.BlockSpec((1, tile), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, 1), tiled.dtype),
        interpret=INTERPRET,
    )(tiled)


def row_scales(tiled: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Shared scales per kernel row (``[rows, 1]``).

    Per-tensor formats finish the hierarchical reduction across tiles
    with a tiny ``[rows]``-length max — the realistic two-phase schedule
    for tensors larger than VMEM.
    """
    amax = absmax_rows(tiled)
    if fmt.block_size <= 0:
        amax = jnp.broadcast_to(jnp.max(amax), amax.shape)
    s = amax / fmt.qmax
    return jnp.where(amax > 0, s, jnp.ones_like(s))


# ---------------------------------------------------------------------------
# lattice math (shared between kernels; operates on VMEM-resident tiles)
# ---------------------------------------------------------------------------


def _bracket(z: jnp.ndarray, levels: np.ndarray):
    """Gather-free enclosing levels: l = max level <= z, u = min level >= z.

    Unrolled over the (small, compile-time) codebook with scalar
    constants only — Pallas kernels may not capture array constants, and
    a 15-way unrolled vector select is exactly what a real TPU kernel
    would emit for an E2M1 codebook.
    """
    u = jnp.full_like(z, np.inf)
    l_ = jnp.full_like(z, -np.inf)
    for lev in [float(v) for v in levels]:
        u = jnp.where((lev >= z) & (lev < u), lev, u)
        l_ = jnp.where((lev <= z) & (lev > l_), lev, l_)
    return l_, u


def _rtn(z: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    if fmt.uniform:
        return jnp.clip(jnp.round(z), -fmt.qmax, fmt.qmax)
    l_, u = _bracket(z, fmt.levels)
    mid = (l_ + u) * 0.5
    return jnp.where(z > mid, u, l_)


def _var(z: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    if fmt.uniform:
        delta = z - jnp.floor(z)
        return delta * (1.0 - delta)
    l_, u = _bracket(z, fmt.levels)
    return (u - z) * (z - l_)


def _dvar_dz(z: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    if fmt.uniform:
        delta = z - jnp.floor(z)
        return 1.0 - 2.0 * delta
    l_, u = _bracket(z, fmt.levels)
    return u + l_ - 2.0 * z


# ---------------------------------------------------------------------------
# element-wise kernels
# ---------------------------------------------------------------------------


def _fake_quant_kernel(fmt: QuantFormat, w_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    z = w_ref[...] / s
    o_ref[...] = _rtn(z, fmt) * s


def _stoch_round_kernel(fmt: QuantFormat, w_ref, u_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    z = w_ref[...] / s
    if fmt.uniform:
        l_ = jnp.floor(z)
        u = l_ + 1.0
        p_up = z - l_
    else:
        l_, u = _bracket(z, fmt.levels)
        gap = u - l_
        p_up = jnp.where(gap > 0, (z - l_) / jnp.where(gap > 0, gap, 1.0), 0.0)
    q = jnp.where(u_ref[...] < p_up, u, l_)
    if fmt.uniform:
        q = jnp.clip(q, -fmt.qmax, fmt.qmax)
    o_ref[...] = q * s


def _sigma2_kernel(fmt: QuantFormat, w_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    z = w_ref[...] / s
    o_ref[...] = (s * s) * _var(z, fmt)


def _penalty_value_kernel(fmt: QuantFormat, w_ref, f_ref, s_ref, acc_ref):
    # Sequential-grid accumulation: one scalar accumulator revisited by
    # every grid step (zero-padded lanes have z on-lattice => var == 0).
    s = s_ref[0, 0]
    z = w_ref[...] / s
    part = 0.5 * jnp.sum(f_ref[...] * (s * s) * _var(z, fmt))

    @pl.when(pl.program_id(0) == 0)
    def _init():
        acc_ref[0, 0] = jnp.zeros((), acc_ref.dtype)

    acc_ref[0, 0] += part


def _penalty_grad_kernel(fmt: QuantFormat, w_ref, f_ref, s_ref, o_ref):
    s = s_ref[0, 0]
    z = w_ref[...] / s
    o_ref[...] = 0.5 * f_ref[...] * s * _dvar_dz(z, fmt)


def _elementwise_call(kernel: Callable, fmt: QuantFormat, w: jnp.ndarray, *extra):
    """Run an elementwise tile kernel over (w, *extra, scales)."""
    tiled, n, tile = _tile_rows(w, fmt)
    rows = tiled.shape[0]
    extra_tiled = [_tile_rows(e, fmt)[0] for e in extra]
    scales = row_scales(tiled, fmt)
    specs = [pl.BlockSpec((1, tile), lambda i: (i, 0))] * (1 + len(extra)) + [
        pl.BlockSpec((1, 1), lambda i: (i, 0))
    ]
    out = pl.pallas_call(
        functools.partial(kernel, fmt),
        grid=(rows,),
        in_specs=specs,
        out_specs=pl.BlockSpec((1, tile), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, tile), w.dtype),
        interpret=INTERPRET,
    )(tiled, *extra_tiled, scales)
    return _untile(out, n, w.shape)


# ---------------------------------------------------------------------------
# public kernel API
# ---------------------------------------------------------------------------


def fake_quant(w: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Round-to-nearest cast onto the scaled lattice (Pallas)."""
    return _elementwise_call(_fake_quant_kernel, fmt, w)


def stochastic_round(w: jnp.ndarray, fmt: QuantFormat, u01: jnp.ndarray) -> jnp.ndarray:
    """Unbiased randomized-rounding cast (Pallas). ``u01 ~ U(0,1)``."""
    return _elementwise_call(_stoch_round_kernel, fmt, w, u01)


def sigma2(w: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Per-coordinate RR variance ``s_B^2 * var(z)`` (Pallas)."""
    return _elementwise_call(_sigma2_kernel, fmt, w)


def penalty_value(w: jnp.ndarray, fisher: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Fused LOTION penalty ``0.5 * sum_i fisher_i * sigma_i^2`` (Eq. 3)."""
    tiled, _, tile = _tile_rows(w, fmt)
    ftiled, _, _ = _tile_rows(fisher, fmt)
    rows = tiled.shape[0]
    scales = row_scales(tiled, fmt)
    acc = pl.pallas_call(
        functools.partial(_penalty_value_kernel, fmt),
        grid=(rows,),
        in_specs=[
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, tile), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), w.dtype),
        interpret=INTERPRET,
    )(tiled, ftiled, scales)
    return acc[0, 0]


def penalty_grad(w: jnp.ndarray, fisher: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Analytic penalty gradient (stop-grad through scales and fisher)."""
    return _elementwise_call(_penalty_grad_kernel, fmt, w, fisher)


# -- custom-vjp wrappers -----------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def lotion_penalty(w: jnp.ndarray, fisher: jnp.ndarray, fmt: QuantFormat):
    """Differentiable LOTION penalty: Pallas value fwd, Pallas grad bwd."""
    return penalty_value(w, fisher, fmt)


def _pen_fwd(w, fisher, fmt):
    return penalty_value(w, fisher, fmt), (w, fisher)


def _pen_bwd(fmt, res, g):
    w, fisher = res
    return (g * penalty_grad(w, fisher, fmt), jnp.zeros_like(fisher))


lotion_penalty.defvjp(_pen_fwd, _pen_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def ste_fake_quant(w: jnp.ndarray, fmt: QuantFormat):
    """QAT forward cast with straight-through (identity) backward."""
    return fake_quant(w, fmt)


def _fq_fwd(w, fmt):
    return fake_quant(w, fmt), None


def _fq_bwd(fmt, _res, g):
    return (g,)


ste_fake_quant.defvjp(_fq_fwd, _fq_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def ste_stochastic_round(w: jnp.ndarray, u01: jnp.ndarray, fmt: QuantFormat):
    """RAT forward cast (randomized rounding) with straight-through backward."""
    return stochastic_round(w, fmt, u01)


def _sr_fwd(w, u01, fmt):
    return stochastic_round(w, fmt, u01), u01


def _sr_bwd(fmt, u01, g):
    return (g, jnp.zeros_like(u01))


ste_stochastic_round.defvjp(_sr_fwd, _sr_bwd)
