"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness ground truth: straightforward, unoptimized
implementations of the paper's quantization math (§2.1, §3.1, §3.3,
A.2.4). The pytest suite asserts the Pallas kernels match these
element-for-element across hypothesis-generated shapes/dtypes/blocks.

All functions operate on arbitrary-shape arrays and handle the
``block_size == 0`` (per-tensor scale) case.
"""

from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from . import common
from .common import QuantFormat, from_blocks, to_blocks


def block_scales_ref(w: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Per-block shared scales ``s_B = absmax(B) / qmax`` (§2.1).

    Returns shape ``[num_blocks]``. Blocks whose absmax is zero get scale 1
    so downstream divisions are safe (every element of such a block is 0,
    and 0 is exactly representable in all supported formats).
    """
    blocked, _ = to_blocks(w, fmt.block_size)
    amax = jnp.max(jnp.abs(blocked), axis=1)
    s = amax / fmt.qmax
    return jnp.where(amax > 0, s, 1.0).astype(w.dtype)


def _enclosing_levels(z: jnp.ndarray, levels: np.ndarray):
    """Lower/upper enclosing codebook levels for scaled values ``z``.

    ``z`` is guaranteed in ``[-qmax, qmax]`` by absmax scaling, so the
    clamped searchsorted result always yields a valid bracket. Exact
    lattice points return ``l == u == z``.
    """
    lv = jnp.asarray(levels)
    # index of first level >= z
    hi = jnp.searchsorted(lv, z, side="left")
    hi = jnp.clip(hi, 0, len(levels) - 1)
    lo = jnp.clip(hi - 1, 0, len(levels) - 1)
    u = lv[hi]
    l_ = lv[lo]
    on_lattice = u == z
    l_ = jnp.where(on_lattice, u, l_)
    return l_, u


def fake_quant_ref(w: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Round-to-nearest cast: ``cast(w) = s_B * round_to_lattice(w / s_B)``."""
    blocked, n = to_blocks(w, fmt.block_size)
    s = block_scales_ref(w, fmt)[:, None]
    z = blocked / s
    if fmt.uniform:
        q = jnp.clip(jnp.round(z), -fmt.qmax, fmt.qmax)
    else:
        l_, u = _enclosing_levels(z, fmt.levels)
        mid = (l_ + u) * 0.5
        q = jnp.where(z > mid, u, l_)
    return from_blocks(q * s, n, w.shape).astype(w.dtype)


def stochastic_round_ref(
    w: jnp.ndarray, fmt: QuantFormat, u01: jnp.ndarray
) -> jnp.ndarray:
    """Unbiased randomized rounding (Def. 1, A.2.4).

    ``u01`` is uniform(0,1) noise of the same shape as ``w``. Scaled value
    ``z`` in bracket ``[l, u]`` rounds up with probability ``(z-l)/(u-l)``
    which makes ``E[RR(w)] = w`` exactly.
    """
    blocked, n = to_blocks(w, fmt.block_size)
    ublk, _ = to_blocks(u01, fmt.block_size)
    s = block_scales_ref(w, fmt)[:, None]
    z = blocked / s
    if fmt.uniform:
        l_ = jnp.floor(z)
        up = l_ + 1.0
        p_up = z - l_
    else:
        l_, up = _enclosing_levels(z, fmt.levels)
        gap = up - l_
        p_up = jnp.where(gap > 0, (z - l_) / jnp.where(gap > 0, gap, 1.0), 0.0)
    q = jnp.where(ublk < p_up, up, l_)
    if fmt.uniform:
        q = jnp.clip(q, -fmt.qmax, fmt.qmax)
    return from_blocks(q * s, n, w.shape).astype(w.dtype)


def sigma2_ref(w: jnp.ndarray, fmt: QuantFormat) -> jnp.ndarray:
    """Per-coordinate randomized-rounding variance ``sigma_i^2`` (§3.2/§3.3).

    Uniform lattice:   sigma^2 = s_B^2 * Delta * (1 - Delta)
    Codebook lattice:  sigma^2 = s_B^2 * (u - z) * (z - l)   (generalizes it)
    """
    blocked, n = to_blocks(w, fmt.block_size)
    s = block_scales_ref(w, fmt)[:, None]
    z = blocked / s
    if fmt.uniform:
        delta = z - jnp.floor(z)
        var = delta * (1.0 - delta)
    else:
        l_, up = _enclosing_levels(z, fmt.levels)
        var = (up - z) * (z - l_)
    return from_blocks(s * s * var, n, w.shape).astype(w.dtype)


def lotion_penalty_ref(
    w: jnp.ndarray, fisher: jnp.ndarray, fmt: QuantFormat
) -> jnp.ndarray:
    """LOTION regularizer (Eq. 3): ``0.5 * sum_i fisher_i * sigma_i^2``."""
    return 0.5 * jnp.sum(fisher * sigma2_ref(w, fmt))


def lotion_penalty_grad_ref(
    w: jnp.ndarray, fisher: jnp.ndarray, fmt: QuantFormat
) -> jnp.ndarray:
    """d(penalty)/dw with stop-grad through ``s_B`` and ``fisher``.

    Uniform:  d/dw [0.5 f s^2 D(1-D)] = 0.5 f s (1 - 2 D)
    Codebook: d/dw [0.5 f s^2 (u-z)(z-l)] = 0.5 f s (u + l - 2 z)
    """
    blocked, n = to_blocks(w, fmt.block_size)
    fblk, _ = to_blocks(fisher, fmt.block_size)
    s = block_scales_ref(w, fmt)[:, None]
    z = blocked / s
    if fmt.uniform:
        delta = z - jnp.floor(z)
        d = 1.0 - 2.0 * delta
    else:
        l_, up = _enclosing_levels(z, fmt.levels)
        d = up + l_ - 2.0 * z
    g = 0.5 * fblk * s * d
    return from_blocks(g, n, w.shape).astype(w.dtype)
