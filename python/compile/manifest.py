"""Artifact manifest: the L2→L3 contract, serialized as JSON.

The rust runtime is entirely manifest-driven: it never guesses shapes,
dtypes, argument order, or tuple layout. Every artifact entry records
the flat input/output TensorSpecs in exactly the positional order the
compiled executable expects, plus method/format/model metadata the
coordinator uses to route experiments.
"""

from __future__ import annotations

import json
import os

from .programs import Program


def program_entry(prog: Program, filename: str) -> dict:
    return {
        "file": filename,
        "inputs": [s.to_json() for s in prog.inputs],
        "outputs": [s.to_json() for s in prog.outputs],
        "meta": prog.meta,
    }


def write_manifest(entries: dict, out_dir: str, extra: dict | None = None) -> str:
    doc = {
        "version": 1,
        "generator": "lotion python/compile/aot.py",
        "artifacts": entries,
    }
    if extra:
        doc.update(extra)
    path = os.path.join(out_dir, "manifest.json")
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=1, sort_keys=True)
    os.replace(tmp, path)
    return path
