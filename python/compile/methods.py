"""Training-method transformations: PTQ, QAT, RAT, LOTION (§4).

Each method is a transformation of a base loss ``L(params, batch)``:

* ``ptq``    — train in FP32, quantize post hoc (baseline; the cast
               happens in the rust evaluator, not here).
* ``qat``    — forward pass through round-to-nearest fake-quantized
               weights, straight-through backward (standard QAT).
* ``rat``    — Rounding-Aware Training: forward through *randomly
               rounded* weights, straight-through backward (§3.2).
* ``lotion`` — the paper's contribution: FP32 forward plus the
               curvature-aware penalty  lam * 0.5 sum_i f_i sigma_i^2
               (Eq. 3), with sigma^2 from the L1 Pallas kernel and the
               Fisher diagonal from the optimizer (or exact GN for the
               synthetic models).

All four share one signature so ``programs.py`` can build identical
scanned train programs for every (method, format) pair.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from .kernels import (
    QuantFormat,
    lotion_penalty,
    ste_fake_quant,
    ste_stochastic_round,
)

METHODS = ("ptq", "qat", "rat", "lotion")


def cast_params_qat(params: dict, qkeys: set, fmt: QuantFormat) -> dict:
    """RTN fake-quantize the quantized subset (STE backward)."""
    return {
        k: ste_fake_quant(v, fmt) if k in qkeys else v for k, v in params.items()
    }


def cast_params_rat(params: dict, qkeys: set, fmt: QuantFormat, key) -> dict:
    """Randomized-rounding cast of the quantized subset (STE backward)."""
    out = {}
    for k in sorted(params):
        v = params[k]
        if k in qkeys:
            key, sub = jax.random.split(key)
            u = jax.random.uniform(sub, v.shape, jnp.float32)
            out[k] = ste_stochastic_round(v, u, fmt)
        else:
            out[k] = v
    return out


def lotion_term(
    params: dict, qkeys: set, fmt: QuantFormat, fisher: dict
) -> jnp.ndarray:
    """Total Eq. 3 penalty over the quantized subset (Fisher is stop-grad:
    'we do not differentiate through the empirical Fisher', §4.3)."""
    total = jnp.zeros((), jnp.float32)
    for k in sorted(qkeys):
        f = jax.lax.stop_gradient(fisher[k])
        total = total + lotion_penalty(params[k], f, fmt)
    return total


def make_method_loss(
    method: str,
    base_loss: Callable[[dict], jnp.ndarray],
    qkeys: set,
    fmt: QuantFormat | None,
) -> Callable:
    """Build ``loss(params, key, lam_reg, fisher) -> (total, base)``.

    ``key`` is consumed by RAT only; ``lam_reg``/``fisher`` by LOTION
    only — unused inputs are simply ignored so the scanned program shape
    is method-independent.
    """
    if method not in METHODS:
        raise ValueError(f"unknown method {method!r}")

    def loss_fn(params, key, lam_reg, fisher):
        if method == "qat":
            base = base_loss(cast_params_qat(params, qkeys, fmt))
            return base, base
        if method == "rat":
            base = base_loss(cast_params_rat(params, qkeys, fmt, key))
            return base, base
        base = base_loss(params)
        if method == "lotion":
            pen = lotion_term(params, qkeys, fmt, fisher)
            return base + lam_reg * pen, base
        return base, base  # ptq

    return loss_fn
