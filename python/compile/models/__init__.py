"""Layer-2 models: the paper's three testbeds.

* :mod:`linreg`      — §4.1 linear regression, d=12000, power-law spectrum.
* :mod:`linear2`     — §4.2 two-layer linear network f(x) = (1/k) W2 W1 x.
* :mod:`transformer` — §4.3 decoder-only LM (OLMo-flavoured).

Every model exposes the same interface consumed by ``programs.py``:

``init(key) -> params``            flat {name: array} dict
``loss(params, batch) -> scalar``  training objective
``val_loss(params, batch)``        validation objective
``quantized_keys() -> set[str]``   tensors the quantizer touches
``fisher_exact(params, statics)``  closed-form GN diagonal (or None)
"""

from . import linear2, linreg, transformer  # noqa: F401
