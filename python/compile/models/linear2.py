"""§4.2 two-layer linear network  f(x) = (1/k) W2 W1 x.

The paper trains this with *full gradient descent using the exact
population Hessian*; with a diagonal covariance the population loss has
the closed form

    L(W1, W2) = 1/2 (v - w*)^T diag(lam) (v - w*),   v = (1/k) W1^T W2^T

so both training and validation are exact (no sampling). The exact
Gauss-Newton diagonal used by LOTION:

    G[W1[j,i]] = (W2[0,j]/k)^2 * lam_i
    G[W2[0,j]] = (1/k^2) * sum_i lam_i W1[j,i]^2

The GT baseline of Fig. 3 (W2 = 1, rows of W1 = w*) is constructed by
the rust experiment driver via ``init_gt``-shaped parameters.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Linear2Config:
    d: int = 12000
    k: int = 4
    alpha: float = 1.1

    @property
    def name(self) -> str:
        return f"linear2_d{self.d}_k{self.k}"


def spectrum(cfg: Linear2Config) -> jnp.ndarray:
    return 1.0 / jnp.arange(1, cfg.d + 1, dtype=jnp.float32) ** cfg.alpha


def init(key, cfg: Linear2Config) -> dict:
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (cfg.k, cfg.d), jnp.float32) / jnp.sqrt(cfg.d),
        "w2": jax.random.normal(k2, (1, cfg.k), jnp.float32),
    }


def init_gt(cfg: Linear2Config, wstar: jnp.ndarray) -> dict:
    """Ground-truth construction behind Lemma 4: W2 = 1, rows(W1) = w*."""
    return {
        "w1": jnp.broadcast_to(wstar[None, :], (cfg.k, cfg.d)).astype(jnp.float32),
        "w2": jnp.ones((1, cfg.k), jnp.float32),
    }


def statics(key, cfg: Linear2Config) -> dict:
    wstar = jax.random.normal(key, (cfg.d,), jnp.float32)
    return {"wstar": wstar, "lam": spectrum(cfg)}


def effective_w(params: dict, k: int) -> jnp.ndarray:
    return (params["w2"] @ params["w1"])[0] / k


def loss(params: dict, st: dict, k: int) -> jnp.ndarray:
    """Exact population loss (this model trains full-batch)."""
    dv = effective_w(params, k) - st["wstar"]
    return 0.5 * jnp.sum(st["lam"] * dv * dv)


val_loss = loss


def quantized_keys() -> set:
    return {"w1", "w2"}


def fisher_exact(params: dict, st: dict, k: int) -> dict:
    lam = st["lam"]
    w2 = params["w2"][0]  # [k]
    g_w1 = (w2[:, None] / k) ** 2 * lam[None, :]
    g_w2 = (jnp.sum(lam[None, :] * params["w1"] ** 2, axis=1) / (k * k))[None, :]
    return {"w1": g_w1, "w2": g_w2}
