"""§4.1 linear regression testbed.

Inputs ``x ~ N(0, Sigma)`` with a power-law spectrum ``lambda_i
propto 1/i^1.1`` (diagonal by construction — the spectrum *is* the
covariance in the eigenbasis, which is the basis we work in). Targets
``y = w*^T x``. The population loss has the closed form

    L(w) = 1/2 (w - w*)^T diag(lam) (w - w*)

so validation is exact, while training draws minibatches in-graph from
the PJRT-supplied key (SGD, as in the paper). The Gauss-Newton diagonal
is exactly ``lam``, which LOTION uses directly (no Fisher EMA needed).

``statics`` (non-trained inputs owned by the rust coordinator):
``wstar [d]`` and ``lam [d]``.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LinRegConfig:
    d: int = 12000
    batch: int = 256
    alpha: float = 1.1  # spectrum exponent: lam_i ~ 1/i^alpha

    @property
    def name(self) -> str:
        return f"linreg_d{self.d}"


def spectrum(cfg: LinRegConfig) -> jnp.ndarray:
    lam = 1.0 / jnp.arange(1, cfg.d + 1, dtype=jnp.float32) ** cfg.alpha
    return lam


def init(key, cfg: LinRegConfig) -> dict:
    return {"w": jnp.zeros((cfg.d,), jnp.float32)}


def statics(key, cfg: LinRegConfig) -> dict:
    wstar = jax.random.normal(key, (cfg.d,), jnp.float32)
    return {"wstar": wstar, "lam": spectrum(cfg)}


def sample_batch(key, cfg: LinRegConfig, st: dict):
    """Draw x ~ N(0, diag(lam)) and y = w*.x in-graph."""
    x = jax.random.normal(key, (cfg.batch, cfg.d), jnp.float32) * jnp.sqrt(st["lam"])
    y = x @ st["wstar"]
    return x, y


def loss(params: dict, batch) -> jnp.ndarray:
    x, y = batch
    r = x @ params["w"] - y
    return 0.5 * jnp.mean(r * r)


def val_loss(params: dict, st: dict) -> jnp.ndarray:
    """Exact population loss 1/2 (w-w*)^T diag(lam) (w-w*)."""
    dw = params["w"] - st["wstar"]
    return 0.5 * jnp.sum(st["lam"] * dw * dw)


def quantized_keys() -> set:
    return {"w"}


def fisher_exact(params: dict, st: dict) -> dict:
    """Exact GN diagonal: H = diag(lam)."""
    return {"w": st["lam"]}
