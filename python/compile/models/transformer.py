"""§4.3 decoder-only transformer LM (OLMo-flavoured).

Pre-norm decoder with RMSNorm, rotary position embeddings, SwiGLU MLP,
untied embedding / lm_head, byte-level vocab by default. Written so
every weight tensor is a flat dict entry (canonical AOT layout) and the
quantizer's target set is an explicit list of 2-D matmul weights.

Size presets mirror the paper's 150M/300M pair plus CPU-scaled
"simulation" variants (DESIGN.md §6 records the substitution).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    seq_len: int = 128
    ffn_mult: float = 8.0 / 3.0  # SwiGLU hidden = mult * d_model, rounded

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads

    @property
    def ffn_dim(self) -> int:
        return int(-(-self.ffn_mult * self.d_model // 64) * 64)

    def param_count(self) -> int:
        d, f, L, v = self.d_model, self.ffn_dim, self.n_layers, self.vocab
        per_layer = 4 * d * d + 3 * d * f + 2 * d
        return v * d + L * per_layer + d + d * v


PRESETS = {
    # CPU-scaled stand-ins (DESIGN.md §6): same shape family as the paper's
    # models, sized for a 1-core PJRT CPU testbed (measured ~40 GFLOP/s:
    # these hit ~0.15-0.4 s/step so the full method matrix stays tractable).
    "lm-tiny": LMConfig("lm-tiny", d_model=64, n_layers=2, n_heads=2, seq_len=64),
    "lm-150m-sim": LMConfig("lm-150m-sim", d_model=192, n_layers=4, n_heads=4, seq_len=128),
    "lm-300m-sim": LMConfig("lm-300m-sim", d_model=256, n_layers=6, n_heads=8, seq_len=128),
    # True-scale config (e2e example / smoke run): ~100M params.
    "lm-100m": LMConfig("lm-100m", d_model=768, n_layers=14, n_heads=12, seq_len=256),
}


def init(key, cfg: LMConfig) -> dict:
    """OLMo-style init: normal(0, 0.02), scaled residual out-projections."""
    p = {}
    keys = iter(jax.random.split(key, 4 + 7 * cfg.n_layers))
    sd = 0.02
    d, f = cfg.d_model, cfg.ffn_dim
    p["embed"] = jax.random.normal(next(keys), (cfg.vocab, d), jnp.float32) * sd
    res_sd = sd / jnp.sqrt(2.0 * cfg.n_layers)
    for i in range(cfg.n_layers):
        pre = f"layer{i:02d}."
        p[pre + "attn_wq"] = jax.random.normal(next(keys), (d, d), jnp.float32) * sd
        p[pre + "attn_wk"] = jax.random.normal(next(keys), (d, d), jnp.float32) * sd
        p[pre + "attn_wv"] = jax.random.normal(next(keys), (d, d), jnp.float32) * sd
        p[pre + "attn_wo"] = jax.random.normal(next(keys), (d, d), jnp.float32) * res_sd
        p[pre + "mlp_wgate"] = jax.random.normal(next(keys), (d, f), jnp.float32) * sd
        p[pre + "mlp_wup"] = jax.random.normal(next(keys), (d, f), jnp.float32) * sd
        p[pre + "mlp_wdown"] = jax.random.normal(next(keys), (f, d), jnp.float32) * res_sd
        p[pre + "norm_attn"] = jnp.ones((d,), jnp.float32)
        p[pre + "norm_mlp"] = jnp.ones((d,), jnp.float32)
    p["norm_final"] = jnp.ones((d,), jnp.float32)
    p["lm_head"] = jax.random.normal(next(keys), (d, cfg.vocab), jnp.float32) * sd
    return p


def quantized_keys(cfg: LMConfig) -> set:
    """The 2-D matmul weights the quantizer touches (embeddings and norms
    stay high precision, lm_head is quantized — weight-only scheme)."""
    ks = {"lm_head"}
    for i in range(cfg.n_layers):
        pre = f"layer{i:02d}."
        ks |= {
            pre + n
            for n in (
                "attn_wq", "attn_wk", "attn_wv", "attn_wo",
                "mlp_wgate", "mlp_wup", "mlp_wdown",
            )
        }
    return ks


def _rmsnorm(x, g):
    return x * g * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + 1e-6)


def _rope(x, cfg: LMConfig):
    """Rotary embeddings over the head dim. x: [B, T, H, Dh]."""
    t = x.shape[1]
    dh = cfg.head_dim
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = jnp.arange(t, dtype=jnp.float32)[:, None] * freqs[None, :]  # [T, half]
    cos = jnp.cos(ang)[None, :, None, :]
    sin = jnp.sin(ang)[None, :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def forward(params: dict, tokens: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    """Logits [B, T, V] for int32 tokens [B, T]."""
    b, t = tokens.shape
    h = params["embed"][tokens]  # [B, T, D]
    nh, dh = cfg.n_heads, cfg.head_dim
    mask = jnp.tril(jnp.ones((t, t), jnp.bool_))
    neg = jnp.asarray(-1e9, jnp.float32)
    for i in range(cfg.n_layers):
        pre = f"layer{i:02d}."
        x = _rmsnorm(h, params[pre + "norm_attn"])
        q = (x @ params[pre + "attn_wq"]).reshape(b, t, nh, dh)
        k = (x @ params[pre + "attn_wk"]).reshape(b, t, nh, dh)
        v = (x @ params[pre + "attn_wv"]).reshape(b, t, nh, dh)
        q, k = _rope(q, cfg), _rope(k, cfg)
        att = jnp.einsum("bthd,bshd->bhts", q, k) / jnp.sqrt(float(dh))
        att = jnp.where(mask[None, None, :, :], att, neg)
        att = jax.nn.softmax(att, axis=-1)
        o = jnp.einsum("bhts,bshd->bthd", att, v).reshape(b, t, cfg.d_model)
        h = h + o @ params[pre + "attn_wo"]
        x = _rmsnorm(h, params[pre + "norm_mlp"])
        g = jax.nn.silu(x @ params[pre + "mlp_wgate"])
        u = x @ params[pre + "mlp_wup"]
        h = h + (g * u) @ params[pre + "mlp_wdown"]
    h = _rmsnorm(h, params["norm_final"])
    return h @ params["lm_head"]


def loss(params: dict, batch: jnp.ndarray, cfg: LMConfig) -> jnp.ndarray:
    """Mean next-token cross-entropy. batch: int32 [B, T+1]."""
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(params, tokens, cfg)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


val_loss = loss
