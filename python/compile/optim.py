"""Layer-2 optimizers: SGD (+momentum), Adam, AdamW, built on param dicts.

Parameters and optimizer state are flat ``{name: array}`` dicts — the
same canonical layout the AOT manifest exposes to the rust coordinator.
Learning rates arrive *per step* from the coordinator (rust owns the
cosine schedule), so programs stay schedule-agnostic.

The Adam second moment doubles as the empirical-Fisher diagonal that
LOTION's Eq. 3 penalty consumes ("we use the empirical Fisher
approximation as we would with Adam", §4.3).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax.numpy as jnp

Params = dict
OptState = dict


@dataclasses.dataclass(frozen=True)
class Optimizer:
    """An optimizer = init + update, plus a fisher view for LOTION."""

    name: str
    init: Callable[[Params], OptState]
    update: Callable[[Params, OptState, Params, jnp.ndarray], tuple[Params, OptState]]
    # fisher(opt_state, name, param) -> empirical-Fisher diagonal estimate
    # for that tensor, or None if this optimizer does not track one.
    fisher: Callable[[OptState, str, jnp.ndarray], jnp.ndarray | None]


def _sgd(momentum: float = 0.0) -> Optimizer:
    def init(params):
        if momentum == 0.0:
            return {"t": jnp.zeros((), jnp.float32)}
        st = {f"mu.{k}": jnp.zeros_like(v) for k, v in params.items()}
        st["t"] = jnp.zeros((), jnp.float32)
        return st

    def update(params, state, grads, lr):
        new_state = dict(state)
        new_state["t"] = state["t"] + 1.0
        new_params = {}
        for k, p in params.items():
            g = grads[k]
            if momentum != 0.0:
                mu = momentum * state[f"mu.{k}"] + g
                new_state[f"mu.{k}"] = mu
                g = mu
            new_params[k] = p - lr * g
        return new_params, new_state

    return Optimizer("sgd", init, update, lambda st, k, p: None)


def _adam(b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8, wd: float = 0.0) -> Optimizer:
    """Adam / AdamW (decoupled weight decay when ``wd > 0``)."""

    def init(params):
        st = {"t": jnp.zeros((), jnp.float32)}
        for k, v in params.items():
            st[f"m.{k}"] = jnp.zeros_like(v)
            st[f"v.{k}"] = jnp.zeros_like(v)
        return st

    def update(params, state, grads, lr):
        t = state["t"] + 1.0
        new_state = {"t": t}
        new_params = {}
        bc1 = 1.0 - b1**t
        bc2 = 1.0 - b2**t
        for k, p in params.items():
            g = grads[k]
            m = b1 * state[f"m.{k}"] + (1 - b1) * g
            v = b2 * state[f"v.{k}"] + (1 - b2) * g * g
            new_state[f"m.{k}"] = m
            new_state[f"v.{k}"] = v
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            if wd > 0.0:
                step = step + wd * p
            new_params[k] = p - lr * step
        return new_params, new_state

    def fisher(state, k, p):
        t = jnp.maximum(state["t"], 1.0)
        return state[f"v.{k}"] / (1.0 - b2**t)

    return Optimizer("adamw" if wd > 0 else "adam", init, update, fisher)


def make_optimizer(name: str, **kw) -> Optimizer:
    name = name.lower()
    if name == "sgd":
        return _sgd(momentum=kw.get("momentum", 0.0))
    if name == "adam":
        return _adam(wd=0.0, **{k: v for k, v in kw.items() if k != "wd"})
    if name == "adamw":
        return _adam(**kw)
    raise ValueError(f"unknown optimizer: {name!r}")
