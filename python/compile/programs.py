"""AOT program builders: scanned K-step train programs, eval, init.

A *program* is a pure function over a flat, canonically-ordered tuple of
arrays — exactly the calling convention the rust runtime uses against
the compiled PJRT executable (one tuple output; see DESIGN.md §2).

Canonical input order :  params (sorted) | opt state (sorted) |
                         statics (sorted) | data | key | lrs | lam_reg
Canonical output order:  params (sorted) | opt state (sorted) |
                         base_losses [K] | total_losses [K]

The K-step ``lax.scan`` is the key systems decision: the PJRT API on
this image returns one un-splittable tuple buffer per call, so state
round-trips through the host once per *chunk* of K optimizer steps,
amortizing the copy by K (measured in EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from . import optim
from .kernels import QuantFormat
from .methods import make_method_loss
from .models import linear2, linreg, transformer


@dataclasses.dataclass(frozen=True)
class TensorSpec:
    name: str
    shape: tuple
    dtype: str  # "f32" | "i32" | "u32"
    role: str   # "param" | "opt" | "static" | "data" | "key" | "scalar" | "metric"

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "shape": list(self.shape),
            "dtype": self.dtype,
            "role": self.role,
        }


@dataclasses.dataclass
class Program:
    """A lowerable flat-arg function plus its I/O contract."""

    name: str
    fn: Callable
    inputs: list
    outputs: list
    meta: dict


_DTYPES = {"f32": jnp.float32, "i32": jnp.int32, "u32": jnp.uint32}


def _np_dtype(name: str):
    return _DTYPES[name]


def example_args(prog: Program):
    """ShapeDtypeStructs for jax.jit(...).lower(...)."""
    return [
        jax.ShapeDtypeStruct(tuple(s.shape), _np_dtype(s.dtype)) for s in prog.inputs
    ]


# ---------------------------------------------------------------------------
# model adapters
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelAdapter:
    """Uniform view over the three testbed models."""

    kind: str
    cfg: object
    param_specs: list          # [TensorSpec]
    static_specs: list         # [TensorSpec]
    data_spec: Callable        # (K) -> TensorSpec | None
    # base_loss(params, statics, data_step, key) -> scalar
    base_loss: Callable
    val_loss: Callable         # (params, statics, data) -> scalar
    quantized: set
    fisher_exact: Callable | None  # (params, statics) -> {name: arr} | None
    init_fn: Callable          # (key) -> params dict


def _specs_from_tree(tree: dict, role: str) -> list:
    out = []
    for k in sorted(tree):
        v = tree[k]
        dt = {jnp.float32: "f32", jnp.int32: "i32", jnp.uint32: "u32"}.get(
            v.dtype.type, "f32"
        )
        out.append(TensorSpec(k, tuple(v.shape), dt, role))
    return out


def make_adapter(kind: str, cfg) -> ModelAdapter:
    if kind == "linreg":
        shapes = jax.eval_shape(lambda k: linreg.init(k, cfg), jax.random.PRNGKey(0))
        statics = [
            TensorSpec("lam", (cfg.d,), "f32", "static"),
            TensorSpec("wstar", (cfg.d,), "f32", "static"),
        ]

        def base_loss(params, st, _data, key):
            return linreg.loss(params, linreg.sample_batch(key, cfg, st))

        return ModelAdapter(
            kind, cfg, _specs_from_tree(shapes, "param"), statics,
            lambda K: None, base_loss,
            lambda params, st, _data: linreg.val_loss(params, st),
            linreg.quantized_keys(),
            lambda params, st: linreg.fisher_exact(params, st),
            lambda key: linreg.init(key, cfg),
        )
    if kind == "linear2":
        shapes = jax.eval_shape(lambda k: linear2.init(k, cfg), jax.random.PRNGKey(0))
        statics = [
            TensorSpec("lam", (cfg.d,), "f32", "static"),
            TensorSpec("wstar", (cfg.d,), "f32", "static"),
        ]

        def base_loss(params, st, _data, _key):
            return linear2.loss(params, st, cfg.k)

        return ModelAdapter(
            kind, cfg, _specs_from_tree(shapes, "param"), statics,
            lambda K: None, base_loss,
            lambda params, st, _data: linear2.val_loss(params, st, cfg.k),
            linear2.quantized_keys(),
            lambda params, st: linear2.fisher_exact(params, st, cfg.k),
            lambda key: linear2.init(key, cfg),
        )
    if kind == "lm":
        shapes = jax.eval_shape(lambda k: transformer.init(k, cfg.lm), jax.random.PRNGKey(0))

        def data_spec(K):
            return TensorSpec(
                "tokens", (K, cfg.batch, cfg.seq_len + 1), "i32", "data"
            )

        def base_loss(params, _st, data_step, _key):
            return transformer.loss(params, data_step, cfg.lm)

        return ModelAdapter(
            kind, cfg, _specs_from_tree(shapes, "param"), [],
            data_spec, base_loss,
            lambda params, _st, data: transformer.loss(params, data, cfg.lm),
            transformer.quantized_keys(cfg.lm),
            None,
            lambda key: transformer.init(key, cfg.lm),
        )
    raise ValueError(f"unknown model kind {kind!r}")


@dataclasses.dataclass(frozen=True)
class LMTrainConfig:
    """LM preset + batch geometry (adapter-level cfg for kind='lm')."""

    lm: transformer.LMConfig
    batch: int = 8

    @property
    def seq_len(self) -> int:
        return self.lm.seq_len

    @property
    def name(self) -> str:
        return self.lm.name


def init(self_key, adapter: ModelAdapter):
    return adapter.init_fn(self_key)


# ---------------------------------------------------------------------------
# program builders
# ---------------------------------------------------------------------------


def build_train_program(
    adapter: ModelAdapter,
    method: str,
    fmt: QuantFormat | None,
    optimizer: optim.Optimizer,
    steps_per_call: int,
) -> Program:
    """K optimizer steps of ``method`` as one flat scanned program."""
    K = steps_per_call
    opt_shapes = jax.eval_shape(
        optimizer.init,
        {s.name: jnp.zeros(s.shape, _np_dtype(s.dtype)) for s in adapter.param_specs},
    )
    opt_specs = _specs_from_tree(opt_shapes, "opt")
    data = adapter.data_spec(K)
    inputs = (
        adapter.param_specs
        + opt_specs
        + adapter.static_specs
        + ([data] if data else [])
        + [
            TensorSpec("key", (2,), "u32", "key"),
            TensorSpec("lrs", (K,), "f32", "scalar"),
            TensorSpec("lam_reg", (), "f32", "scalar"),
        ]
    )
    outputs = (
        [dataclasses.replace(s) for s in adapter.param_specs]
        + [dataclasses.replace(s) for s in opt_specs]
        + [
            TensorSpec("base_losses", (K,), "f32", "metric"),
            TensorSpec("total_losses", (K,), "f32", "metric"),
        ]
    )

    n_p = len(adapter.param_specs)
    n_o = len(opt_specs)
    n_s = len(adapter.static_specs)
    p_names = [s.name for s in adapter.param_specs]
    o_names = [s.name for s in opt_specs]
    s_names = [s.name for s in adapter.static_specs]

    def fn(*flat):
        i = 0
        params = dict(zip(p_names, flat[i : i + n_p])); i += n_p
        opt_state = dict(zip(o_names, flat[i : i + n_o])); i += n_o
        statics = dict(zip(s_names, flat[i : i + n_s])); i += n_s
        data_all = None
        if data is not None:
            data_all = flat[i]; i += 1
        key, lrs, lam_reg = flat[i], flat[i + 1], flat[i + 2]

        def step(carry, xs):
            params, opt_state = carry
            data_step, lr, k = xs
            k_data, k_round = jax.random.split(k)
            if method == "lotion":
                if adapter.fisher_exact is not None:
                    fisher = adapter.fisher_exact(params, statics)
                else:
                    fisher = {
                        name: optimizer.fisher(opt_state, name, params[name])
                        for name in adapter.quantized
                    }
            else:
                fisher = {name: None for name in adapter.quantized}

            loss_fn = make_method_loss(
                method,
                lambda p: adapter.base_loss(p, statics, data_step, k_data),
                adapter.quantized,
                fmt,
            )
            (total, base), grads = jax.value_and_grad(loss_fn, has_aux=True)(
                params, k_round, lam_reg, fisher
            )
            params, opt_state = optimizer.update(params, opt_state, grads, lr)
            return (params, opt_state), (base, total)

        keys = jax.random.split(key, K)
        xs = (
            data_all if data_all is not None else jnp.zeros((K,), jnp.float32),
            lrs,
            keys,
        )
        (params, opt_state), (bases, totals) = jax.lax.scan(
            step, (params, opt_state), xs
        )
        return tuple(
            [params[n] for n in p_names]
            + [opt_state[n] for n in o_names]
            + [bases, totals]
        )

    qfmt = fmt.name if fmt else "none"
    name = f"train_{adapter.cfg.name}_{method}_{qfmt}_k{K}"
    return Program(
        name, fn, inputs, outputs,
        meta={
            "kind": "train", "model": adapter.kind, "model_name": adapter.cfg.name,
            "method": method, "format": qfmt,
            "block_size": fmt.block_size if fmt else 0,
            "steps_per_call": K, "optimizer": optimizer.name,
            "quantized": sorted(adapter.quantized),
        },
    )


def build_eval_program(adapter: ModelAdapter, eval_batches: int = 1) -> Program:
    """Mean validation loss over the supplied data (or exact, synthetic)."""
    data = adapter.data_spec(eval_batches)
    inputs = adapter.param_specs + adapter.static_specs + ([data] if data else [])
    outputs = [TensorSpec("val_loss", (), "f32", "metric")]
    n_p = len(adapter.param_specs)
    n_s = len(adapter.static_specs)
    p_names = [s.name for s in adapter.param_specs]
    s_names = [s.name for s in adapter.static_specs]

    def fn(*flat):
        params = dict(zip(p_names, flat[:n_p]))
        statics = dict(zip(s_names, flat[n_p : n_p + n_s]))
        if data is None:
            return (adapter.val_loss(params, statics, None),)
        batches = flat[n_p + n_s]

        def one(_, b):
            return None, adapter.val_loss(params, statics, b)

        _, losses = jax.lax.scan(one, None, batches)
        return (jnp.mean(losses),)

    name = f"eval_{adapter.cfg.name}"
    return Program(
        name, fn, inputs, outputs,
        meta={
            "kind": "eval", "model": adapter.kind, "model_name": adapter.cfg.name,
            "eval_batches": eval_batches,
            "quantized": sorted(adapter.quantized),
        },
    )


def build_init_program(adapter: ModelAdapter) -> Program:
    """(key) -> freshly initialized params, lowered so the rust side never
    needs python for initialization."""
    inputs = [TensorSpec("key", (2,), "u32", "key")]
    outputs = [dataclasses.replace(s) for s in adapter.param_specs]
    p_names = [s.name for s in adapter.param_specs]

    def fn(key):
        params = adapter.init_fn(key)
        return tuple(params[n] for n in p_names)

    name = f"init_{adapter.cfg.name}"
    return Program(
        name, fn, inputs, outputs,
        meta={"kind": "init", "model": adapter.kind, "model_name": adapter.cfg.name},
    )
