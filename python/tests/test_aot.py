"""AOT pipeline contract tests: manifest shape, HLO text validity, and the
scanned-program semantics the rust runtime depends on."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, optim, programs
from compile.kernels import make_format
from compile.models import linreg

ARTIFACTS = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _adapter():
    return programs.make_adapter("linreg", linreg.LinRegConfig(d=32, batch=16))


def test_hlo_text_has_entry_and_params():
    ad = _adapter()
    prog = programs.build_train_program(
        ad, "lotion", make_format("int4", 0), optim.make_optimizer("sgd"), 2
    )
    txt = aot.to_hlo_text(prog)
    assert "ENTRY" in txt and "HloModule" in txt
    # one HLO parameter per flat input, in order
    for i in range(len(prog.inputs)):
        assert f"parameter({i})" in txt


def test_flat_io_order_is_canonical():
    ad = _adapter()
    prog = programs.build_train_program(
        ad, "qat", make_format("int4", 0), optim.make_optimizer("sgd"), 2
    )
    names = [s.name for s in prog.inputs]
    assert names == ["w", "t", "lam", "wstar", "key", "lrs", "lam_reg"]
    out_names = [s.name for s in prog.outputs]
    assert out_names == ["w", "t", "base_losses", "total_losses"]


def test_scanned_program_chunking_contract():
    """The rust coordinator chains chunks by feeding output state back as
    input state with a fresh per-call key. Verify: (a) a call is
    deterministic in its inputs, (b) state round-trips exactly (output
    specs == input param/opt specs), (c) chained chunks keep training
    (loss decreases across chunks)."""
    ad = _adapter()
    opt = optim.make_optimizer("sgd")
    fmt = make_format("int4", 0)
    p4 = programs.build_train_program(ad, "lotion", fmt, opt, 4)

    lam = (1.0 / np.arange(1, 33) ** 1.1).astype(np.float32)
    wstar = np.random.default_rng(0).normal(size=32).astype(np.float32)
    args = [
        jnp.zeros((32,), jnp.float32),
        jnp.zeros((), jnp.float32),
        jnp.asarray(lam),
        jnp.asarray(wstar),
        jnp.asarray([5, 6], jnp.uint32),
        jnp.full((4,), 0.1, jnp.float32),
        jnp.asarray(2.0, jnp.float32),
    ]
    f = jax.jit(p4.fn)
    o1 = f(*args)
    o2 = f(*args)
    np.testing.assert_array_equal(np.asarray(o1[0]), np.asarray(o2[0]))  # (a)

    # (b)+(c): chain 10 chunks, check exact population val loss drops
    ev = jax.jit(programs.build_eval_program(_adapter()).fn)
    val0 = float(ev(args[0], args[2], args[3])[0])
    w, t = args[0], args[1]
    for call in range(10):
        out = f(w, t, args[2], args[3], jnp.asarray([5, call], jnp.uint32),
                args[5], args[6])
        w, t = out[0], out[1]
    assert float(t) == 40.0  # 10 chunks x 4 steps
    val1 = float(ev(w, args[2], args[3])[0])
    assert val1 < val0 * 0.7


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACTS, "manifest.json")),
    reason="artifacts not built",
)
class TestManifest:
    @pytest.fixture(scope="class")
    def doc(self):
        with open(os.path.join(ARTIFACTS, "manifest.json")) as f:
            return json.load(f)

    def test_every_artifact_file_exists(self, doc):
        for name, e in doc["artifacts"].items():
            assert os.path.exists(os.path.join(ARTIFACTS, e["file"])), name

    def test_train_entries_have_full_contract(self, doc):
        trains = {k: v for k, v in doc["artifacts"].items() if v["meta"]["kind"] == "train"}
        assert len(trains) >= 20
        for name, e in trains.items():
            roles = [s["role"] for s in e["inputs"]]
            assert "key" in roles and "param" in roles, name
            assert e["meta"]["method"] in ("ptq", "qat", "rat", "lotion")
            out_names = [s["name"] for s in e["outputs"]]
            assert out_names[-2:] == ["base_losses", "total_losses"], name
            # params echo back first, in the same order
            in_params = [s["name"] for s in e["inputs"] if s["role"] == "param"]
            assert out_names[: len(in_params)] == in_params, name

    def test_smoke_set_present(self, doc):
        a = doc["artifacts"]
        assert "train_linreg_d256_lotion_int4_k8" in a
        assert "eval_lm-tiny" in a and "init_lm-tiny" in a

    def test_quantized_keys_recorded(self, doc):
        e = doc["artifacts"]["train_lm-tiny_lotion_int4_k4"]
        q = e["meta"]["quantized"]
        assert "lm_head" in q and "embed" not in q
