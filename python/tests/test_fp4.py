"""FP4 (E2M1) codebook specifics (§4.3.3): lattice structure, absmax
mapping, non-uniform resolution, and the generalized RR variance."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import FP4_LEVELS, make_format, ref, sigma2


FMT = make_format("fp4", 0)


def test_codebook_is_e2m1():
    assert sorted(FP4_LEVELS) == [
        -6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0,
        0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
    ]
    assert FMT.qmax == 6.0
    assert not FMT.uniform


def test_absmax_maps_to_six():
    w = jnp.asarray([0.1, -2.4, 0.3], jnp.float32)
    s = float(ref.block_scales_ref(w, FMT)[0])
    assert abs(s - 2.4 / 6.0) < 1e-7
    q = ref.fake_quant_ref(w, FMT)
    # the absmax element lands exactly on +-6 * s = +-absmax
    assert abs(float(q[1]) + 2.4) < 1e-6


def test_resolution_denser_near_zero():
    """E2M1's selling point: finer spacing near 0 (0.5) than near the
    edge (2.0) — quantization error for small values is smaller than a
    uniform INT4 lattice of the same dynamic range would give."""
    gaps = np.diff(sorted(FP4_LEVELS))
    assert gaps.min() == 0.5 and gaps.max() == 2.0
    # compare RMS error on small-magnitude values vs int4
    w = jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.2
    w = jnp.concatenate([w, jnp.asarray([3.0, -3.0])])  # pin dynamic range
    int4 = make_format("int4", 0)
    err = lambda fmt: float(jnp.sqrt(jnp.mean((ref.fake_quant_ref(w, fmt) - w)[:-2] ** 2)))
    assert err(FMT) < err(int4), (err(FMT), err(int4))


def test_rr_variance_uses_local_gap():
    """sigma^2 = s^2 (u-z)(z-l): midpoints of wide bins have larger
    variance than midpoints of narrow bins."""
    s = 0.5  # pin scale via absmax element 3.0 (=6*0.5)
    w = jnp.asarray([3.0, 0.125, 2.5], jnp.float32)  # z = 6, 0.25, 5.0
    v = np.asarray(sigma2(w, FMT))
    # z=0.25 sits mid-bin in [0, 0.5]: var = s^2 * 0.25*0.25
    np.testing.assert_allclose(v[1], s * s * 0.25 * 0.25, rtol=1e-5)
    # z=5.0 sits mid-bin in [4, 6]: var = s^2 * 1.0 * 1.0 (wider bin)
    np.testing.assert_allclose(v[2], s * s * 1.0, rtol=1e-5)
    assert v[2] > v[1]
    assert v[0] == 0.0  # lattice point


def test_fp4_rr_unbiased():
    fmt = FMT
    w = jax.random.normal(jax.random.PRNGKey(3), (32,)) * 1.5
    keys = jax.random.split(jax.random.PRNGKey(4), 3000)

    def one(k):
        u = jax.random.uniform(k, w.shape)
        return ref.stochastic_round_ref(w, fmt, u)

    qs = jax.vmap(one)(keys)
    mean = jnp.mean(qs, axis=0)
    sd = jnp.std(qs, axis=0) / np.sqrt(3000)
    # atol includes f32 roundoff: the absmax element reconstructs as
    # (w/6)*6 which differs from w by ~1 ulp
    tol = 5 * np.asarray(sd) + 1e-5 * np.abs(np.asarray(w)) + 1e-6
    np.testing.assert_array_less(np.abs(np.asarray(mean - w)), tol)


def test_all_casts_land_on_scaled_codebook():
    w = jax.random.normal(jax.random.PRNGKey(5), (257,)) * 2.0
    s = float(ref.block_scales_ref(w, FMT)[0])
    q = np.asarray(ref.fake_quant_ref(w, FMT)) / s
    lattice = np.asarray(FP4_LEVELS, dtype=np.float32)
    for z in q:
        assert np.min(np.abs(lattice - z)) < 1e-5, z
