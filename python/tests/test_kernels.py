"""Pallas kernels vs pure-jnp oracles — the core L1 correctness signal.

Hypothesis sweeps shapes, scales, block sizes and formats; every kernel
must agree with ``ref.py`` element-for-element (identical lattice, not
just allclose-to-float-noise).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    fake_quant,
    lotion_penalty,
    make_format,
    penalty_grad,
    penalty_value,
    ref,
    sigma2,
    ste_fake_quant,
    ste_stochastic_round,
    stochastic_round,
)

FORMATS = ["int4", "int8", "fp4"]
BLOCKS = [0, 32, 64, 257]

shape_st = st.sampled_from([(7,), (128,), (3, 97), (16, 64), (5, 5, 5), (1, 1), (130, 33)])
scale_st = st.sampled_from([1e-3, 0.1, 1.0, 37.5])
seed_st = st.integers(min_value=0, max_value=2**31 - 1)


def _w(seed, shape, scale):
    return jax.random.normal(jax.random.PRNGKey(seed), shape) * scale


@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("block", BLOCKS)
@settings(max_examples=12, deadline=None)
@given(shape=shape_st, scale=scale_st, seed=seed_st)
def test_fake_quant_matches_ref(fmt_name, block, shape, scale, seed):
    fmt = make_format(fmt_name, block)
    w = _w(seed, shape, scale)
    np.testing.assert_allclose(
        fake_quant(w, fmt), ref.fake_quant_ref(w, fmt), rtol=1e-6, atol=1e-8
    )


@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("block", BLOCKS)
@settings(max_examples=12, deadline=None)
@given(shape=shape_st, scale=scale_st, seed=seed_st)
def test_stochastic_round_matches_ref(fmt_name, block, shape, scale, seed):
    fmt = make_format(fmt_name, block)
    w = _w(seed, shape, scale)
    u = jax.random.uniform(jax.random.PRNGKey(seed + 1), shape)
    np.testing.assert_allclose(
        stochastic_round(w, fmt, u),
        ref.stochastic_round_ref(w, fmt, u),
        rtol=1e-6,
        atol=1e-8,
    )


@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("block", BLOCKS)
@settings(max_examples=12, deadline=None)
@given(shape=shape_st, scale=scale_st, seed=seed_st)
def test_sigma2_and_penalty_match_ref(fmt_name, block, shape, scale, seed):
    fmt = make_format(fmt_name, block)
    w = _w(seed, shape, scale)
    f = jax.random.uniform(jax.random.PRNGKey(seed + 2), shape)
    np.testing.assert_allclose(sigma2(w, fmt), ref.sigma2_ref(w, fmt), rtol=1e-5, atol=1e-8)
    np.testing.assert_allclose(
        penalty_value(w, f, fmt), ref.lotion_penalty_ref(w, f, fmt), rtol=1e-5, atol=1e-8
    )
    np.testing.assert_allclose(
        penalty_grad(w, f, fmt), ref.lotion_penalty_grad_ref(w, f, fmt), rtol=1e-5, atol=1e-8
    )


@pytest.mark.parametrize("fmt_name", FORMATS)
def test_custom_vjp_uses_analytic_grad(fmt_name):
    fmt = make_format(fmt_name, 0)
    w = _w(3, (4, 33), 0.7)
    f = jax.random.uniform(jax.random.PRNGKey(4), (4, 33))
    g = jax.grad(lambda ww: lotion_penalty(ww, f, fmt))(w)
    np.testing.assert_allclose(g, ref.lotion_penalty_grad_ref(w, f, fmt), rtol=1e-5, atol=1e-8)


@pytest.mark.parametrize("fmt_name", FORMATS)
def test_penalty_grad_matches_finite_difference(fmt_name):
    """Analytic penalty gradient == centered finite difference of the
    penalty value, away from lattice boundaries (where it is undefined)."""
    fmt = make_format(fmt_name, 0)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(37,)).astype(np.float32))
    f = jnp.asarray(rng.uniform(0.5, 1.5, size=(37,)).astype(np.float32))
    s = ref.block_scales_ref(w, fmt)[0]
    # Perturb only coordinates well inside a bin (and far from the absmax
    # coordinate so the scale does not move).
    z = w / s
    eps = 1e-3
    g_ref = np.asarray(ref.lotion_penalty_grad_ref(w, f, fmt))
    amax_idx = int(np.argmax(np.abs(np.asarray(w))))
    checked = 0
    for i in range(w.shape[0]):
        if i == amax_idx:
            continue
        zi = float(z[i])
        if abs(zi - round(zi)) < 0.05 or abs(zi) > fmt.qmax * 0.9:
            continue
        dw = np.zeros_like(np.asarray(w))
        dw[i] = eps * float(s)
        lp = ref.lotion_penalty_ref(w + dw, f, fmt)
        lm = ref.lotion_penalty_ref(w - dw, f, fmt)
        fd = float((lp - lm) / (2 * eps * float(s)))
        np.testing.assert_allclose(fd, g_ref[i], rtol=0.05, atol=1e-5)
        checked += 1
    assert checked > 5


@pytest.mark.parametrize("fmt_name", FORMATS)
def test_ste_wrappers_are_identity_in_backward(fmt_name):
    fmt = make_format(fmt_name, 0)
    w = _w(5, (64,), 0.5)
    u = jax.random.uniform(jax.random.PRNGKey(6), (64,))
    gq = jax.grad(lambda ww: jnp.sum(jnp.sin(ste_fake_quant(ww, fmt))))(w)
    # STE: gradient flows as if cast were identity applied at the cast point
    expect = jnp.cos(fake_quant(w, fmt))
    np.testing.assert_allclose(gq, expect, rtol=1e-5, atol=1e-6)
    gr = jax.grad(lambda ww: jnp.sum(ste_stochastic_round(ww, u, fmt)))(w)
    np.testing.assert_allclose(gr, jnp.ones_like(w), rtol=1e-6)


@pytest.mark.parametrize("fmt_name", FORMATS)
@pytest.mark.parametrize("block", [0, 32])
def test_cast_is_idempotent(fmt_name, block):
    """cast(cast(w)) == cast(w): lattice points are fixed points (Def. 1.3)."""
    fmt = make_format(fmt_name, block)
    w = _w(7, (130,), 2.0)
    q1 = fake_quant(w, fmt)
    q2 = fake_quant(q1, fmt)
    np.testing.assert_allclose(q1, q2, rtol=1e-6, atol=1e-7)


@pytest.mark.parametrize("fmt_name", FORMATS)
def test_rr_fixed_on_lattice(fmt_name):
    """RR of an exactly-representable point returns it w.p. 1 (Def. 1.3)."""
    fmt = make_format(fmt_name, 0)
    w = fake_quant(_w(8, (64,), 1.0), fmt)
    for seed in range(4):
        u = jax.random.uniform(jax.random.PRNGKey(seed), (64,))
        np.testing.assert_allclose(stochastic_round(w, fmt, u), w, rtol=1e-6, atol=1e-7)


def test_zero_tensor_is_safe():
    for fmt_name in FORMATS:
        fmt = make_format(fmt_name, 0)
        w = jnp.zeros((33,))
        f = jnp.ones((33,))
        assert not np.any(np.isnan(np.asarray(fake_quant(w, fmt))))
        assert float(penalty_value(w, f, fmt)) == 0.0
        assert not np.any(np.isnan(np.asarray(penalty_grad(w, f, fmt))))


def test_bf16_roundtrip():
    fmt = make_format("int8", 0)
    w = _w(9, (128,), 0.3).astype(jnp.bfloat16)
    q = fake_quant(w, fmt)
    assert q.dtype == jnp.bfloat16
    assert not np.any(np.isnan(np.asarray(q, dtype=np.float32)))
