"""Statistical / theoretical property tests for the paper's lemmas.

* Definition 1.1 — unbiasedness: E[RR(w)] = w.
* §3.2          — Var[eps_i] = s_B^2 * Delta_i (1 - Delta_i)  (uniform)
                  and the codebook generalization s^2 (u-z)(z-l).
* Lemma 2       — min of the smoothed loss equals min of the quantized
                  loss on an enumerable 1-D problem.
* Lemma 3       — E[grad L(w+eps)] = grad L(w) for quadratic losses.
* Eq. 1         — E[L(w+eps)] = L(w) + 0.5 tr(H Sigma) exactly for
                  quadratics (sampled vs closed form).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import make_format, ref, sigma2, stochastic_round

N_SAMPLES = 4000


def _rr_samples(w, fmt, n, seed=0):
    keys = jax.random.split(jax.random.PRNGKey(seed), n)

    def one(k):
        u = jax.random.uniform(k, w.shape)
        return stochastic_round(w, fmt, u)

    return jax.vmap(one)(keys)


@pytest.mark.parametrize("fmt_name", ["int4", "int8", "fp4"])
def test_rr_unbiased(fmt_name):
    fmt = make_format(fmt_name, 0)
    w = jax.random.normal(jax.random.PRNGKey(1), (48,)) * 0.8
    qs = _rr_samples(w, fmt, N_SAMPLES)
    mean = jnp.mean(qs, axis=0)
    sd = jnp.std(qs, axis=0) / np.sqrt(N_SAMPLES)
    # 5-sigma elementwise bound (plus atol for exact lattice points, sd=0)
    np.testing.assert_array_less(
        np.abs(np.asarray(mean - w)), 5 * np.asarray(sd) + 1e-6
    )


@pytest.mark.parametrize("fmt_name", ["int4", "fp4"])
def test_rr_variance_identity(fmt_name):
    fmt = make_format(fmt_name, 0)
    w = jax.random.normal(jax.random.PRNGKey(2), (48,)) * 0.8
    qs = _rr_samples(w, fmt, N_SAMPLES, seed=3)
    var_emp = np.asarray(jnp.var(qs, axis=0))
    var_pred = np.asarray(sigma2(w, fmt))
    # Near-lattice coordinates are rare-event Bernoullis: the empirical
    # variance has huge *relative* noise there, so pair rtol with an atol
    # scaled to the sampling error of the variance estimator.
    np.testing.assert_allclose(var_emp, var_pred, rtol=0.3, atol=1.5e-4)


def test_lemma2_global_minima_preserved_1d():
    """On a 1-D quadratic with a fixed lattice, min_w E[L(RR(w))] equals
    min_w L(cast(w)), and both are attained on the lattice."""
    fmt = make_format("int4", 0)
    scale = 0.5  # fixed scale via a pinned absmax element
    pin = scale * fmt.qmax
    wstar = 1.37

    def loss(q):
        return (q - wstar) ** 2

    # Enumerate a dense grid of real-valued w; smoothed loss via exact
    # two-point expectation (uniform lattice: floor/ceil).
    grid = np.linspace(-2.0, 2.0, 2001)
    z = grid / scale
    lo, hi = np.floor(z), np.floor(z) + 1
    p_up = z - lo
    smooth = (1 - p_up) * loss(scale * lo) + p_up * loss(scale * hi)
    cast = scale * np.round(z)
    quant = loss(cast)
    assert abs(smooth.min() - quant.min()) < 1e-9
    # and the smoothed minimum sits on a lattice point
    assert abs((grid[smooth.argmin()] / scale) - round(grid[smooth.argmin()] / scale)) < 1e-3
    _ = pin  # (absmax pinning is implicit: the grid is the scaled lattice)


def test_lemma3_rat_gradient_unbiased_quadratic():
    d = 24
    rng = np.random.default_rng(0)
    A = rng.normal(size=(d, d)).astype(np.float32)
    H = A @ A.T / d + 0.1 * np.eye(d, dtype=np.float32)
    wstar = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    w = jnp.asarray(rng.normal(size=(d,)).astype(np.float32))
    Hj = jnp.asarray(H)

    def grad_at(q):
        return Hj @ (q - wstar)

    fmt = make_format("int4", 0)
    qs = _rr_samples(w, fmt, N_SAMPLES, seed=5)
    g_mean = jnp.mean(jax.vmap(grad_at)(qs), axis=0)
    g_true = grad_at(w)
    sd = jnp.std(jax.vmap(grad_at)(qs), axis=0) / np.sqrt(N_SAMPLES)
    np.testing.assert_array_less(np.abs(np.asarray(g_mean - g_true)), 5 * np.asarray(sd) + 1e-5)


def test_eq1_smoothed_quadratic_closed_form():
    """E[L(w+eps)] == L(w) + 0.5 tr(H Sigma_eps) for a quadratic (Eq. 1)."""
    d = 16
    rng = np.random.default_rng(1)
    hdiag = jnp.asarray(rng.uniform(0.5, 2.0, size=d).astype(np.float32))
    wstar = jnp.asarray(rng.normal(size=d).astype(np.float32))
    w = jnp.asarray(rng.normal(size=d).astype(np.float32))

    def loss(q):
        return 0.5 * jnp.sum(hdiag * (q - wstar) ** 2)

    fmt = make_format("int4", 0)
    qs = _rr_samples(w, fmt, 8000, seed=7)
    smooth_emp = float(jnp.mean(jax.vmap(loss)(qs)))
    sig2 = sigma2(w, fmt)
    smooth_pred = float(loss(w) + 0.5 * jnp.sum(hdiag * sig2))
    per_sample_sd = float(jnp.std(jax.vmap(loss)(qs))) / np.sqrt(8000)
    assert abs(smooth_emp - smooth_pred) < 6 * per_sample_sd + 1e-6


@pytest.mark.parametrize("fmt_name", ["int4", "int8"])
def test_scales_match_paper_formula(fmt_name):
    fmt = make_format(fmt_name, 0)
    w = jax.random.normal(jax.random.PRNGKey(9), (100,)) * 3.0
    s = float(ref.block_scales_ref(w, fmt)[0])
    expect = float(jnp.max(jnp.abs(w))) / (2 ** (fmt.bits - 1) - 1)
    assert abs(s - expect) < 1e-7


def test_codes_stay_in_range():
    """|w| <= (2^{n-1}-1) s_B by construction => no clipping needed (§2.1)."""
    fmt = make_format("int4", 0)
    w = jax.random.normal(jax.random.PRNGKey(10), (257,)) * 11.0
    s = float(ref.block_scales_ref(w, fmt)[0])
    z = np.asarray(w) / s
    codes = np.round(z)
    assert codes.max() <= fmt.qmax and codes.min() >= -fmt.qmax
