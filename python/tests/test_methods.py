"""Method-transformation semantics: QAT/RAT casting, LOTION penalty wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import methods, optim
from compile.kernels import fake_quant, make_format, ref


FMT = make_format("int4", 0)


def _quad_loss(target):
    def f(params):
        return 0.5 * jnp.sum((params["w"] - target) ** 2)

    return f


def test_ptq_is_identity_transformation():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss = methods.make_method_loss("ptq", _quad_loss(0.0), {"w"}, FMT)
    total, base = loss({"w": w}, jax.random.PRNGKey(1), jnp.asarray(1.0), {"w": None})
    assert float(total) == float(base) == float(0.5 * jnp.sum(w * w))


def test_qat_forward_uses_rtn_cast():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss = methods.make_method_loss("qat", _quad_loss(0.0), {"w"}, FMT)
    total, _ = loss({"w": w}, jax.random.PRNGKey(1), jnp.asarray(0.0), {"w": None})
    wq = fake_quant(w, FMT)
    np.testing.assert_allclose(float(total), float(0.5 * jnp.sum(wq * wq)), rtol=1e-6)


def test_qat_backward_is_ste():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss = methods.make_method_loss("qat", _quad_loss(0.0), {"w"}, FMT)
    g = jax.grad(lambda p: loss(p, jax.random.PRNGKey(1), 0.0, {"w": None})[0])(
        {"w": w}
    )
    wq = fake_quant(w, FMT)
    np.testing.assert_allclose(g["w"], wq, rtol=1e-6)  # dL/dwq * dwq/dw|STE = wq


def test_rat_is_stochastic_but_seed_deterministic():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    loss = methods.make_method_loss("rat", _quad_loss(0.0), {"w"}, FMT)
    t1, _ = loss({"w": w}, jax.random.PRNGKey(1), 0.0, {"w": None})
    t2, _ = loss({"w": w}, jax.random.PRNGKey(1), 0.0, {"w": None})
    t3, _ = loss({"w": w}, jax.random.PRNGKey(2), 0.0, {"w": None})
    assert float(t1) == float(t2)
    assert float(t1) != float(t3)


def test_lotion_total_is_base_plus_lambda_penalty():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    fisher = jax.random.uniform(jax.random.PRNGKey(2), (32,)) + 0.1
    loss = methods.make_method_loss("lotion", _quad_loss(0.0), {"w"}, FMT)
    lam = 7.0
    total, base = loss({"w": w}, jax.random.PRNGKey(1), jnp.asarray(lam), {"w": fisher})
    pen = ref.lotion_penalty_ref(w, fisher, FMT)
    np.testing.assert_allclose(float(total), float(base) + lam * float(pen), rtol=1e-5)


def test_lotion_gradient_includes_penalty_term():
    w = jax.random.normal(jax.random.PRNGKey(0), (32,))
    fisher = jnp.ones((32,))
    loss = methods.make_method_loss("lotion", _quad_loss(0.0), {"w"}, FMT)
    lam = 3.0
    g = jax.grad(lambda p: loss(p, jax.random.PRNGKey(1), jnp.asarray(lam), {"w": fisher})[0])(
        {"w": w}
    )
    expect = w + lam * ref.lotion_penalty_grad_ref(w, fisher, FMT)
    np.testing.assert_allclose(g["w"], expect, rtol=1e-5, atol=1e-7)


def test_lotion_fisher_not_differentiated():
    """Fisher enters through stop_gradient: grads w.r.t. fisher are zero."""
    w = jax.random.normal(jax.random.PRNGKey(0), (16,))
    loss = methods.make_method_loss("lotion", _quad_loss(0.0), {"w"}, FMT)

    def f(fi):
        total, _ = loss({"w": w}, jax.random.PRNGKey(1), jnp.asarray(1.0), {"w": fi})
        return total

    g = jax.grad(f)(jnp.ones((16,)))
    np.testing.assert_allclose(g, jnp.zeros((16,)), atol=1e-9)


def test_unquantized_tensors_untouched():
    params = {
        "w": jax.random.normal(jax.random.PRNGKey(0), (16,)),
        "norm": jax.random.normal(jax.random.PRNGKey(1), (16,)),
    }

    def base(p):
        return jnp.sum(p["w"]) + jnp.sum(p["norm"] ** 3)

    loss = methods.make_method_loss("qat", base, {"w"}, FMT)
    total, _ = loss(params, jax.random.PRNGKey(2), 0.0, {})
    expect = jnp.sum(fake_quant(params["w"], FMT)) + jnp.sum(params["norm"] ** 3)
    np.testing.assert_allclose(float(total), float(expect), rtol=1e-6)


class TestOptim:
    def test_sgd_step(self):
        opt = optim.make_optimizer("sgd")
        p = {"w": jnp.asarray([1.0, 2.0])}
        st = opt.init(p)
        g = {"w": jnp.asarray([0.5, -0.5])}
        p2, st2 = opt.update(p, st, g, jnp.asarray(0.1))
        np.testing.assert_allclose(p2["w"], [0.95, 2.05], rtol=1e-6)
        assert float(st2["t"]) == 1.0

    def test_adam_matches_reference_formula(self):
        opt = optim.make_optimizer("adam")
        p = {"w": jnp.asarray([1.0])}
        st = opt.init(p)
        g = {"w": jnp.asarray([0.3])}
        p2, st2 = opt.update(p, st, g, jnp.asarray(0.01))
        # first step of Adam: update = lr * g/|g| (bias-corrected) ~ lr
        np.testing.assert_allclose(p2["w"], [1.0 - 0.01 * 0.3 / (0.3 + 1e-8)], rtol=1e-4)

    def test_adamw_decoupled_decay(self):
        opt = optim.make_optimizer("adamw", wd=0.1)
        p = {"w": jnp.asarray([1.0])}
        st = opt.init(p)
        g = {"w": jnp.asarray([0.0])}
        p2, _ = opt.update(p, st, g, jnp.asarray(0.01))
        np.testing.assert_allclose(p2["w"], [1.0 - 0.01 * 0.1 * 1.0], rtol=1e-5)

    def test_fisher_is_bias_corrected_v(self):
        opt = optim.make_optimizer("adam")
        p = {"w": jnp.asarray([1.0, 2.0])}
        st = opt.init(p)
        g = {"w": jnp.asarray([0.5, -1.0])}
        _, st = opt.update(p, st, g, jnp.asarray(0.0))
        f = opt.fisher(st, "w", p["w"])
        np.testing.assert_allclose(f, g["w"] ** 2, rtol=1e-4)

    def test_sgd_has_no_fisher(self):
        opt = optim.make_optimizer("sgd")
        assert opt.fisher({}, "w", jnp.zeros(3)) is None
