"""L2 model correctness: shapes, losses, exact-Fisher formulas, LM behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import linear2, linreg, transformer


class TestLinReg:
    cfg = linreg.LinRegConfig(d=128, batch=64)

    def test_init_shape(self):
        p = linreg.init(jax.random.PRNGKey(0), self.cfg)
        assert p["w"].shape == (self.cfg.d,)
        assert float(jnp.sum(jnp.abs(p["w"]))) == 0.0

    def test_val_loss_zero_at_optimum(self):
        st = linreg.statics(jax.random.PRNGKey(1), self.cfg)
        assert float(linreg.val_loss({"w": st["wstar"]}, st)) == 0.0

    def test_minibatch_loss_approximates_population(self):
        st = linreg.statics(jax.random.PRNGKey(1), self.cfg)
        p = {"w": jnp.zeros((self.cfg.d,))}
        cfg_big = linreg.LinRegConfig(d=self.cfg.d, batch=8192)
        batch = linreg.sample_batch(jax.random.PRNGKey(2), cfg_big, st)
        emp = float(linreg.loss(p, batch))
        pop = float(linreg.val_loss(p, st))
        assert abs(emp - pop) / pop < 0.15

    def test_spectrum_power_law(self):
        lam = np.asarray(linreg.spectrum(self.cfg))
        assert lam[0] == 1.0
        np.testing.assert_allclose(lam[9], 10.0 ** -1.1, rtol=1e-5)
        assert np.all(np.diff(lam) < 0)

    def test_fisher_exact_is_spectrum(self):
        st = linreg.statics(jax.random.PRNGKey(1), self.cfg)
        f = linreg.fisher_exact({"w": jnp.zeros(self.cfg.d)}, st)
        np.testing.assert_allclose(f["w"], st["lam"])


class TestLinear2:
    cfg = linear2.Linear2Config(d=96, k=4)

    def test_loss_zero_at_gt(self):
        st = linear2.statics(jax.random.PRNGKey(0), self.cfg)
        p = linear2.init_gt(self.cfg, st["wstar"])
        assert float(linear2.loss(p, st, self.cfg.k)) < 1e-10

    def test_fisher_matches_autodiff_gauss_newton(self):
        """Exact-GN formula == diag of J^T diag(lam) J computed by autodiff."""
        st = linear2.statics(jax.random.PRNGKey(1), self.cfg)
        p = linear2.init(jax.random.PRNGKey(2), self.cfg)
        k = self.cfg.k
        f = linear2.fisher_exact(p, st, k)

        # f(x) = v.x with v = (1/k) W1^T W2^T; GN for the population loss
        # 1/2 (v-w*)^T diag(lam) (v-w*) over params theta is
        # (dv/dtheta)^T diag(lam) (dv/dtheta); diagonal via per-param grads.
        def v_of(params):
            return linear2.effective_w(params, k)

        jac = jax.jacobian(v_of)(p)  # dict of [d, *param_shape]
        lam = st["lam"]
        for name in ("w1", "w2"):
            j = jac[name].reshape(self.cfg.d, -1)
            gn_diag = jnp.einsum("di,d->i", j * j, lam).reshape(p[name].shape)
            np.testing.assert_allclose(f[name], gn_diag, rtol=1e-4, atol=1e-7)

    def test_quantized_keys(self):
        assert linear2.quantized_keys() == {"w1", "w2"}


class TestTransformer:
    cfg = transformer.LMConfig("t", vocab=61, d_model=32, n_layers=2, n_heads=2, seq_len=16)

    def _params(self):
        return transformer.init(jax.random.PRNGKey(0), self.cfg)

    def test_param_count_estimate(self):
        p = self._params()
        total = sum(int(np.prod(v.shape)) for v in p.values())
        assert abs(total - self.cfg.param_count()) / total < 0.01

    def test_forward_shape(self):
        p = self._params()
        toks = jnp.zeros((3, 16), jnp.int32)
        logits = transformer.forward(p, toks, self.cfg)
        assert logits.shape == (3, 16, 61)

    def test_initial_loss_near_uniform(self):
        p = self._params()
        batch = jax.random.randint(jax.random.PRNGKey(1), (4, 17), 0, 61)
        loss = float(transformer.loss(p, batch, self.cfg))
        assert abs(loss - np.log(61)) < 0.3

    def test_causality(self):
        """Changing a future token must not affect past logits."""
        p = self._params()
        t1 = jax.random.randint(jax.random.PRNGKey(2), (1, 16), 0, 61)
        t2 = t1.at[0, 10].set((t1[0, 10] + 1) % 61)
        l1 = transformer.forward(p, t1, self.cfg)
        l2 = transformer.forward(p, t2, self.cfg)
        np.testing.assert_allclose(l1[0, :10], l2[0, :10], atol=1e-5)
        assert not np.allclose(l1[0, 10:], l2[0, 10:], atol=1e-5)

    def test_loss_decreases_under_training(self):
        from compile import optim

        p = self._params()
        opt = optim.make_optimizer("adamw")
        st = opt.init(p)
        batch = jax.random.randint(jax.random.PRNGKey(3), (8, 17), 0, 61)

        @jax.jit
        def step(p, st):
            loss, g = jax.value_and_grad(lambda q: transformer.loss(q, batch, self.cfg))(p)
            p, st = opt.update(p, st, g, jnp.asarray(3e-3))
            return p, st, loss

        first = None
        for i in range(30):
            p, st, loss = step(p, st)
            first = first if first is not None else float(loss)
        assert float(loss) < first - 0.5

    def test_quantized_keys_excludes_embed_and_norms(self):
        ks = transformer.quantized_keys(self.cfg)
        assert "embed" not in ks and "norm_final" not in ks
        assert "lm_head" in ks and "layer00.attn_wq" in ks
        assert not any("norm" in k for k in ks)

    def test_presets_param_counts(self):
        p100 = transformer.PRESETS["lm-100m"].param_count()
        assert 80e6 < p100 < 130e6
        assert transformer.PRESETS["lm-300m-sim"].param_count() > (
            2 * transformer.PRESETS["lm-150m-sim"].param_count()
        )
