"""Program-builder coverage beyond test_aot: LM adapter, all methods,
optimizer state shapes, eval/init programs for each model family."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import optim, programs
from compile.kernels import make_format
from compile.models import linear2, linreg, transformer


def lm_adapter():
    lm = transformer.LMConfig("t", vocab=61, d_model=32, n_layers=2, n_heads=2, seq_len=16)
    return programs.make_adapter("lm", programs.LMTrainConfig(lm, batch=2))


def _args_for(prog, seed=0):
    rng = np.random.default_rng(seed)
    args = []
    for s in prog.inputs:
        if s.dtype == "u32":
            args.append(jnp.asarray([1, seed], jnp.uint32))
        elif s.dtype == "i32":
            args.append(jnp.asarray(rng.integers(0, 61, size=s.shape), jnp.int32))
        elif s.name == "lrs":
            args.append(jnp.full(s.shape, 1e-3, jnp.float32))
        elif s.name == "lam_reg":
            args.append(jnp.asarray(10.0, jnp.float32))
        elif s.name == "lam":
            d = s.shape[0]
            args.append(jnp.asarray((1.0 / np.arange(1, d + 1) ** 1.1), jnp.float32))
        elif s.role == "opt":
            args.append(jnp.zeros(s.shape, jnp.float32))
        else:
            args.append(jnp.asarray(rng.normal(size=s.shape).astype(np.float32) * 0.05))
    return args


@pytest.mark.parametrize("method", ["ptq", "qat", "rat", "lotion"])
def test_lm_train_program_runs(method):
    ad = lm_adapter()
    fmt = make_format("int4", 0)
    prog = programs.build_train_program(ad, method, fmt, optim.make_optimizer("adamw"), 2)
    out = jax.jit(prog.fn)(*_args_for(prog))
    assert len(out) == len(prog.outputs)
    losses = np.asarray(out[-2])
    assert losses.shape == (2,)
    assert np.all(np.isfinite(losses))
    # opt step counter advanced
    t_idx = [s.name for s in prog.outputs].index("t")
    assert float(out[t_idx]) == 2.0


def test_lm_adam_state_shapes_match_params():
    ad = lm_adapter()
    prog = programs.build_train_program(
        ad, "lotion", make_format("int8", 0), optim.make_optimizer("adamw"), 1
    )
    params = {s.name: s for s in prog.inputs if s.role == "param"}
    opts = [s for s in prog.inputs if s.role == "opt"]
    for s in opts:
        if s.name == "t":
            assert s.shape == ()
        else:
            kind, pname = s.name.split(".", 1)
            assert kind in ("m", "v")
            assert tuple(s.shape) == tuple(params[pname].shape), s.name

    # the fisher (adam v) exists for every quantized tensor
    qk = set(prog.meta["quantized"])
    vnames = {s.name[2:] for s in opts if s.name.startswith("v.")}
    assert qk <= vnames


def test_lm_lotion_penalty_engages_after_warmup():
    """With zero Adam v the penalty is 0; after steps it must be > 0."""
    ad = lm_adapter()
    fmt = make_format("int4", 0)
    prog = programs.build_train_program(ad, "lotion", fmt, optim.make_optimizer("adamw"), 4)
    args = _args_for(prog)
    out = jax.jit(prog.fn)(*args)
    bases, totals = np.asarray(out[-2]), np.asarray(out[-1])
    assert totals[0] == bases[0]  # fisher starts at zero
    assert np.any(totals[1:] > bases[1:])  # penalty engages


def test_linear2_train_decreases_exact_loss():
    cfg = linear2.Linear2Config(d=64, k=4)
    ad = programs.make_adapter("linear2", cfg)
    prog = programs.build_train_program(
        ad, "ptq", make_format("int4", 0), optim.make_optimizer("sgd"), 8
    )
    ev = programs.build_eval_program(ad)
    rng = np.random.default_rng(0)
    lam = jnp.asarray((1.0 / np.arange(1, 65) ** 1.1), jnp.float32)
    wstar = jnp.asarray(rng.normal(size=64).astype(np.float32))
    w1 = jnp.asarray(rng.normal(size=(4, 64)).astype(np.float32) / 8.0)
    w2 = jnp.asarray(rng.normal(size=(1, 4)).astype(np.float32))
    t = jnp.zeros((), jnp.float32)
    v0 = float(jax.jit(ev.fn)(w1, w2, lam, wstar)[0])
    f = jax.jit(prog.fn)
    for call in range(16):
        out = f(w1, w2, t, lam, wstar, jnp.asarray([1, call], jnp.uint32),
                jnp.full((8,), 0.3, jnp.float32), jnp.asarray(0.0, jnp.float32))
        w1, w2, t = out[0], out[1], out[2]
    v1 = float(jax.jit(ev.fn)(w1, w2, lam, wstar)[0])
    # two-layer linear products converge slowly under plain GD; 128 steps
    # at lr 0.3 reliably cuts the exact loss by ~2x
    assert v1 < v0 * 0.6, f"{v0} -> {v1}"


def test_eval_program_lm_means_over_batches():
    ad = lm_adapter()
    prog = programs.build_eval_program(ad, eval_batches=3)
    data = [s for s in prog.inputs if s.role == "data"]
    assert data and data[0].shape[0] == 3
    out = jax.jit(prog.fn)(*_args_for(prog))
    assert np.isfinite(float(out[0]))


def test_init_program_lm_is_key_dependent():
    ad = lm_adapter()
    prog = programs.build_init_program(ad)
    f = jax.jit(prog.fn)
    a = f(jnp.asarray([0, 1], jnp.uint32))
    b = f(jnp.asarray([0, 2], jnp.uint32))
    emb_idx = [s.name for s in prog.outputs].index("embed")
    assert not np.allclose(np.asarray(a[emb_idx]), np.asarray(b[emb_idx]))
    # norms start at ones regardless of key
    nf = [s.name for s in prog.outputs].index("norm_final")
    np.testing.assert_array_equal(np.asarray(a[nf]), np.ones_like(np.asarray(a[nf])))


def test_input_roles_are_complete_and_ordered():
    ad = lm_adapter()
    prog = programs.build_train_program(
        ad, "rat", make_format("int4", 0), optim.make_optimizer("adamw"), 2
    )
    roles = [s.role for s in prog.inputs]
    # canonical order: params, opt, (statics), data, key, scalars
    first_opt = roles.index("opt")
    assert all(r == "param" for r in roles[:first_opt])
    assert roles[-1] == "scalar" and roles[-2] == "scalar"
    assert "key" in roles and "data" in roles
