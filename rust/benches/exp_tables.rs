//! One end-to-end bench per paper table/figure workload: measures the
//! steady-state step throughput of each experiment's training loop
//! (the quantity that gates regenerating the paper's results) plus the
//! quantized-eval latency that punctuates it. Runs on whichever
//! backend `auto_executor` picks: native covers the synthetic figures,
//! the LM rows need PJRT artifacts and are skipped otherwise.
//!
//! Figure/table mapping (DESIGN.md §4):
//!   fig2/fig7   linreg d=12000 INT4          -> linreg bench
//!   fig3/fig8   linear2 k-sweep INT4         -> linear2 bench (k=8)
//!   fig9/tab1   lm-150m-sim INT4/INT8        -> lm150 benches
//!   fig10/fig1  lm-150m-sim extended budget  -> same workload as fig9
//!   fig11/tab2  lm-300m-sim INT4/INT8        -> lm300 bench
//!   fig12/fig5  lm-150m-sim FP4              -> fp4 bench

use lotion::benchlib::Bench;
use lotion::config::RunConfig;
use lotion::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use lotion::experiments::common::synth_statics;
use lotion::quant::{QuantFormat, Rounding};
use lotion::runtime::{auto_executor, Executor, Role};
use std::path::Path;

fn workload(
    engine: &dyn Executor,
    bench: &mut Bench,
    tag: &str,
    model: &str,
    method: &str,
    format: &str,
    lambda: f64,
) {
    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.method = method.into();
    cfg.format = format.into();
    cfg.steps = 1_000_000;
    cfg.lr = 1e-3;
    cfg.lambda = lambda;
    let Ok(eval_entry) = engine.manifest().find_eval(model) else {
        eprintln!("skip {tag}: no eval program for {model} on this backend");
        return;
    };
    let (statics, data) = if model.starts_with("lin") {
        let Some(d) = eval_entry
            .inputs
            .iter()
            .find(|s| s.name == "lam")
            .map(|s| s.shape[0])
        else {
            eprintln!("skip {tag}: eval program has no lam spec");
            return;
        };
        let (s, _, _) = synth_statics(d, 42);
        (s, DataSource::InGraph)
    } else {
        let Some(d) = eval_entry.inputs.iter().find(|s| matches!(s.role, Role::Data)) else {
            eprintln!("skip {tag}: eval program has no data spec");
            return;
        };
        let corpus = lotion::data::ZipfMarkovCorpus::generate(400_000, 512, 4, 1);
        let toks = lotion::data::ByteTokenizer::new().encode(&corpus.bytes);
        (
            vec![],
            DataSource::Tokens(lotion::data::TokenBatcher::new(
                toks,
                d.shape[1],
                d.shape[2] - 1,
                0.1,
            )),
        )
    };
    let Ok(mut trainer) = Trainer::new(engine, cfg, statics, data) else {
        eprintln!("skip {tag}: train program missing");
        return;
    };
    let k = trainer.steps_per_call() as f64;
    let mut metrics = MetricsLogger::in_memory();
    bench.run_with_items(&format!("{tag}/train_steps"), Some(k), &mut || {
        trainer.chunk(&mut metrics).unwrap();
    });
    // quantized eval latency (cast in rust + eval program)
    let mut eval = Evaluator::new(0);
    let fmt = QuantFormat::parse(if format == "none" { "int4" } else { format }, 0).unwrap();
    bench.run(&format!("{tag}/quantized_eval"), || {
        std::hint::black_box(eval.eval_cast(&trainer, Some(&fmt), Rounding::Rtn).unwrap());
    });
}

fn main() {
    lotion::util::logging::init();
    let engine = match auto_executor(Path::new("artifacts")) {
        Ok(e) => e,
        Err(e) => {
            eprintln!("no backend available: {e:#}");
            return;
        }
    };
    let engine: &dyn Executor = &*engine;
    let mut b = Bench::new(1, 5);
    workload(engine, &mut b, "fig2_linreg_lotion_int4", "linreg_d12000", "lotion", "int4", 1.0);
    workload(engine, &mut b, "fig2_linreg_qat_int4", "linreg_d12000", "qat", "int4", 0.0);
    workload(engine, &mut b, "fig3_linear2_k8_lotion", "linear2_d12000_k8", "lotion", "int4", 1.0);
    workload(engine, &mut b, "fig9_lm150_lotion_int4", "lm-150m-sim", "lotion", "int4", 300.0);
    workload(engine, &mut b, "fig9_lm150_qat_int4", "lm-150m-sim", "qat", "int4", 0.0);
    workload(engine, &mut b, "fig9_lm150_rat_int4", "lm-150m-sim", "rat", "int4", 0.0);
    workload(engine, &mut b, "tab1_lm150_lotion_int8", "lm-150m-sim", "lotion", "int8", 300.0);
    workload(engine, &mut b, "fig11_lm300_lotion_int4", "lm-300m-sim", "lotion", "int4", 300.0);
    workload(engine, &mut b, "fig11_lm300_qat_int4", "lm-300m-sim", "qat", "int4", 0.0);
    workload(engine, &mut b, "fig12_lm150_lotion_fp4", "lm-150m-sim", "lotion", "fp4", 300.0);
    workload(engine, &mut b, "fig12_lm150_qat_fp4", "lm-150m-sim", "qat", "fp4", 0.0);
    print!("{}", b.table("experiment workloads (per paper table/figure)"));
    let out = Path::new("BENCH_exp_tables.json");
    match b.write_json(out, "exp_tables") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
