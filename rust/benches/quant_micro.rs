//! Micro-benchmarks for the rust quantization substrate (the L3 side of
//! quantized evaluation). Run with `cargo bench` — uses the in-repo
//! benchlib since criterion is unavailable offline.

use lotion::benchlib::Bench;
use lotion::quant::{blocks::block_scales, cast_rr, cast_rtn, sigma2, QuantFormat};
use lotion::util::rng::Rng;

fn main() {
    let n = 1_000_000;
    let mut rng = Rng::new(0);
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
    let mut b = Bench::new(2, 10);

    for fmt_name in ["int4", "int8", "fp4"] {
        for block in [0usize, 64] {
            let fmt = QuantFormat::parse(fmt_name, block).unwrap();
            let tag = if block == 0 { "tensor" } else { "b64" };

            b.run_with_items(&format!("block_scales/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                std::hint::black_box(block_scales(&w, &fmt));
            });
            b.run_with_items(&format!("cast_rtn/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                let mut v = w.clone();
                cast_rtn(&mut v, &fmt);
                std::hint::black_box(v);
            });
            let mut rr_rng = Rng::new(1);
            b.run_with_items(&format!("cast_rr/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                let mut v = w.clone();
                cast_rr(&mut v, &fmt, &mut rr_rng);
                std::hint::black_box(v);
            });
            b.run_with_items(&format!("sigma2/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                std::hint::black_box(sigma2(&w, &fmt));
            });
        }
    }
    print!("{}", b.table("quant substrate micro (1M f32 elements)"));
}
