//! Micro-benchmarks for the rust quantization substrate (the L3 side of
//! quantized evaluation). Run with `cargo bench` — uses the in-repo
//! benchlib since criterion is unavailable offline.
//!
//! Emits `BENCH_quant_micro.json` so the kernel-throughput trajectory
//! (incl. the thread-scaling rows) is tracked per PR.

use lotion::benchlib::Bench;
use lotion::quant::{
    blocks::block_scales, cast_rr, cast_rr_seeded, cast_rtn, cast_rtn_pool,
    lotion_penalty_and_grad_pool, sigma2, sigma2_pool, QuantFormat,
};
use lotion::util::pool::Pool;
use lotion::util::rng::Rng;
use std::path::Path;

fn main() {
    let n = 1_000_000;
    let mut rng = Rng::new(0);
    let w: Vec<f32> = (0..n).map(|_| rng.normal_f32() * 0.1).collect();
    let mut b = Bench::new(2, 10);

    for fmt_name in ["int4", "int8", "fp4"] {
        for block in [0usize, 64] {
            let fmt = QuantFormat::parse(fmt_name, block).unwrap();
            let tag = if block == 0 { "tensor" } else { "b64" };

            b.run_with_items(&format!("block_scales/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                std::hint::black_box(block_scales(&w, &fmt));
            });
            b.run_with_items(&format!("cast_rtn/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                let mut v = w.clone();
                cast_rtn(&mut v, &fmt);
                std::hint::black_box(v);
            });
            let mut rr_rng = Rng::new(1);
            b.run_with_items(&format!("cast_rr/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                let mut v = w.clone();
                cast_rr(&mut v, &fmt, &mut rr_rng);
                std::hint::black_box(v);
            });
            b.run_with_items(&format!("sigma2/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                std::hint::black_box(sigma2(&w, &fmt));
            });
        }
    }

    // Thread-scaling rows (ISSUE 2): the 1M-element kernels pinned to
    // 1 / 2 / all worker threads on an explicit pool. Results are
    // bit-identical across rows; only throughput moves.
    let fisher: Vec<f32> = (0..n).map(|i| 1.0 / (1 + i % 7) as f32).collect();
    for (tag, threads) in [("t1", 1usize), ("t2", 2), ("tall", 0)] {
        let pool = Pool::new(threads);
        let fmt = QuantFormat::parse("int4", 64).unwrap();
        b.run_with_items(&format!("cast_rtn/int4/b64/{tag}"), Some(n as f64), &mut || {
            let mut v = w.clone();
            cast_rtn_pool(&mut v, &fmt, &pool);
            std::hint::black_box(v);
        });
        b.run_with_items(&format!("cast_rr/int4/b64/{tag}"), Some(n as f64), &mut || {
            let mut v = w.clone();
            cast_rr_seeded(&mut v, &fmt, 1, &pool);
            std::hint::black_box(v);
        });
        b.run_with_items(&format!("sigma2/int4/b64/{tag}"), Some(n as f64), &mut || {
            std::hint::black_box(sigma2_pool(&w, &fmt, &pool));
        });
        b.run_with_items(&format!("lotion_penalty_grad/int4/b64/{tag}"), Some(n as f64), &mut || {
            std::hint::black_box(lotion_penalty_and_grad_pool(&w, &fisher, &fmt, &pool));
        });
    }

    // Packed-representation rows (ISSUE 6): packing master weights
    // into block codes (the fused eval path's setup cost) and a full
    // dense decode (the traffic the fused matmul avoids paying).
    {
        use lotion::quant::PackedWeights;
        for fmt_name in ["int4", "int8", "fp4"] {
            for block in [0usize, 64] {
                let fmt = QuantFormat::parse(fmt_name, block).unwrap();
                let tag = if block == 0 { "tensor" } else { "b64" };
                b.run_with_items(&format!("pack_rtn/{fmt_name}/{tag}"), Some(n as f64), &mut || {
                    std::hint::black_box(PackedWeights::pack_rtn(&w, &fmt));
                });
            }
        }
        let fmt = QuantFormat::parse("int4", 64).unwrap();
        let packed = PackedWeights::pack_rtn(&w, &fmt);
        let mut out = vec![0.0f32; n];
        b.run_with_items("packed_decode/int4/b64", Some(n as f64), &mut || {
            packed.decode_into(&mut out);
            std::hint::black_box(&out);
        });
    }

    // Dispatch-tier rows (ISSUE 6): the hot kernels pinned to each
    // tier this CPU supports. Bit-identical output across rows — the
    // vector paths keep the scalar fold order — only throughput moves.
    {
        use lotion::util::simd::{set_global_simd, supported_tiers};
        let fmt = QuantFormat::parse("int4", 64).unwrap();
        for tier in supported_tiers() {
            set_global_simd(Some(tier));
            let tag = tier.name();
            b.run_with_items(&format!("cast_rtn/int4/b64/simd_{tag}"), Some(n as f64), &mut || {
                let mut v = w.clone();
                cast_rtn(&mut v, &fmt);
                std::hint::black_box(v);
            });
            b.run_with_items(&format!("sigma2/int4/b64/simd_{tag}"), Some(n as f64), &mut || {
                std::hint::black_box(sigma2(&w, &fmt));
            });
        }
        set_global_simd(None);
    }

    print!("{}", b.table("quant substrate micro (1M f32 elements)"));
    let out = Path::new("BENCH_quant_micro.json");
    match b.write_json(out, "quant_micro") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}
