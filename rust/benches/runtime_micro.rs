//! Runtime micro-benchmarks: native-backend train-step throughput
//! (steps/sec for linreg and linear2 at 1k / 100k parameters), the
//! KV-cache decode hot path (prefill + per-token step, dense vs
//! packed weights) plus, with `--features pjrt`, the PJRT
//! call-overhead and literal conversion numbers behind
//! EXPERIMENTS.md §Perf (L3).
//!
//! Emits `BENCH_runtime_micro.json` (benchlib JSON) next to the cwd so
//! per-PR throughput trajectories can be tracked.

use lotion::benchlib::Bench;
use lotion::config::{RunConfig, Schedule};
use lotion::coordinator::{DataSource, MetricsLogger, Trainer};
use lotion::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use lotion::experiments::common::synth_statics;
use lotion::runtime::native::{EstSchedule, ModelSpec, NativeEngine, NativeModel, OptKind};
use lotion::runtime::{Executor, Role};
use std::path::Path;

/// One native train-chunk throughput measurement.
fn native_train_bench(b: &mut Bench, engine: &dyn Executor, model: &str, tag: &str, d: usize) {
    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 1_000_000; // never reached; we call chunk() directly
    cfg.lr = 0.05;
    cfg.lambda = 1.0;
    cfg.schedule = Schedule::Constant;
    let (statics, _, _) = synth_statics(d, 42);
    let mut trainer =
        Trainer::new(engine, cfg, statics, DataSource::InGraph).expect("native trainer");
    let k = trainer.steps_per_call() as f64;
    let mut metrics = MetricsLogger::in_memory();
    b.run_with_items(&format!("native_train_step/{tag}"), Some(k), &mut || {
        trainer.chunk(&mut metrics).unwrap();
    });
}

/// One native LM train-chunk throughput measurement (steps/sec).
fn lm_train_bench(b: &mut Bench, engine: &dyn Executor, model: &str, tag: &str) {
    let mut cfg = RunConfig::default();
    cfg.model = model.into();
    cfg.method = "lotion".into();
    cfg.format = "int4".into();
    cfg.steps = 1_000_000; // never reached; we call chunk() directly
    cfg.lr = 1e-3;
    cfg.lambda = 100.0;
    cfg.schedule = Schedule::Constant;
    let eval = engine.manifest().find_eval(model).expect("lm eval entry");
    let data = eval
        .inputs
        .iter()
        .find(|s| s.role == Role::Data)
        .expect("lm data spec");
    let (batch, t1) = (data.shape[1], data.shape[2]);
    let corpus = ZipfMarkovCorpus::generate(300_000, 512, 4, 1);
    let toks = ByteTokenizer::new().encode(&corpus.bytes);
    let batcher = TokenBatcher::new(toks, batch, t1 - 1, 0.1);
    let mut trainer =
        Trainer::new(engine, cfg, vec![], DataSource::Tokens(batcher)).expect("lm trainer");
    let k = trainer.steps_per_call() as f64;
    let mut metrics = MetricsLogger::in_memory();
    b.run_with_items(&format!("native_train_step/{tag}"), Some(k), &mut || {
        trainer.chunk(&mut metrics).unwrap();
    });
}

fn main() {
    lotion::util::logging::init();
    let mut b = Bench::new(1, 5);

    // Native backend: steps/sec at ~1k and ~100k parameters for both
    // synthetic testbeds (throughput denominator = optimizer steps).
    let engine = NativeEngine::with_models(&[
        NativeModel::from_spec(ModelSpec::LinReg { d: 1_000, batch: 32 }, OptKind::Sgd, 8),
        NativeModel::from_spec(ModelSpec::LinReg { d: 100_000, batch: 32 }, OptKind::Sgd, 8),
        NativeModel::from_spec(ModelSpec::Linear2 { d: 500, k: 2 }, OptKind::Sgd, 8),
        NativeModel::from_spec(ModelSpec::Linear2 { d: 50_000, k: 2 }, OptKind::Sgd, 8),
    ]);
    native_train_bench(&mut b, &engine, "linreg_d1000", "linreg/1k_params", 1_000);
    native_train_bench(&mut b, &engine, "linreg_d100000", "linreg/100k_params", 100_000);
    native_train_bench(&mut b, &engine, "linear2_d500_k2", "linear2/1k_params", 500);
    native_train_bench(&mut b, &engine, "linear2_d50000_k2", "linear2/100k_params", 50_000);

    // Estimator dispatch (ISSUE 9): one fixed linreg chunk driven
    // through three plug-ins — QAT's RTN cast, LOTION's Fisher
    // penalty, and the annealed-noise cast with its per-step σ_t
    // schedule — so the per-PR BENCH json tracks the trait layer's
    // per-method cost on an identical workload.
    {
        let d = 100_000;
        for method in ["qat", "lotion", "anneal"] {
            let engine = NativeEngine::with_models(&[NativeModel::from_spec(
                ModelSpec::LinReg { d, batch: 32 },
                OptKind::Sgd,
                8,
            )]);
            let mut cfg = RunConfig::default();
            cfg.model = format!("linreg_d{d}");
            cfg.method = method.into();
            cfg.format = "int4".into();
            cfg.steps = 1_000_000; // never reached; we call chunk() directly
            cfg.lr = 0.05;
            cfg.lambda = 1.0;
            cfg.schedule = Schedule::Constant;
            cfg.est_schedule = EstSchedule::Cosine;
            cfg.est_sigma0 = 0.5;
            let (statics, _, _) = synth_statics(d, 42);
            let mut trainer =
                Trainer::new(&engine, cfg, statics, DataSource::InGraph).expect("est trainer");
            let k = trainer.steps_per_call() as f64;
            let mut metrics = MetricsLogger::in_memory();
            b.run_with_items(&format!("estimator_dispatch/{method}"), Some(k), &mut || {
                trainer.chunk(&mut metrics).unwrap();
            });
        }
    }

    // Thread-scaling entries (ISSUE 2): the same workloads pinned to
    // 1 / 2 / all worker threads, so the per-PR BENCH json tracks the
    // threaded backend's speedup explicitly. Output is bit-identical
    // across rows — only wall clock moves.
    for (tag, threads) in [("t1", 1usize), ("t2", 2), ("tall", 0)] {
        let engine = NativeEngine::with_models(&[
            NativeModel::from_spec(ModelSpec::LinReg { d: 1_000, batch: 32 }, OptKind::Sgd, 8),
            NativeModel::from_spec(ModelSpec::LinReg { d: 100_000, batch: 32 }, OptKind::Sgd, 8),
        ])
        .with_threads(threads);
        native_train_bench(
            &mut b,
            &engine,
            "linreg_d1000",
            &format!("linreg/1k_params/{tag}"),
            1_000,
        );
        native_train_bench(
            &mut b,
            &engine,
            "linreg_d100000",
            &format!("linreg/100k_params/{tag}"),
            100_000,
        );
    }

    // Transformer-interpreter train-step throughput (ISSUE 3): the
    // default registry's lm-tiny / lm-150m-sim presets on the native
    // backend, so the per-PR BENCH json tracks the LM hot path.
    {
        let engine = NativeEngine::new();
        lm_train_bench(&mut b, &engine, "lm-tiny", "lm/tiny");
        lm_train_bench(&mut b, &engine, "lm-150m-sim", "lm/150m_sim");
    }

    // SIMD dispatch tiers (ISSUE 6): the LM train step pinned to the
    // scalar tier vs runtime detection (AVX2/NEON where available).
    // Output is bit-identical across rows — only wall clock moves.
    {
        use lotion::util::simd::{set_global_simd, SimdTier};
        let engine = NativeEngine::new();
        set_global_simd(Some(SimdTier::Scalar));
        lm_train_bench(&mut b, &engine, "lm-150m-sim", "lm/150m_sim/simd_scalar");
        set_global_simd(None);
        lm_train_bench(&mut b, &engine, "lm-150m-sim", "lm/150m_sim/simd_auto");
    }

    // RTN-eval path (ISSUE 6): host-side cast through the plain eval
    // entry (materializes a full f32 copy of every quantized tensor)
    // vs the fused `eval_q` route (nibble-packed codes, block dequant
    // inside the matmul tiles — no dense wq buffer). Same loss
    // bit-for-bit; only time and memory traffic move.
    {
        use lotion::coordinator::Evaluator;
        use lotion::quant::{cast_rtn, QuantFormat};
        use lotion::runtime::executor::value;
        use lotion::tensor::HostTensor;
        use lotion::util::rng::Rng;

        let engine = NativeEngine::new();
        let mut cfg = RunConfig::default();
        cfg.model = "lm-150m-sim".into();
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.steps = 1_000_000;
        cfg.lr = 1e-3;
        cfg.schedule = Schedule::Constant;
        let eval = engine.manifest().find_eval("lm-150m-sim").expect("lm eval entry");
        let ke = eval.eval_batches.max(1);
        let data = eval.inputs.iter().find(|s| s.role == Role::Data).expect("lm data spec");
        let (batch, t1) = (data.shape[1], data.shape[2]);
        let corpus = ZipfMarkovCorpus::generate(300_000, 512, 4, 1);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        let batcher = TokenBatcher::new(toks, batch, t1 - 1, 0.1);
        let trainer =
            Trainer::new(&engine, cfg, vec![], DataSource::Tokens(batcher)).expect("lm trainer");
        let chunk = match &trainer.data {
            DataSource::Tokens(bt) => value(bt.val_chunk(ke, &mut Rng::new(3))),
            DataSource::InGraph => unreachable!("lm consumes tokens"),
        };
        let fmt = QuantFormat::parse("int4", 0).unwrap();
        let quantized = trainer.quantized_keys().to_vec();
        b.run("rtn_eval/lm_150m_sim/int4/host_cast", || {
            let loss = trainer
                .session
                .eval_loss(Some(chunk.clone()), &mut |spec, v| {
                    Ok(if quantized.iter().any(|k| k == &spec.name) {
                        let mut wq = v.as_f32();
                        cast_rtn(&mut wq, &fmt);
                        value(HostTensor::from_f32(&v.shape, wq))
                    } else {
                        v.clone()
                    })
                })
                .unwrap();
            std::hint::black_box(loss);
        });
        b.run("rtn_eval/lm_150m_sim/int4/fused_packed", || {
            let loss = trainer
                .session
                .eval_loss_quantized("int4", Some(chunk.clone()))
                .unwrap()
                .expect("native eval_q entry");
            std::hint::black_box(loss);
        });
        // the evaluator's public route lands on the fused path for RTN
        let mut ev = Evaluator::new(7);
        b.run("rtn_eval/lm_150m_sim/int4/evaluator_route", || {
            let loss = ev
                .eval_cast(&trainer, Some(&fmt), lotion::quant::Rounding::Rtn)
                .unwrap();
            std::hint::black_box(loss);
        });
    }

    // KV-cache decode (ISSUE 8): per-token latency of the serving hot
    // path at lm-tiny scale — prefill (items = prompt tokens) and the
    // single-token step, dense f32 weights vs the fused packed routes
    // (per-tensor int4 and per-block int4@64). The packed rows never
    // materialize dense weights; items/s reads as tokens/s.
    {
        use lotion::runtime::executor::value;
        use lotion::runtime::Decoder;
        use lotion::tensor::HostTensor;

        let engine = NativeEngine::new();
        let init = engine.manifest().find_init("lm-tiny").expect("lm-tiny init").clone();
        let out = engine
            .call(&init, &[value(HostTensor::from_u32(&[2], vec![3, 5]))])
            .expect("init weights");
        let weights: Vec<_> = init.outputs.iter().map(|s| s.name.clone()).zip(out).collect();
        for fmt in ["none", "int4", "int4@64"] {
            let dec = Decoder::open(&engine, "lm-tiny", fmt, &weights).expect("decode entry");
            let prompt: Vec<i32> = (0..16).map(|i| (i * 11 % 256) as i32).collect();
            b.run_with_items(
                &format!("decode_prefill/lm_tiny/{fmt}"),
                Some(prompt.len() as f64),
                &mut || {
                    std::hint::black_box(dec.prefill(0, &prompt).unwrap());
                },
            );
            dec.prefill(0, &prompt).expect("prefill");
            let mut pos = prompt.len();
            b.run_with_items(&format!("decode_step/lm_tiny/{fmt}"), Some(1.0), &mut || {
                if pos >= dec.max_seq() {
                    // cache full: rewind the slot with a fresh prefill
                    dec.prefill(0, &prompt).unwrap();
                    pos = prompt.len();
                }
                std::hint::black_box(dec.step(0, pos, 1).unwrap());
                pos += 1;
            });
        }
    }

    // Pool-dispatch overhead (ISSUE 4): an element-wise kernel on a
    // tensor just above PAR_MIN, where per-call thread spawning used
    // to dominate. With the persistent pool the `tall` row tracks pure
    // wake/join cost against the `t1` serial baseline.
    {
        use lotion::util::pool::{chunk_ranges, Pool, PAR_CHUNK, PAR_MIN};
        let n = PAR_MIN + PAR_CHUNK; // just over the serial cutoff
        let ranges = chunk_ranges(n, PAR_CHUNK);
        for (tag, threads) in [("t1", 1usize), ("tall", 0)] {
            let pool = Pool::new(threads);
            let mut data = vec![1.0f32; n];
            b.run_with_items(
                &format!("pool_dispatch/just_over_par_min/{tag}"),
                Some(n as f64),
                &mut || {
                    pool.for_chunks_mut(&mut data, &ranges, n, |_, r, chunk| {
                        for (i, v) in chunk.iter_mut().enumerate() {
                            *v = (*v + (r.start + i) as f32).sqrt();
                        }
                    });
                },
            );
        }
    }

    // Sweep-shard scaling (ISSUE 5): an 8-point LR grid over a small
    // linreg, serial vs 4 sweep workers on factory-spawned engines.
    // Per-engine kernel pools are pinned to 1 thread so the t4/t1
    // ratio isolates sweep-level sharding; outputs are bit-identical
    // across rows — only wall clock moves.
    {
        use lotion::coordinator::sweep::lr_sweep;
        use lotion::runtime::native::NativeFactory;

        let spec = ModelSpec::LinReg { d: 4_000, batch: 32 };
        let factory = NativeFactory::new(vec![NativeModel::from_spec(spec, OptKind::Sgd, 8)], 1);
        let mut cfg = RunConfig::default();
        cfg.name = "bench_sweep".into();
        cfg.model = "linreg_d4000".into();
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.eval_formats = vec!["int4".into()];
        cfg.steps = 32;
        cfg.lambda = 1.0;
        cfg.eval_every = 32;
        cfg.schedule = Schedule::Constant;
        let lrs: Vec<f64> = (1..=8).map(|i| 0.02 + 0.03 * i as f64).collect();
        for (tag, workers) in [("t1", 1usize), ("t4", 4)] {
            b.run_with_items(&format!("sweep/linreg_grid8/{tag}"), Some(8.0), &mut || {
                let res = lr_sweep(
                    &factory,
                    workers,
                    &cfg,
                    &lrs,
                    "int4",
                    "rtn",
                    &|_: &dyn Executor, _: &RunConfig| {
                        let (statics, _, _) = synth_statics(4_000, 42);
                        Ok((statics, DataSource::InGraph))
                    },
                )
                .expect("bench sweep");
                assert!(res.iter().all(|r| !r.diverged));
            });
        }
    }

    // Sweep-spec DSL (ISSUE 10): parse + full expansion of the
    // embedded fig2 grid (DESIGN.md §10). Pure host-side work — these
    // rows track the before-anything-spawns cost of the spec path;
    // items = grid points for the expand row.
    {
        const SPEC: &str = include_str!("../../examples/fig2.sweep");
        b.run("spec_parse/fig2", || {
            std::hint::black_box(lotion::spec::parse(SPEC).unwrap());
        });
        let n = lotion::spec::plan(SPEC, "fig2.sweep", &RunConfig::default(), None)
            .expect("fig2 spec expands")
            .points
            .len() as f64;
        b.run_with_items("spec_expand/fig2", Some(n), &mut || {
            std::hint::black_box(
                lotion::spec::plan(SPEC, "fig2.sweep", &RunConfig::default(), None).unwrap(),
            );
        });
    }

    // Checkpoint save/load (ISSUE 7): the crash-safety tax at the
    // lm-150m-sim scale — the atomic temp+fsync+rename save and the
    // OOM-hardened bounded load of a ~22 MB `.lotn` archive. Items =
    // archive bytes, so the rows read as disk bandwidth.
    {
        use lotion::checkpoint::Checkpoint;
        use lotion::coordinator::Evaluator;
        use lotion::util::tempdir::TempDir;

        let engine = NativeEngine::new();
        let mut cfg = RunConfig::default();
        cfg.model = "lm-150m-sim".into();
        cfg.method = "lotion".into();
        cfg.format = "int4".into();
        cfg.steps = 1_000_000; // never reached; we only snapshot
        cfg.lr = 1e-3;
        cfg.schedule = Schedule::Constant;
        let trainer =
            Trainer::new(&engine, cfg, vec![], DataSource::InGraph).expect("lm trainer");
        let eval = Evaluator::new(7);
        let dir = TempDir::new();
        let path = dir.path().join("bench.lotn");
        trainer.save_checkpoint(&eval, 0, &path).expect("seed save");
        let sz = std::fs::metadata(&path).expect("checkpoint written").len() as f64;
        b.run_with_items("ckpt/lm_150m_sim/save", Some(sz), &mut || {
            trainer.save_checkpoint(&eval, 0, &path).unwrap();
        });
        b.run_with_items("ckpt/lm_150m_sim/load", Some(sz), &mut || {
            std::hint::black_box(Checkpoint::load(&path).unwrap());
        });
    }

    #[cfg(feature = "pjrt")]
    pjrt_benches(&mut b);

    print!("{}", b.table("runtime micro"));
    let out = Path::new("BENCH_runtime_micro.json");
    match b.write_json(out, "runtime_micro") {
        Ok(()) => println!("wrote {}", out.display()),
        Err(e) => eprintln!("could not write {}: {e}", out.display()),
    }
}

/// PJRT-path numbers: literal conversion bandwidth, dispatch overhead,
/// and train-chunk latency per AOT preset (needs `make artifacts`).
#[cfg(feature = "pjrt")]
fn pjrt_benches(b: &mut Bench) {
    use lotion::runtime::literals::{to_host, to_literal};
    use lotion::runtime::{Engine, Role};
    use lotion::tensor::HostTensor;

    let Ok(engine) = Engine::new(Path::new("artifacts")) else {
        eprintln!("artifacts/ not built; skipping PJRT runtime benches");
        return;
    };

    // literal conversion bandwidth (the chunk-boundary copy cost)
    for n in [1usize << 16, 1 << 22] {
        let t = HostTensor::from_f32(&[n], vec![1.0; n]);
        let bytes = (n * 4) as f64;
        b.run_with_items(&format!("host->literal/{}KiB", n * 4 / 1024), Some(bytes), &mut || {
            std::hint::black_box(to_literal(&t).unwrap());
        });
        let lit = to_literal(&t).unwrap();
        b.run_with_items(&format!("literal->host/{}KiB", n * 4 / 1024), Some(bytes), &mut || {
            std::hint::black_box(to_host(&lit).unwrap());
        });
    }

    // eval-call latency (tiny program: measures PJRT dispatch overhead)
    {
        let entry = engine.manifest.find_eval("linreg_d256").unwrap().clone();
        let (statics, _, _) = synth_statics(256, 42);
        let w = to_literal(&HostTensor::zeros(lotion::tensor::DType::F32, &[256])).unwrap();
        let lam = to_literal(&statics[0].1).unwrap();
        let wstar = to_literal(&statics[1].1).unwrap();
        b.run("pjrt_call/eval_linreg_d256", || {
            std::hint::black_box(
                engine.call_literals(&entry, &[w.clone(), lam.clone(), wstar.clone()]).unwrap(),
            );
        });
    }

    // train-chunk latency per preset (K scanned steps per call)
    for (model, method, steps_label) in [
        ("linreg_d256", "lotion", "k8"),
        ("lm-tiny", "lotion", "k4"),
        ("lm-150m-sim", "lotion", "k8"),
        ("lm-150m-sim", "qat", "k8"),
        ("lm-150m-sim", "ptq", "k8"),
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.method = method.into();
        cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
        cfg.steps = 10_000; // never reached; we call chunk() directly
        cfg.lr = 1e-3;
        let (statics, data) = if model.starts_with("linreg") {
            let (s, _, _) = synth_statics(256, 42);
            (s, DataSource::InGraph)
        } else {
            let corpus = lotion::data::ZipfMarkovCorpus::generate(300_000, 512, 4, 1);
            let toks = lotion::data::ByteTokenizer::new().encode(&corpus.bytes);
            let Ok(eval) = engine.manifest.find_eval(model) else {
                eprintln!("skipping {model}/{method} (eval artifact missing)");
                continue;
            };
            let Some(d) = eval.inputs.iter().find(|s| matches!(s.role, Role::Data)) else {
                eprintln!("skipping {model}/{method} (no data spec)");
                continue;
            };
            (
                vec![],
                DataSource::Tokens(lotion::data::TokenBatcher::new(
                    toks,
                    d.shape[1],
                    d.shape[2] - 1,
                    0.1,
                )),
            )
        };
        let Ok(mut trainer) = Trainer::new(&engine, cfg, statics, data) else {
            eprintln!("skipping {model}/{method} (artifact missing)");
            continue;
        };
        let k = trainer.steps_per_call() as f64;
        let mut metrics = MetricsLogger::in_memory();
        b.run_with_items(
            &format!("train_chunk/{model}/{method}/{steps_label}"),
            Some(k),
            &mut || {
                trainer.chunk(&mut metrics).unwrap();
            },
        );
    }
}
