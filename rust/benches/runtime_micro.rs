//! PJRT runtime micro-benchmarks: executable call overhead, literal
//! conversion bandwidth, and train-chunk latency per model preset —
//! the numbers behind EXPERIMENTS.md §Perf (L3).

use lotion::benchlib::Bench;
use lotion::config::RunConfig;
use lotion::coordinator::{DataSource, MetricsLogger, Trainer};
use lotion::experiments::common::synth_statics;
use lotion::runtime::literals::{to_host, to_literal};
use lotion::runtime::Engine;
use lotion::tensor::HostTensor;
use std::path::Path;

fn main() {
    lotion::util::logging::init();
    let Ok(engine) = Engine::new(Path::new("artifacts")) else {
        eprintln!("artifacts/ not built; skipping runtime benches");
        return;
    };
    let mut b = Bench::new(2, 10);

    // literal conversion bandwidth (the chunk-boundary copy cost)
    for n in [1usize << 16, 1 << 22] {
        let t = HostTensor::from_f32(&[n], vec![1.0; n]);
        let bytes = (n * 4) as f64;
        b.run_with_items(&format!("host->literal/{}KiB", n * 4 / 1024), Some(bytes), &mut || {
            std::hint::black_box(to_literal(&t).unwrap());
        });
        let lit = to_literal(&t).unwrap();
        b.run_with_items(&format!("literal->host/{}KiB", n * 4 / 1024), Some(bytes), &mut || {
            std::hint::black_box(to_host(&lit).unwrap());
        });
    }

    // eval-call latency (tiny program: measures PJRT dispatch overhead)
    {
        let entry = engine.manifest.find_eval("linreg_d256").unwrap().clone();
        let (statics, _, _) = synth_statics(256, 42);
        let w = to_literal(&HostTensor::zeros(lotion::tensor::DType::F32, &[256])).unwrap();
        let lam = to_literal(&statics[0].1).unwrap();
        let wstar = to_literal(&statics[1].1).unwrap();
        b.run("pjrt_call/eval_linreg_d256", || {
            std::hint::black_box(engine.call(&entry, &[w.clone(), lam.clone(), wstar.clone()]).unwrap());
        });
    }

    // train-chunk latency per preset (K scanned steps per call)
    for (model, method, steps_label) in [
        ("linreg_d256", "lotion", "k8"),
        ("lm-tiny", "lotion", "k4"),
        ("lm-150m-sim", "lotion", "k8"),
        ("lm-150m-sim", "qat", "k8"),
        ("lm-150m-sim", "ptq", "k8"),
    ] {
        let mut cfg = RunConfig::default();
        cfg.model = model.into();
        cfg.method = method.into();
        cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
        cfg.steps = 10_000; // never reached; we call chunk() directly
        cfg.lr = 1e-3;
        let (statics, data) = if model.starts_with("linreg") {
            let (s, _, _) = synth_statics(256, 42);
            (s, DataSource::InGraph)
        } else {
            let corpus = lotion::data::ZipfMarkovCorpus::generate(300_000, 512, 4, 1);
            let toks = lotion::data::ByteTokenizer::new().encode(&corpus.bytes);
            let eval = engine.manifest.find_eval(model).unwrap();
            let d = eval.inputs.iter().find(|s| matches!(s.role, lotion::runtime::Role::Data)).unwrap();
            (vec![], DataSource::Tokens(lotion::data::TokenBatcher::new(toks, d.shape[1], d.shape[2] - 1, 0.1)))
        };
        let Ok(mut trainer) = Trainer::new(&engine, cfg, statics, data) else {
            eprintln!("skipping {model}/{method} (artifact missing)");
            continue;
        };
        let k = trainer.steps_per_call() as f64;
        let mut metrics = MetricsLogger::in_memory();
        b.run_with_items(
            &format!("train_chunk/{model}/{method}/{steps_label}"),
            Some(k),
            &mut || {
                trainer.chunk(&mut metrics).unwrap();
            },
        );
    }
    print!("{}", b.table("PJRT runtime micro"));
}
