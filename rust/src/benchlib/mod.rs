//! Micro-benchmark harness (criterion is not in the offline vendor
//! set): warmup + timed iterations, robust summary stats, a table
//! printer shared by `cargo bench` targets, and a JSON emitter so
//! `BENCH_*.json` trajectories can be tracked across PRs.

use crate::formats::json::Json;
use crate::util::stats::Summary;
use std::path::Path;
use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_s: f64,
    pub p50_s: f64,
    pub p95_s: f64,
    pub std_s: f64,
    /// optional throughput denominator (elements, steps, bytes...)
    pub per_iter_items: Option<f64>,
}

impl BenchResult {
    pub fn items_per_sec(&self) -> Option<f64> {
        self.per_iter_items.map(|n| n / self.mean_s)
    }
}

pub struct Bench {
    pub warmup: usize,
    pub iters: usize,
    pub results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench { warmup: 2, iters: 10, results: Vec::new() }
    }
}

impl Bench {
    pub fn new(warmup: usize, iters: usize) -> Bench {
        Bench { warmup, iters, ..Default::default() }
    }

    /// Time `f` (excluding warmup runs). Returns the result and records it.
    pub fn run(&mut self, name: &str, mut f: impl FnMut()) -> &BenchResult {
        self.run_with_items(name, None, &mut f)
    }

    /// Like `run`, with a per-iteration item count for throughput.
    pub fn run_with_items(
        &mut self,
        name: &str,
        per_iter_items: Option<f64>,
        f: &mut dyn FnMut(),
    ) -> &BenchResult {
        for _ in 0..self.warmup {
            f();
        }
        let mut s = Summary::new();
        for _ in 0..self.iters {
            let t0 = Instant::now();
            f();
            s.add(t0.elapsed().as_secs_f64());
        }
        self.results.push(BenchResult {
            name: name.to_string(),
            iters: self.iters,
            mean_s: s.mean(),
            p50_s: s.median(),
            p95_s: s.percentile(95.0),
            std_s: s.std(),
            per_iter_items,
        });
        self.results.last().unwrap()
    }

    /// All recorded results as a `BENCH_*.json`-shaped document.
    pub fn to_json(&self, suite: &str) -> Json {
        let results: Vec<Json> = self
            .results
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("name", Json::str(r.name.clone())),
                    ("iters", Json::num(r.iters as f64)),
                    ("mean_s", Json::num(r.mean_s)),
                    ("p50_s", Json::num(r.p50_s)),
                    ("p95_s", Json::num(r.p95_s)),
                    ("std_s", Json::num(r.std_s)),
                    (
                        "items_per_sec",
                        r.items_per_sec()
                            .filter(|v| v.is_finite())
                            .map(Json::num)
                            .unwrap_or(Json::Null),
                    ),
                ])
            })
            .collect();
        Json::obj(vec![("suite", Json::str(suite)), ("results", Json::Arr(results))])
    }

    /// Write the JSON document (e.g. `BENCH_runtime_micro.json`).
    pub fn write_json(&self, path: &Path, suite: &str) -> anyhow::Result<()> {
        std::fs::write(path, self.to_json(suite).to_string())?;
        Ok(())
    }

    /// Render all recorded results as an aligned table.
    pub fn table(&self, title: &str) -> String {
        let mut out = String::new();
        out.push_str(&format!("\n== {title} ==\n"));
        out.push_str(&format!(
            "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}\n",
            "benchmark", "iters", "mean", "p50", "p95", "throughput"
        ));
        for r in &self.results {
            let tp = r
                .items_per_sec()
                .map(|v| {
                    if v > 1e6 {
                        format!("{:.2} M/s", v / 1e6)
                    } else {
                        format!("{v:.1} /s")
                    }
                })
                .unwrap_or_else(|| "-".into());
            out.push_str(&format!(
                "{:<44} {:>8} {:>12} {:>12} {:>12} {:>14}\n",
                r.name,
                r.iters,
                fmt_time(r.mean_s),
                fmt_time(r.p50_s),
                fmt_time(r.p95_s),
                tp
            ));
        }
        out
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.3} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_is_sane() {
        let mut b = Bench::new(1, 5);
        let r = b.run("sleep 2ms", || std::thread::sleep(std::time::Duration::from_millis(2)));
        assert!(r.mean_s >= 0.0015, "mean={}", r.mean_s);
        assert!(r.p95_s >= r.p50_s);
        assert_eq!(r.iters, 5);
    }

    #[test]
    fn throughput() {
        let mut b = Bench::new(0, 3);
        let r = b.run_with_items("noop", Some(1000.0), &mut || {});
        assert!(r.items_per_sec().unwrap() > 0.0);
    }

    #[test]
    fn json_roundtrips() {
        let mut b = Bench::new(0, 2);
        let mut tick = || std::thread::sleep(std::time::Duration::from_micros(200));
        b.run_with_items("fast", Some(100.0), &mut tick);
        b.run("slow", tick);
        let doc = Json::parse(&b.to_json("suite_x").to_string()).unwrap();
        assert_eq!(doc.get("suite").unwrap().as_str(), Some("suite_x"));
        let rs = doc.get("results").unwrap().as_arr().unwrap();
        assert_eq!(rs.len(), 2);
        assert_eq!(rs[0].get("name").unwrap().as_str(), Some("fast"));
        assert!(rs[0].get("items_per_sec").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(rs[1].get("items_per_sec"), Some(&Json::Null));
    }

    #[test]
    fn table_formats() {
        let mut b = Bench::new(0, 2);
        b.run("x", || {});
        let t = b.table("test");
        assert!(t.contains("benchmark") && t.contains('x'));
        assert_eq!(fmt_time(2e-9), "2.0 ns");
        assert_eq!(fmt_time(0.5), "500.00 ms");
    }
}
