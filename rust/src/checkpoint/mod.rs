//! Checkpoint archive: a simple length-prefixed binary tensor container
//! (`.lotn`) holding named tensors + a JSON metadata blob.
//!
//! Layout: magic "LOTN1\n" | meta_len:u64 | meta json bytes |
//!         n_tensors:u64 | per tensor: name_len:u64, name, dtype byte,
//!         ndim:u64, dims:u64*, data_len:u64, raw little-endian data.
//!
//! Crash safety (DESIGN.md §7): `save` writes a uniquely-named temp
//! file, fsyncs it, then atomically renames it over the target and
//! fsyncs the parent directory — a reader never observes a torn
//! archive, and a kill between fsync and rename leaves the previous
//! checkpoint intact. `load` treats every length field as untrusted:
//! allocations are bounded by the bytes actually remaining in the
//! file, so a flipped length byte yields a clean error, not an OOM.

use crate::formats::json::Json;
use crate::tensor::{DType, HostTensor};
use crate::util::faults;
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};

const MAGIC: &[u8; 6] = b"LOTN1\n";

/// Process-wide save sequence: the fault-plan ordinal for the
/// `ckpt_save` site (first save in a process is ordinal 1) and the
/// uniqueness tiebreaker in temp-file names when concurrent sweep
/// workers checkpoint sibling points in one directory.
static SAVE_SEQ: AtomicU64 = AtomicU64::new(0);

pub struct Checkpoint {
    pub meta: Json,
    pub tensors: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    pub fn new(meta: Json) -> Checkpoint {
        Checkpoint { meta, tensors: Vec::new() }
    }

    pub fn push(&mut self, name: &str, t: HostTensor) {
        self.tensors.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let seq = SAVE_SEQ.fetch_add(1, Ordering::Relaxed) + 1;
        // unique temp name: pid + process-wide sequence, so concurrent
        // sweep workers saving siblings never collide on one ".tmp"
        let tmp = path.with_extension(format!("tmp.{}.{}", std::process::id(), seq));
        let res = self.save_inner(&tmp, path, seq);
        if res.is_err() {
            let _ = std::fs::remove_file(&tmp);
        }
        res
    }

    fn save_inner(&self, tmp: &Path, path: &Path, seq: u64) -> Result<()> {
        let file = std::fs::File::create(tmp)?;
        let mut f = std::io::BufWriter::new(file);
        f.write_all(MAGIC)?;
        let meta = self.meta.to_string().into_bytes();
        f.write_all(&(meta.len() as u64).to_le_bytes())?;
        f.write_all(&meta)?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u64).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[dtype_byte(t.dtype)])?;
            f.write_all(&(t.shape.len() as u64).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&(t.bytes().len() as u64).to_le_bytes())?;
            f.write_all(t.bytes())?;
        }
        f.flush()?;
        let file = f.into_inner().map_err(|e| anyhow!("flushing {tmp:?}: {e}"))?;
        // durability point: the temp file's bytes reach disk before the
        // rename can publish them
        file.sync_all()?;
        drop(file);
        // fault site *between* fsync and rename: a kill here must leave
        // the previous checkpoint untouched (atomicity proof in tests)
        faults::poke("ckpt_save", seq)?;
        std::fs::rename(tmp, path)?;
        // fsync the directory so the rename itself survives a crash
        #[cfg(unix)]
        if let Some(dir) = path.parent() {
            if !dir.as_os_str().is_empty() {
                std::fs::File::open(dir)?.sync_all()?;
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let file = std::fs::File::open(path).map_err(|e| anyhow!("opening {path:?}: {e}"))?;
        // every length field below is untrusted: bound allocations by
        // the bytes actually left in the file
        let remaining = file.metadata()?.len();
        let mut f = Bounded { inner: std::io::BufReader::new(file), remaining };
        let mut magic = [0u8; 6];
        f.read_bytes(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a LOTN1 checkpoint");
        }
        let meta_len = f.read_len("meta")?;
        let mut meta_bytes = vec![0u8; meta_len];
        f.read_bytes(&mut meta_bytes)?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)?;
        let n = f.read_count("tensor count", 25)?;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = f.read_len("tensor name")?;
            let mut name = vec![0u8; name_len];
            f.read_bytes(&mut name)?;
            let mut db = [0u8; 1];
            f.read_bytes(&mut db)?;
            let dtype = byte_dtype(db[0])?;
            let ndim = f.read_count("ndim", 8)?;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(f.read_u64()? as usize);
            }
            let data_len = f.read_len("tensor data")?;
            let mut data = vec![0u8; data_len];
            f.read_bytes(&mut data)?;
            tensors.push((
                String::from_utf8(name)?,
                HostTensor::from_bytes(dtype, &shape, data)?,
            ));
        }
        Ok(Checkpoint { meta, tensors })
    }
}

/// A reader that tracks how many bytes the file can still supply, so
/// corrupt length prefixes fail fast instead of driving `vec![0; n]`
/// multi-GB allocations.
struct Bounded<R> {
    inner: R,
    remaining: u64,
}

impl<R: Read> Bounded<R> {
    fn read_bytes(&mut self, buf: &mut [u8]) -> Result<()> {
        if (buf.len() as u64) > self.remaining {
            bail!(
                "truncated checkpoint: need {} bytes, {} remain",
                buf.len(),
                self.remaining
            );
        }
        self.inner.read_exact(buf)?;
        self.remaining -= buf.len() as u64;
        Ok(())
    }

    fn read_u64(&mut self) -> Result<u64> {
        let mut b = [0u8; 8];
        self.read_bytes(&mut b)?;
        Ok(u64::from_le_bytes(b))
    }

    /// A byte-length prefix: must not exceed the bytes remaining.
    fn read_len(&mut self, what: &str) -> Result<usize> {
        let n = self.read_u64()?;
        if n > self.remaining {
            bail!("corrupt checkpoint: {what} length {n} exceeds {} remaining bytes", self.remaining);
        }
        Ok(n as usize)
    }

    /// An element-count prefix where each element occupies at least
    /// `min_bytes` in the file: bounds `Vec::with_capacity`.
    fn read_count(&mut self, what: &str, min_bytes: u64) -> Result<usize> {
        let n = self.read_u64()?;
        match n.checked_mul(min_bytes) {
            Some(total) if total <= self.remaining => Ok(n as usize),
            _ => bail!("corrupt checkpoint: {what} {n} exceeds remaining file size"),
        }
    }
}

fn dtype_byte(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U32 => 2,
    }
}

fn byte_dtype(b: u8) -> Result<DType> {
    Ok(match b {
        0 => DType::F32,
        1 => DType::I32,
        2 => DType::U32,
        other => bail!("bad dtype byte {other}"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::faults::ScopedPlan;
    use crate::util::tempdir::TempDir;

    fn sample() -> Checkpoint {
        let mut c = Checkpoint::new(Json::obj(vec![("step", Json::num(42.0))]));
        c.push("w", HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        c.push("toks", HostTensor::from_i32(&[2], vec![7, -8]));
        c
    }

    #[test]
    fn roundtrip() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        let c = sample();
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.meta.get("step").unwrap().as_usize(), Some(42));
        assert_eq!(back.get("w").unwrap().as_f32(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get("toks").unwrap().as_i32(), vec![7, -8]);
        assert!(back.get("missing").is_none());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = TempDir::new();
        let path = dir.path().join("junk");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn save_is_atomic_replace() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        let c = Checkpoint::new(Json::Null);
        c.save(&path).unwrap();
        c.save(&path).unwrap(); // second save overwrites cleanly
        assert!(Checkpoint::load(&path).is_ok());
        // no temp litter of any suffix left behind
        let leftovers: Vec<_> = std::fs::read_dir(dir.path())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
    }

    #[test]
    fn truncated_archives_error_cleanly() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        sample().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // every prefix of the archive must load-fail, never panic/OOM
        for cut in [0, 3, 6, 10, full.len() / 2, full.len() - 1] {
            let p = dir.path().join("cut.lotn");
            std::fs::write(&p, &full[..cut]).unwrap();
            assert!(Checkpoint::load(&p).is_err(), "cut at {cut} loaded");
        }
    }

    #[test]
    fn bit_flipped_lengths_error_cleanly() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        sample().save(&path).unwrap();
        let full = std::fs::read(&path).unwrap();
        // flip a high byte in each u64 length field so the claimed
        // size becomes multi-GB: must error, not allocate
        // meta_len is at offset 6; n_tensors follows the meta
        let meta_len = u64::from_le_bytes(full[6..14].try_into().unwrap()) as usize;
        let n_tensors_off = 14 + meta_len;
        let first_name_len_off = n_tensors_off + 8;
        for off in [6, n_tensors_off, first_name_len_off] {
            let mut bad = full.clone();
            bad[off + 6] ^= 0x7f; // blow up the 2^48 byte
            let p = dir.path().join("flip.lotn");
            std::fs::write(&p, &bad).unwrap();
            assert!(Checkpoint::load(&p).is_err(), "flip at {off} loaded");
        }
    }

    #[test]
    fn io_fault_during_save_leaves_previous_checkpoint() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        sample().save(&path).unwrap();
        let before = std::fs::read(&path).unwrap();
        // arm an io_err at every save in this scope: the next save's
        // seq is unknown here, so match a wide window via repeats
        let seq_now = SAVE_SEQ.load(Ordering::Relaxed);
        // other tests in this binary may save concurrently and advance
        // the sequence; a wide ordinal window keeps this deterministic
        let plan: Vec<String> = (1..=64)
            .map(|d| format!("io_err@ckpt_save:{}", seq_now + d))
            .collect();
        let _g = ScopedPlan::install(&plan.join(",")).unwrap();
        assert!(sample().save(&path).is_err());
        // target untouched, temp cleaned up
        assert_eq!(std::fs::read(&path).unwrap(), before);
        let leftovers: Vec<_> = std::fs::read_dir(dir.path())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.file_name().to_string_lossy().contains("tmp"))
            .collect();
        assert!(leftovers.is_empty(), "temp files left: {leftovers:?}");
    }
}
