//! Checkpoint archive: a simple length-prefixed binary tensor container
//! (`.lotn`) holding named tensors + a JSON metadata blob.
//!
//! Layout: magic "LOTN1\n" | meta_len:u64 | meta json bytes |
//!         n_tensors:u64 | per tensor: name_len:u64, name, dtype byte,
//!         ndim:u64, dims:u64*, data_len:u64, raw little-endian data.

use crate::formats::json::Json;
use crate::tensor::{DType, HostTensor};
use anyhow::{anyhow, bail, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 6] = b"LOTN1\n";

pub struct Checkpoint {
    pub meta: Json,
    pub tensors: Vec<(String, HostTensor)>,
}

impl Checkpoint {
    pub fn new(meta: Json) -> Checkpoint {
        Checkpoint { meta, tensors: Vec::new() }
    }

    pub fn push(&mut self, name: &str, t: HostTensor) {
        self.tensors.push((name.to_string(), t));
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let tmp = path.with_extension("tmp");
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        f.write_all(MAGIC)?;
        let meta = self.meta.to_string().into_bytes();
        f.write_all(&(meta.len() as u64).to_le_bytes())?;
        f.write_all(&meta)?;
        f.write_all(&(self.tensors.len() as u64).to_le_bytes())?;
        for (name, t) in &self.tensors {
            f.write_all(&(name.len() as u64).to_le_bytes())?;
            f.write_all(name.as_bytes())?;
            f.write_all(&[dtype_byte(t.dtype)])?;
            f.write_all(&(t.shape.len() as u64).to_le_bytes())?;
            for &d in &t.shape {
                f.write_all(&(d as u64).to_le_bytes())?;
            }
            f.write_all(&(t.bytes().len() as u64).to_le_bytes())?;
            f.write_all(t.bytes())?;
        }
        f.flush()?;
        drop(f);
        std::fs::rename(&tmp, path)?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).map_err(|e| anyhow!("opening {path:?}: {e}"))?,
        );
        let mut magic = [0u8; 6];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("{path:?} is not a LOTN1 checkpoint");
        }
        let meta_len = read_u64(&mut f)? as usize;
        let mut meta_bytes = vec![0u8; meta_len];
        f.read_exact(&mut meta_bytes)?;
        let meta = Json::parse(std::str::from_utf8(&meta_bytes)?)?;
        let n = read_u64(&mut f)? as usize;
        let mut tensors = Vec::with_capacity(n);
        for _ in 0..n {
            let name_len = read_u64(&mut f)? as usize;
            let mut name = vec![0u8; name_len];
            f.read_exact(&mut name)?;
            let mut db = [0u8; 1];
            f.read_exact(&mut db)?;
            let dtype = byte_dtype(db[0])?;
            let ndim = read_u64(&mut f)? as usize;
            let mut shape = Vec::with_capacity(ndim);
            for _ in 0..ndim {
                shape.push(read_u64(&mut f)? as usize);
            }
            let data_len = read_u64(&mut f)? as usize;
            let mut data = vec![0u8; data_len];
            f.read_exact(&mut data)?;
            tensors.push((
                String::from_utf8(name)?,
                HostTensor::from_bytes(dtype, &shape, data)?,
            ));
        }
        Ok(Checkpoint { meta, tensors })
    }
}

fn dtype_byte(d: DType) -> u8 {
    match d {
        DType::F32 => 0,
        DType::I32 => 1,
        DType::U32 => 2,
    }
}

fn byte_dtype(b: u8) -> Result<DType> {
    Ok(match b {
        0 => DType::F32,
        1 => DType::I32,
        2 => DType::U32,
        other => bail!("bad dtype byte {other}"),
    })
}

fn read_u64(f: &mut impl Read) -> Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn roundtrip() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        let mut c = Checkpoint::new(Json::obj(vec![("step", Json::num(42.0))]));
        c.push("w", HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]));
        c.push("toks", HostTensor::from_i32(&[2], vec![7, -8]));
        c.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.meta.get("step").unwrap().as_usize(), Some(42));
        assert_eq!(back.get("w").unwrap().as_f32(), vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(back.get("toks").unwrap().as_i32(), vec![7, -8]);
        assert!(back.get("missing").is_none());
    }

    #[test]
    fn rejects_non_checkpoint() {
        let dir = TempDir::new();
        let path = dir.path().join("junk");
        std::fs::write(&path, b"not a checkpoint at all").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn save_is_atomic_replace() {
        let dir = TempDir::new();
        let path = dir.path().join("c.lotn");
        let c = Checkpoint::new(Json::Null);
        c.save(&path).unwrap();
        c.save(&path).unwrap(); // second save overwrites cleanly
        assert!(Checkpoint::load(&path).is_ok());
        assert!(!path.with_extension("tmp").exists());
    }
}
