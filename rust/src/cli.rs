//! CLI argument parser substrate (clap is not in the offline vendor
//! set): subcommand + `--flag value` / `--switch` / positional args.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: String,
    pub positional: Vec<String>,
    flags: BTreeMap<String, Vec<String>>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `argv[1..]`: first bare word is the subcommand; `--key value`
    /// pairs become flags (repeatable), `--key` at end-of-args or before
    /// another `--` is a boolean switch.
    pub fn parse(argv: &[String]) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    bail!("stray --");
                }
                if let Some((k, v)) = name.split_once('=') {
                    args.flags.entry(k.to_string()).or_default().push(v.to_string());
                    continue;
                }
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap().clone();
                        args.flags.entry(name.to_string()).or_default().push(v);
                    }
                    _ => args.switches.push(name.to_string()),
                }
            } else if args.subcommand.is_empty() {
                args.subcommand = a.clone();
            } else {
                args.positional.push(a.clone());
            }
        }
        Ok(args)
    }

    pub fn from_env() -> Result<Args> {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).and_then(|v| v.last()).map(|s| s.as_str())
    }

    pub fn flag_all(&self, name: &str) -> Vec<&str> {
        self.flags.get(name).map(|v| v.iter().map(|s| s.as_str()).collect()).unwrap_or_default()
    }

    pub fn required(&self, name: &str) -> Result<&str> {
        self.flag(name).ok_or_else(|| anyhow!("missing required --{name}"))
    }

    pub fn switch(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    /// A usize flag with no default — `None` when absent. Distinguishes
    /// "not given" from an explicit `0` (e.g. `--ckpt-every 0` disables
    /// checkpointing even when the config or env sets a cadence).
    pub fn usize_opt(&self, name: &str) -> Result<Option<usize>> {
        match self.flag(name) {
            None => Ok(None),
            Some(v) => v.parse().map(Some).map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|e| anyhow!("--{name}: {e}")),
        }
    }

    /// `f64_or` narrowed to f32 (sampling temperatures and the like).
    pub fn f32_or(&self, name: &str, default: f32) -> Result<f32> {
        Ok(self.f64_or(name, default as f64)? as f32)
    }

    pub fn str_or(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    /// The `--backend {auto|native|pjrt}` selector shared by every
    /// subcommand; validates here so all commands report flag typos
    /// the same way.
    pub fn backend(&self) -> Result<&str> {
        let b = self.flag("backend").unwrap_or("auto");
        if b == "auto" || b == "native" || b == "pjrt" {
            Ok(b)
        } else {
            bail!("unknown backend {b:?} (expected auto|native|pjrt)")
        }
    }

    /// The `--simd {auto|scalar|avx2|neon}` kernel-tier override
    /// shared by every subcommand (mirrors [`Args::backend`]).
    /// `None` means auto-detect; an explicit tier pins the dispatch
    /// (clamped to scalar if the CPU lacks it).
    pub fn simd(&self) -> Result<Option<crate::util::simd::SimdTier>> {
        crate::util::simd::SimdTier::parse(self.flag("simd").unwrap_or("auto"))
    }

    /// The `--sweep-workers N` knob shared by the sweep-shaped
    /// subcommands (`sweep`, `exp`). Returns the *requested* width —
    /// flag first, then `cfg_default` (the `[sweep] workers` config
    /// value); `0` is "unresolved" and falls through to
    /// `LOTION_SWEEP_WORKERS` / serial inside
    /// `coordinator::sweep::resolve_sweep_workers`.
    pub fn sweep_workers(&self, cfg_default: usize) -> Result<usize> {
        self.usize_or("sweep-workers", cfg_default)
    }

    /// The sweep-spec source for `lotion sweep`: `--spec-str <text>`
    /// (inline) or `--spec <file|->` (`-` reads stdin), mutually
    /// exclusive. Returns `(origin, source)` where `origin` is the name
    /// spec errors render under (`file.sweep:3:7: ...`), or `None` when
    /// neither flag is given (the `[sweep] spec` config seam and the
    /// legacy `--lrs` path take over).
    pub fn spec_source(&self) -> Result<Option<(String, String)>> {
        match (self.flag("spec"), self.flag("spec-str")) {
            (Some(_), Some(_)) => bail!("--spec and --spec-str are mutually exclusive"),
            (None, None) => Ok(None),
            (None, Some(s)) => Ok(Some(("<spec-str>".to_string(), s.to_string()))),
            (Some("-"), None) => {
                use std::io::Read;
                let mut text = String::new();
                std::io::stdin()
                    .read_to_string(&mut text)
                    .map_err(|e| anyhow!("reading spec from stdin: {e}"))?;
                Ok(Some(("<stdin>".to_string(), text)))
            }
            (Some(path), None) => {
                let text = std::fs::read_to_string(path)
                    .map_err(|e| anyhow!("reading spec {path:?}: {e}"))?;
                Ok(Some((path.to_string(), text)))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>()).unwrap()
    }

    #[test]
    fn subcommand_flags_positional() {
        let a = parse("train --config c.toml --steps 100 extra --dry-run");
        assert_eq!(a.subcommand, "train");
        assert_eq!(a.flag("config"), Some("c.toml"));
        assert_eq!(a.usize_or("steps", 0).unwrap(), 100);
        assert_eq!(a.positional, vec!["extra"]);
        assert!(a.switch("dry-run"));
        assert!(!a.switch("verbose"));
    }

    #[test]
    fn equals_form_and_repeats() {
        let a = parse("exp --set a=1 --set b=2");
        assert_eq!(a.flag_all("set"), vec!["a=1", "b=2"]);
        assert_eq!(a.flag("set"), Some("b=2"));
    }

    #[test]
    fn required_and_defaults() {
        let a = parse("x");
        assert!(a.required("missing").is_err());
        assert_eq!(a.f64_or("lr", 0.5).unwrap(), 0.5);
        assert_eq!(a.str_or("name", "d"), "d");
        assert_eq!(a.f32_or("temperature", 0.8).unwrap(), 0.8);
        assert_eq!(parse("x --temperature 1.5").f32_or("temperature", 0.8).unwrap(), 1.5);
        assert!(parse("x --temperature warm").f32_or("temperature", 0.8).is_err());
    }

    #[test]
    fn usize_opt_distinguishes_unset_from_zero() {
        assert_eq!(parse("train").usize_opt("ckpt-every").unwrap(), None);
        assert_eq!(parse("train --ckpt-every 0").usize_opt("ckpt-every").unwrap(), Some(0));
        assert_eq!(parse("train --ckpt-every 8").usize_opt("ckpt-every").unwrap(), Some(8));
        assert!(parse("train --ckpt-every x").usize_opt("ckpt-every").is_err());
    }

    #[test]
    fn backend_flag_is_validated() {
        assert_eq!(parse("train").backend().unwrap(), "auto");
        assert_eq!(parse("train --backend native").backend().unwrap(), "native");
        assert_eq!(parse("train --backend pjrt").backend().unwrap(), "pjrt");
        assert!(parse("train --backend tpu").backend().is_err());
    }

    #[test]
    fn simd_flag_is_validated() {
        use crate::util::simd::SimdTier;
        assert_eq!(parse("train").simd().unwrap(), None);
        assert_eq!(parse("train --simd scalar").simd().unwrap(), Some(SimdTier::Scalar));
        assert_eq!(parse("train --simd avx2").simd().unwrap(), Some(SimdTier::Avx2));
        assert!(parse("train --simd sse9").simd().is_err());
    }

    #[test]
    fn spec_source_resolves_inline_and_file() {
        assert_eq!(parse("sweep").spec_source().unwrap(), None);
        let (origin, text) =
            parse("sweep --spec-str steps=16").spec_source().unwrap().unwrap();
        assert_eq!(origin, "<spec-str>");
        assert_eq!(text, "steps=16");

        let dir = crate::util::tempdir::TempDir::new();
        let path = dir.path().join("t.sweep");
        std::fs::write(&path, "grid: lr=[0.1]\n").unwrap();
        let argv = vec![
            "sweep".to_string(),
            "--spec".to_string(),
            path.to_string_lossy().into_owned(),
        ];
        let (origin, text) = Args::parse(&argv).unwrap().spec_source().unwrap().unwrap();
        assert_eq!(origin, path.to_string_lossy());
        assert_eq!(text, "grid: lr=[0.1]\n");

        let err = parse("sweep --spec a.sweep --spec-str steps=1")
            .spec_source()
            .unwrap_err()
            .to_string();
        assert!(err.contains("mutually exclusive"), "{err}");
        assert!(parse("sweep --spec /nope/missing.sweep").spec_source().is_err());
    }

    #[test]
    fn sweep_workers_flag_beats_config_default() {
        assert_eq!(parse("sweep --sweep-workers 4").sweep_workers(2).unwrap(), 4);
        assert_eq!(parse("sweep").sweep_workers(2).unwrap(), 2);
        assert_eq!(parse("sweep").sweep_workers(0).unwrap(), 0);
        assert!(parse("sweep --sweep-workers four").sweep_workers(0).is_err());
    }
}
