//! Config system: TOML-subset parser + typed run configuration.
//!
//! `lotion-rs train --config runs/lotion_int4.toml --set train.lr=3e-4`
//! Files parse into a flat `section.key -> Value` map; [`RunConfig`]
//! gives the typed view with defaults and validation.

pub mod run;
pub mod toml;

pub use run::{env_ckpt_dir, env_ckpt_every, RunConfig, Schedule};
pub use toml::{TomlDoc, Value};
