//! Typed run configuration assembled from a [`TomlDoc`] + CLI overrides.

use super::toml::TomlDoc;
use crate::quant::Rounding;
use crate::runtime::native::estimator::{self, EstSchedule};
use anyhow::{bail, Result};

/// LR schedule selector (the coordinator computes per-step LRs; the AOT
/// programs are schedule-agnostic).
#[derive(Clone, Debug, PartialEq)]
pub enum Schedule {
    Constant,
    /// cosine decay from lr to `final_frac * lr` over the run, with
    /// linear warmup for the first `warmup` steps
    Cosine { warmup: usize, final_frac: f64 },
}

/// One training run: which artifact family, for how long, with what
/// schedule/eval cadence.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// run name (output subdirectory under results_dir)
    pub name: String,
    /// model name as it appears in the manifest (e.g. "lm-150m-sim")
    pub model: String,
    pub method: String,
    /// "int4" | "int8" | "fp4" | "none" (ptq trains unquantized)
    pub format: String,
    pub steps: usize,
    pub lr: f64,
    /// LOTION regularization weight (paper's lambda, §4.3)
    pub lambda: f64,
    pub schedule: Schedule,
    pub seed: u64,
    /// evaluate quantized val loss every this many steps
    pub eval_every: usize,
    /// roundings applied at each eval point
    pub eval_roundings: Vec<Rounding>,
    /// eval formats (PTQ evals across all; trained-quantized methods
    /// typically eval in their training format)
    pub eval_formats: Vec<String>,
    pub artifacts_dir: String,
    pub results_dir: String,
    pub checkpoint_every: usize,
    /// checkpoint directory override (`[train] ckpt_dir`); None = the
    /// run's output directory
    pub ckpt_dir: Option<String>,
    /// native-backend worker threads: 0 = auto (`LOTION_THREADS` env
    /// var, else all cores). Output is bit-identical at any value —
    /// a pure throughput knob (DESIGN.md §3).
    pub threads: usize,
    /// sweep-level worker threads (grid points in flight, each on its
    /// own factory-spawned engine): 0 = auto (`LOTION_SWEEP_WORKERS`
    /// env var, else 1 — serial). Sweep output is bit-identical at any
    /// value — a pure throughput knob (DESIGN.md §3).
    pub sweep_workers: usize,
    /// estimator-schedule shape for scheduled methods (`[est] schedule`)
    pub est_schedule: EstSchedule,
    /// annealing noise width at step 0 (`[est] sigma0`, "anneal" only)
    pub est_sigma0: f64,
    /// gradient scale at step 0 (`[est] grad_scale`, "cge" only)
    pub est_grad_scale: f64,
    /// sweep-spec source path (`[sweep] spec`): `lotion sweep` without
    /// `--spec`/`--lrs` runs this spec. Never result-determining — the
    /// spec's own digest guards its journal — so it is excluded from
    /// [`RunConfig::digest`].
    pub sweep_spec: Option<String>,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            // smoke-scale linreg: present in both the native backend's
            // registry and the AOT smoke set, so a bare `lotion-rs
            // train` works on any backend with no artifacts built
            name: "run".into(),
            model: "linreg_d256".into(),
            method: "lotion".into(),
            format: "int4".into(),
            steps: 200,
            lr: 0.1,
            lambda: 1.0,
            schedule: Schedule::Cosine { warmup: 10, final_frac: 0.1 },
            seed: 0,
            eval_every: 50,
            eval_roundings: vec![Rounding::Rtn, Rounding::Rr],
            eval_formats: vec![],
            artifacts_dir: "artifacts".into(),
            results_dir: "results".into(),
            checkpoint_every: 0,
            ckpt_dir: None,
            threads: 0,
            sweep_workers: 0,
            est_schedule: EstSchedule::Constant,
            est_sigma0: 1.0,
            est_grad_scale: 1.0,
            sweep_spec: None,
        }
    }
}

/// Every key [`RunConfig::from_doc`] reads — the strict-key whitelist.
/// Any other key in a config file (or `--set` override) errors with a
/// nearest-key suggestion instead of passing silently, matching how an
/// unknown `--method` lists the estimator registry.
const KNOWN_DOC_KEYS: [&str; 24] = [
    "name",
    "model",
    "method",
    "seed",
    "train.schedule",
    "train.warmup",
    "train.final_frac",
    "train.lr",
    "train.steps",
    "train.lambda",
    "train.checkpoint_every",
    "train.ckpt_dir",
    "train.threads",
    "quant.format",
    "eval.roundings",
    "eval.formats",
    "eval.every",
    "paths.artifacts",
    "paths.results",
    "sweep.workers",
    "sweep.spec",
    "est.schedule",
    "est.sigma0",
    "est.grad_scale",
];

/// Reject unknown config keys. The suggestion tries the full dotted
/// key first (`train.stpes` → `train.steps`), then the bare segment
/// (top-level `steps` → `train.steps`); with no plausible typo it
/// lists the section's known keys.
fn check_known_keys(doc: &TomlDoc) -> Result<()> {
    use crate::util::text::{edit_distance, nearest};
    for key in doc.entries.keys() {
        if KNOWN_DOC_KEYS.contains(&key.as_str()) {
            continue;
        }
        let suggestion = nearest(key, KNOWN_DOC_KEYS.iter().copied()).or_else(|| {
            let last = key.rsplit('.').next().unwrap_or(key);
            KNOWN_DOC_KEYS
                .iter()
                .copied()
                .map(|k| (edit_distance(last, k.rsplit('.').next().unwrap_or(k)), k))
                .min_by_key(|&(d, k)| (d, k.len()))
                .filter(|&(d, _)| d <= 2 && d < last.chars().count())
                .map(|(_, k)| k)
        });
        if let Some(s) = suggestion {
            bail!("unknown config key {key:?} (did you mean {s:?}?)");
        }
        let section = key.split_once('.').map(|(s, _)| s);
        let known: Vec<&str> = match section {
            Some(s) => {
                let prefix = format!("{s}.");
                KNOWN_DOC_KEYS.iter().copied().filter(|k| k.starts_with(&prefix)).collect()
            }
            None => KNOWN_DOC_KEYS.iter().copied().filter(|k| !k.contains('.')).collect(),
        };
        if known.is_empty() {
            bail!("unknown config key {key:?} (known keys: {})", KNOWN_DOC_KEYS.join(", "));
        }
        match section {
            Some(s) => bail!("unknown config key {key:?} (known [{s}] keys: {})", known.join(", ")),
            None => bail!("unknown config key {key:?} (known top-level keys: {})", known.join(", ")),
        }
    }
    Ok(())
}

impl RunConfig {
    pub fn from_doc(doc: &TomlDoc) -> Result<RunConfig> {
        check_known_keys(doc)?;
        let d = RunConfig::default();
        let schedule = match doc.str_or("train.schedule", "cosine").as_str() {
            "constant" => Schedule::Constant,
            "cosine" => Schedule::Cosine {
                warmup: doc.usize_or("train.warmup", 10),
                final_frac: doc.f64_or("train.final_frac", 0.1),
            },
            other => bail!("unknown schedule {other:?}"),
        };
        let mut eval_roundings = Vec::new();
        if let Some(v) = doc.get("eval.roundings").and_then(|v| v.as_arr().map(|a| a.to_vec())) {
            for r in v {
                eval_roundings
                    .push(Rounding::parse(r.as_str().unwrap_or_default())?);
            }
        } else {
            eval_roundings = d.eval_roundings.clone();
        }
        let eval_formats = doc
            .get("eval.formats")
            .and_then(|v| v.as_arr().map(|a| a.to_vec()))
            .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
            .unwrap_or_default();
        let cfg = RunConfig {
            name: doc.str_or("name", &d.name),
            model: doc.str_or("model", &d.model),
            method: doc.str_or("method", &d.method),
            format: doc.str_or("quant.format", &d.format),
            steps: doc.usize_or("train.steps", d.steps),
            lr: doc.f64_or("train.lr", d.lr),
            lambda: doc.f64_or("train.lambda", d.lambda),
            schedule,
            seed: doc.i64_or("seed", 0) as u64,
            eval_every: doc.usize_or("eval.every", d.eval_every),
            eval_roundings,
            eval_formats,
            artifacts_dir: doc.str_or("paths.artifacts", &d.artifacts_dir),
            results_dir: doc.str_or("paths.results", &d.results_dir),
            checkpoint_every: doc.usize_or("train.checkpoint_every", 0),
            ckpt_dir: doc.get("train.ckpt_dir").and_then(|v| v.as_str().map(String::from)),
            threads: doc.usize_or("train.threads", 0),
            sweep_workers: doc.usize_or("sweep.workers", 0),
            est_schedule: EstSchedule::parse(&doc.str_or("est.schedule", "constant"))?,
            est_sigma0: doc.f64_or("est.sigma0", d.est_sigma0),
            est_grad_scale: doc.f64_or("est.grad_scale", d.est_grad_scale),
            sweep_spec: doc.get("sweep.spec").and_then(|v| v.as_str().map(String::from)),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        // registry-driven: an unknown method lists the known estimators
        let est = estimator::parse(&self.method)?;
        if self.steps == 0 {
            bail!("train.steps must be > 0");
        }
        if self.lr <= 0.0 {
            bail!("train.lr must be > 0");
        }
        if !est.formats().is_empty() && self.format == "none" {
            bail!("method {:?} requires a quantization format", self.method);
        }
        if self.est_sigma0 < 0.0 {
            bail!("est.sigma0 must be >= 0");
        }
        Ok(())
    }

    /// Per-step schedule value for scheduled estimators: σ_t for
    /// "anneal" (σ→0 annealing from `est.sigma0`), the gradient scale
    /// for "cge", a plain decay factor otherwise. Pure function of the
    /// step, so resumed runs recompute exactly what the uninterrupted
    /// run saw.
    pub fn est_sched_at(&self, step: usize) -> f64 {
        let base = match self.method.as_str() {
            "anneal" => self.est_sigma0,
            "cge" => self.est_grad_scale,
            _ => 1.0,
        };
        base * self.est_schedule.value_at(step, self.steps)
    }

    /// Per-step learning rate under the configured schedule.
    pub fn lr_at(&self, step: usize) -> f64 {
        match &self.schedule {
            Schedule::Constant => self.lr,
            Schedule::Cosine { warmup, final_frac } => {
                if step < *warmup {
                    return self.lr * (step + 1) as f64 / *warmup as f64;
                }
                let t = (step - warmup) as f64 / (self.steps.saturating_sub(*warmup).max(1)) as f64;
                let cos = 0.5 * (1.0 + (std::f64::consts::PI * t.min(1.0)).cos());
                self.lr * (final_frac + (1.0 - final_frac) * cos)
            }
        }
    }

    /// The manifest key of the training artifact for this run.
    pub fn train_artifact(&self) -> String {
        let fmt = if self.method == "ptq" { "none" } else { self.format.as_str() };
        format!("train_{}_{}_{}", self.model, self.method, fmt)
    }

    /// FNV-1a hash of the *result-determining* configuration: the
    /// fields that feed the bit-identical training output. Throughput
    /// knobs (`threads`, `sweep_workers`), paths, the run name and the
    /// checkpointing knobs are excluded on purpose — a checkpoint or
    /// sweep journal written at one thread count must resume at any
    /// other (the determinism contract makes that sound), and changing
    /// the snapshot cadence must not invalidate existing checkpoints.
    pub fn digest(&self) -> String {
        let mut key = format!(
            "{}|{}|{}|{}|{:016x}|{:016x}|{:?}|{}|{}",
            self.model,
            self.method,
            self.format,
            self.steps,
            self.lr.to_bits(),
            self.lambda.to_bits(),
            self.schedule,
            self.seed,
            self.eval_every,
        );
        for r in &self.eval_roundings {
            key.push('|');
            key.push_str(r.name());
        }
        for f in &self.eval_formats {
            key.push('|');
            key.push_str(f);
        }
        // estimator-schedule knobs join the key only when they differ
        // from the defaults, so every digest computed before the
        // estimator layer existed — including those inside old
        // checkpoints — hashes exactly as it always did
        let d = (EstSchedule::Constant, 1.0f64, 1.0f64);
        if (self.est_schedule, self.est_sigma0, self.est_grad_scale) != d {
            key.push_str(&format!(
                "|est:{}:{:016x}:{:016x}",
                self.est_schedule.name(),
                self.est_sigma0.to_bits(),
                self.est_grad_scale.to_bits()
            ));
        }
        let mut h: u64 = 0xcbf29ce484222325;
        for b in key.as_bytes() {
            h ^= *b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
        format!("{h:016x}")
    }
}

/// `LOTION_CKPT_EVERY`: checkpoint cadence fallback when neither the
/// CLI flag nor the config sets one.
pub fn env_ckpt_every() -> Option<usize> {
    std::env::var("LOTION_CKPT_EVERY").ok().and_then(|v| v.parse().ok())
}

/// `LOTION_CKPT_DIR`: checkpoint directory fallback.
pub fn env_ckpt_dir() -> Option<String> {
    std::env::var("LOTION_CKPT_DIR").ok().filter(|v| !v.is_empty())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_and_doc() {
        let doc = TomlDoc::parse(
            "name = \"t\"\nmodel = \"lm-tiny\"\nmethod = \"qat\"\n[train]\nlr = 0.01\nsteps = 100\n[quant]\nformat = \"int8\"",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.method, "qat");
        assert_eq!(cfg.format, "int8");
        assert_eq!(cfg.steps, 100);
        assert_eq!(cfg.train_artifact(), "train_lm-tiny_qat_int8");
        assert_eq!(cfg.threads, 0); // auto unless [train] threads is set
    }

    #[test]
    fn threads_from_doc() {
        let doc = TomlDoc::parse("[train]\nthreads = 3").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().threads, 3);
    }

    #[test]
    fn sweep_workers_from_doc() {
        let doc = TomlDoc::parse("[sweep]\nworkers = 4").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().sweep_workers, 4);
        // default: 0 (auto — LOTION_SWEEP_WORKERS, else serial)
        assert_eq!(RunConfig::default().sweep_workers, 0);
    }

    #[test]
    fn ptq_artifact_has_no_format() {
        let mut cfg = RunConfig::default();
        cfg.method = "ptq".into();
        assert_eq!(cfg.train_artifact(), "train_linreg_d256_ptq_none");
    }

    #[test]
    fn digest_tracks_result_determining_fields_only() {
        let base = RunConfig::default();
        let d0 = base.digest();
        assert_eq!(d0, base.digest(), "digest must be stable");
        // throughput/path/ckpt knobs do not change the digest
        let mut c = base.clone();
        c.threads = 7;
        c.sweep_workers = 3;
        c.name = "other".into();
        c.results_dir = "/elsewhere".into();
        c.checkpoint_every = 5;
        c.ckpt_dir = Some("/ckpts".into());
        assert_eq!(c.digest(), d0);
        // result-determining fields do
        let mut c = base.clone();
        c.lr = 0.2;
        assert_ne!(c.digest(), d0);
        let mut c = base.clone();
        c.seed = 1;
        assert_ne!(c.digest(), d0);
        let mut c = base.clone();
        c.eval_every = 25;
        assert_ne!(c.digest(), d0);
    }

    #[test]
    fn ckpt_dir_from_doc() {
        let doc = TomlDoc::parse("[train]\nckpt_dir = \"/tmp/ck\"").unwrap();
        assert_eq!(RunConfig::from_doc(&doc).unwrap().ckpt_dir.as_deref(), Some("/tmp/ck"));
        assert_eq!(RunConfig::default().ckpt_dir, None);
    }

    #[test]
    fn validation_catches_bad_method() {
        let doc = TomlDoc::parse("method = \"magic\"").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        // the error lists the known estimators (registry-driven)
        assert!(err.contains("known estimators"), "{err}");
        assert!(err.contains("anneal"), "{err}");
    }

    #[test]
    fn est_knobs_from_doc() {
        let doc = TomlDoc::parse(
            "method = \"anneal\"\n[est]\nschedule = \"cosine\"\nsigma0 = 0.5\ngrad_scale = 2.0",
        )
        .unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.est_schedule, EstSchedule::Cosine);
        assert_eq!(cfg.est_sigma0, 0.5);
        assert_eq!(cfg.est_grad_scale, 2.0);
        assert!((cfg.est_sched_at(0) - 0.5).abs() < 1e-12, "sigma0 scales the schedule");
        assert!(cfg.est_sched_at(cfg.steps).abs() < 1e-12, "cosine anneals to 0");
        // defaults: constant schedule at unit scale
        let d = RunConfig::default();
        assert_eq!(d.est_schedule, EstSchedule::Constant);
        assert_eq!(d.est_sched_at(0), 1.0);
        assert_eq!(d.est_sched_at(d.steps), 1.0);
        // cge routes through grad_scale, legacy methods stay at 1
        let mut c = cfg.clone();
        c.method = "cge".into();
        assert_eq!(c.est_sched_at(0), 2.0);
        c.method = "lotion".into();
        assert_eq!(c.est_sched_at(0), 1.0);
        // bad knobs fail loudly
        let doc = TomlDoc::parse("[est]\nschedule = \"warp\"").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("known schedules"), "{err}");
        let doc = TomlDoc::parse("method = \"anneal\"\n[est]\nsigma0 = -1.0").unwrap();
        assert!(RunConfig::from_doc(&doc).is_err());
    }

    /// Default-valued estimator knobs must hash exactly as the
    /// pre-estimator-layer digest did, so checkpoints from old runs
    /// stay loadable; non-default knobs move the digest.
    #[test]
    fn est_knobs_are_digest_stable_for_old_configs() {
        let base = RunConfig::default();
        let d0 = base.digest();
        // literal pin: the digest of the default config as the
        // pre-estimator-layer code computed it — if this moves, every
        // existing checkpoint refuses to resume
        assert_eq!(d0, "b01037eef8a5832c");
        let mut c = base.clone();
        c.est_schedule = EstSchedule::Cosine;
        assert_ne!(c.digest(), d0);
        let mut c = base.clone();
        c.est_sigma0 = 0.5;
        assert_ne!(c.digest(), d0);
        let mut c = base.clone();
        c.est_grad_scale = 2.0;
        assert_ne!(c.digest(), d0);
    }

    /// Satellite (ISSUE 10): unknown config keys error with a
    /// nearest-known-key suggestion instead of passing silently.
    #[test]
    fn unknown_keys_error_with_suggestion() {
        let doc = TomlDoc::parse("[train]\nstpes = 16").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("unknown config key \"train.stpes\""), "{err}");
        assert!(err.contains("did you mean \"train.steps\"?"), "{err}");

        let doc = TomlDoc::parse("[sweep]\nworker = 4").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("did you mean \"sweep.workers\"?"), "{err}");

        let doc = TomlDoc::parse("[est]\nsigma = 0.5").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("did you mean \"est.sigma0\"?"), "{err}");

        // a bare key that belongs in a section suggests the dotted form
        let doc = TomlDoc::parse("steps = 16").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("did you mean \"train.steps\"?"), "{err}");

        // nothing plausible: list the section's known keys
        let doc = TomlDoc::parse("[train]\nwhatnow = 1").unwrap();
        let err = RunConfig::from_doc(&doc).unwrap_err().to_string();
        assert!(err.contains("known [train] keys"), "{err}");
        assert!(err.contains("train.lr"), "{err}");
    }

    #[test]
    fn sweep_spec_from_doc_and_digest_neutral() {
        let doc = TomlDoc::parse("[sweep]\nspec = \"examples/fig2.sweep\"").unwrap();
        let cfg = RunConfig::from_doc(&doc).unwrap();
        assert_eq!(cfg.sweep_spec.as_deref(), Some("examples/fig2.sweep"));
        // pointing a config at a spec must not move the run digest
        assert_eq!(cfg.digest(), RunConfig::default().digest());
        assert_eq!(RunConfig::default().sweep_spec, None);
    }

    #[test]
    fn cosine_schedule_shape() {
        let mut cfg = RunConfig::default();
        cfg.steps = 100;
        cfg.lr = 1.0;
        cfg.schedule = Schedule::Cosine { warmup: 10, final_frac: 0.1 };
        assert!(cfg.lr_at(0) < 0.2); // warmup start
        assert!((cfg.lr_at(9) - 1.0).abs() < 1e-9); // warmup end
        assert!(cfg.lr_at(55) < 1.0);
        assert!((cfg.lr_at(99) - 0.1).abs() < 0.03); // decayed to ~final
    }

    #[test]
    fn constant_schedule() {
        let mut cfg = RunConfig::default();
        cfg.schedule = Schedule::Constant;
        assert_eq!(cfg.lr_at(0), cfg.lr);
        assert_eq!(cfg.lr_at(1000), cfg.lr);
    }
}
