//! TOML-subset parser (serde/toml crates unavailable offline).
//!
//! Supported grammar — everything the run configs need:
//!   [section] / [a.b] headers, `key = value` pairs, comments (#),
//!   strings ("..." with basic escapes), integers, floats (incl.
//!   scientific), booleans, homogeneous arrays of the above.

use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Arr(Vec<Value>),
}

impl Value {
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Value::Float(f) => Some(*f),
            Value::Int(i) => Some(*i as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Value]> {
        match self {
            Value::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// A parsed document: flat `"section.key" -> Value` map (keys outside
/// any section are stored bare).
#[derive(Clone, Debug, Default)]
pub struct TomlDoc {
    pub entries: BTreeMap<String, Value>,
}

impl TomlDoc {
    pub fn parse(text: &str) -> Result<TomlDoc> {
        let mut doc = TomlDoc::default();
        let mut section = String::new();
        for (lineno, raw) in text.lines().enumerate() {
            let line = strip_comment(raw).trim();
            if line.is_empty() {
                continue;
            }
            if let Some(rest) = line.strip_prefix('[') {
                let name = rest
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow!("line {}: unterminated section", lineno + 1))?
                    .trim();
                if name.is_empty() {
                    bail!("line {}: empty section name", lineno + 1);
                }
                section = name.to_string();
                continue;
            }
            let eq = line
                .find('=')
                .ok_or_else(|| anyhow!("line {}: expected key = value", lineno + 1))?;
            let key = line[..eq].trim();
            if key.is_empty() {
                bail!("line {}: empty key", lineno + 1);
            }
            let value = parse_value(line[eq + 1..].trim())
                .map_err(|e| anyhow!("line {}: {e}", lineno + 1))?;
            let full = if section.is_empty() {
                key.to_string()
            } else {
                format!("{section}.{key}")
            };
            doc.entries.insert(full, value);
        }
        Ok(doc)
    }

    pub fn from_file(path: &std::path::Path) -> Result<TomlDoc> {
        TomlDoc::parse(
            &std::fs::read_to_string(path).map_err(|e| anyhow!("reading {path:?}: {e}"))?,
        )
    }

    /// Apply a `--set section.key=value` override (value re-parsed with
    /// the TOML value grammar; bare words become strings).
    pub fn set_override(&mut self, spec: &str) -> Result<()> {
        let eq = spec
            .find('=')
            .ok_or_else(|| anyhow!("override must be key=value: {spec:?}"))?;
        let key = spec[..eq].trim().to_string();
        let vtext = spec[eq + 1..].trim();
        let value = parse_value(vtext).unwrap_or_else(|_| Value::Str(vtext.to_string()));
        self.entries.insert(key, value);
        Ok(())
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.entries.get(key)
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).and_then(|v| v.as_str().map(String::from)).unwrap_or_else(|| default.into())
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.as_f64()).unwrap_or(default)
    }

    pub fn i64_or(&self, key: &str, default: i64) -> i64 {
        self.get(key).and_then(|v| v.as_i64()).unwrap_or(default)
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.i64_or(key, default as i64) as usize
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool()).unwrap_or(default)
    }

    pub fn f64_list(&self, key: &str) -> Option<Vec<f64>> {
        self.get(key)?.as_arr().map(|a| a.iter().filter_map(|v| v.as_f64()).collect())
    }
}

fn strip_comment(line: &str) -> &str {
    // '#' inside a quoted string does not start a comment
    let mut in_str = false;
    for (i, c) in line.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &line[..i],
            _ => {}
        }
    }
    line
}

fn parse_value(text: &str) -> Result<Value> {
    if text.is_empty() {
        bail!("empty value");
    }
    if let Some(rest) = text.strip_prefix('"') {
        let inner = rest.strip_suffix('"').ok_or_else(|| anyhow!("unterminated string"))?;
        return Ok(Value::Str(
            inner.replace("\\\"", "\"").replace("\\n", "\n").replace("\\\\", "\\"),
        ));
    }
    if let Some(rest) = text.strip_prefix('[') {
        let inner = rest.strip_suffix(']').ok_or_else(|| anyhow!("unterminated array"))?;
        let mut items = Vec::new();
        if !inner.trim().is_empty() {
            for part in split_top_level(inner) {
                items.push(parse_value(part.trim())?);
            }
        }
        return Ok(Value::Arr(items));
    }
    match text {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = text.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(f) = text.parse::<f64>() {
        return Ok(Value::Float(f));
    }
    bail!("cannot parse value {text:?}")
}

/// Split on commas not nested inside brackets/strings.
fn split_top_level(s: &str) -> Vec<&str> {
    let mut parts = Vec::new();
    let mut depth = 0;
    let mut in_str = false;
    let mut start = 0;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth -= 1,
            ',' if !in_str && depth == 0 => {
                parts.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&s[start..]);
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# run config
name = "fig2"          # inline comment
[train]
lr = 3e-4
steps = 400
lrs = [0.1, 0.3, 1.0]
resume = false
[quant]
format = "int4"
block_size = 0
"#;

    #[test]
    fn parses_sections_and_types() {
        let d = TomlDoc::parse(SAMPLE).unwrap();
        assert_eq!(d.str_or("name", ""), "fig2");
        assert_eq!(d.f64_or("train.lr", 0.0), 3e-4);
        assert_eq!(d.i64_or("train.steps", 0), 400);
        assert_eq!(d.bool_or("train.resume", true), false);
        assert_eq!(d.f64_list("train.lrs").unwrap(), vec![0.1, 0.3, 1.0]);
        assert_eq!(d.str_or("quant.format", ""), "int4");
    }

    #[test]
    fn overrides() {
        let mut d = TomlDoc::parse(SAMPLE).unwrap();
        d.set_override("train.lr=0.5").unwrap();
        d.set_override("quant.format=fp4").unwrap();
        assert_eq!(d.f64_or("train.lr", 0.0), 0.5);
        assert_eq!(d.str_or("quant.format", ""), "fp4");
        assert!(d.set_override("no-equals").is_err());
    }

    /// The estimator-schedule block rides the same grammar: `[est]`
    /// keys land under `est.*` and `--set est.k=v` overrides them, so
    /// `--method anneal --est-sigma0 0.5` round-trips through the doc.
    #[test]
    fn est_section_and_overrides() {
        let mut d =
            TomlDoc::parse("method = \"anneal\"\n[est]\nschedule = \"cosine\"\nsigma0 = 0.5")
                .unwrap();
        assert_eq!(d.str_or("est.schedule", "constant"), "cosine");
        assert_eq!(d.f64_or("est.sigma0", 1.0), 0.5);
        d.set_override("est.schedule=linear").unwrap();
        d.set_override("est.grad_scale=2.0").unwrap();
        assert_eq!(d.str_or("est.schedule", "constant"), "linear");
        assert_eq!(d.f64_or("est.grad_scale", 1.0), 2.0);
    }

    #[test]
    fn hash_inside_string() {
        let d = TomlDoc::parse(r##"k = "a#b" # real comment"##).unwrap();
        assert_eq!(d.str_or("k", ""), "a#b");
    }

    #[test]
    fn errors_are_line_numbered() {
        let err = TomlDoc::parse("x = 1\ny 2").unwrap_err().to_string();
        assert!(err.contains("line 2"), "{err}");
    }

    #[test]
    fn nested_arrays() {
        let d = TomlDoc::parse("a = [[1, 2], [3]]").unwrap();
        let a = d.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 2);
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
    }

    #[test]
    fn defaults_apply() {
        let d = TomlDoc::parse("").unwrap();
        assert_eq!(d.usize_or("missing", 7), 7);
    }
}
