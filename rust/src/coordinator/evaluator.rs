//! Quantized evaluation — the paper's measurement protocol (§4):
//! snapshot the FP32 weights, cast the quantized subset with RTN or
//! randomized rounding *in rust* (the `quant` substrate), and run the
//! FP32 eval program on the cast weights. Backend-agnostic: the cast
//! happens on host tensors before they enter `Executor::call`.

use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::executor::{value, Executor, Value};
use crate::runtime::manifest::{ArtifactEntry, Role};
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

use super::metrics::MetricsLogger;
use super::trainer::{DataSource, Trainer};

pub struct Evaluator {
    pub entry: ArtifactEntry,
    /// eval RNG for RR casts and val batches — independent of training
    pub rng: Rng,
    /// fixed val chunk per evaluator (same data at every eval point, so
    /// curves are comparable across steps and methods)
    val_tokens: Option<Value>,
}

impl Evaluator {
    pub fn new(engine: &dyn Executor, model: &str, seed: u64) -> Result<Evaluator> {
        let entry = engine.manifest().find_eval(model)?.clone();
        Ok(Evaluator { entry, rng: Rng::new(seed ^ 0xE7A1_5EED), val_tokens: None })
    }

    /// Evaluate the current weights with a given cast. `format == None`
    /// means FP32 (no cast).
    pub fn eval_cast(
        &mut self,
        trainer: &Trainer,
        format: Option<&QuantFormat>,
        rounding: Rounding,
    ) -> Result<f64> {
        let engine = trainer.engine;
        let specs = self.entry.inputs.clone();
        // snapshot params (values are Rc-shared host buffers)
        let mut args: Vec<Value> = Vec::with_capacity(specs.len());
        for spec in &specs {
            let arg = match spec.role {
                Role::Param => {
                    let v = trainer.state.value(&spec.name)?;
                    if let Some(fmt) = format {
                        if trainer.quantized_keys().iter().any(|k| k == &spec.name) {
                            let mut host = v.as_ref().clone();
                            let mut rng = self.rng.fork(1);
                            host.map_f32_inplace(|w| cast(w, fmt, rounding, &mut rng));
                            value(host)
                        } else {
                            v.clone()
                        }
                    } else {
                        v.clone()
                    }
                }
                Role::Static => trainer
                    .statics
                    .iter()
                    .find(|(n, _)| n == &spec.name)
                    .map(|(_, v)| v.clone())
                    .ok_or_else(|| anyhow!("missing static {:?}", spec.name))?,
                Role::Data => self.val_chunk(trainer)?,
                other => return Err(anyhow!("unexpected eval input role {other:?}")),
            };
            args.push(arg);
        }
        let out = engine.call_to_host(&self.entry, &args, &["val_loss"])?;
        Ok(out[0].scalar_to_f32() as f64)
    }

    fn val_chunk(&mut self, trainer: &Trainer) -> Result<Value> {
        if let Some(v) = &self.val_tokens {
            return Ok(v.clone());
        }
        let ke = self.entry.eval_batches.max(1);
        let v = match &trainer.data {
            DataSource::Tokens(b) => value(b.val_chunk(ke, &mut self.rng)),
            DataSource::InGraph => return Err(anyhow!("eval program wants data for a synthetic task")),
        };
        self.val_tokens = Some(v.clone());
        Ok(v)
    }

    /// The paper's standard eval battery at the current step: FP32 loss
    /// plus quantized loss per (format × rounding) in the run config.
    pub fn eval_all(&mut self, trainer: &Trainer, metrics: &mut MetricsLogger) -> Result<()> {
        let fp32 = self.eval_cast(trainer, None, Rounding::Rtn)?;
        metrics.log_eval(trainer.step, "fp32", "none", fp32);
        let formats: Vec<String> = if trainer.cfg.eval_formats.is_empty() {
            if trainer.cfg.format == "none" {
                vec!["int4".into(), "int8".into()]
            } else {
                vec![trainer.cfg.format.clone()]
            }
        } else {
            trainer.cfg.eval_formats.clone()
        };
        for fname in &formats {
            let fmt = QuantFormat::parse(fname, 0)?;
            for &r in &trainer.cfg.eval_roundings {
                let loss = self.eval_cast(trainer, Some(&fmt), r)?;
                metrics.log_eval(trainer.step, fname, r.name(), loss);
            }
        }
        Ok(())
    }
}
