//! Quantized evaluation — the paper's measurement protocol (§4):
//! snapshot the FP32 weights, cast the quantized subset with RTN or
//! randomized rounding *in rust* (the `quant` substrate), and run the
//! FP32 eval program on the cast weights. Backend-agnostic: the cast
//! is a parameter map handed to
//! [`Session::eval_loss`](crate::runtime::Session::eval_loss), applied
//! on host tensors before they enter `Executor::call`.
//!
//! Per-tensor RTN casts take a faster, bit-identical route when the
//! backend registers a fused `eval_q` entry (the native engine does):
//! [`Session::eval_loss_quantized`](crate::runtime::Session::eval_loss_quantized)
//! hands the *master* weights to the engine, which packs the quantized
//! subset into block codes and dequantizes inside its matmul tiles —
//! no full-f32 cast copy. DESIGN.md §3 "Packed quantized eval".

use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::executor::{value, Value};
use crate::runtime::Role;
use crate::util::rng::Rng;
use anyhow::{anyhow, Result};

use super::metrics::MetricsLogger;
use super::trainer::{DataSource, Trainer};

pub struct Evaluator {
    /// eval RNG for RR casts and val batches — independent of training
    pub rng: Rng,
    /// fixed val chunk per evaluator (same data at every eval point, so
    /// curves are comparable across steps and methods)
    val_tokens: Option<Value>,
}

impl Evaluator {
    /// An evaluator for one run. The eval program itself lives in the
    /// run's [`Session`](crate::runtime::Session); the evaluator owns
    /// only what is measurement-shaped: the eval RNG and the pinned
    /// validation chunk.
    pub fn new(seed: u64) -> Evaluator {
        Evaluator { rng: Rng::new(seed ^ 0xE7A1_5EED), val_tokens: None }
    }

    /// The pinned validation chunk, if one has been drawn — checkpointed
    /// so a resumed run evaluates on the *same* data as the original
    /// (the chunk is drawn lazily from the eval RNG at the first eval).
    pub fn val_tokens(&self) -> Option<crate::tensor::HostTensor> {
        self.val_tokens.as_ref().map(|v| v.as_ref().clone())
    }

    /// Restore a checkpointed validation chunk (resume path).
    pub fn set_val_tokens(&mut self, t: crate::tensor::HostTensor) {
        self.val_tokens = Some(value(t));
    }

    /// Evaluate the current weights with a given cast. `format == None`
    /// means FP32 (no cast).
    pub fn eval_cast(
        &mut self,
        trainer: &Trainer,
        format: Option<&QuantFormat>,
        rounding: Rounding,
    ) -> Result<f64> {
        let data = if trainer.session.eval_wants_data() {
            Some(self.val_chunk(trainer)?)
        } else {
            None
        };
        let quantized = trainer.quantized_keys();
        // RTN casts of any backend-registered format (per-tensor or
        // per-block, e.g. "int4@64") route through the fused `eval_q`
        // entry: the engine packs the quantized subset into block codes
        // and never materializes a full-f32 copy. The fork burn keeps
        // `self.rng` bit-aligned with the host-cast path below, which
        // forks once per quantized param in eval-entry order — later RR
        // evals must see the same stream either way.
        if rounding == Rounding::Rtn {
            if let Some(fmt) = format {
                if let Some(loss) =
                    trainer.session.eval_loss_quantized(&fmt.name, data.clone())?
                {
                    for spec in trainer.session.eval_entry().input_specs(Role::Param) {
                        if quantized.iter().any(|k| k == &spec.name) {
                            let _ = self.rng.fork(1);
                        }
                    }
                    return Ok(loss);
                }
            }
        }
        let rng = &mut self.rng;
        trainer.session.eval_loss(data, &mut |spec, v| {
            let fmt = match format {
                Some(f) if quantized.iter().any(|k| k == &spec.name) => f,
                _ => return Ok(v.clone()),
            };
            let mut host = v.as_ref().clone();
            let mut rng = rng.fork(1);
            host.map_f32_inplace(|w| cast(w, fmt, rounding, &mut rng));
            Ok(value(host))
        })
    }

    fn val_chunk(&mut self, trainer: &Trainer) -> Result<Value> {
        if let Some(v) = &self.val_tokens {
            return Ok(v.clone());
        }
        let ke = trainer.session.eval_entry().eval_batches.max(1);
        let v = match &trainer.data {
            DataSource::Tokens(b) => value(b.val_chunk(ke, &mut self.rng)),
            DataSource::InGraph => {
                return Err(anyhow!("eval program wants data for a synthetic task"))
            }
        };
        self.val_tokens = Some(v.clone());
        Ok(v)
    }

    /// The paper's standard eval battery at the current step: FP32 loss
    /// plus quantized loss per (format × rounding) in the run config.
    pub fn eval_all(&mut self, trainer: &Trainer, metrics: &mut MetricsLogger) -> Result<()> {
        let fp32 = self.eval_cast(trainer, None, Rounding::Rtn)?;
        metrics.log_eval(trainer.step, "fp32", "none", fp32);
        let formats: Vec<String> = if trainer.cfg.eval_formats.is_empty() {
            if trainer.cfg.format == "none" {
                vec!["int4".into(), "int8".into()]
            } else {
                vec![trainer.cfg.format.clone()]
            }
        } else {
            trainer.cfg.eval_formats.clone()
        };
        for fname in &formats {
            let fmt = QuantFormat::parse(fname, 0)?;
            for &r in &trainer.cfg.eval_roundings {
                let loss = self.eval_cast(trainer, Some(&fmt), r)?;
                metrics.log_eval(trainer.step, fname, r.name(), loss);
            }
        }
        Ok(())
    }
}
