//! Run metrics: append-only JSONL (one object per event) + in-memory
//! rows for end-of-run summaries.

use crate::formats::json::Json;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub format: String,
    pub rounding: String,
    pub val_loss: f64,
}

/// Why a run diverged — captured so sweep journals and JSONL sinks
/// record *why* a point scored `+inf`, not just that it did.
#[derive(Clone, Debug)]
pub struct DivergedRecord {
    pub step: usize,
    pub loss: f64,
    pub method: String,
    pub lr: f64,
}

pub struct MetricsLogger {
    file: Option<std::fs::File>,
    pub train_losses: Vec<(usize, f64)>,
    pub eval_points: Vec<EvalPoint>,
    /// set once if the run diverged (non-finite base loss)
    pub diverged: Option<DivergedRecord>,
}

impl MetricsLogger {
    pub fn to_file(path: &Path) -> Result<MetricsLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsLogger {
            file: Some(std::fs::File::create(path)?),
            train_losses: Vec::new(),
            eval_points: Vec::new(),
            diverged: None,
        })
    }

    /// Append to an existing JSONL sink (resume path): earlier events
    /// from the interrupted run stay in place, new events follow.
    pub fn append_to_file(path: &Path) -> Result<MetricsLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsLogger {
            file: Some(std::fs::OpenOptions::new().create(true).append(true).open(path)?),
            train_losses: Vec::new(),
            eval_points: Vec::new(),
            diverged: None,
        })
    }

    pub fn in_memory() -> MetricsLogger {
        MetricsLogger {
            file: None,
            train_losses: Vec::new(),
            eval_points: Vec::new(),
            diverged: None,
        }
    }

    fn emit(&mut self, j: Json) {
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{}", j.to_string());
        }
    }

    pub fn log_train(&mut self, step: usize, base_loss: f64, total_loss: f64, lr: f64, wall_s: f64) {
        self.train_losses.push((step, base_loss));
        self.emit(Json::obj(vec![
            ("kind", Json::str("train")),
            ("step", Json::num(step as f64)),
            ("loss", Json::num(base_loss)),
            ("total_loss", Json::num(total_loss)),
            ("lr", Json::num(lr)),
            ("wall_s", Json::num(wall_s)),
        ]));
    }

    pub fn log_eval(&mut self, step: usize, format: &str, rounding: &str, val_loss: f64) {
        self.eval_points.push(EvalPoint {
            step,
            format: format.into(),
            rounding: rounding.into(),
            val_loss,
        });
        self.emit(Json::obj(vec![
            ("kind", Json::str("eval")),
            ("step", Json::num(step as f64)),
            ("format", Json::str(format)),
            ("rounding", Json::str(rounding)),
            ("val_loss", Json::num(val_loss)),
        ]));
    }

    /// Record a divergence (non-finite base loss) as a structured
    /// event. The loss goes out as a JSON *string*: NaN/inf are not
    /// valid JSON numbers and would corrupt the JSONL stream.
    pub fn log_diverged(&mut self, step: usize, loss: f64, method: &str, lr: f64) {
        self.diverged = Some(DivergedRecord { step, loss, method: method.into(), lr });
        self.emit(Json::obj(vec![
            ("kind", Json::str("diverged")),
            ("step", Json::num(step as f64)),
            ("loss", Json::str(&format!("{loss}"))),
            ("method", Json::str(method)),
            ("lr", Json::num(lr)),
        ]));
    }

    /// Best (minimum) quantized val loss for a (format, rounding) pair.
    pub fn best_eval(&self, format: &str, rounding: &str) -> Option<f64> {
        self.eval_points
            .iter()
            .filter(|p| p.format == format && p.rounding == rounding)
            .map(|p| p.val_loss)
            .fold(None, |m, v| Some(m.map_or(v, |m: f64| m.min(v))))
    }

    /// Final (last-step) quantized val loss for a (format, rounding) pair.
    pub fn final_eval(&self, format: &str, rounding: &str) -> Option<f64> {
        self.eval_points
            .iter()
            .filter(|p| p.format == format && p.rounding == rounding)
            .last()
            .map(|p| p.val_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn logs_jsonl_and_tracks_best() {
        let dir = TempDir::new();
        let path = dir.path().join("run.jsonl");
        let mut m = MetricsLogger::to_file(&path).unwrap();
        m.log_train(1, 2.0, 2.5, 0.1, 0.01);
        m.log_eval(1, "int4", "rtn", 3.0);
        m.log_eval(2, "int4", "rtn", 2.5);
        m.log_eval(2, "int4", "rr", 2.7);
        drop(m.file.take());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("kind").unwrap().as_str(), Some("train"));
        assert_eq!(m.best_eval("int4", "rtn"), Some(2.5));
        assert_eq!(m.final_eval("int4", "rr"), Some(2.7));
        assert_eq!(m.best_eval("int8", "rtn"), None);
    }

    #[test]
    fn diverged_record_is_structured_and_valid_json() {
        let dir = TempDir::new();
        let path = dir.path().join("run.jsonl");
        let mut m = MetricsLogger::to_file(&path).unwrap();
        m.log_diverged(17, f64::NAN, "lotion", 0.5);
        let rec = m.diverged.as_ref().expect("diverged set");
        assert_eq!(rec.step, 17);
        assert!(rec.loss.is_nan());
        assert_eq!(rec.method, "lotion");
        drop(m.file.take());
        let text = std::fs::read_to_string(&path).unwrap();
        // the NaN loss must not break JSON parsing of the line
        let j = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(j.get("kind").unwrap().as_str(), Some("diverged"));
        assert_eq!(j.get("loss").unwrap().as_str(), Some("NaN"));
    }

    #[test]
    fn append_to_file_preserves_existing_lines() {
        let dir = TempDir::new();
        let path = dir.path().join("run.jsonl");
        let mut m = MetricsLogger::to_file(&path).unwrap();
        m.log_train(1, 2.0, 2.5, 0.1, 0.01);
        drop(m.file.take());
        let mut m2 = MetricsLogger::append_to_file(&path).unwrap();
        m2.log_train(2, 1.9, 2.4, 0.1, 0.01);
        drop(m2.file.take());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
    }
}
