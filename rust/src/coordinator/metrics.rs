//! Run metrics: append-only JSONL (one object per event) + in-memory
//! rows for end-of-run summaries.

use crate::formats::json::Json;
use anyhow::Result;
use std::io::Write;
use std::path::Path;

#[derive(Clone, Debug)]
pub struct EvalPoint {
    pub step: usize,
    pub format: String,
    pub rounding: String,
    pub val_loss: f64,
}

pub struct MetricsLogger {
    file: Option<std::fs::File>,
    pub train_losses: Vec<(usize, f64)>,
    pub eval_points: Vec<EvalPoint>,
}

impl MetricsLogger {
    pub fn to_file(path: &Path) -> Result<MetricsLogger> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsLogger {
            file: Some(std::fs::File::create(path)?),
            train_losses: Vec::new(),
            eval_points: Vec::new(),
        })
    }

    pub fn in_memory() -> MetricsLogger {
        MetricsLogger { file: None, train_losses: Vec::new(), eval_points: Vec::new() }
    }

    fn emit(&mut self, j: Json) {
        if let Some(f) = &mut self.file {
            let _ = writeln!(f, "{}", j.to_string());
        }
    }

    pub fn log_train(&mut self, step: usize, base_loss: f64, total_loss: f64, lr: f64, wall_s: f64) {
        self.train_losses.push((step, base_loss));
        self.emit(Json::obj(vec![
            ("kind", Json::str("train")),
            ("step", Json::num(step as f64)),
            ("loss", Json::num(base_loss)),
            ("total_loss", Json::num(total_loss)),
            ("lr", Json::num(lr)),
            ("wall_s", Json::num(wall_s)),
        ]));
    }

    pub fn log_eval(&mut self, step: usize, format: &str, rounding: &str, val_loss: f64) {
        self.eval_points.push(EvalPoint {
            step,
            format: format.into(),
            rounding: rounding.into(),
            val_loss,
        });
        self.emit(Json::obj(vec![
            ("kind", Json::str("eval")),
            ("step", Json::num(step as f64)),
            ("format", Json::str(format)),
            ("rounding", Json::str(rounding)),
            ("val_loss", Json::num(val_loss)),
        ]));
    }

    /// Best (minimum) quantized val loss for a (format, rounding) pair.
    pub fn best_eval(&self, format: &str, rounding: &str) -> Option<f64> {
        self.eval_points
            .iter()
            .filter(|p| p.format == format && p.rounding == rounding)
            .map(|p| p.val_loss)
            .fold(None, |m, v| Some(m.map_or(v, |m: f64| m.min(v))))
    }

    /// Final (last-step) quantized val loss for a (format, rounding) pair.
    pub fn final_eval(&self, format: &str, rounding: &str) -> Option<f64> {
        self.eval_points
            .iter()
            .filter(|p| p.format == format && p.rounding == rounding)
            .last()
            .map(|p| p.val_loss)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::tempdir::TempDir;

    #[test]
    fn logs_jsonl_and_tracks_best() {
        let dir = TempDir::new();
        let path = dir.path().join("run.jsonl");
        let mut m = MetricsLogger::to_file(&path).unwrap();
        m.log_train(1, 2.0, 2.5, 0.1, 0.01);
        m.log_eval(1, "int4", "rtn", 3.0);
        m.log_eval(2, "int4", "rtn", 2.5);
        m.log_eval(2, "int4", "rr", 2.7);
        drop(m.file.take());
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 4);
        let first = Json::parse(text.lines().next().unwrap()).unwrap();
        assert_eq!(first.get("kind").unwrap().as_str(), Some("train"));
        assert_eq!(m.best_eval("int4", "rtn"), Some(2.5));
        assert_eq!(m.final_eval("int4", "rr"), Some(2.7));
        assert_eq!(m.best_eval("int8", "rtn"), None);
    }
}
