//! Training coordination: the L3 control plane.
//!
//! * [`trainer`] — chunked train loop over a scanned artifact.
//! * [`evaluator`] — quantized evaluation (RTN/RR casts in rust,
//!   FP32 eval executable).
//! * [`metrics`] — JSONL/CSV run logs.
//! * [`sweep`] — sharded grid sweeps over factory-spawned engines
//!   (best-per-method over the App. A.5 LR grids, as the paper
//!   reports).
//! * [`serve`] — continuous-batched token generation over an engine
//!   pool (the `lotion serve` / `bench-serve` harness, DESIGN.md §8).

pub mod evaluator;
pub mod metrics;
pub mod serve;
pub mod sweep;
pub mod trainer;

pub use evaluator::Evaluator;
pub use metrics::MetricsLogger;
pub use serve::{ServeConfig, ServeReport};
pub use sweep::{JournalEntry, SweepJournal, SweepPoint, SweepResult, SweepRunner};
pub use trainer::{CkptPolicy, DataSource, Trainer};
