//! Training coordination: the L3 control plane.
//!
//! * [`trainer`] — chunked train loop over a scanned artifact.
//! * [`evaluator`] — quantized evaluation (RTN/RR casts in rust,
//!   FP32 eval executable).
//! * [`metrics`] — JSONL/CSV run logs.
//! * [`sweep`] — sharded grid sweeps over factory-spawned engines
//!   (best-per-method over the App. A.5 LR grids, as the paper
//!   reports).

pub mod evaluator;
pub mod metrics;
pub mod sweep;
pub mod trainer;

pub use evaluator::Evaluator;
pub use metrics::MetricsLogger;
pub use sweep::{JournalEntry, SweepJournal, SweepPoint, SweepResult, SweepRunner};
pub use trainer::{CkptPolicy, DataSource, Trainer};
