//! Serving harness: continuous-batched token generation over an
//! [`ExecutorFactory`]-spawned engine pool (DESIGN.md §8).
//!
//! Shape of the workload: a shared FIFO of [`Request`]s feeds N worker
//! threads; each worker owns one engine (spawned from the factory, the
//! sweep-runner idiom) and a [`Decoder`] over it, and runs a
//! continuous-batching loop — admit requests from the queue whenever a
//! sequence slot is free, advance every live sequence by one decode
//! step per round, retire sequences the moment they finish. Slots are
//! recycled, so engine-side KV memory is bounded by `max_batch`
//! regardless of how many requests drain through a worker.
//!
//! Determinism contract: the *text* is scheduling-independent. A
//! request's token sequence is `sample_token(logits, temperature,
//! sample_seed, request_id, position)` over logits that depend only on
//! (weights, prompt, generated prefix) — and the decode kernels are
//! bit-identical at every `--threads` width — so completions are
//! bitwise-identical across any engine count, batch width, or
//! admission order. Only the *timing* (TTFT, per-token latency,
//! tokens/s) reflects the schedule, which is exactly what the serve
//! bench measures.

use crate::formats::json::Json;
use crate::runtime::executor::value;
use crate::runtime::{sample_token, Decoder, ExecutorFactory, Value};
use crate::tensor::HostTensor;
use crate::util::{pool::Pool, rng::Rng, stats::Summary};
use anyhow::{anyhow, bail, Result};
use std::collections::VecDeque;
use std::sync::Mutex;
use std::time::Instant;

/// Disjoint sub-seed domains under one serve seed: synthetic prompts
/// and sampling draws must never share a counter stream.
const STREAM_PROMPT: u64 = 1;
const STREAM_SAMPLE: u64 = 2;

/// One serving workload description (the `lotion serve` /
/// `lotion bench-serve` knobs).
#[derive(Clone, Debug)]
pub struct ServeConfig {
    pub model: String,
    /// decode-entry format: `"none"` (dense) or a quantized format name
    pub format: String,
    /// worker threads, one factory-spawned engine each
    pub engines: usize,
    /// concurrent sequence slots per engine
    pub max_batch: usize,
    /// synthetic-load request count
    pub requests: usize,
    pub prompt_len: usize,
    /// tokens generated per request (>= 1; the first comes from the
    /// prefill logits)
    pub gen_len: usize,
    /// `<= 0` is greedy
    pub temperature: f32,
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "lm-tiny".to_string(),
            format: "int4".to_string(),
            engines: 1,
            max_batch: 4,
            requests: 16,
            prompt_len: 8,
            gen_len: 16,
            temperature: 0.8,
            seed: 42,
        }
    }
}

/// One generation request.
pub struct Request {
    pub id: u64,
    pub prompt: Vec<i32>,
    pub gen_len: usize,
}

/// One finished request: its tokens plus the timing the scheduler gave
/// it. Tokens are schedule-independent; the timing fields are not.
pub struct Completion {
    pub id: u64,
    pub tokens: Vec<i32>,
    /// request arrival (= serve start for synthetic load) to first token
    pub ttft_s: f64,
    /// per-token intervals, `[0]` being the prefill-to-first-token time
    pub token_lat_s: Vec<f64>,
}

/// The drained workload: completions (sorted by request id) + wall
/// clock + the config that produced them.
pub struct ServeReport {
    pub cfg: ServeConfig,
    pub completions: Vec<Completion>,
    pub wall_s: f64,
}

impl ServeReport {
    pub fn generated_tokens(&self) -> usize {
        self.completions.iter().map(|c| c.tokens.len()).sum()
    }

    pub fn tokens_per_sec(&self) -> f64 {
        self.generated_tokens() as f64 / self.wall_s
    }

    /// Per-token latency distribution across all completions.
    pub fn token_latency(&self) -> Summary {
        let mut s = Summary::new();
        for c in &self.completions {
            for &v in &c.token_lat_s {
                s.add(v);
            }
        }
        s
    }

    /// Time-to-first-token distribution across requests.
    pub fn ttft(&self) -> Summary {
        let mut s = Summary::new();
        for c in &self.completions {
            s.add(c.ttft_s);
        }
        s
    }

    /// One `BENCH_serve.json` result row.
    pub fn to_json(&self) -> Json {
        let lat = self.token_latency();
        let ttft = self.ttft();
        Json::obj(vec![
            (
                "name",
                Json::str(format!(
                    "serve_{}_{}_e{}_b{}",
                    self.cfg.model, self.cfg.format, self.cfg.engines, self.cfg.max_batch
                )),
            ),
            ("model", Json::str(&self.cfg.model)),
            ("format", Json::str(&self.cfg.format)),
            ("engines", Json::num(self.cfg.engines as f64)),
            ("max_batch", Json::num(self.cfg.max_batch as f64)),
            ("requests", Json::num(self.completions.len() as f64)),
            ("prompt_len", Json::num(self.cfg.prompt_len as f64)),
            ("gen_len", Json::num(self.cfg.gen_len as f64)),
            ("generated_tokens", Json::num(self.generated_tokens() as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("tokens_per_sec", Json::num(self.tokens_per_sec())),
            ("tok_lat_p50_s", Json::num(lat.percentile(50.0))),
            ("tok_lat_p99_s", Json::num(lat.percentile(99.0))),
            ("tok_lat_mean_s", Json::num(lat.mean())),
            ("ttft_p50_s", Json::num(ttft.percentile(50.0))),
            ("ttft_p99_s", Json::num(ttft.percentile(99.0))),
        ])
    }

    /// Human-readable one-config summary.
    pub fn table(&self) -> String {
        let lat = self.token_latency();
        let ttft = self.ttft();
        format!(
            "{} fmt={} engines={} batch={}: {} req, {} tok in {:.3}s  \
             -> {:.1} tok/s | tok p50 {:.3}ms p99 {:.3}ms | ttft p50 {:.3}ms p99 {:.3}ms",
            self.cfg.model,
            self.cfg.format,
            self.cfg.engines,
            self.cfg.max_batch,
            self.completions.len(),
            self.generated_tokens(),
            self.wall_s,
            self.tokens_per_sec(),
            lat.percentile(50.0) * 1e3,
            lat.percentile(99.0) * 1e3,
            ttft.percentile(50.0) * 1e3,
            ttft.percentile(99.0) * 1e3,
        )
    }
}

/// Deterministic synthetic load: request `i` draws `prompt_len` tokens
/// from the counter stream `(seed, [STREAM_PROMPT, i])` — independent
/// of every other request and of the sampling streams.
pub fn synthetic_requests(cfg: &ServeConfig, vocab: usize) -> Vec<Request> {
    let prompt_seed = Rng::stream_seed(cfg.seed, &[STREAM_PROMPT]);
    (0..cfg.requests as u64)
        .map(|id| {
            let mut rng = Rng::stream(prompt_seed, &[id]);
            Request {
                id,
                prompt: (0..cfg.prompt_len).map(|_| rng.below(vocab as u64) as i32).collect(),
                gen_len: cfg.gen_len,
            }
        })
        .collect()
}

/// Drive the synthetic workload end to end: spawn a probe engine to
/// resolve the decode geometry, build the requests, then drain them
/// through [`run_serve`].
pub fn serve_synthetic(
    factory: &dyn ExecutorFactory,
    weights: &[(String, HostTensor)],
    cfg: &ServeConfig,
) -> Result<ServeReport> {
    let probe = factory.spawn()?;
    let entry = probe
        .manifest()
        .find_decode(&cfg.model, &cfg.format)
        .ok_or_else(|| {
            anyhow!("no decode entry for model {:?} format {:?}", cfg.model, cfg.format)
        })?;
    let vocab = entry.outputs[0].shape[0];
    let max_seq = entry
        .input_index("tokens")
        .map(|i| entry.inputs[i].shape[0])
        .unwrap_or(0);
    if cfg.prompt_len == 0 || cfg.gen_len == 0 {
        bail!("serve wants prompt_len >= 1 and gen_len >= 1");
    }
    // token i of the generation sits at position prompt_len + i
    if cfg.prompt_len + cfg.gen_len > max_seq {
        bail!(
            "prompt_len {} + gen_len {} exceeds {}'s context of {max_seq}",
            cfg.prompt_len,
            cfg.gen_len,
            cfg.model
        );
    }
    drop(probe);
    run_serve(factory, weights, cfg, synthetic_requests(cfg, vocab))
}

/// Drain `requests` through an engine pool (module docs). Weights are
/// FP32 masters shared read-only across workers; each engine casts and
/// packs its own copy once, on first call.
pub fn run_serve(
    factory: &dyn ExecutorFactory,
    weights: &[(String, HostTensor)],
    cfg: &ServeConfig,
    requests: Vec<Request>,
) -> Result<ServeReport> {
    for r in &requests {
        if r.prompt.is_empty() || r.gen_len == 0 {
            bail!("request {}: empty prompt or zero gen_len", r.id);
        }
    }
    let sample_seed = Rng::stream_seed(cfg.seed, &[STREAM_SAMPLE]);
    let n_req = requests.len();
    let queue = Mutex::new(VecDeque::from(requests));
    let workers = cfg.engines.max(1).min(n_req.max(1));
    let start = Instant::now();
    let outs: Vec<Result<Vec<Completion>>> =
        Pool::new(workers).run((0..workers).collect(), |_, _wid| {
            let engine = factory.spawn()?;
            let named: Vec<(String, Value)> =
                weights.iter().map(|(n, t)| (n.clone(), value(t.clone()))).collect();
            let dec = Decoder::open(&*engine, &cfg.model, &cfg.format, &named)?;
            drain(&dec, &queue, cfg, sample_seed, start)
        });
    let wall_s = start.elapsed().as_secs_f64();
    let mut completions = Vec::with_capacity(n_req);
    for out in outs {
        completions.extend(out?);
    }
    completions.sort_by_key(|c| c.id);
    Ok(ServeReport { cfg: cfg.clone(), completions, wall_s })
}

/// One live sequence on a worker's decoder.
struct Active {
    req: Request,
    slot: i32,
    tokens: Vec<i32>,
    ttft_s: f64,
    lat: Vec<f64>,
    last: Instant,
}

/// Retire a finished sequence: recycle its slot, emit its completion.
fn retire(a: Active, free: &mut Vec<i32>, done: &mut Vec<Completion>) {
    free.push(a.slot);
    done.push(Completion {
        id: a.req.id,
        tokens: a.tokens,
        ttft_s: a.ttft_s,
        token_lat_s: a.lat,
    });
}

/// One worker's continuous-batching loop: admit at step boundaries
/// while slots are free, advance every live sequence one step per
/// round, retire finished sequences (recycling their slot).
fn drain(
    dec: &Decoder<'_>,
    queue: &Mutex<VecDeque<Request>>,
    cfg: &ServeConfig,
    sample_seed: u64,
    start: Instant,
) -> Result<Vec<Completion>> {
    let mut free: Vec<i32> = (0..cfg.max_batch.max(1) as i32).rev().collect();
    let mut active: Vec<Active> = Vec::new();
    let mut done: Vec<Completion> = Vec::new();
    loop {
        // admission boundary: top up the batch from the shared queue
        while !free.is_empty() {
            let req = match queue.lock().unwrap().pop_front() {
                Some(r) => r,
                None => break,
            };
            let slot = free.pop().expect("slot just checked");
            let t0 = Instant::now();
            let logits = dec.prefill(slot, &req.prompt)?;
            let tok = sample_token(&logits, cfg.temperature, sample_seed, req.id, 0) as i32;
            let now = Instant::now();
            let a = Active {
                slot,
                tokens: vec![tok],
                ttft_s: now.duration_since(start).as_secs_f64(),
                lat: vec![now.duration_since(t0).as_secs_f64()],
                last: now,
                req,
            };
            if a.tokens.len() >= a.req.gen_len {
                retire(a, &mut free, &mut done);
            } else {
                active.push(a);
            }
        }
        if active.is_empty() {
            return Ok(done);
        }
        // one decode step per live sequence, then re-check admission
        let mut still = Vec::with_capacity(active.len());
        for mut a in active {
            let pos = a.req.prompt.len() + a.tokens.len() - 1;
            let logits = dec.step(a.slot, pos, *a.tokens.last().expect("nonempty"))?;
            let tok = sample_token(
                &logits,
                cfg.temperature,
                sample_seed,
                a.req.id,
                a.tokens.len() as u64,
            ) as i32;
            a.tokens.push(tok);
            let now = Instant::now();
            a.lat.push(now.duration_since(a.last).as_secs_f64());
            a.last = now;
            if a.tokens.len() >= a.req.gen_len {
                retire(a, &mut free, &mut done);
            } else {
                still.push(a);
            }
        }
        active = still;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeFactory;
    use crate::runtime::Executor;

    fn lm_tiny_weights(factory: &dyn ExecutorFactory) -> Vec<(String, HostTensor)> {
        let e = factory.spawn().unwrap();
        let init = e.manifest().find_init("lm-tiny").unwrap().clone();
        let key = value(HostTensor::from_u32(&[2], vec![3, 5]));
        let out = e.call(&init, &[key]).unwrap();
        init.outputs
            .iter()
            .zip(out)
            .map(|(s, v)| (s.name.clone(), v.as_ref().clone()))
            .collect()
    }

    fn tiny_cfg() -> ServeConfig {
        ServeConfig {
            requests: 6,
            prompt_len: 4,
            gen_len: 5,
            temperature: 0.7,
            ..ServeConfig::default()
        }
    }

    #[test]
    fn synthetic_requests_are_deterministic_and_in_vocab() {
        let cfg = tiny_cfg();
        let a = synthetic_requests(&cfg, 256);
        let b = synthetic_requests(&cfg, 256);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert!(x.prompt.iter().all(|&t| (0..256).contains(&t)));
        }
        // distinct requests draw distinct prompts
        assert_ne!(a[0].prompt, a[1].prompt);
    }

    /// The serving determinism contract: completions are bitwise
    /// independent of engine count, batch width, and hence admission
    /// order (a 1-engine/1-slot pool is strictly serial FIFO; a
    /// 2-engine/3-slot pool interleaves).
    #[test]
    fn completions_are_schedule_independent() {
        let factory = NativeFactory::with_default_models(1);
        let weights = lm_tiny_weights(&factory);
        let serial =
            serve_synthetic(&factory, &weights, &ServeConfig { engines: 1, max_batch: 1, ..tiny_cfg() })
                .unwrap();
        let pooled =
            serve_synthetic(&factory, &weights, &ServeConfig { engines: 2, max_batch: 3, ..tiny_cfg() })
                .unwrap();
        assert_eq!(serial.completions.len(), 6);
        assert_eq!(pooled.completions.len(), 6);
        assert_eq!(serial.generated_tokens(), 6 * 5);
        for (a, b) in serial.completions.iter().zip(&pooled.completions) {
            assert_eq!(a.id, b.id);
            assert_eq!(a.tokens, b.tokens, "request {} diverged across schedules", a.id);
            assert!(a.tokens.iter().all(|&t| (0..256).contains(&t)));
            assert_eq!(a.token_lat_s.len(), a.tokens.len());
            assert!(a.ttft_s >= 0.0);
        }
    }

    #[test]
    fn report_row_carries_throughput_and_percentiles() {
        let factory = NativeFactory::with_default_models(1);
        let weights = lm_tiny_weights(&factory);
        let cfg = ServeConfig { engines: 1, max_batch: 2, ..tiny_cfg() };
        let r = serve_synthetic(&factory, &weights, &cfg).unwrap();
        assert!(r.tokens_per_sec() > 0.0);
        let row = r.to_json();
        assert_eq!(row.get("name").unwrap().as_str(), Some("serve_lm-tiny_int4_e1_b2"));
        assert_eq!(row.get("generated_tokens").unwrap().as_usize(), Some(30));
        for k in ["tokens_per_sec", "tok_lat_p50_s", "tok_lat_p99_s", "ttft_p50_s", "ttft_p99_s"] {
            let v = row.get(k).unwrap().as_f64().unwrap();
            assert!(v.is_finite() && v >= 0.0, "{k} = {v}");
        }
        assert!(r.table().contains("tok/s"));
    }

    #[test]
    fn serve_rejects_bad_geometry() {
        let factory = NativeFactory::with_default_models(1);
        let weights = lm_tiny_weights(&factory);
        // context overflow: lm-tiny's T is 64
        let cfg = ServeConfig { prompt_len: 60, gen_len: 10, requests: 1, ..tiny_cfg() };
        assert!(serve_synthetic(&factory, &weights, &cfg).is_err());
        let cfg = ServeConfig { gen_len: 0, requests: 1, ..tiny_cfg() };
        assert!(serve_synthetic(&factory, &weights, &cfg).is_err());
        // unknown decode format
        let cfg = ServeConfig { format: "int16".into(), ..tiny_cfg() };
        assert!(serve_synthetic(&factory, &weights, &cfg).is_err());
    }
}
