//! Learning-rate sweeps. The paper reports the best run per method
//! (App. A.5 grids); this module runs a grid of RunConfigs and selects
//! by final quantized validation loss.

use crate::config::RunConfig;
use crate::runtime::Executor;
use anyhow::Result;

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;
use super::trainer::{DataSource, Trainer};
use crate::tensor::HostTensor;

/// Outcome of one run inside a sweep.
pub struct SweepResult {
    pub lr: f64,
    pub metrics: MetricsLogger,
    /// final quantized val loss in the run's primary (format, rounding)
    pub score: f64,
    pub diverged: bool,
}

/// Run `base` at each LR; score by final quantized val loss under
/// (`score_format`, `score_rounding`). Diverged runs score +inf.
/// `inputs` rebuilds (statics, data source) per run so every LR sees
/// identical data streams.
pub fn lr_sweep(
    engine: &dyn Executor,
    base: &RunConfig,
    lrs: &[f64],
    score_format: &str,
    score_rounding: &str,
    inputs: &dyn Fn() -> Result<(Vec<(String, HostTensor)>, DataSource)>,
) -> Result<Vec<SweepResult>> {
    let mut results = Vec::new();
    for &lr in lrs {
        let mut cfg = base.clone();
        cfg.lr = lr;
        cfg.name = format!("{}_lr{lr:.0e}", base.name);
        let (statics, data) = inputs()?;
        let mut metrics = MetricsLogger::in_memory();
        let outcome = (|| -> Result<()> {
            let mut trainer = Trainer::new(engine, cfg.clone(), statics, data)?;
            let mut eval = Evaluator::new(engine, &cfg.model, cfg.seed)?;
            trainer.run(&mut eval, &mut metrics)
        })();
        let diverged = outcome.is_err();
        if let Err(e) = &outcome {
            crate::warn_!("sweep lr={lr:.1e}: {e}");
        }
        let score = if diverged {
            f64::INFINITY
        } else {
            metrics
                .final_eval(score_format, score_rounding)
                .unwrap_or(f64::INFINITY)
        };
        crate::info!("sweep {} lr={lr:.2e} -> score {score:.5}", base.name);
        results.push(SweepResult { lr, metrics, score, diverged });
    }
    Ok(results)
}

/// Index of the best (lowest-score) run.
pub fn best(results: &[SweepResult]) -> Option<usize> {
    results
        .iter()
        .enumerate()
        .min_by(|a, b| a.1.score.partial_cmp(&b.1.score).unwrap())
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn best_picks_minimum_and_skips_nan_free() {
        let mk = |score| SweepResult {
            lr: 0.1,
            metrics: MetricsLogger::in_memory(),
            score,
            diverged: false,
        };
        let rs = vec![mk(2.0), mk(0.5), mk(f64::INFINITY)];
        assert_eq!(best(&rs), Some(1));
        assert_eq!(best(&[]), None);
    }
}
