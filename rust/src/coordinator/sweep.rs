//! Grid sweeps. The paper reports every LM/testbed result as the best
//! run over an App. A.5 learning-rate grid, so *sweep* throughput —
//! not single-run throughput — gates reproduction wall clock. The
//! [`SweepRunner`] makes the grid a first-class sharded workload: grid
//! points fan out across worker threads on `util::pool`, each worker
//! owns an engine spawned from an
//! [`ExecutorFactory`](crate::runtime::ExecutorFactory), and results
//! fold back in fixed grid order.
//!
//! Determinism contract (two-level, DESIGN.md §3): each grid point is
//! an independent run — its own session on its own (or the caller's)
//! engine, its own config-seeded RNG, inputs rebuilt per point — so the
//! sharded sweep is **bit-identical** to the serial one at any
//! `--sweep-workers` setting, on top of the kernel-level guarantee that
//! each run is bit-identical at any `--threads` setting. The worker
//! pool only decides *which thread* runs a grid point, never what the
//! point computes; scores/metrics are folded in grid order.

use crate::config::RunConfig;
use crate::formats::json::Json;
use crate::runtime::{Executor, ExecutorFactory};
use crate::tensor::HostTensor;
use crate::util::{faults, pool::Pool, rng::Rng};
use anyhow::{anyhow, Result};
use std::cell::RefCell;
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;
use super::trainer::{DataSource, Trainer};

/// Per-point input builder: rebuilds (statics, data source) on the
/// worker's engine so every grid point sees an identical, freshly
/// constructed data stream regardless of which thread runs it. `Sync`
/// because workers call it concurrently.
pub type SweepInputs =
    dyn Fn(&dyn Executor, &RunConfig) -> Result<(Vec<(String, HostTensor)>, DataSource)> + Sync;

/// One grid point: a full run config plus its display label and an
/// optional JSONL metrics sink.
#[derive(Clone, Debug)]
pub struct SweepPoint {
    pub label: String,
    pub cfg: RunConfig,
    pub metrics_path: Option<PathBuf>,
}

impl SweepPoint {
    pub fn new(label: impl Into<String>, cfg: RunConfig) -> SweepPoint {
        SweepPoint { label: label.into(), cfg, metrics_path: None }
    }

    pub fn with_metrics_path(mut self, path: PathBuf) -> SweepPoint {
        self.metrics_path = Some(path);
        self
    }
}

/// Outcome of one run inside a sweep.
pub struct SweepResult {
    pub label: String,
    pub lr: f64,
    pub metrics: MetricsLogger,
    /// final quantized val loss in the sweep's scoring (format, rounding);
    /// +inf for diverged runs (NaN is mapped to +inf at this source, so
    /// downstream ordering never sees it)
    pub score: f64,
    pub diverged: bool,
}

/// One completed grid point in a sweep journal: a JSONL line keyed by
/// (label, config digest) with a bit-exact score.
#[derive(Clone, Debug)]
pub struct JournalEntry {
    pub label: String,
    /// [`RunConfig::digest`] of the point — resume skips a journaled
    /// point only when the digest still matches, so an edited grid
    /// re-runs instead of silently reusing stale scores
    pub digest: String,
    pub lr: f64,
    /// "ok" | "diverged" | "failed" (panicked through all retries)
    pub status: String,
    pub attempts: usize,
    pub score: f64,
    pub error: Option<String>,
    /// [`crate::spec::digest`] of the sweep-spec source that produced
    /// this point (spec-driven sweeps only): resume against an *edited*
    /// spec is refused outright rather than silently mixing grids
    pub spec: Option<String>,
}

impl JournalEntry {
    /// One JSONL line. The score rides as `score_bits` (hex of the f64
    /// bit pattern): +inf/NaN are not valid JSON numbers, and resume
    /// must reproduce scores *bitwise*. A human-readable `score` field
    /// accompanies finite values.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("label", Json::str(&self.label)),
            ("digest", Json::str(&self.digest)),
            ("lr", Json::num(self.lr)),
            ("status", Json::str(&self.status)),
            ("attempts", Json::num(self.attempts as f64)),
            ("score_bits", Json::str(&format!("{:016x}", self.score.to_bits()))),
            (
                "score",
                if self.score.is_finite() { Json::num(self.score) } else { Json::Null },
            ),
            (
                "error",
                match &self.error {
                    Some(e) => Json::str(e),
                    None => Json::Null,
                },
            ),
            (
                "spec",
                match &self.spec {
                    Some(d) => Json::str(d),
                    None => Json::Null,
                },
            ),
        ])
    }

    pub fn from_json(line: &str) -> Result<JournalEntry> {
        let j = Json::parse(line)?;
        let s = |k: &str| -> Result<String> {
            j.get(k)
                .and_then(|v| v.as_str())
                .map(String::from)
                .ok_or_else(|| anyhow!("journal line missing {k:?}"))
        };
        let bits = u64::from_str_radix(&s("score_bits")?, 16)
            .map_err(|e| anyhow!("bad score_bits: {e}"))?;
        Ok(JournalEntry {
            label: s("label")?,
            digest: s("digest")?,
            lr: j.get("lr").and_then(|v| v.as_f64()).ok_or_else(|| anyhow!("missing lr"))?,
            status: s("status")?,
            attempts: j.get("attempts").and_then(|v| v.as_usize()).unwrap_or(1),
            score: f64::from_bits(bits),
            error: j.get("error").and_then(|v| v.as_str()).map(String::from),
            // absent in pre-spec journals: those resume as before
            spec: j.get("spec").and_then(|v| v.as_str()).map(String::from),
        })
    }
}

/// Append-only JSONL journal of completed sweep points. Each point is
/// one line written atomically-enough for crash recovery: a torn tail
/// line (the process died mid-write) parses as garbage and is skipped
/// by [`SweepJournal::completed`], costing one re-run, never a wrong
/// result.
pub struct SweepJournal {
    path: PathBuf,
    file: Mutex<std::fs::File>,
}

impl SweepJournal {
    /// Open (append mode, created if missing) — existing lines from an
    /// interrupted sweep stay in place.
    pub fn open(path: &Path) -> Result<SweepJournal> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let file = std::fs::OpenOptions::new().create(true).append(true).open(path)?;
        Ok(SweepJournal { path: path.to_path_buf(), file: Mutex::new(file) })
    }

    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Parse the completed entries of an existing journal. Tolerant of
    /// a torn final line; a missing file is an empty journal.
    pub fn completed(path: &Path) -> Result<Vec<JournalEntry>> {
        let text = match std::fs::read_to_string(path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(Vec::new()),
            Err(e) => return Err(anyhow!("reading journal {path:?}: {e}")),
        };
        let mut out = Vec::new();
        for line in text.lines() {
            if line.trim().is_empty() {
                continue;
            }
            match JournalEntry::from_json(line) {
                Ok(e) => out.push(e),
                Err(e) => crate::warn_!("journal {path:?}: skipping unparseable line ({e})"),
            }
        }
        Ok(out)
    }

    /// Append one entry as a single line+newline write, flushed.
    pub fn append(&self, e: &JournalEntry) -> Result<()> {
        let mut line = e.to_json().to_string();
        line.push('\n');
        let mut f = self.file.lock().unwrap();
        f.write_all(line.as_bytes())?;
        f.flush()?;
        Ok(())
    }

    /// Journal a finished point; journal I/O failures degrade to a
    /// warning (the sweep result is still returned in-process).
    fn record(&self, digest: &str, spec: Option<&str>, attempts: usize, r: &SweepResult) {
        let error = r
            .metrics
            .diverged
            .as_ref()
            .map(|d| format!("diverged at step {} (loss {}, lr {:.3e})", d.step, d.loss, d.lr));
        let e = JournalEntry {
            label: r.label.clone(),
            digest: digest.to_string(),
            lr: r.lr,
            status: if r.diverged { "diverged" } else { "ok" }.to_string(),
            attempts,
            score: r.score,
            error,
            spec: spec.map(String::from),
        };
        if let Err(err) = self.append(&e) {
            crate::warn_!("journal {:?}: appending {}: {err}", self.path, r.label);
        }
    }

    /// Journal a point that panicked through all its retries.
    fn record_failed(
        &self,
        p: &SweepPoint,
        spec: Option<&str>,
        attempts: usize,
        error: Option<&str>,
    ) {
        let e = JournalEntry {
            label: p.label.clone(),
            digest: p.cfg.digest(),
            lr: p.cfg.lr,
            status: "failed".to_string(),
            attempts,
            score: f64::INFINITY,
            error: error.map(String::from),
            spec: spec.map(String::from),
        };
        if let Err(err) = self.append(&e) {
            crate::warn_!("journal {:?}: appending {}: {err}", self.path, p.label);
        }
    }
}

/// The `LOTION_SWEEP_WORKERS` environment override (0/unset/garbage =
/// unset).
pub fn env_sweep_workers() -> Option<usize> {
    std::env::var("LOTION_SWEEP_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
}

/// Resolve a requested sweep-worker count: explicit values win, `0`
/// means `LOTION_SWEEP_WORKERS` if set, else 1 (serial). The default is
/// deliberately serial — each engine owns its own kernel pool, so sweep
/// sharding multiplies thread demand and is opt-in.
pub fn resolve_sweep_workers(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    env_sweep_workers().unwrap_or(1)
}

/// Monotone id per sweep invocation: tags the per-thread cached engine
/// so a later sweep (possibly over a different factory) never reuses a
/// stale one.
static SWEEP_EPOCH: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// The engine owned by this worker thread for the current sweep
    /// (spawned lazily on its first grid point, reused for the rest).
    /// `Box<dyn Executor>` is deliberately thread-confined — it never
    /// leaves this slot.
    static WORKER_ENGINE: RefCell<Option<(u64, Box<dyn Executor>)>> = RefCell::new(None);
}

/// Drop guard that clears the calling thread's cached sweep engine —
/// panic-safe, so a propagated grid-point panic cannot strand an
/// engine (registry + scratch) in the submitter's thread_local.
struct ReleaseCallerEngine;

impl Drop for ReleaseCallerEngine {
    fn drop(&mut self) {
        WORKER_ENGINE.with(|slot| slot.borrow_mut().take());
    }
}

/// A sharded grid runner over factory-spawned engines (module docs).
pub struct SweepRunner<'f> {
    factory: &'f dyn ExecutorFactory,
    workers: usize,
    /// engine for the serial path: reuse the caller's (warm scratch,
    /// populated timing report) instead of spawning a throwaway one
    serial_engine: Option<&'f dyn Executor>,
    /// completed-point journal (None = no journaling)
    journal: Option<SweepJournal>,
    /// journaled entries from an interrupted sweep: matching points
    /// are skipped and their scores folded back in grid order
    resume: Vec<JournalEntry>,
    /// extra attempts for a panicking point (each on a fresh engine)
    retries: usize,
    /// spec-source digest stamped into journal entries (spec-driven
    /// sweeps only; see [`crate::spec::digest`])
    spec_digest: Option<String>,
}

impl<'f> SweepRunner<'f> {
    /// `workers == 0` resolves via [`resolve_sweep_workers`].
    pub fn new(factory: &'f dyn ExecutorFactory, workers: usize) -> SweepRunner<'f> {
        SweepRunner {
            factory,
            workers: resolve_sweep_workers(workers),
            serial_engine: None,
            journal: None,
            resume: Vec::new(),
            retries: 1,
            spec_digest: None,
        }
    }

    /// Run the serial (`workers <= 1`) path on this engine instead of a
    /// factory-spawned one: keeps its per-model scratch warm across
    /// grids and its timing report populated (the `exp` profile dump).
    /// Sharded runs still spawn per-worker engines — results are
    /// bit-identical either way (DESIGN.md §3).
    pub fn with_serial_engine(mut self, engine: &'f dyn Executor) -> SweepRunner<'f> {
        self.serial_engine = Some(engine);
        self
    }

    /// Journal completed points to `path`, skipping any point already
    /// present in `resume` (label + config digest match) — the
    /// `--resume-sweep` seam. Pass `SweepJournal::completed(path)?` as
    /// `resume` to fold a previous interrupted run, or an empty vec to
    /// journal from scratch.
    pub fn with_journal(mut self, path: &Path, resume: Vec<JournalEntry>) -> Result<Self> {
        self.journal = Some(SweepJournal::open(path)?);
        self.resume = resume;
        Ok(self)
    }

    /// Extra attempts for a grid point that *panics* (default 1). Each
    /// retry runs on a freshly spawned engine — the panicking engine's
    /// scratch may be poisoned. Deterministic divergence is never
    /// retried: it would diverge identically again.
    pub fn with_retries(mut self, retries: usize) -> Self {
        self.retries = retries;
        self
    }

    /// Stamp every journal entry with the sweep-spec source digest
    /// (`lotion sweep --spec`). The CLI refuses to resume a journal
    /// whose entries carry a *different* spec digest, so an edited spec
    /// can never silently mix with an old journal's grid.
    pub fn with_spec_digest(mut self, digest: impl Into<String>) -> Self {
        self.spec_digest = Some(digest.into());
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The journaled result for a point, if its label + digest match a
    /// resume entry (last entry wins when a label repeats).
    fn resumed_result(&self, p: &SweepPoint) -> Option<SweepResult> {
        let digest = p.cfg.digest();
        let e = self
            .resume
            .iter()
            .rev()
            .find(|e| e.label == p.label && e.digest == digest)?;
        crate::info!("sweep {}: resumed from journal (score {:.5})", e.label, e.score);
        Some(SweepResult {
            label: e.label.clone(),
            lr: e.lr,
            metrics: MetricsLogger::in_memory(),
            score: e.score,
            diverged: e.status != "ok",
        })
    }

    /// Run every grid point and fold the results in grid order. Scores
    /// are the final eval under (`score_format`, `score_rounding`);
    /// diverged runs (and NaN scores) fold as +inf rather than failing
    /// the sweep — a diverged grid point is a data point. A point that
    /// *panics* is caught at the point boundary, retried per
    /// [`SweepRunner::with_retries`], and folds as +inf if exhausted;
    /// journaled points from [`SweepRunner::with_journal`]'s resume set
    /// are skipped and their scores folded back in place.
    pub fn run(
        &self,
        points: Vec<SweepPoint>,
        score_format: &str,
        score_rounding: &str,
        inputs: &SweepInputs,
    ) -> Result<Vec<SweepResult>> {
        let n = points.len();
        let mut slots: Vec<Option<SweepResult>> = Vec::new();
        slots.resize_with(n, || None);
        let mut pending: Vec<(usize, SweepPoint)> = Vec::new();
        for (i, p) in points.into_iter().enumerate() {
            match self.resumed_result(&p) {
                Some(r) => slots[i] = Some(r),
                None => pending.push((i, p)),
            }
        }
        if self.workers <= 1 || pending.len() <= 1 {
            if !pending.is_empty() {
                let spawned;
                let base: &dyn Executor = match self.serial_engine {
                    Some(e) => e,
                    None => {
                        spawned = self.factory.spawn()?;
                        &*spawned
                    }
                };
                // a retried point hands back a fresh engine; later
                // points keep using it (the old one may be poisoned)
                let mut owned: Option<Box<dyn Executor>> = None;
                for (i, p) in &pending {
                    let engine: &dyn Executor = match &owned {
                        Some(b) => &**b,
                        None => base,
                    };
                    let (r, fresh) = run_point_guarded(
                        self.factory,
                        self.journal.as_ref(),
                        self.spec_digest.as_deref(),
                        self.retries,
                        engine,
                        *i,
                        p,
                        score_format,
                        score_rounding,
                        inputs,
                    )?;
                    if let Some(f) = fresh {
                        owned = Some(f);
                    }
                    slots[*i] = Some(r);
                }
            }
        } else {
            let epoch = SWEEP_EPOCH.fetch_add(1, Ordering::Relaxed);
            let pool = Pool::new(self.workers.min(pending.len()));
            let factory = self.factory;
            let journal = self.journal.as_ref();
            let spec_digest = self.spec_digest.as_deref();
            let retries = self.retries;
            // the calling thread participates in the job; make sure its
            // cached engine is released even if a grid point panics (pool
            // workers drop theirs with the pool)
            let _release = ReleaseCallerEngine;
            let results: Vec<Result<(usize, SweepResult)>> = pool.run(pending, |_, (i, p)| {
                WORKER_ENGINE.with(|slot| {
                    let mut slot = slot.borrow_mut();
                    let stale = !matches!(&*slot, Some((e, _)) if *e == epoch);
                    if stale {
                        *slot = Some((epoch, factory.spawn()?));
                    }
                    let engine = &slot.as_ref().expect("engine just installed").1;
                    let (r, fresh) = run_point_guarded(
                        factory,
                        journal,
                        spec_digest,
                        retries,
                        &**engine,
                        i,
                        &p,
                        score_format,
                        score_rounding,
                        inputs,
                    )?;
                    if let Some(f) = fresh {
                        // adopt the retry's fresh engine for the rest of
                        // this worker's points
                        *slot = Some((epoch, f));
                    }
                    Ok((i, r))
                })
            });
            // task order == grid order; a spawn failure fails the sweep
            for r in results {
                let (i, res) = r?;
                slots[i] = Some(res);
            }
        }
        Ok(slots.into_iter().map(|s| s.expect("every grid slot filled")).collect())
    }
}

/// Execute one grid point with the crash boundary around it: the
/// `point` fault site fires first, then [`run_point`] runs under
/// `catch_unwind`. A panic (injected or real) is caught, warned, and
/// retried up to `retries` times on a freshly spawned engine — the
/// panicking engine's scratch may be poisoned mid-kernel. Exhausted
/// retries fold as a `failed` +inf result instead of killing the
/// sweep. Returns the result plus the fresh engine (if one was
/// spawned) so the caller adopts it for subsequent points.
///
/// A free function, not a method: the sharded path calls it from the
/// pool closure, which must not capture `&SweepRunner` (the serial
/// engine borrow is not `Sync`).
#[allow(clippy::too_many_arguments)]
fn run_point_guarded(
    factory: &dyn ExecutorFactory,
    journal: Option<&SweepJournal>,
    spec_digest: Option<&str>,
    retries: usize,
    engine: &dyn Executor,
    index: usize,
    p: &SweepPoint,
    score_format: &str,
    score_rounding: &str,
    inputs: &SweepInputs,
) -> Result<(SweepResult, Option<Box<dyn Executor>>)> {
    let mut fresh: Option<Box<dyn Executor>> = None;
    let mut last_panic: Option<String> = None;
    for attempt in 1..=retries + 1 {
        let eng: &dyn Executor = match &fresh {
            Some(b) => &**b,
            None => engine,
        };
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if let Err(e) = faults::poke("point", index as u64) {
                panic!("{e}");
            }
            run_point(eng, p, score_format, score_rounding, inputs)
        }));
        match caught {
            Ok(r) => {
                if let Some(j) = journal {
                    j.record(&p.cfg.digest(), spec_digest, attempt, &r);
                }
                return Ok((r, fresh));
            }
            Err(payload) => {
                let msg = panic_message(payload.as_ref());
                crate::warn_!("sweep {}: attempt {attempt} panicked: {msg}", p.label);
                last_panic = Some(msg);
                if attempt <= retries {
                    fresh = Some(factory.spawn()?);
                }
            }
        }
    }
    if let Some(j) = journal {
        j.record_failed(p, spec_digest, retries + 1, last_panic.as_deref());
    }
    let r = SweepResult {
        label: p.label.clone(),
        lr: p.cfg.lr,
        metrics: MetricsLogger::in_memory(),
        score: f64::INFINITY,
        diverged: true,
    };
    Ok((r, fresh))
}

fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Execute one grid point on the given engine. Everything here is a
/// pure function of (engine manifest+programs, point, inputs) — the
/// property the sharded/serial bit-identity rests on.
fn run_point(
    engine: &dyn Executor,
    p: &SweepPoint,
    score_format: &str,
    score_rounding: &str,
    inputs: &SweepInputs,
) -> SweepResult {
    let mut metrics = match &p.metrics_path {
        Some(path) => MetricsLogger::to_file(path).unwrap_or_else(|e| {
            crate::warn_!("sweep {}: metrics sink {path:?}: {e}; logging in memory", p.label);
            MetricsLogger::in_memory()
        }),
        None => MetricsLogger::in_memory(),
    };
    let outcome = (|| -> Result<()> {
        let (statics, data) = inputs(engine, &p.cfg)?;
        let mut trainer = Trainer::new(engine, p.cfg.clone(), statics, data)?;
        let mut eval = Evaluator::new(p.cfg.seed);
        trainer.run(&mut eval, &mut metrics)
    })();
    let diverged = outcome.is_err();
    if let Err(e) = &outcome {
        crate::warn_!("sweep {}: {e}", p.label);
    }
    let score = if diverged {
        f64::INFINITY
    } else {
        metrics
            .final_eval(score_format, score_rounding)
            .filter(|v| !v.is_nan()) // NaN -> +inf at the source
            .unwrap_or(f64::INFINITY)
    };
    crate::info!("sweep {} lr={:.2e} -> score {score:.5}", p.label, p.cfg.lr);
    SweepResult { label: p.label.clone(), lr: p.cfg.lr, metrics, score, diverged }
}

/// Run `base` at each LR (sharded across `workers` engines spawned
/// from `factory`); score by final quantized val loss under
/// (`score_format`, `score_rounding`). Each grid point trains under its
/// own counter-derived seed (`Rng::stream_seed(base.seed, [i])`), so
/// points are independent of one another and of execution order —
/// `--sweep-workers N` is bit-identical to serial for every N.
pub fn lr_sweep(
    factory: &dyn ExecutorFactory,
    workers: usize,
    base: &RunConfig,
    lrs: &[f64],
    score_format: &str,
    score_rounding: &str,
    inputs: &SweepInputs,
) -> Result<Vec<SweepResult>> {
    SweepRunner::new(factory, workers).run(
        lr_points(base, lrs),
        score_format,
        score_rounding,
        inputs,
    )
}

/// The LR-grid points [`lr_sweep`] runs — exposed so callers that need
/// a configured [`SweepRunner`] (journaling, retries, resume) build
/// the identical grid: same labels, same counter-derived seeds, so a
/// resumed sweep's journal keys line up with the original's.
pub fn lr_points(base: &RunConfig, lrs: &[f64]) -> Vec<SweepPoint> {
    lrs.iter()
        .enumerate()
        .map(|(i, &lr)| {
            let mut cfg = base.clone();
            cfg.lr = lr;
            cfg.name = format!("{}_lr{lr:.0e}", base.name);
            cfg.seed = Rng::stream_seed(base.seed, &[i as u64]);
            SweepPoint::new(cfg.name.clone(), cfg)
        })
        .collect()
}

/// Index of the best (lowest-score) run. Total order: NaN sorts as
/// +inf, so a backend that ever reports NaN instead of the diverged
/// sentinel cannot panic the selection. Ties on the exact score bits
/// break toward the **lowest grid index** — explicitly, not via
/// `min_by`'s first-wins behavior, so spec-driven grids with duplicate
/// scores pick a stable winner by contract rather than by accident.
pub fn best(results: &[SweepResult]) -> Option<usize> {
    fn key(s: f64) -> f64 {
        if s.is_nan() {
            f64::INFINITY
        } else {
            s
        }
    }
    results
        .iter()
        .enumerate()
        .min_by(|a, b| key(a.1.score).total_cmp(&key(b.1.score)).then_with(|| a.0.cmp(&b.0)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(score: f64) -> SweepResult {
        SweepResult {
            label: "t".into(),
            lr: 0.1,
            metrics: MetricsLogger::in_memory(),
            score,
            diverged: false,
        }
    }

    #[test]
    fn best_picks_minimum_and_skips_inf() {
        let rs = vec![mk(2.0), mk(0.5), mk(f64::INFINITY)];
        assert_eq!(best(&rs), Some(1));
        assert_eq!(best(&[]), None);
    }

    /// Satellite (ISSUE 5): NaN scores must neither panic nor win.
    #[test]
    fn best_treats_nan_as_worst() {
        let rs = vec![mk(f64::NAN), mk(3.0), mk(f64::NAN), mk(1.5)];
        assert_eq!(best(&rs), Some(3));
        // all-NaN still returns *an* index rather than panicking
        assert!(best(&[mk(f64::NAN), mk(f64::NAN)]).is_some());
    }

    /// Satellite (ISSUE 10): bit-equal scores break toward the lowest
    /// grid index, so duplicate-score spec grids pick a stable winner.
    #[test]
    fn best_breaks_ties_toward_lowest_index() {
        let rs = vec![mk(2.0), mk(0.5), mk(0.5), mk(0.5)];
        assert_eq!(best(&rs), Some(1));
        let rs = vec![mk(f64::NAN), mk(f64::NAN)];
        assert_eq!(best(&rs), Some(0), "all-NaN ties break to index 0 too");
        let rs = vec![mk(-0.0), mk(0.0)];
        assert_eq!(best(&rs), Some(0), "total_cmp orders -0 < +0, no tie here");
    }

    #[test]
    fn journal_entry_roundtrips_bitwise() {
        for score in [1.25, f64::INFINITY, f64::NAN, -0.0] {
            let e = JournalEntry {
                label: "p_lr1e-2".into(),
                digest: "0123456789abcdef".into(),
                lr: 0.01,
                status: "ok".into(),
                attempts: 2,
                score,
                error: Some("why \"quoted\"".into()),
                spec: Some("32e004e1b0e69803".into()),
            };
            let line = e.to_json().to_string();
            let back = JournalEntry::from_json(&line).unwrap();
            assert_eq!(back.label, e.label);
            assert_eq!(back.digest, e.digest);
            assert_eq!(back.status, e.status);
            assert_eq!(back.attempts, 2);
            assert_eq!(back.score.to_bits(), e.score.to_bits(), "score {score}");
            assert_eq!(back.error, e.error);
            assert_eq!(back.spec, e.spec);
        }
        // pre-spec journal lines (no "spec" field) still parse
        let legacy = r#"{"label":"a","digest":"d","lr":0.1,"status":"ok","attempts":1,"score_bits":"4000000000000000","score":2}"#;
        let back = JournalEntry::from_json(legacy).unwrap();
        assert_eq!(back.spec, None);
        assert_eq!(back.score, 2.0);
    }

    #[test]
    fn journal_completed_skips_torn_tail() {
        use crate::util::tempdir::TempDir;
        let dir = TempDir::new();
        let path = dir.path().join("sweep.jsonl");
        let j = SweepJournal::open(&path).unwrap();
        let mk_entry = |label: &str| JournalEntry {
            label: label.into(),
            digest: "d".into(),
            lr: 0.1,
            status: "ok".into(),
            attempts: 1,
            score: 2.0,
            error: None,
            spec: None,
        };
        j.append(&mk_entry("a")).unwrap();
        j.append(&mk_entry("b")).unwrap();
        drop(j);
        // simulate a crash mid-append: torn partial line at the tail
        let mut text = std::fs::read_to_string(&path).unwrap();
        text.push_str("{\"label\":\"c\",\"dig");
        std::fs::write(&path, &text).unwrap();
        let entries = SweepJournal::completed(&path).unwrap();
        assert_eq!(entries.len(), 2);
        assert_eq!(entries[0].label, "a");
        assert_eq!(entries[1].label, "b");
        // missing file = empty journal
        assert!(SweepJournal::completed(&dir.path().join("nope.jsonl")).unwrap().is_empty());
    }

    #[test]
    fn worker_resolution_explicit_beats_env() {
        assert_eq!(resolve_sweep_workers(3), 3);
        // 0 falls back to env-or-1; with the var unset in tests this is 1
        // unless the CI lane exports LOTION_SWEEP_WORKERS
        let resolved = resolve_sweep_workers(0);
        assert!(resolved >= 1);
        assert_eq!(resolved, env_sweep_workers().unwrap_or(1));
    }
}
