//! Grid sweeps. The paper reports every LM/testbed result as the best
//! run over an App. A.5 learning-rate grid, so *sweep* throughput —
//! not single-run throughput — gates reproduction wall clock. The
//! [`SweepRunner`] makes the grid a first-class sharded workload: grid
//! points fan out across worker threads on `util::pool`, each worker
//! owns an engine spawned from an
//! [`ExecutorFactory`](crate::runtime::ExecutorFactory), and results
//! fold back in fixed grid order.
//!
//! Determinism contract (two-level, DESIGN.md §3): each grid point is
//! an independent run — its own session on its own (or the caller's)
//! engine, its own config-seeded RNG, inputs rebuilt per point — so the
//! sharded sweep is **bit-identical** to the serial one at any
//! `--sweep-workers` setting, on top of the kernel-level guarantee that
//! each run is bit-identical at any `--threads` setting. The worker
//! pool only decides *which thread* runs a grid point, never what the
//! point computes; scores/metrics are folded in grid order.

use crate::config::RunConfig;
use crate::runtime::{Executor, ExecutorFactory};
use crate::tensor::HostTensor;
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use anyhow::Result;
use std::cell::RefCell;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;
use super::trainer::{DataSource, Trainer};

/// Per-point input builder: rebuilds (statics, data source) on the
/// worker's engine so every grid point sees an identical, freshly
/// constructed data stream regardless of which thread runs it. `Sync`
/// because workers call it concurrently.
pub type SweepInputs =
    dyn Fn(&dyn Executor, &RunConfig) -> Result<(Vec<(String, HostTensor)>, DataSource)> + Sync;

/// One grid point: a full run config plus its display label and an
/// optional JSONL metrics sink.
pub struct SweepPoint {
    pub label: String,
    pub cfg: RunConfig,
    pub metrics_path: Option<PathBuf>,
}

impl SweepPoint {
    pub fn new(label: impl Into<String>, cfg: RunConfig) -> SweepPoint {
        SweepPoint { label: label.into(), cfg, metrics_path: None }
    }

    pub fn with_metrics_path(mut self, path: PathBuf) -> SweepPoint {
        self.metrics_path = Some(path);
        self
    }
}

/// Outcome of one run inside a sweep.
pub struct SweepResult {
    pub label: String,
    pub lr: f64,
    pub metrics: MetricsLogger,
    /// final quantized val loss in the sweep's scoring (format, rounding);
    /// +inf for diverged runs (NaN is mapped to +inf at this source, so
    /// downstream ordering never sees it)
    pub score: f64,
    pub diverged: bool,
}

/// The `LOTION_SWEEP_WORKERS` environment override (0/unset/garbage =
/// unset).
pub fn env_sweep_workers() -> Option<usize> {
    std::env::var("LOTION_SWEEP_WORKERS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
}

/// Resolve a requested sweep-worker count: explicit values win, `0`
/// means `LOTION_SWEEP_WORKERS` if set, else 1 (serial). The default is
/// deliberately serial — each engine owns its own kernel pool, so sweep
/// sharding multiplies thread demand and is opt-in.
pub fn resolve_sweep_workers(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    env_sweep_workers().unwrap_or(1)
}

/// Monotone id per sweep invocation: tags the per-thread cached engine
/// so a later sweep (possibly over a different factory) never reuses a
/// stale one.
static SWEEP_EPOCH: AtomicU64 = AtomicU64::new(1);

thread_local! {
    /// The engine owned by this worker thread for the current sweep
    /// (spawned lazily on its first grid point, reused for the rest).
    /// `Box<dyn Executor>` is deliberately thread-confined — it never
    /// leaves this slot.
    static WORKER_ENGINE: RefCell<Option<(u64, Box<dyn Executor>)>> = RefCell::new(None);
}

/// Drop guard that clears the calling thread's cached sweep engine —
/// panic-safe, so a propagated grid-point panic cannot strand an
/// engine (registry + scratch) in the submitter's thread_local.
struct ReleaseCallerEngine;

impl Drop for ReleaseCallerEngine {
    fn drop(&mut self) {
        WORKER_ENGINE.with(|slot| slot.borrow_mut().take());
    }
}

/// A sharded grid runner over factory-spawned engines (module docs).
pub struct SweepRunner<'f> {
    factory: &'f dyn ExecutorFactory,
    workers: usize,
    /// engine for the serial path: reuse the caller's (warm scratch,
    /// populated timing report) instead of spawning a throwaway one
    serial_engine: Option<&'f dyn Executor>,
}

impl<'f> SweepRunner<'f> {
    /// `workers == 0` resolves via [`resolve_sweep_workers`].
    pub fn new(factory: &'f dyn ExecutorFactory, workers: usize) -> SweepRunner<'f> {
        SweepRunner { factory, workers: resolve_sweep_workers(workers), serial_engine: None }
    }

    /// Run the serial (`workers <= 1`) path on this engine instead of a
    /// factory-spawned one: keeps its per-model scratch warm across
    /// grids and its timing report populated (the `exp` profile dump).
    /// Sharded runs still spawn per-worker engines — results are
    /// bit-identical either way (DESIGN.md §3).
    pub fn with_serial_engine(mut self, engine: &'f dyn Executor) -> SweepRunner<'f> {
        self.serial_engine = Some(engine);
        self
    }

    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Run every grid point and fold the results in grid order. Scores
    /// are the final eval under (`score_format`, `score_rounding`);
    /// diverged runs (and NaN scores) fold as +inf rather than failing
    /// the sweep — a diverged grid point is a data point.
    pub fn run(
        &self,
        points: Vec<SweepPoint>,
        score_format: &str,
        score_rounding: &str,
        inputs: &SweepInputs,
    ) -> Result<Vec<SweepResult>> {
        let n = points.len();
        if self.workers <= 1 || n <= 1 {
            let spawned;
            let engine: &dyn Executor = match self.serial_engine {
                Some(e) => e,
                None => {
                    spawned = self.factory.spawn()?;
                    &*spawned
                }
            };
            return points
                .iter()
                .map(|p| Ok(run_point(engine, p, score_format, score_rounding, inputs)))
                .collect();
        }
        let epoch = SWEEP_EPOCH.fetch_add(1, Ordering::Relaxed);
        let pool = Pool::new(self.workers.min(n));
        let factory = self.factory;
        // the calling thread participates in the job; make sure its
        // cached engine is released even if a grid point panics (pool
        // workers drop theirs with the pool)
        let _release = ReleaseCallerEngine;
        let results: Vec<Result<SweepResult>> = pool.run(points, |_, p| {
            WORKER_ENGINE.with(|slot| {
                let mut slot = slot.borrow_mut();
                let stale = !matches!(&*slot, Some((e, _)) if *e == epoch);
                if stale {
                    *slot = Some((epoch, factory.spawn()?));
                }
                let engine = &slot.as_ref().expect("engine just installed").1;
                Ok(run_point(&**engine, &p, score_format, score_rounding, inputs))
            })
        });
        // task order == grid order; a spawn failure fails the sweep
        results.into_iter().collect()
    }
}

/// Execute one grid point on the given engine. Everything here is a
/// pure function of (engine manifest+programs, point, inputs) — the
/// property the sharded/serial bit-identity rests on.
fn run_point(
    engine: &dyn Executor,
    p: &SweepPoint,
    score_format: &str,
    score_rounding: &str,
    inputs: &SweepInputs,
) -> SweepResult {
    let mut metrics = match &p.metrics_path {
        Some(path) => MetricsLogger::to_file(path).unwrap_or_else(|e| {
            crate::warn_!("sweep {}: metrics sink {path:?}: {e}; logging in memory", p.label);
            MetricsLogger::in_memory()
        }),
        None => MetricsLogger::in_memory(),
    };
    let outcome = (|| -> Result<()> {
        let (statics, data) = inputs(engine, &p.cfg)?;
        let mut trainer = Trainer::new(engine, p.cfg.clone(), statics, data)?;
        let mut eval = Evaluator::new(p.cfg.seed);
        trainer.run(&mut eval, &mut metrics)
    })();
    let diverged = outcome.is_err();
    if let Err(e) = &outcome {
        crate::warn_!("sweep {}: {e}", p.label);
    }
    let score = if diverged {
        f64::INFINITY
    } else {
        metrics
            .final_eval(score_format, score_rounding)
            .filter(|v| !v.is_nan()) // NaN -> +inf at the source
            .unwrap_or(f64::INFINITY)
    };
    crate::info!("sweep {} lr={:.2e} -> score {score:.5}", p.label, p.cfg.lr);
    SweepResult { label: p.label.clone(), lr: p.cfg.lr, metrics, score, diverged }
}

/// Run `base` at each LR (sharded across `workers` engines spawned
/// from `factory`); score by final quantized val loss under
/// (`score_format`, `score_rounding`). Each grid point trains under its
/// own counter-derived seed (`Rng::stream_seed(base.seed, [i])`), so
/// points are independent of one another and of execution order —
/// `--sweep-workers N` is bit-identical to serial for every N.
pub fn lr_sweep(
    factory: &dyn ExecutorFactory,
    workers: usize,
    base: &RunConfig,
    lrs: &[f64],
    score_format: &str,
    score_rounding: &str,
    inputs: &SweepInputs,
) -> Result<Vec<SweepResult>> {
    let points = lrs
        .iter()
        .enumerate()
        .map(|(i, &lr)| {
            let mut cfg = base.clone();
            cfg.lr = lr;
            cfg.name = format!("{}_lr{lr:.0e}", base.name);
            cfg.seed = Rng::stream_seed(base.seed, &[i as u64]);
            SweepPoint::new(cfg.name.clone(), cfg)
        })
        .collect();
    SweepRunner::new(factory, workers).run(points, score_format, score_rounding, inputs)
}

/// Index of the best (lowest-score) run. Total order: NaN sorts as
/// +inf, so a backend that ever reports NaN instead of the diverged
/// sentinel cannot panic the selection.
pub fn best(results: &[SweepResult]) -> Option<usize> {
    fn key(s: f64) -> f64 {
        if s.is_nan() {
            f64::INFINITY
        } else {
            s
        }
    }
    results
        .iter()
        .enumerate()
        .min_by(|a, b| key(a.1.score).total_cmp(&key(b.1.score)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(score: f64) -> SweepResult {
        SweepResult {
            label: "t".into(),
            lr: 0.1,
            metrics: MetricsLogger::in_memory(),
            score,
            diverged: false,
        }
    }

    #[test]
    fn best_picks_minimum_and_skips_inf() {
        let rs = vec![mk(2.0), mk(0.5), mk(f64::INFINITY)];
        assert_eq!(best(&rs), Some(1));
        assert_eq!(best(&[]), None);
    }

    /// Satellite (ISSUE 5): NaN scores must neither panic nor win.
    #[test]
    fn best_treats_nan_as_worst() {
        let rs = vec![mk(f64::NAN), mk(3.0), mk(f64::NAN), mk(1.5)];
        assert_eq!(best(&rs), Some(3));
        // all-NaN still returns *an* index rather than panicking
        assert!(best(&[mk(f64::NAN), mk(f64::NAN)]).is_some());
    }

    #[test]
    fn worker_resolution_explicit_beats_env() {
        assert_eq!(resolve_sweep_workers(3), 3);
        // 0 falls back to env-or-1; with the var unset in tests this is 1
        // unless the CI lane exports LOTION_SWEEP_WORKERS
        let resolved = resolve_sweep_workers(0);
        assert!(resolved >= 1);
        assert_eq!(resolved, env_sweep_workers().unwrap_or(1));
    }
}
