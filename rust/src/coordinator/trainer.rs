//! The chunked training loop: one backend call runs `steps_per_call`
//! optimizer steps (a `lax.scan` inside the PJRT artifact, an
//! interpreted loop in the native backend). The backend round-trip —
//! argument packing by role, metric splitting, state adoption — lives
//! in the run's [`Session`]; this loop owns what is *schedule-shaped*:
//! per-step LRs, the data stream, the run RNG and the step counter.

use crate::checkpoint::Checkpoint;
use crate::config::RunConfig;
use crate::data::TokenBatcher;
use crate::formats::json::Json;
use crate::runtime::executor::{value, Executor};
use crate::runtime::session::{ChunkInputs, Session};
use crate::runtime::TrainState;
use crate::tensor::HostTensor;
use crate::util::{faults, rng::Rng};
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};
use std::time::Instant;

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;

/// Checkpoint tensor key for the evaluator's pinned validation chunk
/// (not a state tensor; namespaced so it can never collide with one).
pub const VAL_TOKENS_KEY: &str = "__evaluator.val_tokens";

/// Periodic-checkpoint policy for [`Trainer::run_with_checkpoints`].
pub struct CkptPolicy {
    pub dir: PathBuf,
    /// snapshot cadence in optimizer steps (rounded to chunk
    /// boundaries; 0 disables — callers pass `None` instead)
    pub every: usize,
}

impl CkptPolicy {
    pub fn step_path(&self, step: usize) -> PathBuf {
        self.dir.join(format!("step{step:06}.lotn"))
    }
}

/// Where per-step batches come from.
pub enum DataSource {
    /// synthetic tasks sample in-graph from the per-chunk key
    InGraph,
    /// token LM: host-side batcher supplies `[K, B, T+1]` chunks
    Tokens(TokenBatcher),
}

pub struct Trainer<'e> {
    /// the run's typed engine handle (entries + state + statics)
    pub session: Session<'e>,
    pub cfg: RunConfig,
    pub data: DataSource,
    pub rng: Rng,
    pub step: usize,
}

impl<'e> Trainer<'e> {
    /// Build a trainer: open a [`Session`] (resolve programs, init
    /// params via the init program, zero the optimizer state, validate
    /// statics) and seed the run RNG.
    pub fn new(
        engine: &'e dyn Executor,
        cfg: RunConfig,
        statics: Vec<(String, HostTensor)>,
        data: DataSource,
    ) -> Result<Trainer<'e>> {
        let mut rng = Rng::new(cfg.seed);
        let init_key = rng.jax_key();
        let session = Session::open(engine, &cfg, statics, init_key)?;
        Ok(Trainer { session, cfg, data, rng, step: 0 })
    }

    pub fn engine(&self) -> &'e dyn Executor {
        self.session.engine()
    }

    /// The run's named train state (params + optimizer tensors).
    pub fn state(&self) -> &TrainState {
        &self.session.state
    }

    pub fn steps_per_call(&self) -> usize {
        self.session.steps_per_call()
    }

    /// The quantized-subset tensor names (from the manifest).
    pub fn quantized_keys(&self) -> &[String] {
        self.session.quantized_keys()
    }

    /// Run one chunk (K steps). Returns (mean base loss, mean total loss).
    pub fn chunk(&mut self, metrics: &mut MetricsLogger) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let k = self.steps_per_call();
        let lrs: Vec<f32> = (0..k).map(|i| self.cfg.lr_at(self.step + i) as f32).collect();
        // RNG draw order is fixed (data chunk, then chunk key) so runs
        // stay bit-identical with the pre-Session trainer
        let data = if self.session.train_wants_data() {
            match &mut self.data {
                DataSource::Tokens(b) => Some(value(b.train_chunk(k, &mut self.rng))),
                DataSource::InGraph => {
                    bail!("{} wants a data input", self.session.train_entry().name)
                }
            }
        } else {
            None
        };
        let key = self.rng.jax_key();
        // scheduled estimators get their per-step σ_t / gradient scale
        // as a pure function of the global step, so a resumed run
        // recomputes exactly the values the uninterrupted one saw —
        // no estimator state needs to live in the snapshot
        let est_sched: Option<Vec<f32>> = self
            .session
            .train_entry()
            .input_index("est_sched")
            .map(|_| (0..k).map(|i| self.cfg.est_sched_at(self.step + i) as f32).collect());
        let out = self.session.train_chunk(ChunkInputs {
            lrs,
            lam_reg: self.cfg.lambda as f32,
            est_sched,
            key,
            data,
        })?;
        self.step += k;
        let base = out.bases.iter().map(|&v| v as f64).sum::<f64>() / out.bases.len() as f64;
        let total = out.totals.iter().map(|&v| v as f64).sum::<f64>() / out.totals.len() as f64;
        if !base.is_finite() {
            // structured record first, so sweep journals and JSONL
            // sinks capture *why* this run scored +inf
            metrics.log_diverged(self.step, base, &self.cfg.method, self.cfg.lr_at(self.step));
            bail!(
                "{}: loss diverged (nan/inf) at step {}",
                self.session.train_entry().name,
                self.step
            );
        }
        metrics.log_train(self.step, base, total, self.cfg.lr_at(self.step), t0.elapsed().as_secs_f64());
        Ok((base, total))
    }

    /// Full run: chunks until `cfg.steps`, evaluating per `eval_every`.
    pub fn run(&mut self, eval: &mut Evaluator, metrics: &mut MetricsLogger) -> Result<()> {
        self.run_with_checkpoints(eval, metrics, None, None)
    }

    /// [`Trainer::run`] with periodic checkpoints and resume support.
    /// `resume_next_eval` is the eval-cadence position restored by
    /// [`Trainer::restore`] (None = fresh run, eval at step 0). The
    /// `step` fault site fires at the top of each loop iteration —
    /// before the iteration's eval — so a killed-at-step-N run appended
    /// after resume reproduces the uninterrupted JSONL exactly.
    pub fn run_with_checkpoints(
        &mut self,
        eval: &mut Evaluator,
        metrics: &mut MetricsLogger,
        ckpt: Option<&CkptPolicy>,
        resume_next_eval: Option<usize>,
    ) -> Result<()> {
        let mut next_eval = resume_next_eval.unwrap_or(0);
        // checkpoint cadence re-arms from the step actually saved, so a
        // resumed run snapshots at the same steps the uninterrupted one
        // would (chunks advance K steps at a time and may overshoot)
        let mut next_ckpt = ckpt.map_or(usize::MAX, |p| self.step + p.every.max(1));
        while self.step < self.cfg.steps {
            faults::poke("step", self.step as u64)?;
            if self.step >= next_eval {
                eval.eval_all(self, metrics)?;
                next_eval = self.step + self.cfg.eval_every.max(1);
            }
            self.chunk(metrics)?;
            if self.step >= next_ckpt {
                let p = ckpt.expect("next_ckpt is armed only with a policy");
                // a failed periodic snapshot degrades crash-safety but
                // must not kill the run it exists to protect
                if let Err(e) = self.save_checkpoint(eval, next_eval, &p.step_path(self.step)) {
                    crate::warn_!("checkpoint at step {} failed: {e}", self.step);
                }
                next_ckpt = self.step + p.every.max(1);
            }
        }
        eval.eval_all(self, metrics)?;
        Ok(())
    }

    /// Snapshot everything a bit-identical resume needs: the train
    /// state (params + optimizer moments), the step counter, both RNG
    /// stream positions, the eval-cadence position, and the pinned
    /// validation chunk. The config digest guards against resuming
    /// into a different run configuration.
    pub fn snapshot(&self, eval: &Evaluator, next_eval: usize) -> Result<Checkpoint> {
        let meta = Json::obj(vec![
            ("step", Json::num(self.step as f64)),
            ("next_eval", Json::num(next_eval as f64)),
            ("model", Json::str(&self.cfg.model)),
            ("method", Json::str(&self.cfg.method)),
            ("format", Json::str(&self.cfg.format)),
            ("config_digest", Json::str(&self.cfg.digest())),
            // estimator schedule knobs, for human inspection: resume
            // needs only the digest (which covers them when non-default)
            // plus the step — schedule values are recomputed, not stored
            ("est_schedule", Json::str(self.cfg.est_schedule.name())),
            ("est_sigma0", Json::num(self.cfg.est_sigma0)),
            ("est_grad_scale", Json::num(self.cfg.est_grad_scale)),
            ("trainer_rng", Json::str(&self.rng.encode_state())),
            ("eval_rng", Json::str(&eval.rng.encode_state())),
        ]);
        let mut c = Checkpoint::new(meta);
        for name in &self.session.state.names {
            c.push(name, self.session.state.fetch(name)?);
        }
        if let Some(t) = eval.val_tokens() {
            c.push(VAL_TOKENS_KEY, t);
        }
        Ok(c)
    }

    /// Snapshot and atomically write a `.lotn` checkpoint.
    pub fn save_checkpoint(&self, eval: &Evaluator, next_eval: usize, path: &Path) -> Result<()> {
        self.snapshot(eval, next_eval)?.save(path)
    }

    /// Restore a checkpoint into this (freshly built) trainer +
    /// evaluator. Returns the `next_eval` cadence position to pass to
    /// [`Trainer::run_with_checkpoints`]. Fails if the checkpoint was
    /// written under a different result-determining configuration.
    pub fn restore(&mut self, eval: &mut Evaluator, ckpt: &Checkpoint) -> Result<usize> {
        let meta_str = |key: &str| -> Result<&str> {
            ckpt.meta
                .get(key)
                .and_then(|j| j.as_str())
                .ok_or_else(|| anyhow!("checkpoint meta missing {key:?}"))
        };
        let digest = meta_str("config_digest")?;
        if digest != self.cfg.digest() {
            bail!(
                "checkpoint config digest {digest} does not match this run ({}); \
                 refusing to resume into a different configuration",
                self.cfg.digest()
            );
        }
        self.session.restore_state(&ckpt.tensors)?;
        self.rng = Rng::decode_state(meta_str("trainer_rng")?)?;
        eval.rng = Rng::decode_state(meta_str("eval_rng")?)?;
        if let Some(t) = ckpt.get(VAL_TOKENS_KEY) {
            eval.set_val_tokens(t.clone());
        }
        self.step = ckpt
            .meta
            .get("step")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow!("checkpoint meta missing step"))?;
        ckpt.meta
            .get("next_eval")
            .and_then(|j| j.as_usize())
            .ok_or_else(|| anyhow!("checkpoint meta missing next_eval"))
    }
}
