//! The chunked training loop: one backend call runs `steps_per_call`
//! optimizer steps (a `lax.scan` inside the PJRT artifact, an
//! interpreted loop in the native backend); state round-trips as
//! backend-neutral values between chunks (DESIGN.md §2).

use crate::config::RunConfig;
use crate::data::TokenBatcher;
use crate::runtime::executor::{value, Executor, Value};
use crate::runtime::manifest::{ArtifactEntry, Role};
use crate::runtime::{state, TrainState};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::time::Instant;

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;

/// Where per-step batches come from.
pub enum DataSource {
    /// synthetic tasks sample in-graph from the per-chunk key
    InGraph,
    /// token LM: host-side batcher supplies `[K, B, T+1]` chunks
    Tokens(TokenBatcher),
}

pub struct Trainer<'e> {
    pub engine: &'e dyn Executor,
    pub cfg: RunConfig,
    pub train: ArtifactEntry,
    pub state: TrainState,
    /// named non-trained inputs (lam, wstar) — empty for the LM
    pub statics: Vec<(String, Value)>,
    pub data: DataSource,
    pub rng: Rng,
    pub step: usize,
}

impl<'e> Trainer<'e> {
    /// Build a trainer: resolve programs, init params via the init
    /// program, zero the optimizer state, set up statics.
    pub fn new(
        engine: &'e dyn Executor,
        cfg: RunConfig,
        statics: Vec<(String, HostTensor)>,
        data: DataSource,
    ) -> Result<Trainer<'e>> {
        let train = engine
            .manifest()
            .find_train(&cfg.model, &cfg.method, &cfg.format)?
            .clone();
        let init = engine.manifest().find_init(&cfg.model)?.clone();
        let mut rng = Rng::new(cfg.seed);
        let state = state::init_train_state(engine, &train, &init, rng.jax_key())?;
        let statics: Vec<(String, Value)> =
            statics.into_iter().map(|(n, t)| (n, value(t))).collect();
        // validate statics against the manifest up front
        for s in train.input_specs(Role::Static) {
            if !statics.iter().any(|(n, _)| n == &s.name) {
                bail!("missing static input {:?} for {}", s.name, train.name);
            }
        }
        Ok(Trainer { engine, cfg, train, state, statics, data, rng, step: 0 })
    }

    pub fn steps_per_call(&self) -> usize {
        self.train.steps_per_call.max(1)
    }

    /// Assemble the positional argument list for one chunk call.
    fn build_args(&mut self) -> Result<Vec<Value>> {
        let k = self.steps_per_call();
        let mut args = Vec::with_capacity(self.train.inputs.len());
        let mut state_iter = self.state.values().iter();
        let lrs: Vec<f32> = (0..k).map(|i| self.cfg.lr_at(self.step + i) as f32).collect();
        for spec in self.train.inputs.clone() {
            let arg = match spec.role {
                Role::Param | Role::Opt => state_iter
                    .next()
                    .ok_or_else(|| anyhow!("state exhausted at {:?}", spec.name))?
                    .clone(),
                Role::Static => self
                    .statics
                    .iter()
                    .find(|(n, _)| n == &spec.name)
                    .map(|(_, v)| v.clone())
                    .ok_or_else(|| anyhow!("missing static {:?}", spec.name))?,
                Role::Data => match &mut self.data {
                    DataSource::Tokens(b) => value(b.train_chunk(k, &mut self.rng)),
                    DataSource::InGraph => bail!("{} wants data input", self.train.name),
                },
                Role::Key => {
                    let key = self.rng.jax_key();
                    value(HostTensor::from_u32(&[2], key.to_vec()))
                }
                Role::Scalar => match spec.name.as_str() {
                    "lrs" => value(HostTensor::from_f32(&[k], lrs.clone())),
                    "lam_reg" => value(HostTensor::scalar_f32(self.cfg.lambda as f32)),
                    other => bail!("unknown scalar input {other:?}"),
                },
                Role::Metric => bail!("metric role on an input"),
            };
            args.push(arg);
        }
        Ok(args)
    }

    /// Run one chunk (K steps). Returns (mean base loss, mean total loss).
    pub fn chunk(&mut self, metrics: &mut MetricsLogger) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let args = self.build_args()?;
        let mut out = self.engine.call(&self.train, &args)?;
        let n_metrics = 2; // base_losses, total_losses
        let metrics_start = out.len() - n_metrics;
        let totals = out[metrics_start + 1].as_f32();
        let bases = out[metrics_start].as_f32();
        out.truncate(metrics_start);
        self.state.adopt(&mut out)?;
        let k = self.steps_per_call();
        self.step += k;
        let base = bases.iter().map(|&v| v as f64).sum::<f64>() / bases.len() as f64;
        let total = totals.iter().map(|&v| v as f64).sum::<f64>() / totals.len() as f64;
        if !base.is_finite() {
            bail!("{}: loss diverged (nan/inf) at step {}", self.train.name, self.step);
        }
        metrics.log_train(self.step, base, total, self.cfg.lr_at(self.step), t0.elapsed().as_secs_f64());
        Ok((base, total))
    }

    /// Full run: chunks until `cfg.steps`, evaluating per `eval_every`.
    pub fn run(&mut self, eval: &mut Evaluator, metrics: &mut MetricsLogger) -> Result<()> {
        let mut next_eval = 0usize;
        while self.step < self.cfg.steps {
            if self.step >= next_eval {
                eval.eval_all(self, metrics)?;
                next_eval = self.step + self.cfg.eval_every.max(1);
            }
            self.chunk(metrics)?;
        }
        eval.eval_all(self, metrics)?;
        Ok(())
    }

    /// The quantized-subset tensor names (from the manifest).
    pub fn quantized_keys(&self) -> &[String] {
        &self.train.quantized
    }
}
