//! The chunked training loop: one backend call runs `steps_per_call`
//! optimizer steps (a `lax.scan` inside the PJRT artifact, an
//! interpreted loop in the native backend). The backend round-trip —
//! argument packing by role, metric splitting, state adoption — lives
//! in the run's [`Session`]; this loop owns what is *schedule-shaped*:
//! per-step LRs, the data stream, the run RNG and the step counter.

use crate::config::RunConfig;
use crate::data::TokenBatcher;
use crate::runtime::executor::{value, Executor};
use crate::runtime::session::{ChunkInputs, Session};
use crate::runtime::TrainState;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::time::Instant;

use super::evaluator::Evaluator;
use super::metrics::MetricsLogger;

/// Where per-step batches come from.
pub enum DataSource {
    /// synthetic tasks sample in-graph from the per-chunk key
    InGraph,
    /// token LM: host-side batcher supplies `[K, B, T+1]` chunks
    Tokens(TokenBatcher),
}

pub struct Trainer<'e> {
    /// the run's typed engine handle (entries + state + statics)
    pub session: Session<'e>,
    pub cfg: RunConfig,
    pub data: DataSource,
    pub rng: Rng,
    pub step: usize,
}

impl<'e> Trainer<'e> {
    /// Build a trainer: open a [`Session`] (resolve programs, init
    /// params via the init program, zero the optimizer state, validate
    /// statics) and seed the run RNG.
    pub fn new(
        engine: &'e dyn Executor,
        cfg: RunConfig,
        statics: Vec<(String, HostTensor)>,
        data: DataSource,
    ) -> Result<Trainer<'e>> {
        let mut rng = Rng::new(cfg.seed);
        let init_key = rng.jax_key();
        let session = Session::open(engine, &cfg, statics, init_key)?;
        Ok(Trainer { session, cfg, data, rng, step: 0 })
    }

    pub fn engine(&self) -> &'e dyn Executor {
        self.session.engine()
    }

    /// The run's named train state (params + optimizer tensors).
    pub fn state(&self) -> &TrainState {
        &self.session.state
    }

    pub fn steps_per_call(&self) -> usize {
        self.session.steps_per_call()
    }

    /// The quantized-subset tensor names (from the manifest).
    pub fn quantized_keys(&self) -> &[String] {
        self.session.quantized_keys()
    }

    /// Run one chunk (K steps). Returns (mean base loss, mean total loss).
    pub fn chunk(&mut self, metrics: &mut MetricsLogger) -> Result<(f64, f64)> {
        let t0 = Instant::now();
        let k = self.steps_per_call();
        let lrs: Vec<f32> = (0..k).map(|i| self.cfg.lr_at(self.step + i) as f32).collect();
        // RNG draw order is fixed (data chunk, then chunk key) so runs
        // stay bit-identical with the pre-Session trainer
        let data = if self.session.train_wants_data() {
            match &mut self.data {
                DataSource::Tokens(b) => Some(value(b.train_chunk(k, &mut self.rng))),
                DataSource::InGraph => {
                    bail!("{} wants a data input", self.session.train_entry().name)
                }
            }
        } else {
            None
        };
        let key = self.rng.jax_key();
        let out = self.session.train_chunk(ChunkInputs {
            lrs,
            lam_reg: self.cfg.lambda as f32,
            key,
            data,
        })?;
        self.step += k;
        let base = out.bases.iter().map(|&v| v as f64).sum::<f64>() / out.bases.len() as f64;
        let total = out.totals.iter().map(|&v| v as f64).sum::<f64>() / out.totals.len() as f64;
        if !base.is_finite() {
            bail!(
                "{}: loss diverged (nan/inf) at step {}",
                self.session.train_entry().name,
                self.step
            );
        }
        metrics.log_train(self.step, base, total, self.cfg.lr_at(self.step), t0.elapsed().as_secs_f64());
        Ok((base, total))
    }

    /// Full run: chunks until `cfg.steps`, evaluating per `eval_every`.
    pub fn run(&mut self, eval: &mut Evaluator, metrics: &mut MetricsLogger) -> Result<()> {
        let mut next_eval = 0usize;
        while self.step < self.cfg.steps {
            if self.step >= next_eval {
                eval.eval_all(self, metrics)?;
                next_eval = self.step + self.cfg.eval_every.max(1);
            }
            self.chunk(metrics)?;
        }
        eval.eval_all(self, metrics)?;
        Ok(())
    }
}
