//! Token batcher: random sliding windows over a token stream, shaped
//! `[K, B, T+1]` to feed one K-step scanned train call (inputs +
//! shifted targets share the buffer, hence T+1).

use crate::tensor::HostTensor;
use crate::util::rng::Rng;

pub struct TokenBatcher {
    tokens: Vec<i32>,
    pub batch: usize,
    pub seq_len: usize,
    /// train/val split point (windows are drawn strictly inside a split)
    split_at: usize,
}

impl TokenBatcher {
    /// `val_frac` of the tail is reserved for validation windows.
    pub fn new(tokens: Vec<i32>, batch: usize, seq_len: usize, val_frac: f64) -> Self {
        assert!(tokens.len() > (seq_len + 1) * 4, "corpus too small");
        let split_at = ((tokens.len() as f64) * (1.0 - val_frac)) as usize;
        TokenBatcher { tokens, batch, seq_len, split_at }
    }

    fn window(&self, start: usize) -> &[i32] {
        &self.tokens[start..start + self.seq_len + 1]
    }

    fn draw(&self, lo: usize, hi: usize, rng: &mut Rng) -> usize {
        lo + rng.below((hi - lo - self.seq_len - 1) as u64) as usize
    }

    /// `[K, B, T+1]` i32 tensor of training windows.
    pub fn train_chunk(&self, k: usize, rng: &mut Rng) -> HostTensor {
        self.chunk_in(0, self.split_at, k, rng)
    }

    /// `[K, B, T+1]` i32 tensor of validation windows.
    pub fn val_chunk(&self, k: usize, rng: &mut Rng) -> HostTensor {
        self.chunk_in(self.split_at, self.tokens.len(), k, rng)
    }

    fn chunk_in(&self, lo: usize, hi: usize, k: usize, rng: &mut Rng) -> HostTensor {
        let t1 = self.seq_len + 1;
        let mut data = Vec::with_capacity(k * self.batch * t1);
        for _ in 0..k {
            for _ in 0..self.batch {
                let start = self.draw(lo, hi, rng);
                data.extend_from_slice(self.window(start));
            }
        }
        HostTensor::from_i32(&[k, self.batch, t1], data)
    }

    pub fn n_tokens(&self) -> usize {
        self.tokens.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{ByteTokenizer, ZipfMarkovCorpus};

    fn batcher() -> TokenBatcher {
        let corpus = ZipfMarkovCorpus::generate(50_000, 256, 4, 0);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        TokenBatcher::new(toks, 4, 32, 0.1)
    }

    #[test]
    fn shapes_and_dtypes() {
        let b = batcher();
        let mut rng = Rng::new(0);
        let c = b.train_chunk(3, &mut rng);
        assert_eq!(c.shape, vec![3, 4, 33]);
        assert_eq!(c.len(), 3 * 4 * 33);
    }

    #[test]
    fn windows_are_contiguous_corpus_slices() {
        let b = batcher();
        let mut rng = Rng::new(1);
        let c = b.train_chunk(1, &mut rng);
        let vals = c.as_i32();
        // each row must appear verbatim in the corpus
        let corpus: Vec<i32> = b.tokens.clone();
        let row = &vals[..33];
        assert!(corpus.windows(33).any(|w| w == row));
    }

    #[test]
    fn train_and_val_splits_disjoint() {
        let b = batcher();
        let mut rng = Rng::new(2);
        // all val window starts >= split; all train window ends < split+T
        for _ in 0..20 {
            let v = b.val_chunk(1, &mut rng);
            let t = b.train_chunk(1, &mut rng);
            assert_eq!(v.shape[2], 33);
            assert_eq!(t.shape[2], 33);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let b = batcher();
        let c1 = b.train_chunk(2, &mut Rng::new(5));
        let c2 = b.train_chunk(2, &mut Rng::new(5));
        assert_eq!(c1.as_i32(), c2.as_i32());
    }
}
