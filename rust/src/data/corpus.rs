//! Zipf–Markov synthetic corpus — the offline stand-in for C4
//! (DESIGN.md §6). A first-order Markov chain over a Zipfian "word"
//! vocabulary rendered to bytes. The chain gives real sequential
//! structure (so an LM has something to learn and validation loss
//! separates methods), while staying fully deterministic from a seed.

use crate::util::rng::Rng;

pub struct ZipfMarkovCorpus {
    /// rendered byte stream
    pub bytes: Vec<u8>,
}

/// Sample a Zipf(s)-distributed rank in [0, n) via inverse CDF.
fn zipf_sample(cdf: &[f64], rng: &mut Rng) -> usize {
    let u = rng.uniform();
    match cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
        Ok(i) => i,
        Err(i) => i.min(cdf.len() - 1),
    }
}

impl ZipfMarkovCorpus {
    /// Generate `n_bytes` of text: `vocab` synthetic words with Zipfian
    /// unigram frequencies, chained by a per-word sparse transition
    /// table (each word prefers `branch` successors), space-separated,
    /// sentence breaks every ~16 words.
    pub fn generate(n_bytes: usize, vocab: usize, branch: usize, seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        // synthetic word strings: 2-8 lowercase letters, deterministic
        let words: Vec<Vec<u8>> = (0..vocab)
            .map(|_| {
                let len = 2 + rng.below(7) as usize;
                (0..len).map(|_| b'a' + rng.below(26) as u8).collect()
            })
            .collect();
        // Zipf CDF over ranks
        let s = 1.1;
        let mut weights: Vec<f64> = (1..=vocab).map(|i| 1.0 / (i as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        for w in weights.iter_mut() {
            acc += *w / total;
            *w = acc;
        }
        let cdf = weights;
        // sparse successor table: word -> `branch` candidate next-words
        let succ: Vec<Vec<usize>> = (0..vocab)
            .map(|_| (0..branch).map(|_| zipf_sample(&cdf, &mut rng)).collect())
            .collect();

        let mut bytes = Vec::with_capacity(n_bytes + 16);
        let mut cur = zipf_sample(&cdf, &mut rng);
        let mut words_in_sentence = 0;
        while bytes.len() < n_bytes {
            bytes.extend_from_slice(&words[cur]);
            words_in_sentence += 1;
            if words_in_sentence >= 8 + rng.below(16) as usize {
                bytes.extend_from_slice(b". ");
                words_in_sentence = 0;
                cur = zipf_sample(&cdf, &mut rng);
            } else {
                bytes.push(b' ');
                // mostly follow the chain; occasionally re-draw globally
                cur = if rng.uniform() < 0.85 {
                    succ[cur][rng.below(branch as u64) as usize]
                } else {
                    zipf_sample(&cdf, &mut rng)
                };
            }
        }
        bytes.truncate(n_bytes);
        ZipfMarkovCorpus { bytes }
    }

    pub fn len(&self) -> usize {
        self.bytes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.bytes.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_sized() {
        let a = ZipfMarkovCorpus::generate(10_000, 512, 4, 7);
        let b = ZipfMarkovCorpus::generate(10_000, 512, 4, 7);
        assert_eq!(a.bytes, b.bytes);
        assert_eq!(a.len(), 10_000);
        let c = ZipfMarkovCorpus::generate(10_000, 512, 4, 8);
        assert_ne!(a.bytes, c.bytes);
    }

    #[test]
    fn is_ascii_text() {
        let c = ZipfMarkovCorpus::generate(5_000, 256, 4, 1);
        assert!(c.bytes.iter().all(|&b| b.is_ascii_lowercase() || b == b' ' || b == b'.'));
    }

    #[test]
    fn has_markov_structure() {
        // bigram entropy must be well below unigram entropy — i.e. the
        // chain is learnable, which is what the LM experiments rely on.
        let c = ZipfMarkovCorpus::generate(200_000, 256, 4, 3);
        let mut uni = [0f64; 256];
        let mut bi = std::collections::HashMap::new();
        for w in c.bytes.windows(2) {
            uni[w[0] as usize] += 1.0;
            *bi.entry((w[0], w[1])).or_insert(0f64) += 1.0;
        }
        let n = (c.len() - 1) as f64;
        let h1: f64 = uni.iter().filter(|&&c| c > 0.0).map(|&c| -(c / n) * (c / n).log2()).sum();
        let h2joint: f64 = bi.values().map(|&c| -(c / n) * (c / n).log2()).sum();
        let h_cond = h2joint - h1;
        assert!(h_cond < h1 * 0.85, "h1={h1:.3} h_cond={h_cond:.3}");
    }
}
