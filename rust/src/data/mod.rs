//! Data pipeline substrates: synthetic regression streams (§4.1/§4.2),
//! a Zipf–Markov synthetic corpus + byte tokenizer for the LM
//! experiments (the paper's C4 corpus is substituted per DESIGN.md §6),
//! and the token batcher feeding the scanned train programs.

pub mod batcher;
pub mod corpus;
pub mod synth;
pub mod tokenizer;

pub use batcher::TokenBatcher;
pub use corpus::ZipfMarkovCorpus;
pub use synth::{power_law_spectrum, sample_wstar};
pub use tokenizer::ByteTokenizer;
