//! Synthetic regression problem generators (§4.1, §4.2).
//!
//! The covariance spectrum `lam_i ∝ 1/i^alpha` mimics the Hessian
//! spectra of modern networks; targets come from a Gaussian `w*`. The
//! scanned train programs *sample minibatches in-graph* from a PJRT
//! key, so the host side only supplies `lam`, `w*` and seeds.

use crate::util::rng::Rng;

/// `lam_i = 1 / i^alpha`, i = 1..=d (paper: alpha = 1.1).
pub fn power_law_spectrum(d: usize, alpha: f64) -> Vec<f32> {
    (1..=d).map(|i| (1.0 / (i as f64).powf(alpha)) as f32).collect()
}

/// Gaussian ground-truth regressor `w* ~ N(0, I)`.
pub fn sample_wstar(d: usize, rng: &mut Rng) -> Vec<f32> {
    let mut w = vec![0f32; d];
    rng.fill_normal(&mut w);
    w
}

/// Exact population loss `1/2 (w - w*)^T diag(lam) (w - w*)` — the same
/// closed form the eval artifact computes; used for host-side
/// cross-checks and the Fig. 6 sweep.
pub fn population_loss(w: &[f32], wstar: &[f32], lam: &[f32]) -> f64 {
    w.iter()
        .zip(wstar)
        .zip(lam)
        .map(|((w, ws), l)| {
            let d = (*w - *ws) as f64;
            0.5 * (*l as f64) * d * d
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spectrum_values() {
        let lam = power_law_spectrum(100, 1.1);
        assert_eq!(lam[0], 1.0);
        assert!((lam[9] as f64 - 10f64.powf(-1.1)).abs() < 1e-6);
        assert!(lam.windows(2).all(|w| w[1] < w[0]));
    }

    #[test]
    fn loss_zero_at_optimum() {
        let mut rng = Rng::new(0);
        let ws = sample_wstar(32, &mut rng);
        let lam = power_law_spectrum(32, 1.1);
        assert_eq!(population_loss(&ws, &ws, &lam), 0.0);
        let zeros = vec![0f32; 32];
        assert!(population_loss(&zeros, &ws, &lam) > 0.0);
    }
}
