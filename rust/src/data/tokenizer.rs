//! Byte-level tokenizer: identity over bytes (vocab 256), plus corpus
//! statistics. The LM presets use vocab=256, so token ids == bytes;
//! the type exists to give the pipeline a seam where a learned
//! subword vocabulary would slot in.

#[derive(Clone, Debug)]
pub struct ByteTokenizer {
    pub vocab_size: usize,
}

impl Default for ByteTokenizer {
    fn default() -> Self {
        ByteTokenizer { vocab_size: 256 }
    }
}

impl ByteTokenizer {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn encode(&self, text: &[u8]) -> Vec<i32> {
        text.iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> Vec<u8> {
        tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect()
    }

    /// Unigram distribution over the corpus (used by tests and the
    /// data-quality report).
    pub fn unigram_counts(&self, text: &[u8]) -> Vec<u64> {
        let mut counts = vec![0u64; self.vocab_size];
        for &b in text {
            counts[b as usize] += 1;
        }
        counts
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let t = ByteTokenizer::new();
        let text = b"hello world.";
        assert_eq!(t.decode(&t.encode(text)), text);
    }

    #[test]
    fn tokens_in_vocab() {
        let t = ByteTokenizer::new();
        for tok in t.encode(b"anything at all \xff\x00") {
            assert!((0..256).contains(&tok));
        }
    }

    #[test]
    fn unigram_counts_sum() {
        let t = ByteTokenizer::new();
        let counts = t.unigram_counts(b"aab");
        assert_eq!(counts[b'a' as usize], 2);
        assert_eq!(counts.iter().sum::<u64>(), 3);
    }
}
