//! Ablation: shared-scale block size (§2.1's "fine-grained" design
//! axis). Trains one FP32 linreg model, then measures quantized val
//! loss casting the same checkpoint with per-tensor vs progressively
//! finer block scales, across formats and roundings.
//!
//! The paper's experiments use per-tensor scales; this ablation
//! quantifies what fine-grained blocks buy (smaller blocks → smaller
//! absmax per block → lower RR variance s_B^2 Δ(1-Δ)).

use crate::config::{RunConfig, Schedule};
use crate::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use crate::formats::csv::CsvWriter;
use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::Executor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

use super::common::{scaled, synth_statics};

const D: usize = 12000;
const BLOCKS: [usize; 5] = [0, 1024, 256, 64, 16];

pub fn run(engine: &dyn Executor, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    // one FP32 training run (PTQ-style master weights)
    let mut cfg = RunConfig::default();
    cfg.name = "ablation_base".into();
    cfg.model = format!("linreg_d{D}");
    cfg.method = "ptq".into();
    cfg.format = "none".into();
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = scaled(1500);
    cfg.lr = 0.6;
    cfg.eval_every = cfg.steps;
    cfg.schedule = Schedule::Cosine { warmup: 0, final_frac: 0.05 };
    let (statics, _, _) = synth_statics(D, 42);
    let mut trainer = Trainer::new(engine, cfg.clone(), statics, DataSource::InGraph)?;
    let mut eval = Evaluator::new(0);
    let mut metrics = MetricsLogger::in_memory();
    trainer.run(&mut eval, &mut metrics)?;
    let fp32 = metrics.final_eval("fp32", "none").unwrap_or(f64::NAN);
    crate::info!("ablation base fp32 val loss: {fp32:.5}");

    // cast the same weights at every (format, block, rounding)
    let w = trainer.state().fetch("w")?.as_f32();
    let mut csv = CsvWriter::create(
        &out_dir.join("ablation_blocks.csv"),
        &["format", "block_size", "rounding", "val_loss", "fp32_val_loss"],
    )?;
    let mut rng = Rng::new(7);
    for fmt_name in ["int4", "int8", "fp4"] {
        for &bs in &BLOCKS {
            let fmt = QuantFormat::parse(fmt_name, bs)?;
            for r in [Rounding::Rtn, Rounding::Rr] {
                let mut wq = w.clone();
                cast(&mut wq, &fmt, r, &mut rng);
                trainer
                    .session
                    .state
                    .replace("w", &crate::tensor::HostTensor::from_f32(&[D], wq))?;
                let loss = eval.eval_cast(&trainer, None, Rounding::Rtn)?;
                csv.row(&[
                    fmt_name.into(),
                    bs.to_string(),
                    r.name().into(),
                    format!("{loss:.6}"),
                    format!("{fp32:.6}"),
                ])?;
                crate::info!("  {fmt_name} block={bs} {}: {loss:.5}", r.name());
            }
        }
        // restore master weights for the next format
        trainer
            .session
            .state
            .replace("w", &crate::tensor::HostTensor::from_f32(&[D], w.clone()))?;
    }
    Ok(())
}
