//! Shared experiment machinery: the experiment context (engine +
//! factory + sweep width), single-run helpers, loss-curve CSV dumps,
//! and paper-style summary tables.

use crate::config::RunConfig;
use crate::coordinator::sweep::SweepRunner;
use crate::coordinator::{DataSource, Evaluator, MetricsLogger, Trainer};
use crate::data::{power_law_spectrum, sample_wstar};
use crate::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use crate::formats::csv::CsvWriter;
use crate::info;
use crate::runtime::{Executor, ExecutorFactory, Role};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;

/// What an experiment regenerator runs against: a borrowed engine for
/// serial/manifest work, a factory + worker count for sharding its run
/// grid across thread-owned engines. `sweep_workers` follows the
/// `--sweep-workers` / `LOTION_SWEEP_WORKERS` / serial precedence
/// (resolved inside [`SweepRunner::new`]), and sharded results are
/// bit-identical to serial at any width.
pub struct ExpCtx<'a> {
    pub engine: &'a dyn Executor,
    pub factory: &'a dyn ExecutorFactory,
    pub sweep_workers: usize,
}

impl<'a> ExpCtx<'a> {
    /// A serial context (tests / embedders without a sharding knob).
    pub fn serial(engine: &'a dyn Executor, factory: &'a dyn ExecutorFactory) -> ExpCtx<'a> {
        ExpCtx { engine, factory, sweep_workers: 1 }
    }

    /// The sharded grid runner for this context's width. The serial
    /// path reuses the context engine (warm scratch, populated timing
    /// report for the `exp` profile dump); sharded runs spawn
    /// per-worker engines from the factory.
    pub fn runner(&self) -> SweepRunner<'a> {
        SweepRunner::new(self.factory, self.sweep_workers).with_serial_engine(self.engine)
    }
}

/// Run one (method, format) training run and return its metrics.
/// `label` names the CSV rows + jsonl file.
pub fn run_method(
    engine: &dyn Executor,
    cfg: &RunConfig,
    statics: Vec<(String, HostTensor)>,
    data: DataSource,
    out_dir: &Path,
    label: &str,
) -> Result<MetricsLogger> {
    let mut metrics = MetricsLogger::to_file(&out_dir.join(format!("{label}.jsonl")))?;
    let mut trainer = Trainer::new(engine, cfg.clone(), statics, data)?;
    let mut eval = Evaluator::new(cfg.seed);
    let t0 = std::time::Instant::now();
    trainer.run(&mut eval, &mut metrics)?;
    info!(
        "[{label}] {} steps in {:.1}s; final fp32={:.4}",
        trainer.step,
        t0.elapsed().as_secs_f64(),
        metrics.final_eval("fp32", "none").unwrap_or(f64::NAN)
    );
    Ok(metrics)
}

/// Build the data source a model needs (token batcher for LMs,
/// in-graph sampling for the synthetic tasks) plus synthetic statics.
/// Shared by `cmd_train`, the generic sweep paths (`--lrs` and
/// `--spec`), and the `.sweep`-file experiment ids, so a config sweeps
/// to the same inputs no matter which door it came in through.
pub fn build_inputs(
    engine: &dyn Executor,
    cfg: &RunConfig,
    corpus_seed: u64,
) -> Result<(Vec<(String, HostTensor)>, DataSource)> {
    let train = engine.manifest().find_train(&cfg.model, &cfg.method, &cfg.format)?;
    let wants_data = train.inputs.iter().any(|s| s.role == Role::Data);
    let wants_statics = train.inputs.iter().any(|s| s.role == Role::Static);
    if wants_data {
        let data = train
            .inputs
            .iter()
            .find(|s| s.role == Role::Data)
            .expect("data spec");
        let (batch, t1) = (data.shape[1], data.shape[2]);
        let corpus = ZipfMarkovCorpus::generate(2_000_000, 2048, 4, corpus_seed);
        let toks = ByteTokenizer::new().encode(&corpus.bytes);
        Ok((vec![], DataSource::Tokens(TokenBatcher::new(toks, batch, t1 - 1, 0.05))))
    } else if wants_statics {
        let d = train
            .inputs
            .iter()
            .find(|s| s.name == "lam")
            .map(|s| s.shape[0])
            .context("no lam static")?;
        let (statics, _, _) = synth_statics(d, 42);
        Ok((statics, DataSource::InGraph))
    } else {
        Ok((vec![], DataSource::InGraph))
    }
}

/// Statics for the synthetic tasks: (lam, wstar) plus the raw vectors
/// for host-side baselines.
pub fn synth_statics(d: usize, seed: u64) -> (Vec<(String, HostTensor)>, Vec<f32>, Vec<f32>) {
    let mut rng = Rng::new(seed);
    let lam = power_law_spectrum(d, 1.1);
    let wstar = sample_wstar(d, &mut rng);
    let statics = vec![
        ("lam".to_string(), HostTensor::from_f32(&[d], lam.clone())),
        ("wstar".to_string(), HostTensor::from_f32(&[d], wstar.clone())),
    ];
    (statics, lam, wstar)
}

/// Write all eval curves from a set of labelled runs into one CSV:
/// label,step,format,rounding,val_loss
pub fn write_curves(out_dir: &Path, runs: &[(String, &MetricsLogger)]) -> Result<()> {
    let mut w = CsvWriter::create(
        &out_dir.join("curves.csv"),
        &["run", "step", "format", "rounding", "val_loss"],
    )?;
    for (label, m) in runs {
        for p in &m.eval_points {
            w.row(&[
                label.clone(),
                p.step.to_string(),
                p.format.clone(),
                p.rounding.clone(),
                format!("{:.6}", p.val_loss),
            ])?;
        }
    }
    Ok(())
}

/// A final-loss table row.
#[derive(Clone, Debug)]
pub struct TableRow {
    pub method: String,
    pub metric: String, // rounding label ("RTN"/"RR")
    pub format: String,
    pub val_loss: f64,
}

/// Render rows as an aligned paper-style table and write table.csv.
pub fn write_table(out_dir: &Path, title: &str, rows: &[TableRow]) -> Result<String> {
    let mut w = CsvWriter::create(
        &out_dir.join("table.csv"),
        &["method", "metric", "format", "val_loss"],
    )?;
    let mut sorted = rows.to_vec();
    sorted.sort_by(|a, b| a.val_loss.partial_cmp(&b.val_loss).unwrap());
    let mut text = format!("\n== {title} ==\n{:<16} {:<8} {:<8} {:>12}\n", "Method", "Metric", "Format", "Val. loss");
    for r in &sorted {
        w.row(&[
            r.method.clone(),
            r.metric.clone(),
            r.format.clone(),
            format!("{:.6}", r.val_loss),
        ])?;
        text.push_str(&format!(
            "{:<16} {:<8} {:<8} {:>12.5}\n",
            r.method, r.metric, r.format, r.val_loss
        ));
    }
    println!("{text}");
    std::fs::write(out_dir.join("table.txt"), &text)?;
    Ok(text)
}

/// Environment-tunable step budget so `exp all` can be scaled to the
/// testbed: LOTION_EXP_SCALE=0.25 quarters every run length.
pub fn scaled(steps: usize) -> usize {
    let scale: f64 = std::env::var("LOTION_EXP_SCALE")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1.0);
    ((steps as f64 * scale) as usize).max(16)
}
