//! Estimator-family experiments riding the sharded [`SweepRunner`]
//! (DESIGN.md §9): the two method families the pluggable estimator
//! layer adds beyond the paper's four.
//!
//! * `est-equiv` — the LR-rescaling equivalence of Schoenbauer et al.
//!   ("Custom Gradient Estimators are Straight-Through Estimators in
//!   Disguise"): on an SGD task, a custom gradient estimator that
//!   scales the quantized subset's gradients by a constant `c` is the
//!   same algorithm as plain QAT at learning rate `c·lr`. The
//!   experiment trains `cge(lr, c)` next to `qat(c·lr)` for several
//!   `c` on identical data/init streams and tabulates the deviation of
//!   their final quantized val losses — near-zero (f32 rounding only),
//!   which is the paper's point.
//! * `anneal` — additive noise annealing (Spallanzani et al.): QAT
//!   next to `anneal` at several σ₀ and σ→0 schedule shapes on the
//!   tiny LM, with the usual curves + final-loss table. Its grid is
//!   `examples/anneal.sweep`, expanded through the sweep-spec DSL.
//!
//! Both run as one sweep grid each, so `--sweep-workers N` trains the
//! legs concurrently on factory-spawned engines, bit-identical to the
//! serial pass at any width.
//!
//! [`SweepRunner`]: crate::coordinator::sweep::SweepRunner

use crate::config::{RunConfig, Schedule};
use crate::coordinator::sweep::SweepPoint;
use crate::coordinator::{DataSource, MetricsLogger};
use crate::formats::csv::CsvWriter;
use crate::runtime::native::estimator::EstSchedule;
use crate::runtime::Executor;
use crate::tensor::HostTensor;
use anyhow::{anyhow, Result};
use std::path::Path;

use super::common::{scaled, synth_statics, write_curves, write_table, ExpCtx, TableRow};
use super::lm_exps::make_batcher;

/// Gradient scales for the equivalence grid; 1.0 is covered by the
/// shared QAT baseline.
const EQUIV_SCALES: [f64; 2] = [0.5, 2.0];

/// `est-equiv` leg config: SGD linreg (the equivalence argument is an
/// SGD identity; Adam's normalizer breaks it, which `exp anneal`'s LM
/// legs do not rely on). Constant LR schedule, so `qat(c·lr)` scales
/// every per-step LR exactly.
fn equiv_cfg(label: &str, method: &str, lr: f64, grad_scale: f64, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("est_equiv_{label}");
    cfg.model = "linreg_d256".into();
    cfg.method = method.into();
    cfg.format = "int4".into();
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.lambda = 1.0;
    cfg.eval_every = (steps / 8).max(8);
    cfg.schedule = Schedule::Constant;
    cfg.seed = 17;
    cfg.est_schedule = EstSchedule::Constant;
    cfg.est_grad_scale = grad_scale;
    cfg
}

/// Schoenbauer et al.'s equivalence, measured: `cge(lr, c)` vs
/// `qat(c·lr)` for each `c`, plus the shared QAT baseline.
pub fn run_equiv(ctx: &ExpCtx<'_>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(240);
    let lr = 0.05;
    let mut points = vec![SweepPoint::new("qat_base", equiv_cfg("qat_base", "qat", lr, 1.0, steps))
        .with_metrics_path(out_dir.join("qat_base.jsonl"))];
    for &c in &EQUIV_SCALES {
        for (label, method, lr, scale) in [
            (format!("cge_c{c}"), "cge", lr, c),
            (format!("qat_lr_x{c}"), "qat", lr * c, 1.0),
        ] {
            points.push(
                SweepPoint::new(label.clone(), equiv_cfg(&label, method, lr, scale, steps))
                    .with_metrics_path(out_dir.join(format!("{label}.jsonl"))),
            );
        }
    }
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let (statics, _, _) = synth_statics(256, 42);
        Ok((statics, DataSource::InGraph))
    };
    let results = ctx.runner().run(points, "int4", "rtn", &inputs)?;
    let loss_of = |label: &str| -> Result<f64> {
        results
            .iter()
            .find(|r| r.label == label && !r.diverged)
            .and_then(|r| r.metrics.final_eval("int4", "rtn"))
            .ok_or_else(|| anyhow!("equivalence leg {label:?} produced no final eval"))
    };

    // the equivalence table: one row per c, with the relative deviation
    // between the two runs that the Schoenbauer argument says coincide
    let mut w = CsvWriter::create(
        &out_dir.join("equiv.csv"),
        &["grad_scale", "cge_loss", "qat_rescaled_loss", "rel_deviation"],
    )?;
    let mut text = format!(
        "\n== est-equiv — cge(lr, c) vs qat(c*lr), linreg_d256/int4 ==\n\
         {:<12} {:>14} {:>18} {:>14}\n",
        "grad_scale", "cge loss", "qat(c*lr) loss", "rel. dev."
    );
    for &c in &EQUIV_SCALES {
        let (a, b) = (loss_of(&format!("cge_c{c}"))?, loss_of(&format!("qat_lr_x{c}"))?);
        let dev = (a - b).abs() / b.abs().max(f64::MIN_POSITIVE);
        w.row(&[format!("{c}"), format!("{a:.8}"), format!("{b:.8}"), format!("{dev:.3e}")])?;
        text.push_str(&format!("{c:<12} {a:>14.6} {b:>18.6} {dev:>14.3e}\n"));
    }
    text.push_str(&format!("(qat baseline at lr={lr}: {:.6})\n", loss_of("qat_base")?));
    println!("{text}");
    std::fs::write(out_dir.join("equiv.txt"), &text)?;

    let labelled: Vec<(String, &MetricsLogger)> =
        results.iter().map(|r| (r.label.clone(), &r.metrics)).collect();
    write_curves(out_dir, &labelled)?;
    Ok(())
}

/// The anneal grid definition — `exp anneal` expands this embedded
/// spec (σ₀ × schedule-shape legs against the QAT baseline) through
/// the sweep-spec DSL (DESIGN.md §10).
pub const ANNEAL_SPEC: &str = include_str!("../../../examples/anneal.sweep");

/// Additive-noise-annealing on the tiny LM: σ₀ × schedule-shape grid
/// against the QAT baseline (σ ≡ 0), identical data/init streams.
pub fn run_anneal(ctx: &ExpCtx<'_>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(96);
    let models = ctx.factory.model_names();
    let plan = crate::spec::plan(
        ANNEAL_SPEC,
        "examples/anneal.sweep",
        &RunConfig::default(),
        models.as_deref(),
    )?;
    let mut points = plan.points;
    for p in &mut points {
        // the spec pins the nominal budget; `exp` runs rescale it
        p.cfg.steps = steps;
        p.cfg.eval_every = (steps / 8).max(8);
        p.cfg.schedule = Schedule::Cosine { warmup: steps / 20, final_frac: 0.1 };
        p.metrics_path = Some(out_dir.join(format!("{}.jsonl", p.label)));
    }
    let inputs = |engine: &dyn Executor,
                  cfg: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        Ok((vec![], DataSource::Tokens(make_batcher(&cfg.model, engine)?)))
    };
    let results =
        ctx.runner().run(points, &plan.score_format, &plan.score_rounding, &inputs)?;

    let mut rows: Vec<TableRow> = Vec::new();
    let mut labelled: Vec<(String, &MetricsLogger)> = Vec::new();
    for r in &results {
        if r.diverged {
            crate::warn_!("[{}] failed; omitting from curves/table", r.label);
            continue;
        }
        for ro in ["rtn", "rr"] {
            if let Some(v) = r.metrics.final_eval("int4", ro) {
                rows.push(TableRow {
                    method: r.label.clone(),
                    metric: ro.to_uppercase(),
                    format: "int4".into(),
                    val_loss: v,
                });
            }
        }
        labelled.push((r.label.clone(), &r.metrics));
    }
    write_curves(out_dir, &labelled)?;
    let title = "anneal — lm-tiny σ→0 annealing vs QAT, final quantized val CE";
    write_table(out_dir, title, &rows)?;
    Ok(())
}
