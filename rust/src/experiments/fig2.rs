//! Figs. 2 & 7 (§4.1): INT4 linear regression, d = 12000, power-law
//! spectrum. Compares LOTION / QAT / RAT / PTQ on quantized validation
//! loss under RTN and RR casts, plus the paper's "quantized w*" PTQ
//! oracle rows. Fig. 2 is the best-variant view of the Fig. 7 table.
//!
//! The per-method LR grid lives in `examples/fig2.sweep` (embedded at
//! compile time) and expands through the sweep-spec DSL (DESIGN.md
//! §10) into the same sharded `SweepRunner` every spec-driven sweep
//! uses: with `--sweep-workers N` the grid points train on N
//! factory-spawned engines, bit-identical to the serial pass.

use crate::config::RunConfig;
use crate::coordinator::sweep::SweepResult;
use crate::coordinator::DataSource;
use crate::data::synth::population_loss;
use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::Executor;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

use super::common::{scaled, synth_statics, write_curves, write_table, ExpCtx, TableRow};

const D: usize = 12000;

/// The grid definition — living documentation as well as the actual
/// source `exp fig2` expands.
pub const SPEC: &str = include_str!("../../../examples/fig2.sweep");

/// Spec axis order, for draining per-method blocks from the
/// method-major result vector.
const METHODS: [&str; 4] = ["lotion", "qat", "rat", "ptq"];

/// The figure's selection score: best final quantized loss over both
/// roundings (the run_point score covers one rounding only).
fn rtn_rr_score(r: &SweepResult) -> f64 {
    ["rtn", "rr"]
        .iter()
        .filter_map(|ro| r.metrics.final_eval("int4", ro))
        .fold(f64::INFINITY, f64::min)
}

pub fn run(ctx: &ExpCtx<'_>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(3000);
    let fmt = QuantFormat::int4();
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let (statics, _, _) = synth_statics(D, 42);
        Ok((statics, DataSource::InGraph))
    };

    // One combined (method x lr) grid — a single sharded sweep, so at
    // `--sweep-workers N` all 8 runs are in flight, not 2 per method.
    let models = ctx.factory.model_names();
    let plan = crate::spec::plan(
        SPEC,
        "examples/fig2.sweep",
        &RunConfig::default(),
        models.as_deref(),
    )?;
    let per_method = plan.points.len() / METHODS.len();
    let mut points = plan.points;
    for p in &mut points {
        // the spec pins the paper's full budget; `exp` runs rescale it
        p.cfg.steps = steps;
        p.cfg.eval_every = (steps / 12).max(16);
        p.metrics_path = Some(out_dir.join(format!("{}.jsonl", p.label)));
    }
    let mut results =
        ctx.runner().run(points, &plan.score_format, &plan.score_rounding, &inputs)?;

    let mut rows: Vec<TableRow> = Vec::new();
    let mut all_runs: Vec<(String, SweepResult)> = Vec::new();
    for method in METHODS {
        // grid order is method-major: drain this method's lr block
        let block: Vec<SweepResult> = results.drain(..per_method).collect();
        debug_assert!(block.iter().all(|r| r.label.starts_with(method)));
        let best = block
            .into_iter()
            .reduce(|a, b| if rtn_rr_score(&b) < rtn_rr_score(&a) { b } else { a })
            .expect("non-empty lr grid");
        for r in ["rtn", "rr"] {
            if let Some(v) = best.metrics.final_eval("int4", r) {
                rows.push(TableRow {
                    method: method.to_uppercase(),
                    metric: r.to_uppercase(),
                    format: "int4".into(),
                    val_loss: v,
                });
            }
        }
        all_runs.push((method.to_string(), best));
    }

    // PTQ oracle rows: quantize the *target* w* directly (§4.1: "Our PTQ
    // baselines are obtained by quantizing the target w* via RTN/RR").
    let (_, lam, wstar) = synth_statics(D, 42);
    let mut rng = Rng::new(1234);
    for (r, name) in [(Rounding::Rtn, "RTN"), (Rounding::Rr, "RR")] {
        let mut wq = wstar.clone();
        cast(&mut wq, &fmt, r, &mut rng);
        rows.push(TableRow {
            method: "PTQ(w*)".into(),
            metric: name.into(),
            format: "int4".into(),
            val_loss: population_loss(&wq, &wstar, &lam),
        });
    }

    let refs: Vec<(String, &crate::coordinator::MetricsLogger)> =
        all_runs.iter().map(|(l, r)| (l.clone(), &r.metrics)).collect();
    write_curves(out_dir, &refs)?;
    write_table(out_dir, "Fig. 2 / Fig. 7 — INT4 linreg final quantized val loss", &rows)?;
    Ok(())
}
