//! Figs. 2 & 7 (§4.1): INT4 linear regression, d = 12000, power-law
//! spectrum. Compares LOTION / QAT / RAT / PTQ on quantized validation
//! loss under RTN and RR casts, plus the paper's "quantized w*" PTQ
//! oracle rows. Fig. 2 is the best-variant view of the Fig. 7 table.
//!
//! The per-method LR grid (the paper's best-over-App.-A.5 protocol)
//! runs through the sharded `SweepRunner`: with `--sweep-workers N`
//! the grid points train on N factory-spawned engines, bit-identical
//! to the serial pass.

use crate::config::{RunConfig, Schedule};
use crate::coordinator::sweep::{SweepPoint, SweepResult};
use crate::coordinator::DataSource;
use crate::data::synth::population_loss;
use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::Executor;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

use super::common::{scaled, synth_statics, write_curves, write_table, ExpCtx, TableRow};

const D: usize = 12000;

fn cfg_for(method: &str, lr: f64, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("fig2_{method}");
    cfg.model = format!("linreg_d{D}");
    cfg.method = method.into();
    cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.lambda = 1.0; // exact GN diagonal => Eq. 3 is parameter-free here
    cfg.eval_every = (steps / 12).max(16);
    cfg.schedule = Schedule::Cosine { warmup: 0, final_frac: 0.05 };
    cfg
}

/// The figure's selection score: best final quantized loss over both
/// roundings (the run_point score covers one rounding only).
fn rtn_rr_score(r: &SweepResult) -> f64 {
    ["rtn", "rr"]
        .iter()
        .filter_map(|ro| r.metrics.final_eval("int4", ro))
        .fold(f64::INFINITY, f64::min)
}

pub fn run(ctx: &ExpCtx<'_>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(3000);
    // Small per-method LR grid (the paper sweeps App. A.5 and reports
    // the best run per method; same protocol, smaller grid).
    let lr_grid: &[f64] = &[0.3, 0.6];
    let fmt = QuantFormat::int4();
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let (statics, _, _) = synth_statics(D, 42);
        Ok((statics, DataSource::InGraph))
    };

    // One combined (method x lr) grid — a single sharded sweep, so at
    // `--sweep-workers N` all 8 runs are in flight, not 2 per method.
    const METHODS: [&str; 4] = ["lotion", "qat", "rat", "ptq"];
    let points: Vec<SweepPoint> = METHODS
        .iter()
        .flat_map(|&method| lr_grid.iter().map(move |&lr| (method, lr)))
        .map(|(method, lr)| {
            let label = format!("{method}_lr{lr}");
            SweepPoint::new(label.clone(), cfg_for(method, lr, steps))
                .with_metrics_path(out_dir.join(format!("{label}.jsonl")))
        })
        .collect();
    let mut results = ctx.runner().run(points, "int4", "rtn", &inputs)?;

    let mut rows: Vec<TableRow> = Vec::new();
    let mut all_runs: Vec<(String, SweepResult)> = Vec::new();
    for method in METHODS {
        // grid order is method-major: drain this method's lr block
        let block: Vec<SweepResult> = results.drain(..lr_grid.len()).collect();
        let best = block
            .into_iter()
            .reduce(|a, b| if rtn_rr_score(&b) < rtn_rr_score(&a) { b } else { a })
            .expect("non-empty lr grid");
        for r in ["rtn", "rr"] {
            if let Some(v) = best.metrics.final_eval("int4", r) {
                rows.push(TableRow {
                    method: method.to_uppercase(),
                    metric: r.to_uppercase(),
                    format: "int4".into(),
                    val_loss: v,
                });
            }
        }
        all_runs.push((method.to_string(), best));
    }

    // PTQ oracle rows: quantize the *target* w* directly (§4.1: "Our PTQ
    // baselines are obtained by quantizing the target w* via RTN/RR").
    let (_, lam, wstar) = synth_statics(D, 42);
    let mut rng = Rng::new(1234);
    for (r, name) in [(Rounding::Rtn, "RTN"), (Rounding::Rr, "RR")] {
        let mut wq = wstar.clone();
        cast(&mut wq, &fmt, r, &mut rng);
        rows.push(TableRow {
            method: "PTQ(w*)".into(),
            metric: name.into(),
            format: "int4".into(),
            val_loss: population_loss(&wq, &wstar, &lam),
        });
    }

    let refs: Vec<(String, &crate::coordinator::MetricsLogger)> =
        all_runs.iter().map(|(l, r)| (l.clone(), &r.metrics)).collect();
    write_curves(out_dir, &refs)?;
    write_table(out_dir, "Fig. 2 / Fig. 7 — INT4 linreg final quantized val loss", &rows)?;
    Ok(())
}
