//! Figs. 3 & 8 (§4.2): two-layer linear network f(x) = (1/k) W2 W1 x,
//! INT4, sweeping the hidden dimension k. Methods: LOTION / QAT / PTQ
//! (trained) + the GT construction of Lemma 4 (W2 = 1, rows(W1) = w*),
//! all cast with RTN and RR. Reports final quantized *training* loss
//! (== exact population loss for this model).

use crate::config::{RunConfig, Schedule};
use crate::coordinator::sweep::SweepPoint;
use crate::coordinator::DataSource;
use crate::data::synth::population_loss;
use crate::formats::csv::CsvWriter;
use crate::quant::{cast, QuantFormat, Rounding};
use crate::runtime::Executor;
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::Result;
use std::path::Path;

use super::common::{scaled, synth_statics, ExpCtx};

const D: usize = 12000;
pub const KS: [usize; 6] = [1, 2, 4, 8, 16, 32];

fn cfg_for(k: usize, method: &str, lr: f64, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("fig3_k{k}_{method}");
    cfg.model = format!("linear2_d{D}_k{k}");
    cfg.method = method.into();
    cfg.format = if method == "ptq" { "none".into() } else { "int4".into() };
    cfg.eval_formats = vec!["int4".into()];
    cfg.steps = steps;
    cfg.lr = lr;
    cfg.lambda = 1.0;
    cfg.eval_every = steps; // final eval only (plus step-0 baseline)
    cfg.schedule = Schedule::Cosine { warmup: 0, final_frac: 0.05 };
    cfg
}

/// GT baseline: construct Lemma 4's solution, cast, exact loss on host.
fn gt_loss(k: usize, lam: &[f32], wstar: &[f32], rounding: Rounding, rng: &mut Rng) -> f64 {
    let fmt = QuantFormat::int4();
    // W1 rows = w*, W2 = ones; flat per-tensor casts as the quantizer sees them
    let mut w1: Vec<f32> = (0..k).flat_map(|_| wstar.iter().copied()).collect();
    let mut w2 = vec![1.0f32; k];
    cast(&mut w1, &fmt, rounding, rng);
    cast(&mut w2, &fmt, rounding, rng);
    // effective w = (1/k) sum_j w2_j * w1_row_j
    let mut v = vec![0f32; wstar.len()];
    for j in 0..k {
        let row = &w1[j * wstar.len()..(j + 1) * wstar.len()];
        for (vi, &r) in v.iter_mut().zip(row) {
            *vi += w2[j] * r;
        }
    }
    for vi in v.iter_mut() {
        *vi /= k as f32;
    }
    population_loss(&v, wstar, lam)
}

const METHODS: [&str; 3] = ["lotion", "qat", "ptq"];

pub fn run(ctx: &ExpCtx<'_>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(1600);
    // The whole (k × method) grid is one sharded sweep: 18 runs fan
    // out over the context's workers, results fold in grid order.
    let inputs = |_: &dyn Executor,
                  _: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let (statics, _, _) = synth_statics(D, 42);
        Ok((statics, DataSource::InGraph))
    };
    let points: Vec<SweepPoint> = KS
        .iter()
        .flat_map(|&k| METHODS.iter().map(move |&method| (k, method)))
        .map(|(k, method)| {
            let label = format!("k{k}_{method}");
            SweepPoint::new(label.clone(), cfg_for(k, method, 0.3, steps))
                .with_metrics_path(out_dir.join(format!("{label}.jsonl")))
        })
        .collect();
    let results = ctx.runner().run(points, "int4", "rtn", &inputs)?;

    let mut w = CsvWriter::create(
        &out_dir.join("fig3.csv"),
        &["k", "method", "rounding", "final_loss"],
    )?;
    let mut rng = Rng::new(99);
    let mut res_iter = results.iter();
    for &k in &KS {
        let (_, lam, wstar) = synth_statics(D, 42);
        for method in METHODS {
            let m = &res_iter.next().expect("one result per grid point").metrics;
            for r in ["rtn", "rr"] {
                if let Some(v) = m.final_eval("int4", r) {
                    w.row(&[k.to_string(), method.into(), r.into(), format!("{v:.6}")])?;
                }
            }
        }
        for (r, name) in [(Rounding::Rtn, "rtn"), (Rounding::Rr, "rr")] {
            let v = gt_loss(k, &lam, &wstar, r, &mut rng);
            w.row(&[k.to_string(), "gt".into(), name.into(), format!("{v:.6}")])?;
            crate::info!("fig3 k={k} gt/{name}: {v:.5}");
        }
    }
    Ok(())
}
