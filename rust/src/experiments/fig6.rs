//! Fig. 6 (App. A.1): 1-D quadratic visualization of LOTION.
//! Emits the three curves over a dense grid of w — the raw loss L(w),
//! the quantized loss L(cast(w)), and the exact smoothed loss
//! E[L(RR(w))] — showing the smoothed loss is continuous and shares
//! the quantized loss's minima.
//!
//! A fixed lattice (scale s) is used, as in the figure: in 1-D the
//! absmax scale would degenerate (every point would be its own absmax).

use crate::formats::csv::CsvWriter;
use anyhow::Result;
use std::path::Path;

pub struct Fig6Point {
    pub w: f64,
    pub loss: f64,
    pub quantized: f64,
    pub smoothed: f64,
}

/// Closed-form curves for L(w) = 0.5 (w - w*)^2 on the lattice s*Z.
pub fn curves(wstar: f64, scale: f64, lo: f64, hi: f64, n: usize) -> Vec<Fig6Point> {
    let loss = |q: f64| 0.5 * (q - wstar) * (q - wstar);
    (0..n)
        .map(|i| {
            let w = lo + (hi - lo) * i as f64 / (n - 1) as f64;
            let z = w / scale;
            let l = z.floor();
            let p_up = z - l;
            let quantized = loss(scale * z.round_ties_even());
            let smoothed = (1.0 - p_up) * loss(scale * l) + p_up * loss(scale * (l + 1.0));
            Fig6Point { w, loss: loss(w), quantized, smoothed }
        })
        .collect()
}

pub fn run(_engine_unused: Option<()>, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let pts = curves(1.37, 0.5, -1.0, 4.0, 1001);
    let mut w = CsvWriter::create(
        &out_dir.join("fig6.csv"),
        &["w", "loss", "quantized", "smoothed"],
    )?;
    for p in &pts {
        w.row(&[
            format!("{:.4}", p.w),
            format!("{:.6}", p.loss),
            format!("{:.6}", p.quantized),
            format!("{:.6}", p.smoothed),
        ])?;
    }
    // sanity relations, also asserted by unit tests
    crate::info!("fig6: wrote {} grid points", pts.len());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn smoothed_matches_quantized_minimum() {
        // Lemma 2: identical global minima
        let pts = curves(1.37, 0.5, -1.0, 4.0, 4001);
        let qmin = pts.iter().map(|p| p.quantized).fold(f64::INFINITY, f64::min);
        let smin = pts.iter().map(|p| p.smoothed).fold(f64::INFINITY, f64::min);
        assert!((qmin - smin).abs() < 1e-9, "qmin={qmin} smin={smin}");
    }

    #[test]
    fn smoothed_is_continuous_quantized_is_not() {
        let pts = curves(1.37, 0.5, -1.0, 4.0, 4001);
        let max_jump = |f: &dyn Fn(&Fig6Point) -> f64| {
            pts.windows(2).map(|w| (f(&w[1]) - f(&w[0])).abs()).fold(0.0, f64::max)
        };
        // grid spacing 1.25e-3: a continuous function moves O(spacing)
        assert!(max_jump(&|p| p.smoothed) < 0.01);
        assert!(max_jump(&|p| p.quantized) > 0.1); // jump discontinuities
    }

    #[test]
    fn smoothed_upper_bounds_loss_by_variance_term() {
        // E[L(RR(w))] = L(w) + 0.5 Var[eps] >= L(w) for quadratics
        for p in curves(0.4, 0.25, -1.0, 1.0, 101) {
            assert!(p.smoothed >= p.loss - 1e-12);
        }
    }

    #[test]
    fn smoothed_equals_loss_on_lattice() {
        let pts = curves(1.0, 0.5, -1.0, 2.0, 7); // grid hits multiples of 0.5
        for p in pts {
            if (p.w / 0.5 - (p.w / 0.5).round()).abs() < 1e-12 {
                assert!((p.smoothed - p.quantized).abs() < 1e-12);
            }
        }
    }
}
