//! LM experiments (§4.3): Figs. 1/4/5/9/10/11/12 + Tables 1/2, on the
//! CPU-scaled presets (DESIGN.md §6).
//!
//! One shared driver trains a set of (method, format) runs on the same
//! Zipf–Markov corpus with identical seeds, evaluates quantized val
//! loss (RTN + RR) on a fixed validation chunk, and emits curves + the
//! paper-style final table. The run set is a sharded sweep: with
//! `--sweep-workers N` the (method, format) runs train concurrently on
//! factory-spawned engines — each rebuilds the identical corpus from
//! the same seed, so the controlled comparison (and bit-identity with
//! the serial pass) is preserved.

use crate::config::{RunConfig, Schedule};
use crate::coordinator::sweep::SweepPoint;
use crate::coordinator::{DataSource, MetricsLogger};
use crate::data::{ByteTokenizer, TokenBatcher, ZipfMarkovCorpus};
use crate::runtime::Executor;
use crate::tensor::HostTensor;
use anyhow::Result;
use std::path::Path;

use super::common::{scaled, write_curves, write_table, ExpCtx, TableRow};

pub struct LmExp {
    pub id: &'static str,
    pub model: &'static str,
    /// (method, train format) pairs
    pub runs: &'static [(&'static str, &'static str)],
    /// formats to evaluate (PTQ evals in all of them)
    pub eval_formats: &'static [&'static str],
    pub steps: usize,
    pub lr: f64,
    pub lambda: f64,
}

pub const FIG9: LmExp = LmExp {
    id: "fig9",
    model: "lm-150m-sim",
    runs: &[
        ("ptq", "none"),
        ("qat", "int4"),
        ("qat", "int8"),
        ("rat", "int4"),
        ("rat", "int8"),
        ("lotion", "int4"),
        ("lotion", "int8"),
    ],
    eval_formats: &["int4", "int8"],
    steps: 360,
    lr: 3e-3,
    lambda: 300.0,
};

pub const FIG10: LmExp = LmExp {
    id: "fig10",
    model: "lm-150m-sim",
    runs: &[("qat", "int4"), ("lotion", "int4")],
    eval_formats: &["int4"],
    steps: 1080, // 3x the fig9 budget: the paper's extended-budget view
    lr: 3e-3,
    lambda: 300.0,
};

pub const FIG11: LmExp = LmExp {
    id: "fig11",
    model: "lm-300m-sim",
    runs: &[
        ("ptq", "none"),
        ("qat", "int4"),
        ("qat", "int8"),
        ("lotion", "int4"),
        ("lotion", "int8"),
    ],
    eval_formats: &["int4", "int8"],
    steps: 320,
    lr: 2e-3,
    lambda: 300.0,
};

pub const FIG12: LmExp = LmExp {
    id: "fig12",
    model: "lm-150m-sim",
    runs: &[("ptq", "none"), ("qat", "fp4"), ("lotion", "fp4")],
    eval_formats: &["fp4"],
    steps: 360,
    lr: 3e-3,
    // FP4's widest scaled bin is 2.0, so sigma^2 peaks at s^2 (4x the
    // uniform lattice's s^2/4): lambda=300 diverges, 100 is stable.
    lambda: 100.0,
};

/// Corpus shared by every run in an experiment (identical data stream
/// per method, as in the paper's controlled comparisons). Shared with
/// the estimator experiments (`est_exps`), which compare method
/// families on the same token stream.
pub(super) fn make_batcher(model: &str, engine: &dyn Executor) -> Result<TokenBatcher> {
    // read batch geometry from the eval artifact's data spec
    let eval = engine.manifest().find_eval(model)?;
    let data = eval
        .inputs
        .iter()
        .find(|s| matches!(s.role, crate::runtime::Role::Data))
        .ok_or_else(|| anyhow::anyhow!("eval artifact has no data input"))?;
    let (batch, t1) = (data.shape[1], data.shape[2]);
    let corpus = ZipfMarkovCorpus::generate(2_000_000, 2048, 4, 7);
    let toks = ByteTokenizer::new().encode(&corpus.bytes);
    Ok(TokenBatcher::new(toks, batch, t1 - 1, 0.05))
}

/// The run config for one (method, format) leg. Every leg shares the
/// same seed (17) — the paper's controlled comparison trains each
/// method on identical data/init streams.
fn leg_cfg(exp: &LmExp, method: &str, format: &str, steps: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.name = format!("{}_{method}_{format}", exp.id);
    cfg.model = exp.model.into();
    cfg.method = method.into();
    cfg.format = format.into();
    cfg.eval_formats = if method == "ptq" {
        exp.eval_formats.iter().map(|s| s.to_string()).collect()
    } else {
        vec![format.to_string()]
    };
    cfg.steps = steps;
    cfg.lr = exp.lr;
    cfg.lambda = exp.lambda;
    cfg.eval_every = (steps / 12).max(8);
    cfg.schedule = Schedule::Cosine { warmup: steps / 20, final_frac: 0.1 };
    cfg.seed = 17;
    cfg
}

pub fn run_exp(ctx: &ExpCtx<'_>, exp: &LmExp, out_dir: &Path) -> Result<()> {
    std::fs::create_dir_all(out_dir)?;
    let steps = scaled(exp.steps);
    let points: Vec<SweepPoint> = exp
        .runs
        .iter()
        .map(|&(method, format)| {
            let label = format!("{method}_{format}");
            SweepPoint::new(label.clone(), leg_cfg(exp, method, format, steps))
                .with_metrics_path(out_dir.join(format!("{label}.jsonl")))
        })
        .collect();
    // each worker builds the corpus/batcher on its own engine from the
    // fixed seed — identical data stream per leg, any shard width
    let inputs = |engine: &dyn Executor,
                  cfg: &RunConfig|
     -> Result<(Vec<(String, HostTensor)>, DataSource)> {
        let batcher = make_batcher(&cfg.model, engine)?;
        Ok((vec![], DataSource::Tokens(batcher)))
    };
    let results = ctx.runner().run(points, exp.eval_formats[0], "rtn", &inputs)?;

    let mut labelled: Vec<(String, &MetricsLogger)> = Vec::new();
    let mut rows: Vec<TableRow> = Vec::new();
    for (r, &(method, format)) in results.iter().zip(exp.runs) {
        // a diverged run is a data point, not a batch-killer
        if r.diverged {
            crate::warn_!("[{}] failed; omitting from curves/table", r.label);
            continue;
        }
        let eval_formats: Vec<String> = if method == "ptq" {
            exp.eval_formats.iter().map(|s| s.to_string()).collect()
        } else {
            vec![format.to_string()]
        };
        for fmt in &eval_formats {
            for ro in ["rtn", "rr"] {
                if let Some(v) = r.metrics.final_eval(fmt, ro) {
                    rows.push(TableRow {
                        method: method.to_uppercase(),
                        metric: ro.to_uppercase(),
                        format: fmt.clone(),
                        val_loss: v,
                    });
                }
            }
        }
        labelled.push((r.label.clone(), &r.metrics));
    }

    write_curves(out_dir, &labelled)?;
    write_table(
        out_dir,
        &format!("{} — {} final quantized val CE", exp.id, exp.model),
        &rows,
    )?;
    Ok(())
}
