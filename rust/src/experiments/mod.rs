//! Experiment registry: one regenerator per paper figure/table
//! (DESIGN.md §4 maps ids to paper artifacts).

pub mod ablation;
pub mod common;
pub mod est_exps;
pub mod fig2;
pub mod fig3;
pub mod fig6;
pub mod lm_exps;
pub mod registry;
