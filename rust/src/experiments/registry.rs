//! Experiment id → regenerator dispatch.
//!
//! | id    | paper artifact                       |
//! |-------|--------------------------------------|
//! | fig2  | Fig. 2 + Fig. 7 table (linreg INT4)  |
//! | fig3  | Fig. 3 / Fig. 8 (linear2 k-sweep)    |
//! | fig6  | Fig. 6 (1-D smoothing visualization) |
//! | fig9  | Fig. 9 + Table 1 (150m INT4/INT8)    |
//! | fig10 | Fig. 1 / Fig. 10 (extended budget)   |
//! | fig11 | Fig. 4 / Fig. 11 + Table 2 (300m)    |
//! | fig12 | Fig. 5 / Fig. 12 (FP4)               |
//!
//! Beyond the paper's artifacts, the estimator layer's two extra
//! method families get their own regenerators (DESIGN.md §9):
//!
//! | id        | artifact                                      |
//! |-----------|-----------------------------------------------|
//! | est-equiv | cge(lr, c) vs qat(c·lr) equivalence table     |
//! | anneal    | σ→0 noise-annealing curves/table (lm-tiny)    |
//! | all       | everything above                              |
//!
//! An id ending in `.sweep` is a sweep-spec *file* (DESIGN.md §10):
//! `exp path/to/grid.sweep` expands it and runs the grid through the
//! same sharded path, writing curves + per-point metrics under
//! `<results>/<spec name>/`.

use anyhow::{bail, Result};
use std::path::Path;

use super::common::ExpCtx;
use super::{ablation, est_exps, fig2, fig3, fig6, lm_exps};

pub const ALL: [&str; 9] =
    ["fig6", "fig2", "fig3", "fig9", "fig10", "fig11", "fig12", "est-equiv", "anneal"];

/// Paper-artifact aliases accepted on the CLI.
pub fn canonical(id: &str) -> &str {
    match id {
        "fig7" => "fig2",
        "fig8" => "fig3",
        "fig1" => "fig10",
        "fig4" | "table2" => "fig11",
        "fig5" => "fig12",
        "table1" => "fig9",
        other => other,
    }
}

/// The backend models an experiment needs (`fig6` is closed-form and
/// needs none) — the availability check behind the `exp all` summary.
fn required_models(id: &str) -> Vec<String> {
    match id {
        "fig2" => vec!["linreg_d12000".to_string()],
        "fig3" => fig3::KS.iter().map(|k| format!("linear2_d12000_k{k}")).collect(),
        "fig9" | "fig10" | "fig12" => vec!["lm-150m-sim".to_string()],
        "fig11" => vec!["lm-300m-sim".to_string()],
        "est-equiv" => vec!["linreg_d256".to_string()],
        "anneal" => vec!["lm-tiny".to_string()],
        _ => Vec::new(),
    }
}

pub fn run(ctx: &ExpCtx<'_>, id: &str, results_dir: &Path) -> Result<()> {
    if id.ends_with(".sweep") {
        return run_spec_file(ctx, id, results_dir);
    }
    let id = canonical(id);
    if id == "all" {
        // a failing experiment is a data point, not a batch-killer —
        // but every skip/failure must be explicit in the final summary
        let mut summary: Vec<(&str, String)> = Vec::new();
        for e in ALL {
            let missing: Vec<String> = required_models(e)
                .into_iter()
                .filter(|m| ctx.engine.manifest().find_init(m).is_err())
                .collect();
            let status = if !missing.is_empty() {
                let s = format!("skipped — backend has no programs for {}", missing.join(", "));
                crate::warn_!("experiment {e} {s}");
                s
            } else {
                match run(ctx, e, results_dir) {
                    Ok(()) => "ran".to_string(),
                    Err(err) => {
                        crate::warn_!("experiment {e} failed: {err:#}");
                        format!("FAILED — {err:#}")
                    }
                }
            };
            summary.push((e, status));
        }
        println!("\n== exp all summary (backend registry: {:?}) ==", ctx.engine.manifest().dir);
        for (e, s) in &summary {
            println!("  {e:<6} {s}");
        }
        return Ok(());
    }
    let out = results_dir.join(id);
    crate::info!("=== experiment {id} -> {out:?} ===");
    match id {
        "fig2" => fig2::run(ctx, &out),
        "fig3" => fig3::run(ctx, &out),
        "fig6" => fig6::run(None, &out),
        "fig9" => lm_exps::run_exp(ctx, &lm_exps::FIG9, &out),
        "fig10" => lm_exps::run_exp(ctx, &lm_exps::FIG10, &out),
        "fig11" => lm_exps::run_exp(ctx, &lm_exps::FIG11, &out),
        "fig12" => lm_exps::run_exp(ctx, &lm_exps::FIG12, &out),
        "est-equiv" => est_exps::run_equiv(ctx, &out),
        "anneal" => est_exps::run_anneal(ctx, &out),
        "ablation" => ablation::run(ctx.engine, &out),
        other => bail!("unknown experiment {other:?} (try: {:?} or all)", ALL),
    }
}

/// `exp <file>.sweep`: expand an arbitrary spec file and run its grid
/// through the same sharded runner the named experiments use.
fn run_spec_file(ctx: &ExpCtx<'_>, path: &str, results_dir: &Path) -> Result<()> {
    use crate::config::RunConfig;
    use crate::runtime::Executor;

    let src = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("reading spec {path:?}: {e}"))?;
    let models = ctx.factory.model_names();
    let plan = crate::spec::plan(&src, path, &RunConfig::default(), models.as_deref())?;
    let out = results_dir.join(&plan.name);
    std::fs::create_dir_all(&out)?;
    let mut points = plan.points;
    for p in &mut points {
        p.metrics_path = Some(out.join(format!("{}.jsonl", p.label)));
    }
    let results = ctx.runner().run(
        points,
        &plan.score_format,
        &plan.score_rounding,
        &|engine: &dyn Executor, cfg: &RunConfig| super::common::build_inputs(engine, cfg, 7),
    )?;
    let labelled: Vec<(String, &crate::coordinator::MetricsLogger)> =
        results.iter().map(|r| (r.label.clone(), &r.metrics)).collect();
    super::common::write_curves(&out, &labelled)?;
    println!("{:<28} {:>12} {:>14} {:>10}", "label", "lr", "score", "diverged");
    for r in &results {
        println!("{:<28} {:>12.4e} {:>14.6} {:>10}", r.label, r.lr, r.score, r.diverged);
    }
    if let Some(i) = crate::coordinator::sweep::best(&results) {
        println!("best: {} score={:.6}", results[i].label, results[i].score);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aliases_resolve() {
        assert_eq!(canonical("fig7"), "fig2");
        assert_eq!(canonical("table1"), "fig9");
        assert_eq!(canonical("fig5"), "fig12");
        assert_eq!(canonical("fig2"), "fig2");
    }

    #[test]
    fn required_models_cover_every_backend_experiment() {
        assert!(required_models("fig6").is_empty()); // closed form
        assert_eq!(required_models("fig3").len(), fig3::KS.len());
        // every requirement resolves on the default native registry —
        // i.e. `exp all --backend native` skips nothing now that the
        // LM interpreter has landed
        let eng = crate::runtime::NativeEngine::new();
        for e in ALL {
            for m in required_models(e) {
                assert!(eng.manifest().find_init(&m).is_ok(), "{e} needs {m}");
            }
        }
    }
}
