//! Experiment id → regenerator dispatch.
//!
//! | id    | paper artifact                       |
//! |-------|--------------------------------------|
//! | fig2  | Fig. 2 + Fig. 7 table (linreg INT4)  |
//! | fig3  | Fig. 3 / Fig. 8 (linear2 k-sweep)    |
//! | fig6  | Fig. 6 (1-D smoothing visualization) |
//! | fig9  | Fig. 9 + Table 1 (150m INT4/INT8)    |
//! | fig10 | Fig. 1 / Fig. 10 (extended budget)   |
//! | fig11 | Fig. 4 / Fig. 11 + Table 2 (300m)    |
//! | fig12 | Fig. 5 / Fig. 12 (FP4)               |
//! | all   | everything above                     |

use crate::runtime::Executor;
use anyhow::{bail, Result};
use std::path::Path;

use super::{ablation, fig2, fig3, fig6, lm_exps};

pub const ALL: [&str; 7] = ["fig6", "fig2", "fig3", "fig9", "fig10", "fig11", "fig12"];

/// Paper-artifact aliases accepted on the CLI.
pub fn canonical(id: &str) -> &str {
    match id {
        "fig7" => "fig2",
        "fig8" => "fig3",
        "fig1" => "fig10",
        "fig4" | "table2" => "fig11",
        "fig5" => "fig12",
        "table1" => "fig9",
        other => other,
    }
}

pub fn run(engine: &dyn Executor, id: &str, results_dir: &Path) -> Result<()> {
    let id = canonical(id);
    if id == "all" {
        // a failing experiment (e.g. LM figures on a backend without LM
        // programs) is a data point, not a batch-killer
        for e in ALL {
            if let Err(err) = run(engine, e, results_dir) {
                crate::warn_!("experiment {e} failed: {err:#}");
            }
        }
        return Ok(());
    }
    let out = results_dir.join(id);
    crate::info!("=== experiment {id} -> {out:?} ===");
    match id {
        "fig2" => fig2::run(engine, &out),
        "fig3" => fig3::run(engine, &out),
        "fig6" => fig6::run(None, &out),
        "fig9" => lm_exps::run_exp(engine, &lm_exps::FIG9, &out),
        "fig10" => lm_exps::run_exp(engine, &lm_exps::FIG10, &out),
        "fig11" => lm_exps::run_exp(engine, &lm_exps::FIG11, &out),
        "fig12" => lm_exps::run_exp(engine, &lm_exps::FIG12, &out),
        "ablation" => ablation::run(engine, &out),
        other => bail!("unknown experiment {other:?} (try: {:?} or all)", ALL),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn aliases_resolve() {
        assert_eq!(canonical("fig7"), "fig2");
        assert_eq!(canonical("table1"), "fig9");
        assert_eq!(canonical("fig5"), "fig12");
        assert_eq!(canonical("fig2"), "fig2");
    }
}
