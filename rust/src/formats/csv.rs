//! Tiny CSV writer for experiment outputs (figures are regenerated as
//! CSV series; the paper-table printers format from the same rows).

use anyhow::Result;
use std::io::Write;
use std::path::Path;

pub struct CsvWriter {
    file: std::fs::File,
    n_cols: usize,
}

impl CsvWriter {
    pub fn create(path: &Path, header: &[&str]) -> Result<Self> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut file = std::fs::File::create(path)?;
        writeln!(file, "{}", header.join(","))?;
        Ok(CsvWriter { file, n_cols: header.len() })
    }

    pub fn row(&mut self, cells: &[String]) -> Result<()> {
        assert_eq!(cells.len(), self.n_cols, "csv row arity mismatch");
        let escaped: Vec<String> = cells
            .iter()
            .map(|c| {
                if c.contains(',') || c.contains('"') || c.contains('\n') {
                    format!("\"{}\"", c.replace('"', "\"\""))
                } else {
                    c.clone()
                }
            })
            .collect();
        writeln!(self.file, "{}", escaped.join(","))?;
        Ok(())
    }

    pub fn row_mixed(&mut self, cells: &[CsvCell]) -> Result<()> {
        self.row(&cells.iter().map(|c| c.to_string()).collect::<Vec<_>>())
    }
}

pub enum CsvCell {
    S(String),
    I(i64),
    F(f64),
}

impl std::fmt::Display for CsvCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CsvCell::S(s) => write!(f, "{s}"),
            CsvCell::I(i) => write!(f, "{i}"),
            CsvCell::F(x) => write!(f, "{x:.6}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn writes_and_escapes() {
        let dir = std::env::temp_dir().join("lotion_csv_test");
        let path = dir.join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        w.row(&["x".into(), "y,z".into()]).unwrap();
        w.row_mixed(&[CsvCell::I(3), CsvCell::F(0.5)]).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text, "a,b\nx,\"y,z\"\n3,0.500000\n");
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let path = std::env::temp_dir().join("lotion_csv_test2").join("t.csv");
        let mut w = CsvWriter::create(&path, &["a", "b"]).unwrap();
        let _ = w.row(&["only-one".into()]);
    }
}
