//! JSON: recursive-descent parser + writer.
//!
//! Covers the full JSON grammar (objects, arrays, strings with escapes
//! and \uXXXX, numbers, bools, null). Object key order is preserved —
//! the AOT manifest relies on positional input/output lists, and
//! deterministic round-trips make golden tests trivial.

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kv) => kv.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    pub fn expect(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn members(&self) -> &[(String, Json)] {
        match self {
            Json::Obj(kv) => kv,
            _ => &[],
        }
    }

    // -- parsing -----------------------------------------------------------

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            bail!("trailing characters at offset {}", p.i);
        }
        Ok(v)
    }

    pub fn from_file(path: &std::path::Path) -> Result<Json> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("reading {path:?}: {e}"))?;
        Json::parse(&text)
    }

    // -- writing -----------------------------------------------------------

    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(kv) => {
                out.push('{');
                for (i, (k, v)) in kv.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected {:?} at offset {} (found {:?})",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at offset {}", other.map(|b| b as char), self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at offset {}", self.i)
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let text = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(text.parse::<f64>().map_err(|e| anyhow!("bad number {text:?}: {e}"))?))
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow!("truncated \\u escape"))?,
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other.map(|b| b as char)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a run of plain bytes (valid UTF-8 by input contract)
                    let start = self.i;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => bail!("expected , or ] (found {:?})", other.map(|b| b as char)),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut kv = Vec::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(kv));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            kv.push((k, v));
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(kv));
                }
                other => bail!("expected , or }} (found {:?})", other.map(|b| b as char)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(Json::parse(r#""a\nb""#).unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x", "c": null}], "d": false}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("x")
        );
        assert_eq!(j.get("d").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""Aé""#).unwrap(), Json::Str("Aé".into()));
    }

    #[test]
    fn roundtrip_preserves_order_and_values() {
        let src = r#"{"z":1,"a":[true,null,"s"],"m":{"k":2.5}}"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(j.to_string(), src);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("012x").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn real_manifest_fragment() {
        let frag = r#"{"artifacts": {"t": {"file": "t.hlo.txt",
            "inputs": [{"name": "w", "shape": [256], "dtype": "f32", "role": "param"}],
            "meta": {"steps_per_call": 8}}}}"#;
        let j = Json::parse(frag).unwrap();
        let t = j.get("artifacts").unwrap().get("t").unwrap();
        assert_eq!(t.get("meta").unwrap().get("steps_per_call").unwrap().as_usize(), Some(8));
    }
}
