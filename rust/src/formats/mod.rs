//! Serialization substrates implemented in-repo (serde is not in the
//! offline vendor set): a full JSON parser/writer and a CSV writer.

pub mod csv;
pub mod json;

pub use json::Json;
