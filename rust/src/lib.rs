//! # lotion-rs — LOTION quantized-training framework (rust coordinator)
//!
//! Reproduction of *LOTION: Smoothing the Optimization Landscape for
//! Quantized Training* (Kwun et al., 2025) as a three-layer
//! rust + JAX + Pallas system. This crate is **Layer 3**: the runtime
//! coordinator that owns training orchestration, data pipelines,
//! quantized evaluation, checkpointing, experiment regeneration and
//! benchmarking.
//!
//! Execution is backend-pluggable behind the `runtime::Executor` trait
//! (DESIGN.md §3): the default **native** backend runs the synthetic
//! testbeds *and* the transformer LM presets in pure rust — exact
//! RR/RTN casts and the Eq. 3 penalty included — with no artifacts,
//! python, or XLA anywhere;
//! `--features pjrt` adds the PJRT backend that loads AOT-lowered HLO
//! artifacts from the JAX/Pallas build layers and executes them with no
//! python on the request path.
//!
//! Module map (see DESIGN.md §5):
//!
//! * [`util`] — PRNG (+ counter-split streams), persistent worker pool,
//!   statistics, logging, mini property-testing.
//! * [`formats`] — JSON/CSV substrates (no serde available offline).
//! * [`tensor`] — host tensors (shape/dtype/bytes) shared by all layers.
//! * [`quant`] — rust-native block quantizer: INT4/INT8/FP4, RTN + RR,
//!   the paper's §2.1 scheme; bit-parity with the python oracles.
//! * [`config`] — TOML-subset config system + typed run configs.
//! * [`data`] — synthetic regression streams, Zipf–Markov corpus,
//!   byte tokenizer, batcher.
//! * [`runtime`] — the `Executor` backend trait, the `ExecutorFactory`
//!   engine spawner, typed per-run `Session` handles, manifest-driven
//!   program registry, train-state management, the native backend and
//!   (feature-gated) the PJRT engine.
//! * [`coordinator`] — trainer, evaluator, LR schedules, sharded
//!   sweeps, metrics.
//! * [`spec`] — sweep-spec DSL: lexer + recursive-descent parser +
//!   grid expansion feeding the sharded `SweepRunner`.
//! * [`checkpoint`] — binary tensor archive.
//! * [`experiments`] — one regenerator per paper figure/table.
//! * [`benchlib`] — micro-benchmark harness (criterion unavailable).

pub mod benchlib;
pub mod cli;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod formats;
pub mod quant;
pub mod runtime;
pub mod spec;
pub mod tensor;
pub mod util;

pub use anyhow::{anyhow, bail, Context, Result};
