//! lotion-rs — the L3 coordinator CLI.
//!
//! ```text
//! lotion-rs train --config runs/example.toml [--set k=v ...] [--backend native|pjrt|auto]
//! lotion-rs exp <fig2|fig3|fig6|fig9|fig10|fig11|fig12|table1|table2|all>
//! lotion-rs sweep --config runs/example.toml --lrs 0.1,0.3,1.0
//! lotion-rs inspect [--artifacts artifacts]
//! lotion-rs data-report
//! ```
//!
//! Every subcommand runs against a backend picked by `--backend`:
//! `native` (pure-rust, no artifacts needed — covers the synthetic
//! testbeds *and* the `lm-*` transformer presets, so every experiment
//! including fig9–fig12 runs offline), `pjrt` (the AOT/XLA path, needs
//! `--features pjrt` and `make artifacts`), or `auto` (the default).

use anyhow::{bail, Context, Result};
use lotion::cli::Args;
use lotion::config::{RunConfig, TomlDoc};
use lotion::coordinator::{CkptPolicy, Evaluator, MetricsLogger, SweepJournal, Trainer};
use lotion::data::{ByteTokenizer, ZipfMarkovCorpus};
use lotion::experiments::common::{build_inputs, ExpCtx};
use lotion::experiments::registry;
use lotion::runtime::{Executor, ExecutorFactory, NativeEngine, NativeFactory, Role};
use lotion::{checkpoint::Checkpoint, info};
use std::path::{Path, PathBuf};

fn main() {
    lotion::util::logging::init();
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: lotion-rs <train|exp|sweep|serve|bench-serve|inspect|data-report> [flags]
  train       --config <toml> [--set k=v ...] [--out results/<name>]
              [--method ptq|qat|rat|lotion|cge|anneal]
              [--est-schedule constant|linear|cosine] [--est-sigma0 s]
              [--est-grad-scale c]
              [--ckpt-every N] [--ckpt-dir dir] [--resume <ckpt|dir>]
  exp         <id|all|file.sweep> [--results results] [--artifacts artifacts]
  sweep       --config <toml> --lrs 0.1,0.3 [--score-format int4] [--score-rounding rtn]
              [--journal <jsonl>] [--resume-sweep] [--retries N]
              spec-driven grids (DESIGN.md §10; replaces --lrs):
              [--spec <file.sweep|->] [--spec-str <text>] ([sweep] spec
              in the config names a default file)
              [--dry-run]            print the expanded grid, spawn nothing
              [--sweep-out <jsonl>]  machine-readable results (label, lr,
                                     score_bits, score, diverged)
              [--out dir]            per-point metrics dir (default:
                                     <results>/<spec name>)
  serve       [--model lm-tiny] [--format int4] [--weights final.lotn]
              [--engines 1] [--max-batch 4] [--requests 16]
              [--prompt-len 8] [--gen-len 16] [--temperature 0.8] [--seed 42]
              drain a synthetic request load through an engine pool
  bench-serve [serve flags] [--formats none,int4,int4@64,int8,fp4]
              [--out BENCH_serve.json]
              serve bench across decode formats: tokens/s, per-token
              p50/p99 latency, TTFT per format
  inspect     [--artifacts artifacts]           list programs + execution timings
  data-report [--bytes 1000000]                 corpus statistics
crash safety (DESIGN.md §7):
  --ckpt-every N     snapshot params+optimizer+RNG every N steps (also
                     [train] checkpoint_every, or LOTION_CKPT_EVERY)
  --ckpt-dir dir     where snapshots go (also [train] ckpt_dir, or
                     LOTION_CKPT_DIR; default: the --out directory)
  --resume p         restore a .lotn checkpoint (or the newest one in a
                     directory) and continue; the finished run is
                     bit-identical to an uninterrupted one
  --journal p        JSONL journal of completed sweep points
                     (default with --resume-sweep:
                     <results>/<name>_sweep.jsonl)
  --resume-sweep     skip journaled points, fold their scores back in
  --retries N        re-attempts for a panicking sweep point on a fresh
                     engine (default 1); diverged points never retry
  LOTION_FAULTS      deterministic fault plan for crash testing, e.g.
                     panic@point:3,io_err@ckpt_save:2,kill@step:40
common flags:
  --backend {auto|native|pjrt}   execution backend (default: auto — pjrt
                                 if built with it and artifacts exist,
                                 else the pure-rust native backend)
  --threads N                    native-backend worker threads (default:
                                 LOTION_THREADS env var, else all cores;
                                 output is bit-identical at any N)
  --simd {auto|scalar|avx2|neon} kernel dispatch tier (default:
                                 LOTION_SIMD env var, else runtime
                                 detection; output is bit-identical at
                                 every tier)
  --sweep-workers N              grid points in flight for sweep/exp,
                                 each on its own engine (default:
                                 LOTION_SWEEP_WORKERS env var, else 1;
                                 output is bit-identical at any N)";

fn run() -> Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_str() {
        "train" => cmd_train(&args),
        "exp" => cmd_exp(&args),
        "sweep" => cmd_sweep(&args),
        "serve" => cmd_serve(&args, false),
        "bench-serve" => cmd_serve(&args, true),
        "inspect" => cmd_inspect(&args),
        "data-report" => cmd_data_report(&args),
        "" => bail!("{USAGE}"),
        other => bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

/// Resolve the `--backend` / `--threads` flags into an executor.
/// Thread resolution: `--threads` > `[train] threads` in the config
/// (`cfg_threads`) > `LOTION_THREADS` env var > all cores.
fn make_executor(
    args: &Args,
    artifacts_dir: &str,
    cfg_threads: usize,
) -> Result<Box<dyn Executor>> {
    let threads = args.usize_or("threads", cfg_threads)?;
    // coordinator-side quant casts (the evaluator's RTN/RR eval casts)
    // go through Pool::global(); keep them on the same knob
    lotion::util::pool::set_global_threads(threads);
    lotion::util::simd::set_global_simd(args.simd()?);
    match args.backend()? {
        "native" => Ok(Box::new(NativeEngine::new().with_threads(threads))),
        "pjrt" => match lotion::runtime::pjrt_executor(Path::new(artifacts_dir))? {
            Some(engine) => Ok(engine),
            None => bail!("this build has no PJRT backend (rebuild with `--features pjrt`)"),
        },
        _ => lotion::runtime::auto_executor_threads(Path::new(artifacts_dir), threads),
    }
}

/// The factory-side twin of [`make_executor`]: same `--backend` /
/// `--threads` policy, but returns a `Send + Sync` spawner the sweep
/// runner can hand to worker threads (each spawned engine is owned by
/// one thread).
fn make_factory(
    args: &Args,
    artifacts_dir: &str,
    cfg_threads: usize,
) -> Result<Box<dyn ExecutorFactory>> {
    let threads = args.usize_or("threads", cfg_threads)?;
    lotion::util::pool::set_global_threads(threads);
    lotion::util::simd::set_global_simd(args.simd()?);
    match args.backend()? {
        "native" => Ok(Box::new(NativeFactory::with_default_models(threads))),
        "pjrt" => match lotion::runtime::pjrt_factory(Path::new(artifacts_dir))? {
            Some(f) => Ok(f),
            None => bail!("this build has no PJRT backend (rebuild with `--features pjrt`)"),
        },
        _ => lotion::runtime::auto_factory(Path::new(artifacts_dir), threads),
    }
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut doc = match args.flag("config") {
        Some(path) => TomlDoc::from_file(Path::new(path))?,
        None => TomlDoc::default(),
    };
    for ov in args.flag_all("set") {
        doc.set_override(ov)?;
    }
    // estimator selection + schedule knobs as first-class flags; they
    // apply after --set, so `--method anneal` beats `--set method=qat`
    for (flag, key) in [
        ("method", "method"),
        ("est-schedule", "est.schedule"),
        ("est-sigma0", "est.sigma0"),
        ("est-grad-scale", "est.grad_scale"),
    ] {
        if let Some(v) = args.flag(flag) {
            doc.set_override(&format!("{key}={v}"))?;
        }
    }
    RunConfig::from_doc(&doc)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let engine = make_executor(args, &cfg.artifacts_dir, cfg.threads)?;
    let engine: &dyn Executor = &*engine;
    let out_dir = PathBuf::from(args.str_or("out", &format!("{}/{}", cfg.results_dir, cfg.name)));
    std::fs::create_dir_all(&out_dir)?;
    let (statics, data) = build_inputs(engine, &cfg, 7)?;
    let mut trainer = Trainer::new(engine, cfg.clone(), statics, data)?;
    let mut eval = Evaluator::new(cfg.seed);

    // --resume restores state/RNGs/cadence before the metrics sink
    // opens: a resumed run *appends* so the final JSONL matches an
    // uninterrupted run's line for line
    let resume_next_eval = match args.flag("resume") {
        Some(spec) => {
            let path = resolve_resume_path(Path::new(spec))?;
            let ckpt = Checkpoint::load(&path)?;
            let next_eval = trainer.restore(&mut eval, &ckpt)?;
            info!("resumed {path:?} at step {}", trainer.step);
            Some(next_eval)
        }
        None => None,
    };
    let metrics_path = out_dir.join("metrics.jsonl");
    let mut metrics = if resume_next_eval.is_some() {
        MetricsLogger::append_to_file(&metrics_path)?
    } else {
        MetricsLogger::to_file(&metrics_path)?
    };

    // cadence: --ckpt-every > [train] checkpoint_every > LOTION_CKPT_EVERY
    let every = match args.usize_opt("ckpt-every")? {
        Some(n) => n,
        None if cfg.checkpoint_every > 0 => cfg.checkpoint_every,
        None => lotion::config::env_ckpt_every().unwrap_or(0),
    };
    // dir: --ckpt-dir > [train] ckpt_dir > LOTION_CKPT_DIR > --out dir
    let ckpt_dir = args
        .flag("ckpt-dir")
        .map(PathBuf::from)
        .or_else(|| cfg.ckpt_dir.clone().map(PathBuf::from))
        .or_else(|| lotion::config::env_ckpt_dir().map(PathBuf::from))
        .unwrap_or_else(|| out_dir.clone());
    let policy = (every > 0).then(|| CkptPolicy { dir: ckpt_dir, every });

    trainer.run_with_checkpoints(&mut eval, &mut metrics, policy.as_ref(), resume_next_eval)?;
    let final_path = out_dir.join("final.lotn");
    trainer.save_checkpoint(&eval, trainer.step + cfg.eval_every.max(1), &final_path)?;
    info!("checkpoint -> {final_path:?}");
    let fp32 = metrics.final_eval("fp32", "none").unwrap_or(f64::NAN);
    info!("run {} done: {} steps, final fp32 val loss {:.4}", cfg.name, trainer.step, fp32);
    for p in metrics.eval_points.iter().rev().take(8) {
        info!("  final {}/{}: {:.4}", p.format, p.rounding, p.val_loss);
    }
    Ok(())
}

/// `--resume` accepts a checkpoint file, or a directory holding
/// `stepNNNNNN.lotn` snapshots (the newest wins, falling back to
/// `final.lotn`).
fn resolve_resume_path(spec: &Path) -> Result<PathBuf> {
    if spec.is_file() {
        return Ok(spec.to_path_buf());
    }
    if spec.is_dir() {
        let mut steps: Vec<PathBuf> = std::fs::read_dir(spec)?
            .filter_map(|e| e.ok())
            .map(|e| e.path())
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .is_some_and(|n| n.starts_with("step") && n.ends_with(".lotn"))
            })
            .collect();
        // zero-padded names sort by step
        steps.sort();
        if let Some(latest) = steps.pop() {
            return Ok(latest);
        }
        let fin = spec.join("final.lotn");
        if fin.is_file() {
            return Ok(fin);
        }
        bail!("--resume {spec:?}: no step*.lotn or final.lotn in directory");
    }
    bail!("--resume {spec:?}: no such file or directory")
}

fn cmd_exp(args: &Args) -> Result<()> {
    let id = args.positional.first().map(String::as_str).unwrap_or("all");
    let artifacts = args.str_or("artifacts", "artifacts");
    let results = PathBuf::from(args.str_or("results", "results"));
    let engine = make_executor(args, &artifacts, 0)?;
    let factory = make_factory(args, &artifacts, 0)?;
    let ctx = ExpCtx {
        engine: &*engine,
        factory: &*factory,
        sweep_workers: args.sweep_workers(0)?,
    };
    registry::run(&ctx, id, &results)?;
    // dump the execution profile alongside results. Serial runs (the
    // default) execute on this engine, so the profile is complete;
    // with --sweep-workers > 1 the grid legs run on worker-owned
    // engines whose timings are dropped with them, so only the
    // serial-side programs appear here.
    let mut prof = String::from("program,compile_s,calls,exec_s\n");
    for (name, c, n, e) in engine.timing_report() {
        prof.push_str(&format!("{name},{c:.3},{n},{e:.3}\n"));
    }
    std::fs::create_dir_all(&results)?;
    std::fs::write(results.join("engine_profile.csv"), prof)?;
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    // spec source precedence: --spec-str / --spec, then `[sweep] spec`
    // in the config; the legacy --lrs grid only when none of those
    let spec = match args.spec_source()? {
        Some(s) => Some(s),
        None => match &cfg.sweep_spec {
            Some(path) => {
                let text = std::fs::read_to_string(path)
                    .with_context(|| format!("reading [sweep] spec {path:?}"))?;
                Some((path.clone(), text))
            }
            None => None,
        },
    };
    if let Some((origin, src)) = spec {
        return run_spec_sweep(args, &cfg, &origin, &src);
    }
    let lrs: Vec<f64> = args
        .required("lrs")?
        .split(',')
        .map(|s| s.parse().map_err(|e| anyhow::anyhow!("bad lr {s:?}: {e}")))
        .collect::<Result<_>>()?;
    let score_fmt = args.str_or("score-format", &cfg.format);
    let score_rounding = args.str_or("score-rounding", "rtn");
    let workers = args.sweep_workers(cfg.sweep_workers)?;
    let factory = make_factory(args, &cfg.artifacts_dir, cfg.threads)?;
    let retries = args.usize_or("retries", 1)?;
    let resume = args.switch("resume-sweep");
    // journal path: --journal, else the run's canonical journal when
    // resuming (plain sweeps stay journal-free unless asked)
    let journal_path = match args.flag("journal") {
        Some(p) => Some(PathBuf::from(p)),
        None if resume => {
            Some(PathBuf::from(format!("{}/{}_sweep.jsonl", cfg.results_dir, cfg.name)))
        }
        None => None,
    };
    let mut runner =
        lotion::coordinator::SweepRunner::new(&*factory, workers).with_retries(retries);
    if let Some(jp) = &journal_path {
        let done = if resume { SweepJournal::completed(jp)? } else { Vec::new() };
        if !done.is_empty() {
            info!("resuming sweep: {} journaled point(s) in {jp:?}", done.len());
        }
        runner = runner.with_journal(jp, done)?;
    }
    let results = runner.run(
        lotion::coordinator::sweep::lr_points(&cfg, &lrs),
        &score_fmt,
        &score_rounding,
        &|engine: &dyn Executor, cfg: &RunConfig| build_inputs(engine, cfg, 7),
    )?;
    println!("{:<12} {:>14} {:>10}", "lr", "score", "diverged");
    for r in &results {
        println!("{:<12.4e} {:>14.6} {:>10}", r.lr, r.score, r.diverged);
    }
    if let Some(i) = lotion::coordinator::sweep::best(&results) {
        println!("best: lr={:.4e} score={:.6}", results[i].lr, results[i].score);
    }
    Ok(())
}

/// The spec-driven sweep path (DESIGN.md §10): expand + validate the
/// grid before anything spawns, stamp every journal entry with the
/// spec digest, and refuse to resume a journal written by a *different*
/// spec instead of silently mixing grids.
fn run_spec_sweep(args: &Args, cfg: &RunConfig, origin: &str, src: &str) -> Result<()> {
    let factory = make_factory(args, &cfg.artifacts_dir, cfg.threads)?;
    let models = factory.model_names();
    let mut plan = lotion::spec::plan(src, origin, cfg, models.as_deref())?;
    // CLI score knobs override the spec's score_format/score_rounding
    if let Some(f) = args.flag("score-format") {
        plan.score_format = f.to_string();
    }
    if let Some(r) = args.flag("score-rounding") {
        plan.score_rounding = r.to_string();
    }

    if args.switch("dry-run") {
        println!(
            "spec {origin} (digest {}): {} point(s), score {}/{}",
            plan.digest,
            plan.points.len(),
            plan.score_format,
            plan.score_rounding
        );
        println!(
            "{:<4} {:<28} {:<14} {:<8} {:<8} {:>10} {:>7} {:>20}  {}",
            "idx", "label", "model", "method", "format", "lr", "steps", "seed", "cfg_digest"
        );
        for (i, p) in plan.points.iter().enumerate() {
            println!(
                "{:<4} {:<28} {:<14} {:<8} {:<8} {:>10.4e} {:>7} {:>20}  {}",
                i,
                p.label,
                p.cfg.model,
                p.cfg.method,
                p.cfg.format,
                p.cfg.lr,
                p.cfg.steps,
                p.cfg.seed,
                p.cfg.digest()
            );
        }
        return Ok(());
    }

    let out_dir =
        PathBuf::from(args.str_or("out", &format!("{}/{}", cfg.results_dir, plan.name)));
    std::fs::create_dir_all(&out_dir)?;
    let workers = args.sweep_workers(cfg.sweep_workers)?;
    let retries = args.usize_or("retries", 1)?;
    let resume = args.switch("resume-sweep");
    let journal_path = match args.flag("journal") {
        Some(p) => Some(PathBuf::from(p)),
        None if resume => {
            Some(PathBuf::from(format!("{}/{}_sweep.jsonl", cfg.results_dir, plan.name)))
        }
        None => None,
    };
    let mut runner = lotion::coordinator::SweepRunner::new(&*factory, workers)
        .with_retries(retries)
        .with_spec_digest(plan.digest.as_str());
    if let Some(jp) = &journal_path {
        let done = if resume { SweepJournal::completed(jp)? } else { Vec::new() };
        if let Some(stale) =
            done.iter().find_map(|e| e.spec.as_deref().filter(|d| *d != plan.digest))
        {
            bail!(
                "journal {jp:?} was written by a different spec \
                 (journal digest {stale}, this spec {}); delete the journal \
                 or revert the spec",
                plan.digest
            );
        }
        if !done.is_empty() {
            info!("resuming sweep: {} journaled point(s) in {jp:?}", done.len());
        }
        runner = runner.with_journal(jp, done)?;
    }
    let mut points = plan.points;
    for p in &mut points {
        p.metrics_path = Some(out_dir.join(format!("{}.jsonl", p.label)));
    }
    let results = runner.run(
        points,
        &plan.score_format,
        &plan.score_rounding,
        &|engine: &dyn Executor, cfg: &RunConfig| build_inputs(engine, cfg, 7),
    )?;

    println!("{:<28} {:>12} {:>14} {:>10}", "label", "lr", "score", "diverged");
    for r in &results {
        println!("{:<28} {:>12.4e} {:>14.6} {:>10}", r.label, r.lr, r.score, r.diverged);
    }
    if let Some(i) = lotion::coordinator::sweep::best(&results) {
        println!("best: {} score={:.6}", results[i].label, results[i].score);
    }
    if let Some(out) = args.flag("sweep-out") {
        use lotion::formats::json::Json;
        let mut text = String::new();
        for r in &results {
            let row = Json::obj(vec![
                ("label", Json::str(r.label.clone())),
                ("lr", Json::num(r.lr)),
                ("score_bits", Json::str(format!("{:016x}", r.score.to_bits()))),
                // NaN (a diverged score) is not a JSON number
                ("score", if r.score.is_finite() { Json::num(r.score) } else { Json::Null }),
                ("diverged", Json::Bool(r.diverged)),
            ]);
            text.push_str(&row.to_string());
            text.push('\n');
        }
        std::fs::write(out, text)?;
        info!("sweep results -> {out}");
    }
    Ok(())
}

/// Serve weights: `--weights <ckpt.lotn>` loads a trained artifact
/// (tensors matched to the decode entry's param specs by name — the
/// names `cmd_train`'s `final.lotn` saves), otherwise fresh init via
/// the model's init entry at a seed-derived key.
fn serve_weights(
    engine: &dyn Executor,
    model: &str,
    args: &Args,
    seed: u64,
) -> Result<Vec<(String, lotion::tensor::HostTensor)>> {
    use lotion::runtime::executor::{check_value, value};
    match args.flag("weights") {
        Some(p) => {
            let entry = engine
                .manifest()
                .find_decode(model, "none")
                .with_context(|| format!("model {model:?} has no decode entries"))?;
            let ckpt = Checkpoint::load(Path::new(p))?;
            entry
                .input_specs(Role::Param)
                .into_iter()
                .map(|s| {
                    let t = ckpt.get(&s.name).ok_or_else(|| {
                        anyhow::anyhow!("checkpoint {p:?} is missing tensor {:?}", s.name)
                    })?;
                    check_value(t, s).with_context(|| format!("checkpoint {p:?}"))?;
                    Ok((s.name.clone(), t.clone()))
                })
                .collect()
        }
        None => {
            let init = engine.manifest().find_init(model)?.clone();
            let key = value(lotion::tensor::HostTensor::from_u32(
                &[2],
                vec![seed as u32, (seed >> 32) as u32],
            ));
            let out = engine.call(&init, &[key])?;
            Ok(init
                .outputs
                .iter()
                .zip(out)
                .map(|(s, v)| (s.name.clone(), v.as_ref().clone()))
                .collect())
        }
    }
}

/// `serve` (one config) and `bench-serve` (a decode-format grid with a
/// `BENCH_serve.json` emission) share everything but the loop.
fn cmd_serve(args: &Args, bench: bool) -> Result<()> {
    use lotion::coordinator::serve::{serve_synthetic, ServeConfig};
    use lotion::formats::json::Json;
    let artifacts = args.str_or("artifacts", "artifacts");
    let factory = make_factory(args, &artifacts, 0)?;
    let base = ServeConfig {
        model: args.str_or("model", "lm-tiny"),
        format: args.str_or("format", "int4"),
        engines: args.usize_or("engines", 1)?,
        max_batch: args.usize_or("max-batch", 4)?,
        requests: args.usize_or("requests", 16)?,
        prompt_len: args.usize_or("prompt-len", 8)?,
        gen_len: args.usize_or("gen-len", 16)?,
        temperature: args.f32_or("temperature", 0.8)?,
        seed: args.usize_or("seed", 42)? as u64,
    };
    let probe = factory.spawn()?;
    let weights = serve_weights(&*probe, &base.model, args, base.seed)?;
    drop(probe);
    if !bench {
        let report = serve_synthetic(&*factory, &weights, &base)?;
        println!("{}", report.table());
        return Ok(());
    }
    let formats: Vec<String> = args
        .str_or("formats", "none,int4,int4@64,int8,fp4")
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    let mut rows = Vec::new();
    for fmt in &formats {
        let cfg = ServeConfig { format: fmt.clone(), ..base.clone() };
        let report = serve_synthetic(&*factory, &weights, &cfg)?;
        println!("{}", report.table());
        rows.push(report.to_json());
    }
    let out = args.str_or("out", "BENCH_serve.json");
    let doc = Json::obj(vec![("suite", Json::str("serve")), ("results", Json::Arr(rows))]);
    std::fs::write(&out, doc.to_string())?;
    info!("serve bench -> {out}");
    Ok(())
}

fn cmd_inspect(args: &Args) -> Result<()> {
    let artifacts = args.str_or("artifacts", "artifacts");
    let engine = make_executor(args, &artifacts, 0)?;
    println!(
        "{:<48} {:>6} {:>8} {:>10} {:>10}",
        "program", "kind", "inputs", "params(M)", "K"
    );
    for e in engine.manifest().artifacts.values() {
        let params: usize = e
            .inputs
            .iter()
            .filter(|s| s.role == Role::Param)
            .map(|s| s.elements())
            .sum();
        println!(
            "{:<48} {:>6} {:>8} {:>10.2} {:>10}",
            e.name,
            e.kind,
            e.inputs.len(),
            params as f64 / 1e6,
            e.steps_per_call
        );
    }
    Ok(())
}

fn cmd_data_report(args: &Args) -> Result<()> {
    let n = args.usize_or("bytes", 1_000_000)?;
    let corpus = ZipfMarkovCorpus::generate(n, 2048, 4, 7);
    let tok = ByteTokenizer::new();
    let counts = tok.unigram_counts(&corpus.bytes);
    let total: u64 = counts.iter().sum();
    let h: f64 = counts
        .iter()
        .filter(|&&c| c > 0)
        .map(|&c| {
            let p = c as f64 / total as f64;
            -p * p.log2()
        })
        .sum();
    let distinct = counts.iter().filter(|&&c| c > 0).count();
    println!("corpus bytes: {total}");
    println!("distinct byte values: {distinct}");
    println!("unigram entropy: {h:.3} bits/byte ({:.3} nats)", h * std::f64::consts::LN_2);
    println!("sample: {:?}", String::from_utf8_lossy(&corpus.bytes[..120.min(n)]));
    Ok(())
}
