//! Block partitioning + shared-scale computation (§2.1).

use super::format::QuantFormat;
use crate::simd_kernel;
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK, PAR_MIN};
use crate::util::simd::active_tier;

/// Iterator over (start, end) element ranges of the shared-scale blocks
/// of an `n`-element tensor.
pub fn block_ranges(n: usize, block_size: usize) -> impl Iterator<Item = (usize, usize)> {
    let bs = if block_size == 0 { n.max(1) } else { block_size };
    (0..n.div_ceil(bs)).map(move |b| (b * bs, ((b + 1) * bs).min(n)))
}

/// Like [`block_ranges`] but clipped to `lo..hi`: yields
/// `(block_index, start, end)` for every shared-scale block overlapping
/// the range. Lets a parallel worker handle an arbitrary element chunk
/// while still indexing the right per-block scale.
pub fn block_ranges_in(
    n: usize,
    block_size: usize,
    lo: usize,
    hi: usize,
) -> impl Iterator<Item = (usize, usize, usize)> {
    let bs = if block_size == 0 { n.max(1) } else { block_size };
    let b0 = lo / bs;
    let b1 = hi.div_ceil(bs);
    (b0..b1).map(move |b| (b, (b * bs).max(lo), ((b + 1) * bs).min(hi)))
}

/// `max` is associative and commutative, so unlike the sum kernels
/// this reduction is order-free — the SIMD tiers agree with scalar for
/// free, but it still routes through the dispatcher so the absmax scan
/// (half the RTN cast's memory traffic) widens with the ISA.
#[inline(always)]
fn abs_max_body(w: &[f32]) -> f32 {
    w.iter().fold(0f32, |m, v| m.max(v.abs()))
}

simd_kernel!(pub(crate) fn abs_max_tier(tier, w: &[f32]) -> f32 = abs_max_body);

fn abs_max(w: &[f32]) -> f32 {
    abs_max_tier(active_tier(), w)
}

/// Per-block scales `s_B = absmax(B)/qmax`; zero-absmax blocks get 1.0
/// (all-zero blocks quantize to exact zeros under any scale).
pub fn block_scales(w: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    block_scales_pool(w, fmt, &Pool::global())
}

/// [`block_scales`] on an explicit pool. Bit-identical to the serial
/// path at any thread count: small blocks are grouped whole into fixed
/// chunks, and big blocks split their absmax reduction — `max` is
/// order-independent, so the grouping can't change the result.
pub fn block_scales_pool(w: &[f32], fmt: &QuantFormat, pool: &Pool) -> Vec<f32> {
    let n = w.len();
    if n == 0 {
        return Vec::new();
    }
    let amax_to_scale = |amax: f32| if amax > 0.0 { amax / fmt.qmax } else { 1.0 };
    if n < PAR_MIN || pool.threads() == 1 {
        return block_ranges(n, fmt.block_size)
            .map(|(s, e)| amax_to_scale(abs_max(&w[s..e])))
            .collect();
    }
    let bs = if fmt.block_size == 0 { n } else { fmt.block_size };
    let nblocks = n.div_ceil(bs);
    if nblocks == 1 {
        // single block (per-tensor): parallelize the absmax reduction
        // inside it via partial maxes
        let parts = pool.run(chunk_ranges(n, PAR_CHUNK), |_, r| abs_max(&w[r]));
        return vec![amax_to_scale(parts.into_iter().fold(0f32, f32::max))];
    }
    // several blocks: whole blocks per task (>= 1 block each), all
    // dispatched through one pool call
    let blocks_per_task = (PAR_CHUNK / bs).max(1);
    let mut scales = vec![0f32; nblocks];
    let ranges = chunk_ranges(nblocks, blocks_per_task);
    pool.run_on_chunks_mut(&mut scales, &ranges, |_, r, out| {
        for (j, b) in (r.start..r.end).enumerate() {
            let (s, e) = (b * bs, ((b + 1) * bs).min(n));
            out[j] = amax_to_scale(abs_max(&w[s..e]));
        }
    });
    scales
}

/// Apply `f(element, scale)` over the tensor, block by block.
pub fn map_blocks(w: &mut [f32], fmt: &QuantFormat, scales: &[f32], mut f: impl FnMut(f32, f32) -> f32) {
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for v in &mut w[s..e] {
            *v = f(*v, sb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly() {
        let r: Vec<_> = block_ranges(10, 4).collect();
        assert_eq!(r, vec![(0, 4), (4, 8), (8, 10)]);
        let r: Vec<_> = block_ranges(10, 0).collect();
        assert_eq!(r, vec![(0, 10)]);
        let r: Vec<_> = block_ranges(8, 4).collect();
        assert_eq!(r, vec![(0, 4), (4, 8)]);
    }

    #[test]
    fn ranges_empty_tensor_yields_no_blocks() {
        assert_eq!(block_ranges(0, 4).count(), 0);
        assert_eq!(block_ranges(0, 0).count(), 0);
        let fmt = QuantFormat::int4();
        assert!(block_scales(&[], &fmt).is_empty());
    }

    #[test]
    fn block_size_larger_than_tensor_is_one_partial_block() {
        let r: Vec<_> = block_ranges(3, 8).collect();
        assert_eq!(r, vec![(0, 3)]);
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 8;
        let s = block_scales(&[1.0, -14.0, 3.5], &fmt);
        assert_eq!(s, vec![2.0]); // same as per-tensor: 14/7
    }

    #[test]
    fn per_tensor_scale() {
        let fmt = QuantFormat::int4();
        let w = [1.0f32, -14.0, 3.5];
        let s = block_scales(&w, &fmt);
        assert_eq!(s, vec![2.0]); // 14/7
    }

    #[test]
    fn per_block_scales_and_zero_block() {
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 2;
        let w = [7.0f32, -7.0, 0.0, 0.0, 1.0];
        let s = block_scales(&w, &fmt);
        assert_eq!(s, vec![1.0, 1.0, 1.0 / 7.0]);
    }

    #[test]
    fn block_ranges_in_clips_to_chunk() {
        // blocks of 4 over n=10, chunk [3, 9): touches blocks 0,1,2
        let r: Vec<_> = block_ranges_in(10, 4, 3, 9).collect();
        assert_eq!(r, vec![(0, 3, 4), (1, 4, 8), (2, 8, 9)]);
        // per-tensor: one block covering the chunk
        let r: Vec<_> = block_ranges_in(10, 0, 2, 7).collect();
        assert_eq!(r, vec![(0, 2, 7)]);
        // chunk aligned exactly on block boundaries
        let r: Vec<_> = block_ranges_in(8, 4, 4, 8).collect();
        assert_eq!(r, vec![(1, 4, 8)]);
        assert_eq!(block_ranges_in(8, 4, 4, 4).count(), 0);
    }

    #[test]
    fn pooled_scales_match_serial_bitwise() {
        use crate::util::Rng;
        let mut rng = Rng::new(3);
        let mut w = vec![0f32; 100_000];
        rng.fill_normal(&mut w);
        for block in [0usize, 64, 20_000] {
            let fmt = QuantFormat::parse("int4", block).unwrap();
            let serial = block_scales_pool(&w, &fmt, &Pool::serial());
            let par = block_scales_pool(&w, &fmt, &Pool::new(4));
            assert_eq!(serial, par, "block={block}");
        }
    }

    #[test]
    fn abs_max_tiers_match_scalar_bitwise() {
        use crate::util::simd::{supported_tiers, SimdTier};
        use crate::util::Rng;
        let mut rng = Rng::new(17);
        for n in [0usize, 1, 7, 8, 9, 65, 1000] {
            let mut w = vec![0f32; n];
            rng.fill_normal(&mut w);
            let want = abs_max_tier(SimdTier::Scalar, &w);
            for tier in supported_tiers() {
                assert_eq!(abs_max_tier(tier, &w).to_bits(), want.to_bits(), "{tier:?} n={n}");
            }
        }
    }

    #[test]
    fn map_blocks_applies_scales() {
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 2;
        let mut w = vec![7.0f32, -7.0, 14.0, 7.0];
        let s = block_scales(&w, &fmt);
        map_blocks(&mut w, &fmt, &s, |v, sb| v / sb);
        assert_eq!(w, vec![7.0, -7.0, 7.0, 3.5]);
    }
}
