//! Block partitioning + shared-scale computation (§2.1).

use super::format::QuantFormat;

/// Iterator over (start, end) element ranges of the shared-scale blocks
/// of an `n`-element tensor.
pub fn block_ranges(n: usize, block_size: usize) -> impl Iterator<Item = (usize, usize)> {
    let bs = if block_size == 0 { n.max(1) } else { block_size };
    (0..n.div_ceil(bs)).map(move |b| (b * bs, ((b + 1) * bs).min(n)))
}

/// Per-block scales `s_B = absmax(B)/qmax`; zero-absmax blocks get 1.0
/// (all-zero blocks quantize to exact zeros under any scale).
pub fn block_scales(w: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    block_ranges(w.len(), fmt.block_size)
        .map(|(s, e)| {
            let amax = w[s..e].iter().fold(0f32, |m, v| m.max(v.abs()));
            if amax > 0.0 {
                amax / fmt.qmax
            } else {
                1.0
            }
        })
        .collect()
}

/// Apply `f(element, scale)` over the tensor, block by block.
pub fn map_blocks(w: &mut [f32], fmt: &QuantFormat, scales: &[f32], mut f: impl FnMut(f32, f32) -> f32) {
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for v in &mut w[s..e] {
            *v = f(*v, sb);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_cover_exactly() {
        let r: Vec<_> = block_ranges(10, 4).collect();
        assert_eq!(r, vec![(0, 4), (4, 8), (8, 10)]);
        let r: Vec<_> = block_ranges(10, 0).collect();
        assert_eq!(r, vec![(0, 10)]);
        let r: Vec<_> = block_ranges(8, 4).collect();
        assert_eq!(r, vec![(0, 4), (4, 8)]);
    }

    #[test]
    fn ranges_empty_tensor_yields_no_blocks() {
        assert_eq!(block_ranges(0, 4).count(), 0);
        assert_eq!(block_ranges(0, 0).count(), 0);
        let fmt = QuantFormat::int4();
        assert!(block_scales(&[], &fmt).is_empty());
    }

    #[test]
    fn block_size_larger_than_tensor_is_one_partial_block() {
        let r: Vec<_> = block_ranges(3, 8).collect();
        assert_eq!(r, vec![(0, 3)]);
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 8;
        let s = block_scales(&[1.0, -14.0, 3.5], &fmt);
        assert_eq!(s, vec![2.0]); // same as per-tensor: 14/7
    }

    #[test]
    fn per_tensor_scale() {
        let fmt = QuantFormat::int4();
        let w = [1.0f32, -14.0, 3.5];
        let s = block_scales(&w, &fmt);
        assert_eq!(s, vec![2.0]); // 14/7
    }

    #[test]
    fn per_block_scales_and_zero_block() {
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 2;
        let w = [7.0f32, -7.0, 0.0, 0.0, 1.0];
        let s = block_scales(&w, &fmt);
        assert_eq!(s, vec![1.0, 1.0, 1.0 / 7.0]);
    }

    #[test]
    fn map_blocks_applies_scales() {
        let mut fmt = QuantFormat::int4();
        fmt.block_size = 2;
        let mut w = vec![7.0f32, -7.0, 14.0, 7.0];
        let s = block_scales(&w, &fmt);
        map_blocks(&mut w, &fmt, &s, |v, sb| v / sb);
        assert_eq!(w, vec![7.0, -7.0, 7.0, 3.5]);
    }
}
