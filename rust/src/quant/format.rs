//! Quantization format descriptors (mirror of python `kernels/common.py`).

use anyhow::{bail, Result};

/// E2M1 lattice: ±{0, 0.5, 1, 1.5, 2, 3, 4, 6}, 15 distinct values.
pub const FP4_LEVELS: [f32; 15] = [
    -6.0, -4.0, -3.0, -2.0, -1.5, -1.0, -0.5, 0.0, 0.5, 1.0, 1.5, 2.0, 3.0, 4.0, 6.0,
];

#[derive(Clone, Debug, PartialEq)]
pub struct QuantFormat {
    pub name: String,
    pub bits: u32,
    /// absmax maps to ±qmax in the scaled domain
    pub qmax: f32,
    /// true => integer lattice; false => FP4 codebook
    pub uniform: bool,
    /// elements per shared-scale block; 0 = per-tensor
    pub block_size: usize,
}

impl QuantFormat {
    pub fn parse(name: &str, block_size: usize) -> Result<QuantFormat> {
        let lower = name.to_ascii_lowercase();
        // `<base>@<block>` names carry their block size inline (e.g.
        // "int4@64"): the suffix overrides the argument and the full
        // string stays the format's registry key, so per-block formats
        // flow through config strings and manifest entry names
        // unchanged.
        if let Some((base, block_s)) = lower.split_once('@') {
            let block: usize = block_s
                .parse()
                .ok()
                .filter(|&b| b > 0)
                .ok_or_else(|| anyhow::anyhow!("bad block size in format {name:?}"))?;
            let mut fmt = Self::parse(base, block)?;
            fmt.name = lower.clone();
            return Ok(fmt);
        }
        if let Some(bits_s) = lower.strip_prefix("int") {
            let bits: u32 = bits_s.parse()?;
            if !(2..=8).contains(&bits) {
                bail!("unsupported int width {name:?}");
            }
            return Ok(QuantFormat {
                name: lower,
                bits,
                qmax: (2i32.pow(bits - 1) - 1) as f32,
                uniform: true,
                block_size,
            });
        }
        if lower == "fp4" {
            return Ok(QuantFormat { name: lower, bits: 4, qmax: 6.0, uniform: false, block_size });
        }
        bail!("unknown quantization format {name:?}")
    }

    pub fn int4() -> QuantFormat {
        Self::parse("int4", 0).unwrap()
    }

    pub fn int8() -> QuantFormat {
        Self::parse("int8", 0).unwrap()
    }

    pub fn fp4() -> QuantFormat {
        Self::parse("fp4", 0).unwrap()
    }

    /// Enclosing lattice bracket for a scaled value `z` ∈ [-qmax, qmax]:
    /// `(l, u)` with `l = max level <= z`, `u = min level >= z`.
    ///
    /// Codebook path is a branchless unrolled select over the 15 E2M1
    /// levels — LLVM vectorizes it. (Perf pass note: a 4-step binary
    /// search was tried and *reverted*: it sped sigma2/RR by ~1.45x but
    /// cost 3x on the RTN cast due to data-dependent branches; see
    /// EXPERIMENTS.md §Perf.)
    #[inline]
    pub fn bracket(&self, z: f32) -> (f32, f32) {
        if self.uniform {
            let l = z.floor();
            if l == z {
                (z, z)
            } else {
                (l, l + 1.0)
            }
        } else {
            let mut l = f32::NEG_INFINITY;
            let mut u = f32::INFINITY;
            for &lev in FP4_LEVELS.iter() {
                l = if lev <= z && lev > l { lev } else { l };
                u = if lev >= z && lev < u { lev } else { u };
            }
            (l, u)
        }
    }

    /// Round-to-nearest on the scaled lattice (python-parity semantics).
    #[inline]
    pub fn rtn(&self, z: f32) -> f32 {
        if self.uniform {
            // jnp.round = half-to-even
            z.round_ties_even().clamp(-self.qmax, self.qmax)
        } else {
            let (l, u) = self.bracket(z);
            let mid = 0.5 * (l + u);
            if z > mid {
                u
            } else {
                l
            }
        }
    }

    /// The packed code for a scaled value `z`: an index into
    /// [`QuantFormat::code_levels`] with `code_levels()[code_of(z)] ==
    /// rtn(z)`. Defined *through* [`QuantFormat::rtn`] rather than as a
    /// parallel rounding path, so packing and casting can never
    /// diverge. The only non-bitwise case is `-0.0`: the code table
    /// holds a single zero, so decode canonicalizes it to `+0.0`
    /// (numerically equal, and a `+0.0`-initialized accumulator never
    /// turns `-0.0` by adding signed zeros — matmul bits are unmoved).
    #[inline]
    pub fn code_of(&self, z: f32) -> u8 {
        let q = self.rtn(z);
        if self.uniform {
            // q is an exact integer in [-qmax, qmax]; int8's 0..=254
            // range is the widest and still fits a byte
            (q + self.qmax) as u8
        } else {
            // q is one of the 15 levels by construction (== finds it
            // even for the signed-zero query)
            FP4_LEVELS.iter().position(|&lev| lev == q).unwrap() as u8
        }
    }

    /// The dequant table: level value per packed code. Uniform formats
    /// enumerate the integer lattice `-qmax..=qmax` (code `q + qmax`);
    /// the codebook format is the E2M1 table itself. At most 255
    /// entries (int8), so every code fits a byte.
    pub fn code_levels(&self) -> Vec<f32> {
        if self.uniform {
            let qmax = self.qmax as i32;
            (-qmax..=qmax).map(|q| q as f32).collect()
        } else {
            FP4_LEVELS.to_vec()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_formats() {
        assert_eq!(QuantFormat::int4().qmax, 7.0);
        assert_eq!(QuantFormat::int8().qmax, 127.0);
        assert_eq!(QuantFormat::fp4().qmax, 6.0);
        assert!(QuantFormat::parse("int16", 0).is_err());
        assert!(QuantFormat::parse("fp8", 0).is_err());
    }

    #[test]
    fn parse_block_suffix() {
        let f = QuantFormat::parse("int4@64", 0).unwrap();
        assert_eq!(f.name, "int4@64");
        assert_eq!(f.bits, 4);
        assert_eq!(f.qmax, 7.0);
        assert_eq!(f.block_size, 64);
        let g = QuantFormat::parse("fp4@32", 0).unwrap();
        assert_eq!(g.name, "fp4@32");
        assert!(!g.uniform);
        assert_eq!(g.block_size, 32);
        // suffix beats the argument; bad suffixes are rejected
        assert_eq!(QuantFormat::parse("int8@16", 128).unwrap().block_size, 16);
        assert!(QuantFormat::parse("int4@0", 0).is_err());
        assert!(QuantFormat::parse("int4@x", 0).is_err());
        assert!(QuantFormat::parse("bf16@64", 0).is_err());
    }

    #[test]
    fn uniform_rtn_half_to_even() {
        let f = QuantFormat::int8();
        assert_eq!(f.rtn(0.5), 0.0); // ties to even
        assert_eq!(f.rtn(1.5), 2.0);
        assert_eq!(f.rtn(2.5), 2.0);
        assert_eq!(f.rtn(-0.5), -0.0);
        assert_eq!(f.rtn(3.4), 3.0);
    }

    #[test]
    fn fp4_bracket_and_rtn() {
        let f = QuantFormat::fp4();
        assert_eq!(f.bracket(0.7), (0.5, 1.0));
        assert_eq!(f.bracket(-2.5), (-3.0, -2.0));
        assert_eq!(f.bracket(1.0), (1.0, 1.0));
        assert_eq!(f.rtn(0.7), 0.5); // mid=0.75, 0.7 <= mid -> lower
        assert_eq!(f.rtn(0.8), 1.0);
        assert_eq!(f.rtn(5.0), 4.0); // mid(4,6)=5, tie -> lower
        assert_eq!(f.rtn(5.01), 6.0);
    }

    #[test]
    fn int_bracket_on_lattice() {
        let f = QuantFormat::int4();
        assert_eq!(f.bracket(3.0), (3.0, 3.0));
        assert_eq!(f.bracket(3.25), (3.0, 4.0));
        assert_eq!(f.bracket(-3.25), (-4.0, -3.0));
    }

    #[test]
    fn fp4_negative_zero_behaves_like_zero() {
        // -0.0 == 0.0 in IEEE comparisons, so the codebook search must
        // land exactly on the zero level, not a (-0.5, 0) bracket
        let f = QuantFormat::fp4();
        assert_eq!(f.bracket(-0.0), (0.0, 0.0));
        assert_eq!(f.rtn(-0.0), 0.0);
        // near-zero negatives: mid(-0.5, 0) = -0.25
        assert_eq!(f.rtn(-0.2), 0.0);
        assert_eq!(f.rtn(-0.3), -0.5);
        assert_eq!(f.rtn(-0.25), -0.5); // tie goes to the lower level
    }

    #[test]
    fn fp4_clamps_at_codebook_extremes() {
        // absmax scaling keeps |z| <= 6, but the lattice ops must still
        // saturate for out-of-range queries (bracket upper = +inf)
        let f = QuantFormat::fp4();
        assert_eq!(f.bracket(6.0), (6.0, 6.0));
        assert_eq!(f.bracket(6.5), (6.0, f32::INFINITY));
        assert_eq!(f.rtn(6.5), 6.0);
        assert_eq!(f.rtn(100.0), 6.0);
        assert_eq!(f.rtn(-6.0), -6.0);
        assert_eq!(f.rtn(-100.0), -6.0);
        // just inside the boundary: mid(4, 6) = 5
        assert_eq!(f.rtn(5.999), 6.0);
    }

    #[test]
    fn code_of_indexes_the_level_table() {
        for fmt in [QuantFormat::int4(), QuantFormat::int8(), QuantFormat::fp4()] {
            let levels = fmt.code_levels();
            assert!(levels.len() <= 255, "{}: codes must fit a byte", fmt.name);
            let mut zs: Vec<f32> = (0..=400).map(|i| -10.0 + 0.05 * i as f32).collect();
            zs.extend([-1e6, 1e6, -0.0, 0.0, 0.5, -0.5, 2.5, -2.5]);
            for z in zs {
                let code = fmt.code_of(z) as usize;
                assert!(code < levels.len(), "{} z={z}: code {code} out of range", fmt.name);
                let q = fmt.rtn(z);
                assert_eq!(levels[code], q, "{} z={z}", fmt.name);
                // bitwise except the canonicalized signed zero
                if q != 0.0 {
                    assert_eq!(levels[code].to_bits(), q.to_bits(), "{} z={z}", fmt.name);
                }
            }
        }
    }

    #[test]
    fn fp4_cast_absmax_maps_to_qmax_exactly() {
        use crate::quant::rounding::cast_rtn;
        let f = QuantFormat::fp4();
        let mut w = vec![0.1f32, -9.0, 0.0];
        cast_rtn(&mut w, &f);
        // scale = 9/6 = 1.5; the absmax element sits exactly on +-qmax
        assert_eq!(w[1], -9.0);
        assert_eq!(w[2], 0.0);
        // 0.1/1.5 = 0.0667 -> rounds to 0 (mid(0, 0.5) = 0.25)
        assert_eq!(w[0], 0.0);
    }
}
