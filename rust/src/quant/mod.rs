//! Rust-native quantization substrate — the paper's §2.1 fine-grained
//! shared-scale scheme, mirrored bit-for-bit from the python oracles.
//!
//! The coordinator uses this for *quantized evaluation*: training keeps
//! FP32 master weights (in PJRT literals); at eval points the
//! checkpointed weights are cast here with round-to-nearest (RTN) or
//! unbiased randomized rounding (RR) and fed to the FP32 eval
//! executable — exactly the paper's protocol ("model checkpoints are
//! quantized or rounded for evaluations", §4).
//!
//! Parity contract with `python/compile/kernels/ref.py` (tested by
//! golden files + the python test suite):
//! * scales: `s_B = absmax(B) / qmax`, zero-absmax blocks get `s = 1`;
//! * RTN (uniform): round-half-to-even (`jnp.round` semantics);
//! * RTN (codebook): ties toward the lower level (`z > mid ? u : l`);
//! * RR: round up w.p. `(z - l)/(u - l)`.
//!
//! Kernels are block-parallel on `util::pool` (serial below a size
//! threshold) and bit-identical at any thread count; RR noise comes
//! from counter-split streams keyed per fixed element chunk
//! (`rounding::cast_rr_seeded`).

pub mod blocks;
pub mod format;
pub mod packed;
pub mod rounding;

pub use format::{QuantFormat, FP4_LEVELS};
pub use packed::PackedWeights;
pub use rounding::{
    cast, cast_anneal_seeded, cast_rr, cast_rr_seeded, cast_rtn, cast_rtn_pool, lotion_penalty,
    lotion_penalty_and_grad, lotion_penalty_and_grad_pool, lotion_penalty_grad, sigma2,
    sigma2_pool, Rounding,
};
