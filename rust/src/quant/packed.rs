//! Packed block-quantized weight storage (§2.1 representation).
//!
//! The RTN-eval path historically materialized a full f32 copy of every
//! quantized tensor (`cast_rtn` into a scratch `wq` buffer) before the
//! dense matmuls consumed it. `PackedWeights` stores the same cast as
//! per-block scales plus lattice *codes* — one byte per element for
//! int5..int8, one nibble for formats with <= 16 levels (int2..int4,
//! fp4) — and the fused matmul dequantizes on the fly. That drops the
//! eval working set ~4-8x and removes the cast pass entirely.
//!
//! Exactness contract: `decode_at(i)` equals what `cast_rtn` would have
//! written at `i`, bitwise, except that signed zero canonicalizes to
//! `+0.0` (see [`QuantFormat::code_of`]; matmul results are still
//! bitwise identical because a `+0.0`-seeded accumulator is immune to
//! zero signs).

use std::sync::atomic::{AtomicUsize, Ordering};

use super::blocks::{block_ranges, block_scales_pool};
use super::format::QuantFormat;
use crate::util::Pool;

/// Counts full-tensor `decode_into` materializations, so tests can
/// assert the fused eval path never falls back to a dense f32 copy.
static DENSE_DECODES: AtomicUsize = AtomicUsize::new(0);

/// Total dense decodes since process start (monotonic; tests diff it).
pub fn dense_decode_count() -> usize {
    DENSE_DECODES.load(Ordering::Relaxed)
}

/// A block-quantized tensor: per-block scales + per-element lattice
/// codes, decoded through a small level table.
#[derive(Clone, Debug)]
pub struct PackedWeights {
    len: usize,
    /// elements per shared-scale block; 0 = per-tensor (single block)
    block_size: usize,
    scales: Vec<f32>,
    /// codes, two-per-byte (low nibble first) when `nibble`
    codes: Vec<u8>,
    /// dequant table: `lut[code] = lattice level` (scaled domain)
    lut: Vec<f32>,
    nibble: bool,
    fmt_name: String,
}

impl PackedWeights {
    /// Pack `w` under RTN rounding (serial pool).
    pub fn pack_rtn(w: &[f32], fmt: &QuantFormat) -> PackedWeights {
        Self::pack_rtn_pool(w, fmt, &Pool::serial())
    }

    /// Pack `w` under RTN rounding: per-block absmax scales (shared
    /// with `cast_rtn` via `block_scales_pool`), then one code per
    /// element. The scale computation parallelizes; the code loop is a
    /// single serial pass (eval-path packing is off the training hot
    /// loop, and the pass is bound by the same `rtn` cost as the cast
    /// it replaces).
    pub fn pack_rtn_pool(w: &[f32], fmt: &QuantFormat, pool: &Pool) -> PackedWeights {
        let scales = block_scales_pool(w, fmt, pool);
        let lut = fmt.code_levels();
        let nibble = lut.len() <= 16;
        let n = w.len();
        let mut codes = vec![0u8; if nibble { n.div_ceil(2) } else { n }];
        for (bi, (s, e)) in block_ranges(n, fmt.block_size).enumerate() {
            let sb = scales[bi];
            for i in s..e {
                let code = fmt.code_of(w[i] / sb);
                if nibble {
                    codes[i >> 1] |= code << ((i & 1) * 4);
                } else {
                    codes[i] = code;
                }
            }
        }
        PackedWeights {
            len: n,
            block_size: fmt.block_size,
            scales,
            codes,
            lut,
            nibble,
            fmt_name: fmt.name.clone(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn fmt_name(&self) -> &str {
        &self.fmt_name
    }

    pub fn block_size(&self) -> usize {
        self.block_size
    }

    /// The dequant table (scaled-domain lattice levels).
    pub fn lut(&self) -> &[f32] {
        &self.lut
    }

    /// Scale of the block containing element `i`.
    #[inline]
    pub fn scale_of(&self, i: usize) -> f32 {
        if self.block_size == 0 {
            self.scales[0]
        } else {
            self.scales[i / self.block_size]
        }
    }

    /// Per-block scales.
    pub fn scales(&self) -> &[f32] {
        &self.scales
    }

    /// Lattice code of element `i`.
    #[inline]
    pub fn code_at(&self, i: usize) -> u8 {
        if self.nibble {
            (self.codes[i >> 1] >> ((i & 1) * 4)) & 0xF
        } else {
            self.codes[i]
        }
    }

    /// Dequantized value of element `i`.
    #[inline]
    pub fn decode_at(&self, i: usize) -> f32 {
        self.lut[self.code_at(i) as usize] * self.scale_of(i)
    }

    /// Materialize the full f32 tensor into `dst`. This is the slow
    /// fallback the fused matmul exists to avoid; it bumps a global
    /// counter so tests can prove the hot path stays packed.
    pub fn decode_into(&self, dst: &mut [f32]) {
        assert_eq!(dst.len(), self.len);
        DENSE_DECODES.fetch_add(1, Ordering::Relaxed);
        for (bi, (s, e)) in block_ranges(self.len, self.block_size).enumerate() {
            let sb = self.scales[bi];
            for i in s..e {
                dst[i] = self.lut[self.code_at(i) as usize] * sb;
            }
        }
    }

    /// Payload bytes (scales + codes + lut), for traffic accounting.
    pub fn bytes(&self) -> usize {
        self.scales.len() * 4 + self.codes.len() + self.lut.len() * 4
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::rounding::cast_rtn;
    use crate::util::Rng;

    fn formats() -> Vec<QuantFormat> {
        let mut fmts = Vec::new();
        for name in ["int4", "int8", "fp4"] {
            for block in [0usize, 64] {
                fmts.push(QuantFormat::parse(name, block).unwrap());
            }
        }
        fmts
    }

    #[test]
    fn decode_matches_cast_rtn() {
        let mut rng = Rng::new(41);
        for fmt in formats() {
            for n in [1usize, 7, 64, 65, 1000] {
                let mut w = vec![0f32; n];
                rng.fill_normal(&mut w);
                let packed = PackedWeights::pack_rtn(&w, &fmt);
                let mut cast = w.clone();
                cast_rtn(&mut cast, &fmt);
                let mut dec = vec![0f32; n];
                packed.decode_into(&mut dec);
                for i in 0..n {
                    assert_eq!(dec[i], cast[i], "{} block={} i={i}", fmt.name, fmt.block_size);
                    // decode_at agrees with the bulk path bitwise
                    assert_eq!(packed.decode_at(i).to_bits(), dec[i].to_bits());
                    // bitwise vs the cast except canonicalized -0.0
                    if cast[i] != 0.0 {
                        assert_eq!(dec[i].to_bits(), cast[i].to_bits(), "{} i={i}", fmt.name);
                    }
                }
            }
        }
    }

    #[test]
    fn nibble_packing_halves_code_bytes() {
        let mut rng = Rng::new(42);
        let mut w = vec![0f32; 101];
        rng.fill_normal(&mut w);
        let p4 = PackedWeights::pack_rtn(&w, &QuantFormat::int4());
        let p8 = PackedWeights::pack_rtn(&w, &QuantFormat::int8());
        assert!(p4.nibble);
        assert!(!p8.nibble);
        assert_eq!(p4.codes.len(), 51); // ceil(101/2)
        assert_eq!(p8.codes.len(), 101);
        let pfp4 = PackedWeights::pack_rtn(&w, &QuantFormat::fp4());
        assert!(pfp4.nibble); // 15 levels fit a nibble
    }

    #[test]
    fn pool_packing_matches_serial() {
        let mut rng = Rng::new(43);
        let mut w = vec![0f32; 100_000];
        rng.fill_normal(&mut w);
        for fmt in formats() {
            let serial = PackedWeights::pack_rtn(&w, &fmt);
            let par = PackedWeights::pack_rtn_pool(&w, &fmt, &Pool::new(4));
            assert_eq!(serial.scales, par.scales, "{} block={}", fmt.name, fmt.block_size);
            assert_eq!(serial.codes, par.codes, "{} block={}", fmt.name, fmt.block_size);
        }
    }

    #[test]
    fn decode_counter_increments_only_on_dense_decode() {
        let w = vec![0.5f32, -1.0, 2.0];
        let packed = PackedWeights::pack_rtn(&w, &QuantFormat::int8());
        let before = dense_decode_count();
        let _ = packed.decode_at(1); // element access: not a dense decode
        let _ = packed.code_at(2);
        assert_eq!(dense_decode_count(), before);
        let mut dst = vec![0f32; 3];
        packed.decode_into(&mut dst);
        assert_eq!(dense_decode_count(), before + 1);
    }

    #[test]
    fn empty_tensor_packs() {
        let packed = PackedWeights::pack_rtn(&[], &QuantFormat::int4());
        assert!(packed.is_empty());
        assert_eq!(packed.bytes(), packed.lut.len() * 4);
        packed.decode_into(&mut []);
    }
}
