//! Casting: round-to-nearest and unbiased randomized rounding (§3.1),
//! plus the per-coordinate RR variance used by Fig. 6 and tests.

use super::blocks::{block_ranges, block_scales};
use super::format::QuantFormat;
use crate::util::rng::Rng;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// round-to-nearest ("RTN" in the paper's tables)
    Rtn,
    /// unbiased randomized rounding ("RR")
    Rr,
}

impl Rounding {
    pub fn parse(s: &str) -> anyhow::Result<Rounding> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Ok(Rounding::Rtn),
            "rr" => Ok(Rounding::Rr),
            other => anyhow::bail!("unknown rounding {other:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rounding::Rtn => "rtn",
            Rounding::Rr => "rr",
        }
    }
}

/// In-place RTN cast: `w <- s_B * rtn(w / s_B)`.
pub fn cast_rtn(w: &mut [f32], fmt: &QuantFormat) {
    let scales = block_scales(w, fmt);
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for v in &mut w[s..e] {
            *v = fmt.rtn(*v / sb) * sb;
        }
    }
}

/// In-place unbiased randomized-rounding cast (Def. 1 / A.2.4):
/// round up with probability `(z - l)/(u - l)`, making `E[cast] = w`.
///
/// The uniform noise is generated in a batched pre-pass so the
/// element loop has no serial RNG dependency and vectorizes (perf
/// pass: ~1.5x on the 1M-element eval cast; EXPERIMENTS.md §Perf).
pub fn cast_rr(w: &mut [f32], fmt: &QuantFormat, rng: &mut Rng) {
    let scales = block_scales(w, fmt);
    let mut noise = vec![0f32; w.len()];
    rng.fill_uniform(&mut noise);
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for (v, n) in w[s..e].iter_mut().zip(&noise[s..e]) {
            let z = *v / sb;
            let (l, u) = fmt.bracket(z);
            if u > l {
                let p_up = (z - l) / (u - l);
                *v = if *n < p_up { u } else { l } * sb;
            } else {
                *v = l * sb;
            }
        }
    }
}

/// Cast with either rounding mode.
pub fn cast(w: &mut [f32], fmt: &QuantFormat, rounding: Rounding, rng: &mut Rng) {
    match rounding {
        Rounding::Rtn => cast_rtn(w, fmt),
        Rounding::Rr => cast_rr(w, fmt, rng),
    }
}

/// Per-coordinate RR variance `sigma_i^2 = s_B^2 (u - z)(z - l)` —
/// equals `s^2 Delta (1-Delta)` on the uniform lattice (§3.2).
pub fn sigma2(w: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    let scales = block_scales(w, fmt);
    let mut out = vec![0f32; w.len()];
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for i in s..e {
            let z = w[i] / sb;
            let (l, u) = fmt.bracket(z);
            out[i] = sb * sb * (u - z) * (z - l);
        }
    }
    out
}

/// LOTION penalty (Eq. 3) on the host side — used by the native
/// backend's train step, Fig. 6 and parity tests. (The PJRT path runs
/// it in the L1 kernel instead.)
pub fn lotion_penalty(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> f64 {
    sigma2(w, fmt)
        .iter()
        .zip(fisher)
        .map(|(s2, f)| 0.5 * (*s2 as f64) * (*f as f64))
        .sum()
}

/// Gradient of the Eq. 3 penalty w.r.t. `w`, with stop-grad through the
/// block scales and the Fisher diagonal (the kernel's VJP semantics,
/// `ref.py::lotion_penalty_grad_ref`):
///
/// uniform lattice:  `d/dw [0.5 f s^2 Δ(1-Δ)] = 0.5 f s (1 - 2Δ)`
/// codebook lattice: `d/dw [0.5 f s^2 (u-z)(z-l)] = 0.5 f s (u+l-2z)`
pub fn lotion_penalty_grad(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    lotion_penalty_and_grad(w, fisher, fmt).1
}

/// Penalty value + gradient in one lattice pass (one `block_scales` +
/// one `bracket` per element instead of two — the native backend calls
/// this every optimizer step on every quantized tensor).
pub fn lotion_penalty_and_grad(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> (f64, Vec<f32>) {
    let scales = block_scales(w, fmt);
    let mut grad = vec![0f32; w.len()];
    let mut penalty = 0.0f64;
    for (bi, (s, e)) in block_ranges(w.len(), fmt.block_size).enumerate() {
        let sb = scales[bi];
        for i in s..e {
            let z = w[i] / sb;
            let (l, u) = fmt.bracket(z);
            penalty += 0.5 * (fisher[i] as f64) * (sb as f64) * (sb as f64)
                * ((u - z) as f64) * ((z - l) as f64);
            grad[i] = 0.5 * fisher[i] * sb * (u + l - 2.0 * z);
        }
    }
    (penalty, grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn rtn_idempotent() {
        forall("rtn idempotent", |r| {
            let n = r.usize_in(1, 300);
            let fmt = match r.below(3) {
                0 => QuantFormat::int4(),
                1 => QuantFormat::int8(),
                _ => QuantFormat::fp4(),
            };
            let scale = r.f32_in(0.01, 10.0);
            let mut w = r.vec_normal(n, scale);
            cast_rtn(&mut w, &fmt);
            let w1 = w.clone();
            cast_rtn(&mut w, &fmt);
            assert_eq!(w, w1);
        });
    }

    #[test]
    fn rr_lands_on_bracket() {
        forall("rr on bracket", |r| {
            let fmt = QuantFormat::int4();
            let orig = r.vec_normal(64, 1.0);
            let scales = block_scales(&orig, &fmt);
            let mut w = orig.clone();
            let mut rng = r.fork(1);
            cast_rr(&mut w, &fmt, &mut rng);
            for (i, (&o, &q)) in orig.iter().zip(&w).enumerate() {
                let z = o / scales[0];
                let (l, u) = fmt.bracket(z);
                let zq = q / scales[0];
                assert!(
                    (zq - l).abs() < 1e-5 || (zq - u).abs() < 1e-5,
                    "i={i} z={z} zq={zq} l={l} u={u}"
                );
            }
        });
    }

    #[test]
    fn rr_unbiased_statistically() {
        let fmt = QuantFormat::int4();
        let w0 = vec![0.31f32, -0.77, 0.05, 0.66, -1.0];
        let mut rng = Rng::new(11);
        let n = 20000;
        let mut sums = vec![0f64; w0.len()];
        for _ in 0..n {
            let mut w = w0.clone();
            cast_rr(&mut w, &fmt, &mut rng);
            for (s, v) in sums.iter_mut().zip(&w) {
                *s += *v as f64;
            }
        }
        for (s, &o) in sums.iter().zip(&w0) {
            let mean = s / n as f64;
            assert!((mean - o as f64).abs() < 0.01, "mean={mean} orig={o}");
        }
    }

    #[test]
    fn rr_variance_matches_sigma2() {
        let fmt = QuantFormat::fp4();
        let w0 = vec![0.31f32, -0.77, 1.4, 2.6, -4.9];
        let pred = sigma2(&w0, &fmt);
        let mut rng = Rng::new(5);
        let n = 30000;
        let mut m1 = vec![0f64; w0.len()];
        let mut m2 = vec![0f64; w0.len()];
        for _ in 0..n {
            let mut w = w0.clone();
            cast_rr(&mut w, &fmt, &mut rng);
            for i in 0..w.len() {
                m1[i] += w[i] as f64;
                m2[i] += (w[i] as f64) * (w[i] as f64);
            }
        }
        for i in 0..w0.len() {
            let mean = m1[i] / n as f64;
            let var = m2[i] / n as f64 - mean * mean;
            assert!(
                (var - pred[i] as f64).abs() < 0.15 * pred[i] as f64 + 1e-4,
                "i={i} var={var} pred={}",
                pred[i]
            );
        }
    }

    #[test]
    fn sigma2_zero_on_lattice() {
        // direct lattice construction (a cast tensor is only on the
        // lattice w.r.t. its *own* absmax scale, so build one exactly)
        let fmt = QuantFormat::int4();
        let s = 0.25f32;
        let w = vec![0.0f32, s * 3.0, -s * 7.0, s * 5.0];
        for v in sigma2(&w, &fmt) {
            assert!(v.abs() < 1e-10);
        }
    }

    #[test]
    fn penalty_grad_matches_finite_differences() {
        // absmax element (1.4) is left unperturbed, so the block scale —
        // stop-grad in the analytic form — is constant under the FD too
        let w0 = vec![0.31f32, -0.77, 0.05, 1.4];
        let fisher = vec![2.0f32, 1.0, 0.5, 0.0];
        for fmt in [QuantFormat::int4(), QuantFormat::int8(), QuantFormat::fp4()] {
            let grad = lotion_penalty_grad(&w0, &fisher, &fmt);
            let eps = 1e-4f32;
            for i in 0..3 {
                let mut hi = w0.clone();
                hi[i] += eps;
                let mut lo = w0.clone();
                lo[i] -= eps;
                let fd = (lotion_penalty(&hi, &fisher, &fmt)
                    - lotion_penalty(&lo, &fisher, &fmt)) as f32
                    / (2.0 * eps);
                assert!(
                    (fd - grad[i]).abs() < 2e-2 * grad[i].abs().max(1.0),
                    "{} i={i}: fd={fd} analytic={}",
                    fmt.name,
                    grad[i]
                );
            }
        }
    }

    #[test]
    fn penalty_grad_zero_on_lattice() {
        let fmt = QuantFormat::int4();
        let s = 0.5f32;
        let w = vec![0.0f32, s * 2.0, -s * 7.0];
        let fisher = vec![1.0f32; 3];
        for g in lotion_penalty_grad(&w, &fisher, &fmt) {
            assert!(g.abs() < 1e-6, "{g}");
        }
    }

    #[test]
    fn penalty_matches_manual_sum() {
        let fmt = QuantFormat::int4();
        let w = vec![0.31f32, -0.77, 0.05];
        let f = vec![2.0f32, 1.0, 0.5];
        let s2 = sigma2(&w, &fmt);
        let manual: f64 = s2.iter().zip(&f).map(|(a, b)| 0.5 * (*a as f64) * (*b as f64)).sum();
        assert!((lotion_penalty(&w, &f, &fmt) - manual).abs() < 1e-12);
    }

    #[test]
    fn int8_cast_error_bounded_by_half_scale() {
        forall("rtn error bound", |r| {
            let fmt = QuantFormat::int8();
            let orig = r.vec_normal(100, 3.0);
            let scales = block_scales(&orig, &fmt);
            let mut w = orig.clone();
            cast_rtn(&mut w, &fmt);
            for (&o, &q) in orig.iter().zip(&w) {
                assert!((o - q).abs() <= 0.5 * scales[0] + 1e-6);
            }
        });
    }
}
