//! Casting: round-to-nearest and unbiased randomized rounding (§3.1),
//! plus the per-coordinate RR variance used by Fig. 6 and tests.
//!
//! Every kernel here is block-parallel over pre-split scale ranges on a
//! [`Pool`], with a serial fallback below [`PAR_MIN`] total elements.
//! Chunk boundaries, RR noise streams and reduction order are pure
//! functions of the tensor size — never of the thread count — so every
//! kernel is bit-identical at `--threads 1` and `--threads N`
//! (DESIGN.md §3). RTN casts, scales and σ² are element-wise and
//! therefore also bit-identical to the pre-threaded serial kernels,
//! which keeps the python parity goldens (`tests/parity.rs`) exact.

use super::blocks::{block_ranges_in, block_scales_pool};
use super::format::QuantFormat;
use crate::simd_kernel;
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK};
use crate::util::rng::Rng;
use crate::util::simd::active_tier;
use std::cell::RefCell;
use std::ops::Range;

// ---------------------------------------------------------------------------
// per-block lattice kernels (SIMD-dispatched)
//
// Every entry point below — serial seed API, explicit-pool API, any
// thread count, any `--simd` tier — funnels through these four block
// bodies, so the rounding loop exists exactly once per operation and
// the tiers cannot diverge from each other or from the scalar
// reference. The bodies are plain element loops; the `simd_kernel!`
// wrappers recompile them per ISA tier (`util::simd`), where the
// autovectorizer widens `rtn`/`bracket` without changing operation
// order — results stay bit-identical across tiers.
// ---------------------------------------------------------------------------

/// One shared-scale block of the RTN cast: `v <- rtn(v / sb) * sb`.
#[inline(always)]
fn rtn_block_body(chunk: &mut [f32], sb: f32, fmt: &QuantFormat) {
    for v in chunk {
        *v = fmt.rtn(*v / sb) * sb;
    }
}

simd_kernel!(pub(crate) fn rtn_block(tier, chunk: &mut [f32], sb: f32, fmt: &QuantFormat) = rtn_block_body);

/// One shared-scale block of the RR cast: round up where the uniform
/// noise undershoots `(z - l)/(u - l)`. `noise` is pre-filled, aligned
/// element-for-element with `chunk`.
#[inline(always)]
fn rr_block_body(chunk: &mut [f32], noise: &[f32], sb: f32, fmt: &QuantFormat) {
    for (v, nz) in chunk.iter_mut().zip(noise) {
        let z = *v / sb;
        let (l, u) = fmt.bracket(z);
        let q = if u > l {
            let p_up = (z - l) / (u - l);
            if *nz < p_up {
                u
            } else {
                l
            }
        } else {
            l
        };
        *v = q * sb;
    }
}

simd_kernel!(pub(crate) fn rr_block(tier, chunk: &mut [f32], noise: &[f32], sb: f32, fmt: &QuantFormat) = rr_block_body);

/// One shared-scale block of the RR variance: `s_B^2 (u - z)(z - l)`.
#[inline(always)]
fn sigma2_block_body(w: &[f32], dst: &mut [f32], sb: f32, fmt: &QuantFormat) {
    for (v, d) in w.iter().zip(dst) {
        let z = *v / sb;
        let (l, u) = fmt.bracket(z);
        *d = sb * sb * (u - z) * (z - l);
    }
}

simd_kernel!(pub(crate) fn sigma2_block(tier, w: &[f32], dst: &mut [f32], sb: f32, fmt: &QuantFormat) = sigma2_block_body);

/// One shared-scale block of the Eq. 3 penalty + gradient; returns the
/// block's f64 penalty partial, accumulated in ascending element order.
#[inline(always)]
fn penalty_block_body(
    w: &[f32],
    fisher: &[f32],
    g: &mut [f32],
    sb: f32,
    fmt: &QuantFormat,
) -> f64 {
    let mut pen = 0.0f64;
    for ((v, f), gi) in w.iter().zip(fisher).zip(g) {
        let z = *v / sb;
        let (l, u) = fmt.bracket(z);
        pen += 0.5
            * (*f as f64)
            * (sb as f64)
            * (sb as f64)
            * ((u - z) as f64)
            * ((z - l) as f64);
        *gi = 0.5 * *f * sb * (u + l - 2.0 * z);
    }
    pen
}

simd_kernel!(pub(crate) fn penalty_block(tier, w: &[f32], fisher: &[f32], g: &mut [f32], sb: f32, fmt: &QuantFormat) -> f64 = penalty_block_body);

/// One shared-scale block of the additive-noise-annealing cast
/// (Spallanzani et al.): perturb with uniform noise `sigma * s_B *
/// (nz - 0.5)` and round-to-nearest on the *pre-noise* block scale.
/// `noise` is pre-filled `[0, 1)` uniforms, aligned with `chunk`.
#[inline(always)]
fn anneal_block_body(chunk: &mut [f32], noise: &[f32], sigma: f32, sb: f32, fmt: &QuantFormat) {
    for (v, nz) in chunk.iter_mut().zip(noise) {
        let z = (*v + sigma * sb * (*nz - 0.5)) / sb;
        *v = fmt.rtn(z) * sb;
    }
}

simd_kernel!(pub(crate) fn anneal_block(tier, chunk: &mut [f32], noise: &[f32], sigma: f32, sb: f32, fmt: &QuantFormat) = anneal_block_body);

thread_local! {
    /// RR noise buffer, at most one chunk (`PAR_CHUNK` f32s) long —
    /// replaces the old full-tensor-length noise `Vec` per call. Pool
    /// workers are persistent (`util::pool`), so both the serial path
    /// and every worker allocate this once per thread and reuse it
    /// across all subsequent casts.
    static NOISE: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Rounding {
    /// round-to-nearest ("RTN" in the paper's tables)
    Rtn,
    /// unbiased randomized rounding ("RR")
    Rr,
}

impl Rounding {
    pub fn parse(s: &str) -> anyhow::Result<Rounding> {
        match s.to_ascii_lowercase().as_str() {
            "rtn" => Ok(Rounding::Rtn),
            "rr" => Ok(Rounding::Rr),
            other => anyhow::bail!("unknown rounding {other:?}"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Rounding::Rtn => "rtn",
            Rounding::Rr => "rr",
        }
    }
}

/// In-place RTN cast: `w <- s_B * rtn(w / s_B)`. Thin seed API over
/// [`cast_rtn_pool`] — both share the single [`rtn_block`] kernel, so
/// there is no serial/pool loop pair to drift apart.
pub fn cast_rtn(w: &mut [f32], fmt: &QuantFormat) {
    cast_rtn_pool(w, fmt, &Pool::global())
}

/// [`cast_rtn`] on an explicit pool (element-wise, so the parallel and
/// serial paths are bitwise interchangeable).
pub fn cast_rtn_pool(w: &mut [f32], fmt: &QuantFormat, pool: &Pool) {
    let n = w.len();
    let scales = block_scales_pool(w, fmt, pool);
    let tier = active_tier();
    pool.for_chunks_mut(w, &chunk_ranges(n, PAR_CHUNK), n, |_, r, chunk| {
        for (bi, s, e) in block_ranges_in(n, fmt.block_size, r.start, r.end) {
            rtn_block(tier, &mut chunk[s - r.start..e - r.start], scales[bi], fmt);
        }
    });
}

/// In-place unbiased randomized-rounding cast (Def. 1 / A.2.4):
/// round up with probability `(z - l)/(u - l)`, making `E[cast] = w`.
///
/// The serial RNG is only used to derive one stream seed; see
/// [`cast_rr_seeded`] for the actual noise model.
pub fn cast_rr(w: &mut [f32], fmt: &QuantFormat, rng: &mut Rng) {
    cast_rr_seeded(w, fmt, rng.next_u64(), &Pool::global())
}

/// [`cast_rr`] with an explicit noise seed + pool. The uniform noise
/// for elements `[c*PAR_CHUNK, (c+1)*PAR_CHUNK)` comes from the
/// counter stream `Rng::stream(seed, &[c])` — a pure function of
/// `(seed, element index)`, so there is no serial RNG dependency to
/// break: workers cast their chunks independently and the result is
/// bit-identical at any thread count. (This replaced the PR-1 serial
/// noise pre-pass and changed the per-seed RR bitstream once.)
pub fn cast_rr_seeded(w: &mut [f32], fmt: &QuantFormat, seed: u64, pool: &Pool) {
    let n = w.len();
    let scales = block_scales_pool(w, fmt, pool);
    let tier = active_tier();
    let kernel = |ci: usize, r: Range<usize>, chunk: &mut [f32]| {
        let mut rng = Rng::stream(seed, &[ci as u64]);
        NOISE.with(|buf| {
            let mut noise = buf.borrow_mut();
            if noise.len() < r.len() {
                noise.resize(r.len(), 0.0);
            }
            let noise = &mut noise[..r.len()];
            rng.fill_uniform(noise);
            for (bi, s, e) in block_ranges_in(n, fmt.block_size, r.start, r.end) {
                rr_block(
                    tier,
                    &mut chunk[s - r.start..e - r.start],
                    &noise[s - r.start..e - r.start],
                    scales[bi],
                    fmt,
                );
            }
        });
    };
    pool.for_chunks_mut(w, &chunk_ranges(n, PAR_CHUNK), n, kernel);
}

/// In-place additive-noise-annealing cast (Spallanzani et al., "Additive
/// Noise Annealing"): each element is perturbed with uniform noise of
/// width `sigma` *measured in block-scale units* — `w + sigma * s_B * u`
/// with `u ~ U[-0.5, 0.5)` — then rounded to nearest on the block scale
/// computed from the **unperturbed** tensor. At `sigma = 0` the noise
/// term vanishes and the cast collapses to [`cast_rtn_pool`]'s lattice
/// map, which is what lets a σ→0 schedule anneal the estimator into
/// QAT over a run. The noise model mirrors [`cast_rr_seeded`]: uniforms
/// for elements `[c*PAR_CHUNK, (c+1)*PAR_CHUNK)` come from the counter
/// stream `Rng::stream(seed, &[c])`, so the cast is bit-identical at
/// any thread count.
pub fn cast_anneal_seeded(w: &mut [f32], fmt: &QuantFormat, sigma: f32, seed: u64, pool: &Pool) {
    let n = w.len();
    let scales = block_scales_pool(w, fmt, pool);
    let tier = active_tier();
    let kernel = |ci: usize, r: Range<usize>, chunk: &mut [f32]| {
        let mut rng = Rng::stream(seed, &[ci as u64]);
        NOISE.with(|buf| {
            let mut noise = buf.borrow_mut();
            if noise.len() < r.len() {
                noise.resize(r.len(), 0.0);
            }
            let noise = &mut noise[..r.len()];
            rng.fill_uniform(noise);
            for (bi, s, e) in block_ranges_in(n, fmt.block_size, r.start, r.end) {
                anneal_block(
                    tier,
                    &mut chunk[s - r.start..e - r.start],
                    &noise[s - r.start..e - r.start],
                    sigma,
                    scales[bi],
                    fmt,
                );
            }
        });
    };
    pool.for_chunks_mut(w, &chunk_ranges(n, PAR_CHUNK), n, kernel);
}

/// Cast with either rounding mode.
pub fn cast(w: &mut [f32], fmt: &QuantFormat, rounding: Rounding, rng: &mut Rng) {
    match rounding {
        Rounding::Rtn => cast_rtn(w, fmt),
        Rounding::Rr => cast_rr(w, fmt, rng),
    }
}

/// Per-coordinate RR variance `sigma_i^2 = s_B^2 (u - z)(z - l)` —
/// equals `s^2 Delta (1-Delta)` on the uniform lattice (§3.2).
pub fn sigma2(w: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    sigma2_pool(w, fmt, &Pool::global())
}

/// [`sigma2`] on an explicit pool (element-wise, bitwise path-neutral).
pub fn sigma2_pool(w: &[f32], fmt: &QuantFormat, pool: &Pool) -> Vec<f32> {
    let n = w.len();
    let scales = block_scales_pool(w, fmt, pool);
    let mut out = vec![0f32; n];
    let tier = active_tier();
    pool.for_chunks_mut(&mut out, &chunk_ranges(n, PAR_CHUNK), n, |_, r, dst| {
        for (bi, s, e) in block_ranges_in(n, fmt.block_size, r.start, r.end) {
            sigma2_block(tier, &w[s..e], &mut dst[s - r.start..e - r.start], scales[bi], fmt);
        }
    });
    out
}

/// LOTION penalty (Eq. 3) on the host side — used by Fig. 6 and parity
/// tests. Serial on purpose: its full-stream f64 sum is the quantity
/// pinned bit-for-bit by the python goldens; the train hot path uses
/// [`lotion_penalty_and_grad`] instead.
pub fn lotion_penalty(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> f64 {
    sigma2(w, fmt)
        .iter()
        .zip(fisher)
        .map(|(s2, f)| 0.5 * (*s2 as f64) * (*f as f64))
        .sum()
}

/// Gradient of the Eq. 3 penalty w.r.t. `w`, with stop-grad through the
/// block scales and the Fisher diagonal (the kernel's VJP semantics,
/// `ref.py::lotion_penalty_grad_ref`):
///
/// uniform lattice:  `d/dw [0.5 f s^2 Δ(1-Δ)] = 0.5 f s (1 - 2Δ)`
/// codebook lattice: `d/dw [0.5 f s^2 (u-z)(z-l)] = 0.5 f s (u+l-2z)`
pub fn lotion_penalty_grad(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> Vec<f32> {
    lotion_penalty_and_grad(w, fisher, fmt).1
}

/// Penalty value + gradient in one lattice pass (one `block_scales` +
/// one `bracket` per element instead of two — the native backend calls
/// this every optimizer step on every quantized tensor).
pub fn lotion_penalty_and_grad(w: &[f32], fisher: &[f32], fmt: &QuantFormat) -> (f64, Vec<f32>) {
    lotion_penalty_and_grad_pool(w, fisher, fmt, &Pool::global())
}

/// [`lotion_penalty_and_grad`] on an explicit pool. The penalty is
/// accumulated per fixed [`PAR_CHUNK`] and the partials folded in
/// chunk order, so serial and parallel runs agree bit-for-bit.
pub fn lotion_penalty_and_grad_pool(
    w: &[f32],
    fisher: &[f32],
    fmt: &QuantFormat,
    pool: &Pool,
) -> (f64, Vec<f32>) {
    let n = w.len();
    let scales = block_scales_pool(w, fmt, pool);
    let mut grad = vec![0f32; n];
    let tier = active_tier();
    let partials = pool.for_chunks_mut(&mut grad, &chunk_ranges(n, PAR_CHUNK), n, |_, r, g| {
        let mut pen = 0.0f64;
        for (bi, s, e) in block_ranges_in(n, fmt.block_size, r.start, r.end) {
            pen += penalty_block(
                tier,
                &w[s..e],
                &fisher[s..e],
                &mut g[s - r.start..e - r.start],
                scales[bi],
                fmt,
            );
        }
        pen
    });
    (partials.iter().sum(), grad)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::blocks::block_scales;
    use crate::util::prop::{forall, Gen};

    #[test]
    fn rtn_idempotent() {
        forall("rtn idempotent", |r| {
            let n = r.usize_in(1, 300);
            let fmt = match r.below(3) {
                0 => QuantFormat::int4(),
                1 => QuantFormat::int8(),
                _ => QuantFormat::fp4(),
            };
            let scale = r.f32_in(0.01, 10.0);
            let mut w = r.vec_normal(n, scale);
            cast_rtn(&mut w, &fmt);
            let w1 = w.clone();
            cast_rtn(&mut w, &fmt);
            assert_eq!(w, w1);
        });
    }

    #[test]
    fn rr_lands_on_bracket() {
        forall("rr on bracket", |r| {
            let fmt = QuantFormat::int4();
            let orig = r.vec_normal(64, 1.0);
            let scales = block_scales(&orig, &fmt);
            let mut w = orig.clone();
            let mut rng = r.fork(1);
            cast_rr(&mut w, &fmt, &mut rng);
            for (i, (&o, &q)) in orig.iter().zip(&w).enumerate() {
                let z = o / scales[0];
                let (l, u) = fmt.bracket(z);
                let zq = q / scales[0];
                assert!(
                    (zq - l).abs() < 1e-5 || (zq - u).abs() < 1e-5,
                    "i={i} z={z} zq={zq} l={l} u={u}"
                );
            }
        });
    }

    #[test]
    fn rr_unbiased_statistically() {
        let fmt = QuantFormat::int4();
        let w0 = vec![0.31f32, -0.77, 0.05, 0.66, -1.0];
        let mut rng = Rng::new(11);
        let n = 20000;
        let mut sums = vec![0f64; w0.len()];
        for _ in 0..n {
            let mut w = w0.clone();
            cast_rr(&mut w, &fmt, &mut rng);
            for (s, v) in sums.iter_mut().zip(&w) {
                *s += *v as f64;
            }
        }
        for (s, &o) in sums.iter().zip(&w0) {
            let mean = s / n as f64;
            assert!((mean - o as f64).abs() < 0.01, "mean={mean} orig={o}");
        }
    }

    #[test]
    fn rr_variance_matches_sigma2() {
        let fmt = QuantFormat::fp4();
        let w0 = vec![0.31f32, -0.77, 1.4, 2.6, -4.9];
        let pred = sigma2(&w0, &fmt);
        let mut rng = Rng::new(5);
        let n = 30000;
        let mut m1 = vec![0f64; w0.len()];
        let mut m2 = vec![0f64; w0.len()];
        for _ in 0..n {
            let mut w = w0.clone();
            cast_rr(&mut w, &fmt, &mut rng);
            for i in 0..w.len() {
                m1[i] += w[i] as f64;
                m2[i] += (w[i] as f64) * (w[i] as f64);
            }
        }
        for i in 0..w0.len() {
            let mean = m1[i] / n as f64;
            let var = m2[i] / n as f64 - mean * mean;
            assert!(
                (var - pred[i] as f64).abs() < 0.15 * pred[i] as f64 + 1e-4,
                "i={i} var={var} pred={}",
                pred[i]
            );
        }
    }

    #[test]
    fn sigma2_zero_on_lattice() {
        // direct lattice construction (a cast tensor is only on the
        // lattice w.r.t. its *own* absmax scale, so build one exactly)
        let fmt = QuantFormat::int4();
        let s = 0.25f32;
        let w = vec![0.0f32, s * 3.0, -s * 7.0, s * 5.0];
        for v in sigma2(&w, &fmt) {
            assert!(v.abs() < 1e-10);
        }
    }

    #[test]
    fn penalty_grad_matches_finite_differences() {
        // absmax element (1.4) is left unperturbed, so the block scale —
        // stop-grad in the analytic form — is constant under the FD too
        let w0 = vec![0.31f32, -0.77, 0.05, 1.4];
        let fisher = vec![2.0f32, 1.0, 0.5, 0.0];
        for fmt in [QuantFormat::int4(), QuantFormat::int8(), QuantFormat::fp4()] {
            let grad = lotion_penalty_grad(&w0, &fisher, &fmt);
            let eps = 1e-4f32;
            for i in 0..3 {
                let mut hi = w0.clone();
                hi[i] += eps;
                let mut lo = w0.clone();
                lo[i] -= eps;
                let fd = (lotion_penalty(&hi, &fisher, &fmt)
                    - lotion_penalty(&lo, &fisher, &fmt)) as f32
                    / (2.0 * eps);
                assert!(
                    (fd - grad[i]).abs() < 2e-2 * grad[i].abs().max(1.0),
                    "{} i={i}: fd={fd} analytic={}",
                    fmt.name,
                    grad[i]
                );
            }
        }
    }

    #[test]
    fn penalty_grad_zero_on_lattice() {
        let fmt = QuantFormat::int4();
        let s = 0.5f32;
        let w = vec![0.0f32, s * 2.0, -s * 7.0];
        let fisher = vec![1.0f32; 3];
        for g in lotion_penalty_grad(&w, &fisher, &fmt) {
            assert!(g.abs() < 1e-6, "{g}");
        }
    }

    #[test]
    fn penalty_matches_manual_sum() {
        let fmt = QuantFormat::int4();
        let w = vec![0.31f32, -0.77, 0.05];
        let f = vec![2.0f32, 1.0, 0.5];
        let s2 = sigma2(&w, &fmt);
        let manual: f64 = s2.iter().zip(&f).map(|(a, b)| 0.5 * (*a as f64) * (*b as f64)).sum();
        assert!((lotion_penalty(&w, &f, &fmt) - manual).abs() < 1e-12);
    }

    #[test]
    fn int8_cast_error_bounded_by_half_scale() {
        forall("rtn error bound", |r| {
            let fmt = QuantFormat::int8();
            let orig = r.vec_normal(100, 3.0);
            let scales = block_scales(&orig, &fmt);
            let mut w = orig.clone();
            cast_rtn(&mut w, &fmt);
            for (&o, &q) in orig.iter().zip(&w) {
                assert!((o - q).abs() <= 0.5 * scales[0] + 1e-6);
            }
        });
    }

    /// The tentpole's determinism contract: every kernel bit-identical
    /// at thread counts 1 / 3 / 4, above and below the serial cutoff.
    #[test]
    fn kernels_are_thread_count_invariant() {
        let mut rng = Rng::new(17);
        for n in [1000usize, 100_000] {
            let mut w = vec![0f32; n];
            rng.fill_normal(&mut w);
            let fisher: Vec<f32> = (0..n).map(|i| 1.0 / (1 + i % 7) as f32).collect();
            for block in [0usize, 64] {
                let fmt = QuantFormat::parse("int4", block).unwrap();
                let pools = [Pool::serial(), Pool::new(3), Pool::new(4)];

                let rtn: Vec<Vec<f32>> = pools
                    .iter()
                    .map(|p| {
                        let mut v = w.clone();
                        cast_rtn_pool(&mut v, &fmt, p);
                        v
                    })
                    .collect();
                assert_eq!(rtn[0], rtn[1], "rtn n={n} block={block}");
                assert_eq!(rtn[0], rtn[2], "rtn n={n} block={block}");

                let rr: Vec<Vec<f32>> = pools
                    .iter()
                    .map(|p| {
                        let mut v = w.clone();
                        cast_rr_seeded(&mut v, &fmt, 99, p);
                        v
                    })
                    .collect();
                assert_eq!(rr[0], rr[1], "rr n={n} block={block}");
                assert_eq!(rr[0], rr[2], "rr n={n} block={block}");

                let s2: Vec<Vec<f32>> =
                    pools.iter().map(|p| sigma2_pool(&w, &fmt, p)).collect();
                assert_eq!(s2[0], s2[1], "sigma2 n={n} block={block}");
                assert_eq!(s2[0], s2[2], "sigma2 n={n} block={block}");

                let pg: Vec<(f64, Vec<f32>)> = pools
                    .iter()
                    .map(|p| lotion_penalty_and_grad_pool(&w, &fisher, &fmt, p))
                    .collect();
                assert_eq!(pg[0].0.to_bits(), pg[1].0.to_bits(), "pen n={n} block={block}");
                assert_eq!(pg[0].1, pg[1].1, "pen grad n={n} block={block}");
                assert_eq!(pg[0].0.to_bits(), pg[2].0.to_bits(), "pen n={n} block={block}");
                assert_eq!(pg[0].1, pg[2].1, "pen grad n={n} block={block}");
            }
        }
    }

    /// The dispatch contract: every supported SIMD tier runs the four
    /// block kernels bit-identically to the scalar reference, across
    /// lengths hitting every remainder lane.
    #[test]
    fn block_kernels_are_tier_invariant() {
        use crate::util::simd::{supported_tiers, SimdTier};
        let mut rng = Rng::new(29);
        for fmt in [QuantFormat::int4(), QuantFormat::int8(), QuantFormat::fp4()] {
            for n in [1usize, 7, 8, 9, 64, 65, 1000] {
                let mut w = vec![0f32; n];
                rng.fill_normal(&mut w);
                let mut noise = vec![0f32; n];
                rng.fill_uniform(&mut noise);
                let fisher: Vec<f32> = (0..n).map(|i| 1.0 / (1 + i % 5) as f32).collect();
                let sb = 0.37f32;

                let mut rtn0 = w.clone();
                rtn_block(SimdTier::Scalar, &mut rtn0, sb, &fmt);
                let mut rr0 = w.clone();
                rr_block(SimdTier::Scalar, &mut rr0, &noise, sb, &fmt);
                let mut s20 = vec![0f32; n];
                sigma2_block(SimdTier::Scalar, &w, &mut s20, sb, &fmt);
                let mut g0 = vec![0f32; n];
                let p0 = penalty_block(SimdTier::Scalar, &w, &fisher, &mut g0, sb, &fmt);

                for tier in supported_tiers() {
                    let mut rtn = w.clone();
                    rtn_block(tier, &mut rtn, sb, &fmt);
                    assert_eq!(rtn, rtn0, "rtn {} {tier:?} n={n}", fmt.name);
                    let mut rr = w.clone();
                    rr_block(tier, &mut rr, &noise, sb, &fmt);
                    assert_eq!(rr, rr0, "rr {} {tier:?} n={n}", fmt.name);
                    let mut s2 = vec![0f32; n];
                    sigma2_block(tier, &w, &mut s2, sb, &fmt);
                    assert_eq!(s2, s20, "sigma2 {} {tier:?} n={n}", fmt.name);
                    let mut g = vec![0f32; n];
                    let p = penalty_block(tier, &w, &fisher, &mut g, sb, &fmt);
                    assert_eq!(p.to_bits(), p0.to_bits(), "pen {} {tier:?} n={n}", fmt.name);
                    assert_eq!(g, g0, "pen grad {} {tier:?} n={n}", fmt.name);
                }
            }
        }
    }

    /// σ = 0 must collapse the annealing cast to the plain RTN lattice
    /// map bit-for-bit — that reduction is what makes a σ→0 schedule
    /// anneal the estimator into QAT.
    #[test]
    fn anneal_sigma_zero_is_rtn() {
        let mut rng = Rng::new(31);
        for n in [5usize, 1000, 100_000] {
            let mut w = vec![0f32; n];
            rng.fill_normal(&mut w);
            for block in [0usize, 64] {
                let fmt = QuantFormat::parse("int4", block).unwrap();
                let mut a = w.clone();
                cast_anneal_seeded(&mut a, &fmt, 0.0, 77, &Pool::new(2));
                let mut r = w.clone();
                cast_rtn_pool(&mut r, &fmt, &Pool::new(2));
                assert_eq!(a, r, "n={n} block={block}");
            }
        }
    }

    /// The annealing cast keeps the crate's determinism contract:
    /// bit-identical at any thread count, per-seed deterministic, and
    /// actually perturbed by a nonzero σ.
    #[test]
    fn anneal_cast_is_thread_invariant_and_seeded() {
        let mut rng = Rng::new(37);
        let mut w = vec![0f32; 100_000];
        rng.fill_normal(&mut w);
        let fmt = QuantFormat::int4();
        let cast_with = |sigma: f32, seed: u64, threads: usize| {
            let mut v = w.clone();
            cast_anneal_seeded(&mut v, &fmt, sigma, seed, &Pool::new(threads));
            v
        };
        assert_eq!(cast_with(0.8, 7, 1), cast_with(0.8, 7, 3));
        assert_eq!(cast_with(0.8, 7, 1), cast_with(0.8, 7, 4));
        assert_eq!(cast_with(0.8, 7, 2), cast_with(0.8, 7, 2));
        assert_ne!(cast_with(0.8, 7, 2), cast_with(0.8, 8, 2), "seed must move the noise");
        assert_ne!(cast_with(0.8, 7, 2), cast_with(0.0, 7, 2), "sigma must move the cast");
        // every output still lies on the (pre-noise scale) lattice
        let scales = block_scales(&w, &fmt);
        for &q in &cast_with(1.0, 9, 2) {
            let z = q / scales[0];
            assert!((z - fmt.rtn(z)).abs() < 1e-5, "off-lattice output {q}");
        }
    }

    /// Same seed -> same RR cast; different seed -> different cast.
    #[test]
    fn rr_seeded_is_deterministic_per_seed() {
        let fmt = QuantFormat::int4();
        let mut rng = Rng::new(23);
        let mut w = vec![0f32; 4096];
        rng.fill_normal(&mut w);
        let cast_with = |seed: u64| {
            let mut v = w.clone();
            cast_rr_seeded(&mut v, &fmt, seed, &Pool::new(2));
            v
        };
        assert_eq!(cast_with(7), cast_with(7));
        assert_ne!(cast_with(7), cast_with(8));
    }
}
