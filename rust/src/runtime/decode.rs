//! A typed generation handle over one engine: the [`Decoder`] is to the
//! `decode_*` entries what [`Session`](super::Session) is to the
//! train/eval entries — it owns the resolved entry, the FP32 weight
//! `Value`s, and the `[tokens, ctl]` argument packing, so callers speak
//! "prefill this prompt into slot 3, then step it" instead of the raw
//! positional calling convention.
//!
//! The weight `Value`s are held for the handle's lifetime and shipped
//! *by `Rc` identity* on every call: the native engine keys its packed
//! weight cache on those pointers, so the expensive RTN pack happens
//! exactly once per `Decoder`, and every subsequent prefill/step runs
//! the fused packed-GEMV path with zero dense decodes.
//!
//! Sampling lives here too ([`sample_token`]) and is pure host-side
//! arithmetic off counter-split RNG streams: the sampled token for
//! `(seed, request, position)` is a function of the logits alone —
//! independent of thread count, engine assignment, and the order the
//! serving layer admits requests in.

use super::executor::{value, Executor, Value};
use super::manifest::{ArtifactEntry, Role};
use crate::tensor::HostTensor;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

/// One model's generation handle on an engine (see module docs).
pub struct Decoder<'e> {
    engine: &'e dyn Executor,
    entry: ArtifactEntry,
    /// weight args in entry order; `Rc` identity doubles as the
    /// engine-side packed-cache key
    params: Vec<Value>,
    vocab: usize,
    max_seq: usize,
}

impl<'e> Decoder<'e> {
    /// Open a decoder: resolve `decode_{model}_{format}` from the
    /// engine's manifest (`format: "none"` is the dense-weight entry)
    /// and validate the named FP32 master weights against its param
    /// specs. Weights are adopted as-is — quantized formats are cast
    /// and packed engine-side on first use.
    pub fn open(
        engine: &'e dyn Executor,
        model: &str,
        format: &str,
        weights: &[(String, Value)],
    ) -> Result<Decoder<'e>> {
        let entry = engine
            .manifest()
            .find_decode(model, format)
            .ok_or_else(|| anyhow!("no decode entry for model {model:?} format {format:?}"))?
            .clone();
        let logits = entry
            .outputs
            .first()
            .ok_or_else(|| anyhow!("{}: decode entry has no outputs", entry.name))?;
        let vocab = logits.shape[0];
        let max_seq = entry
            .input_index("tokens")
            .map(|i| entry.inputs[i].shape[0])
            .ok_or_else(|| anyhow!("{}: decode entry has no tokens input", entry.name))?;
        let mut params = Vec::new();
        for spec in entry.input_specs(Role::Param) {
            let v = weights
                .iter()
                .find(|(n, _)| n == &spec.name)
                .map(|(_, v)| v.clone())
                .ok_or_else(|| anyhow!("{}: missing weight {:?}", entry.name, spec.name))?;
            super::executor::check_value(&v, spec)?;
            params.push(v);
        }
        Ok(Decoder { engine, entry, params, vocab, max_seq })
    }

    /// Logits width per step.
    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// Maximum cached positions per sequence (prompt + generation).
    pub fn max_seq(&self) -> usize {
        self.max_seq
    }

    pub fn entry(&self) -> &ArtifactEntry {
        &self.entry
    }

    fn call(&self, tokens: Vec<i32>, ctl: [i32; 3]) -> Result<Vec<f32>> {
        let mut args = self.params.clone();
        args.push(value(HostTensor::from_i32(&[self.max_seq], tokens)));
        args.push(value(HostTensor::from_i32(&[3], ctl.to_vec())));
        let out = self.engine.call(&self.entry, &args)?;
        Ok(out[0].as_f32())
    }

    /// Ingest `prompt` into sequence slot `slot` (opening it, or
    /// resetting it if it was live) and return the logits at the
    /// prompt's last position.
    pub fn prefill(&self, slot: i32, prompt: &[i32]) -> Result<Vec<f32>> {
        if prompt.is_empty() || prompt.len() > self.max_seq {
            bail!(
                "{}: prompt of {} tokens (want 1..={})",
                self.entry.name,
                prompt.len(),
                self.max_seq
            );
        }
        let mut tokens = vec![0i32; self.max_seq];
        tokens[..prompt.len()].copy_from_slice(prompt);
        self.call(tokens, [slot, 0, prompt.len() as i32])
    }

    /// Append `token` to slot `slot` at position `pos` (== the slot's
    /// current length) and return the next-token logits.
    pub fn step(&self, slot: i32, pos: usize, token: i32) -> Result<Vec<f32>> {
        let mut tokens = vec![0i32; self.max_seq];
        tokens[0] = token;
        self.call(tokens, [slot, pos as i32, 1])
    }
}

/// Sample a token from next-token logits. `temperature <= 0` is greedy
/// (argmax, first max wins). Otherwise: f64 softmax at the given
/// temperature, inverted at a single uniform drawn from the
/// counter-split stream `(seed, [request, position])` — so the result
/// depends only on `(logits, temperature, seed, request, position)`,
/// never on sampling order, thread count, or which engine ran the step
/// (the serving layer's determinism contract, DESIGN.md §8).
pub fn sample_token(
    logits: &[f32],
    temperature: f32,
    seed: u64,
    request: u64,
    position: u64,
) -> usize {
    debug_assert!(!logits.is_empty());
    if temperature <= 0.0 {
        let mut best = 0;
        for (i, &v) in logits.iter().enumerate() {
            if v > logits[best] {
                best = i;
            }
        }
        return best;
    }
    let inv_t = 1.0 / temperature as f64;
    let max = logits.iter().fold(f64::NEG_INFINITY, |m, &v| m.max(v as f64));
    let weights: Vec<f64> = logits.iter().map(|&v| ((v as f64 - max) * inv_t).exp()).collect();
    let total: f64 = weights.iter().sum();
    let u = Rng::stream(seed, &[request, position]).uniform() * total;
    let mut acc = 0.0;
    for (i, w) in weights.iter().enumerate() {
        acc += w;
        if u < acc {
            return i;
        }
    }
    logits.len() - 1 // u == total under rounding: clamp to the last token
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;

    /// Init a model's weights through its init entry, named per spec.
    fn init_weights(engine: &NativeEngine, model: &str, key: [u32; 2]) -> Vec<(String, Value)> {
        let init = engine.manifest().find_init(model).unwrap().clone();
        let args = vec![value(HostTensor::from_u32(&[2], key.to_vec()))];
        let out = engine.call(&init, &args).unwrap();
        init.outputs.iter().map(|s| s.name.clone()).zip(out).collect()
    }

    #[test]
    fn decoder_prefills_and_steps_lm_tiny() {
        let engine = NativeEngine::new();
        let weights = init_weights(&engine, "lm-tiny", [3, 5]);
        let dec = Decoder::open(&engine, "lm-tiny", "int4", &weights).unwrap();
        assert_eq!(dec.vocab(), 256);
        assert_eq!(dec.max_seq(), 64);
        let prompt = [5i32, 9, 2];
        let l0 = dec.prefill(0, &prompt).unwrap();
        assert_eq!(l0.len(), 256);
        let t0 = sample_token(&l0, 0.0, 1, 0, 0) as i32;
        let l1 = dec.step(0, prompt.len(), t0).unwrap();
        assert_eq!(l1.len(), 256);
        // a second prefill of the same prompt into another slot must
        // reproduce the first bitwise (packed cache is weight-keyed,
        // slot state is independent)
        let l0b = dec.prefill(1, &prompt).unwrap();
        assert_eq!(
            l0.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            l0b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        // prompt-length guards fire before the engine call
        assert!(dec.prefill(2, &[]).is_err());
        assert!(dec.prefill(2, &vec![1i32; 65]).is_err());
    }

    #[test]
    fn decoder_open_validates_weights() {
        let engine = NativeEngine::new();
        let mut weights = init_weights(&engine, "lm-tiny", [3, 5]);
        // unregistered format -> no entry
        assert!(Decoder::open(&engine, "lm-tiny", "int2", &weights).is_err());
        // missing weight
        let dropped = weights.remove(0);
        let err = Decoder::open(&engine, "lm-tiny", "none", &weights).unwrap_err();
        assert!(err.to_string().contains("missing weight"), "{err}");
        // wrong shape
        weights.insert(
            0,
            (dropped.0.clone(), value(HostTensor::zeros(crate::tensor::DType::F32, &[3]))),
        );
        assert!(Decoder::open(&engine, "lm-tiny", "none", &weights).is_err());
        // no decode entry for testbed models
        assert!(Decoder::open(&engine, "linreg_d256", "none", &[]).is_err());
    }

    #[test]
    fn greedy_sampling_prefers_first_max() {
        assert_eq!(sample_token(&[0.1, 0.9, 0.9, 0.3], 0.0, 0, 0, 0), 1);
        assert_eq!(sample_token(&[2.0, 1.0], -1.0, 0, 0, 0), 0);
    }

    #[test]
    fn sampling_is_a_pure_function_of_seed_and_counters() {
        let logits: Vec<f32> = (0..16).map(|i| ((i * 7 % 16) as f32) * 0.25).collect();
        let a = sample_token(&logits, 0.8, 42, 3, 9);
        let b = sample_token(&logits, 0.8, 42, 3, 9);
        assert_eq!(a, b);
        // distinct counters decorrelate: across many positions the
        // samples must not all collapse to one token
        let mut seen = std::collections::HashSet::new();
        for pos in 0..64 {
            seen.insert(sample_token(&logits, 1.5, 42, 3, pos));
        }
        assert!(seen.len() > 4, "only {} distinct tokens", seen.len());
        assert!(seen.iter().all(|&t| t < 16));
    }

    #[test]
    fn low_temperature_approaches_greedy() {
        let logits = [0.0f32, 4.0, 1.0, 3.9];
        for pos in 0..32 {
            assert_eq!(sample_token(&logits, 0.01, 7, 1, pos), 1);
        }
    }
}
