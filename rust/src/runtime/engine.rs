//! PJRT engine (`--features pjrt`): client + compiled-executable cache
//! + flat-tuple calls.
//!
//! Executables are compiled from HLO text once per process and cached.
//! A call takes positional `Literal`s matching the manifest's input
//! specs and returns the decomposed output tuple (the PJRT build on
//! this image returns one tuple buffer; `decompose_tuple` splits it on
//! the host — see DESIGN.md §2). The [`Executor`] impl converts the
//! coordinator's backend-neutral [`Value`]s at the call boundary,
//! through a literal cache keyed on `Rc` pointer identity: a train
//! chunk's outputs are cached as (host value, literal) pairs, so when
//! the trainer hands the same `Rc`s back as the next chunk's inputs
//! (params/opt state round-tripping through `TrainState`, statics
//! reused every call) no re-encoding happens — restoring the zero-copy
//! state round-trip the pre-Executor engine had (ROADMAP item).

use super::executor::{check_args, value, Executor, Value};
use super::literals;
use super::manifest::{ArtifactEntry, Manifest};
use crate::info;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::{Rc, Weak};
use std::time::Instant;

/// A cached `Value ⇄ Literal` pair. The weak handle guards against
/// pointer reuse: a hit counts only if the cached host tensor is still
/// alive *and* is the very `Rc` being passed (`Rc::ptr_eq`), so a
/// freed-and-reallocated address can never alias a stale literal.
struct CachedLiteral {
    host: Weak<crate::tensor::HostTensor>,
    lit: literals::Literal,
}

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// `Rc` pointer identity → encoded literal (state round-trip cache)
    lit_cache: RefCell<HashMap<usize, CachedLiteral>>,
    /// cache-effectiveness counters: (hits, misses)
    lit_stats: RefCell<(u64, u64)>,
    /// cumulative timing: (artifact, compile_s, calls, exec_s)
    timings: RefCell<HashMap<String, (f64, u64, f64)>>,
}

impl Engine {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        info!(
            "PJRT client up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            lit_cache: RefCell::new(HashMap::new()),
            lit_stats: RefCell::new((0, 0)),
            timings: RefCell::new(HashMap::new()),
        })
    }

    /// (hits, misses) of the Value⇄Literal state cache.
    pub fn literal_cache_stats(&self) -> (u64, u64) {
        *self.lit_stats.borrow()
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&entry.name) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {:?}: {e}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", entry.name))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        info!("compiled {} in {:.2}s", entry.name, dt);
        self.timings.borrow_mut().entry(entry.name.clone()).or_insert((dt, 0, 0.0));
        self.cache.borrow_mut().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with positional literal inputs; returns the
    /// decomposed output tuple (one literal per manifest output spec).
    pub fn call_literals(
        &self,
        entry: &ArtifactEntry,
        args: &[literals::Literal],
    ) -> Result<Vec<literals::Literal>> {
        if args.len() != entry.inputs.len() {
            bail!(
                "{}: got {} args, manifest expects {}",
                entry.name,
                args.len(),
                entry.inputs.len()
            );
        }
        if cfg!(debug_assertions) {
            for (lit, spec) in args.iter().zip(&entry.inputs) {
                literals::check_spec(lit, spec).with_context(|| entry.name.clone())?;
            }
        }
        let exe = self.load(entry)?;
        let t0 = Instant::now();
        let out = exe
            .execute::<literals::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", entry.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} output: {e}", entry.name))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", entry.name))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest expects {}",
                entry.name,
                parts.len(),
                entry.outputs.len()
            );
        }
        if let Some(t) = self.timings.borrow_mut().get_mut(&entry.name) {
            t.1 += 1;
            t.2 += t0.elapsed().as_secs_f64();
        }
        Ok(parts)
    }
}

impl Executor for Engine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>> {
        check_args(entry, args)?;
        // encode inputs, pulling cached literals by Rc identity (cache
        // entries are moved out for the call and reinstated after, so
        // the same literal is never aliased)
        let mut lits: Vec<literals::Literal> = Vec::with_capacity(args.len());
        for v in args {
            let key = Rc::as_ptr(v) as usize;
            let hit = self
                .lit_cache
                .borrow_mut()
                .remove(&key)
                .filter(|c| c.host.upgrade().map_or(false, |rc| Rc::ptr_eq(&rc, v)));
            match hit {
                Some(c) => {
                    self.lit_stats.borrow_mut().0 += 1;
                    lits.push(c.lit);
                }
                None => {
                    self.lit_stats.borrow_mut().1 += 1;
                    lits.push(literals::to_literal(v)?);
                }
            }
        }
        let parts = self.call_literals(entry, &lits)?;
        // reinstate input literals (statics / val batches recur across
        // calls) and cache each output literal against the host value
        // it decodes to — the next chunk's param/opt inputs are exactly
        // those Rc's, so the round-trip re-encoding disappears.
        {
            let mut cache = self.lit_cache.borrow_mut();
            for (v, lit) in args.iter().zip(lits) {
                let cached = CachedLiteral { host: Rc::downgrade(v), lit };
                cache.insert(Rc::as_ptr(v) as usize, cached);
            }
        }
        let mut out = Vec::with_capacity(parts.len());
        for lit in parts {
            let host = value(literals::to_host(&lit)?);
            let cached = CachedLiteral { host: Rc::downgrade(&host), lit };
            self.lit_cache.borrow_mut().insert(Rc::as_ptr(&host) as usize, cached);
            out.push(host);
        }
        // drop entries whose host tensors are gone (bounds the cache to
        // live state: params, opt moments, statics, data chunks)
        self.lit_cache.borrow_mut().retain(|_, c| c.host.strong_count() > 0);
        Ok(out)
    }

    /// Per-artifact (compile_s, calls, total_exec_s) — the L3 profile
    /// used by the perf pass and `lotion-rs inspect`.
    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, (c, n, e))| (k.clone(), *c, *n, *e))
            .collect();
        rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
        rows
    }
}

/// Stub [`ExecutorFactory`](super::ExecutorFactory) for the PJRT
/// backend: each `spawn` loads the artifact directory into a fresh,
/// thread-owned [`Engine`] (its own PJRT client, executable cache and
/// literal cache). XLA owns its own intra-op threading, so sharding a
/// sweep across PJRT engines oversubscribes unless the XLA thread pool
/// is pinned — this factory exists for API completeness; the sweep
/// default of one worker keeps PJRT serial until that is wired.
pub struct PjrtFactory {
    artifacts_dir: std::path::PathBuf,
}

impl PjrtFactory {
    pub fn new(artifacts_dir: &std::path::Path) -> PjrtFactory {
        PjrtFactory { artifacts_dir: artifacts_dir.to_path_buf() }
    }
}

impl super::factory::ExecutorFactory for PjrtFactory {
    fn spawn(&self) -> Result<Box<dyn Executor>> {
        Ok(Box::new(Engine::new(&self.artifacts_dir)?))
    }

    fn describe(&self) -> String {
        format!("pjrt ({})", self.artifacts_dir.display())
    }
}
