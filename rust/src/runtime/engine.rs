//! PJRT engine (`--features pjrt`): client + compiled-executable cache
//! + flat-tuple calls.
//!
//! Executables are compiled from HLO text once per process and cached.
//! A call takes positional `Literal`s matching the manifest's input
//! specs and returns the decomposed output tuple (the PJRT build on
//! this image returns one tuple buffer; `decompose_tuple` splits it on
//! the host — see DESIGN.md §2). The [`Executor`] impl converts the
//! coordinator's backend-neutral [`Value`]s at the call boundary.

use super::executor::{check_args, value, Executor, Value};
use super::literals;
use super::manifest::{ArtifactEntry, Manifest};
use crate::info;
use anyhow::{anyhow, bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
    /// cumulative timing: (artifact, compile_s, calls, exec_s)
    timings: RefCell<HashMap<String, (f64, u64, f64)>>,
}

impl Engine {
    pub fn new(artifacts_dir: &std::path::Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        info!(
            "PJRT client up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.artifacts.len()
        );
        Ok(Engine {
            client,
            manifest,
            cache: RefCell::new(HashMap::new()),
            timings: RefCell::new(HashMap::new()),
        })
    }

    /// Compile (or fetch from cache) the executable for an artifact.
    pub fn load(&self, entry: &ArtifactEntry) -> Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&entry.name) {
            return Ok(exe.clone());
        }
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parsing {:?}: {e}", entry.file))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(
            self.client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {}: {e}", entry.name))?,
        );
        let dt = t0.elapsed().as_secs_f64();
        info!("compiled {} in {:.2}s", entry.name, dt);
        self.timings.borrow_mut().entry(entry.name.clone()).or_insert((dt, 0, 0.0));
        self.cache.borrow_mut().insert(entry.name.clone(), exe.clone());
        Ok(exe)
    }

    /// Execute an artifact with positional literal inputs; returns the
    /// decomposed output tuple (one literal per manifest output spec).
    pub fn call_literals(
        &self,
        entry: &ArtifactEntry,
        args: &[literals::Literal],
    ) -> Result<Vec<literals::Literal>> {
        if args.len() != entry.inputs.len() {
            bail!(
                "{}: got {} args, manifest expects {}",
                entry.name,
                args.len(),
                entry.inputs.len()
            );
        }
        if cfg!(debug_assertions) {
            for (lit, spec) in args.iter().zip(&entry.inputs) {
                literals::check_spec(lit, spec).with_context(|| entry.name.clone())?;
            }
        }
        let exe = self.load(entry)?;
        let t0 = Instant::now();
        let out = exe
            .execute::<literals::Literal>(args)
            .map_err(|e| anyhow!("executing {}: {e}", entry.name))?;
        let tuple = out[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching {} output: {e}", entry.name))?;
        let parts = tuple.to_tuple().map_err(|e| anyhow!("untupling {}: {e}", entry.name))?;
        if parts.len() != entry.outputs.len() {
            bail!(
                "{}: executable returned {} outputs, manifest expects {}",
                entry.name,
                parts.len(),
                entry.outputs.len()
            );
        }
        if let Some(t) = self.timings.borrow_mut().get_mut(&entry.name) {
            t.1 += 1;
            t.2 += t0.elapsed().as_secs_f64();
        }
        Ok(parts)
    }
}

impl Executor for Engine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>> {
        check_args(entry, args)?;
        let lits: Vec<literals::Literal> = args
            .iter()
            .map(|v| literals::to_literal(v))
            .collect::<Result<_>>()?;
        let parts = self.call_literals(entry, &lits)?;
        parts
            .iter()
            .map(|l| Ok(value(literals::to_host(l)?)))
            .collect()
    }

    /// Per-artifact (compile_s, calls, total_exec_s) — the L3 profile
    /// used by the perf pass and `lotion-rs inspect`.
    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, (c, n, e))| (k.clone(), *c, *n, *e))
            .collect();
        rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
        rows
    }
}
