//! The backend abstraction: every execution backend (PJRT, native
//! pure-rust, future sharded/threaded engines) implements [`Executor`]
//! and the whole coordinator — trainer, evaluator, sweeps, experiments,
//! CLI — runs against `&dyn Executor` (DESIGN.md §3).
//!
//! Values cross the backend boundary as [`Value`]s: reference-counted
//! [`HostTensor`]s, so state round-trips between chunks without copies
//! and a snapshot for a quantized eval cast is one `Rc::clone`.

use super::manifest::{ArtifactEntry, Manifest, TensorSpec};
use crate::tensor::HostTensor;
use anyhow::{anyhow, bail, Result};
use std::rc::Rc;

/// The coordinator-side value type: a cheaply clonable host tensor.
pub type Value = Rc<HostTensor>;

/// Wrap a tensor as a [`Value`].
pub fn value(t: HostTensor) -> Value {
    Rc::new(t)
}

/// An execution backend: a program registry (the manifest) plus a
/// positional call interface matching the AOT calling convention
/// (DESIGN.md §2). Object-safe on purpose — the coordinator holds
/// `&dyn Executor` so backends can be picked at runtime (`--backend`).
pub trait Executor {
    /// The program registry: names, positional I/O specs, metadata.
    fn manifest(&self) -> &Manifest;

    /// Execute one program with positional inputs; returns one value
    /// per manifest output spec, in manifest order.
    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>>;

    /// Per-program (compile_s, calls, total_exec_s) — the profile behind
    /// `lotion-rs inspect` and the exp-run profile dump.
    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        Vec::new()
    }

    /// Call and pick named outputs as host tensors (convenience for
    /// metrics / eval values).
    fn call_to_host(
        &self,
        entry: &ArtifactEntry,
        args: &[Value],
        outputs: &[&str],
    ) -> Result<Vec<HostTensor>> {
        let parts = self.call(entry, args)?;
        outputs
            .iter()
            .map(|name| {
                let idx = entry
                    .output_index(name)
                    .ok_or_else(|| anyhow!("{}: no output {name:?}", entry.name))?;
                Ok(parts[idx].as_ref().clone())
            })
            .collect()
    }
}

/// Check a host tensor against a manifest spec (shape + dtype).
pub fn check_value(t: &HostTensor, spec: &TensorSpec) -> Result<()> {
    if t.shape != spec.shape {
        bail!("tensor {:?}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
    }
    if t.dtype != spec.dtype {
        bail!("tensor {:?}: dtype {:?} != manifest {:?}", spec.name, t.dtype, spec.dtype);
    }
    Ok(())
}

/// Validate a positional argument list against an entry's input specs.
/// Always on: a shape-vector compare per argument is trivial next to
/// the K-step program it guards, and a silently truncated static (e.g.
/// a short `lam`) would otherwise train on wrong data in release.
pub fn check_args(entry: &ArtifactEntry, args: &[Value]) -> Result<()> {
    use anyhow::Context;
    if args.len() != entry.inputs.len() {
        bail!(
            "{}: got {} args, manifest expects {}",
            entry.name,
            args.len(),
            entry.inputs.len()
        );
    }
    for (v, spec) in args.iter().zip(&entry.inputs) {
        check_value(v, spec).with_context(|| entry.name.clone())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Role;
    use crate::tensor::DType;

    #[test]
    fn check_value_catches_mismatches() {
        let spec = TensorSpec {
            name: "w".into(),
            shape: vec![4],
            dtype: DType::F32,
            role: Role::Param,
        };
        assert!(check_value(&HostTensor::zeros(DType::F32, &[4]), &spec).is_ok());
        assert!(check_value(&HostTensor::zeros(DType::F32, &[5]), &spec).is_err());
        assert!(check_value(&HostTensor::zeros(DType::I32, &[4]), &spec).is_err());
    }
}
