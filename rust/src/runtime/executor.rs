//! The backend abstraction: every execution backend (PJRT, native
//! pure-rust, future sharded/threaded engines) implements [`Executor`]
//! and the whole coordinator — trainer, evaluator, sweeps, experiments,
//! CLI — runs against `&dyn Executor` (DESIGN.md §3).
//!
//! Values cross the backend boundary as [`Value`]s: reference-counted
//! [`HostTensor`]s, so state round-trips between chunks without copies
//! and a snapshot for a quantized eval cast is one `Rc::clone`.

use super::manifest::{ArtifactEntry, Manifest, TensorSpec};
use crate::tensor::HostTensor;
use anyhow::{anyhow, bail, Result};
use std::rc::Rc;

/// The coordinator-side value type: a cheaply clonable host tensor.
pub type Value = Rc<HostTensor>;

/// Wrap a tensor as a [`Value`].
pub fn value(t: HostTensor) -> Value {
    Rc::new(t)
}

/// An execution backend: a program registry (the manifest) plus a
/// positional call interface matching the AOT calling convention
/// (DESIGN.md §2). Object-safe on purpose — the coordinator holds
/// `&dyn Executor` so backends can be picked at runtime (`--backend`).
pub trait Executor {
    /// The program registry: names, positional I/O specs, metadata.
    fn manifest(&self) -> &Manifest;

    /// Execute one program with positional inputs; returns one value
    /// per manifest output spec, in manifest order.
    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>>;

    /// Per-program (compile_s, calls, total_exec_s) — the profile behind
    /// `lotion-rs inspect` and the exp-run profile dump.
    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        Vec::new()
    }

    /// Call and pick named outputs (convenience for metrics / eval
    /// values). Returns the call's own [`Value`]s — selection is an
    /// `Rc` clone per requested output, never a tensor copy (an LM
    /// eval output used to be deep-cloned here on every eval point).
    fn call_to_host(
        &self,
        entry: &ArtifactEntry,
        args: &[Value],
        outputs: &[&str],
    ) -> Result<Vec<Value>> {
        let parts = self.call(entry, args)?;
        outputs
            .iter()
            .map(|name| {
                let idx = entry
                    .output_index(name)
                    .ok_or_else(|| anyhow!("{}: no output {name:?}", entry.name))?;
                parts
                    .get(idx)
                    .cloned()
                    .ok_or_else(|| anyhow!("{}: call returned no output {idx}", entry.name))
            })
            .collect()
    }
}

/// Check a host tensor against a manifest spec (shape + dtype).
pub fn check_value(t: &HostTensor, spec: &TensorSpec) -> Result<()> {
    if t.shape != spec.shape {
        bail!("tensor {:?}: shape {:?} != manifest {:?}", spec.name, t.shape, spec.shape);
    }
    if t.dtype != spec.dtype {
        bail!("tensor {:?}: dtype {:?} != manifest {:?}", spec.name, t.dtype, spec.dtype);
    }
    Ok(())
}

/// Validate a positional argument list against an entry's input specs.
/// Always on: a shape-vector compare per argument is trivial next to
/// the K-step program it guards, and a silently truncated static (e.g.
/// a short `lam`) would otherwise train on wrong data in release.
pub fn check_args(entry: &ArtifactEntry, args: &[Value]) -> Result<()> {
    use anyhow::Context;
    if args.len() != entry.inputs.len() {
        bail!(
            "{}: got {} args, manifest expects {}",
            entry.name,
            args.len(),
            entry.inputs.len()
        );
    }
    for (v, spec) in args.iter().zip(&entry.inputs) {
        check_value(v, spec).with_context(|| entry.name.clone())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Role;
    use crate::tensor::DType;
    use std::collections::BTreeMap;
    use std::path::PathBuf;

    /// A backend whose outputs are fixed shared values — lets the
    /// no-copy test observe exactly which `Rc`s cross the trait.
    struct FixedExecutor {
        manifest: Manifest,
        outs: Vec<Value>,
    }

    impl Executor for FixedExecutor {
        fn manifest(&self) -> &Manifest {
            &self.manifest
        }

        fn call(&self, _entry: &ArtifactEntry, _args: &[Value]) -> Result<Vec<Value>> {
            Ok(self.outs.clone())
        }
    }

    fn out_spec(name: &str) -> TensorSpec {
        TensorSpec { name: name.into(), shape: vec![2], dtype: DType::F32, role: Role::Metric }
    }

    /// Regression (ISSUE 5 satellite): `call_to_host` must hand back
    /// the call's own values — one `Rc` clone per requested output —
    /// not deep tensor copies.
    #[test]
    fn call_to_host_returns_shared_values_without_copying() {
        let entry = ArtifactEntry {
            name: "fixed".into(),
            file: PathBuf::from("fixed"),
            inputs: vec![],
            outputs: vec![out_spec("a"), out_spec("b")],
            kind: "eval".into(),
            model_name: "fixed".into(),
            method: String::new(),
            format: String::new(),
            steps_per_call: 0,
            eval_batches: 0,
            optimizer: String::new(),
            quantized: vec![],
        };
        let mut artifacts = BTreeMap::new();
        artifacts.insert(entry.name.clone(), entry.clone());
        let ex = FixedExecutor {
            manifest: Manifest { dir: PathBuf::from("<test>"), artifacts },
            outs: vec![
                value(HostTensor::from_f32(&[2], vec![1.0, 2.0])),
                value(HostTensor::from_f32(&[2], vec![3.0, 4.0])),
            ],
        };
        let got = ex.call_to_host(&entry, &[], &["b", "a"]).unwrap();
        assert_eq!(got.len(), 2);
        assert!(
            Rc::ptr_eq(&got[0], &ex.outs[1]) && Rc::ptr_eq(&got[1], &ex.outs[0]),
            "call_to_host copied the output tensors instead of sharing them"
        );
        assert_eq!(got[0].as_f32(), vec![3.0, 4.0]);
        // unknown output names still error
        assert!(ex.call_to_host(&entry, &[], &["nope"]).is_err());
    }

    #[test]
    fn check_value_catches_mismatches() {
        let spec = TensorSpec {
            name: "w".into(),
            shape: vec![4],
            dtype: DType::F32,
            role: Role::Param,
        };
        assert!(check_value(&HostTensor::zeros(DType::F32, &[4]), &spec).is_ok());
        assert!(check_value(&HostTensor::zeros(DType::F32, &[5]), &spec).is_err());
        assert!(check_value(&HostTensor::zeros(DType::I32, &[4]), &spec).is_err());
    }
}
