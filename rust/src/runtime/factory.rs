//! Engine spawning: the [`ExecutorFactory`] trait (DESIGN.md §3).
//!
//! An [`Executor`](super::Executor) is deliberately thread-confined —
//! `Value = Rc<HostTensor>` shares state between chunks without copies,
//! and the native engine caches per-model scratch in a `RefCell` — so
//! one engine can never be handed to another thread. Multi-engine
//! workloads (the sharded LR-sweep runner, future serving/ablation
//! grids) instead share a **factory**: a `Send + Sync` description of
//! the backend — for the native backend the immutable program
//! definitions themselves, `Arc`-shared across engines — from which
//! every worker thread spawns an engine it alone owns.
//!
//! The contract: two engines spawned from one factory expose identical
//! manifests and compute bit-identical results for identical call
//! sequences (engines are deterministic given their inputs; all
//! randomness enters through explicit key/seed inputs). That is what
//! lets the sweep runner fold sharded results in fixed grid order and
//! match the serial path bit-for-bit.

use super::executor::Executor;
use anyhow::Result;

/// A `Send + Sync` recipe for spawning thread-owned engines. Factories
/// are cheap handles over shared immutable definitions; `spawn` is
/// called once per worker thread, and the spawned engine lives and dies
/// on that thread.
pub trait ExecutorFactory: Send + Sync {
    /// Spawn a fresh engine owned by the calling thread.
    fn spawn(&self) -> Result<Box<dyn Executor>>;

    /// Human-readable backend description for logs and errors.
    fn describe(&self) -> String {
        "executor factory".to_string()
    }

    /// The model presets engines from this factory will carry, when the
    /// backend can enumerate them without spawning (the native backend
    /// can; artifact-backed backends may not). `None` = unknown —
    /// callers (e.g. sweep-spec expansion) skip up-front model
    /// validation and rely on spawn-time errors instead.
    fn model_names(&self) -> Option<Vec<String>> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::NativeFactory;

    #[test]
    fn factories_are_send_and_sync() {
        fn assert_send_sync<T: Send + Sync>(_t: &T) {}
        let f = NativeFactory::with_default_models(1);
        assert_send_sync(&f);
        let boxed: Box<dyn ExecutorFactory> = Box::new(f);
        assert!(boxed.describe().contains("native"));
    }

    /// Engines spawned from one factory expose the same manifest and
    /// compute identical results for identical calls — the invariant
    /// the sharded sweep runner's determinism rests on.
    #[test]
    fn spawned_engines_agree() {
        use crate::runtime::executor::value;
        use crate::tensor::HostTensor;

        let f = NativeFactory::with_default_models(1);
        let a = f.spawn().unwrap();
        let b = f.spawn().unwrap();
        assert_eq!(
            a.manifest().artifacts.keys().collect::<Vec<_>>(),
            b.manifest().artifacts.keys().collect::<Vec<_>>()
        );
        let init = a.manifest().find_init("linreg_d256").unwrap().clone();
        let key = value(HostTensor::from_u32(&[2], vec![3, 9]));
        let pa = a.call(&init, &[key.clone()]).unwrap();
        let pb = b.call(&init, &[key]).unwrap();
        for (x, y) in pa.iter().zip(&pb) {
            assert_eq!(x.as_ref(), y.as_ref(), "spawned engines disagree on init");
        }
    }
}
