//! HostTensor ⇄ `xla::Literal` conversions and spec validation.

use super::manifest::TensorSpec;
use crate::tensor::{DType, HostTensor};
use anyhow::{bail, Result};

fn element(dt: DType) -> xla::ElementType {
    match dt {
        DType::F32 => xla::ElementType::F32,
        DType::I32 => xla::ElementType::S32,
        DType::U32 => xla::ElementType::U32,
    }
}

fn dtype_of(p: xla::PrimitiveType) -> Result<DType> {
    Ok(match p {
        xla::PrimitiveType::F32 => DType::F32,
        xla::PrimitiveType::S32 => DType::I32,
        xla::PrimitiveType::U32 => DType::U32,
        other => bail!("unsupported literal element type {other:?}"),
    })
}

/// Host → Literal (one untyped byte copy).
pub fn to_literal(t: &HostTensor) -> Result<Literal> {
    Ok(xla::Literal::create_from_shape_and_untyped_data(
        element(t.dtype),
        &t.shape,
        t.bytes(),
    )?)
}

/// Literal → Host (one copy).
pub fn to_host(lit: &Literal) -> Result<HostTensor> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let dt = dtype_of(shape.primitive_type())?;
    match dt {
        DType::F32 => Ok(HostTensor::from_f32(&dims, lit.to_vec::<f32>()?)),
        DType::I32 => Ok(HostTensor::from_i32(&dims, lit.to_vec::<i32>()?)),
        DType::U32 => Ok(HostTensor::from_u32(&dims, lit.to_vec::<u32>()?)),
    }
}

/// Check a literal against a manifest spec.
pub fn check_spec(lit: &Literal, spec: &TensorSpec) -> Result<()> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    if dims != spec.shape {
        bail!("tensor {:?}: shape {:?} != manifest {:?}", spec.name, dims, spec.shape);
    }
    let dt = dtype_of(shape.primitive_type())?;
    if dt != spec.dtype {
        bail!("tensor {:?}: dtype {:?} != manifest {:?}", spec.name, dt, spec.dtype);
    }
    Ok(())
}

pub use xla::Literal;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_roundtrip() {
        let t = HostTensor::from_f32(&[2, 2], vec![1., 2., 3., 4.]);
        let lit = to_literal(&t).unwrap();
        let back = to_host(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn i32_and_u32_roundtrip() {
        let t = HostTensor::from_i32(&[3], vec![-1, 0, 7]);
        assert_eq!(to_host(&to_literal(&t).unwrap()).unwrap(), t);
        let t = HostTensor::from_u32(&[2], vec![1, u32::MAX]);
        assert_eq!(to_host(&to_literal(&t).unwrap()).unwrap(), t);
    }

    #[test]
    fn scalar_roundtrip() {
        let t = HostTensor::scalar_f32(2.5);
        let lit = to_literal(&t).unwrap();
        assert_eq!(to_host(&lit).unwrap().scalar_to_f32(), 2.5);
    }

    #[test]
    fn spec_check() {
        use crate::runtime::manifest::Role;
        let t = HostTensor::from_f32(&[4], vec![0.0; 4]);
        let lit = to_literal(&t).unwrap();
        let good = TensorSpec { name: "w".into(), shape: vec![4], dtype: DType::F32, role: Role::Param };
        assert!(check_spec(&lit, &good).is_ok());
        let bad = TensorSpec { name: "w".into(), shape: vec![5], dtype: DType::F32, role: Role::Param };
        assert!(check_spec(&lit, &bad).is_err());
    }
}
