//! Typed view of the AOT manifest (the L2→L3 contract).

use crate::formats::json::Json;
use crate::tensor::DType;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Role {
    Param,
    Opt,
    Static,
    Data,
    Key,
    Scalar,
    Metric,
}

impl Role {
    fn parse(s: &str) -> Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt" => Role::Opt,
            "static" => Role::Static,
            "data" => Role::Data,
            "key" => Role::Key,
            "scalar" => Role::Scalar,
            "metric" => Role::Metric,
            other => bail!("unknown tensor role {other:?}"),
        })
    }
}

#[derive(Clone, Debug)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: DType,
    pub role: Role,
}

impl TensorSpec {
    fn from_json(j: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: j.expect("name")?.as_str().unwrap_or_default().to_string(),
            shape: j
                .expect("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("shape not an array"))?
                .iter()
                .map(|v| v.as_usize().unwrap_or(0))
                .collect(),
            dtype: DType::parse(j.expect("dtype")?.as_str().unwrap_or_default())?,
            role: Role::parse(j.expect("role")?.as_str().unwrap_or_default())?,
        })
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

/// One AOT program: file + positional I/O contract + metadata.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub kind: String,
    pub model_name: String,
    pub method: String,
    pub format: String,
    pub steps_per_call: usize,
    pub eval_batches: usize,
    pub optimizer: String,
    pub quantized: Vec<String>,
}

impl ArtifactEntry {
    pub fn input_specs(&self, role: Role) -> Vec<&TensorSpec> {
        self.inputs.iter().filter(|s| s.role == role).collect()
    }

    pub fn input_index(&self, name: &str) -> Option<usize> {
        self.inputs.iter().position(|s| s.name == name)
    }

    pub fn output_index(&self, name: &str) -> Option<usize> {
        self.outputs.iter().position(|s| s.name == name)
    }
}

/// The whole artifact directory.
pub struct Manifest {
    pub dir: PathBuf,
    pub artifacts: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let doc = Json::from_file(&dir.join("manifest.json"))
            .context("loading artifact manifest (run `make artifacts` first)")?;
        let mut artifacts = BTreeMap::new();
        for (name, e) in doc.expect("artifacts")?.members() {
            let meta = e.expect("meta")?;
            let get_s = |k: &str| meta.get(k).and_then(|v| v.as_str()).unwrap_or("").to_string();
            let get_u = |k: &str| meta.get(k).and_then(|v| v.as_usize()).unwrap_or(0);
            let quantized = meta
                .get("quantized")
                .and_then(|v| v.as_arr())
                .map(|a| a.iter().filter_map(|v| v.as_str().map(String::from)).collect())
                .unwrap_or_default();
            let parse_specs = |key: &str| -> Result<Vec<TensorSpec>> {
                e.expect(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not an array"))?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactEntry {
                    name: name.clone(),
                    file: dir.join(e.expect("file")?.as_str().unwrap_or_default()),
                    inputs: parse_specs("inputs").with_context(|| name.clone())?,
                    outputs: parse_specs("outputs").with_context(|| name.clone())?,
                    kind: get_s("kind"),
                    model_name: get_s("model_name"),
                    method: get_s("method"),
                    format: get_s("format"),
                    steps_per_call: get_u("steps_per_call"),
                    eval_batches: get_u("eval_batches"),
                    optimizer: get_s("optimizer"),
                    quantized,
                },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), artifacts })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.artifacts
            .get(name)
            .ok_or_else(|| anyhow!("artifact {name:?} not in manifest"))
    }

    /// The sorted, deduped model names with a train program — the
    /// suggestion list for "unknown model" errors.
    pub fn known_models(&self) -> Vec<String> {
        let mut v: Vec<String> = self
            .artifacts
            .values()
            .filter(|e| e.kind == "train" && !e.model_name.is_empty())
            .map(|e| e.model_name.clone())
            .collect();
        v.sort();
        v.dedup();
        v
    }

    /// Find the train program for (model, method, format) — the manifest
    /// key carries a `_k<steps>` suffix chosen at AOT time. A miss
    /// reports the registry's known models so a config typo is
    /// self-explaining.
    pub fn find_train(&self, model: &str, method: &str, format: &str) -> Result<&ArtifactEntry> {
        let fmt = if method == "ptq" { "none" } else { format };
        let prefix = format!("train_{model}_{method}_{fmt}_k");
        self.artifacts.values().find(|e| e.name.starts_with(&prefix)).ok_or_else(|| {
            anyhow!(
                "no train artifact matching {prefix}* (known models: {})",
                self.known_models().join(", ")
            )
        })
    }

    pub fn find_eval(&self, model: &str) -> Result<&ArtifactEntry> {
        self.get(&format!("eval_{model}")).map_err(|_| {
            anyhow!(
                "no eval artifact for model {model:?} (known models: {})",
                self.known_models().join(", ")
            )
        })
    }

    /// The RTN-quantized eval entry for (model, format), when the
    /// backend registers one (`eval_q_{model}_{fmt}`, native engines
    /// only — AOT manifests return `None` and callers fall back to
    /// host-side casting through the plain eval entry).
    pub fn find_eval_quant(&self, model: &str, format: &str) -> Option<&ArtifactEntry> {
        self.artifacts.get(&format!("eval_q_{model}_{format}"))
    }

    /// The autoregressive decode entry for (model, format), when the
    /// backend registers one (`decode_{model}_{fmt}`; `"none"` is the
    /// dense-weight entry). Like [`Manifest::find_eval_quant`], native
    /// engines only — programs without a generation path register
    /// nothing and callers get `None`.
    pub fn find_decode(&self, model: &str, format: &str) -> Option<&ArtifactEntry> {
        self.artifacts.get(&format!("decode_{model}_{format}"))
    }

    pub fn find_init(&self, model: &str) -> Result<&ArtifactEntry> {
        self.get(&format!("init_{model}")).map_err(|_| {
            anyhow!(
                "no init artifact for model {model:?} (known models: {})",
                self.known_models().join(", ")
            )
        })
    }

    /// All (method, format) pairs with a train artifact for this model.
    pub fn methods_for(&self, model: &str) -> Vec<(String, String)> {
        self.artifacts
            .values()
            .filter(|e| e.kind == "train" && e.model_name == model)
            .map(|e| (e.method.clone(), e.format.clone()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::util::tempdir::TempDir;

    fn sample_manifest() -> (TempDir, Manifest) {
        let doc = r#"{"artifacts": {
            "train_m_lotion_int4_k8": {"file": "t.hlo.txt",
                "inputs": [
                    {"name": "w", "shape": [4], "dtype": "f32", "role": "param"},
                    {"name": "t", "shape": [], "dtype": "f32", "role": "opt"},
                    {"name": "key", "shape": [2], "dtype": "u32", "role": "key"}],
                "outputs": [
                    {"name": "w", "shape": [4], "dtype": "f32", "role": "param"},
                    {"name": "t", "shape": [], "dtype": "f32", "role": "opt"},
                    {"name": "base_losses", "shape": [8], "dtype": "f32", "role": "metric"}],
                "meta": {"kind": "train", "model_name": "m", "method": "lotion",
                         "format": "int4", "steps_per_call": 8, "optimizer": "sgd",
                         "quantized": ["w"]}},
            "eval_m": {"file": "e.hlo.txt", "inputs": [], "outputs": [],
                "meta": {"kind": "eval", "model_name": "m", "eval_batches": 4}}
        }, "version": 1}"#;
        let dir = TempDir::new();
        std::fs::write(dir.path().join("manifest.json"), doc).unwrap();
        let m = Manifest::load(dir.path()).unwrap();
        (dir, m)
    }

    #[test]
    fn loads_and_indexes() {
        let (_d, m) = sample_manifest();
        let t = m.find_train("m", "lotion", "int4").unwrap();
        assert_eq!(t.steps_per_call, 8);
        assert_eq!(t.quantized, vec!["w"]);
        assert_eq!(t.input_index("key"), Some(2));
        assert_eq!(t.input_specs(Role::Param).len(), 1);
        assert!(m.find_eval("m").is_ok());
        assert!(m.find_train("m", "qat", "int4").is_err());
        assert_eq!(m.methods_for("m"), vec![("lotion".to_string(), "int4".to_string())]);
    }

    #[test]
    fn unknown_model_errors_list_known_models() {
        let (_d, m) = sample_manifest();
        assert_eq!(m.known_models(), vec!["m".to_string()]);
        for err in [
            format!("{:#}", m.find_train("nope", "lotion", "int4").unwrap_err()),
            format!("{:#}", m.find_eval("nope").unwrap_err()),
            format!("{:#}", m.find_init("nope").unwrap_err()),
        ] {
            assert!(err.contains("known models: m"), "{err}");
        }
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let dir = TempDir::new();
        let err = match Manifest::load(&dir.path().join("nope")) {
            Err(e) => format!("{e:#}"),
            Ok(_) => panic!("expected error"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }


}
