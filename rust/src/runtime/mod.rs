//! PJRT runtime: loads the AOT artifacts (HLO text + manifest) and
//! executes them from the coordinator's hot path.
//!
//! * [`manifest`] — typed view of `artifacts/manifest.json`.
//! * [`literals`] — HostTensor ⇄ `xla::Literal` conversions.
//! * [`engine`] — PJRT client + compiled-executable cache + the
//!   flat-tuple calling convention (DESIGN.md §2).
//! * [`state`] — named train state (params + optimizer) that round-trips
//!   through executions.

pub mod engine;
pub mod literals;
pub mod manifest;
pub mod state;

pub use engine::Engine;
pub use manifest::{ArtifactEntry, Manifest, Role, TensorSpec};
pub use state::TrainState;
