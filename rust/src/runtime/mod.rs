//! Runtime layer: execution backends behind the [`Executor`] trait
//! (DESIGN.md §3) plus the manifest-driven program registry and train
//! state shared by all of them.
//!
//! * [`executor`] — the backend trait + the [`Value`] tensor currency.
//! * [`decode`] — [`Decoder`]: a typed generation handle over the
//!   `decode_*` entries, plus host-side counter-split sampling.
//! * [`manifest`] — typed program registry (the backend⇄coordinator
//!   contract; for PJRT it is `artifacts/manifest.json`, the native
//!   backend synthesizes an equivalent one in memory).
//! * [`native`] — pure-rust CPU backend: interprets the synthetic
//!   train/eval/init programs directly over `HostTensor`s.
//! * [`engine`] / [`literals`] — PJRT client + compiled-executable
//!   cache + the flat-tuple calling convention (DESIGN.md §2); only
//!   with `--features pjrt`.
//! * [`state`] — named train state (params + optimizer) that round-trips
//!   through executions.
//! * [`factory`] — [`ExecutorFactory`]: a `Send + Sync` recipe for
//!   spawning thread-owned engines (sharded sweeps, multi-engine
//!   workloads).
//! * [`session`] — [`Session`]: a typed per-run handle owning the
//!   train/eval entries, the state round-trip and the argument packing.

pub mod decode;
#[cfg(feature = "pjrt")]
pub mod engine;
pub mod executor;
pub mod factory;
#[cfg(feature = "pjrt")]
pub mod literals;
pub mod manifest;
pub mod native;
pub mod session;
pub mod state;

pub use self::decode::{sample_token, Decoder};
#[cfg(feature = "pjrt")]
pub use self::engine::Engine;
pub use self::executor::{Executor, Value};
pub use self::factory::ExecutorFactory;
pub use self::manifest::{ArtifactEntry, Manifest, Role, TensorSpec};
pub use self::native::{NativeEngine, NativeFactory};
pub use self::session::{ChunkInputs, ChunkOutcome, Session};
pub use self::state::TrainState;

use anyhow::Result;
use std::path::Path;

/// Pick a backend automatically: PJRT when this build has the `pjrt`
/// feature *and* an artifact directory is present, the native pure-rust
/// backend otherwise (it needs no artifacts at all).
pub fn auto_executor(artifacts_dir: &Path) -> Result<Box<dyn Executor>> {
    auto_executor_threads(artifacts_dir, 0)
}

/// [`auto_executor`] with an explicit native worker-thread count
/// (`0` = auto: `LOTION_THREADS`, else all cores). The PJRT backend
/// ignores the knob — XLA owns its own threading.
pub fn auto_executor_threads(artifacts_dir: &Path, threads: usize) -> Result<Box<dyn Executor>> {
    if artifacts_dir.join("manifest.json").exists() {
        if let Some(engine) = pjrt_executor(artifacts_dir)? {
            return Ok(engine);
        }
    }
    crate::debug!("no usable PJRT artifacts at {artifacts_dir:?}; using the native backend");
    Ok(Box::new(NativeEngine::new().with_threads(threads)))
}

/// Construct the PJRT backend, or `None` when this build lacks the
/// `pjrt` feature. The single cfg point shared by [`auto_executor`] and
/// the CLI's explicit `--backend pjrt`.
#[cfg(feature = "pjrt")]
pub fn pjrt_executor(artifacts_dir: &Path) -> Result<Option<Box<dyn Executor>>> {
    Ok(Some(Box::new(Engine::new(artifacts_dir)?)))
}

/// Construct the PJRT backend, or `None` when this build lacks the
/// `pjrt` feature. The single cfg point shared by [`auto_executor`] and
/// the CLI's explicit `--backend pjrt`.
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_executor(_artifacts_dir: &Path) -> Result<Option<Box<dyn Executor>>> {
    Ok(None)
}

/// Pick an [`ExecutorFactory`] with the same policy as
/// [`auto_executor`]: PJRT when the build has the feature *and*
/// artifacts exist, the native factory (default model registry,
/// per-engine `threads` knob) otherwise.
pub fn auto_factory(artifacts_dir: &Path, threads: usize) -> Result<Box<dyn ExecutorFactory>> {
    if artifacts_dir.join("manifest.json").exists() {
        if let Some(f) = pjrt_factory(artifacts_dir)? {
            return Ok(f);
        }
    }
    Ok(Box::new(NativeFactory::with_default_models(threads)))
}

/// The PJRT factory, or `None` when this build lacks the `pjrt`
/// feature — the factory-side twin of [`pjrt_executor`].
#[cfg(feature = "pjrt")]
pub fn pjrt_factory(artifacts_dir: &Path) -> Result<Option<Box<dyn ExecutorFactory>>> {
    Ok(Some(Box::new(engine::PjrtFactory::new(artifacts_dir))))
}

/// The PJRT factory, or `None` when this build lacks the `pjrt`
/// feature — the factory-side twin of [`pjrt_executor`].
#[cfg(not(feature = "pjrt"))]
pub fn pjrt_factory(_artifacts_dir: &Path) -> Result<Option<Box<dyn ExecutorFactory>>> {
    Ok(None)
}
