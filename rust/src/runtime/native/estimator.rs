//! Pluggable training-method estimators (DESIGN.md §9): the method
//! transformation — forward cast, gradient relaxation, loss penalty —
//! factored out of the native driver into an [`Estimator`] trait, so
//! the driver is a thin model-agnostic loop over `dyn Estimator` and a
//! new quantized-training method is one `impl` plus a registry row
//! instead of a new enum arm in every match.
//!
//! The four paper methods (PTQ, QAT, RAT, LOTION) are rebuilt here as
//! plug-ins with **bitwise-identical** output to the pre-refactor
//! driver: each hook body is the exact statement sequence the old
//! `match method` arms executed, in the same order, on the same pool —
//! `tests/estimator.rs` pins that equivalence against an independent
//! re-implementation of the legacy per-step loop.
//!
//! Two method families from the related work ride the same surface:
//!
//! * [`Cge`] — a custom gradient estimator in the sense of Schoenbauer
//!   et al. ("Custom Gradient Estimators are Straight-Through
//!   Estimators in Disguise"): RTN forward cast, backward gradients of
//!   the quantized subset scaled by a per-step factor. Under plain SGD
//!   this is provably a learning-rate rescaling of QAT — the `exp
//!   est-equiv` experiment measures exactly that equivalence.
//! * [`Anneal`] — additive noise annealing (Spallanzani et al.): the
//!   forward cast rounds `w + σ_t·s_B·u`, `u ~ U[-0.5, 0.5)`, with σ_t
//!   following a step-indexed σ→0 schedule; at σ = 0 the cast is
//!   exactly QAT's RTN lattice map.
//!
//! Scheduled estimators receive their per-step scalar (σ_t, the
//! gradient scale) through the `est_sched` train-entry input — a pure
//! function of the global step computed coordinator-side
//! ([`RunConfig::est_sched_at`](crate::config::RunConfig::est_sched_at)),
//! so checkpoint-resume bit-identity needs no estimator state in the
//! snapshot. Entries for the four legacy estimators carry no such
//! input: their calling convention (and therefore every existing
//! golden and checkpoint) is byte-identical to the pre-refactor one.

use super::program::StepStreams;
use crate::quant::{
    cast_anneal_seeded, cast_rr_seeded, cast_rtn_pool, lotion_penalty_and_grad_pool, QuantFormat,
};
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK};
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};

/// Step-indexed schedule for an estimator's scalar knob (σ for
/// [`Anneal`], the gradient scale for [`Cge`]): a decay factor from 1
/// at step 0 toward 0 (linear/cosine) at the final step. Pure function
/// of the step, so a resumed run recomputes the same values the
/// uninterrupted one saw.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EstSchedule {
    Constant,
    /// linear decay `1 - t` over the run
    Linear,
    /// cosine half-wave decay `0.5 (1 + cos π t)` over the run — the
    /// σ→0 annealing shape of Spallanzani et al.
    Cosine,
}

impl EstSchedule {
    pub fn parse(s: &str) -> Result<EstSchedule> {
        Ok(match s {
            "constant" => EstSchedule::Constant,
            "linear" => EstSchedule::Linear,
            "cosine" => EstSchedule::Cosine,
            other => {
                bail!("unknown est.schedule {other:?} (known schedules: constant, linear, cosine)")
            }
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            EstSchedule::Constant => "constant",
            EstSchedule::Linear => "linear",
            EstSchedule::Cosine => "cosine",
        }
    }

    /// Decay factor at `step` of a `total`-step run.
    pub fn value_at(self, step: usize, total: usize) -> f64 {
        let t = (step as f64 / total.max(1) as f64).min(1.0);
        match self {
            EstSchedule::Constant => 1.0,
            EstSchedule::Linear => 1.0 - t,
            EstSchedule::Cosine => 0.5 * (1.0 + (std::f64::consts::PI * t).cos()),
        }
    }
}

/// Per-step inputs an estimator hook sees: the entry's quantization
/// format, the quantized-subset parameter indices, the engine pool,
/// the regularization weight, this step's schedule value and the
/// counter-split RNG stream roots.
pub struct EstCtx<'a> {
    pub fmt: Option<&'a QuantFormat>,
    /// indices of the quantized parameter subset, in param-spec order
    pub quant_idx: &'a [usize],
    pub pool: &'a Pool,
    /// the LOTION regularization weight (`lam_reg` input)
    pub lam_reg: f32,
    /// this step's schedule value (`est_sched[i]` for scheduled
    /// estimators, 1.0 otherwise)
    pub sched: f32,
    pub streams: StepStreams,
}

/// One training method as the native driver sees it: which entries to
/// register ([`Estimator::formats`]), which per-step hooks run
/// ([`Estimator::casts`] / [`Estimator::needs_fisher`] /
/// [`Estimator::scheduled`]) and the hook bodies themselves. All hooks
/// must draw randomness off `ctx.streams` counter streams only, so
/// every method keeps the crate's any-thread-count bit-identity
/// contract.
pub trait Estimator: Send + Sync {
    /// Registry/manifest name (the `--method` string).
    fn name(&self) -> &'static str;

    /// Quantization formats this estimator registers train entries
    /// for; empty means a single unformatted entry (PTQ trains the
    /// FP32 master weights and only *evaluates* quantized).
    fn formats(&self) -> &'static [&'static str] {
        &["int4", "int8", "fp4"]
    }

    /// Whether the driver builds forward-weight copies and calls
    /// [`Estimator::cast_step`] each step. Non-casting methods forward
    /// the master weights and pay no per-step full-model copy.
    fn casts(&self) -> bool {
        false
    }

    /// Whether the driver refreshes the Fisher diagonal (exact
    /// Gauss-Newton when the program has one, the optimizer's second
    /// moment otherwise) before [`Estimator::penalty_step`].
    fn needs_fisher(&self) -> bool {
        false
    }

    /// Whether train entries carry the per-step `est_sched` scalar
    /// input (and [`EstCtx::sched`] varies by step).
    fn scheduled(&self) -> bool {
        false
    }

    /// Forward cast over the quantized subset of `wq` (already a copy
    /// of the master weights). Only called when [`Estimator::casts`];
    /// the default is a structured error so a mis-wired estimator
    /// fails loudly instead of training on uncast weights.
    fn cast_step(&self, _wq: &mut [Vec<f32>], _ctx: &EstCtx<'_>) -> Result<()> {
        bail!(
            "estimator {:?} is registered as casting but defines no forward cast \
             (non-casting methods must not reach cast_step)",
            self.name()
        )
    }

    /// Gradient relaxation applied to the base-loss gradients before
    /// the penalty and the optimizer step. Default: straight-through
    /// (gradients pass unchanged).
    fn grad_step(&self, _grads: &mut [Vec<f32>], _ctx: &EstCtx<'_>) -> Result<()> {
        Ok(())
    }

    /// Loss penalty: add the method's regularizer to `grads` and fold
    /// its value into `total` (the driver's f64 accumulator, already
    /// holding the base loss). Implementations must preserve their own
    /// fold order — the driver never re-associates the sum. `fisher`
    /// holds one diagonal per quantized tensor when
    /// [`Estimator::needs_fisher`], and is empty otherwise.
    fn penalty_step(
        &self,
        _params: &[Vec<f32>],
        _grads: &mut [Vec<f32>],
        _fisher: &[Vec<f32>],
        _total: &mut f64,
        _ctx: &EstCtx<'_>,
    ) -> Result<()> {
        Ok(())
    }
}

/// The format carried by a casting estimator's entry, as a structured
/// error instead of the old `unreachable!("non-casting method")`.
fn cast_format<'a>(est: &dyn Estimator, ctx: &EstCtx<'a>) -> Result<&'a QuantFormat> {
    ctx.fmt.ok_or_else(|| {
        anyhow!("estimator {:?} casts but its entry carries no quantization format", est.name())
    })
}

/// Post-training quantization: train FP32, quantize only at eval.
pub struct Ptq;

impl Estimator for Ptq {
    fn name(&self) -> &'static str {
        "ptq"
    }

    fn formats(&self) -> &'static [&'static str] {
        &[]
    }
}

/// Quantization-aware training: RTN STE cast each forward step.
pub struct Qat;

impl Estimator for Qat {
    fn name(&self) -> &'static str {
        "qat"
    }

    fn casts(&self) -> bool {
        true
    }

    fn cast_step(&self, wq: &mut [Vec<f32>], ctx: &EstCtx<'_>) -> Result<()> {
        let fmt = cast_format(self, ctx)?;
        for &pi in ctx.quant_idx {
            cast_rtn_pool(&mut wq[pi], fmt, ctx.pool);
        }
        Ok(())
    }
}

/// Randomized-aware training: unbiased randomized-rounding STE cast,
/// per-tensor counter streams off the step's rounding root (mirroring
/// the per-tensor key splits in methods.py).
pub struct Rat;

impl Estimator for Rat {
    fn name(&self) -> &'static str {
        "rat"
    }

    fn casts(&self) -> bool {
        true
    }

    fn cast_step(&self, wq: &mut [Vec<f32>], ctx: &EstCtx<'_>) -> Result<()> {
        let fmt = cast_format(self, ctx)?;
        for (qi, &pi) in ctx.quant_idx.iter().enumerate() {
            let seed = Rng::stream_seed(ctx.streams.round, &[qi as u64]);
            cast_rr_seeded(&mut wq[pi], fmt, seed, ctx.pool);
        }
        Ok(())
    }
}

/// LOTION (the paper's method): no forward cast — the smoothed loss is
/// the base loss at the master weights plus the Eq. 3 σ²-penalty over
/// the quantized subset, weighted by the Fisher diagonal.
pub struct Lotion;

impl Estimator for Lotion {
    fn name(&self) -> &'static str {
        "lotion"
    }

    fn needs_fisher(&self) -> bool {
        true
    }

    fn penalty_step(
        &self,
        params: &[Vec<f32>],
        grads: &mut [Vec<f32>],
        fisher: &[Vec<f32>],
        total: &mut f64,
        ctx: &EstCtx<'_>,
    ) -> Result<()> {
        let Some(fmt) = ctx.fmt else { return Ok(()) };
        // per-tensor fold order is pinned: `total` accumulates one
        // f64 term per quantized tensor, exactly as the pre-refactor
        // driver did — re-associating this sum would move the golden
        // bitstreams
        for (qi, &pi) in ctx.quant_idx.iter().enumerate() {
            let (pen, pg) = lotion_penalty_and_grad_pool(&params[pi], &fisher[qi], fmt, ctx.pool);
            *total += ctx.lam_reg as f64 * pen;
            for (g, p) in grads[pi].iter_mut().zip(&pg) {
                *g += ctx.lam_reg * p;
            }
        }
        Ok(())
    }
}

/// Custom gradient estimator (Schoenbauer et al.): QAT's RTN forward
/// cast, with the quantized subset's backward gradients scaled by the
/// schedule value. Under SGD, scaling the gradient by `c` is exactly
/// scaling the learning rate by `c` — the paper's "STE in disguise"
/// equivalence, measured by `exp est-equiv`.
pub struct Cge;

impl Estimator for Cge {
    fn name(&self) -> &'static str {
        "cge"
    }

    fn casts(&self) -> bool {
        true
    }

    fn scheduled(&self) -> bool {
        true
    }

    fn cast_step(&self, wq: &mut [Vec<f32>], ctx: &EstCtx<'_>) -> Result<()> {
        let fmt = cast_format(self, ctx)?;
        for &pi in ctx.quant_idx {
            cast_rtn_pool(&mut wq[pi], fmt, ctx.pool);
        }
        Ok(())
    }

    fn grad_step(&self, grads: &mut [Vec<f32>], ctx: &EstCtx<'_>) -> Result<()> {
        let c = ctx.sched;
        for &pi in ctx.quant_idx {
            let g = &mut grads[pi];
            let n = g.len();
            ctx.pool.for_chunks_mut(g, &chunk_ranges(n, PAR_CHUNK), n, |_, _, chunk| {
                for v in chunk {
                    *v *= c;
                }
            });
        }
        Ok(())
    }
}

/// Additive noise annealing (Spallanzani et al.): the forward cast
/// rounds `w + σ_t·s_B·u`, `u ~ U[-0.5, 0.5)`, with σ_t on a σ→0
/// schedule — smoothing the expected forward map early and collapsing
/// to QAT's RTN cast as σ_t → 0. Per-tensor noise streams split like
/// RAT's.
pub struct Anneal;

impl Estimator for Anneal {
    fn name(&self) -> &'static str {
        "anneal"
    }

    fn casts(&self) -> bool {
        true
    }

    fn scheduled(&self) -> bool {
        true
    }

    fn cast_step(&self, wq: &mut [Vec<f32>], ctx: &EstCtx<'_>) -> Result<()> {
        let fmt = cast_format(self, ctx)?;
        for (qi, &pi) in ctx.quant_idx.iter().enumerate() {
            let seed = Rng::stream_seed(ctx.streams.round, &[qi as u64]);
            cast_anneal_seeded(&mut wq[pi], fmt, ctx.sched, seed, ctx.pool);
        }
        Ok(())
    }
}

/// The estimator registry, in manifest-registration order. The four
/// paper methods come first so existing entry listings keep their
/// relative order.
static ALL: [&'static dyn Estimator; 6] = [&Ptq, &Qat, &Rat, &Lotion, &Cge, &Anneal];

pub fn all() -> &'static [&'static dyn Estimator] {
    &ALL
}

/// Resolve a `--method`/`[train] method` string; the error lists the
/// known estimators (same style as `Manifest::find_train`'s
/// known-models error).
pub fn parse(name: &str) -> Result<&'static dyn Estimator> {
    all().iter().copied().find(|e| e.name() == name).ok_or_else(|| {
        anyhow!(
            "no estimator matching {name:?} (known estimators: {})",
            all().iter().map(|e| e.name()).collect::<Vec<_>>().join(", ")
        )
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_parse_roundtrip() {
        for est in all() {
            assert_eq!(parse(est.name()).unwrap().name(), est.name());
        }
        let err = parse("magic").unwrap_err().to_string();
        assert!(err.contains("known estimators"), "{err}");
        assert!(err.contains("lotion") && err.contains("anneal"), "{err}");
    }

    #[test]
    fn registry_capability_matrix() {
        let caps: Vec<(&str, bool, bool, bool, bool)> = all()
            .iter()
            .map(|e| (e.name(), e.formats().is_empty(), e.casts(), e.needs_fisher(), e.scheduled()))
            .collect();
        assert_eq!(
            caps,
            vec![
                ("ptq", true, false, false, false),
                ("qat", false, true, false, false),
                ("rat", false, true, false, false),
                ("lotion", false, false, true, false),
                ("cge", false, true, false, true),
                ("anneal", false, true, false, true),
            ]
        );
    }

    #[test]
    fn non_casting_estimator_cast_step_is_a_structured_error() {
        let ctx = EstCtx {
            fmt: None,
            quant_idx: &[],
            pool: &Pool::serial(),
            lam_reg: 0.0,
            sched: 1.0,
            streams: StepStreams { data: 0, round: 0 },
        };
        let err = Ptq.cast_step(&mut [], &ctx).unwrap_err().to_string();
        assert!(err.contains("non-casting"), "{err}");
        // casting estimators on a formatless entry fail loudly too
        let err = Qat.cast_step(&mut [], &ctx).unwrap_err().to_string();
        assert!(err.contains("no quantization format"), "{err}");
    }

    #[test]
    fn schedule_shapes() {
        assert_eq!(EstSchedule::parse("cosine").unwrap(), EstSchedule::Cosine);
        let err = EstSchedule::parse("warp").unwrap_err().to_string();
        assert!(err.contains("known schedules"), "{err}");
        for sch in [EstSchedule::Constant, EstSchedule::Linear, EstSchedule::Cosine] {
            assert_eq!(EstSchedule::parse(sch.name()).unwrap(), sch);
            assert!((sch.value_at(0, 100) - 1.0).abs() < 1e-12, "{sch:?} must start at 1");
        }
        assert_eq!(EstSchedule::Constant.value_at(100, 100), 1.0);
        assert!(EstSchedule::Linear.value_at(100, 100).abs() < 1e-12);
        assert!(EstSchedule::Cosine.value_at(100, 100).abs() < 1e-12);
        assert!((EstSchedule::Linear.value_at(50, 100) - 0.5).abs() < 1e-12);
        assert!((EstSchedule::Cosine.value_at(50, 100) - 0.5).abs() < 1e-12);
        // past the end (chunks may overshoot cfg.steps) the decay clamps
        assert!(EstSchedule::Cosine.value_at(250, 100).abs() < 1e-12);
    }

    #[test]
    fn cge_grad_step_scales_only_the_quantized_subset() {
        let pool = Pool::new(2);
        let mut grads = vec![vec![1.0f32; 70_000], vec![2.0f32; 3]];
        let ctx = EstCtx {
            fmt: None,
            quant_idx: &[0],
            pool: &pool,
            lam_reg: 0.0,
            sched: 0.25,
            streams: StepStreams { data: 0, round: 0 },
        };
        Cge.grad_step(&mut grads, &ctx).unwrap();
        assert!(grads[0].iter().all(|&g| g == 0.25));
        assert!(grads[1].iter().all(|&g| g == 2.0), "unquantized grads must pass through");
    }
}
