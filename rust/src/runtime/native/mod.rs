//! Native pure-rust CPU backend: executes train/eval/init programs
//! directly over [`HostTensor`]s — no PJRT client, no AOT artifacts,
//! no python anywhere (DESIGN.md §3).
//!
//! The backend exposes the *same* manifest-driven program registry as
//! the PJRT engine: entry names, positional I/O specs and metadata all
//! follow the AOT calling convention (DESIGN.md §2), so `Trainer`,
//! `Evaluator`, sweeps and the experiment regenerators run unchanged on
//! either backend.
//!
//! Since the program-layer and estimator-layer refactors the backend
//! is three pieces:
//!
//! * a **model- and method-agnostic driver** (this module): it
//!   interprets a scanned K-step train program as a thin loop of
//!   {copy + [`Estimator::cast_step`] forward weights, call the
//!   program's `loss_grad`, [`Estimator::grad_step`], refresh the
//!   Fisher diagonal for penalty methods (exact Gauss-Newton when the
//!   program has one, Adam's bias-corrected second moment otherwise),
//!   [`Estimator::penalty_step`], step SGD/Adam} — the driver owns no
//!   method math and no model math;
//! * pluggable [`Estimator`]s ([`estimator`]): PTQ/QAT/RAT/LOTION
//!   rebuilt as plug-ins bitwise-identical to the old hard-coded
//!   driver, plus the custom-gradient-estimator and additive-noise-
//!   annealing families from the related work;
//! * pluggable [`NativeProgram`]s: the synthetic testbeds
//!   ([`testbeds`]) and the decoder-only transformer LM
//!   ([`transformer`], unlocking fig9–fig12 offline).
//!
//! Hot loops run on a persistent worker pool (`util::pool`, long-lived
//! parked threads — no per-kernel spawn); RNG use is counter-split
//! (`Rng::stream`), so for a fixed seed the trained bitstream is
//! identical at every `--threads` setting. Per-model driver scratch
//! (activations, gradients, cast/Fisher buffers) is cached on the
//! engine across train calls.

pub mod estimator;
pub mod optim;
pub mod program;
pub mod testbeds;
pub mod transformer;

pub use self::estimator::{EstCtx, EstSchedule, Estimator};
pub use self::optim::OptKind;
pub use self::program::{DecodeSpec, EvalCtx, NativeProgram, ParamView, StepCtx, StepStreams};
pub use self::testbeds::ModelSpec;
pub use self::transformer::{LmConfig, LmProgram};

use self::optim::OptState;
use super::executor::{check_args, value, Executor, Value};
use super::factory::ExecutorFactory;
use super::manifest::{ArtifactEntry, Manifest, Role, TensorSpec};
use crate::quant::{PackedWeights, QuantFormat};
use crate::tensor::{DType, HostTensor};
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::any::Any;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Instant;

/// A model registered with the native backend: which program, which
/// optimizer, and the chunk length K of its scanned train programs.
/// The program definition is `Arc`-shared and immutable, so a model
/// list is `Send + Sync` — one list backs every engine a
/// [`NativeFactory`] spawns.
#[derive(Clone)]
pub struct NativeModel {
    pub program: Arc<dyn NativeProgram>,
    pub opt: OptKind,
    pub steps_per_call: usize,
}

impl NativeModel {
    /// Register a synthetic testbed.
    pub fn from_spec(spec: ModelSpec, opt: OptKind, steps_per_call: usize) -> NativeModel {
        NativeModel { program: Arc::new(spec), opt, steps_per_call }
    }

    /// Register an LM preset by name (AOT-matching batch geometry and
    /// K); the error lists the known presets.
    pub fn lm(preset: &str, opt: OptKind) -> Result<NativeModel> {
        Ok(NativeModel {
            program: Arc::new(LmProgram::preset(preset)?),
            opt,
            steps_per_call: LmProgram::preset_k(preset)?,
        })
    }
}

/// [`ExecutorFactory`] for the native backend: a `Send + Sync` model
/// list (the immutable program definitions, `Arc`-shared) plus the
/// per-engine worker-thread knob. Each [`NativeFactory::spawn`] builds
/// a `NativeEngine` owned by the calling thread; all spawned engines
/// share the same program definitions and synthesize identical
/// manifests, so their results are interchangeable bit-for-bit.
pub struct NativeFactory {
    models: Vec<NativeModel>,
    threads: usize,
}

impl NativeFactory {
    /// A factory over an explicit model list. `threads` is each spawned
    /// engine's kernel-pool width (`0` = auto; sweep callers typically
    /// pin `1` so sweep-level sharding is the only parallelism).
    pub fn new(models: Vec<NativeModel>, threads: usize) -> NativeFactory {
        NativeFactory { models, threads }
    }

    /// A factory over the default registry ([`NativeEngine::new`]'s
    /// model set).
    pub fn with_default_models(threads: usize) -> NativeFactory {
        NativeFactory::new(NativeEngine::default_models(), threads)
    }

    /// The per-engine worker-thread knob this factory spawns with.
    pub fn threads(&self) -> usize {
        self.threads
    }
}

impl ExecutorFactory for NativeFactory {
    fn spawn(&self) -> Result<Box<dyn Executor>> {
        Ok(Box::new(NativeEngine::with_models(&self.models).with_threads(self.threads)))
    }

    fn describe(&self) -> String {
        format!("native ({} models, threads={})", self.models.len(), self.threads)
    }

    fn model_names(&self) -> Option<Vec<String>> {
        Some(self.models.iter().map(|m| m.program.name()).collect())
    }
}

/// One executable native program (the registry value behind an entry).
enum Program {
    Train { model: NativeModel, est: &'static dyn Estimator, fmt: Option<QuantFormat> },
    Eval { model: NativeModel },
    /// RTN-quantized eval (`eval_q_{model}_{fmt}`): casts happen
    /// engine-side into packed block storage and the program consumes
    /// them through its fused dequant path — the host never builds or
    /// ships a full-f32 quantized copy.
    EvalQuant { model: NativeModel, fmt: QuantFormat },
    Init { model: NativeModel },
    /// Autoregressive decode (`decode_{model}_{fmt}`): prefill + one-
    /// token steps against engine-owned KV slots. With a format, the
    /// quantized subset is packed once per weight set and every decode
    /// GEMV reads nibble codes in place — no dense `wq` ever exists.
    Decode { model: NativeModel, fmt: Option<QuantFormat> },
}

/// One weight tensor as the decode cache holds it: dense f32, or
/// packed codes for the quantized subset of a formatted decode entry.
enum CachedParam {
    Dense(Vec<f32>),
    Packed(PackedWeights),
}

/// One live sequence: the program's KV/state box plus the engine-side
/// position counter the calling convention is validated against.
struct DecodeSlot {
    state: Box<dyn Any>,
    len: usize,
}

/// Engine-side serving state for one decode entry: the weight set the
/// caches were built from plus the per-slot sequences decoding against
/// it. `anchors` holds strong [`Value`] clones of the exact argument
/// tensors — `Rc::ptr_eq` against incoming args detects a weight swap
/// (the held clone keeps each allocation alive, so pointer equality
/// cannot false-positive through address reuse), which invalidates
/// every slot and triggers a single re-pack.
struct DecodeCache {
    anchors: Vec<Value>,
    params: Vec<CachedParam>,
    slots: HashMap<i32, DecodeSlot>,
}

/// Reusable per-model driver buffers: the program's own scratch (the
/// LM's activation/backward tensors), the gradient buffers, the
/// forward-weight copies for the casting methods and the LOTION Fisher
/// diagonals. Cached on the engine across train calls so the hot path
/// pays no per-chunk allocation; sizes are stable per model, so the
/// resize checks below are no-ops after the first chunk.
struct DriverScratch {
    program: Box<dyn Any>,
    wq: Vec<Vec<f32>>,
    grads: Vec<Vec<f32>>,
    fisher: Vec<Vec<f32>>,
}

/// The native executor: manifest-compatible registry + the
/// model-agnostic method/optimizer driver. Hot kernels run on `pool`
/// (results are bit-identical at any thread count, see `util::pool`).
pub struct NativeEngine {
    manifest: Manifest,
    programs: HashMap<String, Program>,
    pool: Pool,
    /// cumulative (calls, exec_s) per program
    timings: RefCell<HashMap<String, (u64, f64)>>,
    /// per-model reusable train-call buffers (keyed by program name)
    scratch: RefCell<HashMap<String, DriverScratch>>,
    /// per-decode-entry serving state (packed weights + KV slots)
    decode: RefCell<HashMap<String, DecodeCache>>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// The default registry: the smoke-scale linreg (d=256) used by
    /// tests/examples, the paper-scale synthetic problems behind
    /// `exp fig2`/`exp fig3`, and the LM presets behind
    /// `exp fig9..fig12` (mirrors the AOT `smoke` + `synth` + `lm`
    /// sets — plus `lm-100m` from the `e2e` set).
    pub fn new() -> NativeEngine {
        Self::with_models(&Self::default_models())
    }

    pub fn default_models() -> Vec<NativeModel> {
        let mut models = vec![
            NativeModel::from_spec(ModelSpec::LinReg { d: 256, batch: 64 }, OptKind::Sgd, 8),
            NativeModel::from_spec(ModelSpec::LinReg { d: 12000, batch: 128 }, OptKind::Sgd, 16),
        ];
        for k in [1, 2, 4, 8, 16, 32] {
            models.push(NativeModel::from_spec(
                ModelSpec::Linear2 { d: 12000, k },
                OptKind::Sgd,
                16,
            ));
        }
        for preset in transformer::preset_names() {
            models.push(NativeModel::lm(preset, OptKind::Adam).expect("builtin preset"));
        }
        models
    }

    /// Build an engine for an explicit model list (benches and tests
    /// register custom sizes/optimizers this way).
    pub fn with_models(models: &[NativeModel]) -> NativeEngine {
        let mut artifacts = BTreeMap::new();
        let mut programs = HashMap::new();
        let mut add = |entry: ArtifactEntry, prog: Program| {
            programs.insert(entry.name.clone(), prog);
            artifacts.insert(entry.name.clone(), entry);
        };
        for m in models {
            for est in estimator::all() {
                let fmts: Vec<Option<QuantFormat>> = if est.formats().is_empty() {
                    vec![None]
                } else {
                    est.formats()
                        .iter()
                        .map(|n| Some(QuantFormat::parse(n, 0).expect("builtin format")))
                        .collect()
                };
                for fmt in fmts {
                    let entry = train_entry(m, *est, fmt.as_ref());
                    add(entry, Program::Train { model: m.clone(), est: *est, fmt });
                }
            }
            add(eval_entry(m), Program::Eval { model: m.clone() });
            // "int4@64" exercises the per-block fused path through the
            // same entry surface as the per-tensor formats
            for name in ["int4", "int8", "fp4", "int4@64"] {
                let fmt = QuantFormat::parse(name, 0).expect("builtin format");
                add(eval_quant_entry(m, &fmt), Program::EvalQuant { model: m.clone(), fmt });
            }
            add(init_entry(m), Program::Init { model: m.clone() });
            if m.program.decode_spec().is_some() {
                let mut fmts: Vec<Option<QuantFormat>> = vec![None];
                for name in ["int4", "int8", "fp4", "int4@64"] {
                    fmts.push(Some(QuantFormat::parse(name, 0).expect("builtin format")));
                }
                for fmt in fmts {
                    let entry = decode_entry(m, fmt.as_ref());
                    add(entry, Program::Decode { model: m.clone(), fmt });
                }
            }
        }
        NativeEngine {
            manifest: Manifest { dir: PathBuf::from("<native>"), artifacts },
            programs,
            pool: Pool::new(0),
            timings: RefCell::new(HashMap::new()),
            scratch: RefCell::new(HashMap::new()),
            decode: RefCell::new(HashMap::new()),
        }
    }

    /// Set the worker-thread count for this engine's kernels:
    /// `0` = auto (`LOTION_THREADS` env var, else all cores). Training
    /// output is bit-identical for a fixed seed at any value — the
    /// thread count is a pure throughput knob (DESIGN.md §3).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = Pool::new(threads);
        self
    }

    /// The resolved worker-thread count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    /// Take the model's cached reusable driver buffers, or build a
    /// fresh set. Callers hand them back via [`NativeEngine::put_scratch`]
    /// when the call succeeds; an early error simply drops them and
    /// they rebuild on demand.
    fn take_scratch(&self, model_name: &str, program: &dyn NativeProgram) -> DriverScratch {
        match self.scratch.borrow_mut().remove(model_name) {
            Some(ds) => ds,
            None => DriverScratch {
                program: program.make_scratch(),
                wq: Vec::new(),
                grads: Vec::new(),
                fisher: Vec::new(),
            },
        }
    }

    fn put_scratch(&self, model_name: &str, ds: DriverScratch) {
        self.scratch.borrow_mut().insert(model_name.to_string(), ds);
    }

    fn run_train(
        &self,
        entry: &ArtifactEntry,
        model: &NativeModel,
        est: &dyn Estimator,
        fmt: Option<&QuantFormat>,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let program = &*model.program;
        let k = model.steps_per_call.max(1);
        let get = input_lookup(entry, args);
        let lrs = get("lrs")?.as_f32();
        let lam_reg = get("lam_reg")?.scalar_to_f32();
        if lrs.len() != k {
            bail!("{}: lrs has {} entries, expected K={k}", entry.name, lrs.len());
        }
        // per-step schedule values (σ_t, gradient scale) for scheduled
        // estimators; legacy entries carry no such input and their
        // hooks see a constant 1.0
        let sched: Option<Vec<f32>> = match entry.input_index("est_sched") {
            Some(_) => {
                let s = get("est_sched")?.as_f32();
                if s.len() != k {
                    bail!("{}: est_sched has {} entries, expected K={k}", entry.name, s.len());
                }
                Some(s)
            }
            None => None,
        };
        let param_names: Vec<String> = entry
            .input_specs(Role::Param)
            .iter()
            .map(|s| s.name.clone())
            .collect();
        let mut params: Vec<Vec<f32>> = param_names
            .iter()
            .map(|n| Ok(get(n)?.as_f32()))
            .collect::<Result<Vec<_>>>()?;
        let opt_named: Vec<(String, Vec<f32>)> = entry
            .input_specs(Role::Opt)
            .iter()
            .map(|s| Ok((s.name.clone(), get(&s.name)?.as_f32())))
            .collect::<Result<Vec<_>>>()?;
        let mut opt = OptState::unpack(model.opt, &param_names, &opt_named)?;
        let statics: Vec<(String, Vec<f32>)> = entry
            .input_specs(Role::Static)
            .iter()
            .map(|s| Ok((s.name.clone(), get(&s.name)?.as_f32())))
            .collect::<Result<Vec<_>>>()?;
        let data: Option<Vec<i32>> = match entry.inputs.iter().find(|s| s.role == Role::Data) {
            Some(s) => Some(get(&s.name)?.as_i32()),
            None => None,
        };
        let step_len = data.as_ref().map(|d| d.len() / k).unwrap_or(0);

        // indices of the quantized parameter subset, in param order
        let quantized = program.quantized();
        let quant_idx: Vec<usize> = param_names
            .iter()
            .enumerate()
            .filter(|(_, n)| quantized.iter().any(|q| q.as_str() == n.as_str()))
            .map(|(i, _)| i)
            .collect();

        // Counter-split streams (DESIGN.md §3): each step derives
        // stateless data/rounding stream roots from (chunk key, step
        // index) — no serial RNG dependency anywhere, so the
        // interpreted loop parallelizes and stays bit-identical at any
        // thread count.
        let chunk_seed = key_seed(get("key")?);
        // Forward-weight buffers exist only for the casting estimators:
        // PTQ/LOTION train on the FP32 master weights directly, so the
        // LM hot path pays no per-step full-model copy.
        let casts = fmt.is_some() && est.casts();
        let needs_fisher = est.needs_fisher() && fmt.is_some();
        // Take the model's cached driver scratch (or build it fresh);
        // it goes back into the cache after the chunk, so activations,
        // gradients, cast copies and Fisher buffers are allocated once
        // per run instead of once per K-step call.
        let mut ds = self.take_scratch(&entry.model_name, program);
        if ds.grads.len() != params.len() {
            ds.grads = params.iter().map(|p| vec![0.0; p.len()]).collect();
        }
        if casts && ds.wq.len() != params.len() {
            ds.wq = params.clone();
        } else if !casts {
            // drop a stale full-model cast copy if a previous method on
            // this model (e.g. a qat sweep leg) left one cached
            ds.wq = Vec::new();
        }
        if needs_fisher && ds.fisher.len() != quant_idx.len() {
            ds.fisher = quant_idx.iter().map(|&i| vec![0.0; params[i].len()]).collect();
        } else if !needs_fisher {
            ds.fisher = Vec::new();
        }
        let scratch = &mut ds.program;
        let wq = &mut ds.wq;
        let grads = &mut ds.grads;
        let fisher = &mut ds.fisher;
        let mut bases = Vec::with_capacity(k);
        let mut totals = Vec::with_capacity(k);
        for i in 0..k {
            let streams = StepStreams {
                data: Rng::stream_seed(chunk_seed, &[i as u64, 1]),
                round: Rng::stream_seed(chunk_seed, &[i as u64, 2]),
            };
            let ctx = StepCtx {
                statics: &statics,
                data: data.as_deref().map(|d| &d[i * step_len..(i + 1) * step_len]),
                streams,
                pool: &self.pool,
            };
            let ectx = EstCtx {
                fmt,
                quant_idx: &quant_idx,
                pool: &self.pool,
                lam_reg,
                sched: sched.as_ref().map(|s| s[i]).unwrap_or(1.0),
                streams,
            };
            // forward weights: the estimator's cast over the quantized
            // subset; non-casting estimators (PTQ/LOTION) forward the
            // master weights themselves
            let fwd: &[Vec<f32>] = if casts {
                for (pi, w) in wq.iter_mut().enumerate() {
                    w.copy_from_slice(&params[pi]);
                }
                est.cast_step(wq, &ectx)?;
                &wq
            } else {
                &params
            };
            let base = program.loss_grad(fwd, &ctx, scratch.as_mut(), &mut grads)?;
            let mut total = base;
            est.grad_step(grads, &ectx)?;
            if needs_fisher {
                // Fisher is stop-grad, evaluated at the master
                // weights: the program's exact Gauss-Newton diagonal
                // when it has one, Adam's moments else.
                if !program.fisher_exact_into(&params, &ctx, &mut fisher)? {
                    opt.fisher_into(&quant_idx, &mut fisher)?;
                }
            }
            est.penalty_step(&params, grads, fisher, &mut total, &ectx)?;
            opt.update(&mut params, &grads, lrs[i])?;
            bases.push(base as f32);
            totals.push(total as f32);
        }
        // return the reusable buffers to the cache for the next chunk
        // (an early `?` drops them instead — they rebuild on demand)
        self.put_scratch(&entry.model_name, ds);

        let mut out = Vec::with_capacity(entry.outputs.len());
        let mut params_iter = params.into_iter();
        for o in &entry.outputs {
            let data = match o.role {
                Role::Param => params_iter
                    .next()
                    .ok_or_else(|| anyhow!("output {:?} has no produced param", o.name))?,
                Role::Opt => opt.pack(&o.name, &param_names)?,
                Role::Metric if o.name == "base_losses" => bases.clone(),
                Role::Metric if o.name == "total_losses" => totals.clone(),
                _ => bail!("unexpected train output {:?} ({:?})", o.name, o.role),
            };
            out.push(value(HostTensor::from_f32(&o.shape, data)));
        }
        Ok(out)
    }

    fn run_eval(
        &self,
        entry: &ArtifactEntry,
        model: &NativeModel,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let params: Vec<Vec<f32>> = entry
            .input_specs(Role::Param)
            .iter()
            .map(|s| Ok(get(&s.name)?.as_f32()))
            .collect::<Result<Vec<_>>>()?;
        let statics: Vec<(String, Vec<f32>)> = entry
            .input_specs(Role::Static)
            .iter()
            .map(|s| Ok((s.name.clone(), get(&s.name)?.as_f32())))
            .collect::<Result<Vec<_>>>()?;
        let data: Option<Vec<i32>> = match entry.inputs.iter().find(|s| s.role == Role::Data) {
            Some(s) => Some(get(&s.name)?.as_i32()),
            None => None,
        };
        let ctx = EvalCtx { statics: &statics, data: data.as_deref(), pool: &self.pool };
        // evals share the model's cached scratch with train calls, so
        // periodic evaluation allocates no per-call activation buffers
        let mut ds = self.take_scratch(&entry.model_name, &*model.program);
        let loss = model.program.val_loss(&params, &ctx, ds.program.as_mut())? as f32;
        self.put_scratch(&entry.model_name, ds);
        Ok(vec![value(HostTensor::scalar_f32(loss))])
    }

    /// RTN-quantized eval: the quantized parameter subset is packed
    /// engine-side into block-quantized codes ([`PackedWeights`], ~4-8x
    /// smaller than f32) and handed to the program's
    /// [`NativeProgram::val_loss_packed`] — for the LM that is the
    /// fused dequant matmul, so no full-f32 `wq` copy of any quantized
    /// tensor exists anywhere in the eval path. Bit-identical to
    /// casting with `cast_rtn` on the host and calling the plain eval
    /// entry.
    fn run_eval_quant(
        &self,
        entry: &ArtifactEntry,
        model: &NativeModel,
        fmt: &QuantFormat,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let params: Vec<Vec<f32>> = entry
            .input_specs(Role::Param)
            .iter()
            .map(|s| Ok(get(&s.name)?.as_f32()))
            .collect::<Result<Vec<_>>>()?;
        let statics: Vec<(String, Vec<f32>)> = entry
            .input_specs(Role::Static)
            .iter()
            .map(|s| Ok((s.name.clone(), get(&s.name)?.as_f32())))
            .collect::<Result<Vec<_>>>()?;
        let data: Option<Vec<i32>> = match entry.inputs.iter().find(|s| s.role == Role::Data) {
            Some(s) => Some(get(&s.name)?.as_i32()),
            None => None,
        };
        let quantized = model.program.quantized();
        let packed: Vec<Option<PackedWeights>> = entry
            .input_specs(Role::Param)
            .iter()
            .zip(&params)
            .map(|(s, p)| {
                quantized
                    .iter()
                    .any(|q| q == &s.name)
                    .then(|| PackedWeights::pack_rtn_pool(p, fmt, &self.pool))
            })
            .collect();
        let views: Vec<ParamView<'_>> = packed
            .iter()
            .zip(&params)
            .map(|(pk, p)| match pk {
                Some(pk) => ParamView::Packed(pk),
                None => ParamView::Dense(p),
            })
            .collect();
        let ctx = EvalCtx { statics: &statics, data: data.as_deref(), pool: &self.pool };
        let mut ds = self.take_scratch(&entry.model_name, &*model.program);
        let loss = model.program.val_loss_packed(&views, &ctx, ds.program.as_mut())? as f32;
        self.put_scratch(&entry.model_name, ds);
        Ok(vec![value(HostTensor::scalar_f32(loss))])
    }

    fn run_init(
        &self,
        entry: &ArtifactEntry,
        model: &NativeModel,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let mut rng = Rng::new(key_seed(get("key")?));
        let params = model.program.init(&mut rng);
        if params.len() != entry.outputs.len() {
            bail!("init produced {} tensors, manifest expects {}", params.len(), entry.outputs.len());
        }
        Ok(entry
            .outputs
            .iter()
            .zip(params)
            .map(|(o, p)| value(HostTensor::from_f32(&o.shape, p)))
            .collect())
    }

    /// One decode call, following the `decode_{model}_{fmt}` calling
    /// convention: `ctl = [slot, pos, len]`. `pos == 0` opens (or
    /// reuses) sequence slot `slot` and prefills `tokens[..len]`;
    /// `pos > 0` requires `len == 1` and `pos` equal to the slot's
    /// cached length, and appends `tokens[0]`. Returns the next-token
    /// logits either way. The weight set is packed (quantized formats)
    /// or copied (dense) once per distinct argument tensors; every
    /// subsequent call with the same `Value`s reuses it.
    fn run_decode(
        &self,
        entry: &ArtifactEntry,
        model: &NativeModel,
        fmt: Option<&QuantFormat>,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let program = &*model.program;
        let spec = program
            .decode_spec()
            .ok_or_else(|| anyhow!("{}: program has no decode path", entry.name))?;
        let get = input_lookup(entry, args);
        let ctl = get("ctl")?.as_i32();
        let (slot, pos, len) = (ctl[0], ctl[1], ctl[2]);
        if pos < 0 || len < 0 || len as usize > spec.max_seq {
            bail!("{}: bad ctl [slot={slot}, pos={pos}, len={len}]", entry.name);
        }
        let (pos, len) = (pos as usize, len as usize);
        let tokens = get("tokens")?.as_i32();

        let param_specs = entry.input_specs(Role::Param);
        let anchors: Vec<Value> = param_specs
            .iter()
            .map(|s| Ok(args[entry.input_index(&s.name).expect("param input")].clone()))
            .collect::<Result<Vec<_>>>()?;
        let mut cache_map = self.decode.borrow_mut();
        let stale = match cache_map.get(&entry.name) {
            Some(c) => {
                c.anchors.len() != anchors.len()
                    || c.anchors.iter().zip(&anchors).any(|(a, b)| !std::rc::Rc::ptr_eq(a, b))
            }
            None => true,
        };
        if stale {
            // new weight set: pack the quantized subset once (packing
            // reads master f32s through `code_of`, never the decode
            // counter) and drop every slot — their caches were built
            // against the old weights
            let quantized = program.quantized();
            let params: Vec<CachedParam> = param_specs
                .iter()
                .zip(&anchors)
                .map(|(s, a)| {
                    let w = a.as_f32();
                    match fmt {
                        Some(fmt) if quantized.iter().any(|q| q == &s.name) => {
                            CachedParam::Packed(PackedWeights::pack_rtn_pool(&w, fmt, &self.pool))
                        }
                        _ => CachedParam::Dense(w),
                    }
                })
                .collect();
            cache_map.insert(
                entry.name.clone(),
                DecodeCache { anchors, params, slots: HashMap::new() },
            );
        }
        let cache = cache_map.get_mut(&entry.name).expect("decode cache just ensured");
        let views: Vec<ParamView<'_>> = cache
            .params
            .iter()
            .map(|p| match p {
                CachedParam::Dense(w) => ParamView::Dense(w),
                CachedParam::Packed(pk) => ParamView::Packed(pk),
            })
            .collect();

        let logits = if pos == 0 {
            if len == 0 {
                bail!("{}: prefill of zero tokens", entry.name);
            }
            let mut state = program.make_decode_state()?;
            let logits = program.prefill(&views, &tokens[..len], state.as_mut(), &self.pool)?;
            cache.slots.insert(slot, DecodeSlot { state, len });
            logits
        } else {
            if len != 1 {
                bail!("{}: incremental step wants len=1, got {len}", entry.name);
            }
            let sl = cache
                .slots
                .get_mut(&slot)
                .ok_or_else(|| anyhow!("{}: slot {slot} has no prefilled sequence", entry.name))?;
            if pos != sl.len {
                bail!("{}: slot {slot} is at position {}, not {pos}", entry.name, sl.len);
            }
            let logits = program.decode_step(&views, tokens[0], sl.state.as_mut(), &self.pool)?;
            sl.len += 1;
            logits
        };
        Ok(vec![value(HostTensor::from_f32(&[spec.vocab], logits))])
    }
}

impl Executor for NativeEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>> {
        check_args(entry, args)?;
        let prog = self
            .programs
            .get(&entry.name)
            .ok_or_else(|| anyhow!("{:?} is not a native program", entry.name))?;
        let t0 = Instant::now();
        let out = match prog {
            Program::Train { model, est, fmt } => {
                self.run_train(entry, model, *est, fmt.as_ref(), args)
            }
            Program::Eval { model } => self.run_eval(entry, model, args),
            Program::EvalQuant { model, fmt } => self.run_eval_quant(entry, model, fmt, args),
            Program::Init { model } => self.run_init(entry, model, args),
            Program::Decode { model, fmt } => self.run_decode(entry, model, fmt.as_ref(), args),
        }?;
        let mut t = self.timings.borrow_mut();
        let slot = t.entry(entry.name.clone()).or_insert((0, 0.0));
        slot.0 += 1;
        slot.1 += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, (n, e))| (k.clone(), 0.0, *n, *e))
            .collect();
        rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
        rows
    }
}

/// Positional-args lookup by manifest input name.
fn input_lookup<'a>(
    entry: &'a ArtifactEntry,
    args: &'a [Value],
) -> impl Fn(&str) -> Result<&'a HostTensor> {
    move |name: &str| {
        entry
            .input_index(name)
            .map(|i| args[i].as_ref())
            .ok_or_else(|| anyhow!("{}: no input {name:?}", entry.name))
    }
}

/// Collapse a `[2]` u32 PRNG key tensor into one rust-side seed.
fn key_seed(key: &HostTensor) -> u64 {
    let k = key.as_u32();
    ((k.first().copied().unwrap_or(0) as u64) << 32) | k.get(1).copied().unwrap_or(0) as u64
}

fn scalar_spec(name: &str, role: Role) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: vec![], dtype: DType::F32, role }
}

fn train_entry(m: &NativeModel, est: &dyn Estimator, fmt: Option<&QuantFormat>) -> ArtifactEntry {
    let program = &*m.program;
    let k = m.steps_per_call.max(1);
    let params = program.param_specs();
    let opt = m.opt.state_specs(&params);
    let mut inputs = params.clone();
    inputs.extend(opt.iter().cloned());
    inputs.extend(program.static_specs());
    if let Some(data) = program.train_data_spec(k) {
        inputs.push(data);
    }
    inputs.push(TensorSpec {
        name: "key".to_string(),
        shape: vec![2],
        dtype: DType::U32,
        role: Role::Key,
    });
    inputs.push(TensorSpec {
        name: "lrs".to_string(),
        shape: vec![k],
        dtype: DType::F32,
        role: Role::Scalar,
    });
    if est.scheduled() {
        inputs.push(TensorSpec {
            name: "est_sched".to_string(),
            shape: vec![k],
            dtype: DType::F32,
            role: Role::Scalar,
        });
    }
    inputs.push(scalar_spec("lam_reg", Role::Scalar));
    let mut outputs = params;
    outputs.extend(opt);
    for metric in ["base_losses", "total_losses"] {
        outputs.push(TensorSpec {
            name: metric.to_string(),
            shape: vec![k],
            dtype: DType::F32,
            role: Role::Metric,
        });
    }
    let fmt_name = fmt.map(|f| f.name.clone()).unwrap_or_else(|| "none".to_string());
    let name = format!("train_{}_{}_{}_k{}", program.name(), est.name(), fmt_name, k);
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs,
        kind: "train".to_string(),
        model_name: program.name(),
        method: est.name().to_string(),
        format: fmt_name,
        steps_per_call: k,
        eval_batches: 0,
        optimizer: m.opt.name().to_string(),
        quantized: program.quantized(),
    }
}

fn eval_entry(m: &NativeModel) -> ArtifactEntry {
    let program = &*m.program;
    let mut inputs = program.param_specs();
    inputs.extend(program.static_specs());
    let eval_batches = program.eval_batches().max(1);
    if let Some(data) = program.train_data_spec(eval_batches) {
        inputs.push(data);
    }
    let name = format!("eval_{}", program.name());
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs: vec![scalar_spec("val_loss", Role::Metric)],
        kind: "eval".to_string(),
        model_name: program.name(),
        method: String::new(),
        format: String::new(),
        steps_per_call: 0,
        eval_batches,
        optimizer: String::new(),
        quantized: program.quantized(),
    }
}

/// The RTN-quantized eval entry, `eval_q_{model}_{fmt}`: identical
/// calling convention to the plain eval entry (FP32 master params in,
/// scalar val_loss out) — the cast-and-pack is internal to the engine,
/// which is the whole point: callers ship master weights once and the
/// backend owns the quantized representation.
fn eval_quant_entry(m: &NativeModel, fmt: &QuantFormat) -> ArtifactEntry {
    let program = &*m.program;
    let mut inputs = program.param_specs();
    inputs.extend(program.static_specs());
    let eval_batches = program.eval_batches().max(1);
    if let Some(data) = program.train_data_spec(eval_batches) {
        inputs.push(data);
    }
    let name = format!("eval_q_{}_{}", program.name(), fmt.name);
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs: vec![scalar_spec("val_loss", Role::Metric)],
        kind: "eval_q".to_string(),
        model_name: program.name(),
        method: String::new(),
        format: fmt.name.clone(),
        steps_per_call: 0,
        eval_batches,
        optimizer: String::new(),
        quantized: program.quantized(),
    }
}

/// The autoregressive decode entry, `decode_{model}_{fmt}`: params +
/// a `[max_seq]` token buffer (prompt on prefill, the single appended
/// token on steps; trailing positions are padding) + `ctl = [slot,
/// pos, len]`, returning `[vocab]` next-token logits. Like the eval_q
/// entries, callers ship FP32 master weights — the cast-and-pack is
/// the engine's, so the packed representation never crosses the API.
fn decode_entry(m: &NativeModel, fmt: Option<&QuantFormat>) -> ArtifactEntry {
    let program = &*m.program;
    let spec = program.decode_spec().expect("decode entries need a decode_spec");
    let mut inputs = program.param_specs();
    inputs.push(TensorSpec {
        name: "tokens".to_string(),
        shape: vec![spec.max_seq],
        dtype: DType::I32,
        role: Role::Data,
    });
    inputs.push(TensorSpec {
        name: "ctl".to_string(),
        shape: vec![3],
        dtype: DType::I32,
        role: Role::Data,
    });
    let fmt_name = fmt.map(|f| f.name.clone()).unwrap_or_else(|| "none".to_string());
    let name = format!("decode_{}_{}", program.name(), fmt_name);
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs: vec![TensorSpec {
            name: "logits".to_string(),
            shape: vec![spec.vocab],
            dtype: DType::F32,
            role: Role::Metric,
        }],
        kind: "decode".to_string(),
        model_name: program.name(),
        method: String::new(),
        format: fmt_name,
        steps_per_call: 0,
        eval_batches: 0,
        optimizer: String::new(),
        quantized: program.quantized(),
    }
}

fn init_entry(m: &NativeModel) -> ArtifactEntry {
    let program = &*m.program;
    let name = format!("init_{}", program.name());
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs: vec![TensorSpec {
            name: "key".to_string(),
            shape: vec![2],
            dtype: DType::U32,
            role: Role::Key,
        }],
        outputs: program.param_specs(),
        kind: "init".to_string(),
        model_name: program.name(),
        method: String::new(),
        format: String::new(),
        steps_per_call: 0,
        eval_batches: 0,
        optimizer: String::new(),
        quantized: program.quantized(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zero_args(entry: &ArtifactEntry) -> Vec<Value> {
        entry
            .inputs
            .iter()
            .map(|s| match s.role {
                Role::Key => value(HostTensor::from_u32(&[2], vec![7, 11])),
                Role::Scalar if s.name == "lrs" => {
                    value(HostTensor::from_f32(&s.shape, vec![0.1; s.elements()]))
                }
                _ => value(HostTensor::zeros(s.dtype, &s.shape)),
            })
            .collect()
    }

    #[test]
    fn registry_is_manifest_compatible() {
        let eng = NativeEngine::new();
        let m = eng.manifest();
        let t = m.find_train("linreg_d256", "lotion", "int4").unwrap();
        assert_eq!(t.steps_per_call, 8);
        assert_eq!(t.quantized, vec!["w"]);
        assert_eq!(t.optimizer, "sgd");
        assert!(t.input_index("lam_reg").is_some());
        assert!(m.find_eval("linreg_d256").is_ok());
        assert!(m.find_eval_quant("linreg_d256", "int4").is_some());
        assert!(m.find_eval_quant("linreg_d256", "bf16").is_none());
        assert!(m.find_init("linear2_d12000_k8").is_ok());
        // ptq trains unquantized: format key collapses to "none"
        assert!(m.find_train("linreg_d256", "ptq", "int4").is_ok());
        let methods = m.methods_for("linreg_d256");
        assert!(methods.iter().any(|(me, f)| me == "lotion" && f == "fp4"));
    }

    /// Scheduled estimators (cge/anneal) register train entries with a
    /// per-step `est_sched` scalar input; the four legacy estimators'
    /// entries carry no such input, so their calling convention (and
    /// every existing golden) is byte-identical to the pre-refactor
    /// registry.
    #[test]
    fn scheduled_entries_carry_est_sched() {
        let eng = NativeEngine::new();
        let m = eng.manifest();
        for method in ["cge", "anneal"] {
            let t = m.find_train("linreg_d256", method, "int4").unwrap();
            let idx = t.input_index("est_sched").unwrap_or_else(|| panic!("{method}"));
            let spec = &t.inputs[idx];
            assert_eq!(spec.shape, vec![t.steps_per_call]);
            assert_eq!(spec.role, Role::Scalar);
            // est_sched sits between lrs and lam_reg
            assert_eq!(idx, t.input_index("lrs").unwrap() + 1);
            assert_eq!(idx + 1, t.input_index("lam_reg").unwrap());
        }
        for method in ["ptq", "qat", "rat", "lotion"] {
            let fmt = if method == "ptq" { "none" } else { "int4" };
            let t = m.find_train("linreg_d256", method, fmt).unwrap();
            assert!(t.input_index("est_sched").is_none(), "{method}");
        }
        // a scheduled entry trains end to end through the driver; the
        // zero-filled schedule makes anneal's cast exactly RTN, so the
        // call must match QAT bitwise on identical inputs
        let qat = m.find_train("linreg_d256", "qat", "int4").unwrap();
        let ann = m.find_train("linreg_d256", "anneal", "int4").unwrap();
        let fill = |entry: &ArtifactEntry| {
            let mut args = zero_args(entry);
            let d = 256;
            args[entry.input_index("wstar").unwrap()] =
                value(HostTensor::from_f32(&[d], (0..d).map(|i| (i as f32).sin()).collect()));
            args[entry.input_index("lam").unwrap()] =
                value(HostTensor::from_f32(&[d], vec![0.5; d]));
            args
        };
        let wq = eng.call(qat, &fill(qat)).unwrap();
        let wa = eng.call(ann, &fill(ann)).unwrap();
        assert_eq!(wq[0].as_ref(), wa[0].as_ref(), "anneal at sigma=0 must be QAT");
    }

    #[test]
    fn lm_presets_are_registered() {
        let eng = NativeEngine::new();
        let m = eng.manifest();
        for model in ["lm-tiny", "lm-150m-sim", "lm-300m-sim"] {
            let t = m.find_train(model, "lotion", "int4").unwrap();
            assert_eq!(t.optimizer, "adam", "{model}");
            // the data-role token input sits between statics and key
            let data = t.inputs.iter().find(|s| s.role == Role::Data).expect(model);
            assert_eq!(data.shape[0], t.steps_per_call);
            assert!(t.quantized.contains(&"lm_head".to_string()));
            assert!(!t.quantized.contains(&"embed".to_string()));
            assert!(m.find_eval(model).is_ok());
            for fmt in ["int4", "int8", "fp4"] {
                assert!(m.find_eval_quant(model, fmt).is_some(), "{model}/{fmt}");
            }
            assert!(m.find_init(model).is_ok());
        }
        // AOT-matching chunk lengths and batch geometry
        assert_eq!(m.find_train("lm-tiny", "rat", "int4").unwrap().steps_per_call, 4);
        assert_eq!(m.find_eval("lm-150m-sim").unwrap().eval_batches, 8);
        let ed = m.find_eval("lm-150m-sim").unwrap();
        let dspec = ed.inputs.iter().find(|s| s.role == Role::Data).unwrap();
        assert_eq!(dspec.shape, vec![8, 4, 129]);
        // decode entries: every LM preset, dense + all quant formats
        // (including the per-block one); testbeds have none
        for fmt in ["none", "int4", "int8", "fp4", "int4@64"] {
            let dec = m.find_decode("lm-tiny", fmt).expect(fmt);
            assert_eq!(dec.kind, "decode");
            assert_eq!(dec.outputs[0].shape, vec![256]);
            let toks = dec.inputs.iter().find(|s| s.name == "tokens").unwrap();
            assert_eq!(toks.shape, vec![64]);
        }
        assert!(m.find_decode("linreg_d256", "none").is_none());
        assert!(m.find_eval_quant("lm-tiny", "int4@64").is_some());
    }

    /// The decode entry's slot protocol end to end: prefill + N
    /// incremental steps give bitwise the logits of a fresh full
    /// prefill at every position, slot misuse errors instead of
    /// corrupting caches, and swapping the weight tensors invalidates
    /// the live slots.
    #[test]
    fn decode_entry_matches_fresh_prefill_bitwise() {
        let cfg = LmConfig { vocab: 17, d_model: 8, n_layers: 1, n_heads: 2, seq_len: 8 };
        let prog = LmProgram::new("lm-dec-entry", cfg, 1, 1).unwrap();
        let eng = NativeEngine::with_models(&[NativeModel {
            program: Arc::new(prog),
            opt: OptKind::Adam,
            steps_per_call: 1,
        }]);
        let m = eng.manifest();
        let init = m.find_init("lm-dec-entry").unwrap();
        let params = eng.call(init, &zero_args(init)).unwrap();
        let t = 8usize;
        let mut rng = Rng::new(17);
        let toks: Vec<i32> = (0..t).map(|_| rng.below(17) as i32).collect();
        for fmt in ["none", "int4", "int4@64", "fp4"] {
            let dec = m.find_decode("lm-dec-entry", fmt).expect(fmt).clone();
            let mk_args = |slot: i32, pos: usize, len: usize, window: &[i32]| -> Vec<Value> {
                let mut args = zero_args(&dec);
                for (spec, p) in dec.input_specs(Role::Param).iter().zip(&params) {
                    args[dec.input_index(&spec.name).unwrap()] = p.clone();
                }
                let mut padded = window.to_vec();
                padded.resize(t, 0);
                args[dec.input_index("tokens").unwrap()] =
                    value(HostTensor::from_i32(&[t], padded));
                args[dec.input_index("ctl").unwrap()] =
                    value(HostTensor::from_i32(&[3], vec![slot, pos as i32, len as i32]));
                args
            };
            let call = |slot: i32, pos: usize, len: usize, window: &[i32]| -> Vec<f32> {
                eng.call(&dec, &mk_args(slot, pos, len, window)).unwrap()[0].as_f32()
            };
            let mut inc = call(5, 0, 3, &toks[..3]);
            for p in 3..t {
                let fresh = call(9, 0, p, &toks[..p]);
                assert_eq!(
                    inc.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    fresh.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{fmt}: pos {p}"
                );
                inc = call(5, p, 1, &[toks[p]]);
            }
            // misuse: unknown slot, stale position, bad step length
            assert!(eng.call(&dec, &mk_args(42, 3, 1, &[1])).is_err());
            assert!(eng.call(&dec, &mk_args(5, 2, 1, &[1])).is_err());
            assert!(eng.call(&dec, &mk_args(5, t, 2, &toks[..2])).is_err());
            assert!(eng.call(&dec, &mk_args(5, 0, 0, &[])).is_err());
            // swapping weights drops live slots: the next step errors
            let fresh_params = {
                let mut args = zero_args(init);
                args[init.input_index("key").unwrap()] =
                    value(HostTensor::from_u32(&[2], vec![5, 6]));
                eng.call(init, &args).unwrap()
            };
            let mut args = mk_args(5, t - 1, 1, &[toks[0]]);
            for (spec, p) in dec.input_specs(Role::Param).iter().zip(&fresh_params) {
                args[dec.input_index(&spec.name).unwrap()] = p.clone();
            }
            assert!(eng.call(&dec, &args).is_err(), "{fmt}: slot survived a weight swap");
        }
    }

    /// The engine-side packed eval entry must give bitwise the loss of
    /// casting the quantized subset on the host and calling the plain
    /// eval entry — the packed representation is an optimization, not
    /// a semantic change.
    #[test]
    fn quantized_eval_entry_matches_host_cast_eval() {
        use crate::quant::cast_rtn;
        let eng = NativeEngine::with_models(&[NativeModel::from_spec(
            ModelSpec::LinReg { d: 16, batch: 8 },
            OptKind::Sgd,
            4,
        )]);
        let m = eng.manifest();
        let eval = m.find_eval("linreg_d16").unwrap();
        let d = 16;
        let w: Vec<f32> = (0..d).map(|i| (i as f32 * 0.37).sin()).collect();
        let mk_args = |entry: &ArtifactEntry, w: &[f32]| {
            let mut args = zero_args(entry);
            args[entry.input_index("w").unwrap()] =
                value(HostTensor::from_f32(&[d], w.to_vec()));
            args[entry.input_index("lam").unwrap()] =
                value(HostTensor::from_f32(&[d], vec![1.5; d]));
            args[entry.input_index("wstar").unwrap()] =
                value(HostTensor::from_f32(&[d], (0..d).map(|i| i as f32 / 8.0).collect()));
            args
        };
        for name in ["int4", "int8", "fp4"] {
            let eval_q = m.find_eval_quant("linreg_d16", name).expect("eval_q registered");
            assert_eq!(eval_q.kind, "eval_q");
            assert_eq!(eval_q.format, name);
            let fmt = QuantFormat::parse(name, 0).unwrap();
            let mut wq = w.clone();
            cast_rtn(&mut wq, &fmt);
            let host = eng.call(eval, &mk_args(eval, &wq)).unwrap()[0].scalar_to_f32();
            let fused = eng.call(eval_q, &mk_args(eval_q, &w)).unwrap()[0].scalar_to_f32();
            assert_eq!(fused.to_bits(), host.to_bits(), "{name}: {fused} vs {host}");
        }
        // AOT-style manifests without eval_q entries return None
        assert!(m.find_eval_quant("linreg_d16", "int16").is_none());
    }

    #[test]
    fn unknown_model_error_lists_presets() {
        let err = NativeModel::lm("lm-9000", OptKind::Adam).unwrap_err().to_string();
        assert!(err.contains("lm-tiny"), "{err}");
        let eng = NativeEngine::new();
        let err = eng.manifest().find_train("lm-9000", "lotion", "int4").unwrap_err();
        assert!(format!("{err:#}").contains("known models"), "{err:#}");
    }

    #[test]
    fn init_train_eval_roundtrip() {
        let eng = NativeEngine::with_models(&[NativeModel::from_spec(
            ModelSpec::LinReg { d: 16, batch: 8 },
            OptKind::Sgd,
            4,
        )]);
        let m = eng.manifest();
        let init = m.find_init("linreg_d16").unwrap();
        let params = eng.call(init, &zero_args(init)).unwrap();
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].shape, vec![16]);

        let train = m.find_train("linreg_d16", "lotion", "int4").unwrap();
        let mut args = zero_args(train);
        // a non-trivial target makes losses non-zero
        args[train.input_index("wstar").unwrap()] =
            value(HostTensor::from_f32(&[16], (0..16).map(|i| i as f32 / 8.0).collect()));
        args[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[16], vec![1.0; 16]));
        let out = eng.call(train, &args).unwrap();
        assert_eq!(out.len(), train.outputs.len());
        let bases = out[train.outputs.len() - 2].as_f32();
        assert_eq!(bases.len(), 4);
        assert!(bases.iter().all(|b| b.is_finite()));

        let eval = m.find_eval("linreg_d16").unwrap();
        let mut eargs = zero_args(eval);
        eargs[eval.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[16], vec![1.0; 16]));
        let v = eng.call(eval, &eargs).unwrap();
        assert!(v[0].scalar_to_f32().is_finite());
    }

    #[test]
    fn train_calls_are_deterministic() {
        let eng = NativeEngine::new();
        let train = eng.manifest().find_train("linreg_d256", "rat", "int4").unwrap();
        let mut args = zero_args(train);
        let d = 256;
        args[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[d], vec![0.5; d]));
        args[train.input_index("wstar").unwrap()] =
            value(HostTensor::from_f32(&[d], (0..d).map(|i| (i as f32).sin()).collect()));
        let a = eng.call(train, &args).unwrap();
        let b = eng.call(train, &args).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.as_ref(), y.as_ref());
        }
        // a different key moves the data stream, so the weights differ
        args[train.input_index("key").unwrap()] =
            value(HostTensor::from_u32(&[2], vec![99, 100]));
        let c = eng.call(train, &args).unwrap();
        assert_ne!(a[0].as_ref(), c[0].as_ref());
        assert_eq!(eng.timing_report().len(), 1);
        assert_eq!(eng.timing_report()[0].2, 3);
    }

    /// The driver's cross-call scratch cache must not leak statics
    /// between runs on one engine: training with statics A, then B,
    /// then A again gives bit-identical outputs for both A calls (a
    /// stale `sqrt_lam` hoist keyed on length alone would not).
    #[test]
    fn scratch_cache_does_not_leak_statics_across_runs() {
        let eng = NativeEngine::new();
        let train = eng.manifest().find_train("linreg_d256", "lotion", "int4").unwrap();
        let d = 256;
        let mut args = zero_args(train);
        args[train.input_index("wstar").unwrap()] =
            value(HostTensor::from_f32(&[d], (0..d).map(|i| (i as f32).cos()).collect()));
        args[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[d], vec![0.5; d]));
        let a1 = eng.call(train, &args).unwrap();
        let mut args_b = args.clone();
        args_b[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[d], vec![2.0; d]));
        let b = eng.call(train, &args_b).unwrap();
        let a2 = eng.call(train, &args).unwrap();
        for (x, y) in a1.iter().zip(&a2) {
            assert_eq!(x.as_ref(), y.as_ref(), "statics leaked through the scratch cache");
        }
        // different lam really does move the trained weights
        assert_ne!(a1[0].as_ref(), b[0].as_ref());
    }

    #[test]
    fn rejects_foreign_entries_and_bad_arity() {
        let eng = NativeEngine::new();
        let train = eng.manifest().find_train("linreg_d256", "qat", "int4").unwrap();
        assert!(eng.call(train, &[]).is_err());
        let mut fake = train.clone();
        fake.name = "no_such_program".to_string();
        assert!(eng.call(&fake, &zero_args(train)).is_err());
    }

    /// LOTION on a data-fed Adam LM: one train call runs end-to-end
    /// through the driver (cast → loss_grad → penalty via Adam Fisher
    /// → Adam step) and advances the step counter.
    #[test]
    fn lm_train_call_runs_through_driver() {
        let prog = LmProgram::new(
            "lm-driver-test",
            LmConfig { vocab: 32, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 8 },
            2,
            1,
        )
        .unwrap();
        let eng = NativeEngine::with_models(&[NativeModel {
            program: Arc::new(prog),
            opt: OptKind::Adam,
            steps_per_call: 3,
        }]);
        let m = eng.manifest();
        let init = m.find_init("lm-driver-test").unwrap();
        let params = eng.call(init, &zero_args(init)).unwrap();
        let train = m.find_train("lm-driver-test", "lotion", "int4").unwrap().clone();
        let mut args = zero_args(&train);
        // adopt the real init params and a non-degenerate token batch
        for (spec, p) in train.input_specs(Role::Param).iter().zip(&params) {
            args[train.input_index(&spec.name).unwrap()] = p.clone();
        }
        let dspec = train.inputs.iter().find(|s| s.role == Role::Data).unwrap().clone();
        let mut rng = Rng::new(3);
        let toks: Vec<i32> = (0..dspec.elements()).map(|_| rng.below(32) as i32).collect();
        args[train.input_index("tokens").unwrap()] =
            value(HostTensor::from_i32(&dspec.shape, toks));
        args[train.input_index("lam_reg").unwrap()] = value(HostTensor::scalar_f32(10.0));
        let out = eng.call(&train, &args).unwrap();
        let bases = out[train.outputs.len() - 2].as_f32();
        let totals = out[train.outputs.len() - 1].as_f32();
        assert_eq!(bases.len(), 3);
        assert!(bases.iter().all(|b| b.is_finite()));
        // the sigma^2 penalty is >= 0, so total >= base at every step
        for (b, t) in bases.iter().zip(&totals) {
            assert!(t >= b, "total {t} < base {b}");
        }
        // step counter advanced through the K=3 interpreted steps
        let t_idx = train.output_index("t").unwrap();
        assert_eq!(out[t_idx].scalar_to_f32(), 3.0);
    }
}
