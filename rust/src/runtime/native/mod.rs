//! Native pure-rust CPU backend: executes the synthetic train/eval/init
//! programs directly over [`HostTensor`]s — no PJRT client, no AOT
//! artifacts, no python anywhere (DESIGN.md §3).
//!
//! The backend exposes the *same* manifest-driven program registry as
//! the PJRT engine: entry names, positional I/O specs and metadata all
//! follow the AOT calling convention (DESIGN.md §2), so `Trainer`,
//! `Evaluator`, sweeps and the experiment regenerators run unchanged on
//! either backend. What differs is purely how `call` executes: here a
//! scanned K-step train program is an interpreted loop of
//! forward/backward/optimizer steps built on the `quant` substrate's
//! exact RTN/RR casts and the Eq. 3 penalty.
//!
//! Hot loops (minibatch sampling, linear2 row math, quant block
//! kernels) run on a scoped worker pool (`util::pool`); RNG use is
//! counter-split (`Rng::stream`), so for a fixed seed the trained
//! bitstream is identical at every `--threads` setting.
//!
//! * [`model`] — linreg / linear2 math (loss, grads, methods, fisher).
//! * [`optim`] — SGD / Adam steppers + manifest-shaped state packing.

pub mod model;
pub mod optim;

pub use self::model::{Method, ModelSpec, StepScratch, StepStreams};
pub use self::optim::OptKind;

use super::executor::{check_args, value, Executor, Value};
use super::manifest::{ArtifactEntry, Manifest, Role, TensorSpec};
use crate::quant::QuantFormat;
use crate::tensor::{DType, HostTensor};
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use self::optim::OptState;
use std::cell::RefCell;
use std::collections::{BTreeMap, HashMap};
use std::path::PathBuf;
use std::time::Instant;

/// A model registered with the native backend: which testbed, which
/// optimizer, and the chunk length K of its scanned train programs.
#[derive(Clone, Copy, Debug)]
pub struct NativeModel {
    pub spec: ModelSpec,
    pub opt: OptKind,
    pub steps_per_call: usize,
}

/// One executable native program (the registry value behind an entry).
enum Program {
    Train {
        spec: ModelSpec,
        opt: OptKind,
        method: Method,
        fmt: Option<QuantFormat>,
        k: usize,
    },
    Eval {
        spec: ModelSpec,
    },
    Init {
        spec: ModelSpec,
    },
}

/// The native executor: manifest-compatible registry + interpreter.
/// Hot kernels run on `pool` (tentpole: scoped worker threads; results
/// are bit-identical at any thread count, see `util::pool`).
pub struct NativeEngine {
    manifest: Manifest,
    programs: HashMap<String, Program>,
    pool: Pool,
    /// cumulative (calls, exec_s) per program
    timings: RefCell<HashMap<String, (u64, f64)>>,
}

impl Default for NativeEngine {
    fn default() -> Self {
        Self::new()
    }
}

impl NativeEngine {
    /// The default registry: the smoke-scale linreg (d=256) used by
    /// tests/examples plus the paper-scale synthetic problems behind
    /// `exp fig2`/`exp fig3` (mirrors the AOT `smoke` + `synth` sets).
    pub fn new() -> NativeEngine {
        Self::with_models(&Self::default_models())
    }

    pub fn default_models() -> Vec<NativeModel> {
        let mut models = vec![
            NativeModel {
                spec: ModelSpec::LinReg { d: 256, batch: 64 },
                opt: OptKind::Sgd,
                steps_per_call: 8,
            },
            NativeModel {
                spec: ModelSpec::LinReg { d: 12000, batch: 128 },
                opt: OptKind::Sgd,
                steps_per_call: 16,
            },
        ];
        for k in [1, 2, 4, 8, 16, 32] {
            models.push(NativeModel {
                spec: ModelSpec::Linear2 { d: 12000, k },
                opt: OptKind::Sgd,
                steps_per_call: 16,
            });
        }
        models
    }

    /// Build an engine for an explicit model list (benches and tests
    /// register custom sizes/optimizers this way).
    pub fn with_models(models: &[NativeModel]) -> NativeEngine {
        let mut artifacts = BTreeMap::new();
        let mut programs = HashMap::new();
        let mut add = |entry: ArtifactEntry, prog: Program| {
            programs.insert(entry.name.clone(), prog);
            artifacts.insert(entry.name.clone(), entry);
        };
        for m in models {
            for method in [Method::Ptq, Method::Qat, Method::Rat, Method::Lotion] {
                let fmts: Vec<Option<QuantFormat>> = if method == Method::Ptq {
                    vec![None]
                } else {
                    ["int4", "int8", "fp4"]
                        .iter()
                        .map(|n| Some(QuantFormat::parse(n, 0).expect("builtin format")))
                        .collect()
                };
                for fmt in fmts {
                    let entry = train_entry(m, method, fmt.as_ref());
                    add(
                        entry,
                        Program::Train {
                            spec: m.spec,
                            opt: m.opt,
                            method,
                            fmt,
                            k: m.steps_per_call.max(1),
                        },
                    );
                }
            }
            add(eval_entry(&m.spec), Program::Eval { spec: m.spec });
            add(init_entry(&m.spec), Program::Init { spec: m.spec });
        }
        NativeEngine {
            manifest: Manifest { dir: PathBuf::from("<native>"), artifacts },
            programs,
            pool: Pool::new(0),
            timings: RefCell::new(HashMap::new()),
        }
    }

    /// Set the worker-thread count for this engine's kernels:
    /// `0` = auto (`LOTION_THREADS` env var, else all cores). Training
    /// output is bit-identical for a fixed seed at any value — the
    /// thread count is a pure throughput knob (DESIGN.md §3).
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.pool = Pool::new(threads);
        self
    }

    /// The resolved worker-thread count.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn run_train(
        &self,
        entry: &ArtifactEntry,
        spec: ModelSpec,
        opt_kind: OptKind,
        method: Method,
        fmt: Option<&QuantFormat>,
        k: usize,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let lam = get("lam")?.as_f32();
        let wstar = get("wstar")?.as_f32();
        let lrs = get("lrs")?.as_f32();
        let lam_reg = get("lam_reg")?.scalar_to_f32();
        let param_names: Vec<String> = entry
            .input_specs(Role::Param)
            .iter()
            .map(|s| s.name.clone())
            .collect();
        let mut params: Vec<Vec<f32>> = param_names
            .iter()
            .map(|n| Ok(get(n)?.as_f32()))
            .collect::<Result<Vec<_>>>()?;
        let opt_named: Vec<(String, Vec<f32>)> = entry
            .input_specs(Role::Opt)
            .iter()
            .map(|s| Ok((s.name.clone(), get(&s.name)?.as_f32())))
            .collect::<Result<Vec<_>>>()?;
        let mut opt = OptState::unpack(opt_kind, &param_names, &opt_named)?;
        if lrs.len() != k {
            bail!("{}: lrs has {} entries, expected K={k}", entry.name, lrs.len());
        }

        // Counter-split streams (tentpole): each step derives stateless
        // data/rounding stream roots from (chunk key, step index), and
        // the kernels key per-row / per-chunk sub-streams off those —
        // no serial RNG dependency anywhere, so the interpreted loop
        // parallelizes and stays bit-identical at any thread count.
        let chunk_seed = key_seed(get("key")?);
        let mut scratch = StepScratch::new(&spec, &lam);
        let mut bases = Vec::with_capacity(k);
        let mut totals = Vec::with_capacity(k);
        for i in 0..k {
            let streams = StepStreams {
                data: Rng::stream_seed(chunk_seed, &[i as u64, 1]),
                round: Rng::stream_seed(chunk_seed, &[i as u64, 2]),
            };
            let out = spec.step(
                &params,
                &lam,
                &wstar,
                method,
                fmt,
                lam_reg,
                streams,
                &mut scratch,
                &self.pool,
            );
            opt.update(&mut params, &out.grads, lrs[i])?;
            bases.push(out.base as f32);
            totals.push(out.total as f32);
        }

        let mut out = Vec::with_capacity(entry.outputs.len());
        let mut params_iter = params.into_iter();
        for o in &entry.outputs {
            let data = match o.role {
                Role::Param => params_iter
                    .next()
                    .ok_or_else(|| anyhow!("output {:?} has no produced param", o.name))?,
                Role::Opt => opt.pack(&o.name, &param_names)?,
                Role::Metric if o.name == "base_losses" => bases.clone(),
                Role::Metric if o.name == "total_losses" => totals.clone(),
                _ => bail!("unexpected train output {:?} ({:?})", o.name, o.role),
            };
            out.push(value(HostTensor::from_f32(&o.shape, data)));
        }
        Ok(out)
    }

    fn run_eval(
        &self,
        entry: &ArtifactEntry,
        spec: ModelSpec,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let lam = get("lam")?.as_f32();
        let wstar = get("wstar")?.as_f32();
        let params: Vec<Vec<f32>> = entry
            .input_specs(Role::Param)
            .iter()
            .map(|s| Ok(get(&s.name)?.as_f32()))
            .collect::<Result<Vec<_>>>()?;
        let loss = spec.val_loss_pool(&params, &lam, &wstar, &self.pool) as f32;
        Ok(vec![value(HostTensor::scalar_f32(loss))])
    }

    fn run_init(
        &self,
        entry: &ArtifactEntry,
        spec: ModelSpec,
        args: &[Value],
    ) -> Result<Vec<Value>> {
        let get = input_lookup(entry, args);
        let mut rng = Rng::new(key_seed(get("key")?));
        let params = spec.init(&mut rng);
        if params.len() != entry.outputs.len() {
            bail!("init produced {} tensors, manifest expects {}", params.len(), entry.outputs.len());
        }
        Ok(entry
            .outputs
            .iter()
            .zip(params)
            .map(|(o, p)| value(HostTensor::from_f32(&o.shape, p)))
            .collect())
    }
}

impl Executor for NativeEngine {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn call(&self, entry: &ArtifactEntry, args: &[Value]) -> Result<Vec<Value>> {
        check_args(entry, args)?;
        let prog = self
            .programs
            .get(&entry.name)
            .ok_or_else(|| anyhow!("{:?} is not a native program", entry.name))?;
        let t0 = Instant::now();
        let out = match prog {
            Program::Train { spec, opt, method, fmt, k } => {
                self.run_train(entry, *spec, *opt, *method, fmt.as_ref(), *k, args)
            }
            Program::Eval { spec } => self.run_eval(entry, *spec, args),
            Program::Init { spec } => self.run_init(entry, *spec, args),
        }?;
        let mut t = self.timings.borrow_mut();
        let slot = t.entry(entry.name.clone()).or_insert((0, 0.0));
        slot.0 += 1;
        slot.1 += t0.elapsed().as_secs_f64();
        Ok(out)
    }

    fn timing_report(&self) -> Vec<(String, f64, u64, f64)> {
        let mut rows: Vec<_> = self
            .timings
            .borrow()
            .iter()
            .map(|(k, (n, e))| (k.clone(), 0.0, *n, *e))
            .collect();
        rows.sort_by(|a, b| b.3.partial_cmp(&a.3).unwrap());
        rows
    }
}

/// Positional-args lookup by manifest input name.
fn input_lookup<'a>(
    entry: &'a ArtifactEntry,
    args: &'a [Value],
) -> impl Fn(&str) -> Result<&'a HostTensor> {
    move |name: &str| {
        entry
            .input_index(name)
            .map(|i| args[i].as_ref())
            .ok_or_else(|| anyhow!("{}: no input {name:?}", entry.name))
    }
}

/// Collapse a `[2]` u32 PRNG key tensor into one rust-side seed.
fn key_seed(key: &HostTensor) -> u64 {
    let k = key.as_u32();
    ((k.first().copied().unwrap_or(0) as u64) << 32) | k.get(1).copied().unwrap_or(0) as u64
}

fn scalar_spec(name: &str, role: Role) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: vec![], dtype: DType::F32, role }
}

fn train_entry(m: &NativeModel, method: Method, fmt: Option<&QuantFormat>) -> ArtifactEntry {
    let spec = &m.spec;
    let k = m.steps_per_call.max(1);
    let params = spec.param_specs();
    let opt = m.opt.state_specs(&params);
    let mut inputs = params.clone();
    inputs.extend(opt.iter().cloned());
    inputs.extend(spec.static_specs());
    inputs.push(TensorSpec {
        name: "key".to_string(),
        shape: vec![2],
        dtype: DType::U32,
        role: Role::Key,
    });
    inputs.push(TensorSpec {
        name: "lrs".to_string(),
        shape: vec![k],
        dtype: DType::F32,
        role: Role::Scalar,
    });
    inputs.push(scalar_spec("lam_reg", Role::Scalar));
    let mut outputs = params;
    outputs.extend(opt);
    for metric in ["base_losses", "total_losses"] {
        outputs.push(TensorSpec {
            name: metric.to_string(),
            shape: vec![k],
            dtype: DType::F32,
            role: Role::Metric,
        });
    }
    let fmt_name = fmt.map(|f| f.name.clone()).unwrap_or_else(|| "none".to_string());
    let name = format!("train_{}_{}_{}_k{}", spec.name(), method.name(), fmt_name, k);
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs,
        kind: "train".to_string(),
        model_name: spec.name(),
        method: method.name().to_string(),
        format: fmt_name,
        steps_per_call: k,
        eval_batches: 0,
        optimizer: m.opt.name().to_string(),
        quantized: spec.quantized(),
    }
}

fn eval_entry(spec: &ModelSpec) -> ArtifactEntry {
    let mut inputs = spec.param_specs();
    inputs.extend(spec.static_specs());
    let name = format!("eval_{}", spec.name());
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs,
        outputs: vec![scalar_spec("val_loss", Role::Metric)],
        kind: "eval".to_string(),
        model_name: spec.name(),
        method: String::new(),
        format: String::new(),
        steps_per_call: 0,
        eval_batches: 1,
        optimizer: String::new(),
        quantized: spec.quantized(),
    }
}

fn init_entry(spec: &ModelSpec) -> ArtifactEntry {
    let name = format!("init_{}", spec.name());
    ArtifactEntry {
        file: PathBuf::from(format!("native:{name}")),
        name,
        inputs: vec![TensorSpec {
            name: "key".to_string(),
            shape: vec![2],
            dtype: DType::U32,
            role: Role::Key,
        }],
        outputs: spec.param_specs(),
        kind: "init".to_string(),
        model_name: spec.name(),
        method: String::new(),
        format: String::new(),
        steps_per_call: 0,
        eval_batches: 0,
        optimizer: String::new(),
        quantized: spec.quantized(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn zero_args(entry: &ArtifactEntry) -> Vec<Value> {
        entry
            .inputs
            .iter()
            .map(|s| match s.role {
                Role::Key => value(HostTensor::from_u32(&[2], vec![7, 11])),
                Role::Scalar if s.name == "lrs" => {
                    value(HostTensor::from_f32(&s.shape, vec![0.1; s.elements()]))
                }
                _ => value(HostTensor::zeros(s.dtype, &s.shape)),
            })
            .collect()
    }

    #[test]
    fn registry_is_manifest_compatible() {
        let eng = NativeEngine::new();
        let m = eng.manifest();
        let t = m.find_train("linreg_d256", "lotion", "int4").unwrap();
        assert_eq!(t.steps_per_call, 8);
        assert_eq!(t.quantized, vec!["w"]);
        assert_eq!(t.optimizer, "sgd");
        assert!(t.input_index("lam_reg").is_some());
        assert!(m.find_eval("linreg_d256").is_ok());
        assert!(m.find_init("linear2_d12000_k8").is_ok());
        // ptq trains unquantized: format key collapses to "none"
        assert!(m.find_train("linreg_d256", "ptq", "int4").is_ok());
        let methods = m.methods_for("linreg_d256");
        assert!(methods.iter().any(|(me, f)| me == "lotion" && f == "fp4"));
        assert!(m.find_train("lm-tiny", "lotion", "int4").is_err());
    }

    #[test]
    fn init_train_eval_roundtrip() {
        let eng = NativeEngine::with_models(&[NativeModel {
            spec: ModelSpec::LinReg { d: 16, batch: 8 },
            opt: OptKind::Sgd,
            steps_per_call: 4,
        }]);
        let m = eng.manifest();
        let init = m.find_init("linreg_d16").unwrap();
        let params = eng.call(init, &zero_args(init)).unwrap();
        assert_eq!(params.len(), 1);
        assert_eq!(params[0].shape, vec![16]);

        let train = m.find_train("linreg_d16", "lotion", "int4").unwrap();
        let mut args = zero_args(train);
        // a non-trivial target makes losses non-zero
        args[train.input_index("wstar").unwrap()] =
            value(HostTensor::from_f32(&[16], (0..16).map(|i| i as f32 / 8.0).collect()));
        args[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[16], vec![1.0; 16]));
        let out = eng.call(train, &args).unwrap();
        assert_eq!(out.len(), train.outputs.len());
        let bases = out[train.outputs.len() - 2].as_f32();
        assert_eq!(bases.len(), 4);
        assert!(bases.iter().all(|b| b.is_finite()));

        let eval = m.find_eval("linreg_d16").unwrap();
        let mut eargs = zero_args(eval);
        eargs[eval.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[16], vec![1.0; 16]));
        let v = eng.call(eval, &eargs).unwrap();
        assert!(v[0].scalar_to_f32().is_finite());
    }

    #[test]
    fn train_calls_are_deterministic() {
        let eng = NativeEngine::new();
        let train = eng.manifest().find_train("linreg_d256", "rat", "int4").unwrap();
        let mut args = zero_args(train);
        let d = 256;
        args[train.input_index("lam").unwrap()] =
            value(HostTensor::from_f32(&[d], vec![0.5; d]));
        args[train.input_index("wstar").unwrap()] =
            value(HostTensor::from_f32(&[d], (0..d).map(|i| (i as f32).sin()).collect()));
        let a = eng.call(train, &args).unwrap();
        let b = eng.call(train, &args).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.as_ref(), y.as_ref());
        }
        // a different key moves the data stream, so the weights differ
        args[train.input_index("key").unwrap()] =
            value(HostTensor::from_u32(&[2], vec![99, 100]));
        let c = eng.call(train, &args).unwrap();
        assert_ne!(a[0].as_ref(), c[0].as_ref());
        assert_eq!(eng.timing_report().len(), 1);
        assert_eq!(eng.timing_report()[0].2, 3);
    }

    #[test]
    fn rejects_foreign_entries_and_bad_arity() {
        let eng = NativeEngine::new();
        let train = eng.manifest().find_train("linreg_d256", "qat", "int4").unwrap();
        assert!(eng.call(train, &[]).is_err());
        let mut fake = train.clone();
        fake.name = "no_such_program".to_string();
        assert!(eng.call(&fake, &zero_args(train)).is_err());
    }
}
