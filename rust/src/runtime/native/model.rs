//! Native model math: the synthetic testbeds (§4.1 linreg, §4.2
//! linear2) implemented directly over flat `f32` buffers — forward,
//! backward, method transformations (PTQ/QAT/RAT/LOTION) and exact
//! validation losses. Semantics mirror `python/compile/models/*` and
//! `methods.py`; rounding and the Eq. 3 penalty reuse the `quant`
//! substrate bit-for-bit (DESIGN.md §3).
//!
//! Hot loops are row-parallel on a [`Pool`]: minibatch rows sample
//! from per-row counter streams (`Rng::stream(data_seed, &[row])`),
//! partial gradients accumulate per fixed [`ROW_CHUNK`] and fold in
//! chunk order, and the linear2 row loops split by output row — all
//! partitioned independently of the thread count, so training is
//! bit-identical at `--threads 1` and `--threads N`.

use crate::data::synth::population_loss;
use crate::quant::{cast_rr_seeded, cast_rtn_pool, lotion_penalty_and_grad_pool, QuantFormat};
use crate::runtime::manifest::{Role, TensorSpec};
use crate::tensor::DType;
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK, PAR_MIN};
use crate::util::rng::Rng;
use anyhow::{bail, Result};
use std::ops::Range;

/// Minibatch rows per parallel task — a fixed constant (never derived
/// from the thread count) so the gradient reduction order, and with it
/// the trained bitstream, is invariant to `--threads`.
const ROW_CHUNK: usize = 4;

/// Training-method transformation of the base loss (methods.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Ptq,
    Qat,
    Rat,
    Lotion,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "ptq" => Method::Ptq,
            "qat" => Method::Qat,
            "rat" => Method::Rat,
            "lotion" => Method::Lotion,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Ptq => "ptq",
            Method::Qat => "qat",
            Method::Rat => "rat",
            Method::Lotion => "lotion",
        }
    }
}

/// A native testbed model: defines parameter layout, data distribution,
/// loss/gradients, and the exact Gauss-Newton diagonal LOTION uses.
#[derive(Clone, Copy, Debug)]
pub enum ModelSpec {
    /// §4.1: `y = w*.x`, `x ~ N(0, diag(lam))`, minibatch SGD in-graph.
    LinReg { d: usize, batch: usize },
    /// §4.2: `f(x) = (1/k) W2 W1 x`, full-batch exact population loss.
    Linear2 { d: usize, k: usize },
}

/// One train step's result: losses plus gradients per parameter.
pub struct StepOut {
    pub base: f64,
    pub total: f64,
    pub grads: Vec<Vec<f32>>,
}

/// Per-step RNG stream roots (counter-split, DESIGN.md §3): consumers
/// derive their own `Rng::stream` keyed by row / chunk counters, so
/// sampling parallelizes with no serial RNG dependency.
#[derive(Clone, Copy, Debug)]
pub struct StepStreams {
    /// root for the step's minibatch sampling
    pub data: u64,
    /// root for the step's randomized-rounding noise
    pub round: u64,
}

/// Reusable per-chunk buffers: built once per train call, reused
/// across the K interpreted steps so the hot path allocates nothing
/// per step (`sqrt_lam` hoist + forward-weight and Fisher scratch).
pub struct StepScratch {
    /// element-wise `sqrt(lam)` for linreg sampling (empty for linear2)
    pub sqrt_lam: Vec<f32>,
    /// forward-weight buffers, one per parameter (replaces the
    /// per-step `w.to_vec()` in the old `method_weights`)
    pub wq: Vec<Vec<f32>>,
    /// linear2 Gauss-Newton diagonal buffers (empty for linreg, whose
    /// Fisher *is* `lam` and is borrowed directly)
    pub fisher: Vec<Vec<f32>>,
}

impl StepScratch {
    pub fn new(spec: &ModelSpec, lam: &[f32]) -> StepScratch {
        let sqrt_lam = match spec {
            ModelSpec::LinReg { .. } => lam.iter().map(|l| l.sqrt()).collect(),
            ModelSpec::Linear2 { .. } => Vec::new(),
        };
        let wq = spec
            .param_specs()
            .iter()
            .map(|s| Vec::with_capacity(s.elements()))
            .collect();
        let fisher = match spec {
            ModelSpec::LinReg { .. } => Vec::new(),
            ModelSpec::Linear2 { d, k } => vec![vec![0.0f32; k * d], vec![0.0f32; *k]],
        };
        StepScratch { sqrt_lam, wq, fisher }
    }
}

fn spec(name: &str, shape: &[usize], role: Role) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: DType::F32, role }
}

/// Forward weights for a method, written into a reusable buffer: QAT
/// sees the RTN cast, RAT the RR cast (both straight-through on the
/// backward pass), PTQ/LOTION train on the FP32 master weights.
fn method_weights_into(
    w: &[f32],
    method: Method,
    fmt: Option<&QuantFormat>,
    round_seed: u64,
    pool: &Pool,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.extend_from_slice(w);
    if let Some(fmt) = fmt {
        match method {
            Method::Qat => cast_rtn_pool(out, fmt, pool),
            Method::Rat => cast_rr_seeded(out, fmt, round_seed, pool),
            Method::Ptq | Method::Lotion => {}
        }
    }
}

impl ModelSpec {
    pub fn name(&self) -> String {
        match self {
            ModelSpec::LinReg { d, .. } => format!("linreg_d{d}"),
            ModelSpec::Linear2 { d, k } => format!("linear2_d{d}_k{k}"),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ModelSpec::LinReg { d, .. } | ModelSpec::Linear2 { d, .. } => *d,
        }
    }

    /// Parameter specs in canonical (sorted-name) order.
    pub fn param_specs(&self) -> Vec<TensorSpec> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![spec("w", &[*d], Role::Param)],
            ModelSpec::Linear2 { d, k } => vec![
                spec("w1", &[*k, *d], Role::Param),
                spec("w2", &[1, *k], Role::Param),
            ],
        }
    }

    /// Non-trained inputs owned by the coordinator, sorted by name.
    pub fn static_specs(&self) -> Vec<TensorSpec> {
        let d = self.dim();
        vec![spec("lam", &[d], Role::Static), spec("wstar", &[d], Role::Static)]
    }

    /// Names of the quantized parameter subset.
    pub fn quantized(&self) -> Vec<String> {
        match self {
            ModelSpec::LinReg { .. } => vec!["w".to_string()],
            ModelSpec::Linear2 { .. } => vec!["w1".to_string(), "w2".to_string()],
        }
    }

    /// Fresh parameters in spec order (models/linreg.py, linear2.py).
    pub fn init(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![vec![0.0; *d]],
            ModelSpec::Linear2 { d, k } => {
                let mut k1 = rng.fork(1);
                let mut k2 = rng.fork(2);
                let scale = 1.0 / (*d as f32).sqrt();
                let mut w1 = vec![0.0f32; k * d];
                k1.fill_normal(&mut w1);
                for v in w1.iter_mut() {
                    *v *= scale;
                }
                let mut w2 = vec![0.0f32; *k];
                k2.fill_normal(&mut w2);
                vec![w1, w2]
            }
        }
    }

    /// One training step: method-transformed loss + gradients at the
    /// current parameters (STE backward through the QAT/RAT casts).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        params: &[Vec<f32>],
        lam: &[f32],
        wstar: &[f32],
        method: Method,
        fmt: Option<&QuantFormat>,
        lam_reg: f32,
        streams: StepStreams,
        scratch: &mut StepScratch,
        pool: &Pool,
    ) -> StepOut {
        let (base, mut grads) = match self {
            ModelSpec::LinReg { d, batch } => {
                method_weights_into(
                    &params[0],
                    method,
                    fmt,
                    streams.round,
                    pool,
                    &mut scratch.wq[0],
                );
                linreg_loss_grad(
                    *d,
                    *batch,
                    &scratch.wq[0],
                    &scratch.sqrt_lam,
                    wstar,
                    streams.data,
                    pool,
                )
            }
            ModelSpec::Linear2 { d, k } => {
                method_weights_into(
                    &params[0],
                    method,
                    fmt,
                    Rng::stream_seed(streams.round, &[0]),
                    pool,
                    &mut scratch.wq[0],
                );
                method_weights_into(
                    &params[1],
                    method,
                    fmt,
                    Rng::stream_seed(streams.round, &[1]),
                    pool,
                    &mut scratch.wq[1],
                );
                linear2_loss_grad(*d, *k, &scratch.wq[0], &scratch.wq[1], lam, wstar, pool)
            }
        };
        let mut total = base;
        if method == Method::Lotion {
            if let Some(fmt) = fmt {
                // Gauss-Newton diagonal per parameter: `lam` itself for
                // linreg (borrowed, no copy), the exact closed form into
                // scratch for linear2.
                if let ModelSpec::Linear2 { .. } = self {
                    self.fisher_exact_into(params, lam, &mut scratch.fisher, pool);
                }
                for (i, grad) in grads.iter_mut().enumerate() {
                    let fisher: &[f32] = match self {
                        ModelSpec::LinReg { .. } => lam,
                        ModelSpec::Linear2 { .. } => scratch.fisher[i].as_slice(),
                    };
                    let (pen, pg) = lotion_penalty_and_grad_pool(&params[i], fisher, fmt, pool);
                    total += lam_reg as f64 * pen;
                    for (g, p) in grad.iter_mut().zip(&pg) {
                        *g += lam_reg * p;
                    }
                }
            }
        }
        StepOut { base, total, grads }
    }

    /// Exact Gauss-Newton diagonal for linear2 (the synthetic models'
    /// `fisher_exact`; stop-grad, evaluated at the master weights),
    /// written row-parallel into the scratch buffers.
    fn fisher_exact_into(
        &self,
        params: &[Vec<f32>],
        lam: &[f32],
        fisher: &mut [Vec<f32>],
        pool: &Pool,
    ) {
        let ModelSpec::Linear2 { d, k } = self else {
            return;
        };
        let (d, k) = (*d, *k);
        let (w1, w2) = (&params[0], &params[1]);
        let kf = k as f32;
        let (f1, rest) = fisher.split_at_mut(1);
        let f1 = &mut f1[0][..];
        let f2 = &mut rest[0][..];
        let row_ranges: Vec<Range<usize>> = (0..k).map(|j| j * d..(j + 1) * d).collect();
        let accs = pool.for_chunks_mut(f1, &row_ranges, k * d, |j, _, frow| {
            let wj = w2[j] / kf;
            let row = &w1[j * d..(j + 1) * d];
            let mut acc = 0.0f32;
            for i in 0..d {
                frow[i] = wj * wj * lam[i];
                acc += lam[i] * row[i] * row[i];
            }
            acc / (kf * kf)
        });
        f2.copy_from_slice(&accs);
    }

    /// Exact validation loss at the given parameters.
    pub fn val_loss(&self, params: &[Vec<f32>], lam: &[f32], wstar: &[f32]) -> f64 {
        self.val_loss_pool(params, lam, wstar, &Pool::global())
    }

    /// [`ModelSpec::val_loss`] on an explicit pool.
    pub fn val_loss_pool(
        &self,
        params: &[Vec<f32>],
        lam: &[f32],
        wstar: &[f32],
        pool: &Pool,
    ) -> f64 {
        match self {
            ModelSpec::LinReg { .. } => population_loss(&params[0], wstar, lam),
            ModelSpec::Linear2 { d, k } => {
                let v = effective_w_pool(*d, *k, &params[0], &params[1], pool);
                population_loss(&v, wstar, lam)
            }
        }
    }
}

/// `v = (1/k) W2 W1` — the effective linear map of the two-layer
/// model, split column-parallel: each worker owns a contiguous `v`
/// range and folds the k rows itself, so any chunking yields the same
/// bits.
fn effective_w_pool(d: usize, k: usize, w1: &[f32], w2: &[f32], pool: &Pool) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    let kf = k as f32;
    pool.for_chunks_mut(&mut v, &chunk_ranges(d, PAR_CHUNK), k * d, |_, r, out| {
        for j in 0..k {
            let wj = w2[j];
            let row = &w1[j * d + r.start..j * d + r.end];
            for (o, x) in out.iter_mut().zip(row) {
                *o += wj * x;
            }
        }
        for o in out.iter_mut() {
            *o /= kf;
        }
    });
    v
}

/// Minibatch loss + gradient for linreg at forward weights `wq`:
/// `x ~ N(0, diag(lam))`, `y = w*.x`, `L = 0.5 mean((x.wq - y)^2)`,
/// `dL/dwq = (1/B) X^T r`. Row `b` samples from the counter stream
/// `Rng::stream(data_seed, &[b])`; rows are processed in fixed
/// [`ROW_CHUNK`] groups whose partial gradients fold in chunk order —
/// parallel across the pool, bit-identical at any thread count.
fn linreg_loss_grad(
    d: usize,
    batch: usize,
    wq: &[f32],
    sqrt_lam: &[f32],
    wstar: &[f32],
    data_seed: u64,
    pool: &Pool,
) -> (f64, Vec<Vec<f32>>) {
    let ranges = chunk_ranges(batch, ROW_CHUNK);
    let part = |r: Range<usize>| -> (f64, Vec<f32>) {
        let mut grad = vec![0.0f32; d];
        let mut xrow = vec![0.0f32; d];
        let mut loss_acc = 0.0f64;
        for row in r {
            let mut rng = Rng::stream(data_seed, &[row as u64]);
            for (x, sl) in xrow.iter_mut().zip(sqrt_lam) {
                *x = rng.normal_f32() * sl;
            }
            let mut y = 0.0f32;
            let mut pred = 0.0f32;
            for i in 0..d {
                y += xrow[i] * wstar[i];
                pred += xrow[i] * wq[i];
            }
            let res = pred - y;
            loss_acc += (res as f64) * (res as f64);
            for i in 0..d {
                grad[i] += res * xrow[i];
            }
        }
        (loss_acc, grad)
    };
    let parts: Vec<(f64, Vec<f32>)> = if batch * d < PAR_MIN || pool.threads() == 1 {
        ranges.into_iter().map(part).collect()
    } else {
        pool.run(ranges, |_, r| part(r))
    };
    let mut grad = vec![0.0f32; d];
    let mut loss_acc = 0.0f64;
    for (pl, pg) in &parts {
        loss_acc += pl;
        for (g, p) in grad.iter_mut().zip(pg) {
            *g += p;
        }
    }
    let bf = batch as f32;
    for g in grad.iter_mut() {
        *g /= bf;
    }
    (0.5 * loss_acc / batch as f64, vec![grad])
}

/// Exact full-batch loss + gradients for linear2 at forward weights
/// `(w1q, w2q)`: `L = 0.5 (v - w*)^T diag(lam) (v - w*)` with
/// `v = (1/k) W2 W1`; gradients by the chain rule through `v`. The
/// `v`/`g` passes are column-parallel (per-element independent), the
/// weight-gradient pass row-parallel; the loss folds per fixed chunk.
fn linear2_loss_grad(
    d: usize,
    k: usize,
    w1q: &[f32],
    w2q: &[f32],
    lam: &[f32],
    wstar: &[f32],
    pool: &Pool,
) -> (f64, Vec<Vec<f32>>) {
    let v = effective_w_pool(d, k, w1q, w2q, pool);
    let kf = k as f32;

    // dL/dv (element-wise) + per-chunk loss partials folded in order
    let mut g = vec![0.0f32; d];
    let col_ranges = chunk_ranges(d, PAR_CHUNK);
    // this pass touches only d elements; gate the dispatch on that,
    // not on the k*d-sized weight passes below
    let loss_parts = pool.for_chunks_mut(&mut g, &col_ranges, d, |_, r, gout| {
        let mut loss = 0.0f64;
        for i in r.clone() {
            let dv = v[i] - wstar[i];
            loss += 0.5 * (lam[i] as f64) * (dv as f64) * (dv as f64);
            gout[i - r.start] = lam[i] * dv;
        }
        loss
    });
    let loss: f64 = loss_parts.iter().sum();

    // weight gradients, row-parallel over the k output rows
    let mut gw1 = vec![0.0f32; k * d];
    let row_ranges: Vec<Range<usize>> = (0..k).map(|j| j * d..(j + 1) * d).collect();
    let gw2 = pool.for_chunks_mut(&mut gw1, &row_ranges, k * d, |j, _, grow| {
        let wj = w2q[j] / kf;
        let row = &w1q[j * d..(j + 1) * d];
        let mut acc = 0.0f32;
        for i in 0..d {
            grow[i] = wj * g[i];
            acc += g[i] * row[i];
        }
        acc / kf
    });
    (loss, vec![gw1, gw2])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn serial_streams(data: u64, round: u64) -> StepStreams {
        StepStreams { data, round }
    }

    fn lg(
        d: usize,
        batch: usize,
        wq: &[f32],
        lam: &[f32],
        wstar: &[f32],
        seed: u64,
    ) -> (f64, Vec<Vec<f32>>) {
        let sqrt_lam: Vec<f32> = lam.iter().map(|l| l.sqrt()).collect();
        linreg_loss_grad(d, batch, wq, &sqrt_lam, wstar, seed, &Pool::serial())
    }

    fn l2(
        d: usize,
        k: usize,
        w1: &[f32],
        w2: &[f32],
        lam: &[f32],
        wstar: &[f32],
    ) -> (f64, Vec<Vec<f32>>) {
        linear2_loss_grad(d, k, w1, w2, lam, wstar, &Pool::serial())
    }

    /// Finite-difference check of linear2 gradients (exact loss, so FD
    /// converges cleanly).
    #[test]
    fn linear2_grads_match_finite_differences() {
        let (d, k) = (6, 2);
        let mut rng = Rng::new(3);
        let mut w1 = vec![0.0f32; k * d];
        rng.fill_normal(&mut w1);
        let mut w2 = vec![0.0f32; k];
        rng.fill_normal(&mut w2);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / i as f32).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);

        let (_, grads) = l2(d, k, &w1, &w2, &lam, &wstar);
        let eps = 1e-3f32;
        for idx in 0..k * d {
            let mut hi = w1.clone();
            hi[idx] += eps;
            let mut lo = w1.clone();
            lo[idx] -= eps;
            let (lh, _) = l2(d, k, &hi, &w2, &lam, &wstar);
            let (ll, _) = l2(d, k, &lo, &w2, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[0][idx]).abs() < 1e-3, "w1[{idx}]: fd={fd} an={}", grads[0][idx]);
        }
        for j in 0..k {
            let mut hi = w2.clone();
            hi[j] += eps;
            let mut lo = w2.clone();
            lo[j] -= eps;
            let (lh, _) = l2(d, k, &w1, &hi, &lam, &wstar);
            let (ll, _) = l2(d, k, &w1, &lo, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[1][j]).abs() < 1e-3, "w2[{j}]: fd={fd} an={}", grads[1][j]);
        }
    }

    /// Linreg minibatch gradient is unbiased for the population gradient
    /// `diag(lam) (w - w*)`; check with a large batch.
    #[test]
    fn linreg_grad_approximates_population_gradient() {
        let d = 8;
        let mut rng = Rng::new(7);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / (i as f32).powf(1.1)).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let mut w = vec![0.0f32; d];
        rng.fill_normal(&mut w);
        let (_, grads) = lg(d, 20000, &w, &lam, &wstar, 11);
        for i in 0..d {
            let pop = lam[i] * (w[i] - wstar[i]);
            // B = 20000 puts the estimator's std well under this band
            assert!(
                (grads[0][i] - pop).abs() < 0.15 * pop.abs() + 0.08,
                "i={i} grad={} pop={pop}",
                grads[0][i]
            );
        }
    }

    /// Row-parallel gradients must match the serial fold bit-for-bit
    /// (same fixed chunking, same reduction order).
    #[test]
    fn linreg_grad_is_thread_count_invariant() {
        let d = 3000; // batch*d over PAR_MIN -> parallel path engages
        let batch = 16;
        let mut rng = Rng::new(5);
        let mut w = vec![0.0f32; d];
        rng.fill_normal(&mut w);
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let lam = vec![0.5f32; d];
        let sqrt_lam: Vec<f32> = lam.iter().map(|l| l.sqrt()).collect();
        let run = |threads: usize| {
            linreg_loss_grad(d, batch, &w, &sqrt_lam, &wstar, 42, &Pool::new(threads))
        };
        let (l1, g1) = run(1);
        let (l3, g3) = run(3);
        let (l4, g4) = run(4);
        assert_eq!(l1.to_bits(), l3.to_bits());
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(g1, g3);
        assert_eq!(g1, g4);
    }

    #[test]
    fn linear2_grads_are_thread_count_invariant() {
        let (d, k) = (9000, 4);
        let mut rng = Rng::new(6);
        let mut w1 = vec![0.0f32; k * d];
        rng.fill_normal(&mut w1);
        let mut w2 = vec![0.0f32; k];
        rng.fill_normal(&mut w2);
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let lam: Vec<f32> = (0..d).map(|i| 1.0 / (1 + i % 9) as f32).collect();
        let run = |threads: usize| {
            linear2_loss_grad(d, k, &w1, &w2, &lam, &wstar, &Pool::new(threads))
        };
        let (l1, g1) = run(1);
        let (l4, g4) = run(4);
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(g1, g4);
    }

    #[test]
    fn effective_w_of_gt_construction_is_wstar() {
        // Lemma 4's GT: rows(W1) = w*, W2 = 1 -> v = w*
        let (d, k) = (5, 3);
        let wstar = vec![0.5f32, -1.0, 2.0, 0.0, -0.25];
        let w1: Vec<f32> = (0..k).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; k];
        assert_eq!(effective_w_pool(d, k, &w1, &w2, &Pool::serial()), wstar);
    }

    #[test]
    fn lotion_step_adds_penalty_to_total_only() {
        let m = ModelSpec::Linear2 { d: 4, k: 2 };
        let mut rng = Rng::new(5);
        let params = m.init(&mut rng);
        let lam = vec![1.0f32, 0.5, 0.25, 0.125];
        let wstar = vec![1.0f32, -1.0, 0.5, -0.5];
        let fmt = QuantFormat::int4();
        let pool = Pool::serial();
        let mut scratch = StepScratch::new(&m, &lam);
        let out_ptq = m.step(
            &params,
            &lam,
            &wstar,
            Method::Ptq,
            None,
            0.0,
            serial_streams(1, 2),
            &mut scratch,
            &pool,
        );
        let out_lotion = m.step(
            &params,
            &lam,
            &wstar,
            Method::Lotion,
            Some(&fmt),
            1.0,
            serial_streams(1, 2),
            &mut scratch,
            &pool,
        );
        assert!((out_ptq.base - out_lotion.base).abs() < 1e-9);
        assert!(out_lotion.total >= out_lotion.base); // penalty is >= 0
        assert_eq!(out_lotion.grads.len(), 2);
    }

    /// The linreg LOTION penalty borrows `lam` as the Fisher with no
    /// copy; cross-check against the explicit closed form.
    #[test]
    fn linreg_lotion_penalty_uses_lam_as_fisher() {
        let m = ModelSpec::LinReg { d: 6, batch: 4 };
        let w = vec![vec![0.31f32, -0.77, 0.05, 0.4, -0.2, 0.9]];
        let lam = vec![1.0f32, 0.5, 0.25, 0.125, 1.5, 0.75];
        let wstar = vec![0.0f32; 6];
        let fmt = QuantFormat::int4();
        let mut scratch = StepScratch::new(&m, &lam);
        let out = m.step(
            &w,
            &lam,
            &wstar,
            Method::Lotion,
            Some(&fmt),
            2.0,
            serial_streams(3, 4),
            &mut scratch,
            &Pool::serial(),
        );
        let (pen, _) = crate::quant::lotion_penalty_and_grad(&w[0], &lam, &fmt);
        assert!((out.total - out.base - 2.0 * pen).abs() < 1e-9);
    }

    #[test]
    fn val_loss_zero_at_gt() {
        let m = ModelSpec::Linear2 { d: 3, k: 2 };
        let wstar = vec![0.25f32, -0.75, 1.5];
        let lam = vec![1.0f32, 0.5, 0.25];
        let w1: Vec<f32> = (0..2).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; 2];
        assert_eq!(m.val_loss(&[w1, w2], &lam, &wstar), 0.0);
    }
}
