//! Native model math: the synthetic testbeds (§4.1 linreg, §4.2
//! linear2) implemented directly over flat `f32` buffers — forward,
//! backward, method transformations (PTQ/QAT/RAT/LOTION) and exact
//! validation losses. Semantics mirror `python/compile/models/*` and
//! `methods.py`; rounding and the Eq. 3 penalty reuse the `quant`
//! substrate bit-for-bit (DESIGN.md §3).

use crate::data::synth::population_loss;
use crate::quant::{cast_rr, cast_rtn, lotion_penalty_and_grad, QuantFormat};
use crate::runtime::manifest::{Role, TensorSpec};
use crate::tensor::DType;
use crate::util::rng::Rng;
use anyhow::{bail, Result};

/// Training-method transformation of the base loss (methods.py).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    Ptq,
    Qat,
    Rat,
    Lotion,
}

impl Method {
    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "ptq" => Method::Ptq,
            "qat" => Method::Qat,
            "rat" => Method::Rat,
            "lotion" => Method::Lotion,
            other => bail!("unknown method {other:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            Method::Ptq => "ptq",
            Method::Qat => "qat",
            Method::Rat => "rat",
            Method::Lotion => "lotion",
        }
    }
}

/// A native testbed model: defines parameter layout, data distribution,
/// loss/gradients, and the exact Gauss-Newton diagonal LOTION uses.
#[derive(Clone, Copy, Debug)]
pub enum ModelSpec {
    /// §4.1: `y = w*.x`, `x ~ N(0, diag(lam))`, minibatch SGD in-graph.
    LinReg { d: usize, batch: usize },
    /// §4.2: `f(x) = (1/k) W2 W1 x`, full-batch exact population loss.
    Linear2 { d: usize, k: usize },
}

/// One train step's result: losses plus gradients per parameter.
pub struct StepOut {
    pub base: f64,
    pub total: f64,
    pub grads: Vec<Vec<f32>>,
}

fn spec(name: &str, shape: &[usize], role: Role) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: DType::F32, role }
}

/// Forward weights for a method: QAT sees the RTN cast, RAT the RR
/// cast (both straight-through on the backward pass), PTQ/LOTION train
/// on the FP32 master weights.
fn method_weights(
    w: &[f32],
    method: Method,
    fmt: Option<&QuantFormat>,
    round_rng: &mut Rng,
) -> Vec<f32> {
    let mut out = w.to_vec();
    if let Some(fmt) = fmt {
        match method {
            Method::Qat => cast_rtn(&mut out, fmt),
            Method::Rat => cast_rr(&mut out, fmt, round_rng),
            Method::Ptq | Method::Lotion => {}
        }
    }
    out
}

impl ModelSpec {
    pub fn name(&self) -> String {
        match self {
            ModelSpec::LinReg { d, .. } => format!("linreg_d{d}"),
            ModelSpec::Linear2 { d, k } => format!("linear2_d{d}_k{k}"),
        }
    }

    pub fn dim(&self) -> usize {
        match self {
            ModelSpec::LinReg { d, .. } | ModelSpec::Linear2 { d, .. } => *d,
        }
    }

    /// Parameter specs in canonical (sorted-name) order.
    pub fn param_specs(&self) -> Vec<TensorSpec> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![spec("w", &[*d], Role::Param)],
            ModelSpec::Linear2 { d, k } => vec![
                spec("w1", &[*k, *d], Role::Param),
                spec("w2", &[1, *k], Role::Param),
            ],
        }
    }

    /// Non-trained inputs owned by the coordinator, sorted by name.
    pub fn static_specs(&self) -> Vec<TensorSpec> {
        let d = self.dim();
        vec![spec("lam", &[d], Role::Static), spec("wstar", &[d], Role::Static)]
    }

    /// Names of the quantized parameter subset.
    pub fn quantized(&self) -> Vec<String> {
        match self {
            ModelSpec::LinReg { .. } => vec!["w".to_string()],
            ModelSpec::Linear2 { .. } => vec!["w1".to_string(), "w2".to_string()],
        }
    }

    /// Fresh parameters in spec order (models/linreg.py, linear2.py).
    pub fn init(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![vec![0.0; *d]],
            ModelSpec::Linear2 { d, k } => {
                let mut k1 = rng.fork(1);
                let mut k2 = rng.fork(2);
                let scale = 1.0 / (*d as f32).sqrt();
                let mut w1 = vec![0.0f32; k * d];
                k1.fill_normal(&mut w1);
                for v in w1.iter_mut() {
                    *v *= scale;
                }
                let mut w2 = vec![0.0f32; *k];
                k2.fill_normal(&mut w2);
                vec![w1, w2]
            }
        }
    }

    /// One training step: method-transformed loss + gradients at the
    /// current parameters (STE backward through the QAT/RAT casts).
    #[allow(clippy::too_many_arguments)]
    pub fn step(
        &self,
        params: &[Vec<f32>],
        lam: &[f32],
        wstar: &[f32],
        method: Method,
        fmt: Option<&QuantFormat>,
        lam_reg: f32,
        data_rng: &mut Rng,
        round_rng: &mut Rng,
    ) -> StepOut {
        let (base, mut grads) = match self {
            ModelSpec::LinReg { d, batch } => {
                let wq = method_weights(&params[0], method, fmt, round_rng);
                linreg_loss_grad(*d, *batch, &wq, lam, wstar, data_rng)
            }
            ModelSpec::Linear2 { d, k } => {
                let w1q = method_weights(&params[0], method, fmt, round_rng);
                let w2q = method_weights(&params[1], method, fmt, round_rng);
                linear2_loss_grad(*d, *k, &w1q, &w2q, lam, wstar)
            }
        };
        let mut total = base;
        if method == Method::Lotion {
            if let Some(fmt) = fmt {
                for (i, fisher) in self.fisher_exact(params, lam).iter().enumerate() {
                    let (pen, pg) = lotion_penalty_and_grad(&params[i], fisher, fmt);
                    total += lam_reg as f64 * pen;
                    for (g, p) in grads[i].iter_mut().zip(&pg) {
                        *g += lam_reg * p;
                    }
                }
            }
        }
        StepOut { base, total, grads }
    }

    /// Exact Gauss-Newton diagonal per parameter (the synthetic models'
    /// `fisher_exact`; stop-grad, evaluated at the master weights).
    fn fisher_exact(&self, params: &[Vec<f32>], lam: &[f32]) -> Vec<Vec<f32>> {
        match self {
            ModelSpec::LinReg { .. } => vec![lam.to_vec()],
            ModelSpec::Linear2 { d, k } => {
                let (w1, w2) = (&params[0], &params[1]);
                let kf = *k as f32;
                let mut f1 = vec![0.0f32; k * d];
                let mut f2 = vec![0.0f32; *k];
                for j in 0..*k {
                    let wj = w2[j] / kf;
                    let row = &w1[j * d..(j + 1) * d];
                    let frow = &mut f1[j * d..(j + 1) * d];
                    let mut acc = 0.0f32;
                    for i in 0..*d {
                        frow[i] = wj * wj * lam[i];
                        acc += lam[i] * row[i] * row[i];
                    }
                    f2[j] = acc / (kf * kf);
                }
                vec![f1, f2]
            }
        }
    }

    /// Exact validation loss at the given parameters.
    pub fn val_loss(&self, params: &[Vec<f32>], lam: &[f32], wstar: &[f32]) -> f64 {
        match self {
            ModelSpec::LinReg { .. } => population_loss(&params[0], wstar, lam),
            ModelSpec::Linear2 { d, k } => {
                let v = effective_w(*d, *k, &params[0], &params[1]);
                population_loss(&v, wstar, lam)
            }
        }
    }
}

/// `v = (1/k) W2 W1` — the effective linear map of the two-layer model.
fn effective_w(d: usize, k: usize, w1: &[f32], w2: &[f32]) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    for j in 0..k {
        let wj = w2[j];
        let row = &w1[j * d..(j + 1) * d];
        for i in 0..d {
            v[i] += wj * row[i];
        }
    }
    let kf = k as f32;
    for vi in v.iter_mut() {
        *vi /= kf;
    }
    v
}

/// Minibatch loss + gradient for linreg at forward weights `wq`:
/// `x ~ N(0, diag(lam))`, `y = w*.x`, `L = 0.5 mean((x.wq - y)^2)`,
/// `dL/dwq = (1/B) X^T r`. Streams one row at a time — no `[B, d]`
/// batch materialization on the hot path.
fn linreg_loss_grad(
    d: usize,
    batch: usize,
    wq: &[f32],
    lam: &[f32],
    wstar: &[f32],
    data_rng: &mut Rng,
) -> (f64, Vec<Vec<f32>>) {
    let sqrt_lam: Vec<f32> = lam.iter().map(|l| l.sqrt()).collect();
    let mut grad = vec![0.0f32; d];
    let mut xrow = vec![0.0f32; d];
    let mut loss_acc = 0.0f64;
    for _ in 0..batch {
        for (x, sl) in xrow.iter_mut().zip(&sqrt_lam) {
            *x = data_rng.normal_f32() * sl;
        }
        let mut y = 0.0f32;
        let mut pred = 0.0f32;
        for i in 0..d {
            y += xrow[i] * wstar[i];
            pred += xrow[i] * wq[i];
        }
        let r = pred - y;
        loss_acc += (r as f64) * (r as f64);
        for i in 0..d {
            grad[i] += r * xrow[i];
        }
    }
    let bf = batch as f32;
    for g in grad.iter_mut() {
        *g /= bf;
    }
    (0.5 * loss_acc / batch as f64, vec![grad])
}

/// Exact full-batch loss + gradients for linear2 at forward weights
/// `(w1q, w2q)`: `L = 0.5 (v - w*)^T diag(lam) (v - w*)` with
/// `v = (1/k) W2 W1`; gradients by the chain rule through `v`.
fn linear2_loss_grad(
    d: usize,
    k: usize,
    w1q: &[f32],
    w2q: &[f32],
    lam: &[f32],
    wstar: &[f32],
) -> (f64, Vec<Vec<f32>>) {
    let v = effective_w(d, k, w1q, w2q);
    let kf = k as f32;
    let mut loss = 0.0f64;
    let mut g = vec![0.0f32; d]; // dL/dv
    for i in 0..d {
        let dv = v[i] - wstar[i];
        loss += 0.5 * (lam[i] as f64) * (dv as f64) * (dv as f64);
        g[i] = lam[i] * dv;
    }
    let mut gw1 = vec![0.0f32; k * d];
    let mut gw2 = vec![0.0f32; k];
    for j in 0..k {
        let wj = w2q[j] / kf;
        let row = &w1q[j * d..(j + 1) * d];
        let grow = &mut gw1[j * d..(j + 1) * d];
        let mut acc = 0.0f32;
        for i in 0..d {
            grow[i] = wj * g[i];
            acc += g[i] * row[i];
        }
        gw2[j] = acc / kf;
    }
    (loss, vec![gw1, gw2])
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Finite-difference check of linear2 gradients (exact loss, so FD
    /// converges cleanly).
    #[test]
    fn linear2_grads_match_finite_differences() {
        let (d, k) = (6, 2);
        let mut rng = Rng::new(3);
        let mut w1 = vec![0.0f32; k * d];
        rng.fill_normal(&mut w1);
        let mut w2 = vec![0.0f32; k];
        rng.fill_normal(&mut w2);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / i as f32).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);

        let (_, grads) = linear2_loss_grad(d, k, &w1, &w2, &lam, &wstar);
        let eps = 1e-3f32;
        for idx in 0..k * d {
            let mut hi = w1.clone();
            hi[idx] += eps;
            let mut lo = w1.clone();
            lo[idx] -= eps;
            let (lh, _) = linear2_loss_grad(d, k, &hi, &w2, &lam, &wstar);
            let (ll, _) = linear2_loss_grad(d, k, &lo, &w2, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[0][idx]).abs() < 1e-3, "w1[{idx}]: fd={fd} an={}", grads[0][idx]);
        }
        for j in 0..k {
            let mut hi = w2.clone();
            hi[j] += eps;
            let mut lo = w2.clone();
            lo[j] -= eps;
            let (lh, _) = linear2_loss_grad(d, k, &w1, &hi, &lam, &wstar);
            let (ll, _) = linear2_loss_grad(d, k, &w1, &lo, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[1][j]).abs() < 1e-3, "w2[{j}]: fd={fd} an={}", grads[1][j]);
        }
    }

    /// Linreg minibatch gradient is unbiased for the population gradient
    /// `diag(lam) (w - w*)`; check with a large batch.
    #[test]
    fn linreg_grad_approximates_population_gradient() {
        let d = 8;
        let mut rng = Rng::new(7);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / (i as f32).powf(1.1)).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let mut w = vec![0.0f32; d];
        rng.fill_normal(&mut w);
        let mut data_rng = Rng::new(11);
        let (_, grads) = linreg_loss_grad(d, 20000, &w, &lam, &wstar, &mut data_rng);
        for i in 0..d {
            let pop = lam[i] * (w[i] - wstar[i]);
            // B = 20000 puts the estimator's std well under this band
            assert!(
                (grads[0][i] - pop).abs() < 0.15 * pop.abs() + 0.08,
                "i={i} grad={} pop={pop}",
                grads[0][i]
            );
        }
    }

    #[test]
    fn effective_w_of_gt_construction_is_wstar() {
        // Lemma 4's GT: rows(W1) = w*, W2 = 1 -> v = w*
        let (d, k) = (5, 3);
        let wstar = vec![0.5f32, -1.0, 2.0, 0.0, -0.25];
        let w1: Vec<f32> = (0..k).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; k];
        assert_eq!(effective_w(d, k, &w1, &w2), wstar);
    }

    #[test]
    fn lotion_step_adds_penalty_to_total_only() {
        let m = ModelSpec::Linear2 { d: 4, k: 2 };
        let mut rng = Rng::new(5);
        let params = m.init(&mut rng);
        let lam = vec![1.0f32, 0.5, 0.25, 0.125];
        let wstar = vec![1.0f32, -1.0, 0.5, -0.5];
        let fmt = QuantFormat::int4();
        let mut dr = Rng::new(1);
        let mut rr = Rng::new(2);
        let out_ptq =
            m.step(&params, &lam, &wstar, Method::Ptq, None, 0.0, &mut dr, &mut rr);
        let mut dr = Rng::new(1);
        let mut rr = Rng::new(2);
        let out_lotion =
            m.step(&params, &lam, &wstar, Method::Lotion, Some(&fmt), 1.0, &mut dr, &mut rr);
        assert!((out_ptq.base - out_lotion.base).abs() < 1e-9);
        assert!(out_lotion.total >= out_lotion.base); // penalty is >= 0
        assert_eq!(out_lotion.grads.len(), 2);
    }

    #[test]
    fn val_loss_zero_at_gt() {
        let m = ModelSpec::Linear2 { d: 3, k: 2 };
        let wstar = vec![0.25f32, -0.75, 1.5];
        let lam = vec![1.0f32, 0.5, 0.25];
        let w1: Vec<f32> = (0..2).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; 2];
        assert_eq!(m.val_loss(&[w1, w2], &lam, &wstar), 0.0);
    }
}
