//! Native optimizer steppers: SGD and Adam over named parameter lists,
//! mirroring `python/compile/optim.py` (DESIGN.md §3). Optimizer state
//! crosses the manifest boundary as flat tensors whose names follow the
//! python layout — `t` (step counter), `m.<param>` / `v.<param>` for
//! Adam moments — sorted lexicographically, exactly as
//! `_specs_from_tree` orders them on the AOT side.

use crate::runtime::manifest::{Role, TensorSpec};
use crate::tensor::DType;
use anyhow::{anyhow, bail, Result};

const B1: f32 = 0.9;
const B2: f32 = 0.999;
const EPS: f32 = 1e-8;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OptKind {
    Sgd,
    Adam,
}

impl OptKind {
    pub fn name(self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::Adam => "adam",
        }
    }

    /// Optimizer-state tensor specs for a parameter list, in the
    /// canonical (sorted-by-name) manifest order.
    pub fn state_specs(self, params: &[TensorSpec]) -> Vec<TensorSpec> {
        let mut specs = vec![TensorSpec {
            name: "t".to_string(),
            shape: vec![],
            dtype: DType::F32,
            role: Role::Opt,
        }];
        if self == OptKind::Adam {
            for p in params {
                for prefix in ["m", "v"] {
                    specs.push(TensorSpec {
                        name: format!("{prefix}.{}", p.name),
                        shape: p.shape.clone(),
                        dtype: DType::F32,
                        role: Role::Opt,
                    });
                }
            }
        }
        specs.sort_by(|a, b| a.name.cmp(&b.name));
        specs
    }
}

/// In-flight optimizer state for one train call. Moments are indexed by
/// parameter position (the order of the train entry's param specs).
pub struct OptState {
    pub kind: OptKind,
    pub t: f32,
    /// Adam first/second moments per parameter (empty for SGD).
    pub m: Vec<Vec<f32>>,
    pub v: Vec<Vec<f32>>,
}

impl OptState {
    /// Rebuild state from named flat tensors (one `(name, data)` pair
    /// per opt-role input, manifest order).
    pub fn unpack(
        kind: OptKind,
        param_names: &[String],
        named: &[(String, Vec<f32>)],
    ) -> Result<OptState> {
        let find = |name: &str| -> Result<&Vec<f32>> {
            named
                .iter()
                .find(|(n, _)| n == name)
                .map(|(_, d)| d)
                .ok_or_else(|| anyhow!("missing optimizer tensor {name:?}"))
        };
        let t = *find("t")?
            .first()
            .ok_or_else(|| anyhow!("empty optimizer step counter"))?;
        let (mut m, mut v) = (Vec::new(), Vec::new());
        if kind == OptKind::Adam {
            for p in param_names {
                m.push(find(&format!("m.{p}"))?.clone());
                v.push(find(&format!("v.{p}"))?.clone());
            }
        }
        Ok(OptState { kind, t, m, v })
    }

    /// One optimizer step: `params[i] -= lr * step(grads[i])`.
    pub fn update(&mut self, params: &mut [Vec<f32>], grads: &[Vec<f32>], lr: f32) -> Result<()> {
        if params.len() != grads.len() {
            bail!("optimizer: {} params vs {} grads", params.len(), grads.len());
        }
        self.t += 1.0;
        match self.kind {
            OptKind::Sgd => {
                for (p, g) in params.iter_mut().zip(grads) {
                    for (pi, gi) in p.iter_mut().zip(g) {
                        *pi -= lr * gi;
                    }
                }
            }
            OptKind::Adam => {
                let bc1 = 1.0 - B1.powf(self.t);
                let bc2 = 1.0 - B2.powf(self.t);
                for (idx, (p, g)) in params.iter_mut().zip(grads).enumerate() {
                    let (m, v) = (&mut self.m[idx], &mut self.v[idx]);
                    for i in 0..p.len() {
                        m[i] = B1 * m[i] + (1.0 - B1) * g[i];
                        v[i] = B2 * v[i] + (1.0 - B2) * g[i] * g[i];
                        p[i] -= lr * (m[i] / bc1) / ((v[i] / bc2).sqrt() + EPS);
                    }
                }
            }
        }
        Ok(())
    }

    /// Empirical-Fisher diagonal per selected parameter: Adam's
    /// bias-corrected second moment, `v / (1 - b2^max(t,1))` — exactly
    /// `python/compile/optim.py`'s `Optimizer.fisher` ("we use the
    /// empirical Fisher approximation as we would with Adam", §4.3).
    /// `out[i]` receives the diagonal for parameter `param_idx[i]`.
    /// SGD tracks no curvature, so LOTION on an SGD model needs an
    /// exact Gauss-Newton diagonal instead (the driver enforces this).
    pub fn fisher_into(&self, param_idx: &[usize], out: &mut [Vec<f32>]) -> Result<()> {
        if self.kind != OptKind::Adam {
            bail!(
                "method 'lotion' needs an exact Gauss-Newton diagonal or the adam \
                 optimizer's second moment as the Fisher (optimizer is {:?})",
                self.kind.name()
            );
        }
        let bc2 = 1.0 - B2.powf(self.t.max(1.0));
        for (o, &pi) in out.iter_mut().zip(param_idx) {
            for (ov, &vv) in o.iter_mut().zip(&self.v[pi]) {
                *ov = vv / bc2;
            }
        }
        Ok(())
    }

    /// Emit the state tensor for a named opt spec (inverse of `unpack`).
    pub fn pack(&self, name: &str, param_names: &[String]) -> Result<Vec<f32>> {
        if name == "t" {
            return Ok(vec![self.t]);
        }
        let pos = |p: &str| param_names.iter().position(|n| n == p);
        if let Some(p) = name.strip_prefix("m.") {
            return pos(p)
                .map(|i| self.m[i].clone())
                .ok_or_else(|| anyhow!("unknown moment tensor {name:?}"));
        }
        if let Some(p) = name.strip_prefix("v.") {
            return pos(p)
                .map(|i| self.v[i].clone())
                .ok_or_else(|| anyhow!("unknown moment tensor {name:?}"));
        }
        bail!("unknown optimizer tensor {name:?}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs(names: &[(&str, &[usize])]) -> Vec<TensorSpec> {
        names
            .iter()
            .map(|(n, s)| TensorSpec {
                name: n.to_string(),
                shape: s.to_vec(),
                dtype: DType::F32,
                role: Role::Param,
            })
            .collect()
    }

    #[test]
    fn sgd_state_is_just_the_counter() {
        let s = OptKind::Sgd.state_specs(&specs(&[("w", &[4])]));
        assert_eq!(s.len(), 1);
        assert_eq!(s[0].name, "t");
        assert!(s[0].shape.is_empty());
    }

    #[test]
    fn adam_state_specs_sorted_like_python() {
        // python sorts the flat opt dict: m.w1, m.w2, t, v.w1, v.w2
        let s = OptKind::Adam.state_specs(&specs(&[("w1", &[2, 3]), ("w2", &[1, 2])]));
        let names: Vec<&str> = s.iter().map(|x| x.name.as_str()).collect();
        assert_eq!(names, vec!["m.w1", "m.w2", "t", "v.w1", "v.w2"]);
        assert_eq!(s[0].shape, vec![2, 3]);
    }

    #[test]
    fn sgd_step_moves_against_gradient() {
        let mut st = OptState { kind: OptKind::Sgd, t: 0.0, m: vec![], v: vec![] };
        let mut p = vec![vec![1.0f32, -1.0]];
        st.update(&mut p, &[vec![0.5, -0.5]], 0.1).unwrap();
        assert_eq!(p[0], vec![0.95, -0.95]);
        assert_eq!(st.t, 1.0);
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, |step 1| = lr * g / (|g| + eps) ~= lr
        let mut st = OptState {
            kind: OptKind::Adam,
            t: 0.0,
            m: vec![vec![0.0; 2]],
            v: vec![vec![0.0; 2]],
        };
        let mut p = vec![vec![0.0f32, 0.0]];
        st.update(&mut p, &[vec![3.0, -0.01]], 0.1).unwrap();
        assert!((p[0][0] + 0.1).abs() < 1e-4, "{}", p[0][0]);
        assert!((p[0][1] - 0.1).abs() < 1e-4, "{}", p[0][1]);
    }

    #[test]
    fn adam_fisher_is_bias_corrected_v() {
        let st = OptState {
            kind: OptKind::Adam,
            t: 2.0,
            m: vec![vec![0.0; 2]],
            v: vec![vec![0.5, 1.0]],
        };
        let mut out = vec![vec![0.0f32; 2]];
        st.fisher_into(&[0], &mut out).unwrap();
        let bc2 = 1.0 - B2.powf(2.0);
        assert!((out[0][0] - 0.5 / bc2).abs() < 1e-6);
        assert!((out[0][1] - 1.0 / bc2).abs() < 1e-6);

        let sgd = OptState { kind: OptKind::Sgd, t: 0.0, m: vec![], v: vec![] };
        assert!(sgd.fisher_into(&[0], &mut out).is_err());
    }

    #[test]
    fn pack_unpack_roundtrip() {
        let params = vec!["w".to_string()];
        let named = vec![
            ("t".to_string(), vec![3.0f32]),
            ("m.w".to_string(), vec![1.0, 2.0]),
            ("v.w".to_string(), vec![4.0, 5.0]),
        ];
        let st = OptState::unpack(OptKind::Adam, &params, &named).unwrap();
        assert_eq!(st.t, 3.0);
        assert_eq!(st.pack("m.w", &params).unwrap(), vec![1.0, 2.0]);
        assert_eq!(st.pack("t", &params).unwrap(), vec![3.0]);
        assert!(st.pack("z.w", &params).is_err());
    }
}
