//! The native backend's program abstraction (DESIGN.md §3): a
//! [`NativeProgram`] supplies *model math only* — parameter layout,
//! init, base loss + gradients at given forward weights, optional
//! exact Gauss-Newton diagonals, and validation loss — while the
//! *method* transformation (the casts, gradient relaxations and
//! penalties, owned by the pluggable [`super::estimator::Estimator`]s)
//! and the SGD/Adam loop live in the shared driver (`native::mod`).
//! That split is the structural point of LOTION: the smoothing is a
//! model-agnostic transformation of the loss under randomized-rounding
//! noise, so the code keeps it out of the models.
//!
//! Implementations: the synthetic testbeds ([`super::testbeds`]) and
//! the decoder-only transformer LM ([`super::transformer`]). Future
//! workloads (serving, sharded CPU) plug in behind the same trait.

use crate::quant::PackedWeights;
use crate::runtime::manifest::TensorSpec;
use crate::util::pool::Pool;
use crate::util::rng::Rng;
use anyhow::{anyhow, bail, Result};
use std::any::Any;

/// Per-step RNG stream roots (counter-split, DESIGN.md §3): consumers
/// derive their own `Rng::stream` keyed by row / chunk counters, so
/// sampling parallelizes with no serial RNG dependency.
#[derive(Clone, Copy, Debug)]
pub struct StepStreams {
    /// root for the step's data sampling (in-graph programs only)
    pub data: u64,
    /// root for the step's randomized-rounding noise
    pub round: u64,
}

/// Borrowed per-step inputs handed to [`NativeProgram::loss_grad`].
pub struct StepCtx<'a> {
    /// static-role inputs by name (`lam`, `wstar` for the testbeds;
    /// empty for the LM)
    pub statics: &'a [(String, Vec<f32>)],
    /// this step's data-role batch (`[B, T+1]` tokens, row-major) when
    /// the program consumes data; `None` for in-graph sampling
    pub data: Option<&'a [i32]>,
    pub streams: StepStreams,
    pub pool: &'a Pool,
}

/// Borrowed inputs for [`NativeProgram::val_loss`].
pub struct EvalCtx<'a> {
    pub statics: &'a [(String, Vec<f32>)],
    /// the full eval chunk (`[KE, B, T+1]` tokens) when the program
    /// consumes data
    pub data: Option<&'a [i32]>,
    pub pool: &'a Pool,
}

/// One parameter as seen by the quantized-eval entry: dense f32, or a
/// packed block-quantized tensor ([`PackedWeights`]) that programs
/// with fused dequant kernels consume in place.
pub enum ParamView<'a> {
    Dense(&'a [f32]),
    Packed(&'a PackedWeights),
}

/// Geometry of a program's autoregressive decode surface.
#[derive(Clone, Copy, Debug)]
pub struct DecodeSpec {
    /// logits width per decode step
    pub vocab: usize,
    /// maximum cached positions per sequence (prompt + generation)
    pub max_seq: usize,
}

/// Look up a static-role input by name.
pub fn static_slice<'a>(statics: &'a [(String, Vec<f32>)], name: &str) -> Result<&'a [f32]> {
    statics
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| v.as_slice())
        .ok_or_else(|| anyhow!("program needs static input {name:?}"))
}

/// A workload the native backend can interpret. A program defines its
/// tensors and its math; the driver owns everything method- and
/// optimizer-shaped. All randomness must come off the counter streams
/// in the ctx (never ambient state) so training stays bit-identical at
/// any `--threads` setting.
///
/// Programs are `Send + Sync`: they are immutable definitions (all
/// mutable run state lives in the engine-owned scratch), shared via
/// `Arc` by every engine a [`NativeFactory`](super::NativeFactory)
/// spawns — one definition, N thread-owned interpreters.
pub trait NativeProgram: Send + Sync {
    /// Manifest model name (e.g. `linreg_d256`, `lm-150m-sim`).
    fn name(&self) -> String;

    /// Trainable parameters in canonical (sorted-name) order.
    fn param_specs(&self) -> Vec<TensorSpec>;

    /// Non-trained coordinator-owned inputs, sorted by name.
    fn static_specs(&self) -> Vec<TensorSpec> {
        Vec::new()
    }

    /// The data-role input consumed by one K-step train chunk, or
    /// `None` when the program samples in-graph.
    fn train_data_spec(&self, _k: usize) -> Option<TensorSpec> {
        None
    }

    /// Batches per eval call (shapes the eval entry's data spec).
    fn eval_batches(&self) -> usize {
        1
    }

    /// Names of the quantized parameter subset.
    fn quantized(&self) -> Vec<String>;

    /// Fresh parameters in spec order.
    fn init(&self, rng: &mut Rng) -> Vec<Vec<f32>>;

    /// Reusable buffers; the program downcasts its own type. The
    /// driver caches this across train calls *and runs* on one engine,
    /// so the scratch must not assume fresh zeroing per call, and any
    /// value derived from call inputs (statics, data) must be
    /// re-validated against the current inputs before reuse.
    fn make_scratch(&self) -> Box<dyn Any>;

    /// Base loss + gradients at the given *forward* weights `wq` (the
    /// driver has already applied any QAT/RAT cast, so the gradients
    /// computed here are straight-through by construction). Gradients
    /// are written into `grads` (pre-sized per parameter, overwritten).
    fn loss_grad(
        &self,
        wq: &[Vec<f32>],
        ctx: &StepCtx<'_>,
        scratch: &mut dyn Any,
        grads: &mut [Vec<f32>],
    ) -> Result<f64>;

    /// Exact Gauss-Newton diagonal for the σ² penalty, evaluated at the
    /// master weights (stop-grad). `out[i]` corresponds to the i-th
    /// *quantized* parameter in spec order. Returns `Ok(false)` when the
    /// model has no closed form — the driver then falls back to the
    /// optimizer's empirical Fisher (Adam's second moment, §4.3).
    fn fisher_exact_into(
        &self,
        _params: &[Vec<f32>],
        _ctx: &StepCtx<'_>,
        _out: &mut [Vec<f32>],
    ) -> Result<bool> {
        Ok(false)
    }

    /// Exact (or mean-over-batches) validation loss at the parameters.
    /// `scratch` is the same engine-cached buffer train calls use (from
    /// [`NativeProgram::make_scratch`]), so periodic evals pay no
    /// per-call activation allocation either; programs without eval
    /// buffers just ignore it.
    fn val_loss(
        &self,
        params: &[Vec<f32>],
        ctx: &EvalCtx<'_>,
        scratch: &mut dyn Any,
    ) -> Result<f64>;

    /// Validation loss with some parameters in packed block-quantized
    /// form (the `eval_q_*` entries). The default materializes every
    /// packed tensor back to dense f32 and delegates to
    /// [`NativeProgram::val_loss`] — correct for any program, but it
    /// pays the full decode (and bumps the process-wide dense-decode
    /// counter). Programs with fused dequant kernels (the LM) override
    /// this to consume the packed form in place.
    fn val_loss_packed(
        &self,
        params: &[ParamView<'_>],
        ctx: &EvalCtx<'_>,
        scratch: &mut dyn Any,
    ) -> Result<f64> {
        let dense: Vec<Vec<f32>> = params
            .iter()
            .map(|p| match p {
                ParamView::Dense(w) => w.to_vec(),
                ParamView::Packed(pk) => {
                    let mut out = vec![0.0f32; pk.len()];
                    pk.decode_into(&mut out);
                    out
                }
            })
            .collect();
        self.val_loss(&dense, ctx, scratch)
    }

    /// Geometry of the autoregressive decode surface, or `None` for
    /// programs with no generation path (the synthetic testbeds). The
    /// engine registers `decode_*` entries only when this is `Some`.
    fn decode_spec(&self) -> Option<DecodeSpec> {
        None
    }

    /// Fresh per-sequence decode state (KV caches + step buffers); the
    /// engine owns one per live sequence slot and hands it back to
    /// [`NativeProgram::prefill`]/[`NativeProgram::decode_step`].
    fn make_decode_state(&self) -> Result<Box<dyn Any>> {
        bail!("{}: program has no decode path", self.name())
    }

    /// Ingest a prompt into the decode state and return the logits at
    /// its last position. Params may arrive packed (the quantized
    /// serving path) — programs with fused kernels consume them in
    /// place.
    fn prefill(
        &self,
        _params: &[ParamView<'_>],
        _tokens: &[i32],
        _state: &mut dyn Any,
        _pool: &Pool,
    ) -> Result<Vec<f32>> {
        bail!("{}: program has no decode path", self.name())
    }

    /// Append one token to the cached sequence and return the
    /// next-token logits.
    fn decode_step(
        &self,
        _params: &[ParamView<'_>],
        _token: i32,
        _state: &mut dyn Any,
        _pool: &Pool,
    ) -> Result<Vec<f32>> {
        bail!("{}: program has no decode path", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn static_slice_finds_by_name() {
        let statics = vec![
            ("lam".to_string(), vec![1.0f32, 2.0]),
            ("wstar".to_string(), vec![3.0f32]),
        ];
        assert_eq!(static_slice(&statics, "wstar").unwrap(), &[3.0]);
        assert!(static_slice(&statics, "missing").is_err());
    }
}
