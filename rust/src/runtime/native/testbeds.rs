//! The synthetic testbeds (§4.1 linreg, §4.2 linear2) as
//! [`NativeProgram`]s: forward, backward and exact validation losses
//! over flat `f32` buffers, mirroring `python/compile/models/*`. Both
//! models have *exact* Gauss-Newton diagonals, so LOTION's Eq. 3
//! penalty is parameter-free here (the driver applies it; this module
//! only supplies the curvature).
//!
//! Hot loops are row-parallel on a [`Pool`]: minibatch rows sample
//! from per-row counter streams (`Rng::stream(data_seed, &[row])`),
//! partial gradients accumulate per fixed [`ROW_CHUNK`] and fold in
//! chunk order, and the linear2 row loops split by output row — all
//! partitioned independently of the thread count, so training is
//! bit-identical at `--threads 1` and `--threads N`.

use crate::data::synth::population_loss;
use crate::runtime::manifest::{Role, TensorSpec};
use crate::tensor::DType;
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK, PAR_MIN};
use crate::util::rng::Rng;
use crate::util::simd::{dot_lanes, weighted_sq_lanes};
use anyhow::Result;
use std::any::Any;
use std::ops::Range;

use super::program::{static_slice, EvalCtx, NativeProgram, StepCtx};

/// Minibatch rows per parallel task — a fixed constant (never derived
/// from the thread count) so the gradient reduction order, and with it
/// the trained bitstream, is invariant to `--threads`.
const ROW_CHUNK: usize = 4;

/// A native testbed model: defines parameter layout, data distribution,
/// loss/gradients, and the exact Gauss-Newton diagonal LOTION uses.
#[derive(Clone, Copy, Debug)]
pub enum ModelSpec {
    /// §4.1: `y = w*.x`, `x ~ N(0, diag(lam))`, minibatch SGD in-graph.
    LinReg { d: usize, batch: usize },
    /// §4.2: `f(x) = (1/k) W2 W1 x`, full-batch exact population loss.
    Linear2 { d: usize, k: usize },
}

/// Reusable buffers (`sqrt_lam` hoist — derived lazily from the step
/// statics, so the hot loop never re-takes the square roots). The
/// driver now caches scratch across train calls *and runs*, so the
/// source `lam` is kept alongside and the hoist re-derives whenever
/// the statics actually change (same-length different-values statics
/// must not reuse a stale hoist).
struct TestbedScratch {
    lam: Vec<f32>,
    sqrt_lam: Vec<f32>,
}

fn spec(name: &str, shape: &[usize], role: Role) -> TensorSpec {
    TensorSpec { name: name.to_string(), shape: shape.to_vec(), dtype: DType::F32, role }
}

impl ModelSpec {
    pub fn dim(&self) -> usize {
        match self {
            ModelSpec::LinReg { d, .. } | ModelSpec::Linear2 { d, .. } => *d,
        }
    }
}

impl NativeProgram for ModelSpec {
    fn name(&self) -> String {
        match self {
            ModelSpec::LinReg { d, .. } => format!("linreg_d{d}"),
            ModelSpec::Linear2 { d, k } => format!("linear2_d{d}_k{k}"),
        }
    }

    fn param_specs(&self) -> Vec<TensorSpec> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![spec("w", &[*d], Role::Param)],
            ModelSpec::Linear2 { d, k } => vec![
                spec("w1", &[*k, *d], Role::Param),
                spec("w2", &[1, *k], Role::Param),
            ],
        }
    }

    fn static_specs(&self) -> Vec<TensorSpec> {
        let d = self.dim();
        vec![spec("lam", &[d], Role::Static), spec("wstar", &[d], Role::Static)]
    }

    fn quantized(&self) -> Vec<String> {
        match self {
            ModelSpec::LinReg { .. } => vec!["w".to_string()],
            ModelSpec::Linear2 { .. } => vec!["w1".to_string(), "w2".to_string()],
        }
    }

    /// Fresh parameters in spec order (models/linreg.py, linear2.py).
    fn init(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        match self {
            ModelSpec::LinReg { d, .. } => vec![vec![0.0; *d]],
            ModelSpec::Linear2 { d, k } => {
                let mut k1 = rng.fork(1);
                let mut k2 = rng.fork(2);
                let scale = 1.0 / (*d as f32).sqrt();
                let mut w1 = vec![0.0f32; k * d];
                k1.fill_normal(&mut w1);
                for v in w1.iter_mut() {
                    *v *= scale;
                }
                let mut w2 = vec![0.0f32; *k];
                k2.fill_normal(&mut w2);
                vec![w1, w2]
            }
        }
    }

    fn make_scratch(&self) -> Box<dyn Any> {
        Box::new(TestbedScratch { lam: Vec::new(), sqrt_lam: Vec::new() })
    }

    fn loss_grad(
        &self,
        wq: &[Vec<f32>],
        ctx: &StepCtx<'_>,
        scratch: &mut dyn Any,
        grads: &mut [Vec<f32>],
    ) -> Result<f64> {
        let lam = static_slice(ctx.statics, "lam")?;
        let wstar = static_slice(ctx.statics, "wstar")?;
        match self {
            ModelSpec::LinReg { d, batch } => {
                let s = scratch.downcast_mut::<TestbedScratch>().expect("testbed scratch");
                if s.lam.as_slice() != lam {
                    s.lam = lam.to_vec();
                    s.sqrt_lam = lam.iter().map(|l| l.sqrt()).collect();
                }
                Ok(linreg_loss_grad(
                    *d,
                    *batch,
                    &wq[0],
                    &s.sqrt_lam,
                    wstar,
                    ctx.streams.data,
                    ctx.pool,
                    &mut grads[0],
                ))
            }
            ModelSpec::Linear2 { d, k } => {
                let (g1, g2) = grads.split_at_mut(1);
                Ok(linear2_loss_grad(
                    *d,
                    *k,
                    &wq[0],
                    &wq[1],
                    lam,
                    wstar,
                    ctx.pool,
                    &mut g1[0],
                    &mut g2[0],
                ))
            }
        }
    }

    /// Exact Gauss-Newton diagonal: `lam` itself for linreg, the
    /// closed form for linear2 (the synthetic models' `fisher_exact`;
    /// stop-grad, evaluated at the master weights).
    fn fisher_exact_into(
        &self,
        params: &[Vec<f32>],
        ctx: &StepCtx<'_>,
        out: &mut [Vec<f32>],
    ) -> Result<bool> {
        let lam = static_slice(ctx.statics, "lam")?;
        match self {
            ModelSpec::LinReg { .. } => out[0].copy_from_slice(lam),
            ModelSpec::Linear2 { d, k } => {
                let (d, k) = (*d, *k);
                let (w1, w2) = (&params[0], &params[1]);
                let kf = k as f32;
                let (f1, rest) = out.split_at_mut(1);
                let f1 = &mut f1[0][..];
                let f2 = &mut rest[0][..];
                let row_ranges: Vec<Range<usize>> = (0..k).map(|j| j * d..(j + 1) * d).collect();
                let accs = ctx.pool.for_chunks_mut(f1, &row_ranges, k * d, |j, _, frow| {
                    let wj = w2[j] / kf;
                    let row = &w1[j * d..(j + 1) * d];
                    for (f, &l) in frow.iter_mut().zip(lam) {
                        *f = wj * wj * l;
                    }
                    weighted_sq_lanes(lam, row) / (kf * kf)
                });
                f2.copy_from_slice(&accs);
            }
        }
        Ok(true)
    }

    /// Exact validation loss at the given parameters (closed forms —
    /// no eval buffers, so the driver scratch is unused).
    fn val_loss(
        &self,
        params: &[Vec<f32>],
        ctx: &EvalCtx<'_>,
        _scratch: &mut dyn Any,
    ) -> Result<f64> {
        let lam = static_slice(ctx.statics, "lam")?;
        let wstar = static_slice(ctx.statics, "wstar")?;
        Ok(match self {
            ModelSpec::LinReg { .. } => population_loss(&params[0], wstar, lam),
            ModelSpec::Linear2 { d, k } => {
                let v = effective_w_pool(*d, *k, &params[0], &params[1], ctx.pool);
                population_loss(&v, wstar, lam)
            }
        })
    }
}

/// `v = (1/k) W2 W1` — the effective linear map of the two-layer
/// model, split column-parallel: each worker owns a contiguous `v`
/// range and folds the k rows itself, so any chunking yields the same
/// bits.
pub(crate) fn effective_w_pool(
    d: usize,
    k: usize,
    w1: &[f32],
    w2: &[f32],
    pool: &Pool,
) -> Vec<f32> {
    let mut v = vec![0.0f32; d];
    let kf = k as f32;
    pool.for_chunks_mut(&mut v, &chunk_ranges(d, PAR_CHUNK), k * d, |_, r, out| {
        for j in 0..k {
            let wj = w2[j];
            let row = &w1[j * d + r.start..j * d + r.end];
            for (o, x) in out.iter_mut().zip(row) {
                *o += wj * x;
            }
        }
        for o in out.iter_mut() {
            *o /= kf;
        }
    });
    v
}

/// Minibatch loss + gradient for linreg at forward weights `wq`:
/// `x ~ N(0, diag(lam))`, `y = w*.x`, `L = 0.5 mean((x.wq - y)^2)`,
/// `dL/dwq = (1/B) X^T r`. Row `b` samples from the counter stream
/// `Rng::stream(data_seed, &[b])`; rows are processed in fixed
/// [`ROW_CHUNK`] groups whose partial gradients fold in chunk order —
/// parallel across the pool, bit-identical at any thread count.
#[allow(clippy::too_many_arguments)]
fn linreg_loss_grad(
    d: usize,
    batch: usize,
    wq: &[f32],
    sqrt_lam: &[f32],
    wstar: &[f32],
    data_seed: u64,
    pool: &Pool,
    grad: &mut [f32],
) -> f64 {
    let ranges = chunk_ranges(batch, ROW_CHUNK);
    let part = |r: Range<usize>| -> (f64, Vec<f32>) {
        let mut g = vec![0.0f32; d];
        let mut xrow = vec![0.0f32; d];
        let mut loss_acc = 0.0f64;
        for row in r {
            let mut rng = Rng::stream(data_seed, &[row as u64]);
            for (x, sl) in xrow.iter_mut().zip(sqrt_lam) {
                *x = rng.normal_f32() * sl;
            }
            // lane-unrolled GEMV dots (fixed order, SIMD-friendly)
            let y = dot_lanes(&xrow, wstar);
            let pred = dot_lanes(&xrow, wq);
            let res = pred - y;
            loss_acc += (res as f64) * (res as f64);
            for i in 0..d {
                g[i] += res * xrow[i];
            }
        }
        (loss_acc, g)
    };
    let parts: Vec<(f64, Vec<f32>)> = if batch * d < PAR_MIN || pool.threads() == 1 {
        ranges.into_iter().map(part).collect()
    } else {
        pool.run(ranges, |_, r| part(r))
    };
    grad.fill(0.0);
    let mut loss_acc = 0.0f64;
    for (pl, pg) in &parts {
        loss_acc += pl;
        for (g, p) in grad.iter_mut().zip(pg) {
            *g += p;
        }
    }
    let bf = batch as f32;
    for g in grad.iter_mut() {
        *g /= bf;
    }
    0.5 * loss_acc / batch as f64
}

/// Exact full-batch loss + gradients for linear2 at forward weights
/// `(w1q, w2q)`: `L = 0.5 (v - w*)^T diag(lam) (v - w*)` with
/// `v = (1/k) W2 W1`; gradients by the chain rule through `v`. The
/// `v`/`g` passes are column-parallel (per-element independent), the
/// weight-gradient pass row-parallel; the loss folds per fixed chunk.
#[allow(clippy::too_many_arguments)]
fn linear2_loss_grad(
    d: usize,
    k: usize,
    w1q: &[f32],
    w2q: &[f32],
    lam: &[f32],
    wstar: &[f32],
    pool: &Pool,
    gw1: &mut [f32],
    gw2: &mut [f32],
) -> f64 {
    let v = effective_w_pool(d, k, w1q, w2q, pool);
    let kf = k as f32;

    // dL/dv (element-wise) + per-chunk loss partials folded in order
    let mut g = vec![0.0f32; d];
    let col_ranges = chunk_ranges(d, PAR_CHUNK);
    // this pass touches only d elements; gate the dispatch on that,
    // not on the k*d-sized weight passes below
    let loss_parts = pool.for_chunks_mut(&mut g, &col_ranges, d, |_, r, gout| {
        let mut loss = 0.0f64;
        for i in r.clone() {
            let dv = v[i] - wstar[i];
            loss += 0.5 * (lam[i] as f64) * (dv as f64) * (dv as f64);
            gout[i - r.start] = lam[i] * dv;
        }
        loss
    });
    let loss: f64 = loss_parts.iter().sum();

    // weight gradients, row-parallel over the k output rows
    let row_ranges: Vec<Range<usize>> = (0..k).map(|j| j * d..(j + 1) * d).collect();
    let g2 = pool.for_chunks_mut(gw1, &row_ranges, k * d, |j, _, grow| {
        let wj = w2q[j] / kf;
        let row = &w1q[j * d..(j + 1) * d];
        for (o, &gv) in grow.iter_mut().zip(&g[..]) {
            *o = wj * gv;
        }
        dot_lanes(&g, row) / kf
    });
    gw2.copy_from_slice(&g2);
    loss
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantFormat;
    use crate::runtime::native::program::StepStreams;

    fn lg(
        d: usize,
        batch: usize,
        wq: &[f32],
        lam: &[f32],
        wstar: &[f32],
        seed: u64,
    ) -> (f64, Vec<f32>) {
        let sqrt_lam: Vec<f32> = lam.iter().map(|l| l.sqrt()).collect();
        let mut grad = vec![0.0f32; d];
        let loss =
            linreg_loss_grad(d, batch, wq, &sqrt_lam, wstar, seed, &Pool::serial(), &mut grad);
        (loss, grad)
    }

    fn l2(
        d: usize,
        k: usize,
        w1: &[f32],
        w2: &[f32],
        lam: &[f32],
        wstar: &[f32],
    ) -> (f64, Vec<Vec<f32>>) {
        let mut gw1 = vec![0.0f32; k * d];
        let mut gw2 = vec![0.0f32; k];
        let loss = linear2_loss_grad(d, k, w1, w2, lam, wstar, &Pool::serial(), &mut gw1, &mut gw2);
        (loss, vec![gw1, gw2])
    }

    /// Finite-difference check of linear2 gradients (exact loss, so FD
    /// converges cleanly).
    #[test]
    fn linear2_grads_match_finite_differences() {
        let (d, k) = (6, 2);
        let mut rng = Rng::new(3);
        let mut w1 = vec![0.0f32; k * d];
        rng.fill_normal(&mut w1);
        let mut w2 = vec![0.0f32; k];
        rng.fill_normal(&mut w2);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / i as f32).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);

        let (_, grads) = l2(d, k, &w1, &w2, &lam, &wstar);
        let eps = 1e-3f32;
        for idx in 0..k * d {
            let mut hi = w1.clone();
            hi[idx] += eps;
            let mut lo = w1.clone();
            lo[idx] -= eps;
            let (lh, _) = l2(d, k, &hi, &w2, &lam, &wstar);
            let (ll, _) = l2(d, k, &lo, &w2, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[0][idx]).abs() < 1e-3, "w1[{idx}]: fd={fd} an={}", grads[0][idx]);
        }
        for j in 0..k {
            let mut hi = w2.clone();
            hi[j] += eps;
            let mut lo = w2.clone();
            lo[j] -= eps;
            let (lh, _) = l2(d, k, &w1, &hi, &lam, &wstar);
            let (ll, _) = l2(d, k, &w1, &lo, &lam, &wstar);
            let fd = ((lh - ll) / (2.0 * eps as f64)) as f32;
            assert!((fd - grads[1][j]).abs() < 1e-3, "w2[{j}]: fd={fd} an={}", grads[1][j]);
        }
    }

    /// Linreg minibatch gradient is unbiased for the population gradient
    /// `diag(lam) (w - w*)`; check with a large batch.
    #[test]
    fn linreg_grad_approximates_population_gradient() {
        let d = 8;
        let mut rng = Rng::new(7);
        let lam: Vec<f32> = (1..=d).map(|i| 1.0 / (i as f32).powf(1.1)).collect();
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let mut w = vec![0.0f32; d];
        rng.fill_normal(&mut w);
        let (_, grad) = lg(d, 20000, &w, &lam, &wstar, 11);
        for i in 0..d {
            let pop = lam[i] * (w[i] - wstar[i]);
            // B = 20000 puts the estimator's std well under this band
            assert!(
                (grad[i] - pop).abs() < 0.15 * pop.abs() + 0.08,
                "i={i} grad={} pop={pop}",
                grad[i]
            );
        }
    }

    /// Row-parallel gradients must match the serial fold bit-for-bit
    /// (same fixed chunking, same reduction order).
    #[test]
    fn linreg_grad_is_thread_count_invariant() {
        let d = 3000; // batch*d over PAR_MIN -> parallel path engages
        let batch = 16;
        let mut rng = Rng::new(5);
        let mut w = vec![0.0f32; d];
        rng.fill_normal(&mut w);
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let lam = vec![0.5f32; d];
        let sqrt_lam: Vec<f32> = lam.iter().map(|l| l.sqrt()).collect();
        let run = |threads: usize| {
            let mut grad = vec![0.0f32; d];
            let loss = linreg_loss_grad(
                d,
                batch,
                &w,
                &sqrt_lam,
                &wstar,
                42,
                &Pool::new(threads),
                &mut grad,
            );
            (loss, grad)
        };
        let (l1, g1) = run(1);
        let (l3, g3) = run(3);
        let (l4, g4) = run(4);
        assert_eq!(l1.to_bits(), l3.to_bits());
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(g1, g3);
        assert_eq!(g1, g4);
    }

    #[test]
    fn linear2_grads_are_thread_count_invariant() {
        let (d, k) = (9000, 4);
        let mut rng = Rng::new(6);
        let mut w1 = vec![0.0f32; k * d];
        rng.fill_normal(&mut w1);
        let mut w2 = vec![0.0f32; k];
        rng.fill_normal(&mut w2);
        let mut wstar = vec![0.0f32; d];
        rng.fill_normal(&mut wstar);
        let lam: Vec<f32> = (0..d).map(|i| 1.0 / (1 + i % 9) as f32).collect();
        let run = |threads: usize| {
            let mut gw1 = vec![0.0f32; k * d];
            let mut gw2 = vec![0.0f32; k];
            let loss = linear2_loss_grad(
                d,
                k,
                &w1,
                &w2,
                &lam,
                &wstar,
                &Pool::new(threads),
                &mut gw1,
                &mut gw2,
            );
            (loss, gw1, gw2)
        };
        let (l1, a1, b1) = run(1);
        let (l4, a4, b4) = run(4);
        assert_eq!(l1.to_bits(), l4.to_bits());
        assert_eq!(a1, a4);
        assert_eq!(b1, b4);
    }

    #[test]
    fn effective_w_of_gt_construction_is_wstar() {
        // Lemma 4's GT: rows(W1) = w*, W2 = 1 -> v = w*
        let (d, k) = (5, 3);
        let wstar = vec![0.5f32, -1.0, 2.0, 0.0, -0.25];
        let w1: Vec<f32> = (0..k).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; k];
        assert_eq!(effective_w_pool(d, k, &w1, &w2, &Pool::serial()), wstar);
    }

    /// The linreg Fisher is `lam` itself; the linear2 one matches the
    /// closed form used by the python `fisher_exact`.
    #[test]
    fn fisher_exact_matches_closed_forms() {
        let pool = Pool::serial();
        let statics = vec![
            ("lam".to_string(), vec![1.0f32, 0.5, 0.25]),
            ("wstar".to_string(), vec![0.0f32; 3]),
        ];
        let ctx = StepCtx {
            statics: &statics,
            data: None,
            streams: StepStreams { data: 1, round: 2 },
            pool: &pool,
        };
        let m = ModelSpec::LinReg { d: 3, batch: 2 };
        let mut out = vec![vec![0.0f32; 3]];
        assert!(m.fisher_exact_into(&[vec![0.0; 3]], &ctx, &mut out).unwrap());
        assert_eq!(out[0], vec![1.0, 0.5, 0.25]);

        let m2 = ModelSpec::Linear2 { d: 3, k: 2 };
        let w1 = vec![1.0f32, 2.0, 3.0, -1.0, 0.5, 0.0];
        let w2 = vec![2.0f32, -4.0];
        let mut out = vec![vec![0.0f32; 6], vec![0.0f32; 2]];
        assert!(m2.fisher_exact_into(&[w1.clone(), w2.clone()], &ctx, &mut out).unwrap());
        let lam = [1.0f32, 0.5, 0.25];
        for j in 0..2 {
            let wj = w2[j] / 2.0;
            for i in 0..3 {
                assert_eq!(out[0][j * 3 + i], wj * wj * lam[i]);
            }
            let acc: f32 = (0..3).map(|i| lam[i] * w1[j * 3 + i] * w1[j * 3 + i]).sum();
            assert!((out[1][j] - acc / 4.0).abs() < 1e-6);
        }
    }

    #[test]
    fn val_loss_zero_at_gt() {
        let m = ModelSpec::Linear2 { d: 3, k: 2 };
        let wstar = vec![0.25f32, -0.75, 1.5];
        let lam = vec![1.0f32, 0.5, 0.25];
        let w1: Vec<f32> = (0..2).flat_map(|_| wstar.iter().copied()).collect();
        let w2 = vec![1.0f32; 2];
        let statics = vec![("lam".to_string(), lam), ("wstar".to_string(), wstar)];
        let pool = Pool::serial();
        let ctx = EvalCtx { statics: &statics, data: None, pool: &pool };
        assert_eq!(m.val_loss(&[w1, w2], &ctx, m.make_scratch().as_mut()).unwrap(), 0.0);
    }

    /// LOTION-relevant sanity: quantized subsets and spec shapes agree
    /// with the manifest contract.
    #[test]
    fn specs_and_quantized_sets() {
        let m = ModelSpec::Linear2 { d: 4, k: 2 };
        let names: Vec<String> = m.param_specs().iter().map(|s| s.name.clone()).collect();
        assert_eq!(names, vec!["w1", "w2"]);
        assert_eq!(m.quantized(), vec!["w1", "w2"]);
        assert_eq!(m.param_specs()[0].shape, vec![2, 4]);
        let _ = QuantFormat::int4(); // the driver owns casting now
        assert!(m.train_data_spec(4).is_none());
        assert_eq!(m.eval_batches(), 1);
    }
}
