//! Decoder-only transformer LM interpreter — the [`NativeProgram`]
//! behind the `lm-*` presets, unlocking Figs. 1/4/5/9–12 + Tables 1/2
//! offline (DESIGN.md §3/§6). Semantics mirror
//! `python/compile/models/transformer.py`: pre-norm decoder with
//! RMSNorm, rotary position embeddings, causal attention, SwiGLU MLP
//! and an untied (quantized) `lm_head`; next-token mean cross-entropy.
//! Forward + manual backward run over flat `f32` buffers.
//!
//! Parity with the python oracle is tolerance-based (`f32` summation
//! orders differ), checked by `tests/golden_lm.rs` against goldens
//! from `scripts/gen_golden_lm.py`.
//!
//! Every kernel is row/head-parallel on a [`Pool`] with the
//! determinism contract of DESIGN.md §3: work is partitioned by fixed
//! constants, each output element is produced by exactly one worker
//! with a fixed inner summation order, and loss partials fold in
//! chunk-index order — so training is bit-identical at any
//! `--threads` setting. The interpreter itself is RNG-free (data
//! arrives as a `data`-role token batch; rounding noise is the
//! driver's job).

use crate::quant::PackedWeights;
use crate::runtime::manifest::{Role, TensorSpec};
use crate::simd_kernel;
use crate::tensor::DType;
use crate::util::pool::{chunk_ranges, Pool, PAR_CHUNK, PAR_MIN};
use crate::util::rng::Rng;
use crate::util::simd::{active_tier, dot_lanes_tier};
use anyhow::{bail, Result};
use std::any::Any;
use std::cell::RefCell;
use std::ops::Range;

use super::program::{DecodeSpec, EvalCtx, NativeProgram, ParamView, StepCtx};

/// Rows per parallel task in the row-parallel kernels — a fixed
/// constant (never derived from the thread count), per the DESIGN.md
/// §3 determinism contract.
const ROWS_PER_TASK: usize = 8;

/// Architecture of one decoder-only LM (transformer.py `LMConfig`).
#[derive(Clone, Copy, Debug)]
pub struct LmConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
}

/// The size presets mirrored from the python side (DESIGN.md §6), with
/// the AOT batch geometry: (config, train batch, eval batches, K).
const PRESETS: [(&str, LmConfig, usize, usize, usize); 4] = [
    (
        "lm-tiny",
        LmConfig { vocab: 256, d_model: 64, n_layers: 2, n_heads: 2, seq_len: 64 },
        8,
        4,
        4,
    ),
    (
        "lm-150m-sim",
        LmConfig { vocab: 256, d_model: 192, n_layers: 4, n_heads: 4, seq_len: 128 },
        4,
        8,
        8,
    ),
    (
        "lm-300m-sim",
        LmConfig { vocab: 256, d_model: 256, n_layers: 6, n_heads: 8, seq_len: 128 },
        4,
        8,
        8,
    ),
    (
        "lm-100m",
        LmConfig { vocab: 256, d_model: 768, n_layers: 14, n_heads: 12, seq_len: 256 },
        4,
        2,
        4,
    ),
];

impl LmConfig {
    pub fn head_dim(&self) -> usize {
        self.d_model / self.n_heads
    }

    /// SwiGLU hidden width: `ceil((8/3) * d_model / 64) * 64`, computed
    /// exactly as the python `LMConfig.ffn_dim` float expression.
    pub fn ffn_dim(&self) -> usize {
        let raw = (8.0f64 / 3.0) * self.d_model as f64;
        ((raw / 64.0).ceil() as usize) * 64
    }

    pub fn param_count(&self) -> usize {
        let (d, f, v) = (self.d_model, self.ffn_dim(), self.vocab);
        let per_layer = 4 * d * d + 3 * d * f + 2 * d;
        v * d + self.n_layers * per_layer + d + d * v
    }
}

/// The names of the built-in presets, for error messages.
pub fn preset_names() -> Vec<&'static str> {
    PRESETS.iter().map(|(n, ..)| *n).collect()
}

/// A registered LM workload: preset (or custom) config + batch
/// geometry. The program's K-step chunking is owned by the engine
/// registry ([`super::NativeModel::steps_per_call`]).
#[derive(Clone, Debug)]
pub struct LmProgram {
    pub cfg: LmConfig,
    name: String,
    pub batch: usize,
    eval_batches: usize,
}

// Canonical (sorted-name) parameter order: embed, layer{l:02}.* (9 per
// layer, alphabetical), lm_head, norm_final. Index helpers below.
const PER_LAYER: usize = 9;
const L_ATTN_WK: usize = 0;
const L_ATTN_WO: usize = 1;
const L_ATTN_WQ: usize = 2;
const L_ATTN_WV: usize = 3;
const L_MLP_WDOWN: usize = 4;
const L_MLP_WGATE: usize = 5;
const L_MLP_WUP: usize = 6;
const L_NORM_ATTN: usize = 7;
const L_NORM_MLP: usize = 8;
const P_EMBED: usize = 0;

fn p_layer(l: usize, off: usize) -> usize {
    1 + l * PER_LAYER + off
}

/// A forward-pass weight: dense f32, or packed block-quantized codes
/// consumed in place by the fused dequant matmul. The forward pass is
/// generic over this so the quantized-eval path never materializes a
/// full f32 copy of a cast tensor; training always passes `Dense`.
#[derive(Clone, Copy)]
enum WRef<'a> {
    Dense(&'a [f32]),
    Packed(&'a PackedWeights),
}

impl<'a> WRef<'a> {
    /// The dense view — only the matmul weights may be packed
    /// (embeddings and norm gains are gathered/broadcast elementwise,
    /// which packed storage does not support).
    fn dense(&self) -> &'a [f32] {
        match self {
            WRef::Dense(w) => w,
            WRef::Packed(p) => {
                panic!("packed weight ({} codes) where a dense tensor is required", p.len())
            }
        }
    }
}

/// `y = x @ w` for either weight representation — the single matmul
/// entry the forward pass uses. Both arms share tile geometry and
/// summation order, so the outputs are bit-identical (packed decode
/// canonicalizes `-0.0`, which a `+0.0`-seeded accumulator ignores).
fn mm(x: &[f32], w: &WRef<'_>, y: &mut [f32], m: usize, d: usize, n: usize, pool: &Pool) {
    match w {
        WRef::Dense(wd) => matmul(x, wd, y, m, d, n, pool),
        WRef::Packed(p) => matmul_packed(x, p, y, m, d, n, pool),
    }
}

impl LmProgram {
    /// Build a custom LM program; validates the head geometry.
    pub fn new(name: &str, cfg: LmConfig, batch: usize, eval_batches: usize) -> Result<LmProgram> {
        if cfg.n_heads == 0 || cfg.d_model % cfg.n_heads != 0 {
            bail!("{name}: d_model {} not divisible by n_heads {}", cfg.d_model, cfg.n_heads);
        }
        if cfg.head_dim() % 2 != 0 {
            bail!("{name}: head_dim {} must be even for RoPE", cfg.head_dim());
        }
        if cfg.vocab == 0 || cfg.d_model == 0 || cfg.seq_len == 0 || batch == 0 {
            bail!("{name}: vocab/d_model/seq_len/batch must be positive");
        }
        Ok(LmProgram {
            cfg,
            name: name.to_string(),
            batch,
            eval_batches: eval_batches.max(1),
        })
    }

    /// Look up a built-in preset by name; the error lists the known
    /// presets so a config typo is self-explaining.
    pub fn preset(name: &str) -> Result<LmProgram> {
        for (n, cfg, batch, eval_batches, _) in PRESETS {
            if n == name {
                return LmProgram::new(n, cfg, batch, eval_batches);
            }
        }
        bail!("unknown LM preset {name:?} (known presets: {})", preset_names().join(", "))
    }

    /// The AOT-matching steps-per-call for a preset; fails (listing
    /// the known presets) on a typo, like [`LmProgram::preset`].
    pub fn preset_k(name: &str) -> Result<usize> {
        PRESETS
            .iter()
            .find(|(n, ..)| *n == name)
            .map(|(.., k)| *k)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "unknown LM preset {name:?} (known presets: {})",
                    preset_names().join(", ")
                )
            })
    }

    fn p_lm_head(&self) -> usize {
        1 + self.cfg.n_layers * PER_LAYER
    }

    fn p_norm_final(&self) -> usize {
        2 + self.cfg.n_layers * PER_LAYER
    }

    /// Forward pass at the given forward weights; fills the scratch's
    /// activations and `logits`. `tokens` is one `[B, T+1]` batch.
    /// Weights arrive as [`WRef`]s so the quantized-eval path can feed
    /// packed matmul weights; only the 2-D matmul operands may be
    /// packed (the gather/broadcast tensors must be `Dense`).
    fn forward(
        &self,
        ws: &[WRef<'_>],
        tokens: &[i32],
        s: &mut LmScratch,
        pool: &Pool,
    ) -> Result<()> {
        self.forward_bt(ws, tokens, self.batch, self.cfg.seq_len, s, pool)
    }

    /// The forward body at explicit batch/length `(b, t)` with
    /// `t <= seq_len` — the training path runs it at the preset
    /// geometry; decode prefill runs it at `(1, prompt_len)`. Every
    /// kernel sums ascending over depth and row `p` of causal
    /// attention reads only rows `<= p`, so row `p` of the outputs is
    /// a pure function of tokens `0..=p` — bitwise independent of `b`,
    /// `t` and the trailing tokens. That row-stability is what makes
    /// KV-cache decode bit-equal to full recompute.
    fn forward_bt(
        &self,
        ws: &[WRef<'_>],
        tokens: &[i32],
        b: usize,
        t: usize,
        s: &mut LmScratch,
        pool: &Pool,
    ) -> Result<()> {
        let cfg = &self.cfg;
        let (d, f, v) = (cfg.d_model, cfg.ffn_dim(), cfg.vocab);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let m = b * t;
        if tokens.len() != b * (t + 1) {
            bail!("{}: got {} tokens, expected {}x{}", self.name, tokens.len(), b, t + 1);
        }
        for bi in 0..b {
            for ti in 0..t {
                let tok = tokens[bi * (t + 1) + ti];
                let tgt = tokens[bi * (t + 1) + ti + 1];
                if tok < 0 || tok as usize >= v || tgt < 0 || tgt as usize >= v {
                    bail!("{}: token out of range for vocab {v}", self.name);
                }
                s.tok[bi * t + ti] = tok as usize;
                s.tgt[bi * t + ti] = tgt as usize;
            }
        }

        // token embedding gather (serial memcpy per row)
        let embed = ws[P_EMBED].dense();
        for (row, &tk) in s.tok.iter().enumerate() {
            s.hs[0][row * d..(row + 1) * d].copy_from_slice(&embed[tk * d..(tk + 1) * d]);
        }

        let (cos, sin) = (&s.cos, &s.sin);
        for l in 0..cfg.n_layers {
            let (head, tail) = s.hs.split_at_mut(l + 1);
            let hin: &[f32] = &head[l];
            let hout: &mut [f32] = &mut tail[0];
            let lay = &mut s.layers[l];
            let base = p_layer(l, 0);

            rms_r(hin, &mut lay.r1, d, pool);
            rmsnorm_apply(hin, ws[base + L_NORM_ATTN].dense(), &lay.r1, &mut lay.xn1, d, pool);
            mm(&lay.xn1, &ws[base + L_ATTN_WQ], &mut lay.q, m, d, d, pool);
            mm(&lay.xn1, &ws[base + L_ATTN_WK], &mut lay.k, m, d, d, pool);
            mm(&lay.xn1, &ws[base + L_ATTN_WV], &mut lay.v, m, d, d, pool);
            rope_apply(&mut lay.q, cos, sin, b, t, nh, hd, 1.0, pool);
            rope_apply(&mut lay.k, cos, sin, b, t, nh, hd, 1.0, pool);
            attn_probs(&lay.q, &lay.k, &mut lay.p, b, nh, t, hd, pool);
            attn_mix(&lay.p, &lay.v, &mut lay.o, b, nh, t, hd, pool);
            mm(&lay.o, &ws[base + L_ATTN_WO], &mut s.tmp, m, d, d, pool);
            add_rows(hin, &s.tmp, &mut lay.h_attn, pool);

            rms_r(&lay.h_attn, &mut lay.r2, d, pool);
            let g_mlp = ws[base + L_NORM_MLP].dense();
            rmsnorm_apply(&lay.h_attn, g_mlp, &lay.r2, &mut lay.xn2, d, pool);
            mm(&lay.xn2, &ws[base + L_MLP_WGATE], &mut lay.gpre, m, d, f, pool);
            mm(&lay.xn2, &ws[base + L_MLP_WUP], &mut lay.u, m, d, f, pool);
            swiglu_fwd(&lay.gpre, &lay.u, &mut lay.gu, pool);
            mm(&lay.gu, &ws[base + L_MLP_WDOWN], &mut s.tmp, m, f, d, pool);
            add_rows(&lay.h_attn, &s.tmp, hout, pool);
        }

        let h_last = &s.hs[cfg.n_layers];
        rms_r(h_last, &mut s.rf, d, pool);
        rmsnorm_apply(h_last, ws[self.p_norm_final()].dense(), &s.rf, &mut s.xnf, d, pool);
        mm(&s.xnf, &ws[self.p_lm_head()], &mut s.logits, m, d, v, pool);
        Ok(())
    }

    /// Backward pass from `s.dlogits` into `grads` (all overwritten).
    fn backward(&self, ws: &[Vec<f32>], s: &mut LmScratch, pool: &Pool, grads: &mut [Vec<f32>]) {
        let cfg = &self.cfg;
        let (b, t) = (self.batch, cfg.seq_len);
        let (d, f, v) = (cfg.d_model, cfg.ffn_dim(), cfg.vocab);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let m = b * t;
        let (cos, sin) = (&s.cos, &s.sin);

        // lm_head + final norm
        s.dxn.fill(0.0);
        matmul_dx(&s.dlogits, &ws[self.p_lm_head()], &mut s.dxn, m, d, v, pool);
        matmul_dw(&s.xnf, &s.dlogits, &mut grads[self.p_lm_head()], m, d, v, pool);
        let h_last = &s.hs[cfg.n_layers];
        rmsnorm_bwd_dg(h_last, &s.rf, &s.dxn, &mut grads[self.p_norm_final()], d, pool);
        s.dh.fill(0.0);
        rmsnorm_bwd_dx(h_last, &ws[self.p_norm_final()], &s.rf, &s.dxn, &mut s.dh, d, pool);

        for l in (0..cfg.n_layers).rev() {
            let lay = &mut s.layers[l];
            let base = p_layer(l, 0);
            let hin: &[f32] = &s.hs[l];

            // MLP block: h_out = h_attn + swiglu(xn2) @ wdown
            s.dgu.fill(0.0);
            matmul_dx(&s.dh, &ws[base + L_MLP_WDOWN], &mut s.dgu, m, f, d, pool);
            matmul_dw(&lay.gu, &s.dh, &mut grads[base + L_MLP_WDOWN], m, f, d, pool);
            swiglu_bwd(&lay.gpre, &lay.u, &s.dgu, &mut s.dgpre, &mut s.du, pool);
            s.dxn.fill(0.0);
            matmul_dx(&s.dgpre, &ws[base + L_MLP_WGATE], &mut s.dxn, m, d, f, pool);
            matmul_dx(&s.du, &ws[base + L_MLP_WUP], &mut s.dxn, m, d, f, pool);
            matmul_dw(&lay.xn2, &s.dgpre, &mut grads[base + L_MLP_WGATE], m, d, f, pool);
            matmul_dw(&lay.xn2, &s.du, &mut grads[base + L_MLP_WUP], m, d, f, pool);
            rmsnorm_bwd_dg(&lay.h_attn, &lay.r2, &s.dxn, &mut grads[base + L_NORM_MLP], d, pool);
            // dh += norm path; the residual term is dh itself
            rmsnorm_bwd_dx(
                &lay.h_attn,
                &ws[base + L_NORM_MLP],
                &lay.r2,
                &s.dxn,
                &mut s.dh,
                d,
                pool,
            );

            // attention block: h_attn = h_in + attn(xn1) @ wo
            s.dof.fill(0.0);
            matmul_dx(&s.dh, &ws[base + L_ATTN_WO], &mut s.dof, m, d, d, pool);
            matmul_dw(&lay.o, &s.dh, &mut grads[base + L_ATTN_WO], m, d, d, pool);
            attn_bwd_dv(&lay.p, &s.dof, &mut s.dv, b, nh, t, hd, pool);
            attn_bwd_ds(&lay.p, &s.dof, &lay.v, &mut s.ds, b, nh, t, hd, pool);
            attn_bwd_dq(&s.ds, &lay.k, &mut s.dq, b, nh, t, hd, pool);
            attn_bwd_dk(&s.ds, &lay.q, &mut s.dk, b, nh, t, hd, pool);
            rope_apply(&mut s.dq, cos, sin, b, t, nh, hd, -1.0, pool);
            rope_apply(&mut s.dk, cos, sin, b, t, nh, hd, -1.0, pool);
            s.dxn.fill(0.0);
            matmul_dx(&s.dq, &ws[base + L_ATTN_WQ], &mut s.dxn, m, d, d, pool);
            matmul_dx(&s.dk, &ws[base + L_ATTN_WK], &mut s.dxn, m, d, d, pool);
            matmul_dx(&s.dv, &ws[base + L_ATTN_WV], &mut s.dxn, m, d, d, pool);
            matmul_dw(&lay.xn1, &s.dq, &mut grads[base + L_ATTN_WQ], m, d, d, pool);
            matmul_dw(&lay.xn1, &s.dk, &mut grads[base + L_ATTN_WK], m, d, d, pool);
            matmul_dw(&lay.xn1, &s.dv, &mut grads[base + L_ATTN_WV], m, d, d, pool);
            rmsnorm_bwd_dg(hin, &lay.r1, &s.dxn, &mut grads[base + L_NORM_ATTN], d, pool);
            rmsnorm_bwd_dx(hin, &ws[base + L_NORM_ATTN], &lay.r1, &s.dxn, &mut s.dh, d, pool);
        }

        // embedding scatter-add (serial: deterministic by construction)
        let ge = &mut grads[P_EMBED];
        ge.fill(0.0);
        for (row, &tk) in s.tok.iter().enumerate() {
            let dst = &mut ge[tk * d..(tk + 1) * d];
            let src = &s.dh[row * d..(row + 1) * d];
            for (o, &x) in dst.iter_mut().zip(src) {
                *o += x;
            }
        }
    }

    /// Mean next-token cross-entropy of one `[B, T+1]` batch (forward
    /// only) — shared by eval and the parity tests.
    fn batch_loss(
        &self,
        ws: &[WRef<'_>],
        tokens: &[i32],
        s: &mut LmScratch,
        pool: &Pool,
    ) -> Result<f64> {
        self.forward(ws, tokens, s, pool)?;
        Ok(xent_loss(&s.logits, &s.tgt, self.cfg.vocab, pool))
    }

    /// Mean val loss over the eval batches at the given weight refs —
    /// the shared body of `val_loss` (all dense) and `val_loss_packed`.
    fn val_loss_refs(
        &self,
        ws: &[WRef<'_>],
        ctx: &EvalCtx<'_>,
        scratch: &mut dyn Any,
    ) -> Result<f64> {
        let s = scratch.downcast_mut::<LmScratch>().expect("lm scratch");
        let data = ctx
            .data
            .ok_or_else(|| anyhow::anyhow!("{}: eval got no token batches", self.name))?;
        let blen = self.batch * (self.cfg.seq_len + 1);
        if data.is_empty() || data.len() % blen != 0 {
            bail!("{}: eval data has {} tokens, not a multiple of {blen}", self.name, data.len());
        }
        let ke = data.len() / blen;
        let mut total = 0.0f64;
        for i in 0..ke {
            total += self.batch_loss(ws, &data[i * blen..(i + 1) * blen], s, ctx.pool)?;
        }
        Ok(total / ke as f64)
    }

    /// Logits `[B*T, vocab]` for one `[B, T+1]` batch (the inputs are
    /// `tokens[:, :-1]`, as in the python `forward`) — the parity-test
    /// surface for `tests/golden_lm.rs`.
    pub fn forward_logits(
        &self,
        ws: &[Vec<f32>],
        tokens: &[i32],
        pool: &Pool,
    ) -> Result<Vec<f32>> {
        let mut s = LmScratch::alloc(&self.cfg, self.batch);
        let refs: Vec<WRef<'_>> = ws.iter().map(|w| WRef::Dense(w)).collect();
        self.forward(&refs, tokens, &mut s, pool)?;
        Ok(s.logits)
    }

    /// Prompt ingestion for one sequence: run the blocked forward at
    /// `(b=1, t=len)`, copy the rotated-K / raw-V rows into the decode
    /// state's caches, and return the last position's logits. Row `p`
    /// of every activation is bitwise what a longer forward computes
    /// (see [`LmProgram::forward_bt`]), so the cache seeds incremental
    /// decode without any numeric seam.
    fn prefill_refs(
        &self,
        ws: &[WRef<'_>],
        tokens: &[i32],
        st: &mut LmDecodeState,
        pool: &Pool,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, v) = (cfg.d_model, cfg.vocab);
        let len = tokens.len();
        if len == 0 || len > cfg.seq_len {
            bail!("{}: prompt of {len} tokens (want 1..={})", self.name, cfg.seq_len);
        }
        // forward_bt consumes a [1, len+1] batch; the appended
        // next-token target is a dummy that only feeds `s.tgt`, which
        // decode never reads.
        let mut seq = Vec::with_capacity(len + 1);
        seq.extend_from_slice(tokens);
        seq.push(0);
        let mut s = LmScratch::alloc_bt(cfg, 1, len);
        self.forward_bt(ws, &seq, 1, len, &mut s, pool)?;
        for l in 0..cfg.n_layers {
            st.kc[l][..len * d].copy_from_slice(&s.layers[l].k[..len * d]);
            st.vc[l][..len * d].copy_from_slice(&s.layers[l].v[..len * d]);
        }
        st.len = len;
        Ok(s.logits[(len - 1) * v..len * v].to_vec())
    }

    /// One incremental decode step: append `token` at position
    /// `st.len`, extend the KV caches, and return the next-token
    /// logits. Every matmul runs at `m = 1` through [`mm`] — on the
    /// quantized path that is the fused packed GEMV, so decode reads
    /// nibble codes and never materializes a dense `wq`. Each kernel
    /// application is the single-row restriction of the blocked
    /// forward's (per-row ops, m-independent GEMV rows, causal
    /// attention over cached rows), so the logits are bit-identical to
    /// a full recompute over the extended sequence.
    fn decode_step_refs(
        &self,
        ws: &[WRef<'_>],
        token: i32,
        st: &mut LmDecodeState,
        pool: &Pool,
    ) -> Result<Vec<f32>> {
        let cfg = &self.cfg;
        let (d, f, v) = (cfg.d_model, cfg.ffn_dim(), cfg.vocab);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let half = hd / 2;
        let pos = st.len;
        if pos == 0 {
            bail!("{}: decode_step before prefill", self.name);
        }
        if pos >= cfg.seq_len {
            bail!("{}: context full at {} tokens", self.name, cfg.seq_len);
        }
        if token < 0 || token as usize >= v {
            bail!("{}: token out of range for vocab {v}", self.name);
        }
        // the RoPE table row at `pos` is the entire table of a
        // (b=1, t=1) problem, so the full-seq kernel rotates this one
        // row with bit-identical math
        let cos_p = &st.cos[pos * half..(pos + 1) * half];
        let sin_p = &st.sin[pos * half..(pos + 1) * half];
        let tk = token as usize;
        st.x.copy_from_slice(&ws[P_EMBED].dense()[tk * d..(tk + 1) * d]);
        for l in 0..cfg.n_layers {
            let base = p_layer(l, 0);
            rms_r(&st.x, &mut st.r, d, pool);
            rmsnorm_apply(&st.x, ws[base + L_NORM_ATTN].dense(), &st.r, &mut st.xn, d, pool);
            mm(&st.xn, &ws[base + L_ATTN_WQ], &mut st.q, 1, d, d, pool);
            rope_apply(&mut st.q, cos_p, sin_p, 1, 1, nh, hd, 1.0, pool);
            {
                let krow = &mut st.kc[l][pos * d..(pos + 1) * d];
                mm(&st.xn, &ws[base + L_ATTN_WK], krow, 1, d, d, pool);
                rope_apply(krow, cos_p, sin_p, 1, 1, nh, hd, 1.0, pool);
            }
            mm(&st.xn, &ws[base + L_ATTN_WV], &mut st.vc[l][pos * d..(pos + 1) * d], 1, d, d, pool);
            decode_attn(&st.q, &st.kc[l], &st.vc[l], &mut st.probs, &mut st.o, pos, nh, hd);
            mm(&st.o, &ws[base + L_ATTN_WO], &mut st.tmp, 1, d, d, pool);
            add_rows(&st.x, &st.tmp, &mut st.h, pool);
            rms_r(&st.h, &mut st.r, d, pool);
            rmsnorm_apply(&st.h, ws[base + L_NORM_MLP].dense(), &st.r, &mut st.xn, d, pool);
            mm(&st.xn, &ws[base + L_MLP_WGATE], &mut st.gpre, 1, d, f, pool);
            mm(&st.xn, &ws[base + L_MLP_WUP], &mut st.u, 1, d, f, pool);
            swiglu_fwd(&st.gpre, &st.u, &mut st.gu, pool);
            mm(&st.gu, &ws[base + L_MLP_WDOWN], &mut st.tmp, 1, f, d, pool);
            add_rows(&st.h, &st.tmp, &mut st.x, pool);
        }
        rms_r(&st.x, &mut st.r, d, pool);
        rmsnorm_apply(&st.x, ws[self.p_norm_final()].dense(), &st.r, &mut st.xn, d, pool);
        mm(&st.xn, &ws[self.p_lm_head()], &mut st.logits, 1, d, v, pool);
        st.len = pos + 1;
        Ok(st.logits.clone())
    }
}

/// Single-query causal attention against the KV cache: row `pos` of
/// [`attn_probs`] + [`attn_mix`] with the identical per-head
/// score/softmax/mix summation orders, run serially (one row of work
/// — far below [`PAR_MIN`]). The tier is hoisted exactly as in the
/// blocked kernels, so decode attention is bitwise the full-recompute
/// row at every SIMD tier and thread count.
fn decode_attn(
    q: &[f32],
    kc: &[f32],
    vc: &[f32],
    probs: &mut [f32],
    o: &mut [f32],
    pos: usize,
    nh: usize,
    hd: usize,
) {
    let d = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let tier = active_tier();
    o.fill(0.0);
    for hi in 0..nh {
        let qrow = &q[hi * hd..(hi + 1) * hd];
        let mut mx = f32::NEG_INFINITY;
        for si in 0..=pos {
            let krow = &kc[si * d + hi * hd..si * d + hi * hd + hd];
            let sc = dot_lanes_tier(tier, qrow, krow) * scale;
            probs[si] = sc;
            if sc > mx {
                mx = sc;
            }
        }
        let mut z = 0.0f32;
        for si in 0..=pos {
            let e = (probs[si] - mx).exp();
            probs[si] = e;
            z += e;
        }
        let inv = 1.0 / z;
        for p in probs[..=pos].iter_mut() {
            *p *= inv;
        }
        let osub = &mut o[hi * hd..(hi + 1) * hd];
        for si in 0..=pos {
            let w = probs[si];
            let vrow = &vc[si * d + hi * hd..si * d + hi * hd + hd];
            for (ov, &vv) in osub.iter_mut().zip(vrow) {
                *ov += w * vv;
            }
        }
    }
}

/// Per-sequence KV-cache decode state: rotated-K / raw-V rows for every
/// generated position plus the `m = 1` activation buffers one decode
/// step needs. Owned by the engine's decode slot map (one per live
/// sequence), never shared across sequences.
pub struct LmDecodeState {
    /// tokens cached so far; the next step appends at this position
    len: usize,
    /// per-layer caches, `[seq_len, d_model]` rows (K rows are stored
    /// *rotated*, exactly as the blocked forward leaves `lay.k`)
    kc: Vec<Vec<f32>>,
    vc: Vec<Vec<f32>>,
    /// full-length RoPE tables `[seq_len, head_dim/2]`
    cos: Vec<f32>,
    sin: Vec<f32>,
    x: Vec<f32>,
    h: Vec<f32>,
    xn: Vec<f32>,
    r: Vec<f32>,
    q: Vec<f32>,
    o: Vec<f32>,
    probs: Vec<f32>,
    tmp: Vec<f32>,
    gpre: Vec<f32>,
    u: Vec<f32>,
    gu: Vec<f32>,
    logits: Vec<f32>,
}

impl LmDecodeState {
    fn alloc(cfg: &LmConfig) -> LmDecodeState {
        let (t, d, f, v) = (cfg.seq_len, cfg.d_model, cfg.ffn_dim(), cfg.vocab);
        let half = cfg.head_dim() / 2;
        // same f64 angle math as LmScratch::alloc_bt, of which this
        // full-length table is the elementwise superset
        let (mut cos, mut sin) = (vec![0.0f32; t * half], vec![0.0f32; t * half]);
        for ti in 0..t {
            for j in 0..half {
                let freq = (10000.0f64).powf(-(j as f64) / half as f64);
                let ang = ti as f64 * freq;
                cos[ti * half + j] = ang.cos() as f32;
                sin[ti * half + j] = ang.sin() as f32;
            }
        }
        LmDecodeState {
            len: 0,
            kc: (0..cfg.n_layers).map(|_| vec![0.0; t * d]).collect(),
            vc: (0..cfg.n_layers).map(|_| vec![0.0; t * d]).collect(),
            cos,
            sin,
            x: vec![0.0; d],
            h: vec![0.0; d],
            xn: vec![0.0; d],
            r: vec![0.0; 1],
            q: vec![0.0; d],
            o: vec![0.0; d],
            probs: vec![0.0; t],
            tmp: vec![0.0; d],
            gpre: vec![0.0; f],
            u: vec![0.0; f],
            gu: vec![0.0; f],
            logits: vec![0.0; v],
        }
    }

    /// Number of positions currently cached.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }
}

impl NativeProgram for LmProgram {
    fn name(&self) -> String {
        self.name.clone()
    }

    fn param_specs(&self) -> Vec<TensorSpec> {
        let cfg = &self.cfg;
        let (v, d, f) = (cfg.vocab, cfg.d_model, cfg.ffn_dim());
        let spec = |name: String, shape: &[usize]| TensorSpec {
            name,
            shape: shape.to_vec(),
            dtype: DType::F32,
            role: Role::Param,
        };
        let mut out = vec![spec("embed".to_string(), &[v, d])];
        for l in 0..cfg.n_layers {
            let pre = format!("layer{l:02}.");
            out.push(spec(format!("{pre}attn_wk"), &[d, d]));
            out.push(spec(format!("{pre}attn_wo"), &[d, d]));
            out.push(spec(format!("{pre}attn_wq"), &[d, d]));
            out.push(spec(format!("{pre}attn_wv"), &[d, d]));
            out.push(spec(format!("{pre}mlp_wdown"), &[f, d]));
            out.push(spec(format!("{pre}mlp_wgate"), &[d, f]));
            out.push(spec(format!("{pre}mlp_wup"), &[d, f]));
            out.push(spec(format!("{pre}norm_attn"), &[d]));
            out.push(spec(format!("{pre}norm_mlp"), &[d]));
        }
        out.push(spec("lm_head".to_string(), &[d, v]));
        out.push(spec("norm_final".to_string(), &[d]));
        out
    }

    fn train_data_spec(&self, k: usize) -> Option<TensorSpec> {
        Some(TensorSpec {
            name: "tokens".to_string(),
            shape: vec![k, self.batch, self.cfg.seq_len + 1],
            dtype: DType::I32,
            role: Role::Data,
        })
    }

    fn eval_batches(&self) -> usize {
        self.eval_batches
    }

    /// The 2-D matmul weights (transformer.py `quantized_keys`):
    /// embeddings and norms stay high precision; `lm_head` is
    /// quantized (weight-only scheme).
    fn quantized(&self) -> Vec<String> {
        const MATMUL_WEIGHTS: [&str; 7] =
            ["attn_wk", "attn_wo", "attn_wq", "attn_wv", "mlp_wdown", "mlp_wgate", "mlp_wup"];
        let mut out = Vec::new();
        for l in 0..self.cfg.n_layers {
            let pre = format!("layer{l:02}.");
            for n in MATMUL_WEIGHTS {
                out.push(format!("{pre}{n}"));
            }
        }
        out.push("lm_head".to_string());
        out
    }

    /// OLMo-style init (transformer.py): normal(0, 0.02) weights with
    /// `0.02/sqrt(2L)` residual out-projections, unit norm gains. The
    /// native PRNG is deterministic per seed but (as everywhere in this
    /// backend) not bit-equal to JAX's threefry init.
    fn init(&self, rng: &mut Rng) -> Vec<Vec<f32>> {
        let sd = 0.02f32;
        let res_sd = sd / (2.0 * self.cfg.n_layers as f32).sqrt();
        self.param_specs()
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                let n = spec.elements();
                let name = spec.name.as_str();
                if name.ends_with("norm_attn") || name.ends_with("norm_mlp") || name == "norm_final"
                {
                    return vec![1.0f32; n];
                }
                let scale = if name.ends_with("attn_wo") || name.ends_with("mlp_wdown") {
                    res_sd
                } else {
                    sd
                };
                let mut w = vec![0.0f32; n];
                let mut r = rng.fork(i as u64 + 1);
                r.fill_normal(&mut w);
                for v in w.iter_mut() {
                    *v *= scale;
                }
                w
            })
            .collect()
    }

    fn make_scratch(&self) -> Box<dyn Any> {
        Box::new(LmScratch::alloc(&self.cfg, self.batch))
    }

    fn loss_grad(
        &self,
        wq: &[Vec<f32>],
        ctx: &StepCtx<'_>,
        scratch: &mut dyn Any,
        grads: &mut [Vec<f32>],
    ) -> Result<f64> {
        let s = scratch.downcast_mut::<LmScratch>().expect("lm scratch");
        let tokens = ctx
            .data
            .ok_or_else(|| anyhow::anyhow!("{}: train step got no token batch", self.name))?;
        let refs: Vec<WRef<'_>> = wq.iter().map(|w| WRef::Dense(w)).collect();
        self.forward(&refs, tokens, s, ctx.pool)?;
        let loss = xent_loss_grad(&s.logits, &s.tgt, &mut s.dlogits, self.cfg.vocab, ctx.pool);
        self.backward(wq, s, ctx.pool, grads);
        Ok(loss)
    }

    fn val_loss(
        &self,
        params: &[Vec<f32>],
        ctx: &EvalCtx<'_>,
        scratch: &mut dyn Any,
    ) -> Result<f64> {
        let refs: Vec<WRef<'_>> = params.iter().map(|w| WRef::Dense(w)).collect();
        self.val_loss_refs(&refs, ctx, scratch)
    }

    /// The fused quantized-eval path: packed matmul weights are
    /// consumed in place by [`matmul_packed`] — no full-f32 `wq`
    /// buffer is ever materialized (the default impl's decode counter
    /// stays untouched, asserted by `tests/simd_dispatch.rs`).
    fn val_loss_packed(
        &self,
        params: &[ParamView<'_>],
        ctx: &EvalCtx<'_>,
        scratch: &mut dyn Any,
    ) -> Result<f64> {
        let refs: Vec<WRef<'_>> = params
            .iter()
            .map(|p| match p {
                ParamView::Dense(w) => WRef::Dense(w),
                ParamView::Packed(p) => WRef::Packed(p),
            })
            .collect();
        self.val_loss_refs(&refs, ctx, scratch)
    }

    fn decode_spec(&self) -> Option<DecodeSpec> {
        Some(DecodeSpec { vocab: self.cfg.vocab, max_seq: self.cfg.seq_len })
    }

    fn make_decode_state(&self) -> Result<Box<dyn Any>> {
        Ok(Box::new(LmDecodeState::alloc(&self.cfg)))
    }

    fn prefill(
        &self,
        params: &[ParamView<'_>],
        tokens: &[i32],
        state: &mut dyn Any,
        pool: &Pool,
    ) -> Result<Vec<f32>> {
        let st = state.downcast_mut::<LmDecodeState>().expect("lm decode state");
        let refs: Vec<WRef<'_>> = params
            .iter()
            .map(|p| match p {
                ParamView::Dense(w) => WRef::Dense(w),
                ParamView::Packed(p) => WRef::Packed(p),
            })
            .collect();
        self.prefill_refs(&refs, tokens, st, pool)
    }

    fn decode_step(
        &self,
        params: &[ParamView<'_>],
        token: i32,
        state: &mut dyn Any,
        pool: &Pool,
    ) -> Result<Vec<f32>> {
        let st = state.downcast_mut::<LmDecodeState>().expect("lm decode state");
        let refs: Vec<WRef<'_>> = params
            .iter()
            .map(|p| match p {
                ParamView::Dense(w) => WRef::Dense(w),
                ParamView::Packed(p) => WRef::Packed(p),
            })
            .collect();
        self.decode_step_refs(&refs, token, st, pool)
    }
}

/// Per-layer saved activations for the backward pass.
struct LayerScratch {
    xn1: Vec<f32>,
    r1: Vec<f32>,
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// softmax probabilities, `[B, H, T, T]` (zero above the diagonal)
    p: Vec<f32>,
    /// attention mix `P·V` before the out-projection, `[M, D]`
    o: Vec<f32>,
    h_attn: Vec<f32>,
    xn2: Vec<f32>,
    r2: Vec<f32>,
    gpre: Vec<f32>,
    u: Vec<f32>,
    gu: Vec<f32>,
}

/// All forward activations + backward temporaries for one train call,
/// allocated once and reused across the K interpreted steps.
struct LmScratch {
    tok: Vec<usize>,
    tgt: Vec<usize>,
    /// RoPE tables `[T, head_dim/2]`
    cos: Vec<f32>,
    sin: Vec<f32>,
    /// residual stream at each layer boundary, `n_layers + 1` buffers
    hs: Vec<Vec<f32>>,
    layers: Vec<LayerScratch>,
    xnf: Vec<f32>,
    rf: Vec<f32>,
    logits: Vec<f32>,
    dlogits: Vec<f32>,
    tmp: Vec<f32>,
    dh: Vec<f32>,
    dxn: Vec<f32>,
    dof: Vec<f32>,
    dq: Vec<f32>,
    dk: Vec<f32>,
    dv: Vec<f32>,
    ds: Vec<f32>,
    dgu: Vec<f32>,
    dgpre: Vec<f32>,
    du: Vec<f32>,
}

impl LmScratch {
    fn alloc(cfg: &LmConfig, batch: usize) -> LmScratch {
        Self::alloc_bt(cfg, batch, cfg.seq_len)
    }

    /// Scratch for an explicit `(batch, t)` geometry — decode prefill
    /// allocates at `(1, prompt_len)` so short prompts don't pay the
    /// full `seq_len^2` attention scratch.
    fn alloc_bt(cfg: &LmConfig, batch: usize, t: usize) -> LmScratch {
        let (d, f, v) = (cfg.d_model, cfg.ffn_dim(), cfg.vocab);
        let (nh, hd) = (cfg.n_heads, cfg.head_dim());
        let half = hd / 2;
        let m = batch * t;
        let md = m * d;
        let (mut cos, mut sin) = (vec![0.0f32; t * half], vec![0.0f32; t * half]);
        for ti in 0..t {
            for j in 0..half {
                let freq = (10000.0f64).powf(-(j as f64) / half as f64);
                let ang = ti as f64 * freq;
                cos[ti * half + j] = ang.cos() as f32;
                sin[ti * half + j] = ang.sin() as f32;
            }
        }
        let layers = (0..cfg.n_layers)
            .map(|_| LayerScratch {
                xn1: vec![0.0; md],
                r1: vec![0.0; m],
                q: vec![0.0; md],
                k: vec![0.0; md],
                v: vec![0.0; md],
                p: vec![0.0; batch * nh * t * t],
                o: vec![0.0; md],
                h_attn: vec![0.0; md],
                xn2: vec![0.0; md],
                r2: vec![0.0; m],
                gpre: vec![0.0; m * f],
                u: vec![0.0; m * f],
                gu: vec![0.0; m * f],
            })
            .collect();
        LmScratch {
            tok: vec![0; m],
            tgt: vec![0; m],
            cos,
            sin,
            hs: (0..cfg.n_layers + 1).map(|_| vec![0.0; md]).collect(),
            layers,
            xnf: vec![0.0; md],
            rf: vec![0.0; m],
            logits: vec![0.0; m * v],
            dlogits: vec![0.0; m * v],
            tmp: vec![0.0; md],
            dh: vec![0.0; md],
            dxn: vec![0.0; md],
            dof: vec![0.0; md],
            dq: vec![0.0; md],
            dk: vec![0.0; md],
            dv: vec![0.0; md],
            ds: vec![0.0; batch * nh * t * t],
            dgu: vec![0.0; m * f],
            dgpre: vec![0.0; m * f],
            du: vec![0.0; m * f],
        }
    }
}

// ---------------------------------------------------------------------------
// kernels — all deterministic under the DESIGN.md §3 contract
// ---------------------------------------------------------------------------

/// Element ranges covering `rows` rows of `width`, a fixed number of
/// rows per task.
fn row_ranges(rows: usize, width: usize) -> Vec<Range<usize>> {
    chunk_ranges(rows, ROWS_PER_TASK)
        .into_iter()
        .map(|r| r.start * width..r.end * width)
        .collect()
}

/// One contiguous `[T, T]` block per (batch, head) pair.
fn head_ranges(bh: usize, tt: usize) -> Vec<Range<usize>> {
    (0..bh).map(|i| i * tt..(i + 1) * tt).collect()
}

/// Register-tile geometry for the blocked matmul kernels: each output
/// tile of [`TILE_M`] rows x [`TILE_N`] columns accumulates in local
/// unrolled `f32` registers across the full depth loop (the
/// autovectorizer turns the `TILE_N`-wide inner loops into SIMD)
/// instead of streaming the output row through cache once per depth
/// step. Fixed constants — never derived from the thread count — so
/// tile boundaries, and with them every summation order, are pure
/// functions of the problem shape (DESIGN.md §3).
const TILE_M: usize = 4;
const TILE_N: usize = 16;

/// The per-chunk tile loop of [`matmul`]: rows `row0..row0 + out.len()
/// / n` of `y = x @ w`, register-blocked. Compiled once per SIMD tier
/// through [`simd_kernel!`] — the tier clones run this exact body, so
/// the depth summation order (ascending, per output element) is
/// tier-invariant and the autovectorizer may only widen it.
#[inline(always)]
fn matmul_tile_body(x: &[f32], w: &[f32], out: &mut [f32], row0: usize, d: usize, n: usize) {
    let rows = out.len() / n;
    let mut i0 = 0;
    while i0 < rows {
        let mr = TILE_M.min(rows - i0);
        let mut j0 = 0;
        while j0 < n {
            let nb = TILE_N.min(n - j0);
            let mut acc = [[0.0f32; TILE_N]; TILE_M];
            if mr == TILE_M && nb == TILE_N {
                // full tile: fixed-size loops the compiler unrolls
                for di in 0..d {
                    let wrow: &[f32; TILE_N] =
                        w[di * n + j0..di * n + j0 + TILE_N].try_into().unwrap();
                    for ii in 0..TILE_M {
                        let xv = x[(row0 + i0 + ii) * d + di];
                        for (a, &wv) in acc[ii].iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            } else {
                // edge tile: same loop with clipped bounds
                for di in 0..d {
                    let wrow = &w[di * n + j0..di * n + j0 + nb];
                    for ii in 0..mr {
                        let xv = x[(row0 + i0 + ii) * d + di];
                        for (a, &wv) in acc[ii][..nb].iter_mut().zip(wrow) {
                            *a += xv * wv;
                        }
                    }
                }
            }
            for ii in 0..mr {
                out[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nb].copy_from_slice(&acc[ii][..nb]);
            }
            j0 += nb;
        }
        i0 += mr;
    }
}

simd_kernel!(
    fn matmul_tile(tier, x: &[f32], w: &[f32], out: &mut [f32], row0: usize, d: usize, n: usize) =
        matmul_tile_body
);

/// `y[M,N] = x[M,D] @ w[D,N]`, row-parallel in fixed [`ROWS_PER_TASK`]
/// chunks, register-blocked within each chunk. Per output element the
/// depth summation order is ascending — the same fixed order as the
/// pre-blocked scalar kernel, so forward logits are bit-identical to
/// it (and to any thread count or SIMD tier; the tier is hoisted once
/// per call and pinned across the parallel region).
fn matmul(x: &[f32], w: &[f32], y: &mut [f32], m: usize, d: usize, n: usize, pool: &Pool) {
    if m == 0 || n == 0 {
        return;
    }
    let tier = active_tier();
    pool.for_chunks_mut(y, &row_ranges(m, n), m * d * n, |_, r, out| {
        matmul_tile(tier, x, w, out, r.start / n, d, n);
    });
}

/// The packed-weight twin of [`matmul_tile_body`]: `w` stays in its
/// block-quantized form and each `[TILE_N]` stripe of a `w` row is
/// dequantized into registers right before use — the fused
/// dequant-matmul reads ~4-8x fewer weight bytes than a dense f32
/// matmul and no full-tensor decode ever happens. `pre` is the
/// prescaled level table (`lut * scale`) when one scale covers the
/// whole tensor. Tile geometry and accumulation order are exactly
/// [`matmul_tile_body`]'s, so outputs are bit-identical to running the
/// dense kernel on the decoded tensor.
#[inline(always)]
fn matmul_packed_tile_body(
    x: &[f32],
    w: &PackedWeights,
    pre: Option<&[f32]>,
    out: &mut [f32],
    row0: usize,
    d: usize,
    n: usize,
) {
    let rows = out.len() / n;
    let lut = w.lut();
    let mut i0 = 0;
    while i0 < rows {
        let mr = TILE_M.min(rows - i0);
        let mut j0 = 0;
        while j0 < n {
            let nb = TILE_N.min(n - j0);
            let mut acc = [[0.0f32; TILE_N]; TILE_M];
            let mut wrow = [0.0f32; TILE_N];
            for di in 0..d {
                let base = di * n + j0;
                match pre {
                    Some(slut) => {
                        for (jj, wv) in wrow[..nb].iter_mut().enumerate() {
                            *wv = slut[w.code_at(base + jj) as usize];
                        }
                    }
                    None => {
                        // per-block scales: walk the stripe in runs
                        // that share one block, hoisting the scale
                        // lookup out of the inner dequant loop. The
                        // per-element multiply `lut[c] * s` is
                        // unchanged, so outputs stay bit-identical to
                        // the unhoisted form.
                        let bs = w.block_size();
                        let mut jj = 0;
                        while jj < nb {
                            let idx = base + jj;
                            let run = if bs == 0 { nb - jj } else { (bs - idx % bs).min(nb - jj) };
                            let s = w.scale_of(idx);
                            for (off, wv) in wrow[jj..jj + run].iter_mut().enumerate() {
                                *wv = lut[w.code_at(idx + off) as usize] * s;
                            }
                            jj += run;
                        }
                    }
                }
                for ii in 0..mr {
                    let xv = x[(row0 + i0 + ii) * d + di];
                    for (a, &wv) in acc[ii][..nb].iter_mut().zip(&wrow[..nb]) {
                        *a += xv * wv;
                    }
                }
            }
            for ii in 0..mr {
                out[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nb].copy_from_slice(&acc[ii][..nb]);
            }
            j0 += nb;
        }
        i0 += mr;
    }
}

simd_kernel!(
    fn matmul_packed_tile(
        tier,
        x: &[f32],
        w: &PackedWeights,
        pre: Option<&[f32]>,
        out: &mut [f32],
        row0: usize,
        d: usize,
        n: usize,
    ) = matmul_packed_tile_body
);

/// `y[M,N] = x[M,D] @ dequant(w)[D,N]` with `w` in packed form —
/// bit-identical to [`matmul`] on the decoded tensor (decode
/// canonicalizes `-0.0` to `+0.0`, which cannot move a `+0.0`-seeded
/// accumulator). Per-tensor-scaled weights get a prescaled level table
/// computed once per call (`lut[c] * s` is the same multiply the
/// per-element path performs, just hoisted).
fn matmul_packed(
    x: &[f32],
    w: &PackedWeights,
    y: &mut [f32],
    m: usize,
    d: usize,
    n: usize,
    pool: &Pool,
) {
    if m == 0 || n == 0 {
        return;
    }
    assert_eq!(w.len(), d * n, "packed weight length mismatch");
    let tier = active_tier();
    let pre: Option<Vec<f32>> = (w.block_size() == 0)
        .then(|| w.lut().iter().map(|&lev| lev * w.scales()[0]).collect());
    pool.for_chunks_mut(y, &row_ranges(m, n), m * d * n, |_, r, out| {
        matmul_packed_tile(tier, x, w, pre.as_deref(), out, r.start / n, d, n);
    });
}

/// `dx[M,D] += dy[M,N] @ w[D,N]^T`, row-parallel. Each (row, di)
/// element is a lane-unrolled dot of two contiguous rows
/// ([`dot_lanes_tier`], tier hoisted out of the loops); `w` rows walk
/// the outer loop so one `w` row is reused across every row of the
/// chunk. Accumulates — the caller zeroes `dx` before the first
/// contribution.
fn matmul_dx(dy: &[f32], w: &[f32], dx: &mut [f32], m: usize, d: usize, n: usize, pool: &Pool) {
    if m == 0 || d == 0 {
        return;
    }
    let tier = active_tier();
    pool.for_chunks_mut(dx, &row_ranges(m, d), m * d * n, |_, r, out| {
        let row0 = r.start / d;
        let rows = out.len() / d;
        for di in 0..d {
            let wrow = &w[di * n..(di + 1) * n];
            for i in 0..rows {
                let dyrow = &dy[(row0 + i) * n..(row0 + i + 1) * n];
                out[i * d + di] += dot_lanes_tier(tier, dyrow, wrow);
            }
        }
    });
}

thread_local! {
    /// Per-worker packed `x^T` stripe for [`matmul_dw`]
    /// (`rows-per-chunk * M` floats). Pool workers are persistent
    /// (`util::pool`), so each thread allocates this once and reuses
    /// it across every train step of the run.
    static XPACK: RefCell<Vec<f32>> = RefCell::new(Vec::new());
}

/// The per-chunk tile loop of [`matmul_dw`] over a pre-packed `x^T`
/// stripe (`xt[ii * m + mi] = x[mi, drow0 + ii]`). Shared body for the
/// [`simd_kernel!`] tier clones — same fold order at every tier.
#[inline(always)]
fn matmul_dw_tile_body(xt: &[f32], dy: &[f32], out: &mut [f32], m: usize, n: usize) {
    let drows = out.len() / n;
    let mut i0 = 0;
    while i0 < drows {
        let mr = TILE_M.min(drows - i0);
        let mut j0 = 0;
        while j0 < n {
            let nb = TILE_N.min(n - j0);
            let mut acc = [[0.0f32; TILE_N]; TILE_M];
            if mr == TILE_M && nb == TILE_N {
                for mi in 0..m {
                    let dyt: &[f32; TILE_N] =
                        dy[mi * n + j0..mi * n + j0 + TILE_N].try_into().unwrap();
                    for ii in 0..TILE_M {
                        let xv = xt[(i0 + ii) * m + mi];
                        for (a, &dv) in acc[ii].iter_mut().zip(dyt) {
                            *a += xv * dv;
                        }
                    }
                }
            } else {
                for mi in 0..m {
                    let dyt = &dy[mi * n + j0..mi * n + j0 + nb];
                    for ii in 0..mr {
                        let xv = xt[(i0 + ii) * m + mi];
                        for (a, &dv) in acc[ii][..nb].iter_mut().zip(dyt) {
                            *a += xv * dv;
                        }
                    }
                }
            }
            for ii in 0..mr {
                out[(i0 + ii) * n + j0..(i0 + ii) * n + j0 + nb].copy_from_slice(&acc[ii][..nb]);
            }
            j0 += nb;
        }
        i0 += mr;
    }
}

simd_kernel!(
    fn matmul_dw_tile(tier, xt: &[f32], dy: &[f32], out: &mut [f32], m: usize, n: usize) =
        matmul_dw_tile_body
);

/// `dw[D,N] = x[M,D]^T @ dy[M,N]`, parallel over rows of `dw`: each
/// worker owns a row range and folds the M data rows itself in fixed
/// ascending order, so the result is bit-identical at any thread
/// count (and to the pre-blocked kernel — the per-element order is
/// unchanged). The worker packs its `x^T` stripe into a thread-local
/// buffer once, then accumulates register tiles with contiguous loads
/// from both operands.
fn matmul_dw(x: &[f32], dy: &[f32], dw: &mut [f32], m: usize, d: usize, n: usize, pool: &Pool) {
    if d == 0 || n == 0 {
        return;
    }
    let tier = active_tier();
    pool.for_chunks_mut(dw, &row_ranges(d, n), m * d * n, |_, r, out| {
        let drow0 = r.start / n;
        let drows = out.len() / n;
        XPACK.with(|buf| {
            let mut xt = buf.borrow_mut();
            xt.resize(drows * m, 0.0);
            let xt = &mut xt[..drows * m];
            for mi in 0..m {
                let xrow = &x[mi * d + drow0..mi * d + drow0 + drows];
                for (ii, &xv) in xrow.iter().enumerate() {
                    xt[ii * m + mi] = xv;
                }
            }
            matmul_dw_tile(tier, xt, dy, out, m, n);
        });
    });
}

/// Per-row inverse RMS: `r[mi] = 1/sqrt(mean(x[mi]^2) + 1e-6)`.
fn rms_r(x: &[f32], r_out: &mut [f32], d: usize, pool: &Pool) {
    let m = r_out.len();
    pool.for_chunks_mut(r_out, &chunk_ranges(m, ROWS_PER_TASK), m * d, |_, r, out| {
        for (i, rv) in out.iter_mut().enumerate() {
            let row = &x[(r.start + i) * d..(r.start + i + 1) * d];
            let mut ss = 0.0f32;
            for &v in row {
                ss += v * v;
            }
            *rv = 1.0 / (ss / d as f32 + 1e-6).sqrt();
        }
    });
}

/// `y[mi, j] = x[mi, j] * g[j] * r[mi]`.
fn rmsnorm_apply(x: &[f32], g: &[f32], r: &[f32], y: &mut [f32], d: usize, pool: &Pool) {
    let m = r.len();
    pool.for_chunks_mut(y, &row_ranges(m, d), m * d, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, yrow) in out.chunks_mut(d).enumerate() {
            let mi = row0 + i;
            let rv = r[mi];
            let xrow = &x[mi * d..(mi + 1) * d];
            for j in 0..d {
                yrow[j] = xrow[j] * g[j] * rv;
            }
        }
    });
}

/// RMSNorm input gradient, accumulated into `dx`:
/// `dx_j += r g_j dy_j - r^3 x_j <dy, g ∘ x> / d`.
fn rmsnorm_bwd_dx(
    x: &[f32],
    g: &[f32],
    r: &[f32],
    dy: &[f32],
    dx: &mut [f32],
    d: usize,
    pool: &Pool,
) {
    let m = r.len();
    pool.for_chunks_mut(dx, &row_ranges(m, d), m * d, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, dxrow) in out.chunks_mut(d).enumerate() {
            let mi = row0 + i;
            let rv = r[mi];
            let xrow = &x[mi * d..(mi + 1) * d];
            let dyrow = &dy[mi * d..(mi + 1) * d];
            let mut dot = 0.0f32;
            for j in 0..d {
                dot += dyrow[j] * g[j] * xrow[j];
            }
            let c = rv * rv * rv * dot / d as f32;
            for j in 0..d {
                dxrow[j] += rv * g[j] * dyrow[j] - c * xrow[j];
            }
        }
    });
}

/// RMSNorm gain gradient (overwrites): `dg_j = sum_m dy[m,j] x[m,j] r[m]`
/// — column-parallel, each column folds the rows serially.
fn rmsnorm_bwd_dg(x: &[f32], r: &[f32], dy: &[f32], dg: &mut [f32], d: usize, pool: &Pool) {
    let m = r.len();
    pool.for_chunks_mut(dg, &chunk_ranges(d, 64), m * d, |_, rr, out| {
        for (jo, o) in out.iter_mut().enumerate() {
            let j = rr.start + jo;
            let mut acc = 0.0f32;
            for mi in 0..m {
                acc += dy[mi * d + j] * x[mi * d + j] * r[mi];
            }
            *o = acc;
        }
    });
}

/// Rotary embeddings in place over `[B, T, H*Hd]` rows. `sign = 1.0`
/// rotates forward; `sign = -1.0` applies the transpose (backward).
#[allow(clippy::too_many_arguments)]
fn rope_apply(
    x: &mut [f32],
    cos: &[f32],
    sin: &[f32],
    b: usize,
    t: usize,
    nh: usize,
    hd: usize,
    sign: f32,
    pool: &Pool,
) {
    let half = hd / 2;
    let width = nh * hd;
    pool.for_chunks_mut(x, &row_ranges(b * t, width), b * t * width, |_, rr, out| {
        let row0 = rr.start / width;
        for (i, row) in out.chunks_mut(width).enumerate() {
            let ti = (row0 + i) % t;
            let c = &cos[ti * half..(ti + 1) * half];
            let sn = &sin[ti * half..(ti + 1) * half];
            for head in 0..nh {
                let hrow = &mut row[head * hd..(head + 1) * hd];
                for j in 0..half {
                    let (x1, x2) = (hrow[j], hrow[half + j]);
                    let sj = sign * sn[j];
                    hrow[j] = x1 * c[j] - x2 * sj;
                    hrow[half + j] = x1 * sj + x2 * c[j];
                }
            }
        }
    });
}

/// Causal softmax probabilities `p[B,H,T,T]` from rotated q/k —
/// parallel per (batch, head) block.
#[allow(clippy::too_many_arguments)]
fn attn_probs(
    q: &[f32],
    k: &[f32],
    p: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    let tier = active_tier();
    pool.for_chunks_mut(p, &head_ranges(b * nh, t * t), b * nh * t * t * hd, |bh, _, blk| {
        let (bi, hi) = (bh / nh, bh % nh);
        for ti in 0..t {
            let qrow = &q[(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + hi * hd + hd];
            let prow = &mut blk[ti * t..(ti + 1) * t];
            let mut mx = f32::NEG_INFINITY;
            for si in 0..=ti {
                let krow = &k[(bi * t + si) * d + hi * hd..(bi * t + si) * d + hi * hd + hd];
                let sc = dot_lanes_tier(tier, qrow, krow) * scale;
                prow[si] = sc;
                if sc > mx {
                    mx = sc;
                }
            }
            let mut z = 0.0f32;
            for si in 0..=ti {
                let e = (prow[si] - mx).exp();
                prow[si] = e;
                z += e;
            }
            let inv = 1.0 / z;
            for si in 0..=ti {
                prow[si] *= inv;
            }
            for si in ti + 1..t {
                prow[si] = 0.0;
            }
        }
    });
}

/// `o[B,T,D] = P · V`, row-parallel over output rows.
#[allow(clippy::too_many_arguments)]
fn attn_mix(
    p: &[f32],
    v: &[f32],
    o: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    pool.for_chunks_mut(o, &row_ranges(b * t, d), b * nh * t * t * hd, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, orow) in out.chunks_mut(d).enumerate() {
            let (bi, ti) = ((row0 + i) / t, (row0 + i) % t);
            orow.fill(0.0);
            for hi in 0..nh {
                let osub = &mut orow[hi * hd..(hi + 1) * hd];
                for si in 0..=ti {
                    let w = p[((bi * nh + hi) * t + ti) * t + si];
                    let vrow = &v[(bi * t + si) * d + hi * hd..(bi * t + si) * d + hi * hd + hd];
                    for (ov, &vv) in osub.iter_mut().zip(vrow) {
                        *ov += w * vv;
                    }
                }
            }
        }
    });
}

/// `dv[b,s,h] = sum_{t>=s} p[b,h,t,s] * do[b,t,h]` (overwrites).
#[allow(clippy::too_many_arguments)]
fn attn_bwd_dv(
    p: &[f32],
    dout: &[f32],
    dv: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    pool.for_chunks_mut(dv, &row_ranges(b * t, d), b * nh * t * t * hd, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, dvrow) in out.chunks_mut(d).enumerate() {
            let (bi, si) = ((row0 + i) / t, (row0 + i) % t);
            dvrow.fill(0.0);
            for hi in 0..nh {
                let dsub = &mut dvrow[hi * hd..(hi + 1) * hd];
                for ti in si..t {
                    let w = p[((bi * nh + hi) * t + ti) * t + si];
                    let dorow =
                        &dout[(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + hi * hd + hd];
                    for (o, &x) in dsub.iter_mut().zip(dorow) {
                        *o += w * x;
                    }
                }
            }
        }
    });
}

/// Softmax backward into score-gradients `ds[B,H,T,T]` (overwrites):
/// `dp = do · v^T`, then `ds = p ∘ (dp - rowsum(dp ∘ p))`.
#[allow(clippy::too_many_arguments)]
fn attn_bwd_ds(
    p: &[f32],
    dout: &[f32],
    v: &[f32],
    ds: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    let tier = active_tier();
    pool.for_chunks_mut(ds, &head_ranges(b * nh, t * t), b * nh * t * t * hd, |bh, _, blk| {
        let (bi, hi) = (bh / nh, bh % nh);
        let pblk = &p[bh * t * t..(bh + 1) * t * t];
        for ti in 0..t {
            let dorow = &dout[(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + hi * hd + hd];
            let dsrow = &mut blk[ti * t..(ti + 1) * t];
            let prow = &pblk[ti * t..(ti + 1) * t];
            for si in 0..=ti {
                let vrow = &v[(bi * t + si) * d + hi * hd..(bi * t + si) * d + hi * hd + hd];
                dsrow[si] = dot_lanes_tier(tier, dorow, vrow);
            }
            let mut rd = 0.0f32;
            for si in 0..=ti {
                rd += dsrow[si] * prow[si];
            }
            for si in 0..=ti {
                dsrow[si] = prow[si] * (dsrow[si] - rd);
            }
            for si in ti + 1..t {
                dsrow[si] = 0.0;
            }
        }
    });
}

/// `dq[b,t,h] = scale * sum_{s<=t} ds[b,h,t,s] * k[b,s,h]` (overwrites).
#[allow(clippy::too_many_arguments)]
fn attn_bwd_dq(
    ds: &[f32],
    k: &[f32],
    dq: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    pool.for_chunks_mut(dq, &row_ranges(b * t, d), b * nh * t * t * hd, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, dqrow) in out.chunks_mut(d).enumerate() {
            let (bi, ti) = ((row0 + i) / t, (row0 + i) % t);
            dqrow.fill(0.0);
            for hi in 0..nh {
                let dsub = &mut dqrow[hi * hd..(hi + 1) * hd];
                for si in 0..=ti {
                    let w = ds[((bi * nh + hi) * t + ti) * t + si] * scale;
                    let krow = &k[(bi * t + si) * d + hi * hd..(bi * t + si) * d + hi * hd + hd];
                    for (o, &x) in dsub.iter_mut().zip(krow) {
                        *o += w * x;
                    }
                }
            }
        }
    });
}

/// `dk[b,s,h] = scale * sum_{t>=s} ds[b,h,t,s] * q[b,t,h]` (overwrites).
#[allow(clippy::too_many_arguments)]
fn attn_bwd_dk(
    ds: &[f32],
    q: &[f32],
    dk: &mut [f32],
    b: usize,
    nh: usize,
    t: usize,
    hd: usize,
    pool: &Pool,
) {
    let d = nh * hd;
    let scale = 1.0 / (hd as f32).sqrt();
    pool.for_chunks_mut(dk, &row_ranges(b * t, d), b * nh * t * t * hd, |_, rr, out| {
        let row0 = rr.start / d;
        for (i, dkrow) in out.chunks_mut(d).enumerate() {
            let (bi, si) = ((row0 + i) / t, (row0 + i) % t);
            dkrow.fill(0.0);
            for hi in 0..nh {
                let dsub = &mut dkrow[hi * hd..(hi + 1) * hd];
                for ti in si..t {
                    let w = ds[((bi * nh + hi) * t + ti) * t + si] * scale;
                    let qrow = &q[(bi * t + ti) * d + hi * hd..(bi * t + ti) * d + hi * hd + hd];
                    for (o, &x) in dsub.iter_mut().zip(qrow) {
                        *o += w * x;
                    }
                }
            }
        }
    });
}

/// `gu = silu(gpre) ∘ u`, elementwise.
fn swiglu_fwd(gpre: &[f32], u: &[f32], gu: &mut [f32], pool: &Pool) {
    let n = gu.len();
    pool.for_chunks_mut(gu, &chunk_ranges(n, PAR_CHUNK), n, |_, r, out| {
        for (i, o) in out.iter_mut().enumerate() {
            let g = gpre[r.start + i];
            let s = 1.0 / (1.0 + (-g).exp());
            *o = g * s * u[r.start + i];
        }
    });
}

/// Backward through `gu = silu(gpre) ∘ u` (overwrites both outputs).
fn swiglu_bwd(
    gpre: &[f32],
    u: &[f32],
    dgu: &[f32],
    dgpre: &mut [f32],
    du: &mut [f32],
    pool: &Pool,
) {
    let n = dgu.len();
    pool.for_chunks_mut(dgpre, &chunk_ranges(n, PAR_CHUNK), n, |_, r, out| {
        for (i, o) in out.iter_mut().enumerate() {
            let g = gpre[r.start + i];
            let s = 1.0 / (1.0 + (-g).exp());
            // d(silu)/dg = s * (1 + g * (1 - s))
            *o = dgu[r.start + i] * u[r.start + i] * s * (1.0 + g * (1.0 - s));
        }
    });
    pool.for_chunks_mut(du, &chunk_ranges(n, PAR_CHUNK), n, |_, r, out| {
        for (i, o) in out.iter_mut().enumerate() {
            let g = gpre[r.start + i];
            let s = 1.0 / (1.0 + (-g).exp());
            *o = dgu[r.start + i] * g * s;
        }
    });
}

/// `out = a + b`, elementwise.
fn add_rows(a: &[f32], b: &[f32], out: &mut [f32], pool: &Pool) {
    let n = out.len();
    pool.for_chunks_mut(out, &chunk_ranges(n, PAR_CHUNK), n, |_, r, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = a[r.start + i] + b[r.start + i];
        }
    });
}

/// Mean next-token cross-entropy + logit gradients (overwrites
/// `dlogits` with `(softmax - onehot)/M`). Loss partials fold in
/// chunk-index order.
fn xent_loss_grad(
    logits: &[f32],
    tgt: &[usize],
    dlogits: &mut [f32],
    v: usize,
    pool: &Pool,
) -> f64 {
    let m = tgt.len();
    if m == 0 {
        // no rows: zero loss, nothing to fill (0/0 would be NaN below)
        return 0.0;
    }
    let inv_m = 1.0 / m as f32;
    let parts = pool.for_chunks_mut(dlogits, &row_ranges(m, v), m * v, |_, rr, out| {
        let row0 = rr.start / v;
        let mut lsum = 0.0f64;
        for (i, drow) in out.chunks_mut(v).enumerate() {
            let mi = row0 + i;
            let lrow = &logits[mi * v..(mi + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &x in lrow {
                if x > mx {
                    mx = x;
                }
            }
            let mut z = 0.0f32;
            for j in 0..v {
                let e = (lrow[j] - mx).exp();
                drow[j] = e;
                z += e;
            }
            let logz = mx + z.ln();
            lsum += (logz - lrow[tgt[mi]]) as f64;
            let sc = inv_m / z;
            for j in 0..v {
                drow[j] *= sc;
            }
            drow[tgt[mi]] -= inv_m;
        }
        lsum
    });
    parts.iter().sum::<f64>() / m as f64
}

/// Forward-only mean cross-entropy (eval path): per-chunk partial sums
/// fold in chunk order, parallel above [`PAR_MIN`] work.
fn xent_loss(logits: &[f32], tgt: &[usize], v: usize, pool: &Pool) -> f64 {
    let m = tgt.len();
    if m == 0 {
        return 0.0;
    }
    let part = |r: Range<usize>| -> f64 {
        let mut lsum = 0.0f64;
        for mi in r {
            let lrow = &logits[mi * v..(mi + 1) * v];
            let mut mx = f32::NEG_INFINITY;
            for &x in lrow {
                if x > mx {
                    mx = x;
                }
            }
            let mut z = 0.0f32;
            for &x in lrow {
                z += (x - mx).exp();
            }
            lsum += (mx + z.ln() - lrow[tgt[mi]]) as f64;
        }
        lsum
    };
    let ranges = chunk_ranges(m, ROWS_PER_TASK);
    let parts: Vec<f64> = if m * v < PAR_MIN || pool.threads() == 1 {
        ranges.into_iter().map(part).collect()
    } else {
        pool.run(ranges, |_, r| part(r))
    };
    parts.iter().sum::<f64>() / m as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::native::program::StepStreams;

    fn micro() -> LmProgram {
        LmProgram::new(
            "lm-fd",
            LmConfig { vocab: 11, d_model: 8, n_layers: 1, n_heads: 2, seq_len: 4 },
            2,
            1,
        )
        .unwrap()
    }

    fn hash_params(prog: &LmProgram, seed: u64) -> Vec<Vec<f32>> {
        // arbitrary but deterministic non-degenerate weights
        let mut rng = Rng::new(seed);
        prog.init(&mut rng)
            .into_iter()
            .map(|mut wv| {
                for (i, x) in wv.iter_mut().enumerate() {
                    // perturb norms too so their gradients are exercised
                    *x += 0.01 * ((i % 13) as f32 - 6.0) / 6.0;
                }
                wv
            })
            .collect()
    }

    fn tokens_for(prog: &LmProgram, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..prog.batch * (prog.cfg.seq_len + 1))
            .map(|_| rng.below(prog.cfg.vocab as u64) as i32)
            .collect()
    }

    fn loss_at(prog: &LmProgram, params: &[Vec<f32>], tokens: &[i32]) -> f64 {
        let mut s = LmScratch::alloc(&prog.cfg, prog.batch);
        let refs: Vec<WRef<'_>> = params.iter().map(|w| WRef::Dense(w)).collect();
        prog.batch_loss(&refs, tokens, &mut s, &Pool::serial()).unwrap()
    }

    /// The manual backward must match central finite differences of the
    /// forward loss on every parameter tensor.
    #[test]
    fn grads_match_finite_differences() {
        let prog = micro();
        let params = hash_params(&prog, 5);
        let tokens = tokens_for(&prog, 7);
        let pool = Pool::serial();
        let statics: Vec<(String, Vec<f32>)> = vec![];
        let ctx = StepCtx {
            statics: &statics,
            data: Some(&tokens),
            streams: StepStreams { data: 0, round: 0 },
            pool: &pool,
        };
        let mut scratch = prog.make_scratch();
        let mut grads: Vec<Vec<f32>> = params.iter().map(|p| vec![0.0; p.len()]).collect();
        let base = prog.loss_grad(&params, &ctx, scratch.as_mut(), &mut grads).unwrap();
        assert!(base.is_finite() && base > 0.0);

        let eps = 1e-3f32;
        for (pi, p) in params.iter().enumerate() {
            let stride = (p.len() / 13).max(1);
            for idx in (0..p.len()).step_by(stride) {
                let mut hi = params.clone();
                hi[pi][idx] += eps;
                let mut lo = params.clone();
                lo[pi][idx] -= eps;
                let fd = (loss_at(&prog, &hi, &tokens) - loss_at(&prog, &lo, &tokens))
                    / (2.0 * eps as f64);
                let an = grads[pi][idx] as f64;
                assert!(
                    (fd - an).abs() < 5e-3 + 0.05 * an.abs(),
                    "param {pi} idx {idx}: fd={fd} an={an}"
                );
            }
        }
    }

    #[test]
    fn attn_probs_rows_are_causal_distributions() {
        let (b, nh, t, hd) = (1, 2, 4, 4);
        let d = nh * hd;
        let mut rng = Rng::new(3);
        let mut q = vec![0.0f32; b * t * d];
        let mut k = vec![0.0f32; b * t * d];
        rng.fill_normal(&mut q);
        rng.fill_normal(&mut k);
        let mut p = vec![0.0f32; b * nh * t * t];
        attn_probs(&q, &k, &mut p, b, nh, t, hd, &Pool::serial());
        for bh in 0..b * nh {
            for ti in 0..t {
                let row = &p[(bh * t + ti) * t..(bh * t + ti + 1) * t];
                let sum: f32 = row.iter().sum();
                assert!((sum - 1.0).abs() < 1e-5, "row sums to {sum}");
                for si in ti + 1..t {
                    assert_eq!(row[si], 0.0, "future position leaked");
                }
            }
        }
    }

    #[test]
    fn rope_backward_inverts_forward() {
        let (b, t, nh, hd) = (1, 3, 2, 4);
        let mut rng = Rng::new(9);
        let mut x = vec![0.0f32; b * t * nh * hd];
        rng.fill_normal(&mut x);
        let orig = x.clone();
        let prog = LmProgram::new(
            "rope-test",
            LmConfig { vocab: 4, d_model: nh * hd, n_layers: 1, n_heads: nh, seq_len: t },
            1,
            1,
        )
        .unwrap();
        let s = LmScratch::alloc(&prog.cfg, 1);
        let pool = Pool::serial();
        rope_apply(&mut x, &s.cos, &s.sin, b, t, nh, hd, 1.0, &pool);
        assert_ne!(x, orig);
        rope_apply(&mut x, &s.cos, &s.sin, b, t, nh, hd, -1.0, &pool);
        for (a, o) in x.iter().zip(&orig) {
            assert!((a - o).abs() < 1e-5, "{a} vs {o}");
        }
    }

    #[test]
    fn ffn_dim_matches_python_rounding() {
        let mk = |d| LmConfig { vocab: 256, d_model: d, n_layers: 1, n_heads: 2, seq_len: 8 };
        assert_eq!(mk(64).ffn_dim(), 192);
        assert_eq!(mk(192).ffn_dim(), 512);
        assert_eq!(mk(256).ffn_dim(), 704);
        assert_eq!(mk(768).ffn_dim(), 2048);
        assert_eq!(mk(32).ffn_dim(), 128);
    }

    #[test]
    fn preset_lookup_and_param_order() {
        let p = LmProgram::preset("lm-tiny").unwrap();
        assert_eq!(p.name(), "lm-tiny");
        assert_eq!(p.batch, 8);
        assert_eq!(p.eval_batches(), 4);
        assert_eq!(LmProgram::preset_k("lm-tiny").unwrap(), 4);
        assert!(LmProgram::preset_k("lm-tiny2").is_err());
        let specs = p.param_specs();
        // canonical sorted order end-to-end
        let names: Vec<&str> = specs.iter().map(|s| s.name.as_str()).collect();
        let mut sorted = names.clone();
        sorted.sort();
        assert_eq!(names, sorted);
        assert_eq!(names.first(), Some(&"embed"));
        assert_eq!(names.last(), Some(&"norm_final"));
        // the closed-form param_count matches the actual spec layout
        let total: usize = specs.iter().map(|s| s.elements()).sum();
        assert_eq!(total, p.cfg.param_count());
        // quantized set: 7 matmul weights per layer + lm_head
        assert_eq!(p.quantized().len(), 7 * 2 + 1);
        assert!(!p.quantized().iter().any(|n| n.contains("norm") || n == "embed"));

        let err = LmProgram::preset("lm-never").unwrap_err().to_string();
        assert!(err.contains("lm-tiny") && err.contains("lm-300m-sim"), "{err}");
    }

    #[test]
    fn loss_is_near_uniform_at_tiny_weights() {
        // with ~zero weights the logits are ~uniform: loss ~= ln(vocab)
        let prog = micro();
        let mut rng = Rng::new(1);
        let params = prog.init(&mut rng);
        let tokens = tokens_for(&prog, 2);
        let loss = loss_at(&prog, &params, &tokens);
        let uniform = (prog.cfg.vocab as f64).ln();
        assert!((loss - uniform).abs() < 0.2, "loss={loss} uniform={uniform}");
    }

    #[test]
    fn val_loss_averages_batches() {
        let prog = micro();
        let mut rng = Rng::new(4);
        let params = prog.init(&mut rng);
        let blen = prog.batch * (prog.cfg.seq_len + 1);
        let t1 = tokens_for(&prog, 11);
        let t2 = tokens_for(&prog, 12);
        let mut both = t1.clone();
        both.extend_from_slice(&t2);
        assert_eq!(both.len(), 2 * blen);
        let pool = Pool::serial();
        let ctx1 = EvalCtx { statics: &[], data: Some(&t1), pool: &pool };
        let ctx2 = EvalCtx { statics: &[], data: Some(&t2), pool: &pool };
        let ctxb = EvalCtx { statics: &[], data: Some(&both), pool: &pool };
        let mut scratch = prog.make_scratch();
        let l1 = prog.val_loss(&params, &ctx1, scratch.as_mut()).unwrap();
        let l2 = prog.val_loss(&params, &ctx2, scratch.as_mut()).unwrap();
        let lb = prog.val_loss(&params, &ctxb, scratch.as_mut()).unwrap();
        assert!((lb - 0.5 * (l1 + l2)).abs() < 1e-9);
    }

    // -- blocked-kernel reference checks ------------------------------------

    fn naive_matmul(x: &[f32], w: &[f32], m: usize, d: usize, n: usize) -> Vec<f32> {
        let mut y = vec![0.0f32; m * n];
        for i in 0..m {
            for di in 0..d {
                let xv = x[i * d + di];
                for j in 0..n {
                    y[i * n + j] += xv * w[di * n + j];
                }
            }
        }
        y
    }

    fn naive_dx(dy: &[f32], w: &[f32], m: usize, d: usize, n: usize) -> Vec<f32> {
        let mut dx = vec![0.0f32; m * d];
        for i in 0..m {
            for di in 0..d {
                let mut acc = 0.0f64;
                for j in 0..n {
                    acc += (dy[i * n + j] as f64) * (w[di * n + j] as f64);
                }
                dx[i * d + di] = acc as f32;
            }
        }
        dx
    }

    fn naive_dw(x: &[f32], dy: &[f32], m: usize, d: usize, n: usize) -> Vec<f32> {
        let mut dw = vec![0.0f32; d * n];
        for mi in 0..m {
            for di in 0..d {
                let xv = x[mi * d + di];
                for j in 0..n {
                    dw[di * n + j] += xv * dy[mi * n + j];
                }
            }
        }
        dw
    }

    fn filled(len: usize, seed: u64) -> Vec<f32> {
        let mut v = vec![0.0f32; len];
        Rng::new(seed).fill_normal(&mut v);
        v
    }

    /// The register-blocked kernels must match a naive triple loop on
    /// shapes that exercise full tiles, edge tiles in both dimensions,
    /// and sub-tile problems — at multiple thread counts.
    #[test]
    fn blocked_matmuls_match_naive_reference() {
        let shapes: [(usize, usize, usize); 8] = [
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 16), // exact register tiles
            (8, 16, 32),
            (9, 17, 33), // edge tiles both dims
            (5, 3, 16), // full col tile, partial row tile
            (16, 1, 15), // depth-1, partial col tile
            (2, 40, 70),
        ];
        for pool in [Pool::serial(), Pool::new(3)] {
            for (m, d, n) in shapes {
                let x = filled(m * d, 1);
                let w = filled(d * n, 2);
                let dy = filled(m * n, 3);

                let mut y = vec![0.0f32; m * n];
                matmul(&x, &w, &mut y, m, d, n, &pool);
                // identical per-element fold order: exact match
                assert_eq!(y, naive_matmul(&x, &w, m, d, n), "matmul {m}x{d}x{n}");

                let mut dx = filled(m * d, 4); // accumulates on top
                let base = dx.clone();
                matmul_dx(&dy, &w, &mut dx, m, d, n, &pool);
                let want = naive_dx(&dy, &w, m, d, n);
                for i in 0..m * d {
                    let got = dx[i] - base[i];
                    assert!(
                        (got - want[i]).abs() < 1e-4 * (1.0 + want[i].abs()),
                        "dx {m}x{d}x{n} [{i}]: {got} vs {}",
                        want[i]
                    );
                }

                let mut dw = filled(d * n, 5); // overwritten
                matmul_dw(&x, &dy, &mut dw, m, d, n, &pool);
                assert_eq!(dw, naive_dw(&x, &dy, m, d, n), "dw {m}x{d}x{n}");
            }
        }
    }

    /// Degenerate shapes (ISSUE 4): zero rows/cols/depth must neither
    /// panic (`chunks_mut(0)`) nor divide by zero, and `m == 0` loss
    /// folds return 0 instead of NaN.
    #[test]
    fn degenerate_shapes_are_safe() {
        let pool = Pool::new(2);
        for (m, d, n) in [(0, 4, 4), (4, 0, 4), (4, 4, 0), (0, 0, 0)] {
            let x = filled(m * d, 1);
            let w = filled(d * n, 2);
            let dy = filled(m * n, 3);
            let mut y = vec![7.0f32; m * n];
            matmul(&x, &w, &mut y, m, d, n, &pool);
            if d == 0 {
                // no depth: a matmul over an empty sum is all zeros
                assert!(y.iter().all(|&v| v == 0.0));
            }
            let mut dx = vec![0.0f32; m * d];
            matmul_dx(&dy, &w, &mut dx, m, d, n, &pool);
            if n == 0 {
                assert!(dx.iter().all(|&v| v == 0.0));
            }
            let mut dw = vec![7.0f32; d * n];
            matmul_dw(&x, &dy, &mut dw, m, d, n, &pool);
            if m == 0 {
                // zero data rows must still overwrite dw with zeros
                assert!(dw.iter().all(|&v| v == 0.0));
            }
        }
        // empty-row loss folds: 0, not 0/0 = NaN
        let mut dlogits = vec![0.0f32; 0];
        assert_eq!(xent_loss_grad(&[], &[], &mut dlogits, 11, &pool), 0.0);
        assert_eq!(xent_loss(&[], &[], 11, &pool), 0.0);
        // and the underlying partition helper yields no ranges at n=0
        assert!(chunk_ranges(0, ROWS_PER_TASK).is_empty());
        assert!(row_ranges(0, 5).is_empty());
    }

    /// Every supported SIMD tier runs the matmul tile kernels bitwise
    /// identically to the scalar reference, on shapes exercising full
    /// tiles, edge tiles in both dimensions, and remainder dot lanes.
    #[test]
    fn matmul_kernels_are_tier_invariant() {
        use crate::quant::QuantFormat;
        use crate::util::simd::{supported_tiers, SimdTier};
        let pool = Pool::serial();
        for (m, d, n) in [(1, 1, 1), (4, 8, 16), (9, 17, 33), (5, 3, 16), (2, 40, 70)] {
            let x = filled(m * d, 21);
            let w = filled(d * n, 22);
            let dy = filled(m * n, 23);
            let xt = filled(d * m, 24); // pre-packed stripe for the dw tile

            let mut y0 = vec![0.0f32; m * n];
            matmul_tile(SimdTier::Scalar, &x, &w, &mut y0, 0, d, n);
            let mut dw0 = vec![0.0f32; d * n];
            matmul_dw_tile(SimdTier::Scalar, &xt, &dy, &mut dw0, m, n);
            for tier in supported_tiers() {
                let mut y = vec![0.0f32; m * n];
                matmul_tile(tier, &x, &w, &mut y, 0, d, n);
                assert_eq!(y, y0, "matmul_tile {tier:?} {m}x{d}x{n}");
                let mut dw = vec![0.0f32; d * n];
                matmul_dw_tile(tier, &xt, &dy, &mut dw, m, n);
                assert_eq!(dw, dw0, "matmul_dw_tile {tier:?} {m}x{d}x{n}");
            }

            // packed tile parity across tiers (and vs the dense tile on
            // the decoded tensor, bitwise)
            let fmt = QuantFormat::parse("int4", 16).unwrap();
            let packed = PackedWeights::pack_rtn(&w, &fmt);
            let mut wq = vec![0.0f32; d * n];
            packed.decode_into(&mut wq);
            let mut yq0 = vec![0.0f32; m * n];
            matmul(&x, &wq, &mut yq0, m, d, n, &pool);
            for tier in supported_tiers() {
                let mut yq = vec![0.0f32; m * n];
                matmul_packed_tile(tier, &x, &packed, None, &mut yq, 0, d, n);
                assert_eq!(yq, yq0, "matmul_packed_tile {tier:?} {m}x{d}x{n}");
            }
        }
    }

    /// The fused dequant matmul contract: pack → fused matmul equals
    /// cast_rtn → dense matmul, bitwise, for every format and both
    /// block granularities (the `-0.0` decode canonicalization cannot
    /// move a `+0.0`-seeded accumulator).
    #[test]
    fn packed_matmul_matches_dense_cast_bitwise() {
        use crate::quant::{cast_rtn, QuantFormat};
        let (m, d, n) = (9, 17, 33); // edge tiles in both dims
        let x = filled(m * d, 31);
        let w = filled(d * n, 32);
        for name in ["int4", "int8", "fp4"] {
            for block in [0usize, 64] {
                let fmt = QuantFormat::parse(name, block).unwrap();
                let packed = PackedWeights::pack_rtn(&w, &fmt);
                let mut wq = w.clone();
                cast_rtn(&mut wq, &fmt);
                for pool in [Pool::serial(), Pool::new(3)] {
                    let mut dense = vec![0.0f32; m * n];
                    matmul(&x, &wq, &mut dense, m, d, n, &pool);
                    let mut fused = vec![0.0f32; m * n];
                    matmul_packed(&x, &packed, &mut fused, m, d, n, &pool);
                    for i in 0..m * n {
                        assert_eq!(
                            fused[i].to_bits(),
                            dense[i].to_bits(),
                            "{name} block={block} [{i}]: fused {} vs dense {}",
                            fused[i],
                            dense[i]
                        );
                    }
                }
            }
        }
    }

    /// Routing a forward pass through packed weight refs gives the
    /// exact loss of the equivalent dense cast. (The no-dense-decode
    /// guarantee is asserted in `tests/simd_dispatch.rs`, where the
    /// process-global decode counter can be read without racing other
    /// unit tests.)
    #[test]
    fn packed_forward_matches_dense_cast_forward() {
        use crate::quant::{cast_rtn, QuantFormat};
        let prog = micro();
        let params = hash_params(&prog, 6);
        let tokens = tokens_for(&prog, 8);
        let fmt = QuantFormat::parse("int4", 8).unwrap();
        let quantized = prog.quantized();
        let specs = prog.param_specs();

        // dense path: cast the quantized tensors to f32
        let mut cast_params = params.clone();
        for (i, spec) in specs.iter().enumerate() {
            if quantized.contains(&spec.name) {
                cast_rtn(&mut cast_params[i], &fmt);
            }
        }
        let dense_loss = loss_at(&prog, &cast_params, &tokens);

        // packed path: same tensors in packed form, fused matmuls
        let packs: Vec<Option<PackedWeights>> = specs
            .iter()
            .enumerate()
            .map(|(i, spec)| {
                quantized
                    .contains(&spec.name)
                    .then(|| PackedWeights::pack_rtn(&params[i], &fmt))
            })
            .collect();
        let refs: Vec<WRef<'_>> = packs
            .iter()
            .zip(&params)
            .map(|(p, w)| match p {
                Some(p) => WRef::Packed(p),
                None => WRef::Dense(w),
            })
            .collect();
        let mut s = LmScratch::alloc(&prog.cfg, prog.batch);
        let packed_loss = prog.batch_loss(&refs, &tokens, &mut s, &Pool::serial()).unwrap();
        assert_eq!(packed_loss.to_bits(), dense_loss.to_bits());
    }

    /// Thread-count invariance of the blocked kernels at a size that
    /// engages the parallel dispatch (`m*d*n` above `PAR_MIN`).
    #[test]
    fn blocked_matmuls_are_thread_count_invariant() {
        let (m, d, n) = (64, 48, 33); // 101k work, odd col edge
        let x = filled(m * d, 11);
        let w = filled(d * n, 12);
        let dy = filled(m * n, 13);
        let run = |threads: usize| {
            let pool = Pool::new(threads);
            let mut y = vec![0.0f32; m * n];
            matmul(&x, &w, &mut y, m, d, n, &pool);
            let mut dx = vec![0.0f32; m * d];
            matmul_dx(&dy, &w, &mut dx, m, d, n, &pool);
            let mut dw = vec![0.0f32; d * n];
            matmul_dw(&x, &dy, &mut dw, m, d, n, &pool);
            (y, dx, dw)
        };
        let (y1, dx1, dw1) = run(1);
        for threads in [2, 3, 5] {
            let (y, dx, dw) = run(threads);
            assert_eq!(y1, y, "matmul differs at {threads} threads");
            assert_eq!(dx1, dx, "matmul_dx differs at {threads} threads");
            assert_eq!(dw1, dw, "matmul_dw differs at {threads} threads");
        }
    }

    // -- KV-cache decode ----------------------------------------------------

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn decode_prog() -> LmProgram {
        LmProgram::new(
            "lm-dec",
            LmConfig { vocab: 11, d_model: 8, n_layers: 2, n_heads: 2, seq_len: 8 },
            1,
            1,
        )
        .unwrap()
    }

    /// The KV-decode contract: incremental decode logits are bitwise
    /// the full-recompute `forward_logits` row at every position and
    /// every thread count, and prefill at any prefix length agrees.
    #[test]
    fn kv_decode_matches_full_forward_bitwise() {
        let prog = decode_prog();
        let params = hash_params(&prog, 41);
        let tokens = tokens_for(&prog, 42); // [1, T+1]
        let (t, v) = (prog.cfg.seq_len, prog.cfg.vocab);
        for pool in [Pool::serial(), Pool::new(3)] {
            let full = prog.forward_logits(&params, &tokens, &pool).unwrap();
            let refs: Vec<WRef<'_>> = params.iter().map(|w| WRef::Dense(w)).collect();
            let mut st = LmDecodeState::alloc(&prog.cfg);
            let mut got = prog.prefill_refs(&refs, &tokens[..1], &mut st, &pool).unwrap();
            for p in 1..t {
                assert_eq!(bits(&got), bits(&full[(p - 1) * v..p * v]), "pos {}", p - 1);
                got = prog.decode_step_refs(&refs, tokens[p], &mut st, &pool).unwrap();
            }
            assert_eq!(bits(&got), bits(&full[(t - 1) * v..t * v]), "pos {}", t - 1);
            assert_eq!(st.len(), t);
            // fresh full prefill at every prefix length agrees too
            for p in 1..=t {
                let mut st2 = LmDecodeState::alloc(&prog.cfg);
                let lg = prog.prefill_refs(&refs, &tokens[..p], &mut st2, &pool).unwrap();
                assert_eq!(bits(&lg), bits(&full[(p - 1) * v..p * v]), "prefix {p}");
            }
        }
    }

    /// Decode through packed weight refs (the fused GEMV path) is
    /// bitwise the dense host-cast decode, for every format and both
    /// scale granularities — prefill and every incremental step.
    #[test]
    fn kv_decode_packed_matches_dense_cast_bitwise() {
        use crate::quant::{cast_rtn, QuantFormat};
        let prog = decode_prog();
        let params = hash_params(&prog, 43);
        let tokens = tokens_for(&prog, 44);
        let quantized = prog.quantized();
        let specs = prog.param_specs();
        let pool = Pool::new(2);
        for name in ["int4", "int4@4", "int8", "fp4"] {
            let fmt = QuantFormat::parse(name, 0).unwrap();
            let mut cast_params = params.clone();
            for (i, spec) in specs.iter().enumerate() {
                if quantized.contains(&spec.name) {
                    cast_rtn(&mut cast_params[i], &fmt);
                }
            }
            let dense_refs: Vec<WRef<'_>> = cast_params.iter().map(|w| WRef::Dense(w)).collect();
            let packs: Vec<Option<PackedWeights>> = specs
                .iter()
                .enumerate()
                .map(|(i, spec)| {
                    quantized
                        .contains(&spec.name)
                        .then(|| PackedWeights::pack_rtn(&params[i], &fmt))
                })
                .collect();
            let packed_refs: Vec<WRef<'_>> = packs
                .iter()
                .zip(&params)
                .map(|(p, w)| match p {
                    Some(p) => WRef::Packed(p),
                    None => WRef::Dense(w),
                })
                .collect();
            let mut sd = LmDecodeState::alloc(&prog.cfg);
            let mut sp = LmDecodeState::alloc(&prog.cfg);
            let ld = prog.prefill_refs(&dense_refs, &tokens[..3], &mut sd, &pool).unwrap();
            let lp = prog.prefill_refs(&packed_refs, &tokens[..3], &mut sp, &pool).unwrap();
            assert_eq!(bits(&ld), bits(&lp), "{name}: prefill");
            for p in 3..prog.cfg.seq_len {
                let ld = prog.decode_step_refs(&dense_refs, tokens[p], &mut sd, &pool).unwrap();
                let lp = prog.decode_step_refs(&packed_refs, tokens[p], &mut sp, &pool).unwrap();
                assert_eq!(bits(&ld), bits(&lp), "{name}: pos {p}");
            }
        }
    }

    /// Decode state misuse fails loudly instead of corrupting caches.
    #[test]
    fn decode_guards_reject_misuse() {
        let prog = decode_prog();
        let params = hash_params(&prog, 45);
        let tokens = tokens_for(&prog, 46);
        let refs: Vec<WRef<'_>> = params.iter().map(|w| WRef::Dense(w)).collect();
        let pool = Pool::serial();
        let mut st = LmDecodeState::alloc(&prog.cfg);
        // step before prefill
        assert!(prog.decode_step_refs(&refs, 0, &mut st, &pool).is_err());
        // empty and over-long prompts
        assert!(prog.prefill_refs(&refs, &[], &mut st, &pool).is_err());
        let long = vec![0i32; prog.cfg.seq_len + 1];
        assert!(prog.prefill_refs(&refs, &long, &mut st, &pool).is_err());
        // fill the context, then one step past the end fails
        prog.prefill_refs(&refs, &tokens[..prog.cfg.seq_len], &mut st, &pool).unwrap();
        assert!(prog.decode_step_refs(&refs, 0, &mut st, &pool).is_err());
        // out-of-vocab token
        let mut st2 = LmDecodeState::alloc(&prog.cfg);
        prog.prefill_refs(&refs, &tokens[..1], &mut st2, &pool).unwrap();
        let bad = prog.cfg.vocab as i32;
        assert!(prog.decode_step_refs(&refs, bad, &mut st2, &pool).is_err());
    }
}
