//! A typed run handle over one engine: the [`Session`] owns everything
//! a single training run round-trips through the backend — the resolved
//! train/eval entries, the named [`TrainState`], and the static inputs
//! — and packs/unpacks the positional argument lists the AOT calling
//! convention requires (DESIGN.md §2).
//!
//! Before this layer existed, the raw `call(entry, &[Value])`
//! choreography (role-driven argument packing, metric splitting, state
//! adoption) was duplicated across the trainer, the evaluator and the
//! experiments. A `Session` makes "one run on one engine" a first-class
//! object instead of an implicit convention — which is what lets the
//! sweep runner treat "N concurrent runs on N factory-spawned engines"
//! as N independent sessions.
//!
//! A session *borrows* its engine: several sessions may share one
//! engine within a thread (the engine caches per-model scratch across
//! all of them), while cross-thread sharding goes through
//! [`ExecutorFactory`](super::ExecutorFactory)-spawned engines with one
//! session per run.

use super::executor::{value, Executor, Value};
use super::manifest::{ArtifactEntry, Role, TensorSpec};
use super::state::{self, TrainState};
use crate::config::RunConfig;
use crate::tensor::HostTensor;
use anyhow::{anyhow, bail, Result};

/// Per-chunk inputs for [`Session::train_chunk`]: everything that
/// changes call-to-call. State, statics and entries live in the
/// session.
pub struct ChunkInputs {
    /// per-step learning rates for the K scanned steps
    pub lrs: Vec<f32>,
    /// the LOTION regularization weight (paper's lambda)
    pub lam_reg: f32,
    /// per-step estimator-schedule values (σ_t, gradient scale) for
    /// scheduled estimators; `None` when the entry carries no
    /// `est_sched` input (the four legacy methods)
    pub est_sched: Option<Vec<f32>>,
    /// the chunk's PRNG key (drives in-graph sampling + RR rounding)
    pub key: [u32; 2],
    /// the `[K, B, T+1]` token chunk for data-fed programs, `None` for
    /// in-graph sampling
    pub data: Option<Value>,
}

/// Per-step losses reported by one train chunk.
pub struct ChunkOutcome {
    pub bases: Vec<f32>,
    pub totals: Vec<f32>,
}

/// One training run's typed handle on an engine (see module docs).
pub struct Session<'e> {
    engine: &'e dyn Executor,
    train: ArtifactEntry,
    eval: ArtifactEntry,
    /// named params + optimizer state, adopted back after every chunk
    pub state: TrainState,
    statics: Vec<(String, Value)>,
}

impl<'e> Session<'e> {
    /// Open a session: resolve the run's train/eval/init entries from
    /// the engine's manifest, run the init program at `init_key`, zero
    /// the optimizer state, and validate the statics against the train
    /// entry's specs.
    pub fn open(
        engine: &'e dyn Executor,
        cfg: &RunConfig,
        statics: Vec<(String, HostTensor)>,
        init_key: [u32; 2],
    ) -> Result<Session<'e>> {
        let train = engine
            .manifest()
            .find_train(&cfg.model, &cfg.method, &cfg.format)?
            .clone();
        let eval = engine.manifest().find_eval(&cfg.model)?.clone();
        let init = engine.manifest().find_init(&cfg.model)?.clone();
        let state = state::init_train_state(engine, &train, &init, init_key)?;
        let statics: Vec<(String, Value)> =
            statics.into_iter().map(|(n, t)| (n, value(t))).collect();
        for s in train.input_specs(Role::Static) {
            if !statics.iter().any(|(n, _)| n == &s.name) {
                bail!("missing static input {:?} for {}", s.name, train.name);
            }
        }
        Ok(Session { engine, train, eval, state, statics })
    }

    pub fn engine(&self) -> &'e dyn Executor {
        self.engine
    }

    /// Restore the full train state from checkpointed tensors. Every
    /// live state tensor must be present (by name) in the checkpoint;
    /// extra checkpoint entries (e.g. evaluator-owned tensors) are
    /// ignored. Dtype/shape mismatches fail loudly via
    /// [`TrainState::restore`].
    pub fn restore_state(&mut self, tensors: &[(String, HostTensor)]) -> Result<()> {
        for name in self.state.names.clone() {
            let t = tensors
                .iter()
                .find(|(n, _)| n == &name)
                .map(|(_, t)| t)
                .ok_or_else(|| anyhow!("checkpoint is missing state tensor {name:?}"))?;
            self.state.restore(&name, t)?;
        }
        Ok(())
    }

    pub fn train_entry(&self) -> &ArtifactEntry {
        &self.train
    }

    pub fn eval_entry(&self) -> &ArtifactEntry {
        &self.eval
    }

    /// K: optimizer steps per train call.
    pub fn steps_per_call(&self) -> usize {
        self.train.steps_per_call.max(1)
    }

    /// The quantized-subset tensor names (from the manifest).
    pub fn quantized_keys(&self) -> &[String] {
        &self.train.quantized
    }

    /// Whether the train program consumes a data-role input (token LMs)
    /// rather than sampling in-graph.
    pub fn train_wants_data(&self) -> bool {
        self.train.inputs.iter().any(|s| s.role == Role::Data)
    }

    /// Whether the eval program consumes a data-role input.
    pub fn eval_wants_data(&self) -> bool {
        self.eval.inputs.iter().any(|s| s.role == Role::Data)
    }

    fn static_value(&self, name: &str) -> Result<Value> {
        self.statics
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, v)| v.clone())
            .ok_or_else(|| anyhow!("missing static {name:?}"))
    }

    /// Run one K-step train chunk: pack the positional argument list by
    /// role, execute, split off the loss metrics, and adopt the
    /// returned state for the next chunk.
    pub fn train_chunk(&mut self, inp: ChunkInputs) -> Result<ChunkOutcome> {
        if inp.lrs.len() != self.steps_per_call() {
            bail!(
                "{}: got {} lrs, expected K={}",
                self.train.name,
                inp.lrs.len(),
                self.steps_per_call()
            );
        }
        let mut args = Vec::with_capacity(self.train.inputs.len());
        let mut state_iter = self.state.values().iter();
        for spec in &self.train.inputs {
            let arg = match spec.role {
                Role::Param | Role::Opt => state_iter
                    .next()
                    .ok_or_else(|| anyhow!("state exhausted at {:?}", spec.name))?
                    .clone(),
                Role::Static => self.static_value(&spec.name)?,
                Role::Data => inp
                    .data
                    .clone()
                    .ok_or_else(|| anyhow!("{} wants a data input", self.train.name))?,
                Role::Key => value(HostTensor::from_u32(&[2], inp.key.to_vec())),
                Role::Scalar => match spec.name.as_str() {
                    "lrs" => value(HostTensor::from_f32(&[inp.lrs.len()], inp.lrs.clone())),
                    "est_sched" => {
                        let s = inp.est_sched.clone().ok_or_else(|| {
                            anyhow!("{} wants an est_sched input", self.train.name)
                        })?;
                        value(HostTensor::from_f32(&[s.len()], s))
                    }
                    "lam_reg" => value(HostTensor::scalar_f32(inp.lam_reg)),
                    other => bail!("unknown scalar input {other:?}"),
                },
                Role::Metric => bail!("metric role on an input"),
            };
            args.push(arg);
        }
        let mut out = self.engine.call(&self.train, &args)?;
        let n_metrics = 2; // base_losses, total_losses
        if out.len() < self.state.len() + n_metrics {
            bail!("{}: {} outputs cannot cover state + metrics", self.train.name, out.len());
        }
        let metrics_start = out.len() - n_metrics;
        let bases = out[metrics_start].as_f32();
        let totals = out[metrics_start + 1].as_f32();
        out.truncate(metrics_start);
        self.state.adopt(&mut out)?;
        Ok(ChunkOutcome { bases, totals })
    }

    /// Run the eval program at the current state and return `val_loss`.
    /// `map_param` transforms each param input (identity for FP32
    /// evals, a quantized cast over the quantized subset otherwise);
    /// `data` supplies the validation chunk for data-fed programs.
    pub fn eval_loss(
        &self,
        data: Option<Value>,
        map_param: &mut dyn FnMut(&TensorSpec, &Value) -> Result<Value>,
    ) -> Result<f64> {
        let mut args = Vec::with_capacity(self.eval.inputs.len());
        for spec in &self.eval.inputs {
            let arg = match spec.role {
                Role::Param => map_param(spec, self.state.value(&spec.name)?)?,
                Role::Static => self.static_value(&spec.name)?,
                Role::Data => data
                    .clone()
                    .ok_or_else(|| anyhow!("{} wants a data input", self.eval.name))?,
                other => bail!("unexpected eval input role {other:?}"),
            };
            args.push(arg);
        }
        let out = self.engine.call_to_host(&self.eval, &args, &["val_loss"])?;
        Ok(out[0].scalar_to_f32() as f64)
    }

    /// Run the backend's fused quantized eval entry (`eval_q_*`) at the
    /// current state. Master FP32 params go in *uncast*; the engine
    /// RTN-casts the quantized subset into its packed block form and
    /// consumes it in place — the fused path never materializes a full
    /// f32 copy of the quantized weights. Returns `Ok(None)` when the
    /// manifest carries no such entry for this model + format (AOT
    /// backends); callers fall back to host-side casting through
    /// [`Session::eval_loss`].
    pub fn eval_loss_quantized(&self, fmt_name: &str, data: Option<Value>) -> Result<Option<f64>> {
        let entry = match self.engine.manifest().find_eval_quant(&self.eval.model_name, fmt_name) {
            Some(e) => e,
            None => return Ok(None),
        };
        let mut args = Vec::with_capacity(entry.inputs.len());
        for spec in &entry.inputs {
            let arg = match spec.role {
                Role::Param => self.state.value(&spec.name)?.clone(),
                Role::Static => self.static_value(&spec.name)?,
                Role::Data => data
                    .clone()
                    .ok_or_else(|| anyhow!("{} wants a data input", entry.name))?,
                other => bail!("unexpected eval input role {other:?}"),
            };
            args.push(arg);
        }
        let out = self.engine.call_to_host(entry, &args, &["val_loss"])?;
        Ok(Some(out[0].scalar_to_f32() as f64))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::NativeEngine;

    fn smoke_cfg() -> RunConfig {
        // the default RunConfig targets linreg_d256/lotion/int4, which
        // the default native registry always carries
        RunConfig::default()
    }

    fn smoke_statics(d: usize) -> Vec<(String, HostTensor)> {
        vec![
            ("lam".to_string(), HostTensor::from_f32(&[d], vec![1.0; d])),
            (
                "wstar".to_string(),
                HostTensor::from_f32(&[d], (0..d).map(|i| (i as f32).sin()).collect()),
            ),
        ]
    }

    #[test]
    fn open_resolves_entries_and_inits_state() {
        let engine = NativeEngine::new();
        let s = Session::open(&engine, &smoke_cfg(), smoke_statics(256), [1, 2]).unwrap();
        assert_eq!(s.steps_per_call(), 8);
        assert_eq!(s.quantized_keys(), ["w".to_string()]);
        assert!(!s.train_wants_data());
        assert!(!s.eval_wants_data());
        assert_eq!(s.state.fetch("w").unwrap().shape, vec![256]);
    }

    #[test]
    fn open_rejects_missing_statics() {
        let engine = NativeEngine::new();
        let err = Session::open(&engine, &smoke_cfg(), vec![], [1, 2]).unwrap_err();
        assert!(err.to_string().contains("missing static"), "{err}");
    }

    #[test]
    fn train_chunk_adopts_state_and_reports_k_losses() {
        let engine = NativeEngine::new();
        let mut s = Session::open(&engine, &smoke_cfg(), smoke_statics(256), [1, 2]).unwrap();
        let w0 = s.state.fetch("w").unwrap();
        let k = s.steps_per_call();
        let out = s
            .train_chunk(ChunkInputs {
                lrs: vec![0.05; k],
                lam_reg: 1.0,
                est_sched: None,
                key: [7, 11],
                data: None,
            })
            .unwrap();
        assert_eq!(out.bases.len(), k);
        assert_eq!(out.totals.len(), k);
        assert!(out.bases.iter().all(|b| b.is_finite()));
        assert_ne!(s.state.fetch("w").unwrap(), w0, "chunk did not move the params");
        // bad lr arity is rejected before the engine call
        assert!(s
            .train_chunk(ChunkInputs {
                lrs: vec![0.05; k + 1],
                lam_reg: 1.0,
                est_sched: None,
                key: [7, 11],
                data: None,
            })
            .is_err());
    }

    #[test]
    fn eval_loss_applies_the_param_map() {
        let engine = NativeEngine::new();
        let s = Session::open(&engine, &smoke_cfg(), smoke_statics(256), [1, 2]).unwrap();
        let plain = s.eval_loss(None, &mut |_, v| Ok(v.clone())).unwrap();
        // zeroing w through the map must change the loss
        let zeroed = s
            .eval_loss(None, &mut |spec, v| {
                Ok(if spec.name == "w" {
                    value(HostTensor::zeros(v.dtype, &v.shape))
                } else {
                    v.clone()
                })
            })
            .unwrap();
        assert!(plain.is_finite() && zeroed.is_finite());
        assert_ne!(plain, zeroed);
    }

    /// The fused `eval_q` route must reproduce host-side RTN casting
    /// through the plain eval entry bit-for-bit, and degrade to `None`
    /// for formats the backend did not register.
    #[test]
    fn quantized_eval_matches_host_cast_map() {
        use crate::quant::{cast_rtn, QuantFormat};
        let engine = NativeEngine::new();
        let s = Session::open(&engine, &smoke_cfg(), smoke_statics(256), [1, 2]).unwrap();
        let fmt = QuantFormat::parse("int4", 0).unwrap();
        let quantized = s.quantized_keys().to_vec();
        let host = s
            .eval_loss(None, &mut |spec, v| {
                Ok(if quantized.contains(&spec.name) {
                    let mut w = v.as_f32();
                    cast_rtn(&mut w, &fmt);
                    value(HostTensor::from_f32(&v.shape, w))
                } else {
                    v.clone()
                })
            })
            .unwrap();
        let fused = s.eval_loss_quantized("int4", None).unwrap().expect("native eval_q entry");
        assert_eq!(fused.to_bits(), host.to_bits());
        assert!(s.eval_loss_quantized("int16", None).unwrap().is_none());

        // per-block formats route through the fused path too: the
        // packed per-block scales must reproduce the block-aware host
        // cast bitwise (PR 8 satellite)
        let fmt_b = QuantFormat::parse("int4@64", 0).unwrap();
        let host_b = s
            .eval_loss(None, &mut |spec, v| {
                Ok(if quantized.contains(&spec.name) {
                    let mut w = v.as_f32();
                    cast_rtn(&mut w, &fmt_b);
                    value(HostTensor::from_f32(&v.shape, w))
                } else {
                    v.clone()
                })
            })
            .unwrap();
        let fused_b =
            s.eval_loss_quantized("int4@64", None).unwrap().expect("native int4@64 eval_q entry");
        assert_eq!(fused_b.to_bits(), host_b.to_bits());
        assert_ne!(fused_b.to_bits(), fused.to_bits(), "per-block scales changed nothing");
    }
}
