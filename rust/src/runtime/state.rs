//! Train state: named parameter + optimizer tensors that round-trip
//! through scanned train calls as PJRT literals.

use super::literals::{self, Literal};
use super::manifest::{ArtifactEntry, Role};
use crate::tensor::{DType, HostTensor};
use anyhow::{anyhow, bail, Result};

/// Named literal store. Params and optimizer state live here between
/// chunks; literals go straight back into the next `Engine::call`
/// without re-encoding.
pub struct TrainState {
    pub names: Vec<String>,
    values: Vec<Literal>,
}

impl TrainState {
    /// Zero-initialized state for the given specs (optimizer state init:
    /// Adam moments and the step counter all start at zero).
    pub fn zeros(specs: &[&super::manifest::TensorSpec]) -> Result<TrainState> {
        let mut names = Vec::new();
        let mut values = Vec::new();
        for s in specs {
            names.push(s.name.clone());
            values.push(literals::to_literal(&HostTensor::zeros(s.dtype, &s.shape))?)
        }
        Ok(TrainState { names, values })
    }

    pub fn from_named(pairs: Vec<(String, Literal)>) -> TrainState {
        let (names, values) = pairs.into_iter().unzip();
        TrainState { names, values }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn literal(&self, name: &str) -> Result<&Literal> {
        Ok(&self.values[self.index(name).ok_or_else(|| anyhow!("no tensor {name:?}"))?])
    }

    pub fn literals(&self) -> &[Literal] {
        &self.values
    }

    /// Copy a named tensor to the host.
    pub fn fetch(&self, name: &str) -> Result<HostTensor> {
        literals::to_host(self.literal(name)?)
    }

    /// Replace a named tensor (e.g. with a quantized cast for eval).
    pub fn replace(&mut self, name: &str, t: &HostTensor) -> Result<()> {
        let idx = self.index(name).ok_or_else(|| anyhow!("no tensor {name:?}"))?;
        self.values[idx] = literals::to_literal(t)?;
        Ok(())
    }

    /// Clone the underlying literals (params snapshot for eval casts).
    pub fn clone_literals(&self) -> Vec<Literal> {
        self.values.clone()
    }

    /// Adopt the leading `names.len()` outputs of a train call as the
    /// new state (the manifest guarantees outputs echo params+opt first,
    /// in input order).
    pub fn adopt(&mut self, outputs: &mut Vec<Literal>) -> Result<()> {
        if outputs.len() < self.len() {
            bail!("outputs shorter than state ({} < {})", outputs.len(), self.len());
        }
        for (i, lit) in outputs.drain(..self.len()).enumerate() {
            self.values[i] = lit;
        }
        Ok(())
    }

    /// Total number of f32-equivalent elements (for memory accounting).
    pub fn total_elements(&self) -> usize {
        self.values
            .iter()
            .map(|l| l.element_count())
            .sum()
    }
}

/// Assemble the state sections of a train artifact:
/// params from an init call + zeroed optimizer state.
pub fn init_train_state(
    engine: &super::engine::Engine,
    train: &ArtifactEntry,
    init: &ArtifactEntry,
    seed_key: [u32; 2],
) -> Result<TrainState> {
    let key = literals::to_literal(&HostTensor::from_u32(&[2], seed_key.to_vec()))?;
    let params = engine.call(init, &[key])?;
    let param_specs = train.input_specs(Role::Param);
    if params.len() != param_specs.len() {
        bail!(
            "init returned {} tensors, train expects {} params",
            params.len(),
            param_specs.len()
        );
    }
    let mut pairs: Vec<(String, Literal)> = param_specs
        .iter()
        .zip(params)
        .map(|(s, l)| (s.name.clone(), l))
        .collect();
    for s in train.input_specs(Role::Opt) {
        pairs.push((
            s.name.clone(),
            literals::to_literal(&HostTensor::zeros(DType::F32, &s.shape))?,
        ));
    }
    Ok(TrainState::from_named(pairs))
}
