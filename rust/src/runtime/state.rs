//! Train state: named parameter + optimizer tensors that round-trip
//! through scanned train calls as backend-neutral [`Value`]s.

use super::executor::{value, Executor, Value};
use super::manifest::{ArtifactEntry, Role, TensorSpec};
use crate::tensor::HostTensor;
use anyhow::{anyhow, bail, Result};

/// Named value store. Params and optimizer state live here between
/// chunks; values go straight back into the next `Executor::call`
/// without re-encoding (they are `Rc`-shared host tensors).
pub struct TrainState {
    pub names: Vec<String>,
    values: Vec<Value>,
}

impl TrainState {
    /// Zero-initialized state for the given specs (optimizer state init:
    /// Adam moments and the step counter all start at zero).
    pub fn zeros(specs: &[&TensorSpec]) -> TrainState {
        let mut names = Vec::new();
        let mut values = Vec::new();
        for s in specs {
            names.push(s.name.clone());
            values.push(value(HostTensor::zeros(s.dtype, &s.shape)));
        }
        TrainState { names, values }
    }

    pub fn from_named(pairs: Vec<(String, Value)>) -> TrainState {
        let (names, values) = pairs.into_iter().unzip();
        TrainState { names, values }
    }

    pub fn len(&self) -> usize {
        self.names.len()
    }

    pub fn is_empty(&self) -> bool {
        self.names.is_empty()
    }

    pub fn index(&self, name: &str) -> Option<usize> {
        self.names.iter().position(|n| n == name)
    }

    pub fn value(&self, name: &str) -> Result<&Value> {
        Ok(&self.values[self.index(name).ok_or_else(|| anyhow!("no tensor {name:?}"))?])
    }

    pub fn values(&self) -> &[Value] {
        &self.values
    }

    /// Copy a named tensor to an owned host tensor.
    pub fn fetch(&self, name: &str) -> Result<HostTensor> {
        Ok(self.value(name)?.as_ref().clone())
    }

    /// Replace a named tensor (e.g. with a quantized cast for eval).
    pub fn replace(&mut self, name: &str, t: &HostTensor) -> Result<()> {
        let idx = self.index(name).ok_or_else(|| anyhow!("no tensor {name:?}"))?;
        self.values[idx] = value(t.clone());
        Ok(())
    }

    /// Restore a named tensor from a checkpoint, validating that its
    /// dtype and shape match the live state (a checkpoint from a
    /// different model/config must fail loudly, not corrupt training).
    pub fn restore(&mut self, name: &str, t: &HostTensor) -> Result<()> {
        let idx = self.index(name).ok_or_else(|| anyhow!("no tensor {name:?}"))?;
        let cur = self.values[idx].as_ref();
        if cur.dtype != t.dtype || cur.shape != t.shape {
            bail!(
                "checkpoint tensor {name:?} is {:?}{:?}, state expects {:?}{:?}",
                t.dtype,
                t.shape,
                cur.dtype,
                cur.shape
            );
        }
        self.values[idx] = value(t.clone());
        Ok(())
    }

    /// Adopt the leading `names.len()` outputs of a train call as the
    /// new state (the manifest guarantees outputs echo params+opt first,
    /// in input order).
    pub fn adopt(&mut self, outputs: &mut Vec<Value>) -> Result<()> {
        if outputs.len() < self.len() {
            bail!("outputs shorter than state ({} < {})", outputs.len(), self.len());
        }
        for (i, v) in outputs.drain(..self.len()).enumerate() {
            self.values[i] = v;
        }
        Ok(())
    }

    /// Total number of elements (for memory accounting).
    pub fn total_elements(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }
}

/// Assemble the state sections of a train artifact:
/// params from an init call + zeroed optimizer state.
pub fn init_train_state(
    exec: &dyn Executor,
    train: &ArtifactEntry,
    init: &ArtifactEntry,
    seed_key: [u32; 2],
) -> Result<TrainState> {
    let key = value(HostTensor::from_u32(&[2], seed_key.to_vec()));
    let params = exec.call(init, &[key])?;
    let param_specs = train.input_specs(Role::Param);
    if params.len() != param_specs.len() {
        bail!(
            "init returned {} tensors, train expects {} params",
            params.len(),
            param_specs.len()
        );
    }
    let mut pairs: Vec<(String, Value)> = param_specs
        .iter()
        .zip(params)
        .map(|(s, v)| (s.name.clone(), v))
        .collect();
    for s in train.input_specs(Role::Opt) {
        pairs.push((s.name.clone(), value(HostTensor::zeros(s.dtype, &s.shape))));
    }
    Ok(TrainState::from_named(pairs))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::DType;

    #[test]
    fn zeros_replace_fetch_adopt() {
        let specs = [
            TensorSpec { name: "w".into(), shape: vec![3], dtype: DType::F32, role: Role::Param },
            TensorSpec { name: "t".into(), shape: vec![], dtype: DType::F32, role: Role::Opt },
        ];
        let refs: Vec<&TensorSpec> = specs.iter().collect();
        let mut st = TrainState::zeros(&refs);
        assert_eq!(st.len(), 2);
        assert_eq!(st.total_elements(), 4);
        assert_eq!(st.fetch("w").unwrap().as_f32(), vec![0.0; 3]);
        st.replace("w", &HostTensor::from_f32(&[3], vec![1.0, 2.0, 3.0])).unwrap();
        assert_eq!(st.fetch("w").unwrap().as_f32(), vec![1.0, 2.0, 3.0]);
        assert!(st.replace("missing", &HostTensor::scalar_f32(0.0)).is_err());

        let mut outs = vec![
            value(HostTensor::from_f32(&[3], vec![4.0, 5.0, 6.0])),
            value(HostTensor::scalar_f32(9.0)),
            value(HostTensor::scalar_f32(0.5)), // trailing metric stays
        ];
        st.adopt(&mut outs).unwrap();
        assert_eq!(outs.len(), 1);
        assert_eq!(st.fetch("t").unwrap().scalar_to_f32(), 9.0);
    }

    #[test]
    fn restore_validates_dtype_and_shape() {
        let specs = [TensorSpec {
            name: "w".into(),
            shape: vec![3],
            dtype: DType::F32,
            role: Role::Param,
        }];
        let refs: Vec<&TensorSpec> = specs.iter().collect();
        let mut st = TrainState::zeros(&refs);
        st.restore("w", &HostTensor::from_f32(&[3], vec![1.0, 2.0, 3.0])).unwrap();
        assert_eq!(st.fetch("w").unwrap().as_f32(), vec![1.0, 2.0, 3.0]);
        // wrong shape
        assert!(st.restore("w", &HostTensor::from_f32(&[2], vec![1.0, 2.0])).is_err());
        // wrong dtype
        assert!(st.restore("w", &HostTensor::from_i32(&[3], vec![1, 2, 3])).is_err());
        // unknown name
        assert!(st.restore("zz", &HostTensor::scalar_f32(0.0)).is_err());
    }
}
