//! Sweep-spec syntax tree + spanned errors.
//!
//! Every node carries a byte-offset [`Span`] into the source text so
//! both parse-time and expansion-time diagnostics render as
//! caret-underlined messages pointing at the offending token
//! ([`SpecError::render`]).

use std::fmt;

/// Half-open byte range `[start, end)` into the spec source.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Span {
    pub start: usize,
    pub end: usize,
}

impl Span {
    pub fn new(start: usize, end: usize) -> Span {
        Span { start, end }
    }

    /// The smallest span covering both.
    pub fn join(self, other: Span) -> Span {
        Span { start: self.start.min(other.start), end: self.end.max(other.end) }
    }
}

/// A spec error anchored to a source span. Render with the source text
/// to get the `origin:line:col` + caret-underline form; `Display`
/// alone prints just the message (for contexts without the source).
#[derive(Clone, Debug)]
pub struct SpecError {
    pub msg: String,
    pub span: Span,
}

impl SpecError {
    pub fn new(msg: impl Into<String>, span: Span) -> SpecError {
        SpecError { msg: msg.into(), span }
    }

    /// `origin:line:col: msg` plus the source line with the span
    /// caret-underlined:
    ///
    /// ```text
    /// fig2.sweep:3:15: unknown key "stpes" (did you mean "steps"?)
    ///   grid: lr=[1] x stpes=[2]
    ///                  ^^^^^
    /// ```
    pub fn render(&self, src: &str, origin: &str) -> String {
        let start = self.span.start.min(src.len());
        let line_start = src[..start].rfind('\n').map(|i| i + 1).unwrap_or(0);
        let line_end = src[start..].find('\n').map(|i| start + i).unwrap_or(src.len());
        let line_no = src[..start].matches('\n').count() + 1;
        let col = start - line_start + 1;
        let line = &src[line_start..line_end];
        let carets = self.span.end.min(line_end).saturating_sub(start).max(1);
        format!(
            "{origin}:{line_no}:{col}: {msg}\n  {line}\n  {pad}{carets}",
            msg = self.msg,
            pad = " ".repeat(col - 1),
            carets = "^".repeat(carets),
        )
    }

    /// The rendered form as an `anyhow::Error` (the CLI surface).
    pub fn to_anyhow(&self, src: &str, origin: &str) -> anyhow::Error {
        anyhow::anyhow!("{}", self.render(src, origin))
    }
}

impl fmt::Display for SpecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)
    }
}

impl std::error::Error for SpecError {}

/// An atomic value: a number or a bare word (idents like `lotion`,
/// `lm-tiny`, `int4@64`; quoted strings land here too).
#[derive(Clone, Debug, PartialEq)]
pub enum Scalar {
    Num(f64),
    Word(String),
}

impl Scalar {
    /// The value as it appears in point labels (`0.3`, `lotion`).
    pub fn display(&self) -> String {
        match self {
            Scalar::Num(n) => format!("{n}"),
            Scalar::Word(w) => w.clone(),
        }
    }
}

/// A scalar with its source span.
#[derive(Clone, Debug)]
pub struct ScalarNode {
    pub v: Scalar,
    pub span: Span,
}

/// The right-hand side of an assignment: a single scalar or a list
/// (explicit `[...]` or an expanded `linspace`/`logspace` range).
#[derive(Clone, Debug)]
pub enum ValueNode {
    Scalar(ScalarNode),
    List(Vec<ScalarNode>, Span),
}

impl ValueNode {
    pub fn span(&self) -> Span {
        match self {
            ValueNode::Scalar(s) => s.span,
            ValueNode::List(_, span) => *span,
        }
    }
}

/// `key = value` — a spec-level default, or an override inside a
/// `when` clause.
#[derive(Clone, Debug)]
pub struct Assign {
    pub key: String,
    pub key_span: Span,
    pub value: ValueNode,
}

/// One axis of a `grid:` statement: `key=[v1,v2,...]` (ranges are
/// expanded to explicit value lists at parse time).
#[derive(Clone, Debug)]
pub struct Axis {
    pub key: String,
    pub key_span: Span,
    pub values: Vec<ScalarNode>,
}

/// One `key=value` condition of a `when` clause.
#[derive(Clone, Debug)]
pub struct Cond {
    pub key: String,
    pub key_span: Span,
    pub value: ScalarNode,
}

/// A top-level statement.
#[derive(Clone, Debug)]
pub enum Stmt {
    /// `key = value` — applies to every point (defaults)
    Assign(Assign),
    /// `grid: a=[..] x b=[..]` — one product block of the point grid
    Grid { axes: Vec<Axis>, span: Span },
    /// `when k=v, ...: key=value, ...` — conditional per-point override
    When { conds: Vec<Cond>, assigns: Vec<Assign> },
}

/// A parsed spec: statements in file order.
#[derive(Clone, Debug, Default)]
pub struct SpecAst {
    pub stmts: Vec<Stmt>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn render_points_at_the_span() {
        let src = "a = 1\nb = nope\n";
        let e = SpecError::new("bad value", Span::new(10, 14));
        let r = e.render(src, "t.sweep");
        assert_eq!(r, "t.sweep:2:5: bad value\n  b = nope\n      ^^^^");
    }

    #[test]
    fn render_clamps_eof_spans() {
        let src = "a = 1";
        let e = SpecError::new("unexpected end", Span::new(5, 5));
        let r = e.render(src, "t");
        assert!(r.starts_with("t:1:6: unexpected end"), "{r}");
        assert!(r.ends_with('^'), "{r}");
    }

    #[test]
    fn span_join_covers_both() {
        let s = Span::new(3, 5).join(Span::new(8, 12));
        assert_eq!(s, Span::new(3, 12));
    }
}
