//! Spec → grid expansion: turns a parsed [`SpecAst`] into labeled,
//! validated [`SweepPoint`]s in deterministic grid order.
//!
//! Semantics (DESIGN.md §10):
//!
//! * **Defaults.** Plain `key = value` assignments apply to every
//!   point, wherever they appear in the file. Three keys configure the
//!   plan rather than the points: `seeds` (replicates per point),
//!   `score_format` and `score_rounding` (the eval the runner scores
//!   by; default the training format / `rtn`).
//! * **Grids.** Each `grid:` statement contributes its full axis
//!   product; multiple statements concatenate in file order, so
//!   irregular (non-product) grids are a sequence of `grid:` lines.
//!   Within one product the **first axis is outermost** — e.g.
//!   `grid: method=[a,b] x lr=[1,2]` yields `a,1 a,2 b,1 b,2` — the
//!   same method-major order the hard-coded experiments use.
//! * **Conditionals.** `when k=v, ...: key=val, ...` applies its
//!   assignments to every point matching *all* conditions, evaluated
//!   in file order after the point's axis values are in place.
//! * **Seeds.** `seeds = N` (N > 1) replicates every point with
//!   `_s{k}` label suffixes and per-replicate seeds derived via
//!   [`Rng::stream_seed`] from the point's base seed — decorrelated
//!   streams, stable under grid edits elsewhere.
//! * **Labels.** One part per axis: bare words keep the value
//!   (`lotion`), numbers prefix the key's last dot-segment (`lr0.3`,
//!   `sigma00.5`); parts join with `_`. `cfg.name` becomes
//!   `{base_name}_{label}`. Duplicate labels are an error.
//! * **Validation.** Every key/value is checked at apply time (methods
//!   against the estimator registry, formats against the quantizer,
//!   models against the engine's preset list when available) and every
//!   expanded point runs [`RunConfig::validate`] — all *before* any
//!   engine spawns, with caret-spanned errors.

use crate::config::{RunConfig, Schedule};
use crate::coordinator::sweep::SweepPoint;
use crate::quant::{QuantFormat, Rounding};
use crate::runtime::native::estimator::{self, EstSchedule};
use crate::util::text::nearest;
use crate::util::Rng;

use super::ast::{Assign, Scalar, ScalarNode, Span, SpecAst, SpecError, Stmt, ValueNode};

/// Per-point config keys a spec may assign or sweep.
pub const KNOWN_KEYS: [&str; 17] = [
    "name",
    "model",
    "method",
    "format",
    "steps",
    "lr",
    "lambda",
    "seed",
    "eval_every",
    "schedule",
    "warmup",
    "final_frac",
    "eval_formats",
    "eval_roundings",
    "est.schedule",
    "est.sigma0",
    "est.grad_scale",
];

/// Plan-level keys: configure the sweep, not individual points.
pub const PLAN_KEYS: [&str; 3] = ["seeds", "score_format", "score_rounding"];

/// An expanded, validated sweep: what `lotion sweep --spec` hands to
/// the sharded `SweepRunner`.
#[derive(Clone, Debug)]
pub struct SweepPlan {
    /// base name (the spec's `name` default) — journal/output prefix
    pub name: String,
    /// labeled points in deterministic grid order
    pub points: Vec<SweepPoint>,
    /// eval format the runner scores by
    pub score_format: String,
    /// eval rounding the runner scores by
    pub score_rounding: String,
    /// replicates per grid point (`seeds = N`)
    pub seeds: usize,
    /// FNV-1a digest of the spec source (filled by [`super::plan`];
    /// empty when expanding a bare AST)
    pub digest: String,
}

fn unknown_key(key: &str, span: Span) -> SpecError {
    let all = KNOWN_KEYS.iter().chain(PLAN_KEYS.iter()).copied();
    match nearest(key, all) {
        Some(s) => SpecError::new(format!("unknown key {key:?} (did you mean {s:?}?)"), span),
        None => SpecError::new(
            format!(
                "unknown key {key:?} (known keys: {}; plan keys: {})",
                KNOWN_KEYS.join(", "),
                PLAN_KEYS.join(", ")
            ),
            span,
        ),
    }
}

fn want_word<'a>(key: &str, v: &'a ScalarNode) -> Result<&'a str, SpecError> {
    match &v.v {
        Scalar::Word(w) => Ok(w),
        Scalar::Num(n) => Err(SpecError::new(
            format!("{key} expects a name, got number {n}"),
            v.span,
        )),
    }
}

fn want_num(key: &str, v: &ScalarNode) -> Result<f64, SpecError> {
    match &v.v {
        Scalar::Num(n) => Ok(*n),
        Scalar::Word(w) => Err(SpecError::new(
            format!("{key} expects a number, got {w:?}"),
            v.span,
        )),
    }
}

fn want_uint(key: &str, v: &ScalarNode) -> Result<usize, SpecError> {
    let n = want_num(key, v)?;
    if n.fract() != 0.0 || n < 0.0 || n > u32::MAX as f64 {
        return Err(SpecError::new(
            format!("{key} must be a non-negative integer, got {n}"),
            v.span,
        ));
    }
    Ok(n as usize)
}

/// Apply one scalar `key = value` to a config. Validates the value
/// against the relevant registry (estimators, quant formats, schedule
/// names, model presets) so bad points fail before any engine spawns.
fn apply(
    cfg: &mut RunConfig,
    key: &str,
    key_span: Span,
    v: &ScalarNode,
    known_models: Option<&[String]>,
) -> Result<(), SpecError> {
    match key {
        "name" => cfg.name = want_word(key, v)?.to_string(),
        "model" => {
            let w = want_word(key, v)?;
            if let Some(models) = known_models {
                if !models.iter().any(|m| m == w) {
                    let msg = match nearest(w, models.iter().map(|m| m.as_str())) {
                        Some(s) => format!("unknown model {w:?} (did you mean {s:?}?)"),
                        None => format!(
                            "unknown model {w:?} (known models: {})",
                            models.join(", ")
                        ),
                    };
                    return Err(SpecError::new(msg, v.span));
                }
            }
            cfg.model = w.to_string();
        }
        "method" => {
            let w = want_word(key, v)?;
            // registry-driven: the error lists the known estimators
            estimator::parse(w).map_err(|e| SpecError::new(e.to_string(), v.span))?;
            cfg.method = w.to_string();
        }
        "format" => {
            let w = want_word(key, v)?;
            if w != "none" {
                QuantFormat::parse(w, 0).map_err(|e| SpecError::new(e.to_string(), v.span))?;
            }
            cfg.format = w.to_string();
        }
        "steps" => cfg.steps = want_uint(key, v)?,
        "lr" => cfg.lr = want_num(key, v)?,
        "lambda" => cfg.lambda = want_num(key, v)?,
        "seed" => cfg.seed = want_uint(key, v)? as u64,
        "eval_every" => cfg.eval_every = want_uint(key, v)?,
        "schedule" => match want_word(key, v)? {
            "constant" => cfg.schedule = Schedule::Constant,
            "cosine" => {
                if !matches!(cfg.schedule, Schedule::Cosine { .. }) {
                    let d = RunConfig::default();
                    cfg.schedule = d.schedule;
                }
            }
            other => {
                return Err(SpecError::new(
                    format!("unknown schedule {other:?} (known schedules: constant, cosine)"),
                    v.span,
                ))
            }
        },
        "warmup" => {
            let n = want_uint(key, v)?;
            match &mut cfg.schedule {
                Schedule::Cosine { warmup, .. } => *warmup = n,
                Schedule::Constant => {
                    return Err(SpecError::new(
                        "warmup requires schedule=cosine (set `schedule = cosine` first)",
                        key_span,
                    ))
                }
            }
        }
        "final_frac" => {
            let n = want_num(key, v)?;
            match &mut cfg.schedule {
                Schedule::Cosine { final_frac, .. } => *final_frac = n,
                Schedule::Constant => {
                    return Err(SpecError::new(
                        "final_frac requires schedule=cosine (set `schedule = cosine` first)",
                        key_span,
                    ))
                }
            }
        }
        "eval_formats" => {
            let w = want_word(key, v)?;
            if w != "none" {
                QuantFormat::parse(w, 0).map_err(|e| SpecError::new(e.to_string(), v.span))?;
            }
            cfg.eval_formats = vec![w.to_string()];
        }
        "eval_roundings" => {
            let r = Rounding::parse(want_word(key, v)?)
                .map_err(|e| SpecError::new(e.to_string(), v.span))?;
            cfg.eval_roundings = vec![r];
        }
        "est.schedule" => {
            cfg.est_schedule = EstSchedule::parse(want_word(key, v)?)
                .map_err(|e| SpecError::new(e.to_string(), v.span))?;
        }
        "est.sigma0" => cfg.est_sigma0 = want_num(key, v)?,
        "est.grad_scale" => cfg.est_grad_scale = want_num(key, v)?,
        _ => return Err(unknown_key(key, key_span)),
    }
    Ok(())
}

/// Apply a defaults assignment, which may be list-valued for the two
/// list-typed config fields; any other list value points at `grid:`.
fn apply_default(
    cfg: &mut RunConfig,
    a: &Assign,
    known_models: Option<&[String]>,
) -> Result<(), SpecError> {
    match (&a.value, a.key.as_str()) {
        (ValueNode::List(vs, _), "eval_formats") => {
            let mut out = Vec::with_capacity(vs.len());
            for v in vs {
                let w = want_word(&a.key, v)?;
                if w != "none" {
                    QuantFormat::parse(w, 0).map_err(|e| SpecError::new(e.to_string(), v.span))?;
                }
                out.push(w.to_string());
            }
            cfg.eval_formats = out;
            Ok(())
        }
        (ValueNode::List(vs, _), "eval_roundings") => {
            let mut out = Vec::with_capacity(vs.len());
            for v in vs {
                out.push(
                    Rounding::parse(want_word(&a.key, v)?)
                        .map_err(|e| SpecError::new(e.to_string(), v.span))?,
                );
            }
            cfg.eval_roundings = out;
            Ok(())
        }
        (ValueNode::List(_, span), key) => Err(SpecError::new(
            format!("list value for scalar key {key:?} — use `grid: {key}=[...]` to sweep it"),
            *span,
        )),
        (ValueNode::Scalar(v), _) => apply(cfg, &a.key, a.key_span, v, known_models),
    }
}

/// Current config value of a key, for `when` condition matching.
/// `None` = the key exists but is not testable (list-typed, or
/// schedule params under a non-cosine schedule).
fn current(cfg: &RunConfig, key: &str) -> Result<Option<Scalar>, ()> {
    Ok(Some(match key {
        "name" => Scalar::Word(cfg.name.clone()),
        "model" => Scalar::Word(cfg.model.clone()),
        "method" => Scalar::Word(cfg.method.clone()),
        "format" => Scalar::Word(cfg.format.clone()),
        "steps" => Scalar::Num(cfg.steps as f64),
        "lr" => Scalar::Num(cfg.lr),
        "lambda" => Scalar::Num(cfg.lambda),
        "seed" => Scalar::Num(cfg.seed as f64),
        "eval_every" => Scalar::Num(cfg.eval_every as f64),
        "schedule" => Scalar::Word(
            match cfg.schedule {
                Schedule::Constant => "constant",
                Schedule::Cosine { .. } => "cosine",
            }
            .into(),
        ),
        "warmup" => match cfg.schedule {
            Schedule::Cosine { warmup, .. } => Scalar::Num(warmup as f64),
            Schedule::Constant => return Ok(None),
        },
        "final_frac" => match cfg.schedule {
            Schedule::Cosine { final_frac, .. } => Scalar::Num(final_frac),
            Schedule::Constant => return Ok(None),
        },
        "est.schedule" => Scalar::Word(cfg.est_schedule.name().into()),
        "est.sigma0" => Scalar::Num(cfg.est_sigma0),
        "est.grad_scale" => Scalar::Num(cfg.est_grad_scale),
        "eval_formats" | "eval_roundings" => return Ok(None),
        _ => return Err(()),
    }))
}

/// One label part per axis value: bare words as-is, numbers prefixed
/// with the key's last dot-segment (`est.sigma0` → `sigma0`).
fn label_part(key: &str, v: &Scalar) -> String {
    match v {
        Scalar::Word(w) => w.clone(),
        Scalar::Num(_) => {
            let short = key.rsplit('.').next().unwrap_or(key);
            format!("{short}{}", v.display())
        }
    }
}

/// Expand a parsed spec against a base config. `known_models`, when
/// available (native backend), validates `model` values up front. The
/// returned plan's `digest` is empty — [`super::plan`] stamps it from
/// the raw source.
pub fn expand(
    ast: &SpecAst,
    base: &RunConfig,
    known_models: Option<&[String]>,
) -> Result<SweepPlan, SpecError> {
    let mut defaults = base.clone();
    let mut seeds: usize = 1;
    let mut score_format: Option<String> = None;
    let mut score_rounding: Option<String> = None;
    let mut grids: Vec<(&[super::ast::Axis], Span)> = Vec::new();
    let mut whens: Vec<(&[super::ast::Cond], &[Assign])> = Vec::new();

    // pass 1: defaults + plan keys, collect grids/whens in file order
    for stmt in &ast.stmts {
        match stmt {
            Stmt::Assign(a) => match a.key.as_str() {
                "seeds" => {
                    let v = match &a.value {
                        ValueNode::Scalar(s) => s,
                        ValueNode::List(_, span) => {
                            return Err(SpecError::new(
                                "seeds expects a single integer",
                                *span,
                            ))
                        }
                    };
                    seeds = want_uint("seeds", v)?;
                    if seeds == 0 {
                        return Err(SpecError::new("seeds must be >= 1", v.span));
                    }
                }
                "score_format" | "score_rounding" => {
                    let v = match &a.value {
                        ValueNode::Scalar(s) => s,
                        ValueNode::List(_, span) => {
                            return Err(SpecError::new(
                                format!("{} expects a single value", a.key),
                                *span,
                            ))
                        }
                    };
                    let w = want_word(&a.key, v)?.to_string();
                    if a.key == "score_rounding" {
                        Rounding::parse(&w)
                            .map_err(|e| SpecError::new(e.to_string(), v.span))?;
                        score_rounding = Some(w);
                    } else {
                        if w != "none" {
                            QuantFormat::parse(&w, 0)
                                .map_err(|e| SpecError::new(e.to_string(), v.span))?;
                        }
                        score_format = Some(w);
                    }
                }
                _ => apply_default(&mut defaults, a, known_models)?,
            },
            Stmt::Grid { axes, span } => grids.push((axes.as_slice(), *span)),
            Stmt::When { conds, assigns } => whens.push((conds.as_slice(), assigns.as_slice())),
        }
    }

    // axis/when keys must be per-point config keys, never plan keys
    for (axes, _) in &grids {
        for ax in axes.iter() {
            if PLAN_KEYS.contains(&ax.key.as_str()) {
                return Err(SpecError::new(
                    format!("{:?} is a plan-level key; it cannot be a grid axis", ax.key),
                    ax.key_span,
                ));
            }
            if ax.key == "name" {
                return Err(SpecError::new("name cannot be swept", ax.key_span));
            }
            if !KNOWN_KEYS.contains(&ax.key.as_str()) {
                return Err(unknown_key(&ax.key, ax.key_span));
            }
        }
    }
    for (conds, assigns) in &whens {
        for c in conds.iter() {
            if current(&defaults, &c.key).is_err() {
                return Err(unknown_key(&c.key, c.key_span));
            }
        }
        for a in assigns.iter() {
            if PLAN_KEYS.contains(&a.key.as_str()) || a.key == "name" {
                return Err(SpecError::new(
                    format!("{:?} cannot be assigned in a `when` clause", a.key),
                    a.key_span,
                ));
            }
            // static check, so a typo in a never-matching clause still errors
            if !KNOWN_KEYS.contains(&a.key.as_str()) {
                return Err(unknown_key(&a.key, a.key_span));
            }
        }
    }

    // pass 2: expand each grid's product, first axis outermost
    const MAX_POINTS: usize = 100_000;
    let mut labeled: Vec<(String, RunConfig, Span)> = Vec::new();
    for &(axes, span) in &grids {
        let total: usize = axes.iter().map(|a| a.values.len()).product();
        if labeled.len().saturating_add(total).saturating_mul(seeds.max(1)) > MAX_POINTS {
            return Err(SpecError::new(
                format!("spec expands to more than {MAX_POINTS} points"),
                span,
            ));
        }
        for k in 0..total {
            let mut idx = k;
            let mut pos = vec![0usize; axes.len()];
            for i in (0..axes.len()).rev() {
                pos[i] = idx % axes[i].values.len();
                idx /= axes[i].values.len();
            }
            let mut cfg = defaults.clone();
            let mut parts = Vec::with_capacity(axes.len());
            for (i, ax) in axes.iter().enumerate() {
                let v = &ax.values[pos[i]];
                apply(&mut cfg, &ax.key, ax.key_span, v, known_models)?;
                parts.push(label_part(&ax.key, &v.v));
            }
            apply_whens(&mut cfg, &whens, known_models)?;
            labeled.push((parts.join("_"), cfg, span));
        }
    }
    if grids.is_empty() {
        // a grid-less spec is a single run of the defaults
        let mut cfg = defaults.clone();
        apply_whens(&mut cfg, &whens, known_models)?;
        let span = Span::new(0, 0);
        labeled.push((defaults.name.clone(), cfg, span));
    }

    // seeds replicates + final naming/validation
    let base_name = defaults.name.clone();
    let mut points = Vec::with_capacity(labeled.len() * seeds);
    let mut seen = std::collections::BTreeSet::new();
    for (label, cfg, span) in labeled {
        for s in 0..seeds {
            let mut c = cfg.clone();
            let label = if seeds > 1 { format!("{label}_s{s}") } else { label.clone() };
            if seeds > 1 {
                c.seed = Rng::stream_seed(c.seed, &[s as u64]);
            }
            if c.name == base_name || c.name.is_empty() {
                c.name = if label == base_name {
                    base_name.clone()
                } else {
                    format!("{base_name}_{label}")
                };
            }
            if !seen.insert(label.clone()) {
                return Err(SpecError::new(
                    format!("duplicate point label {label:?} — grids overlap"),
                    span,
                ));
            }
            c.validate()
                .map_err(|e| SpecError::new(format!("point {label:?}: {e}"), span))?;
            points.push(SweepPoint::new(label, c));
        }
    }
    if points.is_empty() {
        return Err(SpecError::new("spec expands to zero points", Span::new(0, 0)));
    }

    Ok(SweepPlan {
        name: base_name,
        score_format: score_format.unwrap_or_else(|| defaults.format.clone()),
        score_rounding: score_rounding.unwrap_or_else(|| "rtn".into()),
        seeds,
        digest: String::new(),
        points,
    })
}

/// Apply every matching `when` clause, in file order, against the
/// point's current values (so later clauses see earlier overrides).
fn apply_whens(
    cfg: &mut RunConfig,
    whens: &[(&[super::ast::Cond], &[Assign])],
    known_models: Option<&[String]>,
) -> Result<(), SpecError> {
    for (conds, assigns) in whens {
        let mut all = true;
        for c in conds.iter() {
            let cur = match current(cfg, &c.key) {
                Ok(Some(v)) => v,
                Ok(None) => {
                    all = false;
                    break;
                }
                Err(()) => return Err(unknown_key(&c.key, c.key_span)),
            };
            let m = match (&cur, &c.value.v) {
                (Scalar::Word(a), Scalar::Word(b)) => a == b,
                (Scalar::Num(a), Scalar::Num(b)) => a == b,
                (have, want) => {
                    return Err(SpecError::new(
                        format!(
                            "type mismatch: {} is {}, condition compares against {}",
                            c.key,
                            kind(have),
                            kind(want)
                        ),
                        c.value.span,
                    ))
                }
            };
            if !m {
                all = false;
                break;
            }
        }
        if !all {
            continue;
        }
        for a in assigns.iter() {
            let v = match &a.value {
                ValueNode::Scalar(s) => s,
                ValueNode::List(_, span) => {
                    return Err(SpecError::new(
                        "`when` overrides take single values, not lists",
                        *span,
                    ))
                }
            };
            apply(cfg, &a.key, a.key_span, v, known_models)?;
        }
    }
    Ok(())
}

fn kind(s: &Scalar) -> &'static str {
    match s {
        Scalar::Num(_) => "a number",
        Scalar::Word(_) => "a name",
    }
}

#[cfg(test)]
mod tests {
    use super::super::parser::parse;
    use super::*;

    fn base() -> RunConfig {
        RunConfig::default()
    }

    fn labels(p: &SweepPlan) -> Vec<&str> {
        p.points.iter().map(|p| p.label.as_str()).collect()
    }

    const GOLDEN: &str = "name = g\nmodel = linreg_d256\nsteps = 16\n\
                          grid: method=[qat,lotion] x lr=[0.1,0.2]\n\
                          when method=lotion: lambda=0.5\n";

    #[test]
    fn golden_expansion_order_and_overrides() {
        let plan = expand(&parse(GOLDEN).unwrap(), &base(), None).unwrap();
        // first axis outermost: method-major, exactly like exp fig2
        assert_eq!(labels(&plan), ["qat_lr0.1", "qat_lr0.2", "lotion_lr0.1", "lotion_lr0.2"]);
        assert_eq!(plan.name, "g");
        assert_eq!(plan.score_format, "int4"); // defaults to the training format
        assert_eq!(plan.score_rounding, "rtn");
        let p = &plan.points[3];
        assert_eq!(p.cfg.name, "g_lotion_lr0.2");
        assert_eq!(p.cfg.method, "lotion");
        assert_eq!(p.cfg.lr, 0.2);
        assert_eq!(p.cfg.lambda, 0.5, "when-clause applied to lotion points");
        assert_eq!(plan.points[0].cfg.lambda, 1.0, "qat points keep the default");
        assert_eq!(p.cfg.steps, 16);
    }

    #[test]
    fn multiple_grids_concatenate_in_file_order() {
        let src = "grid: method=[qat]\ngrid: method=[anneal] x est.sigma0=[0.5,1]\n";
        let plan = expand(&parse(src).unwrap(), &base(), None).unwrap();
        assert_eq!(labels(&plan), ["qat", "anneal_sigma00.5", "anneal_sigma01"]);
        assert_eq!(plan.points[2].cfg.est_sigma0, 1.0);
    }

    #[test]
    fn seeds_replicate_with_stream_seeds() {
        let src = "seeds = 3\nseed = 7\ngrid: method=[qat,lotion]\n";
        let plan = expand(&parse(src).unwrap(), &base(), None).unwrap();
        assert_eq!(
            labels(&plan),
            ["qat_s0", "qat_s1", "qat_s2", "lotion_s0", "lotion_s1", "lotion_s2"]
        );
        let seeds: Vec<u64> = plan.points.iter().map(|p| p.cfg.seed).collect();
        assert_eq!(seeds[0], Rng::stream_seed(7, &[0]));
        assert_eq!(seeds[2], Rng::stream_seed(7, &[2]));
        assert_eq!(seeds[0], seeds[3], "same replicate index → same derived seed");
        assert_ne!(seeds[0], seeds[1]);
    }

    #[test]
    fn no_grid_spec_is_a_single_point() {
        let src = "name = solo\nmethod = qat\nsteps = 8\n";
        let plan = expand(&parse(src).unwrap(), &base(), None).unwrap();
        assert_eq!(labels(&plan), ["solo"]);
        assert_eq!(plan.points[0].cfg.name, "solo");
        assert_eq!(plan.points[0].cfg.method, "qat");
    }

    #[test]
    fn unknown_keys_suggest_the_nearest() {
        let src = "stpes = 16\n";
        let e = expand(&parse(src).unwrap(), &base(), None).unwrap_err();
        assert_eq!(e.msg, "unknown key \"stpes\" (did you mean \"steps\"?)");
        let src = "grid: lamda=[0.1]\n";
        let e = expand(&parse(src).unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("did you mean \"lambda\"?"), "{}", e.msg);
        let src = "when method=qat: lamda=0.1\n";
        let e = expand(&parse(src).unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("did you mean \"lambda\"?"), "{}", e.msg);
    }

    #[test]
    fn registry_backed_value_errors() {
        let e = expand(&parse("method = magic\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("known estimators"), "{}", e.msg);
        let e = expand(&parse("format = int99\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("int99"), "{}", e.msg);
        let e = expand(&parse("est.schedule = warp\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("known schedules"), "{}", e.msg);
        let models = vec!["linreg_d256".to_string(), "lm-tiny".to_string()];
        let e =
            expand(&parse("model = lm-tinny\n").unwrap(), &base(), Some(&models)).unwrap_err();
        assert!(e.msg.contains("did you mean \"lm-tiny\"?"), "{}", e.msg);
        assert!(expand(&parse("model = lm-tiny\n").unwrap(), &base(), Some(&models)).is_ok());
    }

    #[test]
    fn per_point_validation_names_the_point() {
        let e = expand(&parse("grid: lr=[0.1,-1]\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.starts_with("point \"lr-1\":"), "{}", e.msg);
        assert!(e.msg.contains("train.lr must be > 0"), "{}", e.msg);
    }

    #[test]
    fn duplicate_labels_error() {
        let src = "grid: method=[qat]\ngrid: method=[qat]\n";
        let e = expand(&parse(src).unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("duplicate point label \"qat\""), "{}", e.msg);
    }

    #[test]
    fn plan_keys_cannot_be_axes() {
        let e = expand(&parse("grid: seeds=[1,2]\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("plan-level key"), "{}", e.msg);
    }

    #[test]
    fn when_type_mismatch_is_an_error() {
        let src = "grid: method=[qat]\nwhen lr=qat: lambda=0.5\n";
        let e = expand(&parse(src).unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("type mismatch"), "{}", e.msg);
    }

    #[test]
    fn list_for_scalar_key_points_at_grid() {
        let e = expand(&parse("lr = [0.1, 0.2]\n").unwrap(), &base(), None).unwrap_err();
        assert!(e.msg.contains("use `grid: lr=[...]`"), "{}", e.msg);
    }

    #[test]
    fn schedule_and_est_fields_apply() {
        let src = "schedule = cosine\nwarmup = 4\nfinal_frac = 0.2\n\
                   eval_formats = [int4, int8]\neval_roundings = [rr]\n\
                   score_format = int4\nscore_rounding = rr\n\
                   grid: method=[anneal] x est.schedule=[cosine,linear]\n";
        let plan = expand(&parse(src).unwrap(), &base(), None).unwrap();
        assert_eq!(labels(&plan), ["anneal_cosine", "anneal_linear"]);
        let c = &plan.points[0].cfg;
        assert_eq!(c.schedule, Schedule::Cosine { warmup: 4, final_frac: 0.2 });
        assert_eq!(c.eval_formats, ["int4", "int8"]);
        assert_eq!(c.eval_roundings, vec![Rounding::Rr]);
        assert_eq!(c.est_schedule, EstSchedule::Cosine);
        assert_eq!(plan.points[1].cfg.est_schedule, EstSchedule::Linear);
        assert_eq!(plan.score_rounding, "rr");
        // warmup under an explicit constant schedule is rejected
        let e = expand(&parse("schedule = constant\nwarmup = 4\n").unwrap(), &base(), None)
            .unwrap_err();
        assert!(e.msg.contains("requires schedule=cosine"), "{}", e.msg);
    }
}
