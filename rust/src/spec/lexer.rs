//! Sweep-spec lexer: byte-offset spanned tokens, `#` comments,
//! newline-terminated statements.
//!
//! Idents are permissive on purpose — `lm-150m-sim`, `int4@64` and
//! dotted keys like `est.sigma0` are single tokens — while anything
//! starting with a digit (or a sign followed by a digit/dot) lexes as
//! a number, so `3e-3` and `-0.5` are numbers, not idents.

use super::ast::{Span, SpecError};

#[derive(Clone, Debug, PartialEq)]
pub enum Tok {
    Ident(String),
    Num(f64),
    Str(String),
    Eq,
    LBracket,
    RBracket,
    LParen,
    RParen,
    Comma,
    Colon,
    Newline,
    Eof,
}

impl Tok {
    /// Short human name for "expected X, found Y" diagnostics.
    pub fn describe(&self) -> String {
        match self {
            Tok::Ident(s) => format!("{s:?}"),
            Tok::Num(n) => format!("number {n}"),
            Tok::Str(s) => format!("string {s:?}"),
            Tok::Eq => "'='".into(),
            Tok::LBracket => "'['".into(),
            Tok::RBracket => "']'".into(),
            Tok::LParen => "'('".into(),
            Tok::RParen => "')'".into(),
            Tok::Comma => "','".into(),
            Tok::Colon => "':'".into(),
            Tok::Newline => "end of line".into(),
            Tok::Eof => "end of spec".into(),
        }
    }
}

#[derive(Clone, Debug)]
pub struct Token {
    pub tok: Tok,
    pub span: Span,
}

fn is_ident_start(b: u8) -> bool {
    b.is_ascii_alphabetic() || b == b'_'
}

fn is_ident_continue(b: u8) -> bool {
    b.is_ascii_alphanumeric() || matches!(b, b'_' | b'-' | b'.' | b'@')
}

/// Tokenize the whole source; the final token is always [`Tok::Eof`].
pub fn lex(src: &str) -> Result<Vec<Token>, SpecError> {
    let bytes = src.as_bytes();
    let mut out = Vec::new();
    let mut i = 0;
    while i < bytes.len() {
        let b = bytes[i];
        match b {
            b' ' | b'\t' | b'\r' => i += 1,
            b'#' => {
                while i < bytes.len() && bytes[i] != b'\n' {
                    i += 1;
                }
            }
            b'\n' => {
                out.push(Token { tok: Tok::Newline, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'=' | b'[' | b']' | b'(' | b')' | b',' | b':' => {
                let tok = match b {
                    b'=' => Tok::Eq,
                    b'[' => Tok::LBracket,
                    b']' => Tok::RBracket,
                    b'(' => Tok::LParen,
                    b')' => Tok::RParen,
                    b',' => Tok::Comma,
                    _ => Tok::Colon,
                };
                out.push(Token { tok, span: Span::new(i, i + 1) });
                i += 1;
            }
            b'"' => {
                let start = i;
                i += 1;
                let s0 = i;
                while i < bytes.len() && bytes[i] != b'"' && bytes[i] != b'\n' {
                    i += 1;
                }
                if i >= bytes.len() || bytes[i] != b'"' {
                    return Err(SpecError::new(
                        "unterminated string",
                        Span::new(start, i),
                    ));
                }
                out.push(Token {
                    tok: Tok::Str(src[s0..i].to_string()),
                    span: Span::new(start, i + 1),
                });
                i += 1;
            }
            _ if is_ident_start(b) => {
                let start = i;
                while i < bytes.len() && is_ident_continue(bytes[i]) {
                    i += 1;
                }
                out.push(Token {
                    tok: Tok::Ident(src[start..i].to_string()),
                    span: Span::new(start, i),
                });
            }
            _ if b.is_ascii_digit()
                || ((b == b'-' || b == b'+')
                    && i + 1 < bytes.len()
                    && (bytes[i + 1].is_ascii_digit() || bytes[i + 1] == b'.')) =>
            {
                let start = i;
                if b == b'-' || b == b'+' {
                    i += 1;
                }
                while i < bytes.len() && (bytes[i].is_ascii_digit() || bytes[i] == b'.') {
                    i += 1;
                }
                // exponent: e/E, optional sign, at least one digit
                if i < bytes.len() && (bytes[i] == b'e' || bytes[i] == b'E') {
                    let mut j = i + 1;
                    if j < bytes.len() && (bytes[j] == b'-' || bytes[j] == b'+') {
                        j += 1;
                    }
                    if j < bytes.len() && bytes[j].is_ascii_digit() {
                        i = j;
                        while i < bytes.len() && bytes[i].is_ascii_digit() {
                            i += 1;
                        }
                    }
                }
                let span = Span::new(start, i);
                let text = &src[start..i];
                let n: f64 = text.parse().map_err(|_| {
                    SpecError::new(format!("invalid number {text:?}"), span)
                })?;
                out.push(Token { tok: Tok::Num(n), span });
            }
            _ => {
                let ch = src[i..].chars().next().unwrap_or('?');
                return Err(SpecError::new(
                    format!("unexpected character {ch:?}"),
                    Span::new(i, i + ch.len_utf8()),
                ));
            }
        }
    }
    out.push(Token { tok: Tok::Eof, span: Span::new(bytes.len(), bytes.len()) });
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn kinds(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|t| t.tok).collect()
    }

    #[test]
    fn lexes_axis_product_line() {
        let toks = kinds("grid: method=[qat,lotion] x lr=logspace(-3,-1,8)");
        assert_eq!(toks[0], Tok::Ident("grid".into()));
        assert_eq!(toks[1], Tok::Colon);
        assert_eq!(toks[2], Tok::Ident("method".into()));
        assert_eq!(toks[3], Tok::Eq);
        assert_eq!(toks[4], Tok::LBracket);
        assert_eq!(toks[5], Tok::Ident("qat".into()));
        assert!(toks.contains(&Tok::Ident("x".into())));
        assert!(toks.contains(&Tok::Ident("logspace".into())));
        assert!(toks.contains(&Tok::Num(-3.0)));
        assert_eq!(*toks.last().unwrap(), Tok::Eof);
    }

    #[test]
    fn numbers_idents_and_formats() {
        assert_eq!(kinds("3e-3")[0], Tok::Num(3e-3));
        assert_eq!(kinds("-0.5")[0], Tok::Num(-0.5));
        assert_eq!(kinds("int4@64")[0], Tok::Ident("int4@64".into()));
        assert_eq!(kinds("lm-150m-sim")[0], Tok::Ident("lm-150m-sim".into()));
        assert_eq!(kinds("est.sigma0")[0], Tok::Ident("est.sigma0".into()));
        assert_eq!(kinds("\"two words\"")[0], Tok::Str("two words".into()));
    }

    #[test]
    fn comments_and_newlines() {
        let toks = kinds("a = 1 # trailing\n# full line\nb = 2");
        assert_eq!(
            toks,
            vec![
                Tok::Ident("a".into()),
                Tok::Eq,
                Tok::Num(1.0),
                Tok::Newline,
                Tok::Newline,
                Tok::Ident("b".into()),
                Tok::Eq,
                Tok::Num(2.0),
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn spans_are_byte_offsets() {
        let toks = lex("ab = 12").unwrap();
        assert_eq!(toks[0].span, Span::new(0, 2));
        assert_eq!(toks[1].span, Span::new(3, 4));
        assert_eq!(toks[2].span, Span::new(5, 7));
        assert_eq!(toks[3].span, Span::new(7, 7)); // Eof
    }

    #[test]
    fn bad_inputs_error_with_spans() {
        let e = lex("a = 1.2.3").unwrap_err();
        assert!(e.msg.contains("invalid number"), "{}", e.msg);
        assert_eq!(e.span.start, 4);
        let e = lex("a = \"open").unwrap_err();
        assert!(e.msg.contains("unterminated string"), "{}", e.msg);
        let e = lex("a = !").unwrap_err();
        assert!(e.msg.contains("unexpected character"), "{}", e.msg);
        assert_eq!(e.span.start, 4);
    }
}
