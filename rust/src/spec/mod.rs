//! Sweep-spec DSL (DESIGN.md §10): a small experiment language that
//! feeds parsed grids straight into the sharded
//! [`SweepRunner`](crate::coordinator::sweep::SweepRunner).
//!
//! A spec is a line-oriented text file:
//!
//! ```text
//! # fig2-style product, method-major
//! name  = demo
//! model = linreg_d256
//! steps = 200
//!
//! grid: method=[qat,rat,lotion] x lr=logspace(-3,-1,8)
//! when method=lotion: lambda=0.1
//! seeds = 3
//! ```
//!
//! * [`parse`] — lexer + recursive-descent parser; byte-offset spans,
//!   caret-underlined errors ([`SpecError::render`]).
//! * [`expand`] — deterministic grid expansion into labeled, validated
//!   [`SweepPoint`](crate::coordinator::sweep::SweepPoint)s.
//! * [`plan`] — the CLI entry: parse + expand + stamp the source
//!   [`digest`] used to guard journal resume against edited specs.
//!
//! No new dependencies: the parser is hand-rolled, the digest is the
//! same FNV-1a the config layer uses.

pub mod ast;
pub mod expand;
pub mod lexer;
pub mod parser;

pub use ast::{SpecAst, SpecError};
pub use expand::{expand, SweepPlan, KNOWN_KEYS, PLAN_KEYS};
pub use parser::parse;

use crate::config::RunConfig;

/// FNV-1a 64 digest of the raw spec source. Stamped into every journal
/// entry a spec-driven sweep writes, so `--resume-sweep` against a
/// *changed* spec is refused instead of silently mixing grids.
pub fn digest(src: &str) -> String {
    let mut h: u64 = 0xcbf29ce484222325;
    for b in src.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    format!("{h:016x}")
}

/// Parse + expand a spec source into a runnable [`SweepPlan`], with
/// errors rendered against the source as `origin:line:col` + caret
/// underline. `known_models` (when the backend can enumerate presets)
/// validates `model =` values before anything spawns.
pub fn plan(
    src: &str,
    origin: &str,
    base: &RunConfig,
    known_models: Option<&[String]>,
) -> anyhow::Result<SweepPlan> {
    let ast = parse(src).map_err(|e| e.to_anyhow(src, origin))?;
    let mut plan = expand(&ast, base, known_models).map_err(|e| e.to_anyhow(src, origin))?;
    plan.digest = digest(src);
    Ok(plan)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    const GOLDEN: &str = "name = g\nmodel = linreg_d256\nsteps = 16\n\
                          grid: method=[qat,lotion] x lr=[0.1,0.2]\n\
                          when method=lotion: lambda=0.5\n";

    /// Pinned digests: the journal refusal contract depends on these
    /// staying put across refactors (entries written by one build must
    /// resume under the next).
    #[test]
    fn digest_is_pinned_fnv1a() {
        assert_eq!(digest(""), "cbf29ce484222325");
        assert_eq!(digest("abc"), "e71fa2190541574b");
        assert_eq!(digest(GOLDEN), "32e004e1b0e69803");
        assert_ne!(digest(GOLDEN), digest(&GOLDEN.replace("16", "32")));
    }

    #[test]
    fn plan_stamps_digest_and_renders_errors() {
        let base = RunConfig::default();
        let p = plan(GOLDEN, "g.sweep", &base, None).unwrap();
        assert_eq!(p.digest, digest(GOLDEN));
        assert_eq!(p.points.len(), 4);

        let src = "grid: method [qat]\n";
        let err = plan(src, "bad.sweep", &base, None).unwrap_err().to_string();
        // rendered, caret-underlined, pointing into the named origin
        assert!(err.starts_with("bad.sweep:1:14:"), "{err}");
        assert!(err.contains('^'), "{err}");
        assert!(err.contains("grid: method [qat]"), "{err}");
    }

    /// Hand-rolled fuzz loop (proptest is unavailable offline): random
    /// byte mutations of a valid spec must never panic — every input
    /// either parses or returns a spanned `Err`.
    #[test]
    fn fuzz_mutations_never_panic() {
        let base = RunConfig::default();
        let seed_corpus: [&str; 4] = [
            GOLDEN,
            "grid: method=[qat,rat,lotion,anneal] x lr=logspace(-3,-1,8) x format=[fp4,int8,int4@64]\n",
            "seeds = 5\nschedule = cosine\nwarmup = 2\nwhen method=lotion, lr=0.1: lambda=0.1\ngrid: method=[lotion] x lr=[0.1]\n",
            "est.schedule = cosine\nest.sigma0 = 0.5\neval_formats = [int4, int8]\n",
        ];
        let mut rng = Rng::new(0xF00D);
        for src in &seed_corpus {
            for round in 0..400 {
                let mut bytes = src.as_bytes().to_vec();
                for _ in 0..=(round % 4) {
                    match rng.below(3) {
                        0 if !bytes.is_empty() => {
                            // flip a byte to a random printable-ish value
                            let i = rng.below(bytes.len() as u64) as usize;
                            bytes[i] = (rng.below(96) + 32) as u8;
                        }
                        1 if !bytes.is_empty() => {
                            let i = rng.below(bytes.len() as u64) as usize;
                            bytes.remove(i);
                        }
                        _ => {
                            let i = rng.below(bytes.len() as u64 + 1) as usize;
                            bytes.insert(i, (rng.below(96) + 32) as u8);
                        }
                    }
                }
                let mutated = String::from_utf8_lossy(&bytes).into_owned();
                // must not panic; Ok and Err are both acceptable
                let _ = plan(&mutated, "fuzz.sweep", &base, None);
            }
        }
    }
}
