//! Hand-rolled recursive-descent parser for the sweep-spec grammar.
//!
//! ```text
//! spec    := (stmt? NEWLINE)* stmt?
//! stmt    := grid | when | assign
//! grid    := 'grid' ':' axis ('x' axis)*
//! axis    := KEY '=' value
//! when    := 'when' cond (',' cond)* ':' assign (',' assign)*
//! cond    := KEY '=' scalar
//! assign  := KEY '=' value
//! value   := scalar | list | range
//! list    := '[' scalar (',' scalar)* ']'
//! range   := ('linspace' | 'logspace') '(' NUM ',' NUM ',' NUM ')'
//! scalar  := NUM | IDENT | STRING
//! ```
//!
//! `grid` and `when` are contextual keywords: `grid` is only a keyword
//! when followed by `:`, `when` only when *not* followed by `=`, so
//! both remain usable as config keys. Ranges are expanded to explicit
//! value lists here at parse time; every expanded element keeps the
//! range call's span so later errors still point at the source.

use super::ast::{Assign, Axis, Cond, Scalar, ScalarNode, Span, SpecAst, SpecError, Stmt, ValueNode};
use super::lexer::{lex, Tok, Token};

/// Parse a spec source into its AST. Errors carry byte-offset spans;
/// render them against `src` with [`SpecError::render`].
pub fn parse(src: &str) -> Result<SpecAst, SpecError> {
    let tokens = lex(src)?;
    Parser { tokens, pos: 0 }.spec()
}

struct Parser {
    tokens: Vec<Token>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Token {
        &self.tokens[self.pos]
    }

    fn peek2(&self) -> &Token {
        &self.tokens[(self.pos + 1).min(self.tokens.len() - 1)]
    }

    fn bump(&mut self) -> Token {
        let t = self.tokens[self.pos].clone();
        if self.pos + 1 < self.tokens.len() {
            self.pos += 1;
        }
        t
    }

    fn expect(&mut self, want: &Tok, what: &str) -> Result<Token, SpecError> {
        let t = self.peek().clone();
        if std::mem::discriminant(&t.tok) == std::mem::discriminant(want) {
            Ok(self.bump())
        } else {
            Err(SpecError::new(
                format!("expected {what}, found {}", t.tok.describe()),
                t.span,
            ))
        }
    }

    fn spec(&mut self) -> Result<SpecAst, SpecError> {
        let mut stmts = Vec::new();
        loop {
            match &self.peek().tok {
                Tok::Eof => break,
                Tok::Newline => {
                    self.bump();
                }
                _ => {
                    stmts.push(self.stmt()?);
                    // a statement must end the line
                    let t = self.peek().clone();
                    match t.tok {
                        Tok::Newline => {
                            self.bump();
                        }
                        Tok::Eof => {}
                        _ => {
                            return Err(SpecError::new(
                                format!("expected end of line, found {}", t.tok.describe()),
                                t.span,
                            ))
                        }
                    }
                }
            }
        }
        Ok(SpecAst { stmts })
    }

    fn stmt(&mut self) -> Result<Stmt, SpecError> {
        let t = self.peek().clone();
        match &t.tok {
            Tok::Ident(w) if w == "grid" && self.peek2().tok == Tok::Colon => self.grid(),
            Tok::Ident(w) if w == "when" && self.peek2().tok != Tok::Eq => self.when(),
            Tok::Ident(_) => Ok(Stmt::Assign(self.assign()?)),
            _ => Err(SpecError::new(
                format!(
                    "expected a statement (`key = value`, `grid:`, or `when`), found {}",
                    t.tok.describe()
                ),
                t.span,
            )),
        }
    }

    /// `grid ':' axis ('x' axis)*`
    fn grid(&mut self) -> Result<Stmt, SpecError> {
        let kw = self.bump(); // 'grid'
        self.expect(&Tok::Colon, "':' after `grid`")?;
        let mut axes = vec![self.axis()?];
        loop {
            match &self.peek().tok {
                Tok::Ident(w) if w == "x" => {
                    self.bump();
                    axes.push(self.axis()?);
                }
                _ => break,
            }
        }
        let span = kw.span.join(axes.last().map(|a| a.key_span).unwrap_or(kw.span));
        Ok(Stmt::Grid { axes, span })
    }

    /// `KEY '=' value` where the value is coerced to a list (a scalar
    /// axis is a 1-element axis).
    fn axis(&mut self) -> Result<Axis, SpecError> {
        let (key, key_span) = self.key("axis name")?;
        self.expect(&Tok::Eq, "'=' after axis name")?;
        let values = match self.value()? {
            ValueNode::Scalar(s) => vec![s],
            ValueNode::List(vs, _) => vs,
        };
        Ok(Axis { key, key_span, values })
    }

    /// `when cond (',' cond)* ':' assign (',' assign)*`
    fn when(&mut self) -> Result<Stmt, SpecError> {
        self.bump(); // 'when'
        let mut conds = vec![self.cond()?];
        while self.peek().tok == Tok::Comma {
            self.bump();
            conds.push(self.cond()?);
        }
        self.expect(&Tok::Colon, "':' after `when` conditions")?;
        let mut assigns = vec![self.assign()?];
        while self.peek().tok == Tok::Comma {
            self.bump();
            assigns.push(self.assign()?);
        }
        Ok(Stmt::When { conds, assigns })
    }

    fn cond(&mut self) -> Result<Cond, SpecError> {
        let (key, key_span) = self.key("condition key")?;
        self.expect(&Tok::Eq, "'=' in `when` condition")?;
        let value = self.scalar()?;
        Ok(Cond { key, key_span, value })
    }

    fn assign(&mut self) -> Result<Assign, SpecError> {
        let (key, key_span) = self.key("config key")?;
        self.expect(&Tok::Eq, "'=' after key")?;
        let value = self.value()?;
        Ok(Assign { key, key_span, value })
    }

    fn key(&mut self, what: &str) -> Result<(String, Span), SpecError> {
        let t = self.peek().clone();
        match t.tok {
            Tok::Ident(w) => {
                self.bump();
                Ok((w, t.span))
            }
            _ => Err(SpecError::new(
                format!("expected {what}, found {}", t.tok.describe()),
                t.span,
            )),
        }
    }

    fn value(&mut self) -> Result<ValueNode, SpecError> {
        let t = self.peek().clone();
        match &t.tok {
            Tok::LBracket => self.list(),
            Tok::Ident(w) if (w == "linspace" || w == "logspace") && self.peek2().tok == Tok::LParen => {
                self.range()
            }
            _ => Ok(ValueNode::Scalar(self.scalar()?)),
        }
    }

    /// `'[' scalar (',' scalar)* ']'` — empty lists are an error.
    fn list(&mut self) -> Result<ValueNode, SpecError> {
        let open = self.bump(); // '['
        if self.peek().tok == Tok::RBracket {
            let close = self.bump();
            return Err(SpecError::new("empty list", open.span.join(close.span)));
        }
        let mut vs = vec![self.scalar()?];
        while self.peek().tok == Tok::Comma {
            self.bump();
            vs.push(self.scalar()?);
        }
        let close = self.expect(&Tok::RBracket, "']' or ',' in list")?;
        Ok(ValueNode::List(vs, open.span.join(close.span)))
    }

    /// `linspace(a, b, n)` / `logspace(a, b, n)` — expanded here to an
    /// explicit value list. `logspace` yields `10^x` over the linear
    /// ramp, so `logspace(-3, -1, 3)` is `[1e-3, 1e-2, 1e-1]`.
    fn range(&mut self) -> Result<ValueNode, SpecError> {
        let kw = self.bump();
        let name = match &kw.tok {
            Tok::Ident(w) => w.clone(),
            _ => unreachable!("range called off a non-ident"),
        };
        self.expect(&Tok::LParen, "'('")?;
        let a = self.num()?;
        self.expect(&Tok::Comma, "',' between range arguments")?;
        let b = self.num()?;
        self.expect(&Tok::Comma, "',' between range arguments")?;
        let (n, n_span) = self.num_spanned()?;
        let close = self.expect(&Tok::RParen, "')'")?;
        let span = kw.span.join(close.span);
        if n.fract() != 0.0 || n < 1.0 || n > 1_000_000.0 {
            return Err(SpecError::new(
                format!("{name} count must be an integer >= 1, got {n}"),
                n_span,
            ));
        }
        let n = n as usize;
        let mut vs = Vec::with_capacity(n);
        for k in 0..n {
            let t = if n == 1 { 0.0 } else { k as f64 / (n - 1) as f64 };
            let x = a + (b - a) * t;
            let v = if name == "logspace" { 10f64.powf(x) } else { x };
            vs.push(ScalarNode { v: Scalar::Num(v), span });
        }
        Ok(ValueNode::List(vs, span))
    }

    fn num(&mut self) -> Result<f64, SpecError> {
        self.num_spanned().map(|(n, _)| n)
    }

    fn num_spanned(&mut self) -> Result<(f64, Span), SpecError> {
        let t = self.peek().clone();
        match t.tok {
            Tok::Num(n) => {
                self.bump();
                Ok((n, t.span))
            }
            _ => Err(SpecError::new(
                format!("expected a number, found {}", t.tok.describe()),
                t.span,
            )),
        }
    }

    fn scalar(&mut self) -> Result<ScalarNode, SpecError> {
        let t = self.peek().clone();
        match t.tok {
            Tok::Num(n) => {
                self.bump();
                Ok(ScalarNode { v: Scalar::Num(n), span: t.span })
            }
            Tok::Ident(w) => {
                self.bump();
                Ok(ScalarNode { v: Scalar::Word(w), span: t.span })
            }
            Tok::Str(s) => {
                self.bump();
                Ok(ScalarNode { v: Scalar::Word(s), span: t.span })
            }
            _ => Err(SpecError::new(
                format!("expected a value, found {}", t.tok.describe()),
                t.span,
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn nums(vs: &[ScalarNode]) -> Vec<f64> {
        vs.iter()
            .map(|s| match s.v {
                Scalar::Num(n) => n,
                _ => panic!("expected number"),
            })
            .collect()
    }

    fn words(vs: &[ScalarNode]) -> Vec<&str> {
        vs.iter()
            .map(|s| match &s.v {
                Scalar::Word(w) => w.as_str(),
                _ => panic!("expected word"),
            })
            .collect()
    }

    #[test]
    fn parses_defaults_grid_and_when() {
        let ast = parse(
            "name = demo\n\
             model = linreg_d256\n\
             grid: method=[qat,lotion] x lr=[0.1,0.2]\n\
             when method=lotion: lambda=0.5\n",
        )
        .unwrap();
        assert_eq!(ast.stmts.len(), 4);
        match &ast.stmts[2] {
            Stmt::Grid { axes, .. } => {
                assert_eq!(axes.len(), 2);
                assert_eq!(axes[0].key, "method");
                assert_eq!(words(&axes[0].values), ["qat", "lotion"]);
                assert_eq!(axes[1].key, "lr");
                assert_eq!(nums(&axes[1].values), [0.1, 0.2]);
            }
            s => panic!("expected grid, got {s:?}"),
        }
        match &ast.stmts[3] {
            Stmt::When { conds, assigns } => {
                assert_eq!(conds[0].key, "method");
                assert_eq!(conds[0].value.v, Scalar::Word("lotion".into()));
                assert_eq!(assigns[0].key, "lambda");
            }
            s => panic!("expected when, got {s:?}"),
        }
    }

    #[test]
    fn expands_linspace_and_logspace() {
        let ast = parse("grid: lr=logspace(-3,-1,3)\nsigma = linspace(0,1,5)\n").unwrap();
        match &ast.stmts[0] {
            Stmt::Grid { axes, .. } => {
                let v = nums(&axes[0].values);
                assert_eq!(v.len(), 3);
                assert!((v[0] - 1e-3).abs() < 1e-12, "{v:?}");
                assert!((v[1] - 1e-2).abs() < 1e-12, "{v:?}");
                assert!((v[2] - 1e-1).abs() < 1e-12, "{v:?}");
            }
            s => panic!("expected grid, got {s:?}"),
        }
        match &ast.stmts[1] {
            Stmt::Assign(a) => match &a.value {
                ValueNode::List(vs, _) => assert_eq!(nums(vs), [0.0, 0.25, 0.5, 0.75, 1.0]),
                v => panic!("expected list, got {v:?}"),
            },
            s => panic!("expected assign, got {s:?}"),
        }
    }

    #[test]
    fn single_element_range_and_scalar_axis() {
        let ast = parse("grid: lr=linspace(2,9,1) x method=qat\n").unwrap();
        match &ast.stmts[0] {
            Stmt::Grid { axes, .. } => {
                assert_eq!(nums(&axes[0].values), [2.0]);
                assert_eq!(words(&axes[1].values), ["qat"]);
            }
            s => panic!("expected grid, got {s:?}"),
        }
    }

    #[test]
    fn grid_and_when_stay_usable_as_keys() {
        // `grid = 4` (no colon) and `when = x` (followed by '=') are
        // plain assignments, not keywords.
        let ast = parse("grid = 4\nwhen = off\n").unwrap();
        assert_eq!(ast.stmts.len(), 2);
        assert!(matches!(&ast.stmts[0], Stmt::Assign(a) if a.key == "grid"));
        assert!(matches!(&ast.stmts[1], Stmt::Assign(a) if a.key == "when"));
    }

    #[test]
    fn golden_error_positions() {
        // missing '=' in an axis
        let src = "grid: method [qat]\n";
        let e = parse(src).unwrap_err();
        let r = e.render(src, "t.sweep");
        assert_eq!(
            r,
            "t.sweep:1:14: expected '=' after axis name, found '['\n  grid: method [qat]\n               ^"
        );

        // unterminated list
        let src = "lrs = [0.1, 0.2\n";
        let e = parse(src).unwrap_err();
        let r = e.render(src, "t.sweep");
        assert_eq!(
            r,
            "t.sweep:1:16: expected ']' or ',' in list, found end of line\n  lrs = [0.1, 0.2\n                 ^"
        );

        // non-integer range count
        let src = "lr = logspace(-3, -1, 2.5)\n";
        let e = parse(src).unwrap_err();
        assert!(e.msg.contains("count must be an integer"), "{}", e.msg);
        assert_eq!(&src[e.span.start..e.span.end], "2.5");

        // trailing junk after a statement
        let src = "steps = 16 32\n";
        let e = parse(src).unwrap_err();
        assert!(e.msg.contains("expected end of line"), "{}", e.msg);
        assert_eq!(&src[e.span.start..e.span.end], "32");
    }

    #[test]
    fn empty_list_is_an_error() {
        let e = parse("lrs = []\n").unwrap_err();
        assert_eq!(e.msg, "empty list");
    }

    #[test]
    fn eof_without_trailing_newline_is_fine() {
        let ast = parse("steps = 16").unwrap();
        assert_eq!(ast.stmts.len(), 1);
    }
}
