//! Host tensors: the L3-side value type for parameters, optimizer
//! state, batches and metrics. Deliberately xla-free so the quant /
//! data / checkpoint substrates stay testable without a PJRT client;
//! `runtime::literals` owns the Literal conversions.

use anyhow::{bail, Result};

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    pub fn size(self) -> usize {
        4
    }

    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "f32" => DType::F32,
            "i32" => DType::I32,
            "u32" => DType::U32,
            other => bail!("unsupported dtype {other:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "f32",
            DType::I32 => "i32",
            DType::U32 => "u32",
        }
    }
}

/// Dense host tensor: shape + dtype + little-endian bytes.
#[derive(Clone, Debug, PartialEq)]
pub struct HostTensor {
    pub dtype: DType,
    pub shape: Vec<usize>,
    data: Vec<u8>,
}

impl HostTensor {
    pub fn zeros(dtype: DType, shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        HostTensor { dtype, shape: shape.to_vec(), data: vec![0u8; n * dtype.size()] }
    }

    pub fn from_f32(shape: &[usize], values: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::F32, shape: shape.to_vec(), data }
    }

    pub fn from_i32(shape: &[usize], values: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::I32, shape: shape.to_vec(), data }
    }

    pub fn from_u32(shape: &[usize], values: Vec<u32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), values.len());
        let mut data = Vec::with_capacity(values.len() * 4);
        for v in &values {
            data.extend_from_slice(&v.to_le_bytes());
        }
        HostTensor { dtype: DType::U32, shape: shape.to_vec(), data }
    }

    pub fn scalar_f32(v: f32) -> Self {
        Self::from_f32(&[], vec![v])
    }

    pub fn len(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn bytes(&self) -> &[u8] {
        &self.data
    }

    pub fn bytes_mut(&mut self) -> &mut [u8] {
        &mut self.data
    }

    pub fn from_bytes(dtype: DType, shape: &[usize], data: Vec<u8>) -> Result<Self> {
        let n: usize = shape.iter().product();
        if data.len() != n * dtype.size() {
            bail!("byte length {} != {} elements x 4", data.len(), n);
        }
        Ok(HostTensor { dtype, shape: shape.to_vec(), data })
    }

    /// View as f32 (panics on dtype mismatch — programmer error).
    pub fn as_f32(&self) -> Vec<f32> {
        assert_eq!(self.dtype, DType::F32);
        self.data
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_i32(&self) -> Vec<i32> {
        assert_eq!(self.dtype, DType::I32);
        self.data
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    pub fn as_u32(&self) -> Vec<u32> {
        assert_eq!(self.dtype, DType::U32);
        self.data
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect()
    }

    /// In-place f32 mutation through a callback (avoids copies on the
    /// hot path: quantized eval casts params this way).
    pub fn map_f32_inplace(&mut self, f: impl FnOnce(&mut [f32])) {
        assert_eq!(self.dtype, DType::F32);
        // Safety-free path: decode, mutate, re-encode. The data is
        // little-endian f32 on every supported platform; do it with
        // chunk views to avoid unsafe.
        let mut vals = self.as_f32();
        f(&mut vals);
        for (chunk, v) in self.data.chunks_exact_mut(4).zip(&vals) {
            chunk.copy_from_slice(&v.to_le_bytes());
        }
    }

    pub fn scalar_to_f32(&self) -> f32 {
        assert_eq!(self.len(), 1);
        f32::from_le_bytes(self.data[..4].try_into().unwrap())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_f32() {
        let t = HostTensor::from_f32(&[2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.len(), 6);
        assert_eq!(t.as_f32(), vec![1., 2., 3., 4., 5., 6.]);
    }

    #[test]
    fn zeros_and_scalar() {
        let t = HostTensor::zeros(DType::I32, &[4]);
        assert_eq!(t.as_i32(), vec![0; 4]);
        assert_eq!(HostTensor::scalar_f32(2.5).scalar_to_f32(), 2.5);
    }

    #[test]
    fn u32_roundtrip() {
        let t = HostTensor::from_u32(&[3], vec![0, 7, u32::MAX]);
        assert_eq!(t.as_u32(), vec![0, 7, u32::MAX]);
    }

    #[test]
    fn map_inplace() {
        let mut t = HostTensor::from_f32(&[3], vec![1., -2., 3.]);
        t.map_f32_inplace(|v| v.iter_mut().for_each(|x| *x *= 2.0));
        assert_eq!(t.as_f32(), vec![2., -4., 6.]);
    }

    #[test]
    fn from_bytes_validates() {
        assert!(HostTensor::from_bytes(DType::F32, &[2], vec![0u8; 7]).is_err());
        assert!(HostTensor::from_bytes(DType::F32, &[2], vec![0u8; 8]).is_ok());
    }

    #[test]
    fn dtype_parse() {
        assert_eq!(DType::parse("f32").unwrap(), DType::F32);
        assert!(DType::parse("f64").is_err());
    }
}
