//! Deterministic fault injection for crash-safety tests.
//!
//! A *fault plan* is a comma-separated list of entries
//! `kind@site:ordinal[xN]`, e.g.
//! `LOTION_FAULTS=panic@point:3,io_err@ckpt_save:2,kill@step:40`.
//! Instrumented code consults [`poke(site, ordinal)`](poke) at
//! well-defined check-points; when an armed entry matches, the fault
//! fires: `panic` unwinds, `io_err` returns `std::io::Error`, `kill`
//! exits the process with [`KILL_EXIT`].
//!
//! Determinism: the *caller* supplies the ordinal — a stable logical
//! position (the trainer step number, the sweep grid index, the
//! process-wide checkpoint save sequence) rather than a racy hit
//! count — so the same plan fires at the same logical point at any
//! `--threads`/`--sweep-workers` width. Each entry fires `N` times
//! (default 1) and then disarms, so a retried sweep point succeeds on
//! its second attempt instead of panicking forever.
//!
//! Tests install *thread-local* plans via [`ScopedPlan`]; a local plan
//! takes full precedence over the process-wide `LOTION_FAULTS` plan
//! (no fallthrough), so parallel unit tests can't poison each other
//! and CI env plans can't leak into scoped tests. When neither is set,
//! `poke` is a single relaxed atomic load — zero cost in production.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, Once};

use anyhow::{bail, Result};

/// Exit code used by `kill` faults, distinguishable from panics (101)
/// and clean exits so tests can assert the injected kill happened.
pub const KILL_EXIT: i32 = 86;

/// Sites instrumented in the codebase (callers pass these as `site`):
/// `step` (trainer loop, ordinal = step), `ckpt_save` (checkpoint
/// writer, ordinal = process-wide save sequence, consulted after the
/// temp-file fsync and *before* the rename so a kill there proves
/// rename atomicity), `point` (sweep point boundary, ordinal = grid
/// index), `pool_job` (worker-pool task dispatch, ordinal = task
/// index).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FaultKind {
    Panic,
    IoErr,
    Kill,
}

impl FaultKind {
    fn parse(s: &str) -> Result<FaultKind> {
        match s {
            "panic" => Ok(FaultKind::Panic),
            "io_err" => Ok(FaultKind::IoErr),
            "kill" => Ok(FaultKind::Kill),
            _ => bail!("unknown fault kind {s:?} (expected panic|io_err|kill)"),
        }
    }
}

struct FaultEntry {
    kind: FaultKind,
    site: String,
    at: u64,
    /// shots left; entries disarm at 0 so retries make progress
    remaining: AtomicU64,
}

/// A parsed fault plan: a fixed set of armed entries.
pub struct FaultPlan {
    entries: Vec<FaultEntry>,
}

impl FaultPlan {
    /// Parse `kind@site:ordinal[xN]` entries, comma-separated. Empty
    /// tokens are skipped so trailing commas are harmless.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut entries = Vec::new();
        for tok in spec.split(',') {
            let tok = tok.trim();
            if tok.is_empty() {
                continue;
            }
            let (kind_s, rest) = tok
                .split_once('@')
                .ok_or_else(|| anyhow::anyhow!("fault entry {tok:?} missing '@'"))?;
            let (site, at_s) = rest
                .split_once(':')
                .ok_or_else(|| anyhow::anyhow!("fault entry {tok:?} missing ':'"))?;
            let (at_s, times) = match at_s.split_once('x') {
                Some((a, n)) => {
                    let n: u64 = n
                        .parse()
                        .map_err(|_| anyhow::anyhow!("bad repeat count in {tok:?}"))?;
                    (a, n)
                }
                None => (at_s, 1),
            };
            let at: u64 = at_s
                .parse()
                .map_err(|_| anyhow::anyhow!("bad ordinal in fault entry {tok:?}"))?;
            if site.is_empty() {
                bail!("fault entry {tok:?} has empty site");
            }
            entries.push(FaultEntry {
                kind: FaultKind::parse(kind_s)?,
                site: site.to_string(),
                at,
                remaining: AtomicU64::new(times),
            });
        }
        Ok(FaultPlan { entries })
    }

    /// Consume one shot of a matching armed entry, if any. Atomic: a
    /// single-shot entry observed by two racing threads fires exactly
    /// once.
    fn fire(&self, site: &str, ordinal: u64) -> Option<FaultKind> {
        for e in &self.entries {
            if e.at == ordinal && e.site == site {
                let claimed = e
                    .remaining
                    .fetch_update(Ordering::AcqRel, Ordering::Acquire, |n| n.checked_sub(1))
                    .is_ok();
                if claimed {
                    return Some(e.kind);
                }
            }
        }
        None
    }
}

static ENV_INIT: Once = Once::new();
static ENV_ARMED: AtomicBool = AtomicBool::new(false);
static ENV_PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);

thread_local! {
    static LOCAL_PLAN: RefCell<Vec<Arc<FaultPlan>>> = RefCell::new(Vec::new());
    static LOCAL_ARMED: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

fn env_plan() -> Option<Arc<FaultPlan>> {
    ENV_INIT.call_once(|| {
        if let Ok(spec) = std::env::var("LOTION_FAULTS") {
            if spec.trim().is_empty() {
                return;
            }
            match FaultPlan::parse(&spec) {
                Ok(plan) => {
                    *ENV_PLAN.lock().unwrap() = Some(Arc::new(plan));
                    ENV_ARMED.store(true, Ordering::Release);
                }
                Err(e) => {
                    eprintln!("WARN: ignoring malformed LOTION_FAULTS: {e}");
                }
            }
        }
    });
    if !ENV_ARMED.load(Ordering::Acquire) {
        return None;
    }
    ENV_PLAN.lock().unwrap().clone()
}

fn trip(kind: FaultKind, site: &str, ordinal: u64) -> std::io::Result<()> {
    match kind {
        FaultKind::Panic => panic!("fault injection: panic@{site}:{ordinal}"),
        FaultKind::IoErr => Err(std::io::Error::other(format!(
            "fault injection: io_err@{site}:{ordinal}"
        ))),
        FaultKind::Kill => {
            eprintln!("fault injection: kill@{site}:{ordinal}");
            std::process::exit(KILL_EXIT);
        }
    }
}

/// Consult the fault plan at a check-point. The innermost
/// thread-local [`ScopedPlan`] takes full precedence (no fallthrough
/// to the env plan while one is installed); otherwise the
/// `LOTION_FAULTS` plan applies. Zero cost when neither is armed.
pub fn poke(site: &str, ordinal: u64) -> std::io::Result<()> {
    if LOCAL_ARMED.with(|a| a.get()) {
        let fired = LOCAL_PLAN.with(|p| {
            p.borrow()
                .last()
                .and_then(|plan| plan.fire(site, ordinal))
        });
        return match fired {
            Some(kind) => trip(kind, site, ordinal),
            None => Ok(()),
        };
    }
    if let Some(plan) = env_plan() {
        if let Some(kind) = plan.fire(site, ordinal) {
            return trip(kind, site, ordinal);
        }
    }
    Ok(())
}

/// RAII guard installing a thread-local fault plan for tests. While
/// installed, this thread's `poke` calls consult only this plan (the
/// process-wide env plan is shadowed entirely). `!Send` so the Drop
/// pops on the installing thread.
pub struct ScopedPlan {
    _not_send: std::marker::PhantomData<*const ()>,
}

impl ScopedPlan {
    pub fn install(spec: &str) -> Result<ScopedPlan> {
        let plan = Arc::new(FaultPlan::parse(spec)?);
        LOCAL_PLAN.with(|p| p.borrow_mut().push(plan));
        LOCAL_ARMED.with(|a| a.set(true));
        Ok(ScopedPlan { _not_send: std::marker::PhantomData })
    }
}

impl Drop for ScopedPlan {
    fn drop(&mut self) {
        LOCAL_PLAN.with(|p| {
            let mut v = p.borrow_mut();
            v.pop();
            if v.is_empty() {
                LOCAL_ARMED.with(|a| a.set(false));
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("boom@step:3").is_err());
        assert!(FaultPlan::parse("panic@step").is_err());
        assert!(FaultPlan::parse("panic:3").is_err());
        assert!(FaultPlan::parse("panic@:3").is_err());
        assert!(FaultPlan::parse("panic@step:abc").is_err());
        assert!(FaultPlan::parse("panic@step:3xzz").is_err());
    }

    #[test]
    fn parse_accepts_empty_and_trailing_commas() {
        assert!(FaultPlan::parse("").unwrap().entries.is_empty());
        let p = FaultPlan::parse("panic@a:1,,io_err@b:2,").unwrap();
        assert_eq!(p.entries.len(), 2);
    }

    #[test]
    fn scoped_plan_fires_once_then_disarms() {
        let _g = ScopedPlan::install("io_err@site:7").unwrap();
        assert!(poke("site", 6).is_ok());
        assert!(poke("other", 7).is_ok());
        assert!(poke("site", 7).is_err());
        // single-shot: disarmed after firing
        assert!(poke("site", 7).is_ok());
    }

    #[test]
    fn repeat_count_fires_n_times() {
        let _g = ScopedPlan::install("io_err@s:1x3").unwrap();
        for _ in 0..3 {
            assert!(poke("s", 1).is_err());
        }
        assert!(poke("s", 1).is_ok());
        // x0 means never
        let _g2 = ScopedPlan::install("io_err@s:1x0").unwrap();
        assert!(poke("s", 1).is_ok());
    }

    #[test]
    fn scoped_plans_nest_innermost_wins() {
        let _outer = ScopedPlan::install("io_err@a:1").unwrap();
        {
            let _inner = ScopedPlan::install("io_err@b:2").unwrap();
            // inner shadows outer entirely: a:1 does not fire
            assert!(poke("a", 1).is_ok());
            assert!(poke("b", 2).is_err());
        }
        assert!(poke("a", 1).is_err());
    }

    #[test]
    #[should_panic(expected = "fault injection: panic@p:3")]
    fn panic_kind_panics() {
        let _g = ScopedPlan::install("panic@p:3").unwrap();
        let _ = poke("p", 3);
    }

    #[test]
    fn unarmed_poke_is_ok() {
        // no scoped plan on this thread; even if the process has a
        // LOTION_FAULTS env plan, this site/ordinal is not in CI plans
        let _g = ScopedPlan::install("").unwrap();
        assert!(poke("nowhere", 123456).is_ok());
    }
}
