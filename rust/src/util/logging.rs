//! Minimal leveled logger writing to stderr; level picked via
//! `LOTION_LOG` (error|warn|info|debug, default info).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // info
static INIT: std::sync::Once = std::sync::Once::new();
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

pub fn init() {
    INIT.call_once(|| {
        let lvl = match std::env::var("LOTION_LOG").as_deref() {
            Ok("error") => ERROR,
            Ok("warn") => WARN,
            Ok("debug") => DEBUG,
            _ => INFO,
        };
        LEVEL.store(lvl, Ordering::Relaxed);
        let _ = START.set(Instant::now());
    });
}

pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: u8, msg: std::fmt::Arguments) {
    init();
    if !enabled(level) {
        return;
    }
    let t = START.get().map(|s| s.elapsed().as_secs_f64()).unwrap_or(0.0);
    let tag = match level {
        ERROR => "ERROR",
        WARN => "WARN ",
        INFO => "INFO ",
        _ => "DEBUG",
    };
    eprintln!("[{t:9.3}s {tag}] {msg}");
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::INFO, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::WARN, format_args!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::DEBUG, format_args!($($arg)*)) };
}
