//! Small substrates: deterministic PRNG, summary statistics, logging,
//! and a mini property-testing harness (proptest is unavailable offline).

pub mod faults;
pub mod logging;
pub mod pool;
pub mod prop;
pub mod rng;
pub mod simd;
pub mod tempdir;
pub mod stats;
pub mod text;

pub use pool::Pool;
pub use rng::Rng;
pub use simd::SimdTier;
pub use stats::Summary;
