//! Persistent worker pool for the native backend's hot loops
//! (std::thread only — the crate's zero-extra-deps policy keeps
//! `anyhow` the sole external dependency).
//!
//! Workers are **long-lived**: `Pool::new(n)` owns `n - 1` parked
//! threads (spawned lazily on the first parallel dispatch) that wait on
//! a condvar for the next job instead of being re-spawned per kernel
//! call. That removes the per-call `std::thread::scope` spawn/join tax
//! that dominated tensors just above [`PAR_MIN`], and it makes
//! `thread_local!` buffers genuinely reusable scratch: a worker keeps
//! its RR-noise and matmul packing buffers across every kernel call of
//! a training run. The submitting thread participates in each job, so a
//! width-`n` pool runs chunks on `n` threads total.
//!
//! Determinism contract (DESIGN.md §3): callers partition work with
//! [`chunk_ranges`], whose boundaries are a pure function of the
//! problem size — **never** of the thread count — and fold any
//! reductions in chunk-index order. The pool only decides *which
//! thread* runs each chunk, so results are bit-identical at
//! `--threads 1` and `--threads N`. A panic inside a chunk is caught on
//! the worker, the first payload is re-thrown at the call site, and the
//! workers stay parked and reusable — the pool survives the panic.

use std::any::Any;
use std::cell::{Cell, UnsafeCell};
use std::ops::Range;
use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// Fixed task granularity (elements) for element-wise kernels. A pure
/// constant so chunk boundaries — and therefore reduction order and
/// counter-RNG stream keys — do not depend on the machine.
pub const PAR_CHUNK: usize = 16 * 1024;

/// Below this much total work a kernel stays on the calling thread
/// (even with persistent workers, waking and joining them costs more
/// than small kernels do).
pub const PAR_MIN: usize = 32 * 1024;

/// Deterministic partition of `0..n` into contiguous ranges of at most
/// `chunk` elements (the last may be shorter). Pure function of
/// `(n, chunk)`.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<Range<usize>> {
    let c = chunk.max(1);
    (0..n.div_ceil(c)).map(|i| i * c..((i + 1) * c).min(n)).collect()
}

// ---------------------------------------------------------------------------
// job board: one in-flight job, claimed task-by-task via an atomic
// ---------------------------------------------------------------------------

/// One submitted job: the borrowed `run one task` closure plus the
/// claim counter and panic slot. Lives on the submitter's stack for the
/// duration of [`WorkerSet::run_job`]; workers reach it through a
/// lifetime-erased pointer on the job board, but only between
/// registering in `active` (under the state lock) and deregistering,
/// and the submitter does not return — and so does not drop the job —
/// until `active` is back to zero.
struct JobState<'a> {
    /// next unclaimed task index (claims are unique via `fetch_add`)
    next: AtomicUsize,
    n: usize,
    run_one: &'a (dyn Fn(usize) + Sync),
    /// first caught panic payload, re-thrown by the submitter
    panic: Mutex<Option<Box<dyn Any + Send + 'static>>>,
    /// set after a panic so runners stop claiming further tasks
    stop: AtomicBool,
}

thread_local! {
    /// Identity (the `Shared` address) of the pool whose tasks this
    /// thread is currently running, `0` when none — so a same-pool
    /// nested dispatch panics with a diagnosis instead of deadlocking
    /// on `submit_lock` (see [`WorkerSet::run_job`]). Cross-pool
    /// nesting merely blocks and is allowed.
    static RUNNING_POOL: Cell<usize> = Cell::new(0);
}

/// Run a job's claim loop with [`RUNNING_POOL`] set to `pool_id`,
/// restoring the previous value afterwards (cross-pool nesting stacks).
fn run_tasks_tagged(job: &JobState<'_>, pool_id: usize) {
    let prev = RUNNING_POOL.with(|id| id.replace(pool_id));
    job.run_tasks();
    RUNNING_POOL.with(|id| id.set(prev));
}

impl<'a> JobState<'a> {
    /// Claim-and-run loop shared by workers and the submitter. Every
    /// claimed task either completes or records its panic payload, so
    /// a runner that returns has fully settled each claim it made.
    fn run_tasks(&self) {
        let f = self.run_one;
        while !self.stop.load(Ordering::Relaxed) {
            let i = self.next.fetch_add(1, Ordering::Relaxed);
            if i >= self.n {
                break;
            }
            if let Err(payload) = catch_unwind(AssertUnwindSafe(|| {
                // `pool_job` fault site (ordinal = task index): an
                // injected panic exercises the pool's panic-payload
                // plumbing exactly like a real task panic
                if let Err(e) = crate::util::faults::poke("pool_job", i as u64) {
                    panic!("{e}");
                }
                f(i)
            })) {
                self.stop.store(true, Ordering::Relaxed);
                let mut slot = self.panic.lock().unwrap();
                if slot.is_none() {
                    *slot = Some(payload);
                }
            }
        }
    }
}

/// The condvar-protected job board workers park on.
struct PoolState {
    /// current job (lifetime erased by a thin-pointer cast), null when
    /// idle; workers may only read it (and register in `active`) while
    /// holding the state lock
    job: *const JobState<'static>,
    /// bumped per job so a worker runs each job at most once
    epoch: u64,
    /// runners currently inside `run_tasks` for the published job
    active: usize,
    shutdown: bool,
}

// SAFETY: the raw job pointer is only dereferenced by runners that
// registered in `active` under the lock; the submitter keeps the
// pointee alive until `active == 0` (see `run_job`).
unsafe impl Send for PoolState {}

struct Shared {
    state: Mutex<PoolState>,
    /// workers park here waiting for a new epoch
    work_cv: Condvar,
    /// the submitter parks here waiting for `active == 0`
    done_cv: Condvar,
}

/// The persistent threads behind one [`Pool`]. Workers hold
/// `Arc<Shared>` only (not `Arc<WorkerSet>`), so dropping the last
/// `Pool` clone drops the `WorkerSet`, which signals shutdown and joins
/// the threads — no reference cycle keeps them alive.
struct WorkerSet {
    shared: Arc<Shared>,
    width: usize,
    /// spawned lazily on the first parallel dispatch
    handles: Mutex<Vec<JoinHandle<()>>>,
    /// serializes submitters: one job in flight at a time, so pool
    /// clones are safe to use from independent threads
    submit_lock: Mutex<()>,
}

impl WorkerSet {
    fn new(width: usize) -> WorkerSet {
        WorkerSet {
            shared: Arc::new(Shared {
                state: Mutex::new(PoolState {
                    job: std::ptr::null(),
                    epoch: 0,
                    active: 0,
                    shutdown: false,
                }),
                work_cv: Condvar::new(),
                done_cv: Condvar::new(),
            }),
            width,
            handles: Mutex::new(Vec::new()),
            submit_lock: Mutex::new(()),
        }
    }

    /// Spawn the `width - 1` worker threads if they are not up yet.
    fn ensure_spawned(&self) {
        let mut handles = self.handles.lock().unwrap();
        if !handles.is_empty() {
            return;
        }
        for i in 1..self.width {
            let shared = Arc::clone(&self.shared);
            let h = std::thread::Builder::new()
                .name(format!("lotion-pool-{i}"))
                .spawn(move || worker_loop(shared))
                .expect("spawn pool worker");
            handles.push(h);
        }
    }

    /// Publish a job of `n` tasks, run tasks on the calling thread too,
    /// wait until every registered runner has finished, then re-throw
    /// the first worker panic (the pool itself stays usable).
    fn run_job(&self, n: usize, run_one: &(dyn Fn(usize) + Sync)) {
        // fail loudly instead of deadlocking: a same-pool nested
        // dispatch would block on `submit_lock` held by the very job
        // that is running this task
        let pool_id = Arc::as_ptr(&self.shared) as usize;
        assert!(
            RUNNING_POOL.with(|id| id.get()) != pool_id,
            "pool jobs cannot nest: dispatching on the pool that is running this task would \
             deadlock"
        );
        let submit = self.submit_lock.lock().unwrap();
        self.ensure_spawned();
        let job = JobState {
            next: AtomicUsize::new(0),
            n,
            run_one,
            panic: Mutex::new(None),
            stop: AtomicBool::new(false),
        };
        {
            let mut st = self.shared.state.lock().unwrap();
            debug_assert!(st.job.is_null(), "job board not idle under submit_lock");
            // thin-pointer cast erases the closure borrow's lifetime;
            // sound because this function joins before returning
            st.job = (&job as *const JobState<'_>).cast::<JobState<'static>>();
            st.epoch = st.epoch.wrapping_add(1);
            // wake only as many workers as there are tasks beyond the
            // submitter's own share — a small job on a wide pool must
            // not pay a width-proportional wake/relock storm
            let wake = (self.width - 1).min(n.saturating_sub(1));
            for _ in 0..wake {
                self.shared.work_cv.notify_one();
            }
        }
        run_tasks_tagged(&job, pool_id);
        {
            let mut st = self.shared.state.lock().unwrap();
            while st.active > 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            // still holding the lock: no worker can register on the
            // retiring job between the `active == 0` check and this
            st.job = std::ptr::null();
        }
        // release the submitter slot *before* re-throwing: unwinding
        // past a held MutexGuard would poison `submit_lock` and turn
        // one caught task panic into a permanently broken pool
        drop(submit);
        if let Some(payload) = job.panic.into_inner().unwrap() {
            resume_unwind(payload);
        }
    }
}

impl Drop for WorkerSet {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.handles.lock().unwrap().drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: Arc<Shared>) {
    let pool_id = Arc::as_ptr(&shared) as usize;
    let mut last_epoch = 0u64;
    loop {
        let job_ptr: *const JobState<'static>;
        {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if !st.job.is_null() && st.epoch != last_epoch {
                    last_epoch = st.epoch;
                    st.active += 1;
                    job_ptr = st.job;
                    break;
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        }
        // SAFETY: registered in `active` under the lock above, so the
        // submitter keeps the job alive until we deregister below.
        run_tasks_tagged(unsafe { &*job_ptr }, pool_id);
        let mut st = shared.state.lock().unwrap();
        st.active -= 1;
        if st.active == 0 {
            shared.done_cv.notify_all();
        }
    }
}

/// A task-input or result slot: each index is claimed exactly once
/// (unique `fetch_add` claims), so every cell has a single accessor —
/// the claimant — until the submitter reads results after the join.
struct Slot<T>(UnsafeCell<Option<T>>);
// SAFETY: single accessor per slot (see above); T crosses threads.
unsafe impl<T: Send> Sync for Slot<T> {}

// ---------------------------------------------------------------------------
// the public handle
// ---------------------------------------------------------------------------

/// A worker pool of a fixed logical width. The handle is cheap to
/// clone (it shares the persistent workers); kernels borrow it as
/// `&Pool`. Width 1 (and [`Pool::serial`]) owns no threads at all —
/// every kernel takes its serial path on the calling thread.
#[derive(Clone)]
pub struct Pool {
    threads: usize,
    workers: Option<Arc<WorkerSet>>,
}

impl std::fmt::Debug for Pool {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Pool").field("threads", &self.threads).finish()
    }
}

impl Pool {
    /// `threads == 0` means auto: `LOTION_THREADS` if set, else all
    /// available cores. Explicit values are clamped to >= 1. Worker
    /// threads spawn lazily on the first parallel dispatch and persist
    /// until the last clone of this pool is dropped.
    pub fn new(threads: usize) -> Pool {
        let threads = resolve_threads(threads);
        let workers = (threads > 1).then(|| Arc::new(WorkerSet::new(threads)));
        Pool { threads, workers }
    }

    /// A single-threaded pool: every kernel takes its serial path on
    /// the calling thread; no worker threads exist.
    pub fn serial() -> Pool {
        Pool { threads: 1, workers: None }
    }

    /// The process-wide default pool, shared (and kept alive) across
    /// calls so its workers persist. Width: the last explicit
    /// [`set_global_threads`] value if one was set, else auto
    /// (`LOTION_THREADS` / core count — cached in its own slot, never
    /// in the explicit one, so an explicit setting always wins no
    /// matter when the first kernel ran). Backs the seed-API quant
    /// kernels (`cast_rtn(w, fmt)` etc.), so coordinator-side eval
    /// casts honor `--threads` too.
    pub fn global() -> Pool {
        let explicit = EXPLICIT_THREADS.load(Ordering::Relaxed);
        let width = if explicit > 0 { explicit } else { auto_threads() };
        let mut slot = GLOBAL_POOL.lock().unwrap();
        match &*slot {
            Some(p) if p.threads == width => p.clone(),
            _ => {
                // width changed (or first use): build a fresh pool; the
                // old one's workers shut down when its last clone drops
                let p = Pool::new(width);
                *slot = Some(p.clone());
                p
            }
        }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, task)` over owned tasks on up to `threads`
    /// runners (the persistent workers plus the calling thread);
    /// results come back in task order. Task partitioning is the
    /// caller's job (see the module determinism contract). Jobs must
    /// not nest: a task must never dispatch on the pool that is
    /// running it (kernels are leaves; sequential pool calls from the
    /// same caller are fine).
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = tasks.len();
        let workers = match &self.workers {
            Some(w) if n > 1 => w,
            _ => return tasks.into_iter().enumerate().map(|(i, t)| f(i, t)).collect(),
        };
        let slots: Vec<Slot<T>> =
            tasks.into_iter().map(|t| Slot(UnsafeCell::new(Some(t)))).collect();
        let out: Vec<Slot<R>> = (0..n).map(|_| Slot(UnsafeCell::new(None))).collect();
        let run_one = |i: usize| {
            // SAFETY: index `i` is claimed exactly once, so this
            // closure is the only accessor of slot/out `i`; the
            // submitter reads `out` only after the join.
            let task = unsafe { (*slots[i].0.get()).take().expect("task taken twice") };
            let r = f(i, task);
            unsafe { *out[i].0.get() = Some(r) };
        };
        workers.run_job(n, &run_one);
        out.into_iter()
            .map(|s| s.0.into_inner().expect("worker produced no result"))
            .collect()
    }

    /// The standard kernel dispatch: run `f(index, range, chunk)` over
    /// the pre-split chunks of `data`, **serially in range order** when
    /// `total_work < PAR_MIN` or the pool is serial, on worker threads
    /// otherwise. Results come back in range order either way, so a
    /// kernel written against this helper gets the determinism contract
    /// (fixed ranges + in-order folds) without hand-rolling the guard.
    pub fn for_chunks_mut<T, R, F>(
        &self,
        data: &mut [T],
        ranges: &[Range<usize>],
        total_work: usize,
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, &mut [T]) -> R + Sync,
    {
        if total_work < PAR_MIN || self.threads == 1 {
            ranges
                .iter()
                .enumerate()
                .map(|(i, r)| f(i, r.clone(), &mut data[r.clone()]))
                .collect()
        } else {
            self.run_on_chunks_mut(data, ranges, f)
        }
    }

    /// Split `data` at the given ascending, contiguous, covering range
    /// boundaries and run `f(index, range, chunk)` on each disjoint
    /// mutable chunk. The `par_chunks`-style entry point used by every
    /// in-place kernel.
    pub fn run_on_chunks_mut<T, R, F>(
        &self,
        data: &mut [T],
        ranges: &[Range<usize>],
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, &mut [T]) -> R + Sync,
    {
        let mut parts: Vec<(Range<usize>, &mut [T])> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [T] = data;
        let mut offset = 0usize;
        for r in ranges {
            assert!(r.start == offset, "ranges must be contiguous from 0");
            let (head, tail) = rest.split_at_mut(r.end - offset);
            parts.push((r.clone(), head));
            rest = tail;
            offset = r.end;
        }
        self.run(parts, |i, (r, chunk)| f(i, r, chunk))
    }
}

/// The explicit process-wide width (`--threads`/config); `0` = never
/// set, resolve auto per call. Kept separate from any lazily-resolved
/// auto value on purpose: [`Pool::global`] used to latch the resolved
/// core count into the same slot on first use, which made a
/// `set_global_threads` that ran *after* an early kernel
/// indistinguishable from the stale auto value. Explicit now always
/// wins, whenever it is installed.
static EXPLICIT_THREADS: AtomicUsize = AtomicUsize::new(0);

/// The shared global pool instance, rebuilt when the resolved width
/// changes (the retired pool's workers exit once its clones drop).
static GLOBAL_POOL: Mutex<Option<Pool>> = Mutex::new(None);

/// Cached auto width (`LOTION_THREADS` / cores), `0` = not resolved
/// yet. The probe is process-constant, so one resolution is enough —
/// and because it lives apart from [`EXPLICIT_THREADS`], caching it
/// cannot shadow an explicit setting (the bug this PR fixes); it only
/// spares the seed-API quant kernels an env-var read per call.
static AUTO_THREADS: AtomicUsize = AtomicUsize::new(0);

fn auto_threads() -> usize {
    let cached = AUTO_THREADS.load(Ordering::Relaxed);
    if cached > 0 {
        return cached;
    }
    let resolved = resolve_threads(0);
    AUTO_THREADS.store(resolved, Ordering::Relaxed);
    resolved
}

/// Install the process-wide width used by [`Pool::global`]: `0` means
/// auto (`LOTION_THREADS` / cores, re-resolved on use), any other value
/// is explicit and overrides auto from then on — regardless of whether
/// a kernel already used the global pool. The CLI calls this with the
/// `--threads` value so the quant kernels' seed APIs — including the
/// evaluator's RTN/RR eval casts, which run coordinator-side rather
/// than through an engine — respect the same knob.
pub fn set_global_threads(threads: usize) {
    EXPLICIT_THREADS.store(threads, Ordering::Relaxed);
}

fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Some(t) = env_threads() {
        return t.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The `LOTION_THREADS` environment override (0/unset/garbage = auto).
pub fn env_threads() -> Option<usize> {
    std::env::var("LOTION_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::collections::HashSet;
    use std::thread::ThreadId;

    #[test]
    fn chunk_ranges_cover_with_uneven_tail() {
        assert_eq!(chunk_ranges(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(chunk_ranges(8, 4), vec![0..4, 4..8]);
        assert_eq!(chunk_ranges(3, 4), vec![0..3]);
        assert_eq!(chunk_ranges(0, 4), Vec::<Range<usize>>::new());
        // chunk=0 is clamped to 1 rather than dividing by zero
        assert_eq!(chunk_ranges(2, 0), vec![0..1, 1..2]);
    }

    #[test]
    fn run_returns_results_in_task_order() {
        let pool = Pool::new(4);
        let tasks: Vec<usize> = (0..37).collect();
        let out = pool.run(tasks, |i, t| {
            assert_eq!(i, t);
            t * 3
        });
        assert_eq!(out, (0..37).map(|t| t * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_pool_matches_parallel_pool() {
        let work = |_, t: usize| (t as f64).sqrt();
        let a = Pool::serial().run((0..100).collect(), work);
        let b = Pool::new(3).run((0..100).collect(), work);
        assert_eq!(a, b);
    }

    #[test]
    fn more_threads_than_tasks_is_fine() {
        let pool = Pool::new(16);
        let out = pool.run(vec![1, 2], |_, t| t + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn empty_and_single_task_jobs_stay_on_the_caller() {
        let pool = Pool::new(4);
        let me = std::thread::current().id();
        let none: Vec<usize> = pool.run(Vec::<usize>::new(), |_, t| t);
        assert!(none.is_empty());
        let one = pool.run(vec![9], |_, t| {
            assert_eq!(std::thread::current().id(), me);
            t + 1
        });
        assert_eq!(one, vec![10]);
    }

    #[test]
    fn run_on_chunks_mut_uneven_split() {
        let pool = Pool::new(3);
        let mut data: Vec<u32> = (0..23).collect();
        let ranges = chunk_ranges(data.len(), 5);
        let sums = pool.run_on_chunks_mut(&mut data, &ranges, |i, r, chunk| {
            assert_eq!(chunk.len(), r.len());
            let mut s = 0u32;
            for v in chunk.iter_mut() {
                s += *v;
                *v += 100;
            }
            (i, s)
        });
        // every element mutated exactly once
        assert_eq!(data, (100..123).collect::<Vec<u32>>());
        // partial results in chunk order
        assert_eq!(sums.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        let total: u32 = sums.iter().map(|(_, s)| *s).sum();
        assert_eq!(total, (0..23).sum::<u32>());
    }

    #[test]
    fn for_chunks_mut_serial_and_parallel_agree() {
        // the dispatch helper must produce identical data and results
        // on its serial path (small work / 1 thread) and pooled path
        let kernel = |i: usize, r: Range<usize>, chunk: &mut [f64]| -> f64 {
            let mut acc = 0.0;
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = (i * 1000 + r.start + off) as f64;
                acc += *v;
            }
            acc
        };
        let n = 41;
        let ranges = chunk_ranges(n, 7);
        let mut a = vec![0.0f64; n];
        let ra = Pool::serial().for_chunks_mut(&mut a, &ranges, 0, kernel);
        let mut b = vec![0.0f64; n];
        // total_work above PAR_MIN forces the pooled branch
        let rb = Pool::new(3).for_chunks_mut(&mut b, &ranges, PAR_MIN, kernel);
        assert_eq!(a, b);
        assert_eq!(ra, rb);
        assert_eq!(ra.len(), ranges.len());
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = Pool::new(2);
        let res = catch_unwind(AssertUnwindSafe(|| {
            pool.run((0..8).collect::<Vec<usize>>(), |_, t| {
                if t == 5 {
                    panic!("boom in worker");
                }
                t
            })
        }));
        assert!(res.is_err(), "worker panic must propagate");
    }

    /// ISSUE 4 lifecycle: after a propagated panic the same pool (same
    /// persistent workers) keeps executing jobs correctly.
    #[test]
    fn pool_survives_a_worker_panic() {
        let pool = Pool::new(3);
        for round in 0..3 {
            let res = catch_unwind(AssertUnwindSafe(|| {
                pool.run((0..64).collect::<Vec<usize>>(), |_, t| {
                    if t == 40 {
                        panic!("boom {round}");
                    }
                    t
                })
            }));
            assert!(res.is_err(), "round {round}: panic must propagate");
            let ok = pool.run((0..64).collect::<Vec<usize>>(), |_, t| t * 2);
            assert_eq!(ok, (0..64).map(|t| t * 2).collect::<Vec<_>>());
        }
    }

    /// ISSUE 4 lifecycle: the worker threads persist across many kernel
    /// calls — the set of thread ids that ran tasks stays bounded by
    /// the pool width instead of growing per call (the scoped pool
    /// spawned fresh threads every call).
    #[test]
    fn workers_persist_across_many_calls() {
        let pool = Pool::new(4);
        let ids = Mutex::new(HashSet::<ThreadId>::new());
        for _ in 0..50 {
            // enough tasks that workers reliably participate
            pool.run((0..256).collect::<Vec<usize>>(), |_, t| {
                ids.lock().unwrap().insert(std::thread::current().id());
                std::hint::black_box(t * t)
            });
        }
        let distinct = ids.lock().unwrap().len();
        // caller + at most (width - 1) persistent workers; with scoped
        // per-call threads this would be up to 50 * 4 distinct ids
        assert!(distinct <= 4, "saw {distinct} distinct runner threads for a width-4 pool");
    }

    /// ISSUE 4 lifecycle: width-1 pools bypass the workers entirely —
    /// every task runs on the calling thread and no worker threads are
    /// ever spawned (`LOTION_THREADS=1` resolves to this same path).
    #[test]
    fn serial_and_width_one_pools_run_on_the_caller() {
        let me = std::thread::current().id();
        for pool in [Pool::serial(), Pool::new(1)] {
            assert!(pool.workers.is_none(), "width-1 pool must own no threads");
            pool.run((0..64).collect::<Vec<usize>>(), |_, t| {
                assert_eq!(std::thread::current().id(), me, "task left the caller");
                t
            });
            let mut data = vec![0u8; 64];
            pool.for_chunks_mut(&mut data, &chunk_ranges(64, 8), PAR_MIN, |_, _, c| {
                assert_eq!(std::thread::current().id(), me);
                c.fill(1);
            });
            assert!(data.iter().all(|&b| b == 1));
        }
    }

    /// Regression (ISSUE 4 bugfix): an explicit `set_global_threads`
    /// must win even when `Pool::global()` already resolved — and
    /// previously latched — the auto width, and clearing it (0) must
    /// return to auto resolution.
    #[test]
    fn explicit_global_threads_beat_latched_auto() {
        // serialize against anything else touching the global knob
        let _guard = GLOBAL_TEST_LOCK.lock().unwrap();
        set_global_threads(0);
        let auto_width = Pool::global().threads(); // resolves + caches auto
        assert!(auto_width >= 1);
        let explicit = auto_width + 3; // distinct from the cached value
        set_global_threads(explicit);
        assert_eq!(
            Pool::global().threads(),
            explicit,
            "explicit --threads was ignored in favor of the latched auto width"
        );
        // the rebuilt pool must actually execute at the new width
        let out = Pool::global().run((0..16).collect::<Vec<usize>>(), |_, t| t + 1);
        assert_eq!(out, (1..17).collect::<Vec<_>>());
        set_global_threads(0);
        assert_eq!(Pool::global().threads(), auto_width, "0 must restore auto resolution");
    }

    /// Repeated `Pool::global()` calls at a stable width share one
    /// worker set (the pool is cached, not rebuilt per call).
    #[test]
    fn global_pool_is_shared_at_stable_width() {
        let _guard = GLOBAL_TEST_LOCK.lock().unwrap();
        set_global_threads(2);
        let a = Pool::global();
        let b = Pool::global();
        let (wa, wb) = (a.workers.as_ref().unwrap(), b.workers.as_ref().unwrap());
        assert!(Arc::ptr_eq(wa, wb), "same width must reuse the cached worker set");
        set_global_threads(0);
    }

    static GLOBAL_TEST_LOCK: Mutex<()> = Mutex::new(());

    #[test]
    fn zero_resolves_to_at_least_one() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::new(7).threads(), 7);
    }
}
