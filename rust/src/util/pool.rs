//! Scoped worker pool for the native backend's hot loops (std::thread
//! only — the crate's zero-extra-deps policy keeps `anyhow` the sole
//! external dependency).
//!
//! Determinism contract (DESIGN.md §3): callers partition work with
//! [`chunk_ranges`], whose boundaries are a pure function of the
//! problem size — **never** of the thread count — and fold any
//! reductions in chunk-index order. The pool only decides *which
//! worker* runs each chunk, so results are bit-identical at
//! `--threads 1` and `--threads N`. Worker panics propagate to the
//! caller via `std::thread::scope`'s join.

use std::ops::Range;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

/// Fixed task granularity (elements) for element-wise kernels. A pure
/// constant so chunk boundaries — and therefore reduction order and
/// counter-RNG stream keys — do not depend on the machine.
pub const PAR_CHUNK: usize = 16 * 1024;

/// Below this much total work a kernel stays on the calling thread
/// (spawn + scheduling overhead would dominate).
pub const PAR_MIN: usize = 32 * 1024;

/// Deterministic partition of `0..n` into contiguous ranges of at most
/// `chunk` elements (the last may be shorter). Pure function of
/// `(n, chunk)`.
pub fn chunk_ranges(n: usize, chunk: usize) -> Vec<Range<usize>> {
    let c = chunk.max(1);
    (0..n.div_ceil(c)).map(|i| i * c..((i + 1) * c).min(n)).collect()
}

/// A worker pool of a fixed logical width. Threads are scoped per
/// call (`std::thread::scope`), so closures may borrow from the
/// caller's stack and panics resurface at the call site; the `Pool`
/// value itself is the reusable part (width resolution + serial
/// fallback policy).
#[derive(Clone, Copy, Debug)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// `threads == 0` means auto: `LOTION_THREADS` if set, else all
    /// available cores. Explicit values are clamped to >= 1.
    pub fn new(threads: usize) -> Pool {
        Pool { threads: resolve_threads(threads) }
    }

    /// A single-threaded pool: every kernel takes its serial path.
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    /// The process-wide default pool: `LOTION_THREADS` / core count,
    /// or whatever [`set_global_threads`] last installed. Backs the
    /// seed-API quant kernels (`cast_rtn(w, fmt)` etc.), so
    /// coordinator-side eval casts honor `--threads` too.
    pub fn global() -> Pool {
        let t = GLOBAL_THREADS.load(Ordering::Relaxed);
        if t > 0 {
            return Pool { threads: t };
        }
        let p = Pool::new(0);
        GLOBAL_THREADS.store(p.threads, Ordering::Relaxed);
        p
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(index, task)` over owned tasks on up to `threads`
    /// workers; results come back in task order. Task partitioning is
    /// the caller's job (see the module determinism contract).
    pub fn run<T, R, F>(&self, tasks: Vec<T>, f: F) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, T) -> R + Sync,
    {
        let n = tasks.len();
        if self.threads == 1 || n <= 1 {
            return tasks.into_iter().enumerate().map(|(i, t)| f(i, t)).collect();
        }
        let slots: Vec<Mutex<Option<T>>> =
            tasks.into_iter().map(|t| Mutex::new(Some(t))).collect();
        let out: Vec<Mutex<Option<R>>> = (0..n).map(|_| Mutex::new(None)).collect();
        let next = AtomicUsize::new(0);
        let workers = self.threads.min(n);
        std::thread::scope(|s| {
            for _ in 0..workers {
                s.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let task = slots[i].lock().unwrap().take().expect("task taken twice");
                    let r = f(i, task);
                    *out[i].lock().unwrap() = Some(r);
                });
            }
        });
        out.into_iter()
            .map(|m| m.into_inner().unwrap().expect("worker produced no result"))
            .collect()
    }

    /// The standard kernel dispatch: run `f(index, range, chunk)` over
    /// the pre-split chunks of `data`, **serially in range order** when
    /// `total_work < PAR_MIN` or the pool is serial, on worker threads
    /// otherwise. Results come back in range order either way, so a
    /// kernel written against this helper gets the determinism contract
    /// (fixed ranges + in-order folds) without hand-rolling the guard.
    pub fn for_chunks_mut<T, R, F>(
        &self,
        data: &mut [T],
        ranges: &[Range<usize>],
        total_work: usize,
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, &mut [T]) -> R + Sync,
    {
        if total_work < PAR_MIN || self.threads == 1 {
            ranges
                .iter()
                .enumerate()
                .map(|(i, r)| f(i, r.clone(), &mut data[r.clone()]))
                .collect()
        } else {
            self.run_on_chunks_mut(data, ranges, f)
        }
    }

    /// Split `data` at the given ascending, contiguous, covering range
    /// boundaries and run `f(index, range, chunk)` on each disjoint
    /// mutable chunk. The `par_chunks`-style entry point used by every
    /// in-place kernel.
    pub fn run_on_chunks_mut<T, R, F>(
        &self,
        data: &mut [T],
        ranges: &[Range<usize>],
        f: F,
    ) -> Vec<R>
    where
        T: Send,
        R: Send,
        F: Fn(usize, Range<usize>, &mut [T]) -> R + Sync,
    {
        let mut parts: Vec<(Range<usize>, &mut [T])> = Vec::with_capacity(ranges.len());
        let mut rest: &mut [T] = data;
        let mut offset = 0usize;
        for r in ranges {
            assert!(r.start == offset, "ranges must be contiguous from 0");
            let (head, tail) = rest.split_at_mut(r.end - offset);
            parts.push((r.clone(), head));
            rest = tail;
            offset = r.end;
        }
        self.run(parts, |i, (r, chunk)| f(i, r, chunk))
    }
}

static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Install the process-wide default width used by [`Pool::global`]
/// (`0` resolves from `LOTION_THREADS` / cores immediately). The CLI
/// calls this with the `--threads` value so the quant kernels' seed
/// APIs — including the evaluator's RTN/RR eval casts, which run
/// coordinator-side rather than through an engine — respect the same
/// knob.
pub fn set_global_threads(threads: usize) {
    GLOBAL_THREADS.store(resolve_threads(threads), Ordering::Relaxed);
}

fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Some(t) = env_threads() {
        return t.max(1);
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// The `LOTION_THREADS` environment override (0/unset/garbage = auto).
pub fn env_threads() -> Option<usize> {
    std::env::var("LOTION_THREADS")
        .ok()
        .and_then(|v| v.trim().parse::<usize>().ok())
        .filter(|&t| t > 0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_ranges_cover_with_uneven_tail() {
        assert_eq!(chunk_ranges(10, 4), vec![0..4, 4..8, 8..10]);
        assert_eq!(chunk_ranges(8, 4), vec![0..4, 4..8]);
        assert_eq!(chunk_ranges(3, 4), vec![0..3]);
        assert_eq!(chunk_ranges(0, 4), Vec::<Range<usize>>::new());
        // chunk=0 is clamped to 1 rather than dividing by zero
        assert_eq!(chunk_ranges(2, 0), vec![0..1, 1..2]);
    }

    #[test]
    fn run_returns_results_in_task_order() {
        let pool = Pool::new(4);
        let tasks: Vec<usize> = (0..37).collect();
        let out = pool.run(tasks, |i, t| {
            assert_eq!(i, t);
            t * 3
        });
        assert_eq!(out, (0..37).map(|t| t * 3).collect::<Vec<_>>());
    }

    #[test]
    fn serial_pool_matches_parallel_pool() {
        let work = |_, t: usize| (t as f64).sqrt();
        let a = Pool::serial().run((0..100).collect(), work);
        let b = Pool::new(3).run((0..100).collect(), work);
        assert_eq!(a, b);
    }

    #[test]
    fn more_threads_than_tasks_is_fine() {
        let pool = Pool::new(16);
        let out = pool.run(vec![1, 2], |_, t| t + 1);
        assert_eq!(out, vec![2, 3]);
    }

    #[test]
    fn run_on_chunks_mut_uneven_split() {
        let pool = Pool::new(3);
        let mut data: Vec<u32> = (0..23).collect();
        let ranges = chunk_ranges(data.len(), 5);
        let sums = pool.run_on_chunks_mut(&mut data, &ranges, |i, r, chunk| {
            assert_eq!(chunk.len(), r.len());
            let mut s = 0u32;
            for v in chunk.iter_mut() {
                s += *v;
                *v += 100;
            }
            (i, s)
        });
        // every element mutated exactly once
        assert_eq!(data, (100..123).collect::<Vec<u32>>());
        // partial results in chunk order
        assert_eq!(sums.iter().map(|(i, _)| *i).collect::<Vec<_>>(), vec![0, 1, 2, 3, 4]);
        let total: u32 = sums.iter().map(|(_, s)| *s).sum();
        assert_eq!(total, (0..23).sum::<u32>());
    }

    #[test]
    fn for_chunks_mut_serial_and_parallel_agree() {
        // the dispatch helper must produce identical data and results
        // on its serial path (small work / 1 thread) and pooled path
        let kernel = |i: usize, r: Range<usize>, chunk: &mut [f64]| -> f64 {
            let mut acc = 0.0;
            for (off, v) in chunk.iter_mut().enumerate() {
                *v = (i * 1000 + r.start + off) as f64;
                acc += *v;
            }
            acc
        };
        let n = 41;
        let ranges = chunk_ranges(n, 7);
        let mut a = vec![0.0f64; n];
        let ra = Pool::serial().for_chunks_mut(&mut a, &ranges, 0, kernel);
        let mut b = vec![0.0f64; n];
        // total_work above PAR_MIN forces the pooled branch
        let rb = Pool::new(3).for_chunks_mut(&mut b, &ranges, PAR_MIN, kernel);
        assert_eq!(a, b);
        assert_eq!(ra, rb);
        assert_eq!(ra.len(), ranges.len());
    }

    #[test]
    fn worker_panic_propagates_to_caller() {
        let pool = Pool::new(2);
        let res = std::panic::catch_unwind(|| {
            pool.run((0..8).collect::<Vec<usize>>(), |_, t| {
                if t == 5 {
                    panic!("boom in worker");
                }
                t
            })
        });
        assert!(res.is_err(), "worker panic must propagate");
    }

    #[test]
    fn zero_resolves_to_at_least_one() {
        assert!(Pool::new(0).threads() >= 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert_eq!(Pool::new(7).threads(), 7);
    }
}
