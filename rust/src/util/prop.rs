//! Mini property-testing harness (the proptest crate is not in the
//! offline vendor set). Seeded, size-driven generators + a `forall`
//! runner that reports the failing seed so any counterexample is
//! reproducible with `LOTION_PROP_SEED=<seed>`.

use super::rng::Rng;

/// Number of cases per property (override with LOTION_PROP_CASES).
pub fn cases() -> u64 {
    std::env::var("LOTION_PROP_CASES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(64)
}

fn base_seed() -> u64 {
    std::env::var("LOTION_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0xC0FFEE)
}

/// Run `prop` over `cases()` seeded generators; panics with the seed on
/// the first failure.
pub fn forall<F: FnMut(&mut Rng)>(name: &str, mut prop: F) {
    let base = base_seed();
    for case in 0..cases() {
        let seed = base.wrapping_add(case.wrapping_mul(0x9E3779B97F4A7C15));
        let mut rng = Rng::new(seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut rng)));
        if let Err(e) = result {
            eprintln!("property '{name}' failed at case {case} (LOTION_PROP_SEED={seed})");
            std::panic::resume_unwind(e);
        }
    }
}

/// Generator helpers over [`Rng`].
pub trait Gen {
    fn usize_in(&mut self, lo: usize, hi: usize) -> usize;
    fn f32_in(&mut self, lo: f32, hi: f32) -> f32;
    fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32>;
    fn vec_uniform(&mut self, len: usize) -> Vec<f32>;
}

impl Gen for Rng {
    fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.below((hi - lo + 1) as u64) as usize
    }

    fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform_f32() * (hi - lo)
    }

    fn vec_normal(&mut self, len: usize, scale: f32) -> Vec<f32> {
        (0..len).map(|_| self.normal_f32() * scale).collect()
    }

    fn vec_uniform(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.uniform_f32()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_runs_all_cases() {
        let mut n = 0;
        forall("count", |_| n += 1);
        assert_eq!(n as u64, cases());
    }

    #[test]
    #[should_panic]
    fn forall_propagates_failure() {
        forall("fail", |r| assert!(r.uniform() < -1.0));
    }

    #[test]
    fn gen_ranges() {
        forall("ranges", |r| {
            let u = r.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = r.f32_in(-2.0, 2.0);
            assert!((-2.0..=2.0).contains(&f));
            assert_eq!(r.vec_normal(5, 1.0).len(), 5);
        });
    }
}
