//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core, with
//! uniform/normal/integer helpers. Used for randomized rounding, data
//! synthesis and property-test generation — everything reproducible
//! from a single u64 seed.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller output
    spare_normal: Option<f64>,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (rejection-free Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fill a slice with U[0,1) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// A two-word u32 key for the jax threefry PRNG inputs.
    pub fn jax_key(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut mean = 0.0;
        for _ in 0..10000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= 10000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }
}
