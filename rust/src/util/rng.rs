//! Deterministic PRNG: SplitMix64 seeding + xoshiro256++ core, with
//! uniform/normal/integer helpers. Used for randomized rounding, data
//! synthesis and property-test generation — everything reproducible
//! from a single u64 seed.

/// xoshiro256++ generator (Blackman & Vigna), seeded via SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    /// cached second Box–Muller output
    spare_normal: Option<f64>,
}

/// SplitMix64 finalizer: a full-avalanche 64-bit mix.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    mix64(*state)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s, spare_normal: None }
    }

    /// Derive an independent stream for a named sub-purpose.
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    /// Counter-split stream derivation: a stateless, position-aware
    /// hash of `(seed, path)`. Unlike [`Rng::fork`] it consumes no
    /// generator state, so the stream for e.g. `(step, row)` can be
    /// built independently on any worker thread in any order — the
    /// property that makes the native backend's sampling loops
    /// parallel *and* bit-identical at every thread count
    /// (DESIGN.md §3).
    pub fn stream(seed: u64, path: &[u64]) -> Rng {
        Rng::new(Self::stream_seed(seed, path))
    }

    /// The seed [`Rng::stream`] would use — the glue for nested
    /// counter hierarchies: derive a per-step seed once, then key
    /// per-row streams off it without rehashing the whole path.
    pub fn stream_seed(seed: u64, path: &[u64]) -> u64 {
        let mut h = seed ^ 0xA0761D6478BD642F;
        for &p in path {
            h = mix64(h ^ p.wrapping_mul(0x9E3779B97F4A7C15)).wrapping_add(0x2545F4914F6CDD1D);
        }
        mix64(h)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn uniform_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in `[0, n)` (rejection-free Lemire reduction).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.spare_normal.take() {
            return z;
        }
        loop {
            let u1 = self.uniform();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.uniform();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.spare_normal = Some(r * s);
            return r * c;
        }
    }

    #[inline]
    pub fn normal_f32(&mut self) -> f32 {
        self.normal() as f32
    }

    /// Fill a slice with standard normals.
    pub fn fill_normal(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.normal_f32();
        }
    }

    /// Fill a slice with U[0,1) samples.
    pub fn fill_uniform(&mut self, out: &mut [f32]) {
        for v in out.iter_mut() {
            *v = self.uniform_f32();
        }
    }

    /// A two-word u32 key for the jax threefry PRNG inputs.
    pub fn jax_key(&mut self) -> [u32; 2] {
        [self.next_u32(), self.next_u32()]
    }

    /// Serialize the full generator state (xoshiro words + the cached
    /// Box–Muller spare) as hex words. Hex, not JSON numbers: u64
    /// state words don't survive a round-trip through f64 above 2^53.
    pub fn encode_state(&self) -> String {
        let mut s = format!(
            "{:016x},{:016x},{:016x},{:016x}",
            self.s[0], self.s[1], self.s[2], self.s[3]
        );
        if let Some(z) = self.spare_normal {
            s.push_str(&format!(",{:016x}", z.to_bits()));
        }
        s
    }

    /// Restore a generator from [`Rng::encode_state`] output. The
    /// optional fifth word is the cached normal's bit pattern.
    pub fn decode_state(text: &str) -> anyhow::Result<Rng> {
        let words: Vec<u64> = text
            .split(',')
            .map(|w| u64::from_str_radix(w.trim(), 16))
            .collect::<Result<_, _>>()
            .map_err(|e| anyhow::anyhow!("bad rng state {text:?}: {e}"))?;
        if words.len() != 4 && words.len() != 5 {
            anyhow::bail!("rng state has {} words, expected 4 or 5", words.len());
        }
        Ok(Rng {
            s: [words[0], words[1], words[2], words[3]],
            spare_normal: words.get(4).map(|&b| f64::from_bits(b)),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_range_and_roughly_uniform() {
        let mut r = Rng::new(1);
        let mut mean = 0.0;
        for _ in 0..10000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
            mean += u;
        }
        mean /= 10000.0;
        assert!((mean - 0.5).abs() < 0.02, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 20000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut r = Rng::new(9);
        let mut a = r.fork(1);
        let mut b = r.fork(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn stream_is_stateless_and_order_free() {
        // same (seed, path) -> same stream, regardless of what else
        // was derived before or on which "thread" (no shared state)
        let mut r1 = Rng::stream(5, &[3, 7]);
        let a: Vec<u64> = (0..4).map(|_| r1.next_u64()).collect();
        let _ = Rng::stream(5, &[9, 9]); // unrelated derivation in between
        let mut r2 = Rng::stream(5, &[3, 7]);
        let b: Vec<u64> = (0..4).map(|_| r2.next_u64()).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn stream_keys_are_position_sensitive() {
        let first = |seed, path: &[u64]| Rng::stream(seed, path).next_u64();
        assert_ne!(first(1, &[2, 3]), first(1, &[3, 2]));
        assert_ne!(first(1, &[2]), first(1, &[2, 0]));
        assert_ne!(first(1, &[0]), first(1, &[0, 0]));
        assert_ne!(first(1, &[2]), first(2, &[2]));
        // nesting is consistent with one-shot paths
        let nested = Rng::stream(Rng::stream_seed(1, &[2]), &[3]).next_u64();
        assert_eq!(nested, Rng::stream(Rng::stream_seed(1, &[2]), &[3]).next_u64());
    }

    #[test]
    fn state_roundtrip_mid_stream() {
        let mut r = Rng::new(1234);
        for _ in 0..17 {
            r.next_u64();
        }
        // odd number of normals leaves spare_normal populated
        let _ = r.normal();
        let mut restored = Rng::decode_state(&r.encode_state()).unwrap();
        for _ in 0..8 {
            assert_eq!(r.next_u64(), restored.next_u64());
        }
        // the cached spare must survive: next normal() equal on both
        let mut r2 = Rng::new(99);
        let _ = r2.normal();
        let mut restored2 = Rng::decode_state(&r2.encode_state()).unwrap();
        assert_eq!(r2.normal().to_bits(), restored2.normal().to_bits());
        assert_eq!(r2.normal().to_bits(), restored2.normal().to_bits());
    }

    #[test]
    fn decode_state_rejects_garbage() {
        assert!(Rng::decode_state("").is_err());
        assert!(Rng::decode_state("1,2,3").is_err());
        assert!(Rng::decode_state("1,2,3,zz").is_err());
        assert!(Rng::decode_state("1,2,3,4,5,6").is_err());
    }

    /// Counter-adjacent streams must look independent: the property
    /// the parallel per-row sampling relies on (ISSUE 2 tentpole).
    #[test]
    fn stream_independence_across_counters() {
        crate::util::prop::forall("counter streams independent", |r| {
            let seed = r.next_u64();
            let step = r.below(1000);
            // distinct (step, row) keys give distinct first outputs
            let mut seen = std::collections::HashSet::new();
            for row in 0..64u64 {
                let v = Rng::stream(seed, &[step, row]).next_u64();
                assert!(seen.insert(v), "collision at row {row}");
            }
        });
        // per-row uniforms are not correlated with the row counter:
        // the mean over many rows concentrates at 1/2
        let mut mean = 0.0;
        let n = 4000;
        for row in 0..n {
            mean += Rng::stream(42, &[7, row]).uniform();
        }
        mean /= n as f64;
        assert!((mean - 0.5).abs() < 0.03, "mean={mean}");
    }
}
